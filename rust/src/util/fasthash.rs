//! Vendored FxHash-style hasher (no external deps offline — see
//! DESIGN.md) for the scheduler's hot-path maps.
//!
//! `std::collections::HashMap`'s default SipHash buys DoS resistance the
//! simulator does not need and pays for it on every probe — and the hot
//! structures (eviction-policy membership, prefill job table, the sim's
//! pending/in-flight tables) are probed per chain block per scheduling
//! decision.  [`FastHasher`] is the rustc-style Fx construction: fold
//! each word in with a rotate + xor + odd-constant multiply.  Quality is
//! plenty for dense ids and monotone counters; speed is one multiply per
//! word.
//!
//! A pleasant side effect: `FastMap` iteration order is a pure function
//! of the insertion history (no per-process `RandomState` seed), so any
//! accidental order dependence is at least deterministic and
//! reproducible instead of flaking across runs.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Knuth's 64-bit multiplicative-hash constant (2^64 / φ, forced odd).
const SEED: u64 = 0x9e37_79b9_7f4a_7c15;
const ROTATE: u32 = 5;

/// One-word-at-a-time multiplicative hasher (FxHash construction).
#[derive(Debug, Default, Clone, Copy)]
pub struct FastHasher {
    hash: u64,
}

impl FastHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(ROTATE) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            // Mix the length in so "ab" + "\0" and "ab\0" differ.
            self.add(u64::from_le_bytes(tail) ^ ((rest.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FastBuild = BuildHasherDefault<FastHasher>;

/// `HashMap` with the Fx hasher — drop-in for the hot-path tables.
pub type FastMap<K, V> = HashMap<K, V, FastBuild>;

/// `HashSet` with the Fx hasher.
pub type FastSet<K> = HashSet<K, FastBuild>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        FastBuild::default().hash_one(v)
    }

    #[test]
    fn deterministic_across_builders() {
        for v in [0u64, 1, 42, u64::MAX, 0xdead_beef] {
            assert_eq!(hash_of(&v), hash_of(&v));
        }
        assert_eq!(hash_of(&"mooncake"), hash_of(&"mooncake"));
    }

    #[test]
    fn distinguishes_nearby_keys() {
        // Not a collision-resistance claim — just that the mixer actually
        // mixes (sequential ids must not collapse onto few buckets).
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(hash_of(&i));
        }
        assert_eq!(seen.len(), 10_000, "sequential u64 keys must hash distinctly");
        let mut low_bits = std::collections::HashSet::new();
        for i in 0..1_024u64 {
            low_bits.insert(hash_of(&i) & 1023);
        }
        assert!(low_bits.len() > 512, "low bits must spread: {}", low_bits.len());
    }

    #[test]
    fn byte_stream_tail_is_length_sensitive() {
        // Partial trailing chunks must not alias zero-padded longer input.
        assert_ne!(hash_of(&[1u8, 2][..]), hash_of(&[1u8, 2, 0][..]));
        assert_ne!(hash_of(&b"ab"[..]), hash_of(&b"ab\0"[..]));
    }

    #[test]
    fn fastmap_behaves_like_hashmap() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1_000u64 {
            m.insert(i, (i * 3) as u32);
        }
        assert_eq!(m.len(), 1_000);
        for i in 0..1_000u64 {
            assert_eq!(m.get(&i), Some(&((i * 3) as u32)));
        }
        let mut s: FastSet<u32> = FastSet::default();
        s.insert(7);
        assert!(s.contains(&7) && !s.contains(&8));
    }
}
