"""L1 prefill_attention kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import prefill_attention
from compile.kernels.ref import prefill_attention_ref


def _mk(rng, S, C, nh=4, kvh=2, hd=32, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(S, nh, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(C, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(C, kvh, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("S,start", [(64, 0), (64, 100), (128, 0), (128, 384)])
def test_matches_ref(S, start):
    rng = np.random.default_rng(0)
    C = 512
    q, k, v = _mk(rng, S, C)
    out = prefill_attention(q, k, v, jnp.asarray([start], jnp.int32))
    want = prefill_attention_ref(q, k, v, start, start + S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_no_prefix_is_plain_causal():
    """start=0 == standard causal self-attention over the chunk."""
    rng = np.random.default_rng(1)
    S = 128
    q, k, v = _mk(rng, S, S, hd=16)
    out = prefill_attention(q, k, v, jnp.asarray([0], jnp.int32), block_k=64)
    want = prefill_attention_ref(q, k, v, 0, S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_future_cache_ignored():
    """Entries past the chunk's last position must not affect the output."""
    rng = np.random.default_rng(2)
    S, start, C = 64, 64, 256
    q, k, v = _mk(rng, S, C)
    out1 = prefill_attention(q, k, v, jnp.asarray([start], jnp.int32))
    k2 = k.at[start + S:].set(1e9)
    v2 = v.at[start + S:].set(-1e9)
    out2 = prefill_attention(q, k2, v2, jnp.asarray([start], jnp.int32))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_chunking_invariance():
    """Two chunks through the kernel == one big chunk (CPP correctness)."""
    rng = np.random.default_rng(3)
    S, C = 128, 256
    q, k, v = _mk(rng, S, C)
    whole = prefill_attention(q, k, v, jnp.asarray([0], jnp.int32), block_q=64)
    first = prefill_attention(q[:64], k, v, jnp.asarray([0], jnp.int32))
    second = prefill_attention(q[64:], k, v, jnp.asarray([64], jnp.int32))
    np.testing.assert_allclose(np.asarray(whole[:64]), np.asarray(first), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(whole[64:]), np.asarray(second), rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    sblk=st.integers(1, 4),
    startblk=st.integers(0, 3),
    nh_mult=st.integers(1, 4),
    kvh=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(sblk, startblk, nh_mult, kvh, hd, seed):
    rng = np.random.default_rng(seed)
    S = 64 * sblk
    start = 64 * startblk
    C = ((start + S + 63) // 64) * 64 + 64  # cover chunk + slack
    nh = kvh * nh_mult
    q, k, v = _mk(rng, S, C, nh=nh, kvh=kvh, hd=hd)
    out = prefill_attention(q, k, v, jnp.asarray([start], jnp.int32), block_k=64)
    want = prefill_attention_ref(q, k, v, start, start + S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)
