//! Fault-injection integration tests: deterministic node loss with full
//! request accounting (nothing is ever silently lost), bit-for-bit
//! reproducibility of faulted runs, inert-when-empty plans, bounded
//! retry budgets, heterogeneous-cluster placement preference, and the
//! flash-crowd storm scenario against early rejection.

use mooncake::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
use mooncake::config::{NodeOverride, RejectionPolicy, SimConfig};
use mooncake::decode::DecodeInstance;
use mooncake::faults::{Bank, FaultPlan};
use mooncake::metrics::Outcome;
use mooncake::model::PerfModel;
use mooncake::prefill::PrefillPool;
use mooncake::resource::Resources;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::util::rng::Rng;
use mooncake::verify::Paranoia;

fn trace(n: usize, seed: u64) -> Vec<mooncake::trace::TraceRecord> {
    gen::generate(&TraceGenConfig {
        n_requests: n,
        duration_ms: 1_200_000,
        seed,
        ..Default::default()
    })
}

/// Bit-for-bit equality of two runs that must be indistinguishable.
fn assert_runs_identical(a: &sim::SimResult, b: &sim::SimResult) {
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.outcome, y.outcome, "request {}", x.id);
        assert_eq!(x.ttft_ms.to_bits(), y.ttft_ms.to_bits(), "request {}", x.id);
        assert_eq!(x.est_ttft_ms.to_bits(), y.est_ttft_ms.to_bits());
        assert_eq!(x.max_tbt_ms.to_bits(), y.max_tbt_ms.to_bits());
        assert_eq!(x.mean_tbt_ms.to_bits(), y.mean_tbt_ms.to_bits());
        assert_eq!(x.generated, y.generated);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits());
    }
    assert_eq!(a.conductor, b.conductor);
    assert_eq!(a.tier, b.tier);
    assert_eq!(a.wall_ms.to_bits(), b.wall_ms.to_bits());
    assert_eq!(a.transfer_bytes, b.transfer_bytes);
    assert_eq!(a.rejected_at_arrival, b.rejected_at_arrival);
    assert_eq!(a.rejected_at_decode, b.rejected_at_decode);
    assert_eq!(a.ssd_load_events, b.ssd_load_events);
    assert_eq!(a.ssd_loaded_bytes_by_node, b.ssd_loaded_bytes_by_node);
    assert_eq!(a.decode_tokens_out, b.decode_tokens_out);
    assert_eq!(a.n_events, b.n_events);
    assert_eq!(a.n_completed, b.n_completed);
    assert_eq!(a.n_rejected, b.n_rejected);
    assert_eq!(a.live_peak, b.live_peak);
    assert_eq!(a.interner_epochs, b.interner_epochs);
    assert_eq!(a.interner_freed, b.interner_freed);
    assert_eq!(a.interner_id_space, b.interner_id_space);
    assert_eq!(a.resources, b.resources);
    assert_eq!(a.load_samples.len(), b.load_samples.len());
    for (x, y) in a.load_samples.iter().zip(&b.load_samples) {
        assert_eq!(x.t.to_bits(), y.t.to_bits());
        assert_eq!(x.prefill_load.to_bits(), y.prefill_load.to_bits());
        assert_eq!(x.decode_load.to_bits(), y.decode_load.to_bits());
    }
    assert_eq!(a.faults, b.faults);
}

/// Every arrival is accounted exactly once: completed or rejected, with
/// one metrics row per request id.
fn assert_conservation(res: &sim::SimResult, n_arrivals: usize) {
    assert_eq!(
        res.n_completed + res.n_rejected,
        n_arrivals as u64,
        "completed + rejected must sum to arrivals — no silent loss"
    );
    assert_eq!(res.metrics.len(), n_arrivals, "one metrics row per request");
    for w in res.metrics.windows(2) {
        assert!(w[0].id < w[1].id, "request ids must be unique");
    }
    let completed = res.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count();
    assert_eq!(completed as u64, res.n_completed);
}

#[test]
fn node_loss_conserves_every_request_and_keeps_the_index_consistent() {
    // Overloaded 3-node prefill pool (speedup 20 compresses the hour of
    // arrivals into minutes) so node 1 dies at t = 45 s with a deep
    // queue: queued jobs cancel, orphans re-admit against the survivors.
    // Paranoia::Full asserts the prefix index equals a brute-force
    // rebuild of the pools every 1024 events *and* at the end — i.e. the
    // node-loss TierDelta left the index exactly consistent, with no
    // rebuild.
    let t = trace(600, 11);
    let cfg = SimConfig {
        n_prefill: 3,
        n_decode: 3,
        paranoia: Paranoia::Full,
        faults: FaultPlan::new().node_loss(1, 45_000.0).node_recover(1, 200_000.0),
        ..Default::default()
    };
    let res = sim::run(&cfg, &t, 20.0);
    assert_conservation(&res, t.len());
    assert_eq!(res.faults.injected, 2);
    assert_eq!(res.faults.nodes_lost, 1);
    assert_eq!(res.faults.nodes_recovered, 1);
    assert!(res.faults.jobs_killed > 0, "the loss must catch in-flight jobs");
    // Every cancelled job's request is re-admitted or rejected — the two
    // outcomes partition the orphan set.
    assert_eq!(
        res.faults.retried + res.faults.lost,
        res.faults.jobs_killed,
        "every orphan must be retried or counted lost"
    );
    assert!(res.faults.rescued <= res.faults.retried);
    // Rescued requests really completed: their rows carry finite TTFTs.
    for m in res.metrics.iter().filter(|m| m.outcome == Outcome::Completed) {
        assert!(m.ttft_ms.is_finite() && m.ttft_ms > 0.0);
    }
}

#[test]
fn same_plan_twice_is_bit_for_bit_identical() {
    let t = trace(400, 7);
    let cfg = SimConfig {
        n_prefill: 3,
        n_decode: 2,
        faults: FaultPlan::new()
            .node_loss(0, 30_000.0)
            .node_recover(0, 90_000.0)
            .bw_degrade(1, Bank::Nvme, 0.25, 0.0, 120_000.0),
        ..Default::default()
    };
    let a = sim::run(&cfg, &t, 8.0);
    let b = sim::run(&cfg, &t, 8.0);
    assert_runs_identical(&a, &b);
    assert!(a.faults.nodes_lost == 1 && a.faults.bw_changes == 2);
}

#[test]
fn empty_plan_and_inert_knobs_reproduce_the_baseline() {
    // An explicitly empty plan — and a retry budget, which is only
    // consulted when the plan is non-empty — must be bit-for-bit the
    // default healthy run.
    let t = trace(300, 3);
    let base = SimConfig::default();
    let knobs = SimConfig {
        faults: FaultPlan::new(),
        fault_retry_budget: 99,
        ..Default::default()
    };
    let a = sim::run(&base, &t, 2.0);
    let b = sim::run(&knobs, &t, 2.0);
    assert_runs_identical(&a, &b);
    assert_eq!(a.faults, mooncake::faults::FaultStats::default());
}

#[test]
fn zero_retry_budget_rejects_every_orphan_loudly() {
    let t = trace(500, 13);
    let cfg = SimConfig {
        n_prefill: 3,
        n_decode: 3,
        fault_retry_budget: 0,
        faults: FaultPlan::new().node_loss(2, 40_000.0),
        ..Default::default()
    };
    let res = sim::run(&cfg, &t, 20.0);
    assert_conservation(&res, t.len());
    assert!(res.faults.jobs_killed > 0);
    assert_eq!(res.faults.retried, 0, "budget 0 must retry nothing");
    assert_eq!(res.faults.rescued, 0);
    assert_eq!(res.faults.lost, res.faults.jobs_killed);
    // The losses surface as ordinary rejections, not vanished requests.
    assert!(res.n_rejected >= res.faults.lost);
}

#[test]
fn bw_degrade_window_applies_and_restores() {
    // NVMe at 25% across a window plus a halved NIC-tx: the run still
    // completes with full accounting and records the degrade + restore
    // edges.  DRAM is squeezed so staging reads actually traverse the
    // degraded NVMe queue.
    let t = trace(300, 17);
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(50_000),
        paranoia: Paranoia::Full,
        faults: FaultPlan::new()
            .bw_degrade(0, Bank::Nvme, 0.25, 10_000.0, 200_000.0)
            .bw_degrade(1, Bank::NicTx, 0.5, 10_000.0, 200_000.0),
        ..Default::default()
    };
    let res = sim::run(&cfg, &t, 4.0);
    assert_conservation(&res, t.len());
    assert_eq!(res.faults.injected, 2);
    assert_eq!(res.faults.bw_changes, 4, "each window is a degrade + a restore");
    assert_eq!(res.faults.nodes_lost, 0);
}

#[test]
fn conductor_prefers_the_fast_node_when_estimates_differ() {
    // Two idle nodes, no cache anywhere, node 1 three times faster: the
    // KVCache-centric policy's min-estimated-TTFT choice must land on
    // node 1 — and on the homogeneous cluster the same tie falls to
    // node 0, proving the preference comes from the speed estimate.
    let run_once = |overrides: Vec<NodeOverride>| -> usize {
        let cfg = SimConfig {
            n_prefill: 2,
            n_decode: 1,
            node_overrides: overrides,
            ..Default::default()
        };
        let perf = PerfModel::paper();
        let mut prefill = PrefillPool::new(&cfg);
        let decodes =
            vec![DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch)];
        let mut res = Resources::new(&cfg, &perf);
        let mut rng = Rng::new(1);
        let mut scratch = SchedScratch::default();
        let mut stats = ConductorStats::default();
        let req = SchedRequest {
            rid: 1,
            input_tokens: 16_384,
            output_tokens: 64,
            hash_ids: Vec::new(),
        };
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now: 0.0,
            index: None,
            scratch: &mut scratch,
        };
        let pl = conductor::schedule(&mut ctx, &req, &mut stats).expect("idle cluster admits");
        pl.prefill_group[0]
    };
    let fast = run_once(vec![NodeOverride {
        node: 1,
        speed: 3.0,
        dram_blocks: None,
        ssd_blocks: None,
    }]);
    assert_eq!(fast, 1, "the 3x node must win the estimated-TTFT comparison");
    let homog = run_once(Vec::new());
    assert_eq!(homog, 0, "equal estimates tie-break to the lowest node id");
}

#[test]
fn heterogeneous_cluster_estimates_still_match_actuals() {
    // Mixed speeds and asymmetric capacities must not break the
    // estimate == actual contract the scheduler's SLO gates ride on.
    let t = trace(300, 19);
    let cfg = SimConfig {
        n_prefill: 3,
        n_decode: 2,
        node_overrides: vec![
            NodeOverride { node: 0, speed: 2.88, dram_blocks: None, ssd_blocks: None },
            NodeOverride { node: 2, speed: 1.0, dram_blocks: Some(5_000), ssd_blocks: Some(20_000) },
        ],
        paranoia: Paranoia::Full,
        ..Default::default()
    };
    let res = sim::run(&cfg, &t, 4.0);
    assert_conservation(&res, t.len());
    let rep = res.report(&cfg);
    assert!(
        rep.ttft_est_mae < 1.0,
        "estimate/actual drift {} ms on the heterogeneous cluster",
        rep.ttft_est_mae
    );
}

#[test]
fn flash_crowd_storm_engages_early_rejection_then_drains() {
    // A storm packs half the trace into one 20 s window.  Early
    // rejection must fire during the spike, the backlog must drain
    // afterwards, and conservation must hold throughout.
    let storm_start = 300_000u64;
    let storm_width = 20_000u64;
    let t = gen::generate(&TraceGenConfig {
        n_requests: 2_500,
        duration_ms: 1_200_000,
        seed: 7,
        storm_fraction: 0.5,
        storm_start_ms: storm_start,
        storm_width_ms: storm_width,
        ..Default::default()
    });
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        rejection: RejectionPolicy::Early,
        ..Default::default()
    };
    let res = sim::run(&cfg, &t, 1.0);
    assert_conservation(&res, t.len());
    assert!(res.n_rejected > 0, "the spike must engage early rejection");
    // Rejection concentrates in the spike; the quiet tail mostly clears.
    let (mut rej_in, mut tot_in, mut rej_late, mut tot_late) = (0u64, 0u64, 0u64, 0u64);
    for m in &res.metrics {
        let arr = m.arrival as u64;
        let rejected = m.outcome != Outcome::Completed;
        if arr >= storm_start && arr < storm_start + storm_width {
            tot_in += 1;
            rej_in += rejected as u64;
        } else if arr >= storm_start + 300_000 {
            tot_late += 1;
            rej_late += rejected as u64;
        }
    }
    assert!(tot_in > 500 && tot_late > 100, "storm shape: {tot_in} in, {tot_late} late");
    let rate_in = rej_in as f64 / tot_in as f64;
    let rate_late = rej_late as f64 / tot_late as f64;
    assert!(
        rate_in > 0.2,
        "rejection must engage during the spike (rate {rate_in:.3})"
    );
    assert!(
        rate_late < rate_in / 2.0,
        "the pool must drain after the spike: late rate {rate_late:.3} vs spike {rate_in:.3}"
    );
}
