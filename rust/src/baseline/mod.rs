//! vLLM-like *coupled* serving baseline (§8's comparison system).
//!
//! Each instance runs prefill and decode on the same GPUs with continuous
//! batching: at every iteration boundary the engine either (a) runs the
//! prefill of the next queued request as an exclusive iteration — during
//! which every decoding sequence stalls (the long-context TBT spikes the
//! paper observes in vLLM) — or (b) runs one decode step for the active
//! batch.  Dispatch across the M instances is least-loaded.
//!
//! No disaggregation, no KVCache transfer, no prefix reuse (the paper
//! notes open-source vLLM's caching is local-only; its end-to-end
//! baseline runs without Mooncake's global pool).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::SloConfig;
use crate::decode::DecodeInstance;
use crate::metrics::{self, Outcome, RequestMetrics};
use crate::model::PerfModel;
use crate::sim::Request;
use crate::trace::TraceRecord;
use crate::{RequestId, TimeMs};

#[derive(Debug, Clone)]
pub struct VllmConfig {
    pub n_instances: usize,
    pub max_batch: usize,
    pub slo: SloConfig,
    /// §8.1.2: long-context experiments run vLLM "individually, rather
    /// than in batches" — cap concurrent decodes at 1 when set.
    pub serial_mode: bool,
}

impl Default for VllmConfig {
    fn default() -> Self {
        VllmConfig {
            n_instances: 4,
            max_batch: 128,
            slo: SloConfig { ttft_ms: 30_000.0, tbt_ms: 100.0 },
            serial_mode: false,
        }
    }
}

#[derive(Debug)]
struct Instance {
    decode: DecodeInstance,
    prefill_queue: VecDeque<(RequestId, u64, u64, TimeMs)>, // rid, in, out, arrival
    /// In an exclusive prefill iteration until this time (if > now).
    iterating: bool,
    seq: u64,
}

#[derive(Debug, Clone)]
enum Ev {
    Arrival(usize),
    /// End of an iteration (prefill or decode) on an instance.
    IterEnd { inst: usize, seq: u64, kind: IterKind },
}

#[derive(Debug, Clone)]
enum IterKind {
    Prefill { rid: RequestId, dur: f64 },
    Decode { dur: f64 },
}

#[derive(Debug, Clone)]
struct Event {
    t: TimeMs,
    order: u64,
    ev: Ev,
}
impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.order == o.order
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Event {
    fn cmp(&self, o: &Self) -> Ordering {
        o.t.total_cmp(&self.t).then_with(|| o.order.cmp(&self.order))
    }
}

pub struct VllmSim {
    #[allow(dead_code)]
    cfg: VllmConfig,
    perf: PerfModel,
    instances: Vec<Instance>,
    events: BinaryHeap<Event>,
    order: u64,
    pending: std::collections::HashMap<RequestId, (TimeMs, u64, u64, f64)>,
    metrics: Vec<RequestMetrics>,
}

impl VllmSim {
    pub fn new(cfg: VllmConfig) -> Self {
        let perf = PerfModel::paper();
        let max_batch = if cfg.serial_mode { 1 } else { cfg.max_batch };
        let instances = (0..cfg.n_instances)
            .map(|_| Instance {
                decode: DecodeInstance::new(perf.vram_kv_capacity_tokens(), max_batch),
                prefill_queue: VecDeque::new(),
                iterating: false,
                seq: 0,
            })
            .collect();
        VllmSim {
            cfg,
            perf,
            instances,
            events: BinaryHeap::new(),
            order: 0,
            pending: std::collections::HashMap::new(),
            metrics: Vec::new(),
        }
    }

    fn push(&mut self, t: TimeMs, ev: Ev) {
        self.order += 1;
        self.events.push(Event { t, order: self.order, ev });
    }

    /// Start the next iteration on an instance, if any work exists.
    /// Prefill-first matches vLLM's default scheduler.
    fn kick(&mut self, i: usize, now: TimeMs) {
        if self.instances[i].iterating {
            return;
        }
        // Admit decoded-waiting first so batch state is current.
        self.instances[i].decode.admit_waiting();
        let inst = &mut self.instances[i];
        inst.seq += 1;
        let seq = inst.seq;
        if let Some(&(rid, input, _out, _arr)) = inst.prefill_queue.front() {
            // VRAM check: prefill KV must fit beside the active batch.
            let fits = inst.decode.kv_tokens() + input <= inst.decode.kv_capacity_tokens;
            if fits {
                inst.prefill_queue.pop_front();
                inst.iterating = true;
                let dur = self.perf.prefill_ms(input, 0);
                self.push(now + dur, Ev::IterEnd { inst: i, seq, kind: IterKind::Prefill { rid, dur } });
                return;
            }
        }
        if !inst.decode.active.is_empty() {
            inst.iterating = true;
            let dur = inst.decode.step_duration_ms(&self.perf);
            self.push(now + dur, Ev::IterEnd { inst: i, seq, kind: IterKind::Decode { dur } });
        }
    }

    pub fn run(mut self, trace: &[TraceRecord], speedup: f64) -> (Vec<RequestMetrics>, TimeMs) {
        let requests: Vec<Request> = trace
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut q = Request::from_trace(i as RequestId, r);
                q.arrival /= speedup;
                q
            })
            .collect();
        for (i, r) in requests.iter().enumerate() {
            self.push(r.arrival, Ev::Arrival(i));
        }

        let mut now = 0.0;
        while let Some(Event { t, ev, .. }) = self.events.pop() {
            now = t;
            match ev {
                Ev::Arrival(idx) => {
                    let r = &requests[idx];
                    // Least-loaded dispatch (active + queued).
                    let i = (0..self.instances.len())
                        .min_by_key(|&i| {
                            let inst = &self.instances[i];
                            inst.decode.active.len()
                                + inst.decode.waiting.len()
                                + inst.prefill_queue.len()
                        })
                        .unwrap();
                    self.instances[i]
                        .prefill_queue
                        .push_back((r.rid, r.input, r.output, r.arrival));
                    self.pending.insert(r.rid, (r.arrival, r.input, r.output, f64::NAN));
                    self.kick(i, now);
                }
                Ev::IterEnd { inst, seq, kind } => {
                    if self.instances[inst].seq != seq {
                        continue;
                    }
                    self.instances[inst].iterating = false;
                    match kind {
                        IterKind::Prefill { rid, dur } => {
                            let p = self.pending.get_mut(&rid).unwrap();
                            p.3 = now - p.0; // TTFT = prefill completion - arrival
                            let (_, input, out, _) = *self.pending.get(&rid).unwrap();
                            self.instances[inst].decode.enqueue(rid, input, out, now);
                            let _ = dur;
                        }
                        IterKind::Decode { dur } => {
                            let done = self.instances[inst].decode.finish_step(now, dur);
                            for f in done {
                                let (arr, input, out, ttft) =
                                    self.pending.remove(&f.rid).unwrap();
                                self.metrics.push(RequestMetrics {
                                    id: f.rid,
                                    arrival: arr,
                                    input_tokens: input,
                                    output_tokens: out,
                                    outcome: Outcome::Completed,
                                    ttft_ms: ttft,
                                    // The coupled baseline has no TTFT
                                    // estimator (no admission gates).
                                    est_ttft_ms: f64::NAN,
                                    max_tbt_ms: f.max_gap,
                                    mean_tbt_ms: f.mean_gap,
                                    generated: f.generated,
                                    finish: now,
                                });
                            }
                        }
                    }
                    self.kick(inst, now);
                }
            }
        }
        assert!(self.pending.is_empty(), "vllm sim left requests unfinished");
        self.metrics.sort_by(|a, b| a.id.cmp(&b.id));
        (self.metrics, now)
    }
}

/// Run the baseline and aggregate (mirrors `sim::run` + `report`).
pub fn run(cfg: &VllmConfig, trace: &[TraceRecord], speedup: f64) -> metrics::RunReport {
    let (ms, wall) = VllmSim::new(cfg.clone()).run(trace, speedup);
    metrics::report(&ms, cfg.slo.ttft_ms, cfg.slo.tbt_ms, wall)
}

/// Run and keep the raw per-request metrics (Fig 13 CDFs).
pub fn run_raw(cfg: &VllmConfig, trace: &[TraceRecord], speedup: f64) -> (Vec<RequestMetrics>, TimeMs) {
    VllmSim::new(cfg.clone()).run(trace, speedup)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen;

    #[test]
    fn completes_everything() {
        let trace = gen::dataset("arxiv", 80, 0.5, 1);
        let cfg = VllmConfig::default();
        let rep = run(&cfg, &trace, 1.0);
        assert_eq!(rep.n_completed, 80);
        assert_eq!(rep.n_rejected_arrival + rep.n_rejected_after_prefill, 0);
    }

    #[test]
    fn long_context_prefill_spikes_tbt() {
        // Interleave long-context requests with active decodes: the
        // exclusive prefill iterations must stretch some token gap far
        // beyond a clean decode step.
        let trace = gen::dataset("sim64k", 40, 0.5, 2);
        let cfg = VllmConfig { n_instances: 1, ..Default::default() };
        let rep = run(&cfg, &trace, 1.0);
        let clean_step = PerfModel::paper().decode_step_ms(8, 8 * 65_536);
        assert!(
            rep.tbt_p90 > clean_step * 3.0,
            "p90 TBT {} should show prefill stalls >> step {}",
            rep.tbt_p90,
            clean_step
        );
    }

    #[test]
    fn serial_mode_limits_batch() {
        let trace = gen::dataset("sim16k", 30, 2.0, 3);
        let cfg = VllmConfig { n_instances: 1, serial_mode: true, ..Default::default() };
        let rep = run(&cfg, &trace, 1.0);
        assert_eq!(rep.n_completed, 30);
    }

    #[test]
    fn more_instances_lower_latency() {
        let trace = gen::dataset("arxiv", 120, 1.0, 4);
        let one = run(&VllmConfig { n_instances: 1, ..Default::default() }, &trace, 1.0);
        let four = run(&VllmConfig { n_instances: 4, ..Default::default() }, &trace, 1.0);
        assert!(four.ttft_p90 <= one.ttft_p90);
    }
}
