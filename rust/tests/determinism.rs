//! Determinism regression: the simulator is a pure function of
//! (config, trace) — two runs in the same process must agree
//! bit-for-bit, and the default generator stream is pinned by a golden
//! hash.  This is the runtime twin of `pallas_lint`'s static rules
//! (no std hashers, no wall clocks, no unordered iteration on the
//! deterministic side): the lint bans the mechanisms, this test pins
//! the outcome.

use mooncake::config::SimConfig;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::TraceRecord;
use mooncake::verify::Paranoia;

fn default_trace() -> Vec<TraceRecord> {
    gen::generate(&TraceGenConfig { n_requests: 1_000, ..Default::default() })
}

/// FNV-1a over every trace field — the same pin as the golden-stream
/// integration test, asserted here on the exact trace the sim runs on.
fn trace_hash(trace: &[TraceRecord]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for r in trace {
        mix(r.timestamp);
        mix(r.input_length);
        mix(r.output_length);
        mix(r.hash_ids.len() as u64);
        for &b in &r.hash_ids {
            mix(b);
        }
    }
    h
}

/// Bit-for-bit equality of two runs (floats compared via `to_bits` — an
/// "equal within epsilon" drift is exactly the bug this test exists to
/// catch).
fn assert_runs_identical(a: &sim::SimResult, b: &sim::SimResult) {
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.outcome, y.outcome, "request {}", x.id);
        assert_eq!(x.ttft_ms.to_bits(), y.ttft_ms.to_bits(), "request {}", x.id);
        assert_eq!(x.est_ttft_ms.to_bits(), y.est_ttft_ms.to_bits());
        assert_eq!(x.max_tbt_ms.to_bits(), y.max_tbt_ms.to_bits());
        assert_eq!(x.mean_tbt_ms.to_bits(), y.mean_tbt_ms.to_bits());
        assert_eq!(x.generated, y.generated);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits());
    }
    assert_eq!(a.conductor, b.conductor);
    assert_eq!(a.tier, b.tier);
    assert_eq!(a.wall_ms.to_bits(), b.wall_ms.to_bits());
    assert_eq!(a.transfer_bytes, b.transfer_bytes);
    assert_eq!(a.rejected_at_arrival, b.rejected_at_arrival);
    assert_eq!(a.rejected_at_decode, b.rejected_at_decode);
    assert_eq!(a.ssd_load_events, b.ssd_load_events);
    assert_eq!(a.ssd_loaded_bytes_by_node, b.ssd_loaded_bytes_by_node);
    assert_eq!(a.decode_tokens_out, b.decode_tokens_out);
    assert_eq!(a.n_events, b.n_events);
    assert_eq!(a.resources, b.resources);
    assert_eq!(a.load_samples.len(), b.load_samples.len());
    for (x, y) in a.load_samples.iter().zip(&b.load_samples) {
        assert_eq!(x.t.to_bits(), y.t.to_bits());
        assert_eq!(x.prefill_load.to_bits(), y.prefill_load.to_bits());
        assert_eq!(x.decode_load.to_bits(), y.decode_load.to_bits());
    }
}

#[test]
fn same_process_reruns_are_bit_identical() {
    // Two independent generations must agree with each other *and* with
    // the golden stream pin — any ambient-state leak (a randomized
    // hasher, a wall-clock read, address-dependent iteration) breaks
    // one of the two.
    let t1 = default_trace();
    let t2 = default_trace();
    assert_eq!(trace_hash(&t1), 0x7aa958e3910f7633, "default trace stream drifted");
    assert_eq!(trace_hash(&t2), trace_hash(&t1), "trace generation is not a pure function");

    let cfg = SimConfig::default();
    let a = sim::run(&cfg, &t1, 1.0);
    let b = sim::run(&cfg, &t2, 1.0);
    assert!(a.n_events > 0);
    assert_runs_identical(&a, &b);
}

#[test]
fn paranoia_level_does_not_perturb_results() {
    // The `verify::Paranoia` knob turns self-checks on and off; the
    // checks are read-only, so every level must produce the same run
    // bit-for-bit (`Full` additionally proves the index invariant holds
    // in release builds, where `Debug` compiles the check out).
    let t = default_trace();
    let base = sim::run(&SimConfig::default(), &t, 1.0);
    for level in [Paranoia::Off, Paranoia::Full] {
        let cfg = SimConfig { paranoia: level, ..Default::default() };
        let r = sim::run(&cfg, &t, 1.0);
        assert_runs_identical(&base, &r);
    }
}
