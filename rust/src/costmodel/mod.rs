//! The unified cost model — the **single source of timing truth** for
//! prefill scheduling.
//!
//! Algorithm 1 (§6) only works if Conductor's TTFT *estimates* agree with
//! what the cluster actually *does*: SLO-gated admission and early
//! rejection (§7) both compare an estimate against a limit, so any drift
//! between the estimator and the executor silently re-tunes every
//! threshold.  Historically the two were separate code paths
//! (`conductor::est_ttft` summed queue+transfer+compute analytically
//! while `PrefillPool::run_prefill` re-derived start/end with different
//! rules — e.g. the estimate charged the remote-prefix fetch to the
//! *destination* NIC and added fetch and queue serially, where execution
//! used the *source* NIC and overlapped the fetch with queue drain).
//!
//! Now both sides call this module:
//!
//! * [`estimate_prefill`] — Conductor's `EstimatePrefillExecutionTime` +
//!   `EstimateKVCacheTransferTime` + queue probe, returning an absolute
//!   planned (start, end) window;
//! * [`crate::prefill::PrefillPool::submit`] — the executor admits a job
//!   using the *same* function of the *same* state, so the simulator's
//!   `PrefillStart`/`PrefillDone` events land exactly where the estimate
//!   said they would (a property `rust/tests/cost_model_agreement.rs`
//!   asserts end-to-end).

use crate::config::SimConfig;
use crate::messenger::Messenger;
use crate::model::PerfModel;
use crate::prefill::PrefillPool;
use crate::trace::BLOCK_TOKENS;
use crate::TimeMs;

/// Fraction of the local DRAM→VRAM prefix load that stays on the critical
/// path: loading reused KVCache overlaps layer-wise with computation
/// (§5.2), but it bounds when the first layer can start, so a small
/// non-overlapped head remains visible.
pub const PREFIX_LOAD_VISIBLE_FRACTION: f64 = 0.1;

/// Visible (non-overlapped) latency of loading `prefix_tokens` of reused
/// KVCache from local CPU DRAM before prefill can run.
pub fn prefix_load_ms(perf: &PerfModel, prefix_tokens: u64) -> f64 {
    perf.dram_load_ms(prefix_tokens) * PREFIX_LOAD_VISIBLE_FRACTION
}

/// Staging latency of the SSD-resident part of a reused prefix: the
/// NVMe read lands the blocks in DRAM *before* the layer-wise DRAM→VRAM
/// load can touch them, so — unlike the DRAM load — it sits fully on the
/// critical path.  That asymmetry is exactly what makes recomputation
/// competitive with loading for shallow prefixes (the "compute or load?"
/// branch of Algorithm 1's three-way prefix decision).
pub fn ssd_stage_ms(perf: &PerfModel, ssd_prefix_tokens: u64) -> f64 {
    perf.ssd_load_ms(ssd_prefix_tokens, ssd_prefix_tokens.div_ceil(BLOCK_TOKENS))
}

/// Execution makespan of one prefill job on a CPP group of `group_len`
/// nodes: chunked-pipeline compute, the visible prefix-load head, and
/// the SSD staging of the `ssd_prefix_tokens` ⊆ `prefix_tokens` that
/// live on the slow tier.  This is the ONE definition of "how long a
/// prefill takes" — both the estimator and the executor use it.
pub fn prefill_exec_ms(
    perf: &PerfModel,
    cfg: &SimConfig,
    n_new: u64,
    prefix_tokens: u64,
    ssd_prefix_tokens: u64,
    group_len: u64,
) -> f64 {
    debug_assert!(ssd_prefix_tokens <= prefix_tokens);
    perf.cpp_prefill_ms(n_new, prefix_tokens, cfg.prefill_chunk, group_len)
        + prefix_load_ms(perf, prefix_tokens)
        + ssd_stage_ms(perf, ssd_prefix_tokens)
}

/// Wire bytes of a remote prefix fetch of `blocks` cache blocks (§6.2).
pub fn fetch_bytes(perf: &PerfModel, blocks: usize) -> u64 {
    blocks as u64 * BLOCK_TOKENS * perf.model.kv_bytes_per_token()
}

/// A remote §6.2 prefix fetch: `blocks` cache blocks pulled from `src`,
/// of which `src_ssd_blocks` live on the **source's SSD tier** and must
/// be staged into its DRAM before the NIC can serialize them — so the
/// fetch pays `ssd_stage_ms` *and then* the wire, both on the source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FetchPlan {
    pub src: usize,
    pub blocks: usize,
    pub src_ssd_blocks: usize,
}

impl FetchPlan {
    /// Staging latency on the source before its NIC can start (ms).
    pub fn src_stage_ms(&self, perf: &PerfModel) -> f64 {
        ssd_stage_ms(perf, self.src_ssd_blocks as u64 * BLOCK_TOKENS)
    }
}

/// Wire bytes of the layer-wise KVCache stream to the decode node (§5.2).
pub fn kv_stream_bytes(perf: &PerfModel, input_tokens: u64) -> u64 {
    input_tokens * perf.model.kv_bytes_per_token()
}

/// A placement's predicted timing, in absolute simulator time.
#[derive(Debug, Clone)]
pub struct PrefillEstimate {
    /// CPP group the job would run on (primary first).
    pub group: Vec<usize>,
    /// Planned start: the job runs when its whole group has drained AND
    /// any remote prefix fetch has landed (the two overlap — they are
    /// `max`ed, not summed).
    pub start: TimeMs,
    /// Planned completion (start + exec) — the TTFT moment.
    pub end: TimeMs,
    /// Wait behind the group's committed FIFO work, ms from now.
    pub queue_wait_ms: f64,
    /// Remote-prefix fetch landing delay, ms from now, charged to the
    /// **source** node's NIC (its congestion is what §6.1 worries about).
    pub fetch_wait_ms: f64,
    /// Execution makespan from [`prefill_exec_ms`].
    pub exec_ms: f64,
}

impl PrefillEstimate {
    /// Estimated TTFT relative to `now` (what Algorithm 1 line 25 gates).
    pub fn ttft_ms(&self, now: TimeMs) -> f64 {
        self.end - now
    }
}

/// Estimate a prefill on `primary` with `n_new` uncached tokens and
/// `prefix_tokens` reused ones, of which `ssd_prefix_tokens` must first
/// be staged up from the node's SSD tier; `fetch` adds a remote prefix
/// fetch that must land first — charged to the source's NVMe (staging)
/// and then its NIC.  Read-only: probes the prefill queues and the
/// source NIC without mutating either.
#[allow(clippy::too_many_arguments)]
pub fn estimate_prefill(
    perf: &PerfModel,
    cfg: &SimConfig,
    pool: &PrefillPool,
    messenger: &Messenger,
    primary: usize,
    n_new: u64,
    prefix_tokens: u64,
    ssd_prefix_tokens: u64,
    fetch: Option<FetchPlan>,
    now: TimeMs,
) -> PrefillEstimate {
    let group = pool.cpp_group(cfg, primary, n_new, now);
    let exec_ms =
        prefill_exec_ms(perf, cfg, n_new, prefix_tokens, ssd_prefix_tokens, group.len() as u64);
    let queue_free = pool.group_free_at(&group).max(now);
    let fetch_done = match fetch {
        Some(f) if f.blocks > 0 => {
            let stage_done = now + f.src_stage_ms(perf);
            stage_done + messenger.estimate_ms(f.src, stage_done, fetch_bytes(perf, f.blocks))
        }
        _ => now,
    };
    let start = queue_free.max(fetch_done);
    PrefillEstimate {
        group,
        start,
        end: start + exec_ms,
        queue_wait_ms: queue_free - now,
        fetch_wait_ms: fetch_done - now,
        exec_ms,
    }
}

/// When the streamed KVCache lands at the decode node: the layer-wise
/// stream starts with the prefill and can finish no earlier than the
/// prefill itself nor than the wire time on the primary's NIC.
pub fn estimate_kv_arrival(
    perf: &PerfModel,
    messenger: &Messenger,
    primary: usize,
    start: TimeMs,
    end: TimeMs,
    input_tokens: u64,
) -> TimeMs {
    let stream_end =
        start + messenger.estimate_ms(primary, start, kv_stream_bytes(perf, input_tokens));
    stream_end.max(end)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn env() -> (SimConfig, PerfModel, PrefillPool, Messenger) {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let msgr = Messenger::new(cfg.n_prefill + cfg.n_decode, perf.hw.rdma_bw, 1.0);
        (cfg, perf, pool, msgr)
    }

    #[test]
    fn exec_includes_visible_prefix_load() {
        let (cfg, perf, _, _) = env();
        let cold = prefill_exec_ms(&perf, &cfg, 8_000, 0, 0, 1);
        assert_eq!(cold, perf.prefill_ms(8_000, 0));
        // Fully cached input still pays the non-overlapped load head.
        let warm = prefill_exec_ms(&perf, &cfg, 0, 8_000, 0, 1);
        assert!(warm > 0.0 && warm < cold * 0.05, "warm={warm} cold={cold}");
        assert!((warm - prefix_load_ms(&perf, 8_000)).abs() < 1e-9);
    }

    #[test]
    fn ssd_staging_on_critical_path_and_crossover() {
        let (cfg, perf, _, _) = env();
        // An SSD-resident prefix pays the full staging latency on top of
        // the DRAM load head.
        let dram_warm = prefill_exec_ms(&perf, &cfg, 0, 8_000, 0, 1);
        let ssd_warm = prefill_exec_ms(&perf, &cfg, 0, 8_000, 8_000, 1);
        assert!((ssd_warm - dram_warm - ssd_stage_ms(&perf, 8_000)).abs() < 1e-9);
        assert!(ssd_warm > 10.0 * dram_warm, "{ssd_warm} vs {dram_warm}");
        // The load-vs-recompute crossover both ways, through the ONE
        // makespan definition the scheduler and executor share:
        // deep prefix — loading from SSD beats recomputing it...
        let deep = 32_768u64;
        let load_deep = prefill_exec_ms(&perf, &cfg, 0, deep, deep, 1);
        let recompute_deep = prefill_exec_ms(&perf, &cfg, deep, 0, 0, 1);
        assert!(load_deep < recompute_deep, "{load_deep} !< {recompute_deep}");
        // ...shallow prefix — recomputing beats the NVMe read.
        let shallow = 512u64;
        let load_shallow = prefill_exec_ms(&perf, &cfg, 0, shallow, shallow, 1);
        let recompute_shallow = prefill_exec_ms(&perf, &cfg, shallow, 0, 0, 1);
        assert!(
            recompute_shallow < load_shallow,
            "{recompute_shallow} !< {load_shallow}"
        );
    }

    #[test]
    fn fetch_charged_to_source_nic() {
        let (cfg, perf, pool, mut msgr) = env();
        // Congest node 2's outgoing NIC; node 5 stays idle.
        msgr.schedule(2, 0.0, 2_000_000_000_000); // ~20 s backlog
        let dram_fetch = |src| Some(FetchPlan { src, blocks: 4, src_ssd_blocks: 0 });
        let idle =
            estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 4_096, 2_048, 0, dram_fetch(5), 0.0);
        let congested =
            estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 4_096, 2_048, 0, dram_fetch(2), 0.0);
        assert!(
            congested.fetch_wait_ms > idle.fetch_wait_ms + 10_000.0,
            "source congestion must surface: {} vs {}",
            congested.fetch_wait_ms,
            idle.fetch_wait_ms
        );
        assert!(congested.end > idle.end + 10_000.0);
    }

    #[test]
    fn fetch_overlaps_queue_wait() {
        let (cfg, perf, mut pool, mut msgr) = env();
        pool.instances[0].block_until(5_000.0);
        msgr.schedule(3, 0.0, 300_000_000_000); // ~3 s source backlog
        let fetch = Some(FetchPlan { src: 3, blocks: 4, src_ssd_blocks: 0 });
        let est = estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 4_096, 2_048, 0, fetch, 0.0);
        // start = max(queue, fetch), not their sum.
        assert!(est.queue_wait_ms >= 5_000.0);
        assert!(est.fetch_wait_ms > 2_000.0 && est.fetch_wait_ms < 5_000.0);
        assert!((est.start - 5_000.0).abs() < 1e-6, "start={}", est.start);
    }

    #[test]
    fn fetch_charges_source_ssd_staging_before_the_wire() {
        // A source holding the fetched prefix on its SSD tier must stage
        // it into DRAM before the NIC can serialize — the estimate pays
        // NVMe *then* wire, serially, on the source.
        let (cfg, perf, pool, msgr) = env();
        let blocks = 64usize;
        let dram = FetchPlan { src: 3, blocks, src_ssd_blocks: 0 };
        let ssd = FetchPlan { src: 3, blocks, src_ssd_blocks: blocks };
        let a = estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 4_096, 0, 0, Some(dram), 0.0);
        let b = estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 4_096, 0, 0, Some(ssd), 0.0);
        let stage = ssd.src_stage_ms(&perf);
        assert!(stage > 0.0);
        assert!(
            (b.fetch_wait_ms - a.fetch_wait_ms - stage).abs() < 1e-9,
            "SSD-held source must add exactly the staging latency: {} vs {} (+{stage})",
            b.fetch_wait_ms,
            a.fetch_wait_ms
        );
        assert!((b.end - a.end - stage).abs() < 1e-9);
    }

    #[test]
    fn estimate_reads_group_queue_not_just_primary() {
        let (cfg, perf, mut pool, msgr) = env();
        // Only instance 1 is recruitable (others exceed the 1 ms recruit
        // threshold); its 0.5 ms backlog must drive the planned start.
        pool.instances[1].block_until(0.5);
        for i in 2..pool.len() {
            pool.instances[i].block_until(10.0);
        }
        let est = estimate_prefill(&perf, &cfg, &pool, &msgr, 0, 100_000, 0, 0, None, 0.0);
        assert_eq!(est.group, vec![0, 1]);
        assert!((est.start - 0.5).abs() < 1e-9, "group max drives start: {}", est.start);
        assert!((est.queue_wait_ms - 0.5).abs() < 1e-9);
    }

    #[test]
    fn kv_arrival_no_earlier_than_prefill_end() {
        let (_, perf, _, msgr) = env();
        let a = estimate_kv_arrival(&perf, &msgr, 0, 100.0, 5_000.0, 1_000);
        assert!(a >= 5_000.0);
        // Huge stream on a short prefill: the wire dominates.
        let b = estimate_kv_arrival(&perf, &msgr, 0, 100.0, 200.0, 100_000);
        assert!(b > 200.0 + 100.0);
    }
}
