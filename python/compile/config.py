"""Model configuration shared by the L1 kernels, the L2 model, and AOT.

The live-path model is a scaled-down LLaMA-architecture transformer (the
paper's experiments use a "dummy model that follows the same architecture
as LLaMA2-70B"; we keep the architecture — RMSNorm, RoPE, GQA, SwiGLU —
and shrink the dimensions so the CPU PJRT client can serve it).  The
LLaMA2-70B constants used by the Rust analytic performance model live in
`rust/src/model/llama.rs`; keep the two in sync via the manifest.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of the tiny dummy model."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 384
    max_ctx: int = 1024          # per-request KVCache capacity (tokens)
    rope_base: float = 10000.0
    page: int = 64               # KVCache page size used by paged kernels

    # AOT shape buckets.  Rust picks the smallest bucket that fits.
    prefill_buckets: tuple = (64, 256)
    decode_buckets: tuple = (1, 4, 8)

    def __post_init__(self):
        assert self.n_heads * self.head_dim == self.d_model or True
        assert self.n_heads % self.n_kv_heads == 0
        assert self.max_ctx % self.page == 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group(self) -> int:
        """Query heads per KV head (GQA group size)."""
        return self.n_heads // self.n_kv_heads

    def param_specs(self):
        """Ordered (name, shape) list — the AOT parameter ABI.

        Rust reads `weights.npz` and feeds the literals in this exact
        order as the leading executable arguments, so the order here is
        load-bearing.  Names are prefixed with a running index to make
        the order reconstructible from the npz alone.
        """
        specs = [("tok_emb", (self.vocab, self.d_model))]
        for layer in range(self.n_layers):
            p = f"l{layer}_"
            specs += [
                (p + "attn_norm", (self.d_model,)),
                (p + "wq", (self.d_model, self.q_dim)),
                (p + "wk", (self.d_model, self.kv_dim)),
                (p + "wv", (self.d_model, self.kv_dim)),
                (p + "wo", (self.q_dim, self.d_model)),
                (p + "mlp_norm", (self.d_model,)),
                (p + "w_gate", (self.d_model, self.d_ff)),
                (p + "w_up", (self.d_model, self.d_ff)),
                (p + "w_down", (self.d_ff, self.d_model)),
            ]
        specs += [
            ("final_norm", (self.d_model,)),
            ("lm_head", (self.d_model, self.vocab)),
        ]
        return [(f"p{i:03d}_{name}", shape) for i, (name, shape) in enumerate(specs)]

    def to_dict(self) -> dict:
        return asdict(self)


TINY = ModelConfig()
