//! Conductor — the KVCache-centric global scheduler (§6, Algorithm 1).
//!
//! For every arriving request Conductor must pick a (prefill group,
//! decode instance) pair balancing three objectives: reuse as much
//! KVCache as possible, balance prefill loads, and guarantee the TTFT /
//! TBT SLOs — rejecting (HTTP 429) what cannot meet them.  The §6.2
//! cache-load-balancing extension adds remote prefix fetches and
//! heuristic hot-spot replication.
//!
//! The scheduler is itself a throughput-critical component (the cluster
//! is overloaded *by design*), so the decision loop is engineered to be
//! **allocation-free at steady state**: requests arrive with interned
//! [`DenseBlockId`] chains (see `kvcache::intern`), every lookup runs
//! against dense or fast-hashed structures, and all per-decision buffers
//! live in a caller-owned [`SchedScratch`] threaded through [`Ctx`].  A
//! rejected decision (the overloaded steady state) touches no heap at
//! all once the scratch has warmed.
//!
//! All timing comes from [`crate::costmodel`] — the same API the
//! simulator's `PrefillStart`/`PrefillDone` events execute against — so
//! the TTFT a placement predicts is the TTFT the cluster delivers
//! (`rust/tests/cost_model_agreement.rs` holds this to a tight
//! tolerance).  Scheduling no longer *runs* the prefill analytically; it
//! admits a [`crate::prefill::PrefillJob`] onto the group's FIFO queues
//! and returns the planned window.

pub mod migration;

use crate::config::{SchedulingPolicy, SimConfig};
use crate::costmodel::{self, FetchPlan, PrefillEstimate};
use crate::decode::DecodeInstance;
use crate::kvcache::{DenseBlockId, ShardedPrefixIndex, SsdPositions, TierDelta, TierMatch};
use crate::model::PerfModel;
use crate::prefill::{JobId, PrefillPool};
use crate::resource::Resources;
use crate::trace::BLOCK_TOKENS;
use crate::util::rng::Rng;
use crate::TimeMs;

/// A request as the scheduler sees it.  `hash_ids` carries *interned*
/// dense block ids — the trace-level hashes were mapped at admission
/// (`sim::Sim::handle_arrival`), which is the one interning boundary.
#[derive(Debug, Clone)]
pub struct SchedRequest {
    pub rid: u64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub hash_ids: Vec<DenseBlockId>,
}

impl SchedRequest {
    /// Split the input into (reused prefix tokens, tokens to recompute)
    /// given `prefix_blocks` reusable cache blocks.  The prefix is capped
    /// by the input length (the last block may be partial).
    fn split(&self, prefix_blocks: usize) -> (u64, u64) {
        let prefix_tokens = (prefix_blocks as u64 * BLOCK_TOKENS).min(self.input_tokens);
        (prefix_tokens, self.input_tokens - prefix_tokens)
    }

    /// Blocks the prefill actually touches: the hash chain, capped at the
    /// blocks needed to cover the input (a chain can overhang a
    /// non-block-aligned input).
    fn needed_blocks(&self) -> usize {
        (self.input_tokens.div_ceil(BLOCK_TOKENS) as usize).min(self.hash_ids.len())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Estimated TTFT exceeds the SLO on every instance (Alg. 1 line 25).
    TtftSlo,
    /// Estimated TBT exceeds the SLO on every decode instance.
    TbtSlo,
    /// Overload admission control (§7) refused the request.
    Overload,
}

/// A successful placement (Algorithm 1's return).
#[derive(Debug, Clone)]
pub struct Placement {
    pub prefill_group: Vec<usize>,
    /// The admitted queue entry; the simulator drives it through
    /// `PrefillStart`/`PrefillDone`.
    pub job: JobId,
    pub decode: usize,
    /// Prefix blocks served from the primary's local pool (either tier).
    pub local_prefix_blocks: usize,
    /// Of the reused prefix, blocks staged up from the primary's SSD
    /// tier (0 when the three-way decision chose recompute instead).
    pub ssd_load_blocks: usize,
    /// Tokens the local staging read covers (`ssd_load_blocks` clamped
    /// to the input), and when the read — reserved on the primary's
    /// NVMe queue at admission — lands.
    pub ssd_stage_tokens: u64,
    pub ssd_stage_done: Option<TimeMs>,
    /// Remote fetch performed before prefill (source instance, blocks).
    pub fetch: Option<(usize, usize)>,
    /// Of the fetched blocks, how many the source staged up from its own
    /// SSD tier before its NIC could serialize them (§6.2 + tiering),
    /// and when that read — reserved on the source's NVMe queue — lands.
    pub fetch_ssd_stage_blocks: usize,
    pub fetch_stage_done: Option<TimeMs>,
    /// Planned prefill window from the unified cost model (the group is
    /// occupied for the span; `prefill_end - arrival` is the estimated
    /// TTFT).
    pub prefill_start: TimeMs,
    pub prefill_end: TimeMs,
    /// When the streamed KVCache lands at the decode node (§5.2 overlap).
    pub kv_arrive: TimeMs,
    pub est_tbt: f64,
}

/// Reusable per-conductor scratch: every buffer a scheduling decision
/// needs, owned by the caller (the `Sim`, a bench, a test) and threaded
/// through [`Ctx`].  After the first few decisions nothing here
/// reallocates, which is what makes the steady-state (SLO-rejecting)
/// decision loop allocation-free — `sched_throughput` measures exactly
/// that loop.
#[derive(Debug, Default)]
pub struct SchedScratch {
    /// Per-node tier matches from the one prefix walk.
    matches: Vec<TierMatch>,
    /// Per-node SSD positions within each matched head (same walk) —
    /// what the §6.2 wire-refresh pricing consumes instead of re-probing
    /// tiers per head block.
    ssd_pos: SsdPositions,
    /// Suffix counts of the best holder's SSD copies (balancing branch).
    src_ssd_suffix: Vec<u32>,
    /// CPP group buffer for per-candidate estimates.
    group: Vec<usize>,
    /// The chosen placement's CPP group (accept path).
    best_group: Vec<usize>,
    /// Residency-delta buffer for pool mutations on the accept path.
    delta: TierDelta,
    /// Replica block list for the §6.2 forwarding path.
    replica_blocks: Vec<DenseBlockId>,
    /// Per-shard SSD-position buffers for the sharded index walk (one
    /// per 256-node shard, warmed once; single-shard clusters never
    /// touch them).
    shard_pos: Vec<SsdPositions>,
    /// Per-candidate choice slots for the parallel scoring fan-out
    /// (`sched_workers > 1`): workers fill disjoint slices, the reduce
    /// reads them back in ascending node order.
    choices: Vec<PrefillChoice>,
    /// One CPP-group buffer per scoring worker (disjoint, warmed once).
    worker_groups: Vec<Vec<usize>>,
    /// Recycled `Placement::prefill_group` buffers: the Sim hands each
    /// consumed placement's vector back via
    /// [`SchedScratch::recycle_placement_group`], so a warmed accept
    /// path allocates nothing for the placement either.
    placement_groups: Vec<Vec<usize>>,
}

impl SchedScratch {
    /// Return a consumed placement's group buffer for reuse by a future
    /// accept — the other half of the allocation-free accept loop.
    pub fn recycle_placement_group(&mut self, group: Vec<usize>) {
        self.placement_groups.push(group);
    }
}

/// Scratch the scheduler needs each call (everything lives in the Sim).
pub struct Ctx<'a> {
    pub cfg: &'a SimConfig,
    pub perf: &'a PerfModel,
    pub prefill: &'a mut PrefillPool,
    pub decodes: &'a [DecodeInstance],
    /// The per-node resource banks (NIC tx/rx + NVMe): estimates probe
    /// them read-only; the committed placement reserves on them.
    pub res: &'a mut Resources,
    pub rng: &'a mut Rng,
    pub now: TimeMs,
    /// The global prefix index (§5): when present, `FindBestPrefixMatch`
    /// is one O(chain) walk instead of a scan of every pool, and every
    /// pool mutation's [`crate::kvcache::TierDelta`] is applied back to
    /// it.  `None` falls back to the per-node scan — results are
    /// bit-for-bit identical either way (a debug assert checks it).
    pub index: Option<&'a mut ShardedPrefixIndex>,
    /// Reused decision buffers (see [`SchedScratch`]).
    pub scratch: &'a mut SchedScratch,
}

/// Counters for Fig 8-style scheduling studies.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ConductorStats {
    pub scheduled: u64,
    pub rejected_ttft: u64,
    pub rejected_tbt: u64,
    pub remote_fetches: u64,
    pub migrations: u64,
    pub reused_blocks: u64,
    pub recomputed_blocks: u64,
    /// Placements whose three-way prefix decision chose to stage blocks
    /// up from the SSD tier, and how many blocks they staged.
    pub ssd_loads: u64,
    pub ssd_loaded_blocks: u64,
    /// Placements that *could* have loaded SSD-resident prefix blocks
    /// but recomputed them instead (the load was the slower branch).
    pub ssd_recomputes: u64,
    /// Remote fetches whose *source* first had to stage blocks up from
    /// its SSD tier before the wire transfer could start, and how many
    /// blocks those stagings covered.
    pub fetch_stagings: u64,
    pub fetch_staged_blocks: u64,
    /// Placements that chose the *hybrid* load+recompute plan
    /// (`cfg.hybrid`, Algorithm 1's fourth branch): the head of the
    /// matched SSD prefix streams up while the GPU recomputes the tail.
    /// `hybrid_staged_blocks` / `hybrid_recomputed_blocks` split the
    /// SSD-resident match between the two sides of the chosen split.
    pub hybrid_placements: u64,
    pub hybrid_staged_blocks: u64,
    pub hybrid_recomputed_blocks: u64,
}

/// The read-only environment one candidate's scoring needs.  Everything
/// is a shared borrow — the cost model only *probes* the pools and
/// resource banks — so a candidate's score is a pure function of
/// `(env, i)` plus a caller-owned CPP-group buffer.  That purity is what
/// lets `select_prefill` fan the candidate loop out across scoped
/// threads and still reduce to bit-for-bit the sequential answer.
struct ScoreEnv<'a> {
    perf: &'a PerfModel,
    cfg: &'a SimConfig,
    prefill: &'a PrefillPool,
    res: &'a Resources,
    req: &'a SchedRequest,
    now: TimeMs,
    /// Per-node tier matches from the one prefix walk.
    matches: &'a [TierMatch],
    /// Per-node SSD positions from the same walk.
    ssd_pos: &'a SsdPositions,
    /// Suffix counts of the best holder's SSD copies (valid only when
    /// `have_src_ssd`; empty otherwise).
    suf: &'a [u32],
    best_inst: usize,
    best_blocks: usize,
    /// §6.2 cache load balancing is on (KvCacheCentric policy).
    balancing: bool,
    /// The best holder keeps part of its match on SSD, so `suf` holds
    /// valid suffix counts.
    have_src_ssd: bool,
}

/// One cost-model probe: instance `i`, `prefix_blocks` reusable blocks
/// of which `ssd_blocks` must be staged up from the SSD tier, and an
/// optional remote fetch first.  Allocation-free: the CPP group forms in
/// the caller's buffer and the returned estimate is plain `Copy` data.
// lint: hot
fn estimate_in(
    env: &ScoreEnv,
    i: usize,
    prefix_blocks: usize,
    ssd_blocks: usize,
    fetch: Option<FetchPlan>,
    group: &mut Vec<usize>,
) -> PrefillEstimate {
    let (prefix_tokens, n_new) = env.req.split(prefix_blocks);
    let ssd_tokens = (ssd_blocks as u64 * BLOCK_TOKENS).min(prefix_tokens);
    env.prefill.cpp_group_into(env.cfg, i, n_new, env.now, group);
    costmodel::estimate_prefill(
        env.perf,
        env.cfg,
        env.prefill,
        env.res,
        group,
        n_new,
        prefix_tokens,
        ssd_tokens,
        fetch,
        env.now,
    )
}

/// The prefill placement `select_prefill` decided on.  `Copy + Default`
/// so the parallel scoring fan-out can pre-size a per-candidate slot
/// buffer once and overwrite it in place every decision.
#[derive(Debug, Clone, Copy, Default)]
struct PrefillChoice {
    inst: usize,
    /// Prefix blocks resident on `inst` (either tier) — reported in the
    /// Placement.
    local_blocks: usize,
    /// Blocks the placement reuses (local + any remote fetch).
    eff_blocks: usize,
    /// Of `eff_blocks`, blocks staged up from `inst`'s SSD tier.
    ssd_blocks: usize,
    /// SSD-resident prefix blocks deliberately recomputed because the
    /// load was priced slower (the "compute, don't load" branch).
    recomputed_ssd_blocks: usize,
    /// Remote fetch (balancing branch): `blocks` may exceed
    /// `eff_blocks - local_blocks` when wire-refreshing local SSD copies
    /// was priced cheaper than staging them, and `src_ssd_blocks` is the
    /// source-side SSD staging the transfer pays first.
    fetch: Option<FetchPlan>,
    /// The hybrid load+recompute plan won (`cfg.hybrid`): `ssd_blocks`
    /// stage up *overlapped* with recomputing the tail — the staging
    /// read floors the job's completion instead of gating its start.
    hybrid: bool,
    est: PrefillEstimate,
}

/// Price the local-reuse options on instance `i` and return the cheaper
/// as a fetch-free [`PrefillChoice`]: (a) reuse the whole matched
/// prefix, staging its SSD-resident blocks; (b) reuse only the
/// pure-DRAM prefix and recompute the rest.  This is the
/// load-vs-recompute half of the three-way prefix decision — the third
/// option (recompute everything) is what a zero match degenerates to.
// lint: hot
fn local_choice_in(env: &ScoreEnv, i: usize, m: TierMatch, group: &mut Vec<usize>) -> PrefillChoice {
    let full = estimate_in(env, i, m.blocks, m.ssd_blocks, None, group);
    let mut choice = PrefillChoice {
        inst: i,
        local_blocks: m.blocks,
        eff_blocks: m.blocks,
        ssd_blocks: m.ssd_blocks,
        recomputed_ssd_blocks: 0,
        fetch: None,
        hybrid: false,
        est: full,
    };
    if m.blocks > m.dram_prefix {
        let dram_only = estimate_in(env, i, m.dram_prefix, 0, None, group);
        if dram_only.end < choice.est.end {
            choice.eff_blocks = m.dram_prefix;
            choice.ssd_blocks = 0;
            choice.recomputed_ssd_blocks = m.ssd_blocks;
            choice.est = dram_only;
        }
        // The fourth branch (`cfg.hybrid`): split the match at an SSD
        // position — stage the head *while* recomputing the tail, so
        // the critical path is max(load, compute) rather than their
        // sum.  The scan prices every distinct split (j staged blocks,
        // reuse up to the next SSD position); j = 0 is the dram_only
        // plan above and j = npos competes with the full-stage plan.
        // Strict `<` keeps `hybrid: false` ties on yesterday's plans.
        if env.cfg.hybrid {
            let scan = costmodel::hybrid_split_scan(m.blocks, env.ssd_pos.node(i), |k, j| {
                let (prefix_tokens, n_new) = env.req.split(k);
                let ssd_tokens = (j as u64 * BLOCK_TOKENS).min(prefix_tokens);
                env.prefill.cpp_group_into(env.cfg, i, n_new, env.now, group);
                costmodel::estimate_prefill_hybrid(
                    env.perf,
                    env.cfg,
                    env.prefill,
                    env.res,
                    group,
                    n_new,
                    prefix_tokens,
                    ssd_tokens,
                    env.now,
                )
            });
            if let Some((k, j, h)) = scan {
                if h.end < choice.est.end {
                    choice = PrefillChoice {
                        inst: i,
                        local_blocks: m.blocks,
                        eff_blocks: k,
                        ssd_blocks: j,
                        recomputed_ssd_blocks: m.ssd_blocks - j,
                        fetch: None,
                        hybrid: true,
                        est: h,
                    };
                }
            }
        }
    }
    choice
}

/// Score one candidate: Algorithm 1 lines 8–21 for instance `i` — the
/// local-vs-balancing branch, the stage-vs-wire fetch pricing, the
/// load-vs-recompute split.  Pure in `(env, i)`; `group` is scratch.
// lint: hot
fn score_candidate(env: &ScoreEnv, i: usize, group: &mut Vec<usize>) -> PrefillChoice {
    let m = env.matches[i];
    let local = m.blocks;
    let src_ssd_from =
        |k: usize| if env.have_src_ssd { env.suf[k.min(env.best_blocks)] as usize } else { 0 };
    // Line 8: prefer local compute unless the best remote match dwarfs
    // the local one.
    let ratio = if local == 0 { f64::INFINITY } else { env.best_blocks as f64 / local as f64 };
    if !env.balancing
        || env.best_inst == i
        || env.best_blocks == 0
        || ratio < env.cfg.kvcache_balancing_threshold
    {
        // Cache-aware branch (lines 9–13), with the load-vs-recompute
        // split priced per instance.
        local_choice_in(env, i, m, group)
    } else {
        // Cache-aware and -balancing branch (lines 15–21): fetch the
        // missing blocks from the best holder; the transfer runs on the
        // *source* NIC — and first pays the source's NVMe staging for
        // any of the missing blocks the holder keeps on SSD.  The local
        // contribution's SSD-resident blocks are priced both ways:
        // staged from the local NVMe, or wire-refreshed from the holder
        // along with the missing blocks (RDMA is often faster than the
        // local SSD read).
        let stage_fetch = FetchPlan {
            src: env.best_inst,
            blocks: env.best_blocks - local,
            src_ssd_blocks: src_ssd_from(local),
        };
        let stage = estimate_in(env, i, env.best_blocks, m.ssd_blocks, Some(stage_fetch), group);
        // The wire plan only differs when local SSD copies exist —
        // don't pay a second probe otherwise.
        let wire_plan = if m.ssd_blocks > 0 {
            // Exact source-SSD accounting: the wire plan also re-fetches
            // the candidate's own SSD copies inside its matched head,
            // and the *source* may hold some of those on its SSD too —
            // each one is a staging read the source pays before its NIC
            // can start.  The candidate's SSD positions came out of the
            // prefix walk; its `TierMatch` SSD-run summary
            // (`[dram_prefix, ssd_last]`) rejects non-overlapping spans
            // in O(1), and otherwise each of its SSD positions tests the
            // source via the suffix array (`suf[p] > suf[p+1]` ⟺ the
            // source holds position p on SSD) — O(1) per position, zero
            // tier probes.
            let head_overlap = if env.have_src_ssd
                && env.suf[m.dram_prefix] > env.suf[m.ssd_last as usize + 1]
            {
                env.ssd_pos
                    .node(i)
                    .iter()
                    .filter(|&&p| env.suf[p as usize] > env.suf[p as usize + 1])
                    .count()
            } else {
                0
            };
            let wire_fetch = FetchPlan {
                src: env.best_inst,
                blocks: env.best_blocks - m.dram_blocks,
                src_ssd_blocks: src_ssd_from(local) + head_overlap,
            };
            let wire = estimate_in(env, i, env.best_blocks, 0, Some(wire_fetch), group);
            (wire.end < stage.end).then_some((wire_fetch, wire))
        } else {
            None
        };
        if let Some((wire_fetch, wire)) = wire_plan {
            PrefillChoice {
                inst: i,
                local_blocks: local,
                eff_blocks: env.best_blocks,
                ssd_blocks: 0,
                recomputed_ssd_blocks: 0,
                fetch: Some(wire_fetch),
                hybrid: false,
                est: wire,
            }
        } else {
            PrefillChoice {
                inst: i,
                local_blocks: local,
                eff_blocks: env.best_blocks,
                ssd_blocks: m.ssd_blocks,
                recomputed_ssd_blocks: 0,
                fetch: Some(stage_fetch),
                hybrid: false,
                est: stage,
            }
        }
    }
}

/// Per-pool scan form of `FindBestPrefixMatch` (the explicit
/// `use_prefix_index: false` path): same outputs as the index walk —
/// matches, SSD-run summaries, and per-node SSD positions.
// lint: hot
fn scan_into(
    prefill: &PrefillPool,
    hash_ids: &[DenseBlockId],
    out: &mut Vec<TierMatch>,
    ssd_pos: &mut SsdPositions,
) {
    out.clear();
    ssd_pos.reset(prefill.len());
    // Each pool probe collects its SSD positions into the scratch the
    // `SsdPositions` loans out, then stages them under the node — the
    // flat buffer has no per-node tails to hand out as `&mut Vec`s.
    let mut probe = ssd_pos.take_scratch();
    for (n, inst) in prefill.instances.iter().enumerate() {
        out.push(inst.pool.prefix_match_with(hash_ids, &mut probe));
        for &p in &probe {
            ssd_pos.push(n, p);
        }
    }
    ssd_pos.put_scratch(probe);
    ssd_pos.seal();
}

/// `FindBestPrefixMatch` over every instance, tier-aware: one O(chain)
/// walk per 256-node shard of the global [`ShardedPrefixIndex`] when
/// available (fanned across `workers` scoped threads past one shard),
/// the per-pool scan otherwise.  The two are interchangeable bit-for-bit
/// — the index is a pure optimization, and a debug build cross-checks
/// every call (matches *and* the carried SSD positions).
/// `out`/`ssd_pos`/`shard_pos` are caller-owned scratch, cleared here.
// lint: hot
pub fn find_prefix_matches_into(
    prefill: &PrefillPool,
    index: Option<&ShardedPrefixIndex>,
    hash_ids: &[DenseBlockId],
    out: &mut Vec<TierMatch>,
    ssd_pos: &mut SsdPositions,
    shard_pos: &mut Vec<SsdPositions>,
    workers: usize,
) {
    match index {
        Some(idx) => {
            idx.best_prefix_into(hash_ids, out, ssd_pos, shard_pos, workers);
            #[cfg(debug_assertions)]
            {
                // lint: allow(hot-no-alloc) — debug-only walk-vs-scan cross-check, compiled out of release
                let mut want = Vec::new();
                let mut want_pos = SsdPositions::default();
                scan_into(prefill, hash_ids, &mut want, &mut want_pos);
                debug_assert_eq!(*out, want, "prefix index diverged from the per-pool scan");
                debug_assert!(
                    ssd_pos.same_nodes(&want_pos, prefill.len()),
                    "prefix index SSD positions diverged from the per-pool scan"
                );
            }
        }
        None => scan_into(prefill, hash_ids, out, ssd_pos),
    }
}

/// Allocating convenience wrapper around [`find_prefix_matches_into`].
pub fn find_prefix_matches(
    prefill: &PrefillPool,
    index: Option<&ShardedPrefixIndex>,
    hash_ids: &[DenseBlockId],
) -> Vec<TierMatch> {
    let mut out = Vec::new();
    let mut ssd_pos = SsdPositions::default();
    let mut shard_pos = Vec::new();
    find_prefix_matches_into(prefill, index, hash_ids, &mut out, &mut ssd_pos, &mut shard_pos, 1);
    out
}

/// Algorithm 1 (lines 1–23): choose the prefill instance, including the
/// tier-aware reuse-from-DRAM / load-from-SSD / recompute decision.
/// With `cfg.sched_workers > 1` the per-candidate scoring fans out
/// across scoped threads into pre-sized choice slots; the reduce scans
/// the slots in ascending node order with the same strict-min rule as
/// the sequential loop, so the winner is bit-for-bit identical at any
/// worker count.  Dead nodes (`faults::FaultEntry::NodeLoss`) are never
/// candidates — every policy masks them out — and `None` means no
/// surviving instance exists (the caller rejects).  With every node
/// alive the masks are no-ops, so healthy runs are bit-for-bit
/// yesterday's (including the Random policy's RNG stream: the draw is
/// over the alive count, which then equals `n`).
// lint: hot
fn select_prefill(ctx: &mut Ctx, req: &SchedRequest) -> Option<PrefillChoice> {
    let n = ctx.prefill.len();
    // The walk's outputs move out of the scratch for the decision (the
    // scoring environment below borrows them shared while the CPP-group
    // buffers stay mutable) and return at the end — a reborrow dance,
    // not an allocation.
    let mut matches = std::mem::take(&mut ctx.scratch.matches);
    let mut ssd_pos = std::mem::take(&mut ctx.scratch.ssd_pos);
    let mut suf = std::mem::take(&mut ctx.scratch.src_ssd_suffix);
    let mut shard_pos = std::mem::take(&mut ctx.scratch.shard_pos);
    let workers = ctx.cfg.sched_workers.max(1);
    find_prefix_matches_into(
        &*ctx.prefill,
        ctx.index.as_deref(),
        &req.hash_ids,
        &mut matches,
        &mut ssd_pos,
        &mut shard_pos,
        workers,
    );
    // Best holder: `max_by_key` keeps the *last* maximal element — part
    // of the determinism contract, so this stays one cheap sequential
    // O(n) pass whatever `sched_workers` says.
    let (best_inst, best_blocks) = matches
        .iter()
        .enumerate()
        .max_by_key(|(_, m)| m.blocks)
        .map(|(i, m)| (i, m.blocks))
        .unwrap_or((0, 0));

    let balancing = ctx.cfg.scheduling == SchedulingPolicy::KvCacheCentric;
    // §6.2 fetches serialize on the *source*: when the holder's copy is
    // partly SSD-resident, the transfer also pays the source's NVMe
    // staging.  The holder's SSD *positions* came out of the one prefix
    // walk above; one suffix-count pass over them lets every candidate
    // price its own fetch range in O(1) — no per-block tier probes
    // anywhere below.
    let have_src_ssd = balancing && best_blocks > 0 && matches[best_inst].ssd_blocks > 0;
    if have_src_ssd {
        suf.clear();
        suf.resize(best_blocks + 1, 0);
        for &p in ssd_pos.node(best_inst) {
            suf[p as usize] = 1;
        }
        let mut c = 0u32;
        for s in suf[..best_blocks].iter_mut().rev() {
            c += *s;
            *s = c;
        }
    }

    let scratch = &mut *ctx.scratch;
    let env = ScoreEnv {
        perf: ctx.perf,
        cfg: ctx.cfg,
        prefill: &*ctx.prefill,
        res: &*ctx.res,
        req,
        now: ctx.now,
        matches: &matches,
        ssd_pos: &ssd_pos,
        suf: &suf,
        best_inst,
        best_blocks,
        balancing,
        have_src_ssd,
    };
    let choice = match ctx.cfg.scheduling {
        SchedulingPolicy::Random => {
            // Draw over the *alive* count, then walk to the k-th alive
            // node: with every node alive this is exactly the historical
            // `below(n)` draw (same RNG stream), and after a loss the
            // dead nodes simply vanish from the index space.
            let n_alive = env.prefill.instances.iter().filter(|inst| inst.alive).count();
            if n_alive == 0 {
                None
            } else {
                let k = ctx.rng.below(n_alive as u64) as usize;
                let i = env
                    .prefill
                    .instances
                    .iter()
                    .enumerate()
                    .filter(|(_, inst)| inst.alive)
                    .nth(k)
                    .map(|(i, _)| i)
                    .expect("k < n_alive");
                Some(local_choice_in(&env, i, matches[i], &mut scratch.group))
            }
        }
        SchedulingPolicy::LoadBalance => (0..n)
            .filter(|&i| env.prefill.instances[i].alive)
            .min_by(|&a, &b| {
                env.prefill.instances[a]
                    .queue_ms(env.now)
                    .partial_cmp(&env.prefill.instances[b].queue_ms(env.now))
                    .unwrap()
            })
            .map(|i| local_choice_in(&env, i, matches[i], &mut scratch.group)),
        SchedulingPolicy::CacheAware | SchedulingPolicy::KvCacheCentric => {
            let workers = workers.clamp(1, n);
            if workers <= 1 {
                // Sequential scoring — the historical loop, byte-for-byte
                // the same float sequence.
                let mut best: Option<PrefillChoice> = None;
                for i in 0..n {
                    if !env.prefill.instances[i].alive {
                        continue;
                    }
                    let cand = score_candidate(&env, i, &mut scratch.group);
                    let better = match &best {
                        None => true,
                        Some(b) => cand.est.end < b.est.end,
                    };
                    if better {
                        best = Some(cand);
                    }
                }
                best
            } else {
                // Parallel scoring: contiguous candidate ranges, one
                // worker each, writing disjoint slices of the warmed
                // choice buffer; every worker owns its own CPP-group
                // buffer.  Scoring is pure in `(env, i)`, so the slots
                // hold exactly what the sequential loop would have
                // computed — the reduce below re-applies its strict-min
                // rule in ascending node order.
                scratch.choices.clear();
                scratch.choices.resize(n, PrefillChoice::default());
                if scratch.worker_groups.len() < workers {
                    scratch.worker_groups.resize_with(workers, Default::default);
                }
                std::thread::scope(|scope| {
                    let env = &env;
                    let mut ch_rest: &mut [PrefillChoice] = &mut scratch.choices;
                    let mut grp_rest: &mut [Vec<usize>] = &mut scratch.worker_groups;
                    let mut lo = 0usize;
                    for w in 0..workers {
                        let take = (n - lo).div_ceil(workers - w);
                        let (ch_mine, r) = ch_rest.split_at_mut(take);
                        ch_rest = r;
                        let (grp_mine, r) = grp_rest.split_at_mut(1);
                        grp_rest = r;
                        let base = lo;
                        lo += take;
                        scope.spawn(move || {
                            let group = &mut grp_mine[0];
                            for (k, slot) in ch_mine.iter_mut().enumerate() {
                                *slot = score_candidate(env, base + k, group);
                            }
                        });
                    }
                });
                // The reduce skips dead slots — bit-identical to the
                // sequential loop's `alive` skip (workers still score
                // them, but scoring is pure and the slots are ignored).
                let mut best: Option<PrefillChoice> = None;
                for (i, &cand) in scratch.choices.iter().enumerate() {
                    if !env.prefill.instances[i].alive {
                        continue;
                    }
                    let better = match &best {
                        None => true,
                        Some(b) => cand.est.end < b.est.end,
                    };
                    if better {
                        best = Some(cand);
                    }
                }
                best
            }
        }
    };
    ctx.scratch.matches = matches;
    ctx.scratch.ssd_pos = ssd_pos;
    ctx.scratch.src_ssd_suffix = suf;
    ctx.scratch.shard_pos = shard_pos;
    choice
}

/// Algorithm 1 line 24: pick the decode instance with the smallest
/// predicted TBT.  With `gate` set (early-rejection admission), only
/// instances that can hold the request qualify; without it (the §7
/// *baseline*, which defers the decode load check until the KVCache
/// actually arrives) the best instance is chosen unconditionally and
/// over-commitment surfaces at the decode-side double-check instead.
pub fn select_decode(
    perf: &PerfModel,
    decodes: &[DecodeInstance],
    ctx_tokens: u64,
    out_tokens: u64,
    gate: bool,
) -> Option<(usize, f64)> {
    let pick = |require_fit: bool| {
        decodes
            .iter()
            .enumerate()
            .filter(|(_, d)| !require_fit || d.can_fit(ctx_tokens, out_tokens))
            .map(|(i, d)| (i, d.predicted_step_ms(perf, ctx_tokens)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    };
    if gate {
        pick(true)
    } else {
        pick(true).or_else(|| pick(false))
    }
}

/// Full Algorithm 1.  Mutates the prefill pool (job admission +
/// optimistic cache admission), the resource banks (remote prefix fetch
/// on NIC tx/rx, staging reads and demotion writes on NVMe), and the
/// stats.  The *decode* side is only probed here; the Sim owns
/// decode state transitions, and the Sim's `PrefillStart`/`PrefillDone`
/// events execute the admitted job.
// lint: hot
pub fn schedule(
    ctx: &mut Ctx,
    req: &SchedRequest,
    stats: &mut ConductorStats,
) -> Result<Placement, RejectReason> {
    // `None` = no surviving prefill instance (every node dead): no
    // placement can meet any TTFT, so the request is an SLO rejection.
    let Some(choice) = select_prefill(ctx, req) else {
        stats.rejected_ttft += 1;
        return Err(RejectReason::TtftSlo);
    };
    let p = choice.inst;

    // Line 24–27: decode selection and SLO gate.  The decode-side gate at
    // arrival is itself an *early rejection* (§7.2), so it only applies
    // under the early/predictive admission policies; the §7 baseline and
    // the no-rejection mode defer decode-load problems to the decode-side
    // double-check / queueing.
    let gate = matches!(
        ctx.cfg.rejection,
        crate::config::RejectionPolicy::Early | crate::config::RejectionPolicy::Predictive
    );
    let (d, est_tbt) = match select_decode(
        ctx.perf,
        ctx.decodes,
        req.input_tokens,
        req.output_tokens,
        gate,
    ) {
        Some(x) => x,
        None => {
            stats.rejected_tbt += 1;
            return Err(RejectReason::TbtSlo);
        }
    };
    if choice.est.ttft_ms(ctx.now) > ctx.cfg.slo.ttft_ms {
        stats.rejected_ttft += 1;
        return Err(RejectReason::TtftSlo);
    }
    if gate && est_tbt > ctx.cfg.slo.tbt_ms {
        stats.rejected_tbt += 1;
        return Err(RejectReason::TbtSlo);
    }

    let (prefix_tokens, n_new) = req.split(choice.eff_blocks);
    let ssd_tokens = (choice.ssd_blocks as u64 * BLOCK_TOKENS).min(prefix_tokens);

    // The chosen placement's CPP group, recomputed into the scratch from
    // the same pool state the estimate priced (nothing has touched the
    // queues since).  Both downstream copies — the Placement's and the
    // admitted job's — ride recycled buffers, so the accept path's
    // steady state allocates nothing at all.
    ctx.prefill.cpp_group_into(ctx.cfg, p, n_new, ctx.now, &mut ctx.scratch.best_group);

    // Local SSD→DRAM staging (the load half of the three-way decision):
    // reserve the read on the primary's NVMe queue — the same probe the
    // estimate priced, reserved first so admission-driven demotion
    // writes below queue *behind* it, not ahead of it.  It overlaps both
    // the FIFO drain and any remote fetch (independent devices).
    let mut ssd_stage_done = None;
    if ssd_tokens > 0 {
        let op = costmodel::schedule_stage(ctx.perf, &mut ctx.res.nvme, p, ctx.now, ssd_tokens);
        ssd_stage_done = Some(op.end);
    }

    // Remote prefix fetch (balancing branch): the fetch must land before
    // prefill starts.  Reserve exactly what the estimate probed, in the
    // same order: the source's NVMe queue for any transferred blocks it
    // keeps on SSD, then the wire — source tx, destination rx.
    let mut fetch_gate = ctx.now;
    let mut fetch = None;
    let mut fetch_ssd_stage_blocks = 0;
    let mut fetch_stage_done = None;
    if let Some(plan) = choice.fetch {
        if plan.blocks > 0 {
            let bytes = costmodel::fetch_bytes(ctx.perf, plan.blocks);
            let wire_start = if plan.src_ssd_blocks > 0 {
                let op = costmodel::schedule_stage(
                    ctx.perf,
                    &mut ctx.res.nvme,
                    plan.src,
                    ctx.now,
                    plan.src_ssd_blocks as u64 * BLOCK_TOKENS,
                );
                fetch_stage_done = Some(op.end);
                op.end
            } else {
                ctx.now
            };
            let tr = ctx.res.nic.schedule(plan.src, p, wire_start, bytes);
            fetch_gate = tr.end;
            fetch = Some((plan.src, plan.blocks));
            fetch_ssd_stage_blocks = plan.src_ssd_blocks;
            stats.remote_fetches += 1;
            if plan.src_ssd_blocks > 0 {
                stats.fetch_stagings += 1;
                stats.fetch_staged_blocks += plan.src_ssd_blocks as u64;
            }
            // The fetched prefix is now replicated on p (hot-spot
            // replication as a side effect of forwarding, §6.2).  Under
            // the stage plan the SSD copies *within the local matched
            // run* are NOT wire-fetched — admission below promotes them
            // as SSD hits, exactly what the estimate priced as staging —
            // so they must not be replica-promoted here.  Everything
            // else (missing blocks, and any stray SSD copies beyond the
            // match gap, which the wire transfer covered) lands as a
            // DRAM replica; the wire plan refreshed all SSD copies.  The
            // skip set is exactly p's SSD positions from the prefix walk
            // — an ascending merge, no tier probes.
            let replica = &mut ctx.scratch.replica_blocks;
            replica.clear();
            let skip: &[u32] =
                if choice.ssd_blocks > 0 { ctx.scratch.ssd_pos.node(p) } else { &[] };
            let mut cur = 0usize;
            for (idx, &b) in req.hash_ids[..choice.eff_blocks].iter().enumerate() {
                while cur < skip.len() && (skip[cur] as usize) < idx {
                    cur += 1;
                }
                let on_ssd_head = cur < skip.len() && skip[cur] as usize == idx;
                if !on_ssd_head {
                    replica.push(b);
                }
            }
            ctx.prefill.instances[p].pool.insert_replica_into(
                &ctx.scratch.replica_blocks,
                ctx.now,
                &mut ctx.scratch.delta,
            );
            if let Some(idx) = ctx.index.as_deref_mut() {
                idx.apply(p, &ctx.scratch.delta);
            }
            // Replica insertion under capacity pressure demotes victims:
            // those writes share the destination's NVMe device.
            let _ = ctx.res.schedule_demote_writes(
                ctx.perf,
                p,
                ctx.now,
                ctx.scratch.delta.demoted_to_ssd(),
            );
            stats.migrations += 1;
        }
    }

    // The job may not start before both gates have landed — except that
    // a *hybrid* placement's staging read is not a start gate at all:
    // compute begins as soon as the group drains, and the read instead
    // floors the job's completion (the overlap the plan priced).
    let job_gate = if choice.hybrid {
        fetch_gate
    } else {
        fetch_gate.max(ssd_stage_done.unwrap_or(ctx.now))
    };
    let stage_floor = if choice.hybrid {
        ssd_stage_done.expect("hybrid placement without a staging read")
    } else {
        f64::NEG_INFINITY
    };

    // Admit the job onto the group's FIFO queues.  The planned window is
    // the estimate: same cost model, same queue state, same gates.
    let job = ctx.prefill.submit_with_floor(
        ctx.perf,
        ctx.cfg,
        req.rid,
        &ctx.scratch.best_group,
        n_new,
        prefix_tokens,
        job_gate,
        ctx.now,
        stage_floor,
    );
    let (planned_start, planned_end) = {
        let j = ctx.prefill.job(job);
        (j.planned_start, j.planned_end)
    };

    // Admit the full chain into p's pool with the reuse decision just
    // made: reused blocks are tier hits (SSD ones promote), recomputed
    // ones are misses whose fresh KV supersedes any stale SSD copy.
    // Clamped to the blocks the input needs.  The reuse accounting below
    // counts the hits that *actually landed* (a replica insertion under
    // extreme capacity pressure can drop part of its own chain before
    // admission reaches it), keeping `dram_hits + ssd_hits ==
    // reused_blocks` an invariant rather than a best case.
    let needed = req.needed_blocks();
    let planned_reuse = choice.eff_blocks.min(needed);
    let hits_before = ctx.prefill.instances[p].pool.stats.hits();
    ctx.prefill.instances[p].pool.admit_chain_reusing_into(
        &req.hash_ids,
        planned_reuse,
        ctx.now,
        &mut ctx.scratch.delta,
    );
    if let Some(idx) = ctx.index.as_deref_mut() {
        idx.apply(p, &ctx.scratch.delta);
    }
    // Eviction pressure from this admission demoted blocks: the NVMe
    // writes queue behind the staging reads reserved above.
    let _ = ctx.res.schedule_demote_writes(
        ctx.perf,
        p,
        ctx.now,
        ctx.scratch.delta.demoted_to_ssd(),
    );
    let reused = (ctx.prefill.instances[p].pool.stats.hits() - hits_before) as usize;

    // Layer-wise KV stream to the decode node (§5.2): transfer overlaps
    // prefill; the Sim schedules the actual wire transfer when the job
    // starts — this is the matching estimate (primary tx, decode rx).
    let kv_arrive = costmodel::estimate_kv_arrival(
        ctx.perf,
        &*ctx.res,
        p,
        ctx.cfg.n_prefill + d,
        planned_start,
        planned_end,
        req.input_tokens,
    );

    stats.scheduled += 1;
    // Block accounting: clamp to the blocks the input actually needs so
    // reused + recomputed == needed for every request, including
    // non-block-aligned inputs whose chain overhangs the input.
    stats.reused_blocks += reused as u64;
    stats.recomputed_blocks += (needed - reused) as u64;
    // Tier traffic of the three-way decision, both ways.
    if choice.ssd_blocks > 0 {
        stats.ssd_loads += 1;
        stats.ssd_loaded_blocks += choice.ssd_blocks as u64;
    }
    if choice.recomputed_ssd_blocks > 0 {
        stats.ssd_recomputes += 1;
    }
    if choice.hybrid {
        stats.hybrid_placements += 1;
        stats.hybrid_staged_blocks += choice.ssd_blocks as u64;
        stats.hybrid_recomputed_blocks += choice.recomputed_ssd_blocks as u64;
    }

    // The placement's group rides a recycled buffer (the Sim returns it
    // through `recycle_placement_group` once the placement is consumed),
    // so even the accept path is allocation-free in warmed steady state
    // — pinned by `tests/alloc_audit.rs`.
    let mut prefill_group = ctx.scratch.placement_groups.pop().unwrap_or_default();
    prefill_group.clear();
    prefill_group.extend_from_slice(&ctx.scratch.best_group);

    Ok(Placement {
        prefill_group,
        job,
        decode: d,
        local_prefix_blocks: choice.local_blocks,
        ssd_load_blocks: choice.ssd_blocks,
        ssd_stage_tokens: ssd_tokens,
        ssd_stage_done,
        fetch,
        fetch_ssd_stage_blocks,
        fetch_stage_done,
        prefill_start: planned_start,
        prefill_end: planned_end,
        kv_arrive,
        est_tbt,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn setup(
        policy: SchedulingPolicy,
    ) -> (SimConfig, PerfModel, PrefillPool, Vec<DecodeInstance>, Resources, Rng, SchedScratch) {
        let cfg = SimConfig { scheduling: policy, ..Default::default() };
        let perf = PerfModel::paper();
        let prefill = PrefillPool::new(&cfg);
        let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
            .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
            .collect();
        let res = Resources::new(&cfg, &perf);
        (cfg, perf, prefill, decodes, res, Rng::new(7), SchedScratch::default())
    }

    fn req(rid: u64, blocks: u32) -> SchedRequest {
        let base = rid as u32 * 1000;
        SchedRequest {
            rid,
            input_tokens: blocks as u64 * BLOCK_TOKENS,
            output_tokens: 100,
            hash_ids: (base..base + blocks).collect(),
        }
    }

    macro_rules! ctx {
        ($cfg:expr, $perf:expr, $prefill:expr, $decodes:expr, $res:expr, $rng:expr,
         $scratch:expr, $now:expr) => {
            Ctx {
                cfg: &$cfg,
                perf: &$perf,
                prefill: &mut $prefill,
                decodes: &$decodes,
                res: &mut $res,
                rng: &mut $rng,
                now: $now,
                index: None,
                scratch: &mut $scratch,
            }
        };
    }

    #[test]
    fn schedules_and_reuses_prefix() {
        let (cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        let mut stats = ConductorStats::default();
        let r1 = req(1, 16);
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
        let p1 = schedule(&mut ctx, &r1, &mut stats).unwrap();
        assert!(p1.prefill_end > p1.prefill_start);
        assert!(p1.kv_arrive >= p1.prefill_end);

        // Same chain again much later (queue drained): the primary holding
        // the cache must win, and most blocks must be reused.
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e7);
        let p2 = schedule(&mut ctx, &r1, &mut stats).unwrap();
        assert_eq!(p2.prefill_group[0], p1.prefill_group[0]);
        assert!(p2.prefill_end - p2.prefill_start < (p1.prefill_end - p1.prefill_start) * 0.3);
        assert!(stats.reused_blocks >= 16);
    }

    #[test]
    fn cache_aware_beats_random_on_warm_chain() {
        // Warm one instance, then compare policies' TTFT estimates.
        for policy in [SchedulingPolicy::CacheAware, SchedulingPolicy::KvCacheCentric] {
            let (cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) = setup(policy);
            let mut stats = ConductorStats::default();
            let r = req(3, 32);
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            let first = schedule(&mut ctx, &r, &mut stats).unwrap();
            let cold = first.prefill_end - first.prefill_start;
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e7);
            let warm_p = schedule(&mut ctx, &r, &mut stats).unwrap();
            let warm = warm_p.prefill_end - warm_p.prefill_start;
            assert!(warm < cold * 0.2, "{policy:?}: warm={warm} cold={cold}");
        }
    }

    #[test]
    fn rejects_when_ttft_unattainable() {
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg.slo.ttft_ms = 1.0; // impossible
        let mut stats = ConductorStats::default();
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
        let e = schedule(&mut ctx, &req(9, 64), &mut stats).unwrap_err();
        assert_eq!(e, RejectReason::TtftSlo);
        assert_eq!(stats.rejected_ttft, 1);
    }

    #[test]
    fn balancing_branch_fetches_remote_prefix() {
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg.kvcache_balancing_threshold = 1.5;
        let mut stats = ConductorStats::default();
        let r = req(5, 64);
        // Warm instance 0 with the chain, then make the holder very busy
        // so the scheduler prefers another node + fetch.
        {
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        let holder = prefill
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 64)
            .unwrap();
        prefill.instances[holder].block_until(1e9); // swamped
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e6);
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_ne!(p.prefill_group[0], holder);
        assert!(p.fetch.is_some(), "expected remote fetch");
        assert_eq!(stats.remote_fetches, 1);
        // Replica now exists on the new node.
        assert_eq!(
            prefill.instances[p.prefill_group[0]].pool.prefix_match_blocks(&r.hash_ids),
            64
        );
    }

    #[test]
    fn fetch_estimate_uses_source_nic_congestion() {
        // Regression: the estimate used to charge the fetch to the
        // *destination* NIC while execution ran it on the *source* NIC —
        // a congested holder made the estimate wildly optimistic.
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg.kvcache_balancing_threshold = 1.5;
        let mut stats = ConductorStats::default();
        let r = req(7, 64);
        {
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        let holder = prefill
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 64)
            .unwrap();
        prefill.instances[holder].block_until(1e9); // queue swamped -> fetch branch

        // Source NIC asymmetrically congested far past the TTFT SLO: the
        // estimate must see it and reject (the old destination-NIC
        // estimate accepted, then the fetch landed ~2000 s late).
        msgr.nic.schedule(holder, holder + 1, 1e6, 200_000_000_000_000); // ~2e6 ms of backlog
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e6);
        let e = schedule(&mut ctx, &r, &mut stats).unwrap_err();
        assert_eq!(e, RejectReason::TtftSlo);

        // Moderate congestion (under the SLO): accepted, but the planned
        // start must wait for the source's backlog to drain.
        let (mut cfg2, perf2, mut prefill2, decodes2, mut msgr2, mut rng2, mut sc2) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg2.kvcache_balancing_threshold = 1.5;
        let mut stats2 = ConductorStats::default();
        {
            let mut ctx = ctx!(cfg2, perf2, prefill2, decodes2, msgr2, rng2, sc2, 0.0);
            schedule(&mut ctx, &r, &mut stats2).unwrap();
        }
        let holder2 = prefill2
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 64)
            .unwrap();
        prefill2.instances[holder2].block_until(1e9);
        msgr2.nic.schedule(holder2, holder2 + 1, 1e6, 1_000_000_000_000); // ~10 s backlog
        let mut ctx = ctx!(cfg2, perf2, prefill2, decodes2, msgr2, rng2, sc2, 1e6);
        let p = schedule(&mut ctx, &r, &mut stats2).unwrap();
        assert!(p.fetch.is_some());
        assert!(
            p.prefill_start >= 1e6 + 9_000.0,
            "planned start {} must include the source NIC backlog",
            p.prefill_start
        );
    }

    #[test]
    fn ssd_load_chosen_over_recompute_for_deep_prefix() {
        // A 63-block (~32k-token) chain demoted to the holder's SSD tier:
        // recomputing it costs quadratic attention, so Algorithm 1's
        // three-way decision must stage it up from SSD instead.  (63
        // blocks keeps the recompute alternative below the CPP threshold,
        // and CacheAware disables the remote-fetch branch — RDMA is an
        // order of magnitude faster than NVMe, so under KvCacheCentric a
        // remote DRAM fetch would rightly shadow the local SSD load.)
        // Hybrid off: this test pins the *exclusive* three-way decision;
        // the fourth branch would split this very chain (see
        // `hybrid_splits_deep_ssd_prefix_and_beats_the_exclusive_plans`).
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::CacheAware);
        cfg.hybrid = false;
        let mut stats = ConductorStats::default();
        let r = req(1, 63);
        {
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        assert_eq!(stats.ssd_loads, 0, "cold pass has nothing to stage");
        let holder = prefill
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 63)
            .unwrap();
        // Long idle gap: the whole chain got demoted to the SSD tier.
        for &b in &r.hash_ids {
            assert!(prefill.instances[holder].pool.demote_block(b, 1.0).is_some());
        }
        assert_eq!(prefill.instances[holder].pool.ssd_len(), 63);

        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e7);
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_eq!(p.prefill_group[0], holder, "SSD holder must win the placement");
        assert_eq!(p.ssd_load_blocks, 63, "the whole prefix loads from SSD");
        assert_eq!(stats.ssd_loads, 1);
        assert_eq!(stats.ssd_loaded_blocks, 63);
        assert_eq!(stats.ssd_recomputes, 0);
        // Reuse accounting: staged blocks count as reused, not recomputed.
        assert_eq!(stats.reused_blocks, 63);
        // The staged blocks promoted back to DRAM.
        assert_eq!(prefill.instances[holder].pool.ssd_len(), 0);
        assert_eq!(prefill.instances[holder].pool.stats.ssd_hits, 63);
        assert_eq!(prefill.instances[holder].pool.stats.promotions, 63);
    }

    #[test]
    fn hybrid_splits_deep_ssd_prefix_and_beats_the_exclusive_plans() {
        // The deep-prefix scenario above with the fourth branch left on
        // (the default): instead of gating the whole job on a ~3.5 s
        // full-chain staging read, the conductor stages only the head of
        // the demoted chain while the GPU recomputes the tail under the
        // read — and must finish strictly earlier than the exclusive
        // three-way plan on the identical cluster.
        let run = |hybrid: bool| {
            let (mut cfg, perf, mut prefill, decodes, mut res, mut rng, mut sc) =
                setup(SchedulingPolicy::CacheAware);
            cfg.hybrid = hybrid;
            let mut stats = ConductorStats::default();
            let r = req(1, 63);
            {
                let mut ctx = ctx!(cfg, perf, prefill, decodes, res, rng, sc, 0.0);
                schedule(&mut ctx, &r, &mut stats).unwrap();
            }
            let holder = prefill
                .instances
                .iter()
                .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 63)
                .unwrap();
            for &b in &r.hash_ids {
                assert!(prefill.instances[holder].pool.demote_block(b, 1.0).is_some());
            }
            let mut ctx = ctx!(cfg, perf, prefill, decodes, res, rng, sc, 1e7);
            let p = schedule(&mut ctx, &r, &mut stats).unwrap();
            assert_eq!(p.prefill_group[0], holder);
            (p, stats)
        };
        let (exclusive, sx) = run(false);
        let (hybrid, sh) = run(true);
        assert_eq!(sx.hybrid_placements, 0);
        assert_eq!(sh.hybrid_placements, 1);
        assert_eq!(sh.ssd_loads, 1, "the hybrid split still stages its head");
        assert!(hybrid.fetch.is_none());
        // A real split: part of the chain stages, the rest recomputes.
        assert!(
            hybrid.ssd_load_blocks > 0 && hybrid.ssd_load_blocks < 63,
            "ssd_load_blocks = {}",
            hybrid.ssd_load_blocks
        );
        assert_eq!(
            sh.hybrid_staged_blocks + sh.hybrid_recomputed_blocks,
            63,
            "the split covers the whole SSD-resident match"
        );
        // The staging read floors completion instead of gating the start.
        let stage_done = hybrid.ssd_stage_done.unwrap();
        assert!(hybrid.prefill_start < stage_done);
        assert!(hybrid.prefill_end >= stage_done);
        // The overlap must strictly beat the exclusive full-stage plan.
        assert!(
            hybrid.prefill_end < exclusive.prefill_end,
            "hybrid {} must finish before exclusive {}",
            hybrid.prefill_end,
            exclusive.prefill_end
        );
    }

    #[test]
    fn recompute_chosen_over_slow_ssd_load_for_shallow_prefix() {
        // A 2-block (1k-token) chain on SSD: at near-zero context the
        // recompute is cheaper than the NVMe read, so the decision must
        // recompute — exercising the "compute, don't load" branch.
        // Hybrid off: with it on, staging one block (~56 ms) under the
        // ~52 ms tail recompute would rightly beat pure recompute even
        // here — this test pins the exclusive decision.
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::CacheAware);
        cfg.hybrid = false;
        let mut stats = ConductorStats::default();
        let r = req(2, 2);
        {
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        let holder = prefill
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 2)
            .unwrap();
        for &b in &r.hash_ids {
            assert!(prefill.instances[holder].pool.demote_block(b, 1.0).is_some());
        }

        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e7);
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_eq!(p.ssd_load_blocks, 0, "slow SSD load must lose to recompute");
        assert_eq!(stats.ssd_loads, 0);
        assert_eq!(stats.ssd_recomputes, 1);
        // Recomputed blocks count as recomputed, and the fresh KV
        // supersedes the stale SSD copies (back in DRAM, one tier only).
        assert_eq!(stats.reused_blocks, 0);
        assert_eq!(stats.recomputed_blocks, 4);
        let pool = &prefill.instances[p.prefill_group[0]].pool;
        assert_eq!(pool.stats.ssd_hits, 0);
        assert_eq!(pool.prefix_match(&r.hash_ids).dram_blocks, 2);
    }

    #[test]
    fn index_backed_scheduling_matches_scan_backed() {
        // The global prefix index is a pure optimization: the same
        // request stream against two identical clusters — one scheduling
        // through the index, one through the per-pool scan — must
        // produce identical placements, stats, and pool states.
        let (cfg_a, perf_a, mut pf_a, dec_a, mut ms_a, mut rng_a, mut sc_a) =
            setup(SchedulingPolicy::KvCacheCentric);
        let (cfg_b, perf_b, mut pf_b, dec_b, mut ms_b, mut rng_b, mut sc_b) =
            setup(SchedulingPolicy::KvCacheCentric);
        let mut idx = pf_b.build_prefix_index();
        let mut sa = ConductorStats::default();
        let mut sb = ConductorStats::default();
        for k in 0..24u64 {
            let r = req(k % 5, 8 + (k % 3) as u32 * 17); // overlapping chains
            let now = k as f64 * 2_000.0;
            let pa = {
                let mut ctx = ctx!(cfg_a, perf_a, pf_a, dec_a, ms_a, rng_a, sc_a, now);
                schedule(&mut ctx, &r, &mut sa)
            };
            let pb = {
                let mut ctx = Ctx {
                    cfg: &cfg_b,
                    perf: &perf_b,
                    prefill: &mut pf_b,
                    decodes: &dec_b,
                    res: &mut ms_b,
                    rng: &mut rng_b,
                    now,
                    index: Some(&mut idx),
                    scratch: &mut sc_b,
                };
                schedule(&mut ctx, &r, &mut sb)
            };
            match (pa, pb) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.prefill_group, b.prefill_group, "request {k}");
                    assert_eq!(a.local_prefix_blocks, b.local_prefix_blocks);
                    assert_eq!(a.ssd_load_blocks, b.ssd_load_blocks);
                    assert_eq!(a.fetch, b.fetch);
                    assert_eq!(a.prefill_start.to_bits(), b.prefill_start.to_bits());
                    assert_eq!(a.prefill_end.to_bits(), b.prefill_end.to_bits());
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                (a, b) => panic!("request {k} diverged: {a:?} vs {b:?}"),
            }
        }
        assert_eq!(sa, sb);
        // The incrementally maintained index still equals a rebuild.
        assert!(idx.equals_rebuild_of(pf_b.instances.iter().map(|i| &i.pool)));
    }

    #[test]
    fn walk_carries_ssd_summary_and_positions_for_both_paths() {
        // The tentpole's O(1) wire-refresh contract: the prefix walk (and
        // its scan twin) deliver each candidate's SSD-run summary
        // (`TierMatch::{dram_prefix, ssd_last}`) plus the exact SSD
        // positions — the balancing branch prices `head_overlap` off
        // these alone, never probing a tier per head block.
        let (cfg, _perf, mut prefill, _decodes, _res, _rng, _sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        let chain: Vec<DenseBlockId> = (500..516).collect();
        let _ = prefill.instances[0].pool.admit_chain(&chain, 0.0);
        for b in [502, 503, 509] {
            assert!(prefill.instances[0].pool.demote_block(b, 1.0).is_some());
        }
        let _ = prefill.instances[1].pool.admit_chain(&chain[..6], 0.0);
        assert!(prefill.instances[1].pool.demote_block(504, 1.0).is_some());
        let idx = prefill.build_prefix_index();

        let mut via_idx = (Vec::new(), SsdPositions::default());
        let mut via_scan = (Vec::new(), SsdPositions::default());
        let mut shard_pos = Vec::new();
        find_prefix_matches_into(
            &prefill,
            Some(&idx),
            &chain,
            &mut via_idx.0,
            &mut via_idx.1,
            &mut shard_pos,
            1,
        );
        find_prefix_matches_into(
            &prefill,
            None,
            &chain,
            &mut via_scan.0,
            &mut via_scan.1,
            &mut shard_pos,
            1,
        );
        assert_eq!(via_idx.0, via_scan.0);
        assert!(via_idx.1.same_nodes(&via_scan.1, cfg.n_prefill));

        let m0 = via_idx.0[0];
        assert_eq!((m0.blocks, m0.dram_prefix, m0.ssd_blocks), (16, 2, 3));
        assert_eq!(m0.ssd_last, 9);
        assert_eq!(via_idx.1.node(0), &[2, 3, 9]);
        let m1 = via_idx.0[1];
        assert_eq!((m1.blocks, m1.dram_prefix, m1.ssd_blocks), (6, 4, 1));
        assert_eq!(m1.ssd_last, 4);
        assert_eq!(via_idx.1.node(1), &[4]);
        // Positions always sit inside the summary's span.
        for n in 0..cfg.n_prefill {
            let m = via_idx.0[n];
            for &p in via_idx.1.node(n) {
                assert!((p as usize) >= m.dram_prefix && p <= m.ssd_last);
            }
        }
    }

    #[test]
    fn wire_refresh_prices_source_ssd_copies_in_matched_head() {
        // ROADMAP PR 3 follow-up: the balancing branch's *wire plan*
        // re-fetches the candidate's own SSD copies inside its matched
        // head — and when the source ALSO holds those blocks on SSD,
        // each one is a staging read the source pays before its NIC can
        // start.  They used to be assumed DRAM-resident on the source,
        // underpricing the wire plan exactly when both ends had demoted
        // the same blocks.  (Since the O(1) refactor the overlap count
        // comes from the walk's SSD positions + the source suffix array
        // — same numbers, no per-block tier probes.)
        let mk = || {
            let cfg = SimConfig {
                scheduling: SchedulingPolicy::KvCacheCentric,
                n_prefill: 2,
                n_decode: 2,
                kvcache_balancing_threshold: 1.5,
                // This test pins the balancing branch's stage-vs-wire
                // pricing; the orthogonal hybrid local plan would
                // otherwise compete for the same SSD head.
                hybrid: false,
                ..Default::default()
            };
            let perf = PerfModel::paper();
            let prefill = PrefillPool::new(&cfg);
            let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
                .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
                .collect();
            let res = Resources::new(&cfg, &perf);
            (cfg, perf, prefill, decodes, res, Rng::new(7), SchedScratch::default())
        };
        let chain: Vec<DenseBlockId> = (100..108).collect();
        let r = SchedRequest {
            rid: 1,
            input_tokens: 8 * BLOCK_TOKENS,
            output_tokens: 10,
            hash_ids: chain.clone(),
        };

        // Case A: the source keeps two of the candidate's three SSD-held
        // head blocks on its own SSD too.  Wire-refreshing them costs
        // the source three NVMe stagings serialized before the wire —
        // slower than staging locally (which overlaps the fetch), so the
        // exact accounting must flip the decision to the stage plan.
        let (cfg, perf, mut prefill, decodes, mut res, mut rng, mut sc) = mk();
        let _ = prefill.instances[0].pool.admit_chain(&chain, 0.0);
        for b in [chain[2], chain[3], chain[6]] {
            assert!(prefill.instances[0].pool.demote_block(b, 1.0).is_some());
        }
        let _ = prefill.instances[1].pool.admit_chain(&chain[..4], 0.0);
        for b in [chain[1], chain[2], chain[3]] {
            assert!(prefill.instances[1].pool.demote_block(b, 1.0).is_some());
        }
        prefill.instances[0].block_until(1e9); // swamp the holder
        let mut stats = ConductorStats::default();
        let mut ctx = ctx!(cfg, perf, prefill, decodes, res, rng, sc, 1e6);
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_eq!(p.prefill_group[0], 1, "swamped holder must lose the placement");
        assert_eq!(
            (p.fetch, p.ssd_load_blocks, p.fetch_ssd_stage_blocks),
            (Some((0, 4)), 3, 1),
            "overlapping SSD copies must push the decision to the stage plan"
        );

        // Case B: the source holds the candidate's SSD head blocks in
        // DRAM (only a gap block on SSD) — the wire refresh stays cheap
        // and must win, with exactly the gap block staged at the source.
        let (cfg, perf, mut prefill, decodes, mut res, mut rng, mut sc) = mk();
        let _ = prefill.instances[0].pool.admit_chain(&chain, 0.0);
        assert!(prefill.instances[0].pool.demote_block(chain[6], 1.0).is_some());
        let _ = prefill.instances[1].pool.admit_chain(&chain[..4], 0.0);
        for b in [chain[1], chain[2], chain[3]] {
            assert!(prefill.instances[1].pool.demote_block(b, 1.0).is_some());
        }
        prefill.instances[0].block_until(1e9);
        let mut stats = ConductorStats::default();
        let mut ctx = ctx!(cfg, perf, prefill, decodes, res, rng, sc, 1e6);
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_eq!(p.prefill_group[0], 1);
        assert_eq!(
            (p.fetch, p.ssd_load_blocks, p.fetch_ssd_stage_blocks),
            (Some((0, 7)), 0, 1),
            "DRAM-resident head copies on the source keep the wire plan cheap"
        );
    }

    #[test]
    fn block_accounting_conserved_for_unaligned_inputs() {
        // Regression: prefix_tokens was clamped to the input but the
        // reused/recomputed counters were not, so a chain overhanging a
        // non-block-aligned input broke conservation.
        let (cfg, perf, mut prefill, decodes, mut msgr, mut rng, mut sc) =
            setup(SchedulingPolicy::KvCacheCentric);
        let mut stats = ConductorStats::default();
        // 4-block chain over a 1300-token input (needs only 3 blocks).
        let r = SchedRequest {
            rid: 1,
            input_tokens: 1_300,
            output_tokens: 10,
            hash_ids: vec![10, 11, 12, 13],
        };
        let needed = 3u64; // ceil(1300 / 512)
        {
            let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 0.0);
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        assert_eq!(stats.reused_blocks + stats.recomputed_blocks, needed);
        // Warm pass: the whole chain matches (4 blocks) but only 3 count.
        let mut ctx = ctx!(cfg, perf, prefill, decodes, msgr, rng, sc, 1e7);
        schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_eq!(stats.reused_blocks + stats.recomputed_blocks, 2 * needed);
        assert!(stats.reused_blocks >= needed, "warm pass must reuse the needed blocks");
    }
}
