//! Counting `#[global_allocator]` — the *runtime* twin of
//! `pallas_lint`'s static `hot-no-alloc` rule (rule R3).
//!
//! Compiled only under the `alloc-audit` feature: the crate then
//! registers [`CountingAlloc`] (a thin wrapper over
//! [`std::alloc::System`] with an atomic allocation counter) as the
//! global allocator, and a scoped [`AllocGuard`] reads the counter
//! delta across a region.  `rust/tests/alloc_audit.rs` uses it to pin
//! the scheduler's steady-state decision loop at **zero** heap
//! allocations, and `benches/sched_throughput.rs` reports the same
//! measurement as the `allocs_per_decision` column of
//! `BENCH_sched.json`.
//!
//! Only allocation *counts* are tracked (not bytes, not frees): the
//! claim under test is "no allocation happens at all", so a counter is
//! enough and keeps the allocator overhead to one relaxed atomic add.

#![cfg(feature = "alloc-audit")]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// [`System`], with every `alloc`/`realloc`/`alloc_zeroed` counted.
/// Frees are not counted — R3 is about allocation pressure, and a
/// hot-path free implies a hot-path allocation elsewhere anyway.
pub struct CountingAlloc;

// SAFETY: defers every operation to `System`, which upholds the
// `GlobalAlloc` contract; the counter has no effect on the returned
// memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// The one registration point: every binary built with `alloc-audit`
/// (tests, benches, the CLI) counts through this allocator.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Total heap allocations since process start.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Scoped allocation counter: construct before the region under audit,
/// read [`AllocGuard::count`] after.  Single-threaded regions see an
/// exact count; concurrent allocations elsewhere in the process would
/// inflate it (the tier-1 audit runs single-threaded).
#[derive(Debug)]
pub struct AllocGuard {
    start: u64,
}

impl AllocGuard {
    pub fn new() -> Self {
        AllocGuard { start: alloc_count() }
    }

    /// Allocations since this guard was created.
    pub fn count(&self) -> u64 {
        alloc_count() - self.start
    }
}

impl Default for AllocGuard {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_counts_allocations() {
        // Lib unit tests share the process (and therefore the global
        // counter) across threads, so only monotone assertions are
        // reliable here; the exact-zero steady-state claim lives in the
        // single-test `rust/tests/alloc_audit.rs` binary.
        let g = AllocGuard::new();
        let v: Vec<u64> = (0..64).collect();
        assert!(g.count() >= 1, "an allocation must be counted");
        drop(v);
        assert!(alloc_count() >= g.count(), "the global counter is monotone");
    }
}
