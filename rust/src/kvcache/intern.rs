//! Block interning — the boundary where trace-level block *hashes*
//! become scheduler-internal dense ids.
//!
//! The published trace (and `chain_hashes` on the live path) identifies a
//! KVCache block by a 64-bit prefix-chain hash.  Those hashes are the
//! *public* surface (JSONL schema, Fig 6 analyzers) — but nothing inside
//! the scheduler needs them: Conductor, the pools, and the prefix index
//! only ever compare ids for equality.  [`BlockInterner`] maps each hash
//! to a dense `u32` at request admission (`sim::Sim::handle_arrival`),
//! and everything downstream — [`super::CachePool`],
//! [`super::PrefixIndex`], [`super::TierDelta`], migration heat — carries
//! [`DenseBlockId`]:
//!
//! * hot maps key on 4-byte ids instead of 8-byte hashes;
//! * the prefix index stops hashing entirely — dense ids index a flat
//!   residency table directly (see `kvcache::index`);
//! * ids are assigned in first-appearance order, so every run of the
//!   same trace produces the same ids (determinism is preserved).
//!
//! Interning is injective by construction: a new hash gets the next
//! unused dense id, a seen hash gets its existing id, and nothing is
//! ever un-interned (dropped blocks may re-enter the cluster later and
//! must keep their identity).

use crate::util::fasthash::FastMap;
use crate::BlockId;

/// Dense scheduler-internal block id (see module docs).  `u32` bounds
/// the cluster at ~4.3 B distinct cache blocks — at 512 tokens/block
/// that is two *trillion* tokens of distinct prefix, far past any trace.
pub type DenseBlockId = u32;

/// Hash → dense-id map (one per simulated cluster, owned by the `Sim`
/// next to the interner's consumers).
#[derive(Debug, Default)]
pub struct BlockInterner {
    map: FastMap<BlockId, DenseBlockId>,
}

impl BlockInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Dense id for `hash`, assigning the next free id on first sight.
    #[inline]
    pub fn intern(&mut self, hash: BlockId) -> DenseBlockId {
        let next = self.map.len();
        match self.map.entry(hash) {
            std::collections::hash_map::Entry::Occupied(e) => *e.get(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let id = DenseBlockId::try_from(next).expect("interner exhausted u32 id space");
                *e.insert(id)
            }
        }
    }

    /// Intern a whole hash chain into a reused buffer (the per-arrival
    /// path — `out` is cleared first, so the caller's scratch never
    /// reallocates past the longest chain seen).
    pub fn intern_chain_into(&mut self, chain: &[BlockId], out: &mut Vec<DenseBlockId>) {
        out.clear();
        out.reserve(chain.len());
        for &h in chain {
            let id = self.intern(h);
            out.push(id);
        }
    }

    /// Dense id of an already-interned hash (read-only probe).
    pub fn lookup(&self, hash: BlockId) -> Option<DenseBlockId> {
        self.map.get(&hash).copied()
    }

    /// Distinct hashes interned so far (== the dense id space in use).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_appearance_order_and_stability() {
        let mut it = BlockInterner::new();
        assert_eq!(it.intern(0xdead_beef), 0);
        assert_eq!(it.intern(42), 1);
        assert_eq!(it.intern(0xdead_beef), 0, "re-interning must be stable");
        assert_eq!(it.intern(u64::MAX), 2);
        assert_eq!(it.len(), 3);
        assert_eq!(it.lookup(42), Some(1));
        assert_eq!(it.lookup(7), None);
    }

    #[test]
    fn chain_interning_reuses_the_buffer() {
        let mut it = BlockInterner::new();
        let mut buf = Vec::new();
        it.intern_chain_into(&[10, 20, 10, 30], &mut buf);
        assert_eq!(buf, vec![0, 1, 0, 2]);
        let cap = buf.capacity();
        it.intern_chain_into(&[20, 30], &mut buf);
        assert_eq!(buf, vec![1, 2]);
        assert_eq!(buf.capacity(), cap, "shorter chains must not shrink the scratch");
    }
}
