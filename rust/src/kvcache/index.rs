//! The Conductor's **global prefix index** (§5, §6): one map from
//! `BlockId` to a per-node, tier-aware residency bitset, replacing the
//! per-request scan of every prefill instance's pool.
//!
//! `FindBestPrefixMatch` used to cost O(nodes × chain) HashMap probes
//! per scheduling decision — worst in exactly the long-context regime
//! the paper targets (128K ctx ≈ thousands of blocks).  With the index,
//! [`PrefixIndex::best_prefix`] touches each chain block **once** and
//! advances every candidate node's match simultaneously with bitmask
//! arithmetic: per block, one probe plus O(words) mask ops plus work
//! proportional only to the nodes whose state *changes* at that block
//! (death, DRAM-run end, SSD copy).
//!
//! Consistency protocol: the index is owned next to the scheduler (the
//! `Sim`), not by the pools — pools stay self-contained LRU structures
//! and every mutation ([`CachePool::admit_chain_reusing`],
//! [`CachePool::insert_replica`], [`CachePool::demote_block`],
//! [`CachePool::demote_idle`], …) *returns* a [`TierDelta`] of residency
//! changes which the owner applies via [`PrefixIndex::apply`].  A
//! debug-mode invariant ([`PrefixIndex::equals_rebuild_of`]) checks the
//! incremental index against a brute-force rebuild.
//!
//! The bitset is a single `u64` per tier per block, so one index shard
//! covers up to [`PrefixIndex::MAX_NODES`] prefill nodes; the Conductor
//! falls back to the per-pool scan beyond that (`PrefixIndex::supports`).

use std::collections::HashMap;

use super::pool::{CachePool, Tier, TierDelta, TierMatch};
use crate::BlockId;

/// Which nodes hold a block, split by tier.  A node's bit is set in at
/// most one of the two masks (a block lives in exactly one tier per
/// pool).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
struct Residency {
    dram: u64,
    ssd: u64,
}

#[derive(Debug)]
pub struct PrefixIndex {
    n_nodes: usize,
    map: HashMap<BlockId, Residency>,
}

impl PrefixIndex {
    /// One `u64` bitset word per tier per block.
    pub const MAX_NODES: usize = 64;

    /// Whether a single index shard can cover `n_nodes` prefill nodes.
    pub fn supports(n_nodes: usize) -> bool {
        n_nodes <= Self::MAX_NODES
    }

    pub fn new(n_nodes: usize) -> Self {
        assert!(Self::supports(n_nodes), "PrefixIndex shard covers at most 64 nodes");
        PrefixIndex { n_nodes, map: HashMap::new() }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Distinct blocks resident anywhere in the cluster.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Record `node`'s residency for one block (`None` = not resident).
    /// Setting one tier clears the other — a block lives in exactly one
    /// tier per pool — and entries with no holders are removed so the
    /// index stays equal to a fresh rebuild.
    pub fn set(&mut self, node: usize, b: BlockId, loc: Option<Tier>) {
        debug_assert!(node < self.n_nodes);
        let bit = 1u64 << node;
        let r = self.map.entry(b).or_default();
        r.dram &= !bit;
        r.ssd &= !bit;
        match loc {
            Some(Tier::Dram) => r.dram |= bit,
            Some(Tier::Ssd) => r.ssd |= bit,
            None => {}
        }
        if r.dram == 0 && r.ssd == 0 {
            self.map.remove(&b);
        }
    }

    /// Apply a pool mutation's residency changes for `node`, in order.
    pub fn apply(&mut self, node: usize, delta: &TierDelta) {
        for &(b, loc) in &delta.changes {
            self.set(node, b, loc);
        }
    }

    /// `node`'s residency for one block, as the pool would report it.
    pub fn tier_on(&self, node: usize, b: BlockId) -> Option<Tier> {
        debug_assert!(node < self.n_nodes);
        let r = self.map.get(&b)?;
        let bit = 1u64 << node;
        if r.dram & bit != 0 {
            Some(Tier::Dram)
        } else if r.ssd & bit != 0 {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    /// Bulk-load one node's pool (brute-force rebuild path).
    pub fn insert_pool(&mut self, node: usize, pool: &CachePool) {
        for b in pool.iter_dram_blocks() {
            self.set(node, b, Some(Tier::Dram));
        }
        for b in pool.iter_ssd_blocks() {
            self.set(node, b, Some(Tier::Ssd));
        }
    }

    /// `FindBestPrefixMatch` for **all** nodes in one chain walk:
    /// `out[n]` equals `pools[n].prefix_match(hash_ids)` exactly, but the
    /// whole cluster costs one HashMap probe per chain block instead of
    /// one per (node, block) pair.
    pub fn best_prefix_into(&self, hash_ids: &[BlockId], out: &mut Vec<TierMatch>) {
        out.clear();
        out.resize(self.n_nodes, TierMatch::default());
        if self.n_nodes == 0 {
            return;
        }
        let all: u64 = if self.n_nodes == 64 { u64::MAX } else { (1u64 << self.n_nodes) - 1 };
        // Nodes whose match still extends / whose match is still a pure
        // DRAM run.  A cleared bit means that node's `blocks` (resp.
        // `dram_prefix`) has been finalized in `out`.
        let mut alive = all;
        let mut dram_run = all;
        for (i, &b) in hash_ids.iter().enumerate() {
            if alive == 0 {
                break;
            }
            let r = self.map.get(&b).copied().unwrap_or_default();
            let resident = (r.dram | r.ssd) & alive;
            // Nodes missing this block: their match ends at i blocks.
            let mut died = alive & !resident;
            while died != 0 {
                let n = died.trailing_zeros() as usize;
                died &= died - 1;
                out[n].blocks = i;
                if dram_run & (1u64 << n) != 0 {
                    out[n].dram_prefix = i;
                }
            }
            alive = resident;
            dram_run &= alive;
            // Nodes whose block is SSD-resident: their pure-DRAM leading
            // run ends here (and the block counts as an SSD copy).
            let mut run_end = dram_run & !r.dram;
            while run_end != 0 {
                let n = run_end.trailing_zeros() as usize;
                run_end &= run_end - 1;
                out[n].dram_prefix = i;
            }
            dram_run &= r.dram;
            let mut on_ssd = alive & r.ssd;
            while on_ssd != 0 {
                let n = on_ssd.trailing_zeros() as usize;
                on_ssd &= on_ssd - 1;
                out[n].ssd_blocks += 1;
            }
        }
        // Survivors matched the whole chain.
        let full = hash_ids.len();
        let mut still = alive;
        while still != 0 {
            let n = still.trailing_zeros() as usize;
            still &= still - 1;
            out[n].blocks = full;
            if dram_run & (1u64 << n) != 0 {
                out[n].dram_prefix = full;
            }
        }
        for m in out.iter_mut() {
            m.dram_blocks = m.blocks - m.ssd_blocks;
        }
    }

    /// Allocating convenience wrapper around [`Self::best_prefix_into`].
    pub fn best_prefix(&self, hash_ids: &[BlockId]) -> Vec<TierMatch> {
        let mut out = Vec::new();
        self.best_prefix_into(hash_ids, &mut out);
        out
    }

    /// Debug invariant: the incrementally maintained index equals a
    /// brute-force rebuild from the pools (in node order).
    pub fn equals_rebuild_of<'a>(&self, pools: impl Iterator<Item = &'a CachePool>) -> bool {
        let mut fresh = PrefixIndex::new(self.n_nodes);
        let mut count = 0usize;
        for (n, pool) in pools.enumerate() {
            fresh.insert_pool(n, pool);
            count = n + 1;
        }
        count == self.n_nodes && fresh.map == self.map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PolicyKind;

    fn pools(n: usize) -> Vec<CachePool> {
        (0..n).map(|_| CachePool::new(PolicyKind::Lru, Some(64), Some(64))).collect()
    }

    fn scan(pools: &[CachePool], chain: &[BlockId]) -> Vec<TierMatch> {
        pools.iter().map(|p| p.prefix_match(chain)).collect()
    }

    #[test]
    fn best_prefix_matches_per_pool_scan() {
        let mut ps = pools(3);
        let mut idx = PrefixIndex::new(3);
        let chain: Vec<BlockId> = (10..20).collect();
        // Node 0: full chain in DRAM; node 1: first half, with one block
        // demoted to SSD; node 2: nothing.
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        idx.apply(1, &ps[1].admit_chain(&chain[..5], 0.0));
        idx.apply(1, &ps[1].demote_block(12, 1.0).unwrap());
        let got = idx.best_prefix(&chain);
        let want = scan(&ps, &chain);
        assert_eq!(got, want);
        assert_eq!(got[0].blocks, 10);
        assert_eq!(got[1], TierMatch { blocks: 5, dram_prefix: 2, dram_blocks: 4, ssd_blocks: 1 });
        assert_eq!(got[2], TierMatch::default());
        assert!(idx.equals_rebuild_of(ps.iter()));
    }

    #[test]
    fn tier_on_tracks_moves_and_drops() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        idx.apply(0, &ps[0].admit_chain(&[1, 2], 0.0));
        idx.apply(1, &ps[1].admit_chain(&[2], 0.0));
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Dram));
        assert_eq!(idx.tier_on(1, 1), None);
        assert_eq!(idx.tier_on(1, 2), Some(Tier::Dram));
        idx.apply(0, &ps[0].demote_block(1, 1.0).unwrap());
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Ssd));
        // A drop removes the node's bit; the last holder's drop removes
        // the entry entirely.
        idx.set(0, 1, None);
        assert_eq!(idx.tier_on(0, 1), None);
        assert_eq!(idx.len(), 1); // only block 2 remains
    }

    #[test]
    fn eviction_pressure_keeps_index_consistent() {
        // A 4-block DRAM tier over a 6-block SSD tier: admissions demote
        // and eventually drop; the deltas must keep the index equal to a
        // rebuild at every step, and best_prefix equal to the scan.
        let mut ps = vec![CachePool::new(PolicyKind::Lru, Some(4), Some(6))];
        let mut idx = PrefixIndex::new(1);
        for round in 0..8u64 {
            let chain: Vec<BlockId> = (round * 3..round * 3 + 4).collect();
            let delta = ps[0].admit_chain(&chain, round as f64);
            idx.apply(0, &delta);
            assert!(idx.equals_rebuild_of(ps.iter()), "round {round}");
            assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain), "round {round}");
        }
    }

    #[test]
    fn sixty_four_node_masks_have_no_shift_overflow() {
        let mut idx = PrefixIndex::new(64);
        idx.set(63, 7, Some(Tier::Ssd));
        assert_eq!(idx.tier_on(63, 7), Some(Tier::Ssd));
        let m = idx.best_prefix(&[7]);
        assert_eq!(m[63], TierMatch { blocks: 1, dram_prefix: 0, dram_blocks: 0, ssd_blocks: 1 });
        assert_eq!(m[0], TierMatch::default());
        assert!(!PrefixIndex::supports(65));
    }

    #[test]
    fn empty_chain_and_empty_index() {
        let idx = PrefixIndex::new(2);
        assert!(idx.is_empty());
        let m = idx.best_prefix(&[]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
        let m = idx.best_prefix(&[99]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
    }
}
