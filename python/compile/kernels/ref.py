"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float32 tolerance across the shape/dtype sweep in
`python/tests/`.  They are written for clarity, not speed.
"""

import jax.numpy as jnp


def repeat_kv(x, group: int):
    """[..., kvh, hd] -> [..., kvh*group, hd] by repeating each KV head."""
    return jnp.repeat(x, group, axis=-2)


def decode_attention_ref(q, k, v, lens):
    """Single-token (decode-step) attention over a contiguous cache.

    q:    [B, nh, hd]   query for the new token of each sequence
    k, v: [B, C, kvh, hd]   per-slot KVCache (positions >= lens are junk)
    lens: [B] int32     valid cache length per slot (>= 1)
    returns [B, nh, hd]
    """
    B, nh, hd = q.shape
    kvh = k.shape[2]
    group = nh // kvh
    kr = repeat_kv(k, group)  # [B, C, nh, hd]
    vr = repeat_kv(v, group)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # [B, nh, C]
    s = jnp.einsum("bnd,bcnd->bnc", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    pos = jnp.arange(k.shape[1])[None, None, :]
    s = jnp.where(pos < lens[:, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bnc,bcnd->bnd", p, vr.astype(jnp.float32)).astype(q.dtype)


def prefill_attention_ref(q, k, v, q_start, kv_len):
    """Causal chunked-prefill attention.

    The chunk's queries live at global positions q_start..q_start+S-1 and
    attend to all cache positions j <= their own position (the cache holds
    the reused prefix plus this chunk's freshly-written K/V).

    q:    [S, nh, hd]
    k, v: [C, kvh, hd]
    q_start: scalar int32 (global offset of q[0])
    kv_len:  scalar int32 (valid cache positions; >= q_start + S)
    returns [S, nh, hd]
    """
    S, nh, hd = q.shape
    kvh = k.shape[1]
    group = nh // kvh
    kr = repeat_kv(k, group)  # [C, nh, hd]
    vr = repeat_kv(v, group)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("snd,cnd->snc", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    qpos = q_start + jnp.arange(S)[:, None, None]
    cpos = jnp.arange(k.shape[0])[None, None, :]
    mask = (cpos <= qpos) & (cpos < kv_len)
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("snc,cnd->snd", p, vr.astype(jnp.float32)).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lens):
    """Decode attention over a paged KVCache.

    q:            [B, nh, hd]
    k/v_pages:    [NP, PS, kvh, hd]   global page pool
    block_tables: [B, MB] int32       page ids per sequence (row-major)
    lens:         [B] int32           valid tokens per sequence
    returns [B, nh, hd]
    """
    B = q.shape[0]
    MB = block_tables.shape[1]
    PS = k_pages.shape[1]
    # Gather each sequence's pages into a contiguous [B, MB*PS, kvh, hd] view.
    k = k_pages[block_tables].reshape(B, MB * PS, *k_pages.shape[2:])
    v = v_pages[block_tables].reshape(B, MB * PS, *v_pages.shape[2:])
    return decode_attention_ref(q, k, v, lens)
