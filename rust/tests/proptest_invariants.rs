//! Property-based tests (hand-rolled generators — proptest is not
//! available offline) over coordinator invariants: request conservation,
//! cache capacity bounds, prefix-chain consistency, JSON roundtrips, and
//! simulator determinism, across randomized configurations and traces.

use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig};
use mooncake::kvcache::{chain_hashes, CachePool, EvictionPolicy, PolicyKind};
use mooncake::metrics::Outcome;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::jsonl;
use mooncake::trace::{TraceRecord, BLOCK_TOKENS};
use mooncake::util::json;
use mooncake::util::rng::Rng;

fn random_trace(rng: &mut Rng, n: usize) -> Vec<TraceRecord> {
    let cfg = TraceGenConfig {
        n_requests: n,
        duration_ms: 300_000 + rng.below(1_200_000),
        seed: rng.next_u64(),
        mean_first_input: 1_000.0 + rng.f64() * 15_000.0,
        session_fraction: rng.f64(),
        mean_session_turns: 1.0 + rng.f64() * 5.0,
        ..Default::default()
    };
    gen::generate(&cfg)
}

fn random_sim_config(rng: &mut Rng) -> SimConfig {
    let scheds = [
        SchedulingPolicy::Random,
        SchedulingPolicy::LoadBalance,
        SchedulingPolicy::CacheAware,
        SchedulingPolicy::KvCacheCentric,
    ];
    let rejects = [
        RejectionPolicy::None,
        RejectionPolicy::Baseline,
        RejectionPolicy::Early,
        RejectionPolicy::Predictive,
    ];
    SimConfig {
        n_prefill: 1 + rng.below(6) as usize,
        n_decode: 1 + rng.below(6) as usize,
        scheduling: scheds[rng.below(4) as usize],
        rejection: rejects[rng.below(4) as usize],
        cache_capacity_blocks: if rng.f64() < 0.3 { Some(1 + rng.below(5_000) as usize) } else { None },
        seed: rng.next_u64(),
        ..Default::default()
    }
}

/// Property: every submitted request is accounted for exactly once, with
/// a consistent outcome.
#[test]
fn prop_request_conservation() {
    let mut rng = Rng::new(0xC0FFEE);
    for round in 0..8 {
        let n = 200 + rng.below(300) as usize;
        let trace = random_trace(&mut rng, n);
        let cfg = random_sim_config(&mut rng);
        let speedup = 1.0 + rng.f64() * 5.0;
        let res = sim::run(&cfg, &trace, speedup);
        assert_eq!(res.metrics.len(), trace.len(), "round {round}: {cfg:?}");
        for m in &res.metrics {
            match m.outcome {
                Outcome::Completed => {
                    assert!(m.ttft_ms.is_finite() && m.ttft_ms >= 0.0);
                    assert_eq!(m.generated, m.output_tokens);
                    assert!(m.finish >= m.arrival + m.ttft_ms - 1e-6);
                }
                _ => {
                    assert!(m.ttft_ms.is_nan());
                    assert_eq!(m.generated, 0);
                }
            }
        }
        // Block accounting: every block a scheduled request *needs* is
        // either reused or recomputed — needed is the hash chain capped
        // at the blocks covering the input (a chain may overhang a
        // non-block-aligned input; the overhang is neither).
        let scheduled_blocks: u64 = res
            .metrics
            .iter()
            .filter(|m| m.outcome != Outcome::RejectedAtArrival)
            .map(|m| {
                let r = &trace[m.id as usize];
                (r.hash_ids.len() as u64).min(r.input_length.div_ceil(BLOCK_TOKENS))
            })
            .sum();
        assert_eq!(
            res.conductor.reused_blocks + res.conductor.recomputed_blocks,
            scheduled_blocks,
            "round {round}"
        );
    }
}

/// Property: simulation is a pure function of (config, trace).
#[test]
fn prop_determinism() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..4 {
        let trace = random_trace(&mut rng, 150);
        let cfg = random_sim_config(&mut rng);
        let a = sim::run(&cfg, &trace, 2.0);
        let b = sim::run(&cfg, &trace, 2.0);
        assert_eq!(a.metrics.len(), b.metrics.len());
        for (x, y) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(x.outcome, y.outcome);
            assert!((x.ttft_ms.is_nan() && y.ttft_ms.is_nan()) || x.ttft_ms == y.ttft_ms);
            assert_eq!(x.finish, y.finish);
        }
        assert_eq!(a.transfer_bytes, b.transfer_bytes);
    }
}

/// Property: eviction policies never exceed capacity and never lose a
/// block that wasn't evicted or removed.
#[test]
fn prop_eviction_capacity_and_accounting() {
    let mut rng = Rng::new(0xFEED);
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        for _ in 0..5 {
            let cap = 1 + rng.below(200) as usize;
            let mut p = EvictionPolicy::new(kind, Some(cap));
            let mut inserted = std::collections::HashSet::new();
            let mut evicted = std::collections::HashSet::new();
            for step in 0..3_000u64 {
                let b = rng.below(500);
                match rng.below(10) {
                    0 => {
                        if p.remove(b) {
                            inserted.remove(&b);
                        }
                    }
                    1..=3 => {
                        p.touch(b, step as f64, rng.below(40) as usize);
                    }
                    _ => {
                        if let Some(e) = p.insert(b, step as f64, rng.below(40) as usize) {
                            evicted.insert(e);
                            inserted.remove(&e);
                        }
                        inserted.insert(b);
                    }
                }
                assert!(p.len() <= cap, "{kind:?}: {} > {cap}", p.len());
                // Everything we believe is inside must be inside.
                for &x in inserted.iter() {
                    assert!(p.contains(x), "{kind:?} lost block {x}");
                }
            }
        }
    }
}

/// Property: a pool's prefix match length never exceeds the chain length
/// and is monotone under chain extension.
#[test]
fn prop_prefix_match_monotone() {
    let mut rng = Rng::new(0xABCD);
    for _ in 0..20 {
        let mut pool = CachePool::new(PolicyKind::Lru, Some(1_000));
        let chain: Vec<u64> = (0..rng.range(1, 40)).map(|_| rng.below(10_000)).collect();
        pool.admit_chain(&chain, 0.0);
        let m1 = pool.prefix_match_blocks(&chain);
        assert!(m1 <= chain.len());
        let mut longer = chain.clone();
        longer.push(99_999_999);
        let m2 = pool.prefix_match_blocks(&longer);
        assert!(m2 >= m1.min(chain.len()));
        // Divergence at position k caps the match at k.
        if chain.len() > 2 {
            let mut diverged = chain.clone();
            diverged[1] = 77_777_777;
            assert!(pool.prefix_match_blocks(&diverged) <= 1);
        }
    }
}

/// Property: chain hashes are prefix-stable and divergence-propagating.
#[test]
fn prop_chain_hash_prefix_stability() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..30 {
        let n = rng.range(1, 2_000) as usize;
        let toks: Vec<u32> = (0..n).map(|_| rng.below(1 << 20) as u32).collect();
        let block = [16usize, 64, 512][rng.below(3) as usize];
        let h = chain_hashes(&toks, block);
        assert_eq!(h.len(), n.div_ceil(block));
        // A prefix of the tokens yields a prefix of the hashes (for the
        // full blocks it covers).
        let cut = rng.range(1, n as u64) as usize;
        let h2 = chain_hashes(&toks[..cut], block);
        let full = cut / block;
        assert_eq!(h[..full], h2[..full]);
    }
}

/// Property: JSONL roundtrip is the identity on generated traces.
#[test]
fn prop_jsonl_roundtrip_identity() {
    let mut rng = Rng::new(0xD00D);
    for _ in 0..5 {
        let trace = random_trace(&mut rng, 100);
        let path = std::env::temp_dir().join(format!("mc_prop_{}.jsonl", rng.next_u64()));
        jsonl::save(&path, &trace).unwrap();
        let loaded = jsonl::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace.len(), loaded.len());
        let mut sorted = trace.clone();
        sorted.sort_by_key(|r| r.timestamp);
        // Loader sorts by timestamp; compare multisets via sorted order.
        for (a, b) in sorted.iter().zip(&loaded) {
            assert_eq!(a.timestamp, b.timestamp);
        }
    }
}

/// Property: arbitrary JSON values survive serialize -> parse.
#[test]
fn prop_json_roundtrip_fuzz() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.f64() < 0.5),
            2 => json::Value::Num((rng.below(1 << 30) as f64) - (1 << 29) as f64),
            3 => json::Value::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => json::Value::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => json::Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0xFACE);
    for _ in 0..200 {
        let v = random_value(&mut rng, 3);
        let s = json::to_string(&v);
        let back = json::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(v, back, "roundtrip failed for {s}");
    }
}
