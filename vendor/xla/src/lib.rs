//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container image this repo builds in has no XLA/PJRT shared
//! libraries and no registry access, so the live-serving path
//! (`mooncake::runtime`) links against this stub instead.  Host-side
//! [`Literal`] operations (creation, round-tripping, shapes) are fully
//! functional — they back unit tests — while anything requiring a real
//! PJRT device client ([`PjRtClient::cpu`], compilation, execution, npz
//! loading) returns an explicit "unavailable" error.  The e2e tests skip
//! when `artifacts/` is absent, so the stub never fails a test run; on a
//! machine with real bindings, point the `xla` dependency at them and the
//! call sites compile unchanged.

use std::fmt;
use std::path::Path;

/// Stub error: a message, `Debug`-printable like xla-rs errors.
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("{what} unavailable: built against the stub `xla` crate (vendor/xla)"))
}

/// Element types the runtime uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::S32 => 4,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// A host-resident tensor: shape + raw bytes.  Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Rank-1 literal from a scalar slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let bytes = unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        };
        Literal { ty: T::TY, dims: vec![v.len()], data: bytes.to_vec() }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.byte_width();
        if data.len() != want {
            return Err(Error(format!(
                "shape {dims:?} needs {want} bytes, got {}",
                data.len()
            )));
        }
        Literal { ty, dims: dims.to_vec(), data: data.to_vec() }.ok()
    }

    fn ok(self) -> Result<Literal> {
        Ok(self)
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.ty.byte_width()
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!("literal is {:?}, asked for {:?}", self.ty, T::TY)));
        }
        let n = self.element_count();
        let mut out: Vec<T> = Vec::with_capacity(n);
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    /// Tuple literals only exist on-device in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals"))
    }
}

/// Loading host data from serialized containers (npz).
pub trait FromRawBytes: Sized {
    fn read_npz(path: impl AsRef<Path>, opts: &()) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz(path: impl AsRef<Path>, _opts: &()) -> Result<Vec<(String, Literal)>> {
        Err(Error(format!(
            "read_npz({:?}) unavailable: built against the stub `xla` crate",
            path.as_ref()
        )))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error(format!(
            "HLO parsing of {:?} unavailable: built against the stub `xla` crate",
            path.as_ref()
        )))
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// On-device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device buffers"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// PJRT client.  `cpu()` fails fast so callers surface a clear message.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 3],
            &[0u8; 24],
        )
        .unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![0.0; 6]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn vec1_preserves_values() {
        let l = Literal::vec1(&[1i32, -2, 3]);
        assert_eq!(l.shape(), &[3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2, 3]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 5])
            .is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::read_npz("/tmp/nope.npz", &()).is_err());
        assert!(HloModuleProto::from_text_file("/tmp/nope.hlo").is_err());
    }
}
