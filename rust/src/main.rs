//! Mooncake CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   gen-trace   — write a calibrated synthetic trace (published schema)
//!   analyze     — trace statistics (Fig 5/6, Table 1 style)
//!   simulate    — replay a trace through the Mooncake cluster simulator
//!   replay      — stream trace file(s) through the simulator without
//!                 materializing them (bounded memory, multi-tenant mixing)
//!   baseline    — replay through the vLLM-like coupled baseline
//!   serve       — live path: load AOT artifacts, serve prompts via PJRT

use anyhow::{bail, Result};

use mooncake::baseline::{self, VllmConfig};
use mooncake::config::{NodeOverride, RejectionPolicy, SchedulingPolicy, SimConfig};
use mooncake::engine::{Engine, EngineConfig, GenRequest};
use mooncake::faults::FaultPlan;
use mooncake::kvcache::PolicyKind;
use mooncake::model::HardwareSpec;
use mooncake::runtime::Runtime;
use mooncake::sim;
use mooncake::trace::{gen, jsonl, replay as trace_replay, stats};
use mooncake::util::args::Args;
use mooncake::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse();
    match args.positional.first().map(String::as_str) {
        Some("gen-trace") => gen_trace(&args),
        Some("analyze") => analyze(&args),
        Some("simulate") => simulate(&args),
        Some("replay") => replay(&args),
        Some("baseline") => run_baseline(&args),
        Some("serve") => serve(&args),
        _ => {
            eprintln!(
                "usage: mooncake <gen-trace|analyze|simulate|replay|baseline|serve> [--options]\n\
                 \n\
                 gen-trace --out trace.jsonl [--requests 23608] [--seed 42]\n\
                 analyze   --trace trace.jsonl[.gz]\n\
                 simulate  --trace trace.jsonl[.gz] [--prefill 8] [--decode 8] [--speedup 1]\n\
                 \t[--policy random|load|cache|centric] [--reject none|baseline|early|predictive]\n\
                 \t[--dram-blocks 50000] [--ssd-blocks 250000] [--demote-after-ms N]\n\
                 \t[--rx-bw BYTES_PER_SEC] [--ssd-write-bw BYTES_PER_SEC]\n\
                 \t[--no-prefix-index] [--sched-workers N] [--no-hybrid]\n\
                 \t[--faults plan.json] [--retry-budget N]\n\
                 \t[--node-hw node:spec[:dram[:ssd]],...  (spec: a800|h800|FACTOR)]\n\
                 replay    --traces a.jsonl[,b.jsonl.gz,...] [--rates 1[,2,...]]\n\
                 \t[--prefill 8] [--decode 8] [--policy ...] [--reject ...]\n\
                 \t[--max-live N] [--epoch-blocks N] [--no-metrics]\n\
                 \t[--sched-workers N] [--no-hybrid]\n\
                 \t[--faults plan.json] [--retry-budget N] [--node-hw ...]\n\
                 baseline  --trace trace.jsonl [--instances 4] [--speedup 1]\n\
                 serve     [--artifacts artifacts] [--requests 8] [--max-new 32]"
            );
            bail!("missing or unknown subcommand")
        }
    }
}

fn gen_trace(args: &Args) -> Result<()> {
    let out = args.get_or("out", "trace.jsonl");
    let cfg = gen::TraceGenConfig {
        n_requests: args.get_usize("requests", 23_608),
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    let trace = gen::generate(&cfg);
    jsonl::save(&out, &trace)?;
    let s = stats::summarize(&trace);
    println!(
        "wrote {} requests to {out} (mean input {:.0}, mean output {:.0}, {} unique blocks)",
        s.n_requests, s.mean_input, s.mean_output, s.unique_blocks
    );
    Ok(())
}

fn analyze(args: &Args) -> Result<()> {
    let path = args.get_or("trace", "trace.jsonl");
    let trace = jsonl::load(&path)?;
    let s = stats::summarize(&trace);
    println!("requests:        {}", s.n_requests);
    println!("mean input len:  {:.0} tokens", s.mean_input);
    println!("mean output len: {:.0} tokens", s.mean_output);
    println!("blocks: {} total refs, {} unique", s.total_blocks, s.unique_blocks);
    println!("\ncache hit rate (single global pool, Table 1 style):");
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        print!("  {:18}", kind.name());
        for cap in [None, Some(50_000), Some(10_000), Some(1_000)] {
            let r = stats::cache_hit_rate(&trace, kind, cap);
            let label = cap.map(|c| c.to_string()).unwrap_or_else(|| "inf".into());
            print!("  {label}:{r:.2}");
        }
        println!();
    }
    Ok(())
}

fn parse_policy(s: &str) -> Result<SchedulingPolicy> {
    Ok(match s {
        "random" => SchedulingPolicy::Random,
        "load" => SchedulingPolicy::LoadBalance,
        "cache" => SchedulingPolicy::CacheAware,
        "centric" => SchedulingPolicy::KvCacheCentric,
        other => bail!("unknown scheduling policy {other}"),
    })
}

fn parse_reject(s: &str) -> Result<RejectionPolicy> {
    Ok(match s {
        "none" => RejectionPolicy::None,
        "baseline" => RejectionPolicy::Baseline,
        "early" => RejectionPolicy::Early,
        "predictive" => RejectionPolicy::Predictive,
        other => bail!("unknown rejection policy {other}"),
    })
}

/// Scripted fault plan (`--faults plan.json`): parsed and validated
/// loudly *before* the run starts — a malformed script must not silently
/// produce a healthy-looking measurement.  Absent → the empty plan (the
/// healthy baseline, bit-for-bit).
fn parse_faults(args: &Args) -> Result<FaultPlan> {
    match args.get("faults") {
        None if args.has_flag("faults") => {
            bail!("--faults requires a path (a fault-plan JSON file)")
        }
        None => Ok(FaultPlan::default()),
        Some(path) => {
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--faults {path}: {e}"))?;
            FaultPlan::from_json(&src).map_err(|e| anyhow::anyhow!("--faults {path}: {e}"))
        }
    }
}

fn parse_retry_budget(args: &Args, default: u32) -> Result<u32> {
    match args.get("retry-budget") {
        None if args.has_flag("retry-budget") => {
            bail!("--retry-budget requires a value (re-admissions per orphaned request)")
        }
        None => Ok(default),
        Some(s) => s
            .parse::<u32>()
            .map_err(|_| anyhow::anyhow!("invalid --retry-budget {s} (expected a count)")),
    }
}

/// Heterogeneous hardware: `--node-hw node:spec[:dram[:ssd]]`, comma
/// separated.  `spec` is a named GPU generation (`a800` = the 1.0
/// baseline, `h800` = the measured prefill speed ratio over A800) or a
/// bare positive speed factor; the optional trailing fields override
/// that node's DRAM/SSD tier capacities in blocks.
fn parse_node_hw(args: &Args) -> Result<Vec<NodeOverride>> {
    let Some(s) = args.get("node-hw") else {
        if args.has_flag("node-hw") {
            bail!("--node-hw requires a value (node:spec[:dram[:ssd]], comma separated)");
        }
        return Ok(Vec::new());
    };
    let a800 = HardwareSpec::a800_node();
    let mut out = Vec::new();
    for part in s.split(',') {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 2 || fields.len() > 4 {
            bail!("invalid --node-hw entry {part:?} (expected node:spec[:dram[:ssd]])");
        }
        let node: usize = fields[0]
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --node-hw node {:?}", fields[0]))?;
        let speed = match fields[1] {
            "a800" | "a100" => 1.0,
            "h800" | "h100" => HardwareSpec::h800_node().prefill_speed_ratio(&a800),
            num => match num.parse::<f64>() {
                Ok(v) if v > 0.0 && v.is_finite() => v,
                _ => bail!(
                    "invalid --node-hw spec {num:?} (expected a800|h800 or a positive factor)"
                ),
            },
        };
        let cap = |i: usize| -> Result<Option<usize>> {
            match fields.get(i) {
                None => Ok(None),
                Some(x) => x
                    .parse::<usize>()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("invalid --node-hw capacity {x:?} (blocks)")),
            }
        };
        out.push(NodeOverride { node, speed, dram_blocks: cap(2)?, ssd_blocks: cap(3)? });
    }
    Ok(out)
}

/// Scheduler worker threads for the candidate walk + scoring (default 1
/// = the sequential loop).  Any value yields bit-for-bit the same
/// placements — this is purely a wall-clock knob — but a bad value must
/// still fail loudly, not silently fall back to sequential.
fn parse_sched_workers(args: &Args) -> Result<usize> {
    match args.get("sched-workers") {
        None if args.has_flag("sched-workers") => {
            bail!("--sched-workers requires a value (a positive thread count)")
        }
        None => Ok(1),
        Some(s) => match s.parse::<usize>() {
            Ok(v) if v > 0 => Ok(v),
            _ => bail!("invalid --sched-workers {s} (expected a positive thread count)"),
        },
    }
}

fn simulate(args: &Args) -> Result<()> {
    let path = args.get_or("trace", "trace.jsonl");
    let trace = jsonl::load(&path)?;
    let defaults = SimConfig::default();
    // Proactive background demotion sweep (off unless given).  Reject
    // bad values loudly — silently disabling a requested feature would
    // fake a demotions=0 measurement.
    let demote_after_ms = match args.get("demote-after-ms") {
        None if args.has_flag("demote-after-ms") => {
            bail!("--demote-after-ms requires a value (positive ms)")
        }
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => Some(v),
            _ => bail!("invalid --demote-after-ms {s} (expected a positive ms value)"),
        },
    };
    // Optional contention knobs (B/s), off by default: a finite rx
    // bandwidth makes incast congest; a finite NVMe write bandwidth
    // makes demotion writes contend with staging reads.
    let parse_bw = |key: &str| -> Result<Option<f64>> {
        match args.get(key) {
            None if args.has_flag(key) => bail!("--{key} requires a value (bytes/sec)"),
            None => Ok(None),
            Some(s) => match s.parse::<f64>() {
                Ok(v) if v > 0.0 => Ok(Some(v)),
                _ => bail!("invalid --{key} {s} (expected a positive bytes/sec value)"),
            },
        }
    };
    let cfg = SimConfig {
        n_prefill: args.get_usize("prefill", 8),
        n_decode: args.get_usize("decode", 8),
        scheduling: parse_policy(&args.get_or("policy", "centric"))?,
        rejection: parse_reject(&args.get_or("reject", "none"))?,
        seed: args.get_u64("seed", 42),
        cache_capacity_blocks: Some(
            args.get_usize("dram-blocks", defaults.cache_capacity_blocks.unwrap_or(50_000)),
        ),
        ssd_capacity_blocks: Some(
            args.get_usize("ssd-blocks", defaults.ssd_capacity_blocks.unwrap_or(250_000)),
        ),
        // Pure optimization — `--no-prefix-index` restores the per-pool
        // scan (bit-for-bit identical results, for A/B timing).
        use_prefix_index: !args.has_flag("no-prefix-index"),
        // `--no-hybrid` restores the exclusive three-way prefix decision
        // (bit-for-bit yesterday's placements, for A/B ablations).
        hybrid: !args.has_flag("no-hybrid"),
        sched_workers: parse_sched_workers(args)?,
        nic_rx_bw: parse_bw("rx-bw")?,
        ssd_write_bw: parse_bw("ssd-write-bw")?,
        demote_after_ms,
        faults: parse_faults(args)?,
        fault_retry_budget: parse_retry_budget(args, defaults.fault_retry_budget)?,
        node_overrides: parse_node_hw(args)?,
        ..Default::default()
    };
    // Shape errors fail here, before the run, with the plan's own
    // diagnostics (the simulator would only panic mid-run).
    if let Err(e) = cfg.faults.validate(cfg.n_prefill, cfg.n_prefill + cfg.n_decode) {
        bail!("{e}");
    }
    let speedup = args.get_f64("speedup", 1.0);
    let res = sim::run(&cfg, &trace, speedup);
    let rep = res.report(&cfg);
    println!("requests:   {} total, {} completed", rep.n_total, rep.n_completed);
    println!(
        "rejected:   {} at arrival, {} after prefill (wasted {} prefill tokens)",
        rep.n_rejected_arrival, rep.n_rejected_after_prefill, rep.wasted_prefill_tokens
    );
    println!("TTFT:       mean {:.0} ms, P90 {:.0} ms (SLO {:.0})", rep.ttft_mean, rep.ttft_p90, cfg.slo.ttft_ms);
    println!("TTFT est:   mean abs drift {:.2} ms (cost-model estimate vs observed)", rep.ttft_est_mae);
    println!("TBT:        P90 {:.1} ms (SLO {:.0})", rep.tbt_p90, cfg.slo.tbt_ms);
    println!("SLO attainment: {:.1}%", rep.slo_attainment * 100.0);
    println!("goodput:    {:.2} req/s, {:.0} tok/s", rep.goodput_rps, rep.goodput_tokens_per_sec);
    println!(
        "cache:      {} blocks reused, {} recomputed, {} remote fetches, {} migrations",
        res.conductor.reused_blocks,
        res.conductor.recomputed_blocks,
        res.conductor.remote_fetches,
        res.conductor.migrations
    );
    println!(
        "tiers:      {} DRAM hits, {} SSD hits, {} demotions, {} promotions, {} dropped",
        res.tier.dram_hits, res.tier.ssd_hits, res.tier.demotions, res.tier.promotions, res.tier.dropped
    );
    println!(
        "SSD loads:  {} placements staged {} blocks ({} recompute-overrides, {} MB read)",
        res.conductor.ssd_loads,
        res.conductor.ssd_loaded_blocks,
        res.conductor.ssd_recomputes,
        res.ssd_loaded_bytes / 1_000_000
    );
    println!(
        "hybrid:     {} placements overlapped {} staged + {} recomputed blocks",
        res.conductor.hybrid_placements,
        res.conductor.hybrid_staged_blocks,
        res.conductor.hybrid_recomputed_blocks
    );
    if !cfg.faults.is_empty() {
        println!(
            "faults:     {} injected ({} node losses, {} recoveries, {} bw changes); \
             {} jobs killed, {} retried, {} rescued, {} lost",
            res.faults.injected,
            res.faults.nodes_lost,
            res.faults.nodes_recovered,
            res.faults.bw_changes,
            res.faults.jobs_killed,
            res.faults.retried,
            res.faults.rescued,
            res.faults.lost
        );
    }
    // Utilization denominators: NIC banks span every node; NVMe traffic
    // only ever lands on prefill nodes (staging reads, demotion writes),
    // so its device utilization is per prefill node.
    let n_nodes = cfg.n_prefill + cfg.n_decode;
    for (name, bank, devices) in [
        ("NIC-tx", &res.resources.nic_tx, n_nodes),
        ("NIC-rx", &res.resources.nic_rx, n_nodes),
        ("NVMe", &res.resources.nvme, cfg.n_prefill),
    ] {
        println!(
            "{name:7} {} ops, {} MB, queued {:.0} ms, utilization {:.1}%",
            bank.n_ops,
            bank.total_bytes / 1_000_000,
            bank.queued_ms,
            bank.utilization(res.wall_ms, devices) * 100.0
        );
    }
    Ok(())
}

/// Streaming replay: admit requests straight from the trace file(s)
/// without materializing them — the 10M-request path.  A single trace
/// streams with its hashes untouched (same results as `simulate` on the
/// same file at the same rate); several traces merge as tenants with
/// per-tenant arrival-rate scales and FNV hash namespacing.
fn replay(args: &Args) -> Result<()> {
    let traces: Vec<String> =
        args.get_or("traces", "trace.jsonl").split(',').map(str::to_string).collect();
    let rates: Vec<f64> = match args.get("rates") {
        None => vec![1.0; traces.len()],
        Some(s) => s
            .split(',')
            .map(|x| {
                x.parse::<f64>().map_err(|e| anyhow::anyhow!("bad --rates entry {x:?}: {e}"))
            })
            .collect::<Result<_>>()?,
    };
    if rates.len() != traces.len() {
        bail!("--rates has {} entries for {} traces", rates.len(), traces.len());
    }
    // Loud parsing for the bounded-memory knobs, same contract as the
    // simulate knobs: a bad value must not silently run unbounded.
    let parse_count = |key: &str| -> Result<Option<usize>> {
        match args.get(key) {
            None if args.has_flag(key) => bail!("--{key} requires a value (a positive count)"),
            None => Ok(None),
            Some(s) => match s.parse::<usize>() {
                Ok(v) if v > 0 => Ok(Some(v)),
                _ => bail!("invalid --{key} {s} (expected a positive count)"),
            },
        }
    };
    let cfg = SimConfig {
        n_prefill: args.get_usize("prefill", 8),
        n_decode: args.get_usize("decode", 8),
        scheduling: parse_policy(&args.get_or("policy", "centric"))?,
        rejection: parse_reject(&args.get_or("reject", "none"))?,
        seed: args.get_u64("seed", 42),
        hybrid: !args.has_flag("no-hybrid"),
        sched_workers: parse_sched_workers(args)?,
        max_live_requests: parse_count("max-live")?,
        interner_epoch_blocks: parse_count("epoch-blocks")?,
        retain_metrics: !args.has_flag("no-metrics"),
        faults: parse_faults(args)?,
        fault_retry_budget: parse_retry_budget(args, SimConfig::default().fault_retry_budget)?,
        node_overrides: parse_node_hw(args)?,
        ..Default::default()
    };
    if let Err(e) = cfg.faults.validate(cfg.n_prefill, cfg.n_prefill + cfg.n_decode) {
        bail!("{e}");
    }
    // A loader error (bad line, timestamp regression) aborts the replay
    // with the reader's `file:line` diagnostic.
    let die = |e: anyhow::Error| -> sim::Request {
        eprintln!("{e}");
        std::process::exit(2);
    };
    let t0 = std::time::Instant::now();
    let res = if traces.len() == 1 {
        let stream = trace_replay::ReplayStream::open(&traces[0], rates[0])?;
        sim::run_streaming(&cfg, stream.map(|r| r.unwrap_or_else(die)))
    } else {
        let mix = trace_replay::ReplayMix::open(&traces, &rates)?;
        sim::run_streaming(&cfg, mix.map(|r| r.unwrap_or_else(die)))
    };
    let wall = t0.elapsed().as_secs_f64();
    let total = res.n_completed + res.n_rejected;
    println!(
        "replayed {total} requests ({} completed, {} rejected) in {wall:.2} s — {:.0} req/s",
        res.n_completed,
        res.n_rejected,
        total as f64 / wall.max(1e-9)
    );
    println!(
        "live peak:  {} requests{}",
        res.live_peak,
        cfg.max_live_requests.map(|c| format!(" (cap {c})")).unwrap_or_default()
    );
    println!(
        "interner:   id space {} ({} recycle epochs freed {} ids)",
        res.interner_id_space, res.interner_epochs, res.interner_freed
    );
    if !cfg.faults.is_empty() {
        println!(
            "faults:     {} injected; {} jobs killed, {} retried, {} rescued, {} lost",
            res.faults.injected,
            res.faults.jobs_killed,
            res.faults.retried,
            res.faults.rescued,
            res.faults.lost
        );
    }
    println!(
        "simulated:  {:.0} s of cluster time, {} events, {} tokens decoded",
        res.wall_ms / 1e3,
        res.n_events,
        res.decode_tokens_out
    );
    if cfg.retain_metrics {
        let rep = res.report(&cfg);
        println!("TTFT:       mean {:.0} ms, P90 {:.0} ms", rep.ttft_mean, rep.ttft_p90);
        println!("SLO attainment: {:.1}%", rep.slo_attainment * 100.0);
    }
    Ok(())
}

fn run_baseline(args: &Args) -> Result<()> {
    let path = args.get_or("trace", "trace.jsonl");
    let trace = jsonl::load(&path)?;
    let cfg = VllmConfig {
        n_instances: args.get_usize("instances", 4),
        serial_mode: args.has_flag("serial"),
        ..Default::default()
    };
    let rep = baseline::run(&cfg, &trace, args.get_f64("speedup", 1.0));
    println!("vLLM-[{}M]: {} completed", cfg.n_instances, rep.n_completed);
    println!("TTFT: mean {:.0} ms, P90 {:.0} ms", rep.ttft_mean, rep.ttft_p90);
    println!("TBT:  P90 {:.1} ms", rep.tbt_p90);
    println!("SLO attainment: {:.1}%", rep.slo_attainment * 100.0);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let n = args.get_usize("requests", 8);
    let max_new = args.get_usize("max-new", 32);
    println!("loading AOT artifacts from {dir} ...");
    let rt = Runtime::load(&dir)?;
    let vocab = rt.manifest.vocab;
    let mut engine = Engine::new(rt, EngineConfig::default());
    let mut rng = Rng::new(args.get_u64("seed", 42));
    // Shared system-prompt prefix exercises the live prefix cache.
    let system: Vec<i32> = (0..96).map(|_| rng.below(vocab as u64) as i32).collect();
    let reqs: Vec<GenRequest> = (0..n)
        .map(|i| {
            let mut prompt = system.clone();
            let user_len = 32 + rng.below(96) as usize;
            prompt.extend((0..user_len).map(|_| rng.below(vocab as u64) as i32));
            GenRequest { id: i as u64, prompt, max_new }
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = engine.serve(&reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut total_tokens = 0usize;
    for r in &results {
        total_tokens += r.output.len();
        println!(
            "req {:>3}: prompt {:>4} tok ({} reused), {} generated, TTFT {:>8.1} ms, TBT mean {:>6.2} ms max {:>6.2} ms",
            r.id, r.prompt_tokens, r.reused_tokens, r.output.len(), r.ttft_ms, r.mean_tbt_ms, r.max_tbt_ms
        );
    }
    println!(
        "\nserved {n} requests in {wall:.2} s — {:.1} tok/s decode throughput, cache {} hits / {} misses",
        total_tokens as f64 / wall,
        engine.cache_hits,
        engine.cache_misses
    );
    Ok(())
}
