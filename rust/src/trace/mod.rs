//! The Mooncake open-source trace schema (§4) and tooling around it:
//! JSONL load/store, a statistical generator calibrated to the published
//! trace features, and analyzers for Figs 5/6 and Table 1.

pub mod gen;
pub mod inflate;
pub mod jsonl;
pub mod replay;
pub mod stats;

use crate::BlockId;

/// Number of tokens hashed into one prefix block in the published trace.
pub const BLOCK_TOKENS: u64 = 512;

/// One request record — exactly the published schema:
/// `{"timestamp", "input_length", "output_length", "hash_ids"}`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Arrival time, ms since trace start (0..3_600_000 in the paper).
    pub timestamp: u64,
    /// Input (prompt) tokens.
    pub input_length: u64,
    /// Output (generated) tokens.
    pub output_length: u64,
    /// Prefix-chained block hashes remapped to global ids; identical ids
    /// ⇒ identical 512-token blocks *and* identical preceding context, so
    /// a shared leading run of ids is a reusable KVCache prefix.
    pub hash_ids: Vec<BlockId>,
}

impl TraceRecord {
    /// Full blocks covered by the input (the trace's hash_ids length).
    pub fn n_blocks(&self) -> usize {
        self.hash_ids.len()
    }

    /// Longest shared prefix (in blocks) with a set of cached block ids,
    /// scanning leading hash_ids.  This is the `prefix_len` lookup of
    /// Algorithm 1 expressed on the trace schema.
    pub fn prefix_match_blocks(&self, contains: impl Fn(BlockId) -> bool) -> usize {
        self.hash_ids.iter().take_while(|&&b| contains(b)).count()
    }

    /// Prefix match measured in tokens (capped by input_length).
    pub fn prefix_match_tokens(&self, matched_blocks: usize) -> u64 {
        (matched_blocks as u64 * BLOCK_TOKENS).min(self.input_length)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_match_respects_chain_order() {
        let r = TraceRecord {
            timestamp: 0,
            input_length: 2048,
            output_length: 10,
            hash_ids: vec![5, 6, 7, 8],
        };
        // Cache holds 5,6,8 — the chain breaks at 7.
        let cached = [5u64, 6, 8];
        let m = r.prefix_match_blocks(|b| cached.contains(&b));
        assert_eq!(m, 2);
        assert_eq!(r.prefix_match_tokens(m), 1024);
    }

    #[test]
    fn prefix_tokens_capped_by_input() {
        let r = TraceRecord {
            timestamp: 0,
            input_length: 600, // 1 full block + change
            output_length: 1,
            hash_ids: vec![1],
        };
        assert_eq!(r.prefix_match_tokens(1), 512);
    }
}
