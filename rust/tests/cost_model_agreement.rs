//! The acceptance property of the unified cost model: Conductor's TTFT
//! estimate (recorded at admission) and the simulator-observed TTFT
//! (recorded by the `PrefillDone` event) must agree — on an unloaded
//! cluster and under heavy queueing — because both are computed by the
//! same `costmodel` API over the same queue state.
//!
//! Stated tolerance: |estimate − observed| ≤ 1 ms + 1% of the observed
//! TTFT per request.  (The implementation is exact up to f64 noise; the
//! tolerance leaves room for future stochastic execution models.)

use mooncake::config::{RejectionPolicy, SimConfig};
use mooncake::metrics::Outcome;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::TraceRecord;

fn trace(n: usize) -> Vec<TraceRecord> {
    gen::generate(&TraceGenConfig { n_requests: n, duration_ms: 900_000, ..Default::default() })
}

fn assert_agreement(
    cfg: &SimConfig,
    trace: &[TraceRecord],
    speedup: f64,
    min_completed: usize,
) -> sim::SimResult {
    let res = sim::run(cfg, trace, speedup);
    check_agreement(&res, cfg, min_completed);
    res
}

/// The per-request tolerance check on an already-produced result, so
/// streaming-replay scenarios (which drive `sim::run_streaming`
/// themselves) share the exact same bound.
fn check_agreement(res: &sim::SimResult, cfg: &SimConfig, min_completed: usize) {
    let mut checked = 0;
    for m in res.metrics.iter().filter(|m| m.outcome == Outcome::Completed) {
        assert!(m.est_ttft_ms.is_finite(), "request {} lost its estimate", m.id);
        let err = (m.est_ttft_ms - m.ttft_ms).abs();
        let tol = 1.0 + 0.01 * m.ttft_ms;
        assert!(
            err <= tol,
            "request {}: estimated TTFT {} vs observed {} (err {err} > tol {tol})",
            m.id,
            m.est_ttft_ms,
            m.ttft_ms
        );
        checked += 1;
    }
    assert!(
        checked >= min_completed,
        "agreement check needs completions to mean anything: {checked} < {min_completed}"
    );
    let rep = res.report(cfg);
    assert!(
        rep.ttft_est_mae <= 1.0,
        "mean abs estimate drift {} ms exceeds 1 ms",
        rep.ttft_est_mae
    );
}

#[test]
fn estimates_match_actuals_unloaded() {
    let cfg = SimConfig::default();
    assert_agreement(&cfg, &trace(150), 1.0, 140);
}

#[test]
fn estimates_match_actuals_on_loaded_cluster() {
    // 2 prefill instances at 5x replay: deep FIFO queues, CPP groups, and
    // remote fetches all in play — the estimate must still track the
    // events, since queue drift compounds over every queued request.
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    assert_agreement(&cfg, &trace(300), 5.0, 250);
}

#[test]
fn estimates_match_under_admission_control() {
    // Early rejection consults the same queues; whatever it admits must
    // still land where the estimate said.
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        rejection: RejectionPolicy::Early,
        ..Default::default()
    };
    assert_agreement(&cfg, &trace(300), 4.0, 50);
}

#[test]
fn estimates_hold_on_sustained_streaming_replay_with_early_rejection() {
    // Sustained overloaded replay through the bounded-memory streaming
    // loop with §7.2 early rejection live at the arrival boundary.
    // Decode slots are scarce (2 instances × batch 8) against a ~4×
    // oversubscribed arrival rate, so the decode backlog term drives
    // admission back and forth across the 0.9 load threshold: a steady
    // interleaving of admitted and rejected arrivals for minutes of
    // simulated time — and every admitted request's TTFT estimate must
    // still land within 1 ms + 1%, with the interner recycling ids
    // underneath the whole run.
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        rejection: RejectionPolicy::Early,
        max_decode_batch: 8,
        overload_threshold: 0.9,
        cache_capacity_blocks: Some(2_000),
        ssd_capacity_blocks: Some(4_000),
        max_live_requests: Some(48),
        interner_epoch_blocks: Some(1_024),
        ..Default::default()
    };
    let mut reqs: Vec<sim::Request> = trace(2_000)
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut req = sim::Request::from_trace(i as u64, r);
            req.arrival /= 4.0;
            req
        })
        .collect();
    reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let res = sim::run_streaming(&cfg, reqs.into_iter());
    check_agreement(&res, &cfg, 200);
    assert!(res.rejected_at_arrival > 0, "early rejection never engaged");
    assert_eq!(res.n_completed + res.n_rejected, 2_000, "requests went missing");
    assert!(res.live_peak <= 48, "live cap breached: {}", res.live_peak);
    assert!(res.interner_epochs > 0, "sustained replay must trigger id recycling");
}

#[test]
fn estimates_match_on_cold_start_after_idle_gap() {
    // Sessions go idle and re-arrive much later (the PR-1 re-arrival
    // knob) against a DRAM tier far smaller than the working set: by the
    // time a session returns, its prefix has been demoted to SSD, so the
    // three-way decision (reuse DRAM / stage from SSD / recompute) is
    // live — and the estimate must still land exactly where the
    // `PrefillStart`/`PrefillDone`/`SsdLoad` events put it.
    let trace = gen::generate(&TraceGenConfig {
        n_requests: 250,
        duration_ms: 1_800_000,
        rearrival_fraction: 0.7,
        mean_rearrival_gap_ms: 600_000.0,
        ..Default::default()
    });
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(100_000),
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let res = assert_agreement(&cfg, &trace, 1.0, 200);
    // The scenario actually exercised the tier machinery: capacity
    // pressure demoted blocks, and returning prefixes faced the
    // load-vs-recompute pricing.
    assert!(res.tier.demotions > 0, "DRAM pressure must demote to SSD");
    assert!(
        res.tier.ssd_hits + res.conductor.ssd_recomputes > 0,
        "re-arrived prefixes must hit the three-way decision"
    );
}

#[test]
fn remote_fetch_estimate_charges_source_ssd_staging() {
    // ROADMAP follow-up (PR 2): a §6.2 remote prefix fetch whose source
    // holds the prefix on its SSD tier must charge the *source's* NVMe
    // queue before the wire — estimate and execution alike.  Wire-only
    // pricing would put the planned start seconds early (NVMe is ~30×
    // slower than RDMA here), exactly the estimate/actual drift the
    // unified cost model exists to prevent.
    use mooncake::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
    use mooncake::costmodel;
    use mooncake::model::PerfModel;
    use mooncake::prefill::PrefillPool;
    use mooncake::resource::Resources;
    use mooncake::trace::BLOCK_TOKENS;
    use mooncake::util::rng::Rng;

    let cfg = SimConfig { kvcache_balancing_threshold: 1.5, ..Default::default() };
    let perf = PerfModel::paper();
    let mut prefill = PrefillPool::new(&cfg);
    let decodes: Vec<mooncake::decode::DecodeInstance> = (0..cfg.n_decode)
        .map(|_| {
            mooncake::decode::DecodeInstance::new(
                perf.vram_kv_capacity_tokens(),
                cfg.max_decode_batch,
            )
        })
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let blocks = 64u64;
    let r = SchedRequest {
        rid: 5,
        input_tokens: blocks * BLOCK_TOKENS,
        output_tokens: 100,
        hash_ids: (5_000u32..5_000 + blocks as u32).collect(),
    };
    // Warm one holder with the chain.
    {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now: 0.0,
            index: None,
            scratch: &mut scratch,
        };
        conductor::schedule(&mut ctx, &r, &mut stats).unwrap();
    }
    let holder = prefill
        .instances
        .iter()
        .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == blocks as usize)
        .unwrap();
    // Demote the whole chain to the holder's SSD tier, then swamp the
    // holder so the balancing branch fetches the prefix remotely.
    for &b in &r.hash_ids {
        assert!(prefill.instances[holder].pool.demote_block(b, 1.0).is_some());
    }
    prefill.instances[holder].block_until(1e9);

    let now = 1e6;
    let mut ctx = conductor::Ctx {
        cfg: &cfg,
        perf: &perf,
        prefill: &mut prefill,
        decodes: &decodes,
        res: &mut res,
        rng: &mut rng,
        now,
        index: None,
        scratch: &mut scratch,
    };
    let p = conductor::schedule(&mut ctx, &r, &mut stats).unwrap();
    assert_ne!(p.prefill_group[0], holder, "swamped holder must lose the placement");
    assert_eq!(p.fetch, Some((holder, blocks as usize)));
    assert_eq!(p.fetch_ssd_stage_blocks, blocks as usize, "whole prefix staged at source");
    assert_eq!(stats.fetch_stagings, 1);
    assert_eq!(stats.fetch_staged_blocks, blocks);

    // Estimate == execution, to the millisecond term: with the source's
    // NVMe queue, its NIC, and the target queue idle, the planned start
    // is exactly source staging + wire serialization.  (The probe runs
    // against a fresh bank — `res`'s queues already hold the committed
    // reservation.)
    let fresh = Resources::new(&cfg, &perf);
    let stage =
        costmodel::estimate_stage_done(&perf, &fresh.nvme, holder, 0.0, blocks * BLOCK_TOKENS);
    let bytes = costmodel::fetch_bytes(&perf, blocks as usize);
    let wire = 1.0 + bytes as f64 / (perf.hw.rdma_bw / 1e3);
    assert!(stage > 1_000.0, "NVMe staging must be material: {stage}");
    assert!(
        (p.prefill_start - (now + stage + wire)).abs() < 1e-6,
        "planned start {} != now + stage {stage} + wire {wire}",
        p.prefill_start
    );
    assert_eq!(p.fetch_stage_done, Some(now + stage));
}

#[test]
fn estimates_match_under_concurrent_nvme_staging() {
    // The tentpole's NVMe-queue scenario: two deep prefixes demoted to
    // one node's SSD tier re-arrive ~1 s apart, so the second staging
    // read queues behind the first on the shared NVMe device — and the
    // TTFT estimate must price that queueing exactly, because estimator
    // and executor read the same `BwQueue`.  (The chains are deep enough
    // that staging beats recompute even with the queueing priced in —
    // shallower chains would make Algorithm 1 flip to recompute, which
    // is the decision-side face of the same contention signal.)
    use mooncake::trace::BLOCK_TOKENS;
    let blocks = 256u64;
    let rec = |t: u64, base: u64| TraceRecord {
        timestamp: t,
        input_length: blocks * BLOCK_TOKENS,
        output_length: 8,
        hash_ids: (base..base + blocks).collect(),
    };
    let trace = vec![
        rec(0, 1_000),       // A cold — fills the DRAM tier exactly
        rec(60_000, 2_000),  // B cold — evicts A wholesale to SSD
        rec(300_000, 1_000), // A returns: a ~14 s staging read
        rec(301_000, 2_000), // B returns while A's read is in flight
    ];
    let cfg = SimConfig {
        n_prefill: 1,
        n_decode: 1,
        scheduling: mooncake::config::SchedulingPolicy::CacheAware,
        cache_capacity_blocks: Some(blocks as usize),
        ssd_capacity_blocks: Some(100_000),
        // Pin the exclusive three-way decision: this scenario's asserts
        // (whole-chain staging, 2·blocks SSD hits) are about the *full*
        // staging read queueing on the shared device.  The hybrid twin
        // below runs the same scenario with the fourth branch live.
        hybrid: false,
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let res = assert_agreement(&cfg, &trace, 1.0, 4);
    // The scenario really contended: both returns staged from SSD, on
    // the same device, back to back.
    assert_eq!(res.conductor.ssd_loads, 2, "both re-arrivals must stage, not recompute");
    assert_eq!(res.resources.nvme.n_ops, 2);
    assert!(
        res.resources.nvme.queued_ms > 5_000.0,
        "the second staging must queue behind the first: {} ms",
        res.resources.nvme.queued_ms
    );
    assert_eq!(res.tier.ssd_hits, 2 * blocks);
}

#[test]
fn estimates_match_with_nvme_degraded_to_quarter_speed_mid_run() {
    // The PR-10 degraded twin of the scenario above: two deep demoted
    // prefixes re-arrive ~1 s apart, but a fault plan has cut node 0's
    // NVMe to 25% just before they return — so both staging reads are
    // priced *and executed* at quarter bandwidth, the second queued
    // behind a ~4×-longer first read.  The 1 ms + 1% contract must hold
    // anyway: the BwChange event rescales the same `BwQueue` estimator
    // and executor share, and the restore (which lands while the second
    // reserved read is still draining) touches only future ops, never a
    // booked window.
    //
    // Chain length is the decision margin here.  At quarter bandwidth a
    // staging read costs 4 × ~0.44 ms/KB-token; recompute grows
    // *quadratically* in chain length through the attention term.  A
    // 2048-block (1M-token) chain recomputes in ~1050 s but stages in
    // ~458 s even degraded (~915 s for the queued second read), so the
    // three-way decision still picks SSD for both re-arrivals — a
    // shorter chain would rationally flip to recompute, which is the
    // degraded-mode adaptivity other tests cover.
    use mooncake::faults::{Bank, FaultPlan};
    use mooncake::trace::BLOCK_TOKENS;
    let blocks = 2_048u64;
    let rec = |t: u64, base: u64| TraceRecord {
        timestamp: t,
        input_length: blocks * BLOCK_TOKENS,
        output_length: 8,
        hash_ids: (base..base + blocks).collect(),
    };
    let trace = vec![
        rec(0, 10_000),          // A cold — fills the DRAM tier exactly
        rec(1_100_000, 20_000),  // B cold — evicts A wholesale to SSD
        rec(2_600_000, 10_000),  // A returns: a ~4x slower staging read
        rec(2_601_000, 20_000),  // B returns while A's slow read drains
    ];
    let cfg = SimConfig {
        n_prefill: 1,
        n_decode: 1,
        scheduling: mooncake::config::SchedulingPolicy::CacheAware,
        cache_capacity_blocks: Some(blocks as usize),
        ssd_capacity_blocks: Some(100_000),
        // Same exclusive-decision pin as the healthy twin: the asserts
        // are about whole-chain staging reads on the degraded device.
        hybrid: false,
        faults: FaultPlan::new().bw_degrade(0, Bank::Nvme, 0.25, 2_500_000.0, 3_100_000.0),
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let res = assert_agreement(&cfg, &trace, 1.0, 4);
    assert_eq!(res.conductor.ssd_loads, 2, "both re-arrivals must still stage");
    assert_eq!(res.resources.nvme.n_ops, 2);
    assert_eq!(res.faults.bw_changes, 2, "one degrade edge, one restore edge");
    // The second read queued behind a 4x-longer first: minutes of
    // queueing, dwarfing the healthy twin's > 5 s.
    assert!(
        res.resources.nvme.queued_ms > 100_000.0,
        "degraded queueing must dwarf the healthy twin: {} ms",
        res.resources.nvme.queued_ms
    );
    assert_eq!(res.tier.ssd_hits, 2 * blocks);
}

#[test]
fn estimates_match_on_hybrid_placements_under_concurrent_nvme_staging() {
    // The PR-9 acceptance scenario: the same two deep demoted prefixes
    // re-arrive ~1 s apart, but with Algorithm 1's fourth branch live
    // both returns take *hybrid* plans — a partial staging read
    // overlapped with recompute of the tail — so the second request's
    // split is priced against an NVMe queue already holding the first's
    // multi-second read.  The 1 ms + 1% tolerance must hold anyway:
    // `estimate_prefill_hybrid` probes the same `BwQueue` the executor
    // reserves, and the completion floor folds the staging landing into
    // the job's exec time with the identical float expressions.
    use mooncake::trace::BLOCK_TOKENS;
    let blocks = 256u64;
    let rec = |t: u64, base: u64| TraceRecord {
        timestamp: t,
        input_length: blocks * BLOCK_TOKENS,
        output_length: 8,
        hash_ids: (base..base + blocks).collect(),
    };
    let trace = vec![
        rec(0, 1_000),       // A cold — fills the DRAM tier exactly
        rec(60_000, 2_000),  // B cold — evicts A wholesale to SSD
        rec(300_000, 1_000), // A returns: hybrid stage+recompute
        rec(301_000, 2_000), // B returns while A's read is in flight
    ];
    let cfg = SimConfig {
        n_prefill: 1,
        n_decode: 1,
        scheduling: mooncake::config::SchedulingPolicy::CacheAware,
        cache_capacity_blocks: Some(blocks as usize),
        ssd_capacity_blocks: Some(100_000),
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let res = assert_agreement(&cfg, &trace, 1.0, 4);
    // Both re-arrivals took the fourth branch: one partial staging read
    // each, overlapped with recompute of the rest of the chain.
    assert_eq!(res.conductor.hybrid_placements, 2, "both re-arrivals must go hybrid");
    assert_eq!(res.conductor.ssd_loads, 2);
    assert_eq!(res.resources.nvme.n_ops, 2);
    assert_eq!(
        res.conductor.hybrid_staged_blocks + res.conductor.hybrid_recomputed_blocks,
        2 * blocks,
        "the two splits must cover both chains exactly"
    );
    assert!(res.conductor.hybrid_staged_blocks > 0);
    assert!(res.conductor.hybrid_recomputed_blocks > 0);
    // The second read genuinely queued behind the first on the device.
    assert!(
        res.resources.nvme.queued_ms > 1_000.0,
        "the second staging must queue behind the first: {} ms",
        res.resources.nvme.queued_ms
    );
    // Hits reflect the staged heads only — strictly fewer than the
    // exclusive scenario's whole-chain 2·blocks.
    assert!(res.tier.ssd_hits > 0 && res.tier.ssd_hits < 2 * blocks, "{}", res.tier.ssd_hits);
}

#[test]
fn estimates_match_under_incast_onto_one_prefill_node() {
    // The tentpole's NIC-rx scenario: three busy holders each forward
    // their hot prefix to the single idle node, so three fetches
    // converge on that node's rx queue.  With rx bandwidth far below tx
    // bandwidth the fan-in serializes on the *destination* — the
    // congestion the old source-NIC-only model could not express — and
    // the estimates must still match execution exactly.
    use mooncake::trace::BLOCK_TOKENS;
    let rec = |t: u64, base: u64, blocks: u64| TraceRecord {
        timestamp: t,
        input_length: blocks * BLOCK_TOKENS,
        output_length: 8,
        hash_ids: (base..base + blocks).collect(),
    };
    let trace = vec![
        // Warm three distinct chains onto nodes 0, 1, 2 (staggered so
        // queue depth spreads them).
        rec(0, 1_000, 64),
        rec(1, 2_000, 64),
        rec(2, 3_000, 64),
        // Occupy nodes 0, 1, 2 with ~30 s cold prefills.
        rec(60_000, 4_000, 256),
        rec(60_001, 5_000, 256),
        rec(60_002, 6_000, 256),
        // The warm chains return while their holders are busy: the
        // balancing branch forwards all three to the idle node 3.
        rec(60_100, 1_000, 64),
        rec(60_200, 2_000, 64),
        rec(60_300, 3_000, 64),
    ];
    let cfg = SimConfig {
        n_prefill: 4,
        n_decode: 2,
        cpp_group_max: 1, // keep the busy-filler jobs single-node
        nic_rx_bw: Some(2e9),
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let res = assert_agreement(&cfg, &trace, 1.0, 9);
    assert!(
        res.conductor.remote_fetches >= 3,
        "the returns must forward-fetch: {}",
        res.conductor.remote_fetches
    );
    assert!(
        res.resources.nic_rx.queued_ms > 5_000.0,
        "incast must serialize on the destination rx queue: {} ms",
        res.resources.nic_rx.queued_ms
    );
    // Pure-NIC scenario: nothing ever touched an SSD.
    assert_eq!(res.resources.nvme.n_ops, 0);
}

#[test]
fn estimates_match_on_bursty_replay() {
    // Burst windows drive the deepest queues — exactly where a drifting
    // estimator would be furthest off.
    let bursty = gen::generate(&TraceGenConfig {
        n_requests: 250,
        duration_ms: 900_000,
        burst_fraction: 0.7,
        n_bursts: 2,
        burst_width_ms: 15_000,
        ..Default::default()
    });
    let cfg = SimConfig {
        n_prefill: 4,
        n_decode: 4,
        slo: mooncake::config::SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    assert_agreement(&cfg, &bursty, 1.0, 200);
}
