//! Fig 7 — latency of storing KVCache for different request lengths:
//! serialized store cost vs the *visible* latency under layer-wise
//! prefill (§5.2).  The paper's point: overlap makes the store latency
//! negligible even at 128k tokens, so prefill scheduling can ignore VRAM.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::model::PerfModel;
use mooncake::prefill::layerwise;

fn main() {
    let perf = PerfModel::paper();

    banner("Fig 7: KVCache store latency vs request length");
    row(&[
        "tokens".into(),
        "full_store_ms".into(),
        "layerwise_visible_ms".into(),
        "prefill_ms".into(),
        "visible_over_prefill_%".into(),
    ]);
    for n in [1_000u64, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000, 128_000] {
        let (full, _) = perf.layerwise_store_ms(n);
        let visible = layerwise::visible_store_latency_ms(&perf, n);
        let prefill = perf.prefill_ms(n, 0);
        row(&[
            n.to_string(),
            fmt(full, 1),
            fmt(visible, 2),
            fmt(prefill, 1),
            fmt(visible / prefill * 100.0, 2),
        ]);
    }

    // Shape checks: visible latency stays a small, near-constant share.
    for n in [8_000u64, 32_000, 128_000] {
        let visible = layerwise::visible_store_latency_ms(&perf, n);
        let (full, _) = perf.layerwise_store_ms(n);
        let prefill = perf.prefill_ms(n, 0);
        assert!(visible < full * 0.25, "overlap must hide >75% at n={n}");
        assert!(visible < prefill * 0.1, "visible store < 10% of prefill at n={n}");
    }
    println!("\nfig7 shape checks OK");
}
