//! Table 1 — cache hit rates under different cache policies and
//! capacities, on the (generated) 23,608-request trace with a single
//! global cache pool.
//!
//! Paper row (LRU): inf 0.51, 100k 0.51, 50k 0.50, 30k 0.48, 10k 0.40,
//! 1k 0.30 — and LRU >= LFU >= LengthAware at mid capacities.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::kvcache::PolicyKind;
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::stats::{cache_hit_rate, tiered_cache_hit_rate};

fn main() {
    let trace = generate(&TraceGenConfig::default());
    let caps: Vec<Option<usize>> =
        vec![None, Some(100_000), Some(50_000), Some(30_000), Some(10_000), Some(1_000)];

    banner("Table 1: cache hit rates (23,608-request trace, global pool)");
    let mut header = vec!["policy".to_string()];
    header.extend(caps.iter().map(|c| c.map(|x| x.to_string()).unwrap_or("inf".into())));
    row(&header);

    let mut rates = std::collections::HashMap::new();
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        let mut cells = vec![kind.name().to_string()];
        for cap in &caps {
            let r = cache_hit_rate(&trace, kind, *cap);
            rates.insert((kind.name(), cap.map(|c| c).unwrap_or(usize::MAX)), r);
            cells.push(fmt(r, 3));
        }
        row(&cells);
    }

    // Shape checks against the paper's qualitative claims.
    let lru_inf = rates[&("LRUCache", usize::MAX)];
    let lru_1k = rates[&("LRUCache", 1_000)];
    assert!(lru_inf > 0.38 && lru_inf < 0.62, "infinite-cache ceiling ~0.5, got {lru_inf}");
    assert!(lru_1k < lru_inf - 0.05, "small cache must lose hits");
    // Capacity growth from 1k to 50k must recover most of the ceiling.
    let lru_50k = rates[&("LRUCache", 50_000)];
    assert!(lru_50k > lru_inf - 0.03, "50k blocks should be near the ceiling");
    println!("\ntable1 shape checks OK (ceiling {lru_inf:.2})");

    // Tier-capacity ablation: fixed DRAM, growing SSD tier underneath.
    // The SSD tier turns evictions into demotions, so DRAM+SSD at equal
    // DRAM capacity strictly dominates DRAM-only (§4.2's "underutilized
    // ... DRAM and SSD resources" claim made measurable).
    banner("Table 1b: DRAM+SSD tier ablation (LRU)");
    let ssd_caps: Vec<usize> = vec![0, 10_000, 50_000, 200_000];
    let header_b: Vec<String> =
        ["dram", "ssd", "hit", "demote", "promote", "dropped"].iter().map(|s| s.to_string()).collect();
    row(&header_b);
    for dram in [1_000usize, 10_000, 30_000] {
        for &ssd in &ssd_caps {
            let (r, tc) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(ssd));
            row(&[
                dram.to_string(),
                ssd.to_string(),
                fmt(r, 3),
                tc.demotions.to_string(),
                tc.promotions.to_string(),
                tc.dropped.to_string(),
            ]);
        }
    }
    for dram in [1_000usize, 10_000] {
        let (dram_only, _) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(0));
        assert!(
            (dram_only - rates[&("LRUCache", dram)]).abs() < 1e-12,
            "SSD-disabled tiered replay must equal the DRAM-only replay"
        );
        let (tiered, tc) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(200_000));
        assert!(
            tiered > dram_only + 0.02,
            "dram {dram}: DRAM+SSD hit rate {tiered} must beat DRAM-only {dram_only}"
        );
        assert!(tc.ssd_hits > 0 && tc.demotions > tc.dropped);
    }
    println!("\ntable1b tier ablation OK");
}
