//! Deterministic, scripted fault injection — the robustness frontier of
//! ROADMAP item 5.
//!
//! A [`FaultPlan`] is a list of *scheduled* adversities: a prefill node
//! dies (its DRAM+SSD pools drop, its in-flight jobs cancel, its
//! orphaned requests go back to the conductor for bounded re-admission),
//! a node comes back empty, or a device bank (NIC-tx, NIC-rx, NVMe)
//! degrades to a fraction of its bandwidth over a window.  Entries are
//! injected as *ordinary simulator events*, so a run with a plan is
//! exactly as reproducible as a run without one: same (config, plan) →
//! bit-for-bit the same `SimResult`, and the empty plan reproduces the
//! healthy baseline bit-for-bit (the simulator pushes zero fault
//! events).
//!
//! The plan is deliberately *scripted*, not sampled: determinism is the
//! repo's central invariant, and a fault schedule drawn from the sim RNG
//! would entangle failure timing with every other random draw.  Scripts
//! come from the builder API (tests) or `--faults plan.json` (CLI),
//! validated against the cluster shape before the run starts.

use crate::TimeMs;
use crate::util::json;

/// Which per-node bandwidth bank a [`FaultEntry::BwDegrade`] hits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bank {
    /// Outgoing NIC (remote prefix fetches, KV streams from this node).
    NicTx,
    /// Incoming NIC (incast onto this node).  With the default
    /// *unconstrained* rx model (`nic_rx_bw: None` → infinite bandwidth)
    /// a factor times infinity is still infinity, so degrading rx is a
    /// documented no-op unless the run sets a finite `--rx-bw`.
    NicRx,
    /// NVMe queue (SSD staging reads + demotion writes).
    Nvme,
}

impl Bank {
    fn name(self) -> &'static str {
        match self {
            Bank::NicTx => "nic_tx",
            Bank::NicRx => "nic_rx",
            Bank::Nvme => "nvme",
        }
    }
}

/// One scheduled adversity.  Times are absolute simulator milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEntry {
    /// Prefill node `node` dies at `at_ms`: pools drop (through the
    /// delta-maintained index), queued/running jobs cancel, orphaned
    /// requests re-admit against the survivors under the retry budget.
    NodeLoss { node: usize, at_ms: TimeMs },
    /// The node rejoins (empty — a dead node's cache does not survive
    /// it) and becomes placeable again.
    NodeRecover { node: usize, at_ms: TimeMs },
    /// Bank `bank` on `node` runs at `factor` × nominal bandwidth over
    /// `[from_ms, to_ms)`; already-reserved windows are honored, so
    /// estimates made after the change still equal actuals.
    BwDegrade { node: usize, bank: Bank, factor: f64, from_ms: TimeMs, to_ms: TimeMs },
}

/// A scripted fault schedule.  Empty by default — the healthy baseline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub entries: Vec<FaultEntry>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Builder: prefill node `node` dies at `at_ms`.
    pub fn node_loss(mut self, node: usize, at_ms: TimeMs) -> Self {
        self.entries.push(FaultEntry::NodeLoss { node, at_ms });
        self
    }

    /// Builder: prefill node `node` rejoins (empty) at `at_ms`.
    pub fn node_recover(mut self, node: usize, at_ms: TimeMs) -> Self {
        self.entries.push(FaultEntry::NodeRecover { node, at_ms });
        self
    }

    /// Builder: `bank` on `node` runs at `factor` × nominal over
    /// `[from_ms, to_ms)`.
    pub fn bw_degrade(
        mut self,
        node: usize,
        bank: Bank,
        factor: f64,
        from_ms: TimeMs,
        to_ms: TimeMs,
    ) -> Self {
        self.entries.push(FaultEntry::BwDegrade { node, bank, factor, from_ms, to_ms });
        self
    }

    /// Parse a plan from JSON: a top-level array of entry objects, e.g.
    /// `[{"kind":"node_loss","node":2,"at_ms":60000},
    ///   {"kind":"bw_degrade","node":0,"bank":"nvme","factor":0.25,
    ///    "from_ms":0,"to_ms":120000}]`.
    pub fn from_json(src: &str) -> Result<Self, String> {
        let v = json::parse(src).map_err(|e| format!("fault plan: {e}"))?;
        let arr = v.as_arr().ok_or("fault plan: top level must be a JSON array")?;
        let mut plan = FaultPlan::default();
        for (i, entry) in arr.iter().enumerate() {
            let obj = entry.as_obj().ok_or_else(|| format!("fault plan entry {i}: not an object"))?;
            let field = |key: &str| -> Result<f64, String> {
                obj.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("fault plan entry {i}: missing numeric \"{key}\""))
            };
            let kind = obj
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("fault plan entry {i}: missing string \"kind\""))?;
            let e = match kind {
                "node_loss" => FaultEntry::NodeLoss {
                    node: field("node")? as usize,
                    at_ms: field("at_ms")?,
                },
                "node_recover" => FaultEntry::NodeRecover {
                    node: field("node")? as usize,
                    at_ms: field("at_ms")?,
                },
                "bw_degrade" => {
                    let bank = match obj.get("bank").and_then(|v| v.as_str()) {
                        Some("nic_tx") => Bank::NicTx,
                        Some("nic_rx") => Bank::NicRx,
                        Some("nvme") => Bank::Nvme,
                        other => {
                            return Err(format!(
                                "fault plan entry {i}: bad \"bank\" {other:?} \
                                 (expected nic_tx|nic_rx|nvme)"
                            ))
                        }
                    };
                    FaultEntry::BwDegrade {
                        node: field("node")? as usize,
                        bank,
                        factor: field("factor")?,
                        from_ms: field("from_ms")?,
                        to_ms: field("to_ms")?,
                    }
                }
                other => {
                    return Err(format!(
                        "fault plan entry {i}: unknown \"kind\" {other:?} \
                         (expected node_loss|node_recover|bw_degrade)"
                    ))
                }
            };
            plan.entries.push(e);
        }
        Ok(plan)
    }

    /// Check the plan against the cluster shape before the run starts:
    /// only *prefill* nodes can be lost/recovered (decode loss is out of
    /// scope — validated here so it fails loudly, not silently), NVMe
    /// banks exist only on prefill nodes, NIC banks on every node, and
    /// degradation factors/windows must be sane.
    pub fn validate(&self, n_prefill: usize, n_total: usize) -> Result<(), String> {
        for (i, e) in self.entries.iter().enumerate() {
            match *e {
                FaultEntry::NodeLoss { node, at_ms } | FaultEntry::NodeRecover { node, at_ms } => {
                    if node >= n_prefill {
                        return Err(format!(
                            "fault plan entry {i}: node {node} out of range \
                             (only prefill nodes 0..{n_prefill} can be lost/recovered)"
                        ));
                    }
                    if !at_ms.is_finite() || at_ms < 0.0 {
                        return Err(format!("fault plan entry {i}: bad at_ms {at_ms}"));
                    }
                }
                FaultEntry::BwDegrade { node, bank, factor, from_ms, to_ms } => {
                    let limit = match bank {
                        Bank::Nvme => n_prefill,
                        Bank::NicTx | Bank::NicRx => n_total,
                    };
                    if node >= limit {
                        return Err(format!(
                            "fault plan entry {i}: node {node} out of range for bank {} \
                             (limit {limit})",
                            bank.name()
                        ));
                    }
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(format!(
                            "fault plan entry {i}: bad factor {factor} \
                             (expected a finite fraction > 0)"
                        ));
                    }
                    if !from_ms.is_finite() || !to_ms.is_finite() || from_ms < 0.0 || to_ms < from_ms
                    {
                        return Err(format!(
                            "fault plan entry {i}: bad window [{from_ms}, {to_ms})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// What the injected plan did to the run — reported in `SimResult` /
/// `RunReport` so no request is ever silently lost: every orphan is
/// either `rescued` (retried and later completed) or `lost` (retry
/// budget exhausted → counted as a rejection).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Fault events injected into the run (plan entries; a BwDegrade
    /// window counts once even though it compiles to two events).
    pub injected: u64,
    pub nodes_lost: u64,
    pub nodes_recovered: u64,
    /// Mid-run bandwidth scale changes applied (degrade + restore).
    pub bw_changes: u64,
    /// Prefill jobs cancelled by node loss (queued or running).
    pub jobs_killed: u64,
    /// Orphaned requests handed back to the conductor and re-admitted.
    pub retried: u64,
    /// Retried requests that later completed.
    pub rescued: u64,
    /// Orphans whose retry budget ran out (or re-pricing rejected them)
    /// — counted in `n_rejected`, never dropped silently.
    pub lost: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_json_agree() {
        let built = FaultPlan::new()
            .node_loss(2, 60_000.0)
            .node_recover(2, 180_000.0)
            .bw_degrade(0, Bank::Nvme, 0.25, 30_000.0, 90_000.0);
        let parsed = FaultPlan::from_json(
            r#"[
                {"kind":"node_loss","node":2,"at_ms":60000},
                {"kind":"node_recover","node":2,"at_ms":180000},
                {"kind":"bw_degrade","node":0,"bank":"nvme","factor":0.25,
                 "from_ms":30000,"to_ms":90000}
            ]"#,
        )
        .unwrap();
        assert_eq!(built, parsed);
        assert!(built.validate(8, 16).is_ok());
    }

    #[test]
    fn empty_plan_is_default() {
        assert_eq!(FaultPlan::from_json("[]").unwrap(), FaultPlan::default());
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn validate_rejects_out_of_range_nodes() {
        // Decode nodes cannot be lost.
        let p = FaultPlan::new().node_loss(9, 0.0);
        assert!(p.validate(8, 16).unwrap_err().contains("out of range"));
        // NVMe banks exist only on prefill nodes...
        let p = FaultPlan::new().bw_degrade(9, Bank::Nvme, 0.5, 0.0, 1.0);
        assert!(p.validate(8, 16).is_err());
        // ...but NIC banks span the whole cluster.
        let p = FaultPlan::new().bw_degrade(9, Bank::NicTx, 0.5, 0.0, 1.0);
        assert!(p.validate(8, 16).is_ok());
    }

    #[test]
    fn validate_rejects_bad_factors_and_windows() {
        for factor in [0.0, -0.5, f64::INFINITY, f64::NAN] {
            let p = FaultPlan::new().bw_degrade(0, Bank::Nvme, factor, 0.0, 1.0);
            assert!(p.validate(8, 16).is_err(), "factor {factor} must be rejected");
        }
        let p = FaultPlan::new().bw_degrade(0, Bank::Nvme, 0.5, 10.0, 5.0);
        assert!(p.validate(8, 16).unwrap_err().contains("window"));
    }

    #[test]
    fn json_errors_are_loud() {
        assert!(FaultPlan::from_json("{}").unwrap_err().contains("array"));
        assert!(FaultPlan::from_json(r#"[{"kind":"meteor"}]"#).unwrap_err().contains("meteor"));
        assert!(FaultPlan::from_json(r#"[{"kind":"node_loss"}]"#)
            .unwrap_err()
            .contains("node"));
        assert!(FaultPlan::from_json(r#"[{"kind":"bw_degrade","node":0,"bank":"warp",
            "factor":0.5,"from_ms":0,"to_ms":1}]"#)
            .unwrap_err()
            .contains("bank"));
    }
}
