//! JSONL reader/writer for the published trace format.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::TraceRecord;
use crate::util::json::{self, Value};

/// Parse a single JSONL line into a record.
pub fn parse_record(line: &str) -> Result<TraceRecord> {
    let v = json::parse(line).with_context(|| format!("bad trace line: {line:.80}"))?;
    let get = |k: &str| -> Result<&Value> {
        v.get(k).ok_or_else(|| anyhow::anyhow!("missing field {k}"))
    };
    let hash_ids = get("hash_ids")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("hash_ids not an array"))?
        .iter()
        .map(|x| x.as_u64().ok_or_else(|| anyhow::anyhow!("bad hash id")))
        .collect::<Result<Vec<_>>>()?;
    let rec = TraceRecord {
        timestamp: get("timestamp")?.as_u64().context("timestamp")?,
        input_length: get("input_length")?.as_u64().context("input_length")?,
        output_length: get("output_length")?.as_u64().context("output_length")?,
        hash_ids,
    };
    if rec.output_length == 0 {
        bail!("output_length must be >= 1");
    }
    Ok(rec)
}

pub fn record_to_json(r: &TraceRecord) -> String {
    json::to_string(&json::obj(vec![
        ("timestamp", json::num(r.timestamp as f64)),
        ("input_length", json::num(r.input_length as f64)),
        ("output_length", json::num(r.output_length as f64)),
        ("hash_ids", json::arr_u64(&r.hash_ids)),
    ]))
}

/// Load a whole trace, sorted by timestamp.  Gzipped traces are
/// detected by the `0x1F 0x8B` magic (same sniff as
/// [`super::replay::ReplayReader`]) and routed through the vendored
/// streaming inflater.
pub fn load<P: AsRef<Path>>(path: P) -> Result<Vec<TraceRecord>> {
    let f = File::open(path.as_ref())
        .with_context(|| format!("open trace {:?}", path.as_ref()))?;
    let mut raw = BufReader::new(f);
    let head =
        raw.fill_buf().with_context(|| format!("read trace {:?}", path.as_ref()))?;
    let reader: Box<dyn BufRead> = if head.starts_with(&[0x1F, 0x8B]) {
        Box::new(BufReader::new(super::inflate::GzReader::new(raw)))
    } else {
        Box::new(raw)
    };
    let mut out = Vec::new();
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_record(&line)?);
    }
    // Replay requires time order.
    out.sort_by_key(|r| r.timestamp);
    Ok(out)
}

pub fn save<P: AsRef<Path>>(path: P, records: &[TraceRecord]) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("create trace {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    for r in records {
        writeln!(w, "{}", record_to_json(r))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_sample() {
        let line = r#"{"timestamp": 27482, "input_length": 6955, "output_length": 52,
            "hash_ids": [46, 47, 48, 49, 50, 51, 52, 53, 54, 55, 56, 57, 2353, 2354]}"#;
        let r = parse_record(line).unwrap();
        assert_eq!(r.input_length, 6955);
        assert_eq!(r.hash_ids.len(), 14);
        assert_eq!(r.hash_ids[12], 2353);
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let recs = vec![
            TraceRecord { timestamp: 5, input_length: 100, output_length: 3, hash_ids: vec![1] },
            TraceRecord { timestamp: 2, input_length: 700, output_length: 9, hash_ids: vec![1, 2] },
        ];
        let path = std::env::temp_dir().join("mooncake_trace_test.jsonl");
        save(&path, &recs).unwrap();
        let loaded = load(&path).unwrap();
        // Loader sorts by timestamp.
        assert_eq!(loaded[0].timestamp, 2);
        assert_eq!(loaded[1], recs[0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_zero_output() {
        let line = r#"{"timestamp": 1, "input_length": 10, "output_length": 0, "hash_ids": []}"#;
        assert!(parse_record(line).is_err());
    }
}
