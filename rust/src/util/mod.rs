//! In-crate infrastructure: JSON, RNG + distributions, statistics, CLI
//! argument parsing, and the vendored fast hasher.  (No
//! serde/clap/rand/fxhash offline — see DESIGN.md.)

pub mod alloc_audit;
pub mod args;
pub mod fasthash;
pub mod json;
pub mod rng;
pub mod stats;
