//! L3 hot-path micro-benchmarks (the §Perf targets): Algorithm 1
//! scheduling latency, prefix matching, block interning, eviction ops,
//! and end-to-end simulator event throughput.  The paper notes TTFT
//! estimation "is computed in parallel, rendering the processing time
//! negligible compared to the inference time" — Conductor must stay out
//! of the way.

use mooncake::bench_util::{banner, bench};
use mooncake::conductor;
use mooncake::config::SimConfig;
use mooncake::kvcache::{BlockInterner, CachePool, DenseBlockId, PolicyKind};
use mooncake::prefill::PrefillPool;
use mooncake::sim;
use mooncake::trace::gen::{generate, TraceGenConfig};

fn main() {
    banner("hot-path micro-benchmarks");

    // Interning: the once-per-admission hash→dense mapping (warm path —
    // every chain block already has its id).
    let mut interner = BlockInterner::new();
    let hashes: Vec<u64> = (0..30u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15)).collect();
    let mut dense = Vec::new();
    interner.intern_chain_into(&hashes, &mut dense);
    bench("intern warm 30-block chain", 100, 10_000, || {
        interner.intern_chain_into(&hashes, &mut dense);
        std::hint::black_box(dense.len());
    })
    .print();

    // Prefix matching over a warm pool.
    let mut pool = CachePool::new(PolicyKind::Lru, Some(100_000), Some(0));
    for chain in 0..2_000u32 {
        let blocks: Vec<DenseBlockId> = (chain * 40..chain * 40 + 30).collect();
        let _ = pool.admit_chain(&blocks, chain as f64);
    }
    let probe: Vec<DenseBlockId> = (40_000..40_030).collect();
    bench("prefix_match_blocks (30-block chain)", 100, 10_000, || {
        std::hint::black_box(pool.prefix_match_blocks(&probe));
    })
    .print();

    // Eviction-policy churn, DRAM-only (evictions drop).
    let mut lru = CachePool::new(PolicyKind::Lru, Some(10_000), Some(0));
    let mut i = 0u32;
    bench("cache admit_chain under eviction (15 blocks)", 100, 10_000, || {
        let blocks: Vec<DenseBlockId> = (i * 15..i * 15 + 15).collect();
        let _ = lru.admit_chain(&blocks, i as f64);
        i += 1;
    })
    .print();

    // Tier churn: same workload but DRAM evictions demote to SSD and the
    // SSD tier itself overflows — the worst-case two-map path.
    let mut tiered = CachePool::new(PolicyKind::Lru, Some(10_000), Some(20_000));
    let mut j = 0u32;
    bench("tiered admit_chain under demotion (15 blocks)", 100, 10_000, || {
        let blocks: Vec<DenseBlockId> = (j * 15..j * 15 + 15).collect();
        let _ = tiered.admit_chain(&blocks, j as f64);
        j += 1;
    })
    .print();

    // FindBestPrefixMatch: per-pool scan vs the global prefix index on a
    // 16-node cluster where every node holds the probe chain — the
    // scan's worst case (no early miss terminates the walk).  The
    // deeper asymptotic sweep lives in the `sched_throughput` bench.
    let cfg16 = SimConfig {
        n_prefill: 16,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: None,
        ..Default::default()
    };
    let mut pfpool = PrefillPool::new(&cfg16);
    let probe512: Vec<DenseBlockId> = (0..512).collect();
    for inst in pfpool.instances.iter_mut() {
        let _ = inst.pool.admit_chain(&probe512, 0.0);
    }
    let idx = pfpool.build_prefix_index();
    bench("find_prefix_matches scan (16n x 512blk)", 100, 2_000, || {
        std::hint::black_box(conductor::find_prefix_matches(&pfpool, None, &probe512));
    })
    .print();
    bench("find_prefix_matches index (16n x 512blk)", 100, 2_000, || {
        std::hint::black_box(conductor::find_prefix_matches(&pfpool, Some(&idx), &probe512));
    })
    .print();

    // Full simulator throughput: events/sec over a 2k-request replay.
    let trace = generate(&TraceGenConfig { n_requests: 2_000, ..Default::default() });
    let cfg = SimConfig::default();
    let s = bench("sim replay 2k requests (8P+8D)", 1, 5, || {
        std::hint::black_box(sim::run(&cfg, &trace, 2.0));
    });
    s.print();
    let total_tokens: u64 = trace.iter().map(|r| r.output_length).sum();
    println!(
        "  -> {:.0} simulated decode tokens/ms of wall time",
        total_tokens as f64 / s.mean_ms
    );
}
