//! End-to-end tests over the live PJRT path: load the AOT artifacts,
//! run the real (tiny) dummy model, and check serving semantics —
//! determinism, prefix-cache equivalence, and chunked-prefill
//! consistency.  Skipped when `artifacts/` hasn't been built.

use mooncake::engine::{Engine, EngineConfig, GenRequest};
use mooncake::runtime::Runtime;
use mooncake::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn prompt(rng: &mut Rng, vocab: usize, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[test]
fn runtime_loads_and_manifests_agree() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let m = &rt.manifest;
    assert!(m.vocab > 0 && m.n_layers > 0 && m.max_ctx > 0);
    assert!(!m.prefill_buckets.is_empty() && !m.decode_buckets.is_empty());
    assert_eq!(m.kv_elems(), m.n_layers * 2 * m.max_ctx * m.n_kv_heads * m.head_dim);
    assert!(rt.prefill_bucket(1).is_some());
    assert!(rt.prefill_bucket(m.prefill_buckets[0]).is_some());
    assert!(rt.decode_bucket(1).is_some());
    assert!(rt.decode_bucket(999).is_none());
}

#[test]
fn generation_is_deterministic() {
    let dir = require_artifacts!();
    let mut outs = Vec::new();
    for _ in 0..2 {
        let rt = Runtime::load(&dir).unwrap();
        let vocab = rt.manifest.vocab;
        let mut engine = Engine::new(rt, EngineConfig::default());
        let mut rng = Rng::new(123);
        let reqs = vec![GenRequest { id: 0, prompt: prompt(&mut rng, vocab, 50), max_new: 12 }];
        let res = engine.serve(&reqs).unwrap();
        outs.push(res[0].output.clone());
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be deterministic");
    assert_eq!(outs[0].len(), 12);
}

#[test]
fn prefix_cache_reuse_matches_cold_output() {
    // The KVCache-reuse path (the paper's core mechanism) must be
    // *numerically equivalent* to recomputation.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let vocab = rt.manifest.vocab;
    let mut engine = Engine::new(rt, EngineConfig { block_tokens: 32, ..Default::default() });
    let mut rng = Rng::new(77);

    let shared = prompt(&mut rng, vocab, 96); // 3 cache blocks
    let tail_a = prompt(&mut rng, vocab, 40);
    let tail_b = prompt(&mut rng, vocab, 40);
    let mut pa = shared.clone();
    pa.extend(&tail_a);
    let mut pb = shared.clone();
    pb.extend(&tail_b);

    // Cold: request A primes the cache with the shared prefix.
    let res_a = engine.serve(&[GenRequest { id: 0, prompt: pa, max_new: 8 }]).unwrap();
    assert_eq!(res_a[0].reused_tokens, 0);

    // Warm: request B must reuse >= 96 tokens...
    let res_b = engine.serve(&[GenRequest { id: 1, prompt: pb.clone(), max_new: 8 }]).unwrap();
    assert!(res_b[0].reused_tokens >= 96, "reused {}", res_b[0].reused_tokens);

    // ...and produce exactly what a cold engine produces for B.
    let rt2 = Runtime::load(&dir).unwrap();
    let mut cold = Engine::new(rt2, EngineConfig { block_tokens: 32, ..Default::default() });
    let res_cold = cold.serve(&[GenRequest { id: 2, prompt: pb, max_new: 8 }]).unwrap();
    assert_eq!(
        res_b[0].output, res_cold[0].output,
        "prefix reuse changed the generation"
    );
}

#[test]
fn batched_decode_matches_single() {
    // Continuous batching must not perturb per-sequence results: serving
    // two prompts together equals serving them alone.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let vocab = rt.manifest.vocab;
    let mut rng = Rng::new(55);
    let p1 = prompt(&mut rng, vocab, 40);
    let p2 = prompt(&mut rng, vocab, 70);

    let serve_fresh = |reqs: &[GenRequest]| {
        let rt = Runtime::load(&dir).unwrap();
        let mut e = Engine::new(rt, EngineConfig::default());
        e.serve(reqs).unwrap()
    };
    let solo1 = serve_fresh(&[GenRequest { id: 0, prompt: p1.clone(), max_new: 10 }]);
    let solo2 = serve_fresh(&[GenRequest { id: 1, prompt: p2.clone(), max_new: 10 }]);
    let both = serve_fresh(&[
        GenRequest { id: 0, prompt: p1, max_new: 10 },
        GenRequest { id: 1, prompt: p2, max_new: 10 },
    ]);
    assert_eq!(both[0].output, solo1[0].output, "slot 0 diverged in batch");
    assert_eq!(both[1].output, solo2[0].output, "slot 1 diverged in batch");
}

#[test]
fn long_prompt_uses_chunked_prefill() {
    // A prompt longer than the biggest prefill bucket must be served via
    // multiple chunks (§5.1) and still generate max_new tokens.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let vocab = rt.manifest.vocab;
    let biggest = *rt.manifest.prefill_buckets.last().unwrap();
    let before = rt.n_prefill_calls.get();
    let mut engine = Engine::new(rt, EngineConfig::default());
    let mut rng = Rng::new(99);
    let long = prompt(&mut rng, vocab, biggest + 100);
    let res = engine
        .serve(&[GenRequest { id: 0, prompt: long, max_new: 6 }])
        .unwrap();
    assert_eq!(res[0].output.len(), 6);
    assert!(
        engine.rt.n_prefill_calls.get() - before >= 2,
        "expected >= 2 prefill chunks"
    );
}
