//! Decode instance pool: continuous batching (§3 step 4).
//!
//! Each decode instance holds a set of active sequences in VRAM and runs
//! fixed iterations; every iteration emits one token for every active
//! sequence (the iteration duration *is* each sequence's inter-token
//! time).  Newly arrived KVCaches join at iteration boundaries, subject
//! to the VRAM capacity and batch cap; completed sequences leave the
//! batch (continuous batching à la Orca/vLLM).

use std::collections::VecDeque;

use crate::model::PerfModel;
use crate::{RequestId, TimeMs};

#[derive(Debug, Clone)]
pub struct ActiveSeq {
    pub rid: RequestId,
    /// Current context length (grows by 1 per iteration).
    pub ctx: u64,
    /// Output tokens still to generate.
    pub remaining: u64,
    /// Arrival time of the KVCache at this instance.
    pub joined: TimeMs,
    /// Inter-token gaps experienced (ms) — TBT samples.
    pub gaps: Vec<f64>,
    /// Time of last token emission (or join).
    pub last_token: TimeMs,
}

#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub rid: RequestId,
    pub finish: TimeMs,
    pub max_gap: f64,
    pub mean_gap: f64,
    pub generated: u64,
}

#[derive(Debug)]
pub struct DecodeInstance {
    pub active: Vec<ActiveSeq>,
    pub waiting: VecDeque<ActiveSeq>,
    /// Monotonic step counter; stale DecodeStep events are dropped.
    pub step_seq: u64,
    /// Whether a step event is currently in flight.
    pub stepping: bool,
    /// VRAM KVCache capacity (tokens) and batch cap.
    pub kv_capacity_tokens: u64,
    pub max_batch: usize,
    /// Tokens decoded by this instance (throughput accounting).
    pub tokens_out: u64,
    /// Cached sum of active sequences' ctx (kept incrementally — the
    /// per-step O(batch) re-sum dominated the simulator hot path).
    kv_cached: u64,
    /// Busy time accumulated (for utilization / load curves).
    pub busy_ms: f64,
}

impl DecodeInstance {
    pub fn new(kv_capacity_tokens: u64, max_batch: usize) -> Self {
        DecodeInstance {
            active: Vec::new(),
            waiting: VecDeque::new(),
            step_seq: 0,
            stepping: false,
            kv_capacity_tokens,
            max_batch,
            tokens_out: 0,
            kv_cached: 0,
            busy_ms: 0.0,
        }
    }

    pub fn kv_tokens(&self) -> u64 {
        debug_assert_eq!(self.kv_cached, self.active.iter().map(|s| s.ctx).sum::<u64>());
        self.kv_cached
    }

    pub fn batch_size(&self) -> usize {
        self.active.len()
    }

    /// Predicted iteration time if one more sequence of `ctx` tokens
    /// joined now — Conductor's `SelectDecodingInstance` estimate.
    pub fn predicted_step_ms(&self, perf: &PerfModel, extra_ctx: u64) -> f64 {
        perf.decode_step_ms(self.batch_size() as u64 + 1, self.kv_tokens() + extra_ctx)
    }

    /// Whether a sequence with `ctx` context and `out` output tokens can
    /// ever fit (VRAM for ctx+out plus what's already resident).
    pub fn can_fit(&self, ctx: u64, out: u64) -> bool {
        self.kv_tokens() + ctx + out <= self.kv_capacity_tokens
            && self.active.len() + self.waiting.len() < self.max_batch
    }

    /// Enqueue an arrived KVCache; it joins at the next step boundary.
    pub fn enqueue(&mut self, rid: RequestId, ctx: u64, remaining: u64, now: TimeMs) {
        self.waiting.push_back(ActiveSeq {
            rid,
            ctx,
            remaining: remaining.max(1),
            joined: now,
            gaps: Vec::new(),
            last_token: now,
        });
    }

    /// Pull waiting sequences into the batch (capacity permitting).
    pub fn admit_waiting(&mut self) {
        while let Some(seq) = self.waiting.front() {
            let fits = self.kv_tokens() + seq.ctx + seq.remaining
                <= self.kv_capacity_tokens
                && self.active.len() < self.max_batch;
            if !fits {
                break;
            }
            let seq = self.waiting.pop_front().unwrap();
            self.kv_cached += seq.ctx;
            self.active.push(seq);
        }
    }

    /// Duration of the iteration that starts now.
    pub fn step_duration_ms(&self, perf: &PerfModel) -> f64 {
        perf.decode_step_ms(self.batch_size() as u64, self.kv_tokens())
    }

    /// Complete one iteration ending at `now` with duration `dur`:
    /// every active sequence emits a token; finished ones are returned.
    pub fn finish_step(&mut self, now: TimeMs, dur: f64) -> Vec<FinishedSeq> {
        self.busy_ms += dur;
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.active.len());
        for mut seq in self.active.drain(..) {
            seq.gaps.push(now - seq.last_token);
            seq.last_token = now;
            seq.ctx += 1;
            self.kv_cached += 1;
            seq.remaining -= 1;
            self.tokens_out += 1;
            if seq.remaining == 0 {
                self.kv_cached -= seq.ctx;
                let max_gap = seq.gaps.iter().cloned().fold(0.0, f64::max);
                let mean_gap = seq.gaps.iter().sum::<f64>() / seq.gaps.len().max(1) as f64;
                done.push(FinishedSeq {
                    rid: seq.rid,
                    finish: now,
                    max_gap,
                    mean_gap,
                    generated: seq.gaps.len() as u64,
                });
            } else {
                keep.push(seq);
            }
        }
        self.active = keep;
        done
    }

    /// Instantaneous load: predicted TBT against the SLO, VRAM occupancy,
    /// and admission backlog, whichever is tighter (§7.1's SLO-based
    /// load).  Sequences stuck in `waiting` mean the instance is already
    /// over-committed, so they push the load past 1.
    pub fn load(&self, perf: &PerfModel, tbt_slo: f64) -> f64 {
        if self.active.is_empty() && self.waiting.is_empty() {
            return 0.0;
        }
        let tbt_ratio = self.step_duration_ms(perf) / tbt_slo;
        let vram_ratio = self.kv_tokens() as f64 / self.kv_capacity_tokens as f64;
        let backlog = self.waiting.len() as f64 / self.max_batch.max(1) as f64;
        tbt_ratio.max(vram_ratio) + backlog
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> DecodeInstance {
        DecodeInstance::new(1_000_000, 64)
    }

    fn perf() -> PerfModel {
        PerfModel::paper()
    }

    #[test]
    fn join_and_finish() {
        let mut d = inst();
        d.enqueue(1, 100, 2, 0.0);
        d.admit_waiting();
        assert_eq!(d.batch_size(), 1);
        let done = d.finish_step(10.0, 10.0);
        assert!(done.is_empty());
        let done = d.finish_step(20.0, 10.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].generated, 2);
        assert_eq!(done[0].finish, 20.0);
        assert_eq!(d.batch_size(), 0);
        assert_eq!(d.tokens_out, 2);
    }

    #[test]
    fn gaps_are_step_intervals() {
        let mut d = inst();
        d.enqueue(1, 100, 3, 5.0);
        d.admit_waiting();
        d.finish_step(15.0, 10.0);
        d.finish_step(40.0, 25.0);
        let done = d.finish_step(50.0, 10.0);
        assert_eq!(done[0].max_gap, 25.0);
        assert!((done[0].mean_gap - (10.0 + 25.0 + 10.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn vram_capacity_blocks_admission() {
        let mut d = DecodeInstance::new(1_000, 64);
        d.enqueue(1, 800, 10, 0.0);
        d.enqueue(2, 500, 10, 0.0);
        d.admit_waiting();
        assert_eq!(d.batch_size(), 1); // second doesn't fit (800+10+500+10 > 1000)
        assert_eq!(d.waiting.len(), 1);
        // After the first finishes, the second fits.
        for t in 0..10 {
            d.finish_step((t + 1) as f64, 1.0);
        }
        assert_eq!(d.batch_size(), 0);
        d.admit_waiting();
        assert_eq!(d.batch_size(), 1);
    }

    #[test]
    fn batch_cap_respected() {
        let mut d = DecodeInstance::new(u64::MAX, 2);
        for rid in 0..4 {
            d.enqueue(rid, 10, 5, 0.0);
        }
        d.admit_waiting();
        assert_eq!(d.batch_size(), 2);
        assert_eq!(d.waiting.len(), 2);
    }

    #[test]
    fn load_zero_when_idle_positive_when_busy() {
        let p = perf();
        let mut d = inst();
        assert_eq!(d.load(&p, 100.0), 0.0);
        d.enqueue(1, 4_000, 100, 0.0);
        d.admit_waiting();
        assert!(d.load(&p, 100.0) > 0.0);
    }

    #[test]
    fn predicted_step_grows_with_extra_context() {
        let p = perf();
        let mut d = inst();
        d.enqueue(1, 4_000, 100, 0.0);
        d.admit_waiting();
        let small = d.predicted_step_ms(&p, 1_000);
        let big = d.predicted_step_ms(&p, 100_000);
        assert!(big > small);
    }
}
