//! The unified cost model — the **single source of timing truth** for
//! prefill scheduling.
//!
//! Algorithm 1 (§6) only works if Conductor's TTFT *estimates* agree with
//! what the cluster actually *does*: SLO-gated admission and early
//! rejection (§7) both compare an estimate against a limit, so any drift
//! between the estimator and the executor silently re-tunes every
//! threshold.  Historically the two were separate code paths
//! (`conductor::est_ttft` summed queue+transfer+compute analytically
//! while `PrefillPool::run_prefill` re-derived start/end with different
//! rules — e.g. the estimate charged the remote-prefix fetch to the
//! *destination* NIC and added fetch and queue serially, where execution
//! used the *source* NIC and overlapped the fetch with queue drain).
//!
//! Now both sides call this module, and **every device term is a queue
//! probe, not a closed form**: NIC-tx, NIC-rx, and NVMe time all flows
//! through [`crate::resource::BwQueue`] banks, so estimates stay honest
//! even under concurrent stagings and incast:
//!
//! * [`estimate_prefill`] — Conductor's `EstimatePrefillExecutionTime` +
//!   `EstimateKVCacheTransferTime` + queue probes (prefill FIFO, source
//!   tx, destination rx, both ends' NVMe), returning an absolute planned
//!   (start, end) window;
//! * [`crate::prefill::PrefillPool::submit`] — the executor admits a job
//!   using the *same* function of the *same* state, so the simulator's
//!   `PrefillStart`/`PrefillDone` events land exactly where the estimate
//!   said they would (a property `rust/tests/cost_model_agreement.rs`
//!   asserts end-to-end).
//!
//! SSD staging is a **gate**, like the remote fetch: the NVMe read is
//! reserved on the node's queue at admission and the job may not start
//! before it lands (it overlaps queue drain and any fetch — independent
//! devices), which is also what makes concurrent stagings contend.

use crate::config::SimConfig;
use crate::model::PerfModel;
use crate::prefill::PrefillPool;
use crate::resource::{BwQueue, Op, Resources};
use crate::trace::BLOCK_TOKENS;
use crate::TimeMs;

/// Fraction of the local DRAM→VRAM prefix load that stays on the critical
/// path: loading reused KVCache overlaps layer-wise with computation
/// (§5.2), but it bounds when the first layer can start, so a small
/// non-overlapped head remains visible.
pub const PREFIX_LOAD_VISIBLE_FRACTION: f64 = 0.1;

/// Visible (non-overlapped) latency of loading `prefix_tokens` of reused
/// KVCache from local CPU DRAM before prefill can run.
pub fn prefix_load_ms(perf: &PerfModel, prefix_tokens: u64) -> f64 {
    perf.dram_load_ms(prefix_tokens) * PREFIX_LOAD_VISIBLE_FRACTION
}

/// Wire bytes of `tokens` of KVCache (an NVMe staging read or write
/// moves the same bytes the wire would).
pub fn stage_bytes(perf: &PerfModel, tokens: u64) -> u64 {
    tokens * perf.model.kv_bytes_per_token()
}

/// Per-op setup of an NVMe staging read spanning `tokens`: the
/// random-access IOPS term, one seek per cache block.
pub fn stage_setup_ms(perf: &PerfModel, tokens: u64) -> f64 {
    tokens.div_ceil(BLOCK_TOKENS) as f64 / perf.hw.ssd_iops * 1e3
}

/// Absolute landing time of an SSD→DRAM staging read of `tokens` on
/// `node`, **through the node's NVMe queue** — concurrent stagings (and
/// demotion writes) on the same device serialize.  Read-only;
/// [`schedule_stage`] is the matching reservation and returns the same
/// time bit-for-bit.
pub fn estimate_stage_done(
    perf: &PerfModel,
    nvme: &BwQueue,
    node: usize,
    now: TimeMs,
    tokens: u64,
) -> TimeMs {
    if tokens == 0 {
        return now;
    }
    nvme.estimate_done(node, now, stage_bytes(perf, tokens), stage_setup_ms(perf, tokens))
}

/// Reserve the staging read [`estimate_stage_done`] priced.
pub fn schedule_stage(
    perf: &PerfModel,
    nvme: &mut BwQueue,
    node: usize,
    now: TimeMs,
    tokens: u64,
) -> Op {
    nvme.schedule(node, now, stage_bytes(perf, tokens), stage_setup_ms(perf, tokens))
}

/// Execution makespan of one prefill job on a CPP group of `group_len`
/// nodes: chunked-pipeline compute plus the visible prefix-load head.
/// SSD staging is *not* part of the makespan — it is a gate reserved on
/// the node's NVMe queue, overlapping queue drain.  This is the ONE
/// definition of "how long a running prefill takes" — both the
/// estimator and the executor use it.
pub fn prefill_exec_ms(
    perf: &PerfModel,
    cfg: &SimConfig,
    n_new: u64,
    prefix_tokens: u64,
    group_len: u64,
) -> f64 {
    perf.cpp_prefill_ms(n_new, prefix_tokens, cfg.prefill_chunk, group_len)
        + prefix_load_ms(perf, prefix_tokens)
}

/// Wire bytes of a remote prefix fetch of `blocks` cache blocks (§6.2).
pub fn fetch_bytes(perf: &PerfModel, blocks: usize) -> u64 {
    blocks as u64 * BLOCK_TOKENS * perf.model.kv_bytes_per_token()
}

/// A remote §6.2 prefix fetch: `blocks` cache blocks pulled from `src`,
/// of which `src_ssd_blocks` live on the **source's SSD tier** and must
/// be staged into its DRAM before the NIC can serialize them — so the
/// fetch pays the source's NVMe queue *and then* the wire (source tx,
/// destination rx).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FetchPlan {
    pub src: usize,
    pub blocks: usize,
    pub src_ssd_blocks: usize,
}

/// Wire bytes of the layer-wise KVCache stream to the decode node (§5.2).
pub fn kv_stream_bytes(perf: &PerfModel, input_tokens: u64) -> u64 {
    input_tokens * perf.model.kv_bytes_per_token()
}

/// A placement's predicted timing, in absolute simulator time.  Plain
/// `Copy` data — the CPP group is the *caller's* (reused) buffer, so the
/// scheduler's candidate loop prices dozens of estimates per decision
/// without a heap allocation per probe.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefillEstimate {
    /// Planned start: the job runs when its whole group has drained AND
    /// any remote prefix fetch has landed AND any local SSD staging has
    /// landed (the three overlap — they are `max`ed, not summed).
    pub start: TimeMs,
    /// Planned completion (start + exec) — the TTFT moment.
    pub end: TimeMs,
    /// Wait behind the group's committed FIFO work, ms from now.
    pub queue_wait_ms: f64,
    /// Remote-prefix fetch landing delay, ms from now: the source's NVMe
    /// queue (SSD-held blocks), then its tx queue, then the
    /// destination's rx queue.
    pub fetch_wait_ms: f64,
    /// Local SSD→DRAM staging landing delay, ms from now, through the
    /// primary's NVMe queue.
    pub stage_wait_ms: f64,
    /// Execution makespan from [`prefill_exec_ms`].
    pub exec_ms: f64,
}

impl PrefillEstimate {
    /// Estimated TTFT relative to `now` (what Algorithm 1 line 25 gates).
    pub fn ttft_ms(&self, now: TimeMs) -> f64 {
        self.end - now
    }
}

/// Estimate a prefill on the CPP `group` (primary first — the caller
/// forms it with [`PrefillPool::cpp_group_into`] over the same state)
/// with `n_new` uncached tokens and `prefix_tokens` reused ones, of
/// which `ssd_prefix_tokens` must first be staged up through the node's
/// NVMe queue; `fetch` adds a remote prefix fetch that must land first —
/// charged to the source's NVMe queue (staging), its tx queue, and the
/// destination's rx queue.  Read-only and allocation-free: probes the
/// prefill queues and every resource bank without mutating any of them.
#[allow(clippy::too_many_arguments)]
#[must_use = "a discarded estimate means the probe's cost never reached the decision"]
// lint: hot
pub fn estimate_prefill(
    perf: &PerfModel,
    cfg: &SimConfig,
    pool: &PrefillPool,
    res: &Resources,
    group: &[usize],
    n_new: u64,
    prefix_tokens: u64,
    ssd_prefix_tokens: u64,
    fetch: Option<FetchPlan>,
    now: TimeMs,
) -> PrefillEstimate {
    debug_assert!(ssd_prefix_tokens <= prefix_tokens);
    debug_assert!(!group.is_empty());
    let primary = group[0];
    // Heterogeneity-aware: the pool divides by the group's min speed —
    // the same function `submit_with_floor` fixes the makespan with.
    let exec_ms = pool.exec_ms_for(perf, cfg, group, n_new, prefix_tokens);
    let queue_free = pool.group_free_at(group).max(now);
    let stage_done = estimate_stage_done(perf, &res.nvme, primary, now, ssd_prefix_tokens);
    let fetch_done = match fetch {
        Some(f) if f.blocks > 0 => {
            let wire_from = estimate_stage_done(
                perf,
                &res.nvme,
                f.src,
                now,
                f.src_ssd_blocks as u64 * BLOCK_TOKENS,
            );
            res.nic.estimate_done(f.src, primary, wire_from, fetch_bytes(perf, f.blocks))
        }
        _ => now,
    };
    let start = queue_free.max(stage_done).max(fetch_done);
    PrefillEstimate {
        start,
        end: start + exec_ms,
        queue_wait_ms: queue_free - now,
        fetch_wait_ms: fetch_done - now,
        stage_wait_ms: stage_done - now,
        exec_ms,
    }
}

/// Estimate the **hybrid load+recompute** plan — Algorithm 1's fourth
/// branch (`cfg.hybrid`): the head of the matched prefix
/// (`ssd_prefix_tokens` of `prefix_tokens`) streams up from the primary's
/// SSD tier *while* the GPU recomputes everything past `prefix_tokens`.
/// Unlike [`estimate_prefill`], the staging read is not a start gate but
/// a completion floor: compute starts as soon as the group drains and the
/// job finishes at `max(compute, load)` instead of `load + compute` —
/// the overlap the plan exists to buy.  Local-only by construction (the
/// balancing branch prices remote fetches separately), read-only and
/// allocation-free like [`estimate_prefill`]; with
/// `ssd_prefix_tokens == 0` it returns the DRAM-only estimate
/// bit-for-bit.
#[allow(clippy::too_many_arguments)]
#[must_use = "a discarded estimate means the probe's cost never reached the decision"]
// lint: hot
pub fn estimate_prefill_hybrid(
    perf: &PerfModel,
    cfg: &SimConfig,
    pool: &PrefillPool,
    res: &Resources,
    group: &[usize],
    n_new: u64,
    prefix_tokens: u64,
    ssd_prefix_tokens: u64,
    now: TimeMs,
) -> PrefillEstimate {
    debug_assert!(ssd_prefix_tokens <= prefix_tokens);
    debug_assert!(!group.is_empty());
    let primary = group[0];
    let exec_ms = pool.exec_ms_for(perf, cfg, group, n_new, prefix_tokens);
    let queue_free = pool.group_free_at(group).max(now);
    let stage_done = estimate_stage_done(perf, &res.nvme, primary, now, ssd_prefix_tokens);
    let start = queue_free;
    // The staging overhang (if any) folds into the job's effective
    // makespan — the executor applies the same floor via
    // `PrefillPool::submit_with_floor`, keeping estimate == actual.
    let exec_eff = exec_ms.max(stage_done - start);
    PrefillEstimate {
        start,
        end: start + exec_eff,
        queue_wait_ms: queue_free - now,
        fetch_wait_ms: 0.0,
        stage_wait_ms: stage_done - now,
        exec_ms: exec_eff,
    }
}

/// Scan the hybrid split frontier of one matched prefix and return the
/// cheapest split, if any.
///
/// The match spans `match_blocks` cache blocks of which those at
/// `ssd_positions` (ascending chain indices) sit on the SSD tier;
/// everything before `ssd_positions[0]` is DRAM-resident.  Splitting
/// "after the j-th SSD block" stages the first `j` SSD blocks, reuses
/// the prefix up to the next SSD-resident block (the whole match for
/// `j = npos`), and recomputes the tail.  Those are the only splits
/// worth pricing: between two SSD positions the staged set cannot
/// change, so the reuse boundary snaps to SSD positions.  `price(k, j)`
/// returns the estimate for reusing `k` blocks of which `j` are staged;
/// `j = 0` (pure DRAM reuse) is NOT scanned — the caller already prices
/// it as the dram-only plan.  Returns `(k, j, estimate)` of the strict
/// argmin over `end` (smallest `j` on ties), or `None` when the match
/// has no SSD blocks.
// lint: hot
pub fn hybrid_split_scan(
    match_blocks: usize,
    ssd_positions: &[u32],
    mut price: impl FnMut(usize, usize) -> PrefillEstimate,
) -> Option<(usize, usize, PrefillEstimate)> {
    let npos = ssd_positions.len();
    let mut best: Option<(usize, usize, PrefillEstimate)> = None;
    for j in 1..=npos {
        let k = if j < npos { ssd_positions[j] as usize } else { match_blocks };
        let est = price(k, j);
        let better = match best {
            None => true,
            Some((_, _, b)) => est.end < b.end,
        };
        if better {
            best = Some((k, j, est));
        }
    }
    best
}

/// When the streamed KVCache lands at the decode node: the layer-wise
/// stream starts with the prefill and can finish no earlier than the
/// prefill itself, than the wire time on the primary's tx queue, nor
/// than the decode node's rx queue.
pub fn estimate_kv_arrival(
    perf: &PerfModel,
    res: &Resources,
    primary: usize,
    decode_node: usize,
    start: TimeMs,
    end: TimeMs,
    input_tokens: u64,
) -> TimeMs {
    let stream_end =
        res.nic.estimate_done(primary, decode_node, start, kv_stream_bytes(perf, input_tokens));
    stream_end.max(end)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn env() -> (SimConfig, PerfModel, PrefillPool, Resources) {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let res = Resources::new(&cfg, &perf);
        (cfg, perf, pool, res)
    }

    /// Old-signature shim: form the CPP group the way the scheduler does,
    /// then estimate on it.
    #[allow(clippy::too_many_arguments)]
    fn est(
        perf: &PerfModel,
        cfg: &SimConfig,
        pool: &PrefillPool,
        res: &Resources,
        primary: usize,
        n_new: u64,
        prefix_tokens: u64,
        ssd_prefix_tokens: u64,
        fetch: Option<FetchPlan>,
        now: TimeMs,
    ) -> PrefillEstimate {
        let group = pool.cpp_group(cfg, primary, n_new, now);
        estimate_prefill(
            perf,
            cfg,
            pool,
            res,
            &group,
            n_new,
            prefix_tokens,
            ssd_prefix_tokens,
            fetch,
            now,
        )
    }

    #[test]
    fn exec_includes_visible_prefix_load() {
        let (cfg, perf, _, _) = env();
        let cold = prefill_exec_ms(&perf, &cfg, 8_000, 0, 1);
        assert_eq!(cold, perf.prefill_ms(8_000, 0));
        // Fully cached input still pays the non-overlapped load head.
        let warm = prefill_exec_ms(&perf, &cfg, 0, 8_000, 1);
        assert!(warm > 0.0 && warm < cold * 0.05, "warm={warm} cold={cold}");
        assert!((warm - prefix_load_ms(&perf, 8_000)).abs() < 1e-9);
    }

    #[test]
    fn ssd_staging_gates_the_start_and_crossover_holds() {
        let (cfg, perf, pool, res) = env();
        // An SSD-resident prefix delays the planned start by exactly the
        // NVMe queue probe (idle queue here), on top of the DRAM head.
        let dram_warm = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 0, None, 0.0);
        let ssd_warm = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        let stage = estimate_stage_done(&perf, &res.nvme, 0, 0.0, 8_000);
        assert!(stage > 10.0 * dram_warm.end, "{stage} vs {}", dram_warm.end);
        assert!((ssd_warm.stage_wait_ms - stage).abs() < 1e-9);
        assert!((ssd_warm.end - dram_warm.exec_ms - stage).abs() < 1e-9);
        // The load-vs-recompute crossover both ways, through the ONE
        // timing API the scheduler and executor share (single node, so
        // CPP grouping doesn't shrink the recompute side):
        // deep prefix — loading from SSD beats recomputing it...
        let deep = 32_768u64;
        let load_deep = estimate_stage_done(&perf, &res.nvme, 0, 0.0, deep)
            + prefill_exec_ms(&perf, &cfg, 0, deep, 1);
        let recompute_deep = prefill_exec_ms(&perf, &cfg, deep, 0, 1);
        assert!(load_deep < recompute_deep, "{load_deep} !< {recompute_deep}");
        // ...shallow prefix — recomputing beats the NVMe read.
        let shallow = 512u64;
        let load_shallow = estimate_stage_done(&perf, &res.nvme, 0, 0.0, shallow)
            + prefill_exec_ms(&perf, &cfg, 0, shallow, 1);
        let recompute_shallow = prefill_exec_ms(&perf, &cfg, shallow, 0, 1);
        assert!(recompute_shallow < load_shallow, "{recompute_shallow} !< {load_shallow}");
    }

    #[test]
    fn staging_overlaps_queue_wait() {
        // The gate semantics: the NVMe read proceeds while the job waits
        // in the FIFO — start = max(queue, stage), not their sum.
        let (cfg, perf, mut pool, res) = env();
        pool.instances[0].block_until(100_000.0);
        let est = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        assert!(est.queue_wait_ms >= 100_000.0);
        assert!(est.stage_wait_ms > 100.0 && est.stage_wait_ms < 100_000.0);
        assert!((est.start - 100_000.0).abs() < 1e-6, "start={}", est.start);
    }

    #[test]
    fn concurrent_stagings_contend_on_the_nvme_queue() {
        let (cfg, perf, pool, mut res) = env();
        // Reserve one staging on node 0's NVMe; a second estimate on the
        // same node queues behind it, a different node does not.
        let first = schedule_stage(&perf, &mut res.nvme, 0, 0.0, 8_000);
        let queued = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        let fresh = est(&perf, &cfg, &pool, &res, 1, 0, 8_000, 8_000, None, 0.0);
        assert!(
            (queued.stage_wait_ms - fresh.stage_wait_ms - (first.end - first.start)).abs() < 1e-6,
            "second staging must wait out the first: {} vs {}",
            queued.stage_wait_ms,
            fresh.stage_wait_ms
        );
        assert!((queued.end - fresh.end - (first.end - first.start)).abs() < 1e-6);
    }

    #[test]
    fn fetch_charged_to_source_nic() {
        let (cfg, perf, pool, mut res) = env();
        // Congest node 2's outgoing NIC; node 5 stays idle.
        res.nic.schedule(2, 0, 0.0, 2_000_000_000_000); // ~20 s backlog
        let dram_fetch = |src| Some(FetchPlan { src, blocks: 4, src_ssd_blocks: 0 });
        let idle =
            est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, dram_fetch(5), 0.0);
        let congested =
            est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, dram_fetch(2), 0.0);
        assert!(
            congested.fetch_wait_ms > idle.fetch_wait_ms + 10_000.0,
            "source congestion must surface: {} vs {}",
            congested.fetch_wait_ms,
            idle.fetch_wait_ms
        );
        assert!(congested.end > idle.end + 10_000.0);
    }

    #[test]
    fn fetch_charged_to_destination_rx() {
        // Incast: with finite rx bandwidth, a fetch into a destination
        // already receiving another transfer queues on the rx side even
        // though the sources differ.
        let cfg = SimConfig { nic_rx_bw: Some(10e9), ..SimConfig::default() };
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let mut res = Resources::new(&cfg, &perf);
        // Node 5 is already pushing 10 GB into node 0 (~1 s of rx).
        res.nic.schedule(5, 0, 0.0, 10_000_000_000);
        let fetch = Some(FetchPlan { src: 3, blocks: 4, src_ssd_blocks: 0 });
        let onto_hot = est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, fetch, 0.0);
        let onto_cold = est(&perf, &cfg, &pool, &res, 1, 4_096, 2_048, 0, fetch, 0.0);
        assert!(
            onto_hot.fetch_wait_ms > onto_cold.fetch_wait_ms + 500.0,
            "incast onto the hot node must surface: {} vs {}",
            onto_hot.fetch_wait_ms,
            onto_cold.fetch_wait_ms
        );
    }

    #[test]
    fn fetch_overlaps_queue_wait() {
        let (cfg, perf, mut pool, mut res) = env();
        pool.instances[0].block_until(5_000.0);
        res.nic.schedule(3, 1, 0.0, 300_000_000_000); // ~3 s source backlog
        let fetch = Some(FetchPlan { src: 3, blocks: 4, src_ssd_blocks: 0 });
        let est = est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, fetch, 0.0);
        // start = max(queue, fetch), not their sum.
        assert!(est.queue_wait_ms >= 5_000.0);
        assert!(est.fetch_wait_ms > 2_000.0 && est.fetch_wait_ms < 5_000.0);
        assert!((est.start - 5_000.0).abs() < 1e-6, "start={}", est.start);
    }

    #[test]
    fn fetch_charges_source_ssd_staging_before_the_wire() {
        // A source holding the fetched prefix on its SSD tier must stage
        // it into DRAM before the NIC can serialize — the estimate pays
        // the source's NVMe queue *then* the wire, serially.
        let (cfg, perf, pool, res) = env();
        let blocks = 64usize;
        let dram = FetchPlan { src: 3, blocks, src_ssd_blocks: 0 };
        let ssd = FetchPlan { src: 3, blocks, src_ssd_blocks: blocks };
        let a = est(&perf, &cfg, &pool, &res, 0, 4_096, 0, 0, Some(dram), 0.0);
        let b = est(&perf, &cfg, &pool, &res, 0, 4_096, 0, 0, Some(ssd), 0.0);
        let stage = estimate_stage_done(&perf, &res.nvme, 3, 0.0, blocks as u64 * BLOCK_TOKENS);
        assert!(stage > 1_000.0);
        assert!(
            (b.fetch_wait_ms - a.fetch_wait_ms - stage).abs() < 1e-9,
            "SSD-held source must add exactly the staging latency: {} vs {} (+{stage})",
            b.fetch_wait_ms,
            a.fetch_wait_ms
        );
        assert!((b.end - a.end - stage).abs() < 1e-9);
    }

    #[test]
    fn estimate_reads_group_queue_not_just_primary() {
        let (cfg, perf, mut pool, res) = env();
        // Only instance 1 is recruitable (others exceed the 1 ms recruit
        // threshold); its 0.5 ms backlog must drive the planned start.
        pool.instances[1].block_until(0.5);
        for i in 2..pool.len() {
            pool.instances[i].block_until(10.0);
        }
        let group = pool.cpp_group(&cfg, 0, 100_000, 0.0);
        assert_eq!(group, vec![0, 1]);
        let e = estimate_prefill(&perf, &cfg, &pool, &res, &group, 100_000, 0, 0, None, 0.0);
        assert!((e.start - 0.5).abs() < 1e-9, "group max drives start: {}", e.start);
        assert!((e.queue_wait_ms - 0.5).abs() < 1e-9);
    }

    #[test]
    fn hybrid_without_ssd_tokens_is_the_dram_plan_bit_for_bit() {
        // The fourth branch's j = 0 degenerate case must be exactly the
        // dram-only plan — what makes `hybrid: false` a pure pin.
        let (cfg, perf, mut pool, res) = env();
        pool.instances[0].block_until(1_234.5);
        let group = pool.cpp_group(&cfg, 0, 4_096, 0.0);
        let a = estimate_prefill(&perf, &cfg, &pool, &res, &group, 4_096, 2_048, 0, None, 0.0);
        let b = estimate_prefill_hybrid(&perf, &cfg, &pool, &res, &group, 4_096, 2_048, 0, 0.0);
        assert_eq!(a.start.to_bits(), b.start.to_bits());
        assert_eq!(a.end.to_bits(), b.end.to_bits());
        assert_eq!(a.exec_ms.to_bits(), b.exec_ms.to_bits());
        assert_eq!(a.queue_wait_ms.to_bits(), b.queue_wait_ms.to_bits());
        assert_eq!(a.stage_wait_ms.to_bits(), b.stage_wait_ms.to_bits());
        assert_eq!(a.fetch_wait_ms.to_bits(), b.fetch_wait_ms.to_bits());
    }

    #[test]
    fn hybrid_overlap_floors_completion_at_the_staging_read() {
        // Load-dominant: a long NVMe read under a short compute — the
        // plan ends exactly when the read lands, not read + compute.
        let (cfg, perf, pool, res) = env();
        let group = [0usize];
        let h = estimate_prefill_hybrid(&perf, &cfg, &pool, &res, &group, 0, 8_000, 8_000, 0.0);
        let stage = estimate_stage_done(&perf, &res.nvme, 0, 0.0, 8_000);
        let serial = estimate_prefill(&perf, &cfg, &pool, &res, &group, 0, 8_000, 8_000, None, 0.0);
        assert_eq!(h.end.to_bits(), stage.to_bits(), "load-bound: end == stage landing");
        assert!(serial.end > h.end, "the exclusive plan pays load + compute serially");
        assert!((serial.end - h.end - serial.exec_ms).abs() < 1e-9);
        // Compute-dominant: enough new tokens that the GPU outlasts the
        // read — the staging read vanishes from the critical path.
        let c =
            estimate_prefill_hybrid(&perf, &cfg, &pool, &res, &group, 16_384, 8_000, 8_000, 0.0);
        let dram = estimate_prefill(&perf, &cfg, &pool, &res, &group, 16_384, 8_000, 0, None, 0.0);
        assert!(c.exec_ms > stage, "compute must dominate in this regime");
        assert_eq!(c.end.to_bits(), dram.end.to_bits(), "overlap hides the read entirely");
    }

    #[test]
    fn hybrid_split_scan_prices_every_split_and_keeps_the_first_argmin() {
        let mk = |end: f64| PrefillEstimate { end, ..Default::default() };
        // k maps j to the reuse frontier: the next SSD position, or the
        // whole match for the final split.
        let mut seen = Vec::new();
        let got = hybrid_split_scan(10, &[2, 4, 7], |k, j| {
            seen.push((k, j));
            mk(match j {
                1 => 5.0,
                2 => 3.0,
                _ => 3.0, // tie with j = 2 — the earlier split must win
            })
        });
        assert_eq!(seen, vec![(4, 1), (7, 2), (10, 3)]);
        let (k, j, e) = got.unwrap();
        assert_eq!((k, j), (7, 2), "strict argmin keeps the first of equal ends");
        assert_eq!(e.end, 3.0);
        // No SSD blocks -> no splits to price.
        assert!(hybrid_split_scan(10, &[], |_, _| mk(0.0)).is_none());
    }

    #[test]
    fn kv_arrival_no_earlier_than_prefill_end() {
        let (_, perf, _, res) = env();
        let a = estimate_kv_arrival(&perf, &res, 0, 9, 100.0, 5_000.0, 1_000);
        assert!(a >= 5_000.0);
        // Huge stream on a short prefill: the wire dominates.
        let b = estimate_kv_arrival(&perf, &res, 0, 9, 100.0, 200.0, 100_000);
        assert!(b > 200.0 + 100.0);
    }
}
