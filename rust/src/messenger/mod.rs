//! Messenger — the (GPUDirect-)RDMA KVCache transfer engine (§3).
//!
//! Each node runs a Messenger that owns the node's NIC.  Transfers out of
//! a node serialize on that NIC, which is exactly the congestion effect
//! §6.1 worries about ("high demand on the KVCache server can lead to
//! network congestion, prolonging the waiting time") and the reason hot
//! blocks must be replicated (§6.2).
//!
//! The simulator uses [`Messenger::estimate_ms`] for Conductor's
//! `EstimateKVCacheTransferTime` (a *read-only* probe) and
//! [`Messenger::schedule`] to actually enqueue the transfer.

use crate::{TimeMs};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub start: TimeMs,
    pub end: TimeMs,
    pub bytes: u64,
}

#[derive(Debug)]
pub struct Messenger {
    /// Outgoing-link bandwidth per node, B/ms.
    bw_per_ms: f64,
    /// Fixed per-transfer setup latency, ms.
    latency_ms: f64,
    /// Each node's NIC is busy (sending) until this time.
    busy_until: Vec<TimeMs>,
    pub total_bytes: u64,
    pub n_transfers: u64,
    /// Total time transfers spent queued behind earlier ones (congestion).
    pub queued_ms: f64,
}

impl Messenger {
    /// `n_nodes` NICs at `bw_bytes_per_sec` with `latency_ms` setup cost.
    pub fn new(n_nodes: usize, bw_bytes_per_sec: f64, latency_ms: f64) -> Self {
        Messenger {
            bw_per_ms: bw_bytes_per_sec / 1e3,
            latency_ms,
            busy_until: vec![0.0; n_nodes],
            total_bytes: 0,
            n_transfers: 0,
            queued_ms: 0.0,
        }
    }

    fn serialize_ms(&self, bytes: u64) -> f64 {
        self.latency_ms + bytes as f64 / self.bw_per_ms
    }

    /// Estimated completion delay (ms from `now`) if a transfer of
    /// `bytes` from `src` were enqueued now — includes queueing behind
    /// in-flight transfers on the source NIC.  Read-only.
    pub fn estimate_ms(&self, src: usize, now: TimeMs, bytes: u64) -> f64 {
        let start = self.busy_until[src].max(now);
        (start - now) + self.serialize_ms(bytes)
    }

    /// Enqueue a transfer out of `src`; returns its (start, end).
    pub fn schedule(&mut self, src: usize, now: TimeMs, bytes: u64) -> Transfer {
        let start = self.busy_until[src].max(now);
        let end = start + self.serialize_ms(bytes);
        self.queued_ms += start - now;
        self.busy_until[src] = end;
        self.total_bytes += bytes;
        self.n_transfers += 1;
        Transfer { start, end, bytes }
    }

    /// Current outgoing-queue depth of a node in ms (the congestion
    /// signal for replication decisions).
    pub fn backlog_ms(&self, src: usize, now: TimeMs) -> f64 {
        (self.busy_until[src] - now).max(0.0)
    }

    pub fn n_nodes(&self) -> usize {
        self.busy_until.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Messenger {
        // 100 GB/s (800 Gbps), 1 ms latency, 4 nodes.
        Messenger::new(4, 100e9, 1.0)
    }

    #[test]
    fn uncongested_transfer_time() {
        let mut msg = m();
        // 5.24 GB (16k tokens of 70B KVCache) -> ~52.4 ms + 1 ms latency.
        let t = msg.schedule(0, 0.0, 5_242_880_000);
        assert!((t.end - t.start - 53.4).abs() < 0.5, "{t:?}");
        assert_eq!(t.start, 0.0);
    }

    #[test]
    fn same_nic_serializes() {
        let mut msg = m();
        let a = msg.schedule(0, 0.0, 1_000_000_000);
        let b = msg.schedule(0, 0.0, 1_000_000_000);
        assert_eq!(b.start, a.end);
        assert!(msg.queued_ms > 0.0);
        // Different NIC does not queue.
        let c = msg.schedule(1, 0.0, 1_000_000_000);
        assert_eq!(c.start, 0.0);
    }

    #[test]
    fn estimate_matches_schedule() {
        let mut msg = m();
        msg.schedule(2, 0.0, 2_000_000_000);
        let est = msg.estimate_ms(2, 5.0, 1_000_000_000);
        let t = msg.schedule(2, 5.0, 1_000_000_000);
        assert!((est - (t.end - 5.0)).abs() < 1e-9);
    }

    #[test]
    fn backlog_decays_with_time() {
        let mut msg = m();
        msg.schedule(0, 0.0, 10_000_000_000); // 100ms serialize + 1ms
        assert!(msg.backlog_ms(0, 0.0) > 100.0);
        assert!(msg.backlog_ms(0, 50.0) < msg.backlog_ms(0, 0.0));
        assert_eq!(msg.backlog_ms(0, 1_000.0), 0.0);
    }
}
