//! Architecture / hardware constants.
//!
//! The paper's experiments run a *dummy model with the LLaMA2-70B
//! architecture* on nodes of 8×NVIDIA A800-SXM4-80GB with NVLink intra-
//! node and RDMA NICs up to 800 Gbps inter-node (§8.1 Testbed).  These
//! structs capture exactly the quantities the performance model needs.

/// Transformer architecture description (decoder-only, GQA).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: u64,
    pub d_model: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub head_dim: u64,
    pub d_ff: u64,
    pub vocab: u64,
    /// Bytes per weight/activation element (bf16 = 2).
    pub dtype_bytes: u64,
}

impl ModelSpec {
    /// LLaMA2-70B — the paper's dummy model architecture.
    pub fn llama2_70b() -> Self {
        ModelSpec {
            name: "llama2-70b",
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8, // GQA
            head_dim: 128,
            d_ff: 28672,
            vocab: 32000,
            dtype_bytes: 2,
        }
    }

    /// Parameter count (dense decoder, untied embeddings).
    pub fn n_params(&self) -> u64 {
        let attn = self.d_model * (self.n_heads * self.head_dim) * 2 // wq, wo
            + self.d_model * (self.n_kv_heads * self.head_dim) * 2; // wk, wv
        let mlp = 3 * self.d_model * self.d_ff; // gate, up, down
        let per_layer = attn + mlp + 2 * self.d_model; // + norms
        self.n_layers * per_layer + 2 * self.vocab * self.d_model + self.d_model
    }

    pub fn weight_bytes(&self) -> u64 {
        self.n_params() * self.dtype_bytes
    }

    /// KVCache bytes for one token: K and V per layer per kv-head.
    /// LLaMA2-70B: 2 * 80 * 8 * 128 * 2B = 327,680 B/token.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * self.dtype_bytes
    }

    /// Dense-layer FLOPs for one token (matmuls only, fwd): 2 * params.
    pub fn linear_flops_per_token(&self) -> f64 {
        2.0 * self.n_params() as f64
    }

    /// Attention (QK^T + PV) FLOPs for one query token attending over a
    /// context of `ctx` keys: 4 * ctx * n_heads * head_dim.
    pub fn attn_flops_per_token(&self, ctx: f64) -> f64 {
        4.0 * ctx * (self.n_heads * self.head_dim) as f64 * self.n_layers as f64
    }
}

/// One inference node (the deployment unit: a prefill or decode instance).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareSpec {
    pub name: &'static str,
    /// Aggregate dense bf16 throughput of the node, FLOP/s (peak).
    pub flops_peak: f64,
    /// Achievable model FLOPs utilization for prefill (compute-bound).
    pub prefill_mfu: f64,
    /// Aggregate HBM bandwidth of the node, B/s.
    pub hbm_bw: f64,
    /// Fraction of peak HBM bandwidth achievable in decode.
    pub hbm_eff: f64,
    /// Fixed per-iteration overhead in decode (scheduler, kernel
    /// launches, TP sync) — dominant at small batches on real engines.
    pub step_overhead_ms: f64,
    /// VRAM bytes available for KVCache after weights (per node).
    pub vram_kv_bytes: u64,
    /// Inter-node RDMA bandwidth, B/s (paper: up to 800 Gbps).
    pub rdma_bw: f64,
    /// Intra-node CPU DRAM <-> GPU transfer bandwidth, B/s (PCIe4 x16ish).
    pub pcie_bw: f64,
    /// CPU DRAM bytes contributed to the distributed KVCache pool.
    pub dram_pool_bytes: u64,
    /// Sustained local NVMe read bandwidth feeding the SSD cache tier, B/s.
    pub ssd_read_bw: f64,
    /// SSD random-read IOPS budget: each cache-block read pays `1/iops`
    /// seconds of access latency on top of the bandwidth term.
    pub ssd_iops: f64,
    /// SSD bytes contributed to the second (capacity) KVCache tier.
    pub ssd_pool_bytes: u64,
    /// Per-transfer fixed overhead, ms (rendezvous, control plane).
    pub transfer_latency_ms: f64,
}

impl HardwareSpec {
    /// Prefill-speed ratio of this node over `baseline` — what the
    /// heterogeneity layer feeds `NodeOverride::speed` (prefill is
    /// compute-bound, so achieved dense throughput is the right proxy).
    pub fn prefill_speed_ratio(&self, baseline: &HardwareSpec) -> f64 {
        (self.flops_peak * self.prefill_mfu) / (baseline.flops_peak * baseline.prefill_mfu)
    }

    /// 8×A800-SXM4-80GB node as in §8.1.
    pub fn a800_node() -> Self {
        let gpus = 8.0;
        HardwareSpec {
            name: "8xA800",
            flops_peak: gpus * 312e12,      // A100/A800 bf16 dense peak
            prefill_mfu: 0.55,
            hbm_bw: gpus * 2.0e12,          // ~2 TB/s per GPU
            hbm_eff: 0.55,
            step_overhead_ms: 25.0,
            // 8*80 GB - 70B bf16 weights (~140 GB) - activations/overheads.
            vram_kv_bytes: (8 * 80 - 160) as u64 * 1_000_000_000,
            rdma_bw: 100e9,                 // 800 Gbps
            pcie_bw: 64e9,                  // GPUDirect staging
            dram_pool_bytes: 1_000_000_000_000, // 1 TB CPU DRAM pool/node
            ssd_read_bw: 3e9,                   // NVMe sustained read
            ssd_iops: 20_000.0,                 // 0.05 ms per block access
            ssd_pool_bytes: 8_000_000_000_000,  // 8 TB NVMe pool/node
            transfer_latency_ms: 1.0,
        }
    }

    /// 8×H800 node — the newer-generation box a heterogeneous cluster
    /// mixes in (Hopper bf16 dense peak ~990 TFLOP/s per GPU; prefill
    /// MFU a bit lower than Ampere's at these sequence lengths).  Same
    /// pool/NIC shape as the A800 node: the interesting asymmetry is
    /// compute speed, which `prefill_speed_ratio` turns into a
    /// `NodeOverride::speed` factor (~2.9× over A800).
    pub fn h800_node() -> Self {
        let gpus = 8.0;
        HardwareSpec {
            name: "8xH800",
            flops_peak: gpus * 990e12,
            prefill_mfu: 0.5,
            hbm_bw: gpus * 3.35e12,
            hbm_eff: 0.55,
            step_overhead_ms: 25.0,
            ..HardwareSpec::a800_node()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama70b_params_close_to_70b() {
        let m = ModelSpec::llama2_70b();
        let p = m.n_params() as f64;
        assert!((p / 70e9 - 1.0).abs() < 0.05, "params = {p:.3e}");
    }

    #[test]
    fn kv_bytes_match_paper_math() {
        let m = ModelSpec::llama2_70b();
        assert_eq!(m.kv_bytes_per_token(), 327_680);
    }

    #[test]
    fn h800_speed_ratio_is_sane() {
        let a = HardwareSpec::a800_node();
        let h = HardwareSpec::h800_node();
        let r = h.prefill_speed_ratio(&a);
        assert!(r > 2.0 && r < 4.0, "H800/A800 prefill ratio {r}");
        assert_eq!(a.prefill_speed_ratio(&a), 1.0);
    }

    #[test]
    fn node_kv_capacity_order_of_magnitude() {
        let m = ModelSpec::llama2_70b();
        let h = HardwareSpec::a800_node();
        let tokens = h.vram_kv_bytes / m.kv_bytes_per_token();
        // ~1.5M tokens of KVCache fit on a node — enough for big batches.
        assert!(tokens > 1_000_000 && tokens < 3_000_000, "{tokens}");
    }
}
