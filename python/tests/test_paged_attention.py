"""L1 paged_attention kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import paged_attention, decode_attention
from compile.kernels.ref import paged_attention_ref


def _mk(rng, B, NP, PS, MB, nh=4, kvh=2, hd=32):
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NP, PS, kvh, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NP, PS, kvh, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, NP, size=(B, MB)), jnp.int32)
    return q, kp, vp, bt


@pytest.mark.parametrize("B,NP,PS,MB", [(1, 8, 64, 2), (3, 16, 64, 4), (4, 32, 32, 8)])
def test_matches_ref(B, NP, PS, MB):
    rng = np.random.default_rng(0)
    q, kp, vp, bt = _mk(rng, B, NP, PS, MB)
    lens = jnp.asarray(rng.integers(1, MB * PS + 1, size=(B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens)
    want = paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_shared_pages_dedup():
    """Two sequences sharing prefix pages (the Mooncake dedup case) see
    identical attention for identical queries and lengths."""
    rng = np.random.default_rng(1)
    q, kp, vp, _ = _mk(rng, 2, 8, 64, 4)
    q = q.at[1].set(q[0])
    bt = jnp.asarray([[0, 1, 2, 3], [0, 1, 2, 3]], jnp.int32)  # fully shared
    lens = jnp.asarray([200, 200], jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]), rtol=1e-6, atol=1e-6)


def test_agrees_with_contiguous_kernel():
    """Paged layout == contiguous layout when pages are laid out in order."""
    rng = np.random.default_rng(2)
    B, NP, PS, MB = 2, 8, 64, 4
    q, kp, vp, _ = _mk(rng, B, NP, PS, MB)
    bt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    lens = jnp.asarray([130, 256], jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens)
    k = kp.reshape(2, MB * PS, *kp.shape[2:])
    v = vp.reshape(2, MB * PS, *vp.shape[2:])
    want = decode_attention(q, k, v, lens, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 3),
    NP=st.sampled_from([4, 8, 16]),
    PS=st.sampled_from([16, 32, 64]),
    MB=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(B, NP, PS, MB, seed):
    rng = np.random.default_rng(seed)
    q, kp, vp, bt = _mk(rng, B, NP, PS, MB)
    lens = jnp.asarray(rng.integers(1, MB * PS + 1, size=(B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lens)
    want = paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)
