//! Per-instance KVCache pool: the CPU-DRAM-resident paged block store of
//! one prefill/decode node (Fig 3), with capacity-bounded eviction and
//! the prefix matcher Conductor queries during scheduling.

use super::eviction::{EvictionPolicy, PolicyKind};
use crate::{BlockId, TimeMs};

#[derive(Debug)]
pub struct CachePool {
    policy: EvictionPolicy,
    /// Statistics for cache-efficiency reporting.
    pub hits: u64,
    pub misses: u64,
}

impl CachePool {
    pub fn new(kind: PolicyKind, capacity_blocks: Option<usize>) -> Self {
        CachePool { policy: EvictionPolicy::new(kind, capacity_blocks), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.policy.len()
    }

    pub fn is_empty(&self) -> bool {
        self.policy.is_empty()
    }

    pub fn contains(&self, b: BlockId) -> bool {
        self.policy.contains(b)
    }

    /// Algorithm 1's `prefix_len` (in blocks): longest leading run of the
    /// request's hash chain present in this pool.  Read-only (hit
    /// accounting happens on admission, not on probing).
    pub fn prefix_match_blocks(&self, hash_ids: &[BlockId]) -> usize {
        hash_ids.iter().take_while(|&&b| self.policy.contains(b)).count()
    }

    /// Admit a request's block chain after (or during) its prefill: leading
    /// `matched` blocks are touched as hits, the rest inserted as misses.
    /// Returns evicted blocks.
    pub fn admit_chain(&mut self, hash_ids: &[BlockId], now: TimeMs) -> Vec<BlockId> {
        let matched = self.prefix_match_blocks(hash_ids);
        let mut evicted = Vec::new();
        for (i, &b) in hash_ids.iter().enumerate() {
            if i < matched {
                self.hits += 1;
                self.policy.touch(b, now, i);
            } else {
                self.misses += 1;
                if let Some(e) = self.policy.insert(b, now, i) {
                    evicted.push(e);
                }
            }
        }
        evicted
    }

    /// Insert replicated blocks (hot-spot migration §6.2) without hit
    /// accounting.  Returns evicted blocks.
    pub fn insert_replica(&mut self, blocks: &[BlockId], now: TimeMs) -> Vec<BlockId> {
        let mut evicted = Vec::new();
        for (i, &b) in blocks.iter().enumerate() {
            if !self.policy.contains(b) {
                if let Some(e) = self.policy.insert(b, now, i) {
                    evicted.push(e);
                }
            }
        }
        evicted
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn evictions(&self) -> u64 {
        self.policy.evictions
    }

    pub fn iter_blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.policy.iter_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_match_stops_at_gap() {
        let mut p = CachePool::new(PolicyKind::Lru, None);
        p.admit_chain(&[1, 2, 3], 0.0);
        assert_eq!(p.prefix_match_blocks(&[1, 2, 9, 3]), 2);
        assert_eq!(p.prefix_match_blocks(&[9, 1, 2]), 0);
        assert_eq!(p.prefix_match_blocks(&[1, 2, 3, 4]), 3);
    }

    #[test]
    fn admit_counts_hits_and_misses() {
        let mut p = CachePool::new(PolicyKind::Lru, None);
        p.admit_chain(&[1, 2], 0.0);
        assert_eq!((p.hits, p.misses), (0, 2));
        p.admit_chain(&[1, 2, 3], 1.0);
        assert_eq!((p.hits, p.misses), (2, 3));
        assert!((p.hit_rate() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn eviction_under_capacity_pressure() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(4));
        p.admit_chain(&[1, 2, 3, 4], 0.0);
        let evicted = p.admit_chain(&[5, 6], 1.0);
        assert_eq!(evicted, vec![1, 2]); // LRU order
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn replica_insert_no_hit_accounting() {
        let mut p = CachePool::new(PolicyKind::Lru, None);
        p.insert_replica(&[7, 8], 0.0);
        assert_eq!((p.hits, p.misses), (0, 0));
        assert_eq!(p.prefix_match_blocks(&[7, 8]), 2);
    }

    #[test]
    fn replica_does_not_duplicate() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(3));
        p.admit_chain(&[1, 2], 0.0);
        p.insert_replica(&[1, 2, 3], 1.0);
        assert_eq!(p.len(), 3);
    }
}
