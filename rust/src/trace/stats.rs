//! Trace analyzers: the quantities behind Fig 5 (length distributions),
//! Fig 6 (block-hit CDF) and Table 1 (cache-policy hit rates).

use std::collections::HashMap;

use super::TraceRecord;
use crate::kvcache::eviction::{EvictionPolicy, PolicyKind};
use crate::kvcache::{BlockInterner, CachePool, TierCounters};
use crate::util::stats::Histogram;
use crate::BlockId;

#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub n_requests: usize,
    pub mean_input: f64,
    pub mean_output: f64,
    pub total_blocks: u64,
    pub unique_blocks: u64,
    pub duration_ms: u64,
}

pub fn summarize(trace: &[TraceRecord]) -> TraceSummary {
    let n = trace.len();
    let mut unique = std::collections::HashSet::new();
    let mut total = 0u64;
    for r in trace {
        total += r.hash_ids.len() as u64;
        unique.extend(r.hash_ids.iter().copied());
    }
    TraceSummary {
        n_requests: n,
        mean_input: trace.iter().map(|r| r.input_length as f64).sum::<f64>() / n.max(1) as f64,
        mean_output: trace.iter().map(|r| r.output_length as f64).sum::<f64>() / n.max(1) as f64,
        total_blocks: total,
        unique_blocks: unique.len() as u64,
        duration_ms: trace.iter().map(|r| r.timestamp).max().unwrap_or(0),
    }
}

/// Fig 5: input/output length histograms (normalized).
pub fn length_histograms(trace: &[TraceRecord], bins: usize) -> (Histogram, Histogram) {
    let max_in = trace.iter().map(|r| r.input_length).max().unwrap_or(1) as f64;
    let max_out = trace.iter().map(|r| r.output_length).max().unwrap_or(1) as f64;
    let mut hin = Histogram::new(0.0, max_in, bins);
    let mut hout = Histogram::new(0.0, max_out, bins);
    for r in trace {
        hin.add(r.input_length as f64);
        hout.add(r.output_length as f64);
    }
    (hin, hout)
}

/// Per-block access counts (Fig 6 input).
pub fn block_hit_counts(trace: &[TraceRecord]) -> HashMap<BlockId, u64> {
    let mut counts = HashMap::new();
    for r in trace {
        for &b in &r.hash_ids {
            *counts.entry(b).or_default() += 1;
        }
    }
    counts
}

/// Fig 6: CDF of block hit counts — returns (hit_count, cumulative
/// fraction of blocks with count <= hit_count), log-spaced points.
pub fn block_hit_cdf(trace: &[TraceRecord]) -> Vec<(u64, f64)> {
    let counts = block_hit_counts(trace);
    let mut vals: Vec<u64> = counts.values().copied().collect();
    vals.sort_unstable();
    let n = vals.len().max(1) as f64;
    let mut points = Vec::new();
    let mut threshold = 1u64;
    while threshold <= *vals.last().unwrap_or(&1) {
        let idx = vals.partition_point(|&v| v <= threshold);
        points.push((threshold, idx as f64 / n));
        threshold = (threshold * 2).max(threshold + 1);
    }
    points
}

/// Table 1: replay the trace through a single global cache pool with the
/// given eviction policy and capacity (None = infinite); returns the block
/// hit rate.  Mirrors the paper's "simple cache policy analysis".
pub fn cache_hit_rate(
    trace: &[TraceRecord],
    policy: PolicyKind,
    capacity_blocks: Option<usize>,
) -> f64 {
    // The pool speaks interned dense ids (like the scheduler); the
    // replay interns each trace hash at its own admission boundary.
    // Interning is a bijection, so hit sequences are unchanged.
    let mut interner = BlockInterner::new();
    let mut policy = EvictionPolicy::new(policy, capacity_blocks);
    let mut hits = 0u64;
    let mut total = 0u64;
    for r in trace {
        for (idx, &h) in r.hash_ids.iter().enumerate() {
            let b = interner.intern(h);
            total += 1;
            if policy.contains(b) {
                hits += 1;
                policy.touch(b, r.timestamp as f64, idx);
            } else {
                policy.insert(b, r.timestamp as f64, idx);
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Table 1 tier ablation: replay the trace through a single tiered
/// DRAM+SSD pool.  DRAM evictions demote to the SSD tier and SSD-resident
/// blocks count as hits (promoting on access), so at equal DRAM capacity
/// the tiered pool's hit rate dominates the DRAM-only replay above.
/// With `ssd_capacity_blocks = Some(0)` this degenerates *exactly* to
/// [`cache_hit_rate`] — same victims, same hit sequence.
pub fn tiered_cache_hit_rate(
    trace: &[TraceRecord],
    policy: PolicyKind,
    dram_capacity_blocks: Option<usize>,
    ssd_capacity_blocks: Option<usize>,
) -> (f64, TierCounters) {
    let mut interner = BlockInterner::new();
    let mut pool = CachePool::new(policy, dram_capacity_blocks, ssd_capacity_blocks);
    for r in trace {
        for (idx, &h) in r.hash_ids.iter().enumerate() {
            let b = interner.intern(h);
            let _ = pool.admit_block(b, idx, r.timestamp as f64);
        }
    }
    (pool.hit_rate(), pool.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{generate, TraceGenConfig};

    fn trace() -> Vec<TraceRecord> {
        generate(&TraceGenConfig { n_requests: 3_000, ..Default::default() })
    }

    #[test]
    fn summary_consistency() {
        let t = trace();
        let s = summarize(&t);
        assert_eq!(s.n_requests, 3_000);
        assert!(s.unique_blocks <= s.total_blocks);
        assert!(s.mean_input > 1_000.0);
    }

    #[test]
    fn hit_cdf_monotone_and_bounded() {
        let t = trace();
        let cdf = block_hit_cdf(&t);
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 < w[1].0);
        }
        assert!(cdf.last().unwrap().1 > 0.999);
    }

    #[test]
    fn infinite_cache_beats_finite() {
        let t = trace();
        let inf = cache_hit_rate(&t, PolicyKind::Lru, None);
        let small = cache_hit_rate(&t, PolicyKind::Lru, Some(500));
        assert!(inf > small, "{inf} vs {small}");
        assert!(inf <= 1.0 && small >= 0.0);
    }

    #[test]
    fn tiered_replay_degenerates_to_dram_only_and_beats_it() {
        let t = trace();
        for cap in [1_000usize, 5_000] {
            let dram_only = cache_hit_rate(&t, PolicyKind::Lru, Some(cap));
            let (no_ssd, counters) = tiered_cache_hit_rate(&t, PolicyKind::Lru, Some(cap), Some(0));
            assert!((no_ssd - dram_only).abs() < 1e-12, "{no_ssd} != {dram_only}");
            assert_eq!(counters.ssd_hits, 0);
            assert_eq!(counters.demotions, 0);
            let (tiered, tc) = tiered_cache_hit_rate(&t, PolicyKind::Lru, Some(cap), Some(20_000));
            assert!(
                tiered > dram_only + 0.02,
                "cap {cap}: tiered {tiered} must beat DRAM-only {dram_only}"
            );
            assert!(tc.ssd_hits > 0 && tc.demotions > 0 && tc.promotions > 0);
        }
    }

    #[test]
    fn capacity_monotonicity_lru() {
        // Table 1's rows: hit rate grows with capacity.
        let t = trace();
        let r1k = cache_hit_rate(&t, PolicyKind::Lru, Some(1_000));
        let r10k = cache_hit_rate(&t, PolicyKind::Lru, Some(10_000));
        let r50k = cache_hit_rate(&t, PolicyKind::Lru, Some(50_000));
        assert!(r1k <= r10k + 0.02 && r10k <= r50k + 0.02, "{r1k} {r10k} {r50k}");
    }
}
