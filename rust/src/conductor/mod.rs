//! Conductor — the KVCache-centric global scheduler (§6, Algorithm 1).
//!
//! For every arriving request Conductor must pick a (prefill group,
//! decode instance) pair balancing three objectives: reuse as much
//! KVCache as possible, balance prefill loads, and guarantee the TTFT /
//! TBT SLOs — rejecting (HTTP 429) what cannot meet them.  The §6.2
//! cache-load-balancing extension adds remote prefix fetches and
//! heuristic hot-spot replication.

pub mod migration;

use crate::config::{SchedulingPolicy, SimConfig};
use crate::decode::DecodeInstance;
use crate::messenger::Messenger;
use crate::model::PerfModel;
use crate::prefill::PrefillPool;
use crate::trace::BLOCK_TOKENS;
use crate::util::rng::Rng;
use crate::{BlockId, TimeMs};

/// A request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct SchedRequest {
    pub rid: u64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub hash_ids: Vec<BlockId>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Estimated TTFT exceeds the SLO on every instance (Alg. 1 line 25).
    TtftSlo,
    /// Estimated TBT exceeds the SLO on every decode instance.
    TbtSlo,
    /// Overload admission control (§7) refused the request.
    Overload,
}

/// A successful placement (Algorithm 1's return).
#[derive(Debug, Clone)]
pub struct Placement {
    pub prefill_group: Vec<usize>,
    pub decode: usize,
    /// Prefix blocks served from the primary's local pool.
    pub local_prefix_blocks: usize,
    /// Remote fetch performed before prefill (blocks, source instance).
    pub fetch: Option<(usize, usize)>,
    /// Prefill starts/ends (group occupied for the span).
    pub prefill_start: TimeMs,
    pub prefill_end: TimeMs,
    /// When the streamed KVCache lands at the decode node (§5.2 overlap).
    pub kv_arrive: TimeMs,
    pub est_tbt: f64,
}

/// Scratch the scheduler needs each call (everything lives in the Sim).
pub struct Ctx<'a> {
    pub cfg: &'a SimConfig,
    pub perf: &'a PerfModel,
    pub prefill: &'a mut PrefillPool,
    pub decodes: &'a [DecodeInstance],
    pub messenger: &'a mut Messenger,
    pub rng: &'a mut Rng,
    pub now: TimeMs,
}

/// Counters for Fig 8-style scheduling studies.
#[derive(Debug, Default, Clone)]
pub struct ConductorStats {
    pub scheduled: u64,
    pub rejected_ttft: u64,
    pub rejected_tbt: u64,
    pub remote_fetches: u64,
    pub migrations: u64,
    pub reused_blocks: u64,
    pub recomputed_blocks: u64,
}

/// Algorithm 1 (lines 1–23): choose the prefill instance.
///
/// Returns (instance, local_prefix_blocks, effective_prefix_blocks,
/// fetch source, estimated ttft) — `effective` includes a remote fetch
/// if the balancing branch chose one.
fn select_prefill(
    ctx: &mut Ctx,
    req: &SchedRequest,
) -> (usize, usize, usize, Option<usize>, f64) {
    let pools = &ctx.prefill.instances;
    // FindBestPrefixMatch over every instance's pool.
    let matches: Vec<usize> =
        pools.iter().map(|p| p.pool.prefix_match_blocks(&req.hash_ids)).collect();
    let (best_inst, best_blocks) = matches
        .iter()
        .enumerate()
        .max_by_key(|(_, &m)| m)
        .map(|(i, &m)| (i, m))
        .unwrap_or((0, 0));

    match ctx.cfg.scheduling {
        SchedulingPolicy::Random => {
            let i = ctx.rng.below(pools.len() as u64) as usize;
            let prefix = matches[i];
            let t = est_ttft(ctx, req, i, prefix, 0);
            (i, prefix, prefix, None, t)
        }
        SchedulingPolicy::LoadBalance => {
            let i = (0..pools.len())
                .min_by(|&a, &b| {
                    pools[a]
                        .queue_ms(ctx.now)
                        .partial_cmp(&pools[b].queue_ms(ctx.now))
                        .unwrap()
                })
                .unwrap();
            let prefix = matches[i];
            let t = est_ttft(ctx, req, i, prefix, 0);
            (i, prefix, prefix, None, t)
        }
        SchedulingPolicy::CacheAware | SchedulingPolicy::KvCacheCentric => {
            let balancing = ctx.cfg.scheduling == SchedulingPolicy::KvCacheCentric;
            let mut best: (usize, usize, usize, Option<usize>, f64) =
                (0, 0, 0, None, f64::INFINITY);
            for i in 0..pools.len() {
                let local = matches[i];
                // Line 8: prefer local compute unless the best remote
                // match dwarfs the local one.
                let ratio = if local == 0 {
                    f64::INFINITY
                } else {
                    best_blocks as f64 / local as f64
                };
                let (prefix, fetch, ttft) = if !balancing
                    || best_inst == i
                    || best_blocks == 0
                    || ratio < ctx.cfg.kvcache_balancing_threshold
                {
                    // Cache-aware branch (lines 9–13).
                    (local, None, est_ttft(ctx, req, i, local, 0))
                } else {
                    // Cache-aware and -balancing branch (lines 15–21).
                    let transfer_blocks = best_blocks - local;
                    let t = est_ttft(ctx, req, i, best_blocks, transfer_blocks);
                    (best_blocks, Some(best_inst), t)
                };
                if ttft < best.4 {
                    best = (i, matches[i], prefix, fetch, ttft);
                }
            }
            best
        }
    }
}

/// TTFT estimate for instance `i` with `prefix` reusable blocks and an
/// optional remote transfer of `fetch_blocks` first.
fn est_ttft(ctx: &Ctx, req: &SchedRequest, i: usize, prefix: usize, fetch_blocks: usize) -> f64 {
    let prefix_tokens = (prefix as u64 * BLOCK_TOKENS).min(req.input_tokens);
    let n_new = req.input_tokens - prefix_tokens;
    let group = ctx.prefill.cpp_group(ctx.cfg, i, n_new, ctx.now);
    let t_prefill =
        ctx.perf
            .cpp_prefill_ms(n_new, prefix_tokens, ctx.cfg.prefill_chunk, group.len() as u64);
    let t_queue = ctx.prefill.instances[i].queue_ms(ctx.now);
    let t_transfer = if fetch_blocks > 0 {
        ctx.messenger.estimate_ms(
            i, // conservative: source NIC congestion dominates; use probe of src below
            ctx.now,
            fetch_blocks as u64 * BLOCK_TOKENS * ctx.perf.model.kv_bytes_per_token(),
        )
    } else {
        0.0
    };
    // Loading the local prefix from DRAM overlaps layer-wise (§5.2) but
    // bounds the start; include the non-overlapped fraction.
    let t_load = ctx.perf.dram_load_ms(prefix_tokens) * 0.1;
    t_transfer + t_queue + t_prefill + t_load
}

/// Algorithm 1 line 24: pick the decode instance with the smallest
/// predicted TBT.  With `gate` set (early-rejection admission), only
/// instances that can hold the request qualify; without it (the §7
/// *baseline*, which defers the decode load check until the KVCache
/// actually arrives) the best instance is chosen unconditionally and
/// over-commitment surfaces at the decode-side double-check instead.
pub fn select_decode(
    perf: &PerfModel,
    decodes: &[DecodeInstance],
    ctx_tokens: u64,
    out_tokens: u64,
    gate: bool,
) -> Option<(usize, f64)> {
    let pick = |require_fit: bool| {
        decodes
            .iter()
            .enumerate()
            .filter(|(_, d)| !require_fit || d.can_fit(ctx_tokens, out_tokens))
            .map(|(i, d)| (i, d.predicted_step_ms(perf, ctx_tokens)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    };
    if gate {
        pick(true)
    } else {
        pick(true).or_else(|| pick(false))
    }
}

/// Full Algorithm 1.  Mutates the prefill pool (queue occupation +
/// optimistic cache admission), the messenger (fetch + KV stream), and
/// the stats.  The *decode* side is only probed here; the Sim owns
/// decode state transitions.
pub fn schedule(
    ctx: &mut Ctx,
    req: &SchedRequest,
    stats: &mut ConductorStats,
) -> Result<Placement, RejectReason> {
    let (p, local_blocks, eff_blocks, fetch_src, est_ttft_ms) = select_prefill(ctx, req);

    // Line 24–27: decode selection and SLO gate.  The decode-side gate at
    // arrival is itself an *early rejection* (§7.2), so it only applies
    // under the early/predictive admission policies; the §7 baseline and
    // the no-rejection mode defer decode-load problems to the decode-side
    // double-check / queueing.
    let gate = matches!(
        ctx.cfg.rejection,
        crate::config::RejectionPolicy::Early | crate::config::RejectionPolicy::Predictive
    );
    let (d, est_tbt) = match select_decode(
        ctx.perf,
        ctx.decodes,
        req.input_tokens,
        req.output_tokens,
        gate,
    ) {
        Some(x) => x,
        None => {
            stats.rejected_tbt += 1;
            return Err(RejectReason::TbtSlo);
        }
    };
    if est_ttft_ms > ctx.cfg.slo.ttft_ms {
        stats.rejected_ttft += 1;
        return Err(RejectReason::TtftSlo);
    }
    if gate && est_tbt > ctx.cfg.slo.tbt_ms {
        stats.rejected_tbt += 1;
        return Err(RejectReason::TbtSlo);
    }

    let prefix_tokens = (eff_blocks as u64 * BLOCK_TOKENS).min(req.input_tokens);
    let n_new = req.input_tokens - prefix_tokens;

    // Remote prefix fetch (balancing branch): the fetch must land before
    // prefill starts; it runs on the *source* node's NIC.
    let mut earliest = ctx.now;
    let mut fetch = None;
    if let Some(src) = fetch_src {
        let blocks = eff_blocks - local_blocks;
        if blocks > 0 {
            let bytes = blocks as u64 * BLOCK_TOKENS * ctx.perf.model.kv_bytes_per_token();
            let tr = ctx.messenger.schedule(src, ctx.now, bytes);
            earliest = tr.end;
            fetch = Some((src, blocks));
            stats.remote_fetches += 1;
            // The fetched prefix is now replicated on p (hot-spot
            // replication as a side effect of forwarding, §6.2).
            let blocks_list: Vec<BlockId> = req.hash_ids[..eff_blocks].to_vec();
            ctx.prefill.instances[p].pool.insert_replica(&blocks_list, ctx.now);
            stats.migrations += 1;
        }
    }

    // Occupy the prefill group.
    let group = ctx.prefill.cpp_group(ctx.cfg, p, n_new, ctx.now);
    let (start, end) =
        ctx.prefill.run_prefill(ctx.perf, ctx.cfg, &group, n_new, prefix_tokens, earliest);

    // Admit the full chain into p's pool (its KVCache now exists there).
    ctx.prefill.instances[p].pool.admit_chain(&req.hash_ids, ctx.now);

    // Layer-wise KV stream to the decode node (§5.2): transfer overlaps
    // prefill; it can finish no earlier than prefill *and* no earlier
    // than the wire time starting at prefill start.
    let kv_bytes = req.input_tokens * ctx.perf.model.kv_bytes_per_token();
    let stream = ctx.messenger.schedule(p, start, kv_bytes);
    let kv_arrive = stream.end.max(end);

    stats.scheduled += 1;
    stats.reused_blocks += eff_blocks as u64;
    stats.recomputed_blocks += (req.hash_ids.len() - eff_blocks) as u64;

    Ok(Placement {
        prefill_group: group,
        decode: d,
        local_prefix_blocks: local_blocks,
        fetch,
        prefill_start: start,
        prefill_end: end,
        kv_arrive,
        est_tbt,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn setup(policy: SchedulingPolicy) -> (SimConfig, PerfModel, PrefillPool, Vec<DecodeInstance>, Messenger, Rng)
    {
        let cfg = SimConfig { scheduling: policy, ..Default::default() };
        let perf = PerfModel::paper();
        let prefill = PrefillPool::new(&cfg);
        let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
            .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
            .collect();
        let messenger = Messenger::new(cfg.n_prefill + cfg.n_decode, perf.hw.rdma_bw, 1.0);
        (cfg, perf, prefill, decodes, messenger, Rng::new(7))
    }

    fn req(rid: u64, blocks: u64) -> SchedRequest {
        SchedRequest {
            rid,
            input_tokens: blocks * BLOCK_TOKENS,
            output_tokens: 100,
            hash_ids: (rid * 1000..rid * 1000 + blocks).collect(),
        }
    }

    #[test]
    fn schedules_and_reuses_prefix() {
        let (cfg, perf, mut prefill, decodes, mut msgr, mut rng) =
            setup(SchedulingPolicy::KvCacheCentric);
        let mut stats = ConductorStats::default();
        let r1 = req(1, 16);
        let mut ctx = Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            messenger: &mut msgr,
            rng: &mut rng,
            now: 0.0,
        };
        let p1 = schedule(&mut ctx, &r1, &mut stats).unwrap();
        assert!(p1.prefill_end > p1.prefill_start);
        assert!(p1.kv_arrive >= p1.prefill_end);

        // Same chain again much later (queue drained): the primary holding
        // the cache must win, and most blocks must be reused.
        let mut ctx = Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            messenger: &mut msgr,
            rng: &mut rng,
            now: 1e7,
        };
        let p2 = schedule(&mut ctx, &r1, &mut stats).unwrap();
        assert_eq!(p2.prefill_group[0], p1.prefill_group[0]);
        assert!(p2.prefill_end - p2.prefill_start < (p1.prefill_end - p1.prefill_start) * 0.3);
        assert!(stats.reused_blocks >= 16);
    }

    #[test]
    fn cache_aware_beats_random_on_warm_chain() {
        // Warm one instance, then compare policies' TTFT estimates.
        for policy in [SchedulingPolicy::CacheAware, SchedulingPolicy::KvCacheCentric] {
            let (cfg, perf, mut prefill, decodes, mut msgr, mut rng) = setup(policy);
            let mut stats = ConductorStats::default();
            let r = req(3, 32);
            let mut ctx = Ctx {
                cfg: &cfg,
                perf: &perf,
                prefill: &mut prefill,
                decodes: &decodes,
                messenger: &mut msgr,
                rng: &mut rng,
                now: 0.0,
            };
            let first = schedule(&mut ctx, &r, &mut stats).unwrap();
            let cold = first.prefill_end - first.prefill_start;
            let mut ctx = Ctx {
                cfg: &cfg,
                perf: &perf,
                prefill: &mut prefill,
                decodes: &decodes,
                messenger: &mut msgr,
                rng: &mut rng,
                now: 1e7,
            };
            let warm_p = schedule(&mut ctx, &r, &mut stats).unwrap();
            let warm = warm_p.prefill_end - warm_p.prefill_start;
            assert!(warm < cold * 0.2, "{policy:?}: warm={warm} cold={cold}");
        }
    }

    #[test]
    fn rejects_when_ttft_unattainable() {
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg.slo.ttft_ms = 1.0; // impossible
        let mut stats = ConductorStats::default();
        let mut ctx = Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            messenger: &mut msgr,
            rng: &mut rng,
            now: 0.0,
        };
        let e = schedule(&mut ctx, &req(9, 64), &mut stats).unwrap_err();
        assert_eq!(e, RejectReason::TtftSlo);
        assert_eq!(stats.rejected_ttft, 1);
    }

    #[test]
    fn balancing_branch_fetches_remote_prefix() {
        let (mut cfg, perf, mut prefill, decodes, mut msgr, mut rng) =
            setup(SchedulingPolicy::KvCacheCentric);
        cfg.kvcache_balancing_threshold = 1.5;
        let mut stats = ConductorStats::default();
        let r = req(5, 64);
        // Warm instance 0 with the chain, then make instance 0 very busy
        // so the scheduler prefers another node + fetch.
        {
            let mut ctx = Ctx {
                cfg: &cfg,
                perf: &perf,
                prefill: &mut prefill,
                decodes: &decodes,
                messenger: &mut msgr,
                rng: &mut rng,
                now: 0.0,
            };
            schedule(&mut ctx, &r, &mut stats).unwrap();
        }
        let holder = prefill
            .instances
            .iter()
            .position(|i| i.pool.prefix_match_blocks(&r.hash_ids) == 64)
            .unwrap();
        prefill.instances[holder].busy_until = 1e9; // swamped
        let mut ctx = Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut prefill,
            decodes: &decodes,
            messenger: &mut msgr,
            rng: &mut rng,
            now: 1e6,
        };
        let p = schedule(&mut ctx, &r, &mut stats).unwrap();
        assert_ne!(p.prefill_group[0], holder);
        assert!(p.fetch.is_some(), "expected remote fetch");
        assert_eq!(stats.remote_fetches, 1);
        // Replica now exists on the new node.
        assert_eq!(
            prefill.instances[p.prefill_group[0]].pool.prefix_match_blocks(&r.hash_ids),
            64
        );
    }
}
