//! Fig 9 / Fig 10 — instance load over time in an overloaded cluster:
//! early rejection causes anti-phase prefill/decode load oscillation
//! (Fig 9, 10a); prediction-based early rejection damps it (10b).

use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{RejectionPolicy, SimConfig};
use mooncake::sim::{self, LoadSample};
use mooncake::trace::gen::{generate, TraceGenConfig};

/// Mean |prefill - decode| anti-phase gap and load variance.
fn fluctuation(samples: &[LoadSample]) -> (f64, f64) {
    let busy: Vec<&LoadSample> =
        samples.iter().filter(|s| s.prefill_load + s.decode_load > 0.05).collect();
    if busy.len() < 4 {
        return (0.0, 0.0);
    }
    let anti: f64 = busy.iter().map(|s| (s.prefill_load - s.decode_load).abs()).sum::<f64>()
        / busy.len() as f64;
    let mean_p: f64 = busy.iter().map(|s| s.prefill_load).sum::<f64>() / busy.len() as f64;
    let var: f64 = busy.iter().map(|s| (s.prefill_load - mean_p).powi(2)).sum::<f64>()
        / busy.len() as f64;
    (anti, var.sqrt())
}

fn main() {
    // Overloaded small cluster (the paper: 20 machines, 2x replay, worse
    // with fewer prefill machines).
    let trace = generate(&TraceGenConfig { n_requests: 6_000, ..Default::default() });
    let mk = |rej| SimConfig {
        n_prefill: 6,
        n_decode: 4,
        // Decode-contended regime (see EXPERIMENTS.md): concurrency per
        // decode instance bounded as in the paper's TBT-constrained engine.
        max_decode_batch: 16,
        rejection: rej,
        ..Default::default()
    };

    banner("Fig 9/10: prefill vs decode load over time (overloaded, 6x replay)");
    let mut stats = Vec::new();
    for (name, rej) in
        [("early-rejection", RejectionPolicy::Early), ("predictive", RejectionPolicy::Predictive)]
    {
        let cfg = mk(rej);
        let res = sim::run(&cfg, &trace, 6.0);
        println!("\n--- {name} ---");
        row(&["t_min".into(), "prefill_load".into(), "decode_load".into()]);
        for s in res.load_samples.iter().step_by(6).take(40) {
            row(&[fmt(s.t / 60_000.0, 1), fmt(s.prefill_load, 2), fmt(s.decode_load, 2)]);
        }
        let (anti, sd) = fluctuation(&res.load_samples);
        println!("anti-phase gap: {anti:.3}, prefill load stddev: {sd:.3}");
        stats.push((name, anti, sd));
    }

    let early = stats[0];
    let pred = stats[1];
    assert!(
        pred.1 <= early.1 * 1.05,
        "prediction must not worsen anti-phase gap: {} vs {}",
        pred.1,
        early.1
    );
    println!(
        "\nfig9/10 check OK: anti-phase gap early={:.3} predictive={:.3}",
        early.1, pred.1
    );

    // Bursty-replay variant: the same cluster under 3 concentrated burst
    // windows (70% of arrival mass).  The event-driven prefill queues make
    // the burst back-pressure directly observable in the load samples.
    banner("Fig 9 variant: bursty arrival replay (3 bursts, 70% of mass)");
    let bursty = generate(&TraceGenConfig {
        n_requests: 6_000,
        burst_fraction: 0.7,
        n_bursts: 3,
        burst_width_ms: 30_000,
        ..Default::default()
    });
    for (name, rej) in
        [("early-rejection", RejectionPolicy::Early), ("predictive", RejectionPolicy::Predictive)]
    {
        let cfg = mk(rej);
        let res = sim::run(&cfg, &bursty, 2.0);
        let (anti, sd) = fluctuation(&res.load_samples);
        let rep = res.report(&cfg);
        println!(
            "{name:16} anti-phase {anti:.3}, prefill stddev {sd:.3}, \
             completed {}, rejected-at-arrival {}",
            rep.n_completed, rep.n_rejected_arrival
        );
    }
}
