"""AOT path: HLO text emission and manifest integrity."""

import json
import os

import numpy as np
import pytest

from compile.config import TINY as cfg
from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_prefill_lowers_to_hlo_text():
    text = aot.lower_prefill(cfg, cfg.prefill_buckets[0])
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Weights are inputs, not constants: the param count must show up.
    nparams = len(cfg.param_specs())
    assert f"parameter({nparams})" in text or f"parameter({nparams + 1})" in text


def test_decode_lowers_to_hlo_text():
    text = aot.lower_decode(cfg, cfg.decode_buckets[0])
    assert text.startswith("HloModule")
    assert "ENTRY" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_config():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["d_model"] == cfg.d_model
    assert man["model"]["n_layers"] == cfg.n_layers
    assert man["prefill_buckets"] == list(cfg.prefill_buckets)
    assert man["decode_buckets"] == list(cfg.decode_buckets)
    for key, fname in man["artifacts"].items():
        assert os.path.exists(os.path.join(ARTIFACTS, fname)), (key, fname)


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "weights.npz")),
                    reason="artifacts not built")
def test_weights_npz_abi():
    """npz member names must sort in param_specs order (the Rust ABI)."""
    with np.load(os.path.join(ARTIFACTS, "weights.npz")) as z:
        names = sorted(z.files)
        specs = cfg.param_specs()
        assert names == [n for n, _ in specs]
        for name, shape in specs:
            assert z[name].shape == tuple(shape), name
            assert z[name].dtype == np.float32
