"""L2: the dummy-model forward passes (prefill / decode-step) in JAX.

LLaMA-architecture decoder (RMSNorm, RoPE, GQA, SwiGLU) over a contiguous
per-slot KVCache, calling the L1 Pallas kernels for attention.  Two entry
points are AOT-lowered per shape bucket (see aot.py):

  prefill_step(params, tokens[S], kv[L,2,C,kvh,hd], start[1], n_valid[1])
      -> (last_logits[V], kv_out)
  decode_step(params, tokens[B], kv[B,L,2,C,kvh,hd], positions[B])
      -> (logits[B,V], kv_out)

Semantics the Rust engine relies on:
  * prefill writes the chunk's K/V at cache positions [start, start+S) and
    returns the logits of query row n_valid-1 (rows >= n_valid are padding;
    their K/V are junk in the cache but are either overwritten by the next
    chunk — which starts at start+n_valid — or masked at decode time by
    `positions`).
  * decode appends one token per slot at cache position `positions[b]` and
    attends over positions < positions[b]+1.  Inactive batch slots simply
    carry junk that the engine ignores.

Weights are *inputs* (not baked constants) so every artifact stays small
and shares one `weights.npz`; see ModelConfig.param_specs for the ABI.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import decode_attention, prefill_attention


# ---------------------------------------------------------------------------
# Building blocks


def rms_norm(x, w, eps: float = 1e-5):
    """LLaMA RMSNorm over the trailing feature axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope(x, positions, base: float):
    """Rotary embedding.  x: [..., T, H, hd]; positions broadcast to [..., T]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # [..., T, 1, half]
    angles = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def unpack_params(cfg: ModelConfig, flat):
    """Flat tuple (param_specs order) -> nested dict."""
    # Names are "p{idx:03d}_{name}"; strip the index prefix.
    d = {n.split("_", 1)[1]: arr for (n, _), arr in zip(cfg.param_specs(), flat)}

    def layer(i):
        prefix = f"l{i}_"
        return {k[len(prefix):]: v for k, v in d.items() if k.startswith(prefix)}

    return {
        "tok_emb": d["tok_emb"],
        "layers": [layer(i) for i in range(cfg.n_layers)],
        "final_norm": d["final_norm"],
        "lm_head": d["lm_head"],
    }


def init_params(cfg: ModelConfig, seed: int = 0):
    """Synthetic dummy-model weights (the paper also serves a dummy model)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            out.append(0.05 * jax.random.normal(sub, shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Attention + MLP blocks


def _mlp(p, x):
    gate = jax.nn.silu(x @ p["w_gate"])
    return (gate * (x @ p["w_up"])) @ p["w_down"]


def _qkv(cfg, p, x, positions):
    """x: [..., T, d] -> q [..., T, nh, hd], k/v [..., T, kvh, hd] (roped)."""
    lead = x.shape[:-1]
    q = (x @ p["wq"]).reshape(*lead, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(*lead, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(*lead, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_base)
    k = rope(k, positions, cfg.rope_base)
    return q, k, v


# ---------------------------------------------------------------------------
# Entry points


def prefill_step(cfg: ModelConfig, params_flat, tokens, kv, start, n_valid):
    """One CPP chunk of prefill.  Shapes in the module docstring."""
    p = unpack_params(cfg, params_flat)
    S = tokens.shape[0]
    s0 = start[0]
    positions = s0 + jnp.arange(S, dtype=jnp.int32)  # [S]
    x = p["tok_emb"][tokens]  # [S, d]

    for li, lp in enumerate(p["layers"]):
        h = rms_norm(x, lp["attn_norm"])
        q, k, v = _qkv(cfg, lp, h, positions)
        # Write this chunk's K/V into the cache at [start, start+S).
        kv = jax.lax.dynamic_update_slice(kv, k[None, None], (li, 0, s0, 0, 0))
        kv = jax.lax.dynamic_update_slice(kv, v[None, None], (li, 1, s0, 0, 0))
        attn = prefill_attention(q, kv[li, 0], kv[li, 1], start)
        x = x + attn.reshape(S, -1) @ lp["wo"]
        x = x + _mlp(lp, rms_norm(x, lp["mlp_norm"]))

    x = rms_norm(x, p["final_norm"])
    # Logits of the last *valid* row (rows past n_valid are padding).
    last = jax.lax.dynamic_slice(x, (n_valid[0] - 1, 0), (1, cfg.d_model))[0]
    return last @ p["lm_head"], kv


def decode_step(cfg: ModelConfig, params_flat, tokens, kv, positions):
    """One continuous-batching decode iteration over B slots."""
    p = unpack_params(cfg, params_flat)
    B = tokens.shape[0]
    x = p["tok_emb"][tokens]  # [B, d]

    def write(cache_bl, val, pos):
        # cache_bl: [C, kvh, hd]; val: [kvh, hd] — insert at `pos`.
        return jax.lax.dynamic_update_slice(cache_bl, val[None], (pos, 0, 0))

    for li, lp in enumerate(p["layers"]):
        h = rms_norm(x, lp["attn_norm"])
        q, k, v = _qkv(cfg, lp, h, positions)  # q: [B, nh, hd]; k/v: [B, kvh, hd]
        kc = jax.vmap(write)(kv[:, li, 0], k, positions)  # [B, C, kvh, hd]
        vc = jax.vmap(write)(kv[:, li, 1], v, positions)
        kv = kv.at[:, li, 0].set(kc)
        kv = kv.at[:, li, 1].set(vc)
        attn = decode_attention(q, kc, vc, positions + 1)
        x = x + attn.reshape(B, -1) @ lp["wo"]
        x = x + _mlp(lp, rms_norm(x, lp["mlp_norm"]))

    x = rms_norm(x, p["final_norm"])
    return x @ p["lm_head"], kv


def kv_shape(cfg: ModelConfig, batch: int | None = None):
    """Canonical KVCache tensor shape (leading batch dim optional)."""
    base = (cfg.n_layers, 2, cfg.max_ctx, cfg.n_kv_heads, cfg.head_dim)
    return base if batch is None else (batch, *base)
