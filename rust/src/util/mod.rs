//! In-crate infrastructure: JSON, RNG + distributions, statistics, CLI
//! argument parsing.  (No serde/clap/rand offline — see DESIGN.md.)

pub mod args;
pub mod json;
pub mod rng;
pub mod stats;
