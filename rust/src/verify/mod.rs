//! Runtime invariant enforcement levels.
//!
//! The repo's expensive self-checks — the incremental global prefix
//! index against a brute-force rebuild every 1024 events, plus
//! the end-of-run rebuild — used to be bare `debug_assert!`s: always on
//! in debug builds, never available in release.  [`Paranoia`] makes the
//! level a [`crate::config::SimConfig`] knob instead, so a release
//! binary replaying a 10M-request trace can opt *in* to full checking
//! (`Full`) and a debug experiment hunting an unrelated bug can opt
//! *out* (`Off`).  The default (`Debug`) is bit-for-bit the old
//! behavior.
//!
//! The conductor's walk-vs-scan parity cross-check stays a
//! `#[cfg(debug_assertions)]` block inside `find_prefix_matches_into`
//! (threading a level through that pub signature would churn every
//! caller, including benches); see DESIGN.md's static-analysis section.

/// How much runtime self-verification a `Sim` performs.  Checks gated on
/// [`Paranoia::active`] are *hard* `assert!`s when enabled — a paranoia
/// failure is corruption, not a soft warning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Paranoia {
    /// Never check (release semantics even in a debug build).
    Off,
    /// Check in debug builds only — the historical `debug_assert!`
    /// behavior, and the default.
    #[default]
    Debug,
    /// Always check, including in release builds (slow: the index
    /// rebuild is O(resident blocks) per check).
    Full,
}

impl Paranoia {
    /// Whether gated checks run in this build.
    #[inline]
    pub fn active(self) -> bool {
        match self {
            Paranoia::Off => false,
            Paranoia::Debug => cfg!(debug_assertions),
            Paranoia::Full => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_resolve_against_the_build_profile() {
        assert!(!Paranoia::Off.active());
        assert!(Paranoia::Full.active());
        assert_eq!(Paranoia::Debug.active(), cfg!(debug_assertions));
        assert_eq!(Paranoia::default(), Paranoia::Debug);
    }
}
