//! Runtime no-alloc audit (tier-1, `--features alloc-audit`): the
//! counting global allocator in `util::alloc_audit` pins the
//! scheduler's warmed steady-state decision loop at **zero** heap
//! allocations — the runtime twin of `pallas_lint`'s static
//! `hot-no-alloc` rule, catching what token scanning cannot (an
//! allocation hidden behind a helper call, an amortized `Vec` that was
//! never pre-sized).
//!
//! One `#[test]` only: the allocation counter is process-global, so a
//! second concurrent test in this binary would pollute the audited
//! regions.  All phases (scan/index pricing, scan/index *accepts*) run
//! sequentially inside it.

use mooncake::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
use mooncake::prefill::JobId;
use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig, SloConfig};
use mooncake::decode::DecodeInstance;
use mooncake::kvcache::DenseBlockId;
use mooncake::model::PerfModel;
use mooncake::prefill::PrefillPool;
use mooncake::resource::Resources;
use mooncake::trace::BLOCK_TOKENS;
use mooncake::util::alloc_audit::AllocGuard;
use mooncake::util::rng::Rng;

/// Allocations across `iters` warmed steady-state `schedule` calls
/// (SLO-rejecting, so every iteration prices identical cluster state
/// and nothing mutates).  Mirrors `benches/sched_throughput.rs`'s
/// `measure_allocs_per_decision`, as a pass/fail gate instead of a
/// reported column.
fn audit_decisions(use_index: bool, iters: usize) -> u64 {
    let mut cfg = SimConfig {
        n_prefill: 8,
        n_decode: 4,
        scheduling: SchedulingPolicy::KvCacheCentric,
        rejection: RejectionPolicy::None,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: None,
        ..Default::default()
    };
    // ttft_ms = 0 makes the SLO gate reject after the *full* pricing
    // pass (prefill + decode selection), before any mutation.
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    let chain = 256usize;
    let perf = PerfModel::paper();

    // Warm every node with the probe chain plus two filler chains, so
    // pricing pays its worst case against realistically loaded maps.
    let mut pool = PrefillPool::new(&cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    for (node, inst) in pool.instances.iter_mut().enumerate() {
        let _ = inst.pool.admit_chain(&probe, 0.0);
        for f in 0..2u32 {
            let base = 1_000_000 + (node as u32 * 2 + f) * chain as u32;
            let filler: Vec<DenseBlockId> = (base..base + chain as u32).collect();
            let _ = inst.pool.admit_chain(&filler, 0.0);
        }
    }
    let mut index = use_index.then(|| pool.build_prefix_index());

    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..64 {
        run_one(w as f64);
    }
    let guard = AllocGuard::new();
    for k in 0..iters {
        run_one(k as f64);
    }
    guard.count()
}

/// Allocations across `iters` warmed *hybrid-branch* decisions (ISSUE 9
/// satellite): the tail half of the probe chain sits on every node's
/// SSD tier, so each pricing pass runs Algorithm 1's fourth branch —
/// `hybrid_split_scan` pricing every SSD split position against the
/// NVMe queue — before the SLO gate rejects.  The hybrid decision path
/// must be as allocation-free as the exclusive three-way one.
fn audit_hybrid_decisions(use_index: bool, iters: usize) -> u64 {
    let mut cfg = SimConfig {
        n_prefill: 8,
        n_decode: 4,
        scheduling: SchedulingPolicy::KvCacheCentric,
        rejection: RejectionPolicy::None,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: Some(1_000_000),
        ..Default::default()
    };
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    assert!(cfg.hybrid, "the audited branch must be on by default");
    let chain = 256usize;
    let perf = PerfModel::paper();

    // Warm every node with the probe chain, then demote its tail half:
    // every candidate carries a 128-position SSD tail for the scan.
    let mut pool = PrefillPool::new(&cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    for inst in pool.instances.iter_mut() {
        let _ = inst.pool.admit_chain(&probe, 0.0);
        for b in (chain as u32 / 2)..chain as u32 {
            let _ = inst.pool.demote_block(b, 0.5);
        }
    }
    let mut index = use_index.then(|| pool.build_prefix_index());

    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..64 {
        run_one(w as f64);
    }
    let guard = AllocGuard::new();
    for k in 0..iters {
        run_one(k as f64);
    }
    guard.count()
}

/// Allocations across `iters` warmed **accept** cycles: an accepting
/// SLO admits the same fully-resident chain every iteration, and the
/// job is driven through `startable_into`/`start`/`finish` so the pool
/// returns to its idle state before the next accept.  Every buffer the
/// lifecycle needs is recycled — the placement group, the job's CPP
/// group, the startable list — so the warmed cycle performs zero heap
/// allocations (ISSUE 8 satellite).  Uncapped tiers, so the hit path
/// never touches the eviction-order tree.
fn audit_accepts(use_index: bool, iters: usize) -> u64 {
    let cfg = SimConfig {
        n_prefill: 4,
        n_decode: 4,
        scheduling: SchedulingPolicy::KvCacheCentric,
        rejection: RejectionPolicy::None,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: None,
        slo: SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let chain = 64usize;
    let perf = PerfModel::paper();

    // Every node holds the whole chain in DRAM: each accept is an
    // all-hit local placement — no fetch, no staging, no demotions —
    // and admission merely touches recency metadata.
    let mut pool = PrefillPool::new(&cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    for inst in pool.instances.iter_mut() {
        let _ = inst.pool.admit_chain(&probe, 0.0);
    }
    let mut index = use_index.then(|| pool.build_prefix_index());

    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    // Four blocks of fresh suffix keep the prefill non-degenerate.
    let req = SchedRequest {
        rid: 1,
        input_tokens: (chain as u64 + 4) * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut ready: Vec<JobId> = Vec::new();
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let p = conductor::schedule(&mut ctx, &req, &mut stats)
            .expect("accepting steady state must admit");
        let jid = p.job;
        assert!(p.fetch.is_none() && p.ssd_load_blocks == 0, "accept must be all-hit local");
        scratch.recycle_placement_group(p.prefill_group);
        // Drive the admitted job to completion so the queues drain back
        // to the idle state the next accept prices.
        pool.startable_into(now, &mut ready);
        assert!(ready.len() == 1 && ready[0] == jid, "the fresh job must be startable");
        let (_primary, exec_ms, rid) = pool.start(jid, now);
        assert!(rid == req.rid);
        let _done = pool.finish(jid, now + exec_ms);
        assert!(pool.outstanding() == 0);
    };
    for w in 0..64 {
        run_one(w as f64 * 1e4);
    }
    let guard = AllocGuard::new();
    for k in 0..iters {
        run_one((64 + k) as f64 * 1e4);
    }
    guard.count()
}

#[test]
fn steady_state_decisions_do_not_allocate() {
    let iters = 1_000usize;

    // Scan pricing (no global index): allocation-free in every build
    // profile once the scratch buffers are warm.
    let scan = audit_decisions(false, iters);
    assert_eq!(scan, 0, "scan-path decision loop allocated ({scan} allocs / {iters} decisions)");

    // Hybrid-branch pricing (ISSUE 9): the fourth branch's split scan
    // prices every SSD position of every candidate without allocating.
    let hybrid = audit_hybrid_decisions(false, iters);
    assert_eq!(
        hybrid, 0,
        "hybrid decision loop allocated ({hybrid} allocs / {iters} decisions)"
    );

    // Accept lifecycle on the scan path: admit → start → finish, also
    // allocation-free once the recycled buffers are warm.
    let scan_accepts = audit_accepts(false, iters);
    assert_eq!(
        scan_accepts, 0,
        "scan-path accept loop allocated ({scan_accepts} allocs / {iters} accepts)"
    );

    // Index-backed phases: the release hot path is allocation-free.
    // Debug builds run the scan-vs-index parity self-check inside
    // `find_prefix_matches_into`, which allocates by design — so these
    // phases only gate optimized builds (CI runs them via
    // `cargo test --release --features alloc-audit`).
    if !cfg!(debug_assertions) {
        let indexed = audit_decisions(true, iters);
        assert_eq!(
            indexed, 0,
            "index-path decision loop allocated ({indexed} allocs / {iters} decisions)"
        );
        let indexed_accepts = audit_accepts(true, iters);
        assert_eq!(
            indexed_accepts, 0,
            "index-path accept loop allocated ({indexed_accepts} allocs / {iters} accepts)"
        );
        let indexed_hybrid = audit_hybrid_decisions(true, iters);
        assert_eq!(
            indexed_hybrid, 0,
            "index-path hybrid loop allocated ({indexed_hybrid} allocs / {iters} decisions)"
        );
    }
}
