//! Tiered per-instance KVCache pool (§3, §4.2): each node contributes a
//! fast CPU **DRAM** tier and a capacity **SSD** tier to the disaggregated
//! cache.  Eviction from DRAM *demotes* a block to SSD instead of
//! destroying it; only SSD overflow actually drops data.  Reusing an
//! SSD-resident block *promotes* it back to DRAM (its KV is staged up for
//! the prefill), so heat naturally stratifies the tiers.  Conductor's
//! scheduling reads the per-tier split through [`CachePool::prefix_match`]
//! to price the three-way reuse-from-DRAM / load-from-SSD / recompute
//! decision.
//!
//! Pools speak interned [`DenseBlockId`]s (see `kvcache::intern`), and
//! the hot mutators have `_into` variants that fill a caller-owned
//! [`TierDelta`] so the scheduler's steady-state path reuses one scratch
//! delta instead of allocating per mutation.

use super::eviction::{EvictionPolicy, PolicyKind};
use super::intern::DenseBlockId;
use crate::TimeMs;

/// Which tier a resident block currently lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Dram,
    Ssd,
}

/// Per-tier hit and traffic counters.  The invariant the integration
/// tests pin: `dram_hits + ssd_hits` equals the blocks the scheduler
/// counted as reused, because hits are only recorded for the reused
/// prefix the placement actually consumed.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TierCounters {
    /// Reused blocks served straight from DRAM.
    pub dram_hits: u64,
    /// Reused blocks staged up from the SSD tier.
    pub ssd_hits: u64,
    /// Blocks admitted without reuse (inserted fresh into DRAM).
    pub misses: u64,
    /// DRAM evictions that moved a block down to SSD.
    pub demotions: u64,
    /// SSD blocks moved back to DRAM on reuse.
    pub promotions: u64,
    /// Blocks destroyed outright (SSD overflow, or DRAM eviction with the
    /// SSD tier disabled).
    pub dropped: u64,
}

impl TierCounters {
    pub fn hits(&self) -> u64 {
        self.dram_hits + self.ssd_hits
    }

    pub fn accesses(&self) -> u64 {
        self.hits() + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.accesses();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &TierCounters) {
        self.dram_hits += other.dram_hits;
        self.ssd_hits += other.ssd_hits;
        self.misses += other.misses;
        self.demotions += other.demotions;
        self.promotions += other.promotions;
        self.dropped += other.dropped;
    }
}

/// Residency changes a pool mutation caused, in application order — the
/// feed that keeps the Conductor's global [`crate::kvcache::PrefixIndex`]
/// consistent with the per-node pools without rescanning them.  `None`
/// means the block left the pool entirely (dropped).
#[derive(Debug, Default, Clone)]
pub struct TierDelta {
    pub changes: Vec<(DenseBlockId, Option<Tier>)>,
}

impl TierDelta {
    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }

    /// Reset for reuse (the `_into` mutators call this; capacity is
    /// kept, so a reused scratch delta stops allocating at steady state).
    pub fn clear(&mut self) {
        self.changes.clear();
    }

    /// Blocks destroyed outright, in drop order (the pre-delta return
    /// value of the `admit_*` family, kept for accounting tests).
    pub fn dropped(&self) -> Vec<DenseBlockId> {
        self.changes.iter().filter(|(_, t)| t.is_none()).map(|(b, _)| *b).collect()
    }

    /// Blocks this mutation moved down to the SSD tier — each one is an
    /// NVMe *write* the resource model charges to the node's device
    /// queue (the one definition shared by admission-time and
    /// sweep-time accounting).
    pub fn demoted_to_ssd(&self) -> usize {
        self.changes.iter().filter(|&&(_, t)| t == Some(Tier::Ssd)).count()
    }

    fn push(&mut self, b: DenseBlockId, t: Option<Tier>) {
        self.changes.push((b, t));
    }
}

/// The longest usable prefix of a request's hash chain in this pool,
/// split by tier (Algorithm 1's `prefix_len`, tier-aware), plus the
/// matched head's SSD-run summary: the leading pure-DRAM run ends at
/// `dram_prefix` (which is also the *first* SSD position whenever
/// `ssd_blocks > 0`), and `ssd_last` is the last SSD position — so the
/// candidate's SSD copies all lie in `[dram_prefix, ssd_last]`.  The
/// §6.2 wire-refresh pricing rejects non-overlapping source/candidate
/// SSD spans in O(1) off this summary alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierMatch {
    /// Leading run of chain blocks resident in *either* tier.
    pub blocks: usize,
    /// Leading run resident in DRAM before the first SSD (or absent)
    /// block — the prefix reusable without touching the SSD.
    pub dram_prefix: usize,
    /// Of `blocks`, how many are DRAM-resident.
    pub dram_blocks: usize,
    /// Of `blocks`, how many would have to be staged up from SSD.
    pub ssd_blocks: usize,
    /// Chain position of the last SSD-resident block in the match
    /// ([`TierMatch::NO_SSD`] when `ssd_blocks == 0`).
    pub ssd_last: u32,
}

impl TierMatch {
    /// Sentinel for `ssd_last` when the match has no SSD blocks.
    pub const NO_SSD: u32 = u32::MAX;
}

impl Default for TierMatch {
    fn default() -> Self {
        TierMatch {
            blocks: 0,
            dram_prefix: 0,
            dram_blocks: 0,
            ssd_blocks: 0,
            ssd_last: Self::NO_SSD,
        }
    }
}

/// Per-node SSD *positions* within each node's matched head, carried out
/// of the one prefix walk (`PrefixIndex::best_prefix_into` or the
/// per-pool scan) so the §6.2 balancing branch prices wire-refreshing a
/// candidate's SSD copies without re-probing any tier per head block.
///
/// Flat layout: producers `push(node, pos)` in any node order into one
/// staging vector, then `seal()` groups the pairs into a single flat
/// buffer with per-node offset bounds — a stable counting sort, so
/// within a node positions keep push order (both fill paths push them
/// ascending).  One buffer plus one bounds vector replace up to
/// `PrefixIndex::MAX_NODES` tiny per-node Vecs, and everything clears in
/// place, so the steady-state decision loop stops allocating once
/// warmed.
#[derive(Debug, Default)]
pub struct SsdPositions {
    /// Staged `(node, position)` pairs in push order.
    pairs: Vec<(u32, u32)>,
    /// During staging, `bounds[n + 1]` counts node `n`'s pushes; after
    /// `seal`, `bounds[n]..bounds[n + 1]` spans node `n` in `buf`.
    bounds: Vec<u32>,
    /// Sealed positions, grouped by node.
    buf: Vec<u32>,
    /// Counting-sort write cursors (seal-time scratch).
    cursors: Vec<u32>,
    /// Reusable per-probe scratch loaned to scan-side callers (see
    /// [`Self::take_scratch`]), kept here so they need no extra state.
    scratch: Vec<u32>,
}

impl SsdPositions {
    /// Clear (and, first time, grow) to an empty — and trivially
    /// *sealed* — state for `n_nodes` nodes.
    // lint: hot
    pub fn reset(&mut self, n_nodes: usize) {
        self.pairs.clear();
        self.buf.clear();
        self.bounds.clear();
        self.bounds.resize(n_nodes + 1, 0);
    }

    /// Stage one SSD position for `node`.  Positions become readable
    /// only after [`Self::seal`].
    // lint: hot
    #[inline]
    pub fn push(&mut self, node: usize, pos: u32) {
        self.bounds[node + 1] += 1;
        self.pairs.push((node as u32, pos));
    }

    /// Group the staged pairs by node.  Call once after the last `push`
    /// and before any [`Self::node`] read.
    // lint: hot
    pub fn seal(&mut self) {
        let n_nodes = self.bounds.len().saturating_sub(1);
        for n in 1..=n_nodes {
            self.bounds[n] += self.bounds[n - 1];
        }
        self.buf.resize(self.pairs.len(), 0);
        self.cursors.clear();
        self.cursors.extend_from_slice(&self.bounds[..n_nodes]);
        for &(node, pos) in &self.pairs {
            let c = &mut self.cursors[node as usize];
            self.buf[*c as usize] = pos;
            *c += 1;
        }
    }

    /// Ascending SSD positions within `node`'s matched head.
    pub fn node(&self, node: usize) -> &[u32] {
        debug_assert_eq!(self.buf.len(), self.pairs.len(), "SsdPositions read before seal");
        &self.buf[self.bounds[node] as usize..self.bounds[node + 1] as usize]
    }

    /// Borrow the reusable probe scratch (empty Vec swapped out; return
    /// it with [`Self::put_scratch`] so its capacity survives).
    pub fn take_scratch(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.scratch)
    }

    pub fn put_scratch(&mut self, v: Vec<u32>) {
        self.scratch = v;
    }

    /// Equality over the first `n` nodes (scratch may keep longer spare
    /// capacity from earlier, wider uses).
    pub fn same_nodes(&self, other: &Self, n: usize) -> bool {
        (0..n).all(|k| self.node(k) == other.node(k))
    }
}

/// One node's tiered KVCache pool: DRAM + SSD [`EvictionPolicy`] maps
/// (same policy kind per tier) plus the tier counters.  A block lives in
/// exactly one tier at a time — `rust/tests/proptest_invariants.rs`
/// hammers that conservation property.
#[derive(Debug)]
pub struct CachePool {
    dram: EvictionPolicy,
    ssd: EvictionPolicy,
    pub stats: TierCounters,
}

impl CachePool {
    /// `ssd_capacity_blocks`: `Some(0)` disables the SSD tier (DRAM-only,
    /// eviction destroys blocks — the pre-tiering behavior), `None` is an
    /// unbounded SSD.
    pub fn new(
        kind: PolicyKind,
        dram_capacity_blocks: Option<usize>,
        ssd_capacity_blocks: Option<usize>,
    ) -> Self {
        CachePool {
            dram: EvictionPolicy::new(kind, dram_capacity_blocks),
            ssd: EvictionPolicy::new(kind, ssd_capacity_blocks),
            stats: TierCounters::default(),
        }
    }

    /// Total resident blocks across both tiers.
    pub fn len(&self) -> usize {
        self.dram.len() + self.ssd.len()
    }

    pub fn dram_len(&self) -> usize {
        self.dram.len()
    }

    pub fn ssd_len(&self) -> usize {
        self.ssd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dram.is_empty() && self.ssd.is_empty()
    }

    pub fn contains(&self, b: DenseBlockId) -> bool {
        self.dram.contains(b) || self.ssd.contains(b)
    }

    pub fn tier_of(&self, b: DenseBlockId) -> Option<Tier> {
        if self.dram.contains(b) {
            Some(Tier::Dram)
        } else if self.ssd.contains(b) {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    fn ssd_enabled(&self) -> bool {
        self.ssd.capacity() != Some(0)
    }

    // lint: hot
    fn match_inner(&self, hash_ids: &[DenseBlockId], mut pos: Option<&mut Vec<u32>>) -> TierMatch {
        if let Some(v) = pos.as_deref_mut() {
            v.clear();
        }
        let mut m = TierMatch::default();
        let mut dram_run = true;
        for (i, &b) in hash_ids.iter().enumerate() {
            if self.dram.contains(b) {
                m.blocks += 1;
                m.dram_blocks += 1;
                if dram_run {
                    m.dram_prefix += 1;
                }
            } else if self.ssd.contains(b) {
                m.blocks += 1;
                m.ssd_blocks += 1;
                m.ssd_last = i as u32;
                if let Some(v) = pos.as_deref_mut() {
                    v.push(i as u32);
                }
                dram_run = false;
            } else {
                break;
            }
        }
        m
    }

    /// Tier-aware prefix match: the leading run of the chain resident in
    /// either tier, with its DRAM/SSD composition.
    pub fn prefix_match(&self, hash_ids: &[DenseBlockId]) -> TierMatch {
        self.match_inner(hash_ids, None)
    }

    /// [`Self::prefix_match`] that also collects the match's SSD
    /// positions into `ssd_pos` (cleared first) — the scan-side twin of
    /// `PrefixIndex::best_prefix_into`'s position capture.
    // lint: hot
    pub fn prefix_match_with(
        &self,
        hash_ids: &[DenseBlockId],
        ssd_pos: &mut Vec<u32>,
    ) -> TierMatch {
        self.match_inner(hash_ids, Some(ssd_pos))
    }

    /// Algorithm 1's `prefix_len` (in blocks), tier-blind.  Read-only
    /// (hit accounting happens on admission, not on probing).
    pub fn prefix_match_blocks(&self, hash_ids: &[DenseBlockId]) -> usize {
        self.prefix_match(hash_ids).blocks
    }

    /// Insert into DRAM, demoting (or, with SSD disabled, dropping) LRU
    /// victims first so the insert itself never evicts.  Every residency
    /// change (demotion, drop, the insert itself) is recorded in `delta`.
    fn insert_dram(&mut self, b: DenseBlockId, now: TimeMs, pos: usize, delta: &mut TierDelta) {
        if self.dram.capacity() == Some(0) {
            // Degenerate no-DRAM config: fresh KV spills straight down to
            // the SSD tier (or is dropped), keeping the capacity bound
            // exact instead of holding one block over it.  Not counted as
            // a demotion — the block was never DRAM-resident.
            if self.ssd_enabled() {
                if let Some(dead) = self.ssd.insert(b, now, pos) {
                    self.stats.dropped += 1;
                    delta.push(dead, None);
                }
                delta.push(b, Some(Tier::Ssd));
            } else {
                self.stats.dropped += 1;
                delta.push(b, None);
            }
            return;
        }
        while self.dram.at_capacity() {
            let Some((victim, vpos)) = self.dram.evict_entry() else {
                break;
            };
            if self.ssd_enabled() {
                self.stats.demotions += 1;
                if let Some(dead) = self.ssd.insert(victim, now, vpos) {
                    self.stats.dropped += 1;
                    delta.push(dead, None);
                }
                delta.push(victim, Some(Tier::Ssd));
            } else {
                self.stats.dropped += 1;
                delta.push(victim, None);
            }
        }
        // Room was made above (or the tier is unbounded), so this insert
        // itself cannot evict.
        let evicted = self.dram.insert(b, now, pos);
        debug_assert!(evicted.is_none());
        delta.push(b, Some(Tier::Dram));
    }

    /// Place one block of an admitted chain.  `reused` says whether the
    /// scheduler counted this block as reused KVCache: reused blocks are
    /// hits (promoting from SSD if needed); non-reused blocks are misses
    /// whose KV gets (re)materialized in DRAM — recomputed blocks shadow
    /// any stale SSD copy, which is removed so a block never lives in two
    /// tiers.
    fn place(
        &mut self,
        b: DenseBlockId,
        pos: usize,
        now: TimeMs,
        reused: bool,
        delta: &mut TierDelta,
    ) {
        if self.dram.contains(b) {
            if reused {
                self.stats.dram_hits += 1;
            } else {
                self.stats.misses += 1;
            }
            self.dram.touch(b, now, pos);
        } else if self.ssd.contains(b) {
            if reused {
                self.stats.ssd_hits += 1;
                self.stats.promotions += 1;
            } else {
                self.stats.misses += 1;
            }
            self.ssd.remove(b);
            self.insert_dram(b, now, pos, delta);
        } else {
            self.stats.misses += 1;
            self.insert_dram(b, now, pos, delta);
        }
    }

    /// Admit a request's block chain with the scheduler's reuse decision,
    /// recording residency changes into a caller-owned (reused) delta:
    /// the leading `reused_blocks` count as hits (DRAM touch or SSD
    /// promotion), the rest as misses inserted into DRAM (their KV was
    /// just computed).
    // lint: hot
    pub fn admit_chain_reusing_into(
        &mut self,
        hash_ids: &[DenseBlockId],
        reused_blocks: usize,
        now: TimeMs,
        delta: &mut TierDelta,
    ) {
        delta.clear();
        for (i, &b) in hash_ids.iter().enumerate() {
            self.place(b, i, now, i < reused_blocks, delta);
        }
    }

    /// Allocating convenience form of [`Self::admit_chain_reusing_into`].
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn admit_chain_reusing(
        &mut self,
        hash_ids: &[DenseBlockId],
        reused_blocks: usize,
        now: TimeMs,
    ) -> TierDelta {
        let mut delta = TierDelta::default();
        self.admit_chain_reusing_into(hash_ids, reused_blocks, now, &mut delta);
        delta
    }

    /// Admit a chain reusing everything the pool can prefix-match — the
    /// pre-tiering API, kept for callers without a scheduling decision.
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn admit_chain(&mut self, hash_ids: &[DenseBlockId], now: TimeMs) -> TierDelta {
        let matched = self.prefix_match_blocks(hash_ids);
        self.admit_chain_reusing(hash_ids, matched, now)
    }

    /// Admit a single block with per-block (non-prefix) semantics — the
    /// Table 1 global-pool replays.  A block resident in either tier is a
    /// hit (promoting from SSD); a miss inserts into DRAM.  Returns
    /// whether it hit plus the residency changes.
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn admit_block(&mut self, b: DenseBlockId, pos: usize, now: TimeMs) -> (bool, TierDelta) {
        let hit = self.contains(b);
        let mut delta = TierDelta::default();
        self.place(b, pos, now, hit, &mut delta);
        (hit, delta)
    }

    /// Insert replicated blocks (hot-spot migration §6.2) without hit
    /// accounting, recording residency changes into a caller-owned
    /// delta.  Replicas land in DRAM (they arrive hot off the wire); a
    /// stale SSD copy is superseded.
    // lint: hot
    pub fn insert_replica_into(
        &mut self,
        blocks: &[DenseBlockId],
        now: TimeMs,
        delta: &mut TierDelta,
    ) {
        delta.clear();
        for (i, &b) in blocks.iter().enumerate() {
            if self.dram.contains(b) {
                continue;
            }
            if self.ssd.contains(b) {
                self.ssd.remove(b);
                self.stats.promotions += 1;
            }
            self.insert_dram(b, now, i, delta);
        }
    }

    /// Allocating convenience form of [`Self::insert_replica_into`].
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn insert_replica(&mut self, blocks: &[DenseBlockId], now: TimeMs) -> TierDelta {
        let mut delta = TierDelta::default();
        self.insert_replica_into(blocks, now, &mut delta);
        delta
    }

    /// Move a DRAM-resident block down to the SSD tier (idle-demotion /
    /// test hook).  Returns `None` if the block is not in DRAM or the SSD
    /// tier is disabled, the residency changes otherwise.
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn demote_block(&mut self, b: DenseBlockId, now: TimeMs) -> Option<TierDelta> {
        if !self.dram.contains(b) || !self.ssd_enabled() {
            return None;
        }
        let mut delta = TierDelta::default();
        let pos = self.dram.pos_of(b).unwrap_or(0);
        self.dram.remove(b);
        self.stats.demotions += 1;
        if let Some(dead) = self.ssd.insert(b, now, pos) {
            self.stats.dropped += 1;
            debug_assert_ne!(dead, b, "SSD tier evicted the block being demoted");
            delta.push(dead, None);
        }
        delta.push(b, Some(Tier::Ssd));
        Some(delta)
    }

    /// Proactive background demotion (the low-priority sweep behind
    /// `SimConfig::demote_after_ms`): move every DRAM block idle for at
    /// least `idle_ms` down to the SSD tier without waiting for capacity
    /// pressure.  Deterministic (idle candidates are sorted by id).
    #[must_use = "apply the TierDelta to the PrefixIndex or residency accounting diverges"]
    pub fn demote_idle(&mut self, now: TimeMs, idle_ms: f64) -> TierDelta {
        let mut delta = TierDelta::default();
        if !self.ssd_enabled() {
            return delta;
        }
        for b in self.dram.idle_blocks(now, idle_ms) {
            if let Some(d) = self.demote_block(b, now) {
                delta.changes.extend(d.changes);
            }
        }
        delta
    }

    /// Drop every resident block from *both* tiers at once — node loss
    /// (`faults::FaultEntry::NodeLoss`): the node's DRAM and SSD pools
    /// vanish together, so each block leaves the pool entirely.  The
    /// residency changes are recorded into a caller-owned delta (cleared
    /// first, `_into` convention) in ascending dense-id order, keeping
    /// fault runs deterministic regardless of tier-map iteration order.
    /// Applying the delta to the prefix index is what keeps the index
    /// `equals_rebuild_of`-consistent without a rebuild.
    pub fn drop_all_into(&mut self, delta: &mut TierDelta) {
        delta.clear();
        let mut ids: Vec<DenseBlockId> =
            self.dram.iter_blocks().chain(self.ssd.iter_blocks()).collect();
        ids.sort_unstable();
        for &b in &ids {
            if self.dram.contains(b) {
                self.dram.remove(b);
            } else {
                self.ssd.remove(b);
            }
            delta.push(b, None);
        }
        self.stats.dropped += ids.len() as u64;
    }

    pub fn hits(&self) -> u64 {
        self.stats.hits()
    }

    pub fn misses(&self) -> u64 {
        self.stats.misses
    }

    pub fn hit_rate(&self) -> f64 {
        self.stats.hit_rate()
    }

    /// Blocks destroyed outright (not demoted).
    pub fn evictions(&self) -> u64 {
        self.stats.dropped
    }

    pub fn iter_blocks(&self) -> impl Iterator<Item = DenseBlockId> + '_ {
        self.dram.iter_blocks().chain(self.ssd.iter_blocks())
    }

    pub fn iter_dram_blocks(&self) -> impl Iterator<Item = DenseBlockId> + '_ {
        self.dram.iter_blocks()
    }

    pub fn iter_ssd_blocks(&self) -> impl Iterator<Item = DenseBlockId> + '_ {
        self.ssd.iter_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_match_stops_at_gap() {
        let mut p = CachePool::new(PolicyKind::Lru, None, Some(0));
        let _ = p.admit_chain(&[1, 2, 3], 0.0);
        assert_eq!(p.prefix_match_blocks(&[1, 2, 9, 3]), 2);
        assert_eq!(p.prefix_match_blocks(&[9, 1, 2]), 0);
        assert_eq!(p.prefix_match_blocks(&[1, 2, 3, 4]), 3);
    }

    #[test]
    fn admit_counts_hits_and_misses() {
        let mut p = CachePool::new(PolicyKind::Lru, None, Some(0));
        let _ = p.admit_chain(&[1, 2], 0.0);
        assert_eq!((p.hits(), p.misses()), (0, 2));
        let _ = p.admit_chain(&[1, 2, 3], 1.0);
        assert_eq!((p.hits(), p.misses()), (2, 3));
        assert!((p.hit_rate() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn eviction_without_ssd_drops_blocks() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(4), Some(0));
        let _ = p.admit_chain(&[1, 2, 3, 4], 0.0);
        let dropped = p.admit_chain(&[5, 6], 1.0).dropped();
        assert_eq!(dropped, vec![1, 2]); // LRU order
        assert_eq!(p.len(), 4);
        assert_eq!(p.stats.demotions, 0);
        assert_eq!(p.stats.dropped, 2);
    }

    #[test]
    fn eviction_with_ssd_demotes_instead_of_dropping() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(4), Some(8));
        let _ = p.admit_chain(&[1, 2, 3, 4], 0.0);
        let delta = p.admit_chain(&[5, 6], 1.0);
        assert!(delta.dropped().is_empty(), "demotion must not destroy blocks");
        // The delta reports the demotions and inserts it caused.
        assert!(delta.changes.contains(&(1, Some(Tier::Ssd))));
        assert!(delta.changes.contains(&(5, Some(Tier::Dram))));
        assert_eq!(p.len(), 6);
        assert_eq!(p.dram_len(), 4);
        assert_eq!(p.ssd_len(), 2);
        assert_eq!(p.tier_of(1), Some(Tier::Ssd));
        assert_eq!(p.tier_of(2), Some(Tier::Ssd));
        assert_eq!(p.tier_of(5), Some(Tier::Dram));
        assert_eq!(p.stats.demotions, 2);
        assert_eq!(p.stats.dropped, 0);
        // The whole chain is still prefix-matchable across tiers.
        assert_eq!(p.prefix_match_blocks(&[1, 2, 3, 4]), 4);
    }

    #[test]
    fn ssd_overflow_finally_drops() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(2), Some(2));
        let _ = p.admit_chain(&[1, 2], 0.0); // DRAM [1,2]
        let _ = p.admit_chain(&[3, 4], 1.0); // DRAM [3,4], SSD [1,2]
        let dropped = p.admit_chain(&[5, 6], 2.0).dropped(); // 3,4 demote; 1,2 fall off SSD
        assert_eq!(dropped, vec![1, 2]);
        assert_eq!(p.len(), 4);
        assert_eq!(p.stats.dropped, 2);
        assert_eq!(p.stats.demotions, 4);
    }

    #[test]
    fn reuse_promotes_ssd_blocks_back_to_dram() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(2), Some(4));
        let _ = p.admit_chain(&[1, 2], 0.0);
        let _ = p.admit_chain(&[3, 4], 1.0); // 1,2 now on SSD
        assert_eq!(p.tier_of(1), Some(Tier::Ssd));
        let m = p.prefix_match(&[1, 2, 3, 4]);
        assert_eq!((m.blocks, m.dram_prefix, m.ssd_blocks, m.dram_blocks), (4, 0, 2, 2));
        assert_eq!(m.ssd_last, 1, "SSD copies at positions 0 and 1");
        let _ = p.admit_chain_reusing(&[1, 2], 2, 2.0);
        assert_eq!(p.tier_of(1), Some(Tier::Dram));
        assert_eq!(p.tier_of(2), Some(Tier::Dram));
        assert_eq!(p.stats.ssd_hits, 2);
        assert_eq!(p.stats.promotions, 2);
        // 3,4 demoted to make room — conservation: everything resident.
        assert_eq!(p.len(), 4);
        assert_eq!(p.prefix_match_blocks(&[1, 2, 3, 4]), 4);
    }

    #[test]
    fn recompute_supersedes_stale_ssd_copy() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(2), Some(4));
        let _ = p.admit_chain(&[1, 2], 0.0);
        let _ = p.admit_chain(&[3, 4], 1.0); // 1,2 on SSD
        // Scheduler chose to recompute 1,2 rather than load them: misses,
        // no ssd hits, block moves to DRAM exactly once.
        let _ = p.admit_chain_reusing(&[1, 2], 0, 2.0);
        assert_eq!(p.stats.ssd_hits, 0);
        assert_eq!(p.stats.promotions, 0);
        assert_eq!(p.tier_of(1), Some(Tier::Dram));
        let dram: Vec<DenseBlockId> = p.iter_dram_blocks().collect();
        let ssd: Vec<DenseBlockId> = p.iter_ssd_blocks().collect();
        assert!(!ssd.contains(&1) && !ssd.contains(&2), "stale SSD copies must go");
        assert_eq!(dram.len() + ssd.len(), p.len());
    }

    #[test]
    fn replica_insert_no_hit_accounting() {
        let mut p = CachePool::new(PolicyKind::Lru, None, Some(0));
        let _ = p.insert_replica(&[7, 8], 0.0);
        assert_eq!((p.hits(), p.misses()), (0, 0));
        assert_eq!(p.prefix_match_blocks(&[7, 8]), 2);
    }

    #[test]
    fn replica_does_not_duplicate() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(3), Some(0));
        let _ = p.admit_chain(&[1, 2], 0.0);
        let _ = p.insert_replica(&[1, 2, 3], 1.0);
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn into_mutators_reuse_the_scratch_delta() {
        // The allocation-free contract: `_into` clears and refills one
        // caller-owned delta, and reports exactly what the allocating
        // form would.
        let mut p = CachePool::new(PolicyKind::Lru, Some(2), Some(4));
        let mut q = CachePool::new(PolicyKind::Lru, Some(2), Some(4));
        let mut delta = TierDelta::default();
        p.admit_chain_reusing_into(&[1, 2], 0, 0.0, &mut delta);
        assert_eq!(delta.changes, q.admit_chain_reusing(&[1, 2], 0, 0.0).changes);
        p.admit_chain_reusing_into(&[3, 4], 0, 1.0, &mut delta);
        assert_eq!(delta.changes, q.admit_chain_reusing(&[3, 4], 0, 1.0).changes);
        assert!(delta.demoted_to_ssd() > 0, "pressure must demote");
        let cap = delta.changes.capacity();
        p.insert_replica_into(&[9], 2.0, &mut delta);
        let _ = q.insert_replica(&[9], 2.0);
        assert_eq!(delta.changes.len(), p.len() - 3, "replica delta replaces prior content");
        assert!(delta.changes.capacity() >= 1 && cap >= delta.changes.len());
        assert_eq!(p.stats, q.stats);
    }

    #[test]
    fn demote_block_moves_tier() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(8), Some(8));
        let _ = p.admit_chain(&[1, 2], 0.0);
        let d = p.demote_block(1, 1.0).expect("DRAM block must demote");
        assert_eq!(d.changes, vec![(1, Some(Tier::Ssd))]);
        assert!(p.demote_block(1, 1.0).is_none()); // already on SSD
        assert!(p.demote_block(99, 1.0).is_none()); // unknown
        assert_eq!(p.tier_of(1), Some(Tier::Ssd));
        assert_eq!(p.len(), 2);
        // Disabled SSD refuses demotion.
        let mut q = CachePool::new(PolicyKind::Lru, Some(8), Some(0));
        let _ = q.admit_chain(&[5], 0.0);
        assert!(q.demote_block(5, 1.0).is_none());
        assert_eq!(q.tier_of(5), Some(Tier::Dram));
    }

    #[test]
    fn demote_idle_sweeps_only_stale_dram() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(8), Some(8));
        let _ = p.admit_chain(&[1, 2, 3], 0.0);
        let _ = p.admit_chain(&[3], 900.0); // refresh 3
        let delta = p.demote_idle(1_000.0, 500.0);
        assert_eq!(delta.changes, vec![(1, Some(Tier::Ssd)), (2, Some(Tier::Ssd))]);
        assert_eq!(p.tier_of(1), Some(Tier::Ssd));
        assert_eq!(p.tier_of(2), Some(Tier::Ssd));
        assert_eq!(p.tier_of(3), Some(Tier::Dram));
        assert_eq!(p.stats.demotions, 2);
        // Sweeping again moves nothing (already demoted / not idle).
        assert!(p.demote_idle(1_000.0, 500.0).is_empty());
        // Disabled SSD tier: the sweep is a no-op.
        let mut q = CachePool::new(PolicyKind::Lru, Some(8), Some(0));
        let _ = q.admit_chain(&[7], 0.0);
        assert!(q.demote_idle(1e9, 1.0).is_empty());
        assert_eq!(q.tier_of(7), Some(Tier::Dram));
    }

    #[test]
    fn zero_dram_capacity_spills_straight_to_ssd() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(0), Some(4));
        let _ = p.admit_chain(&[1, 2], 0.0);
        assert_eq!(p.dram_len(), 0, "cap-0 DRAM must hold nothing");
        assert_eq!(p.ssd_len(), 2);
        assert_eq!(p.prefix_match_blocks(&[1, 2]), 2);
        // And with both tiers disabled, nothing is ever resident.
        let mut q = CachePool::new(PolicyKind::Lru, Some(0), Some(0));
        let _ = q.admit_chain(&[1, 2], 0.0);
        assert_eq!(q.len(), 0);
        assert_eq!(q.stats.dropped, 2);
    }

    #[test]
    fn dram_prefix_stops_at_first_ssd_block() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(8), Some(8));
        let _ = p.admit_chain(&[1, 2, 3, 4], 0.0);
        let _ = p.demote_block(2, 1.0);
        let m = p.prefix_match(&[1, 2, 3, 4]);
        assert_eq!(m.blocks, 4);
        assert_eq!(m.dram_prefix, 1); // 1 is DRAM, 2 is SSD
        assert_eq!(m.dram_blocks, 3);
        assert_eq!(m.ssd_blocks, 1);
        assert_eq!(m.ssd_last, 1, "the one SSD copy sits at position 1");
    }

    #[test]
    fn drop_all_empties_both_tiers_in_id_order() {
        let mut p = CachePool::new(PolicyKind::Lru, Some(4), Some(8));
        let _ = p.admit_chain(&[3, 1, 4], 0.0);
        let _ = p.demote_block(1, 1.0).expect("demote");
        let dropped_before = p.stats.dropped;
        let mut delta = TierDelta { changes: vec![(99, None)] }; // stale scratch
        p.drop_all_into(&mut delta);
        assert_eq!(
            delta.changes,
            vec![(1, None), (3, None), (4, None)],
            "everything leaves, ascending id order"
        );
        assert!(p.is_empty());
        assert_eq!(p.stats.dropped, dropped_before + 3);
        // Idempotent on an empty pool.
        p.drop_all_into(&mut delta);
        assert!(delta.is_empty());
    }

    #[test]
    fn ssd_summary_and_positions_agree() {
        // The SSD-run summary the §6.2 wire-refresh pricing consumes:
        // first SSD position == dram_prefix, last == ssd_last, and the
        // collected positions are exactly the SSD-resident offsets.
        let mut p = CachePool::new(PolicyKind::Lru, Some(16), Some(16));
        let chain: Vec<DenseBlockId> = (10..18).collect();
        let _ = p.admit_chain(&chain, 0.0);
        for b in [12, 13, 16] {
            assert!(p.demote_block(b, 1.0).is_some());
        }
        let mut pos = vec![99]; // stale scratch must be cleared
        let m = p.prefix_match_with(&chain, &mut pos);
        assert_eq!(m.blocks, 8);
        assert_eq!(m.dram_prefix, 2);
        assert_eq!(m.ssd_blocks, 3);
        assert_eq!(m.ssd_last, 6);
        assert_eq!(pos, vec![2, 3, 6]);
        assert_eq!(pos[0] as usize, m.dram_prefix, "first SSD position == dram_prefix");
        // No SSD blocks -> sentinel + empty positions.
        let m2 = p.prefix_match_with(&chain[..2], &mut pos);
        assert_eq!(m2.ssd_last, TierMatch::NO_SSD);
        assert!(pos.is_empty());
    }
}
