"""Pallas flash-decoding kernel over a contiguous per-slot KVCache.

TPU adaptation of the paper's decode hot-spot (PagedAttention-style decode
on A800s): the grid streams the KVCache HBM->VMEM one (BK, kvh, hd) block
per step via BlockSpec — the analogue of per-threadblock shared-memory
staging — and keeps an online-softmax accumulator in VMEM scratch that
persists across the sequential kv-block grid dimension.  The q·kᵀ and p·v
contractions are MXU work on (8,128)-aligned tiles in f32.

interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls,
so the kernel is lowered to plain HLO; the BlockSpec structure (VMEM
footprint, MXU tiles) is what the §Perf TPU estimate is based on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # avoid (-inf) - (-inf) = nan in the running-max update


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *, bk, group):
    j = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [nh, hd]
    k = k_ref[0].astype(jnp.float32)  # [BK, kvh, hd]
    v = v_ref[0].astype(jnp.float32)
    nh, hd = q.shape
    # GQA: expand kv heads to query heads.
    k = jnp.repeat(k, group, axis=1)  # [BK, nh, hd]
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("nd,knd->nk", q, k, preferred_element_type=jnp.float32) * scale

    # Mask out cache positions beyond the sequence's valid length.
    kvpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = kvpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                      # [nh, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum(
        "nk,knd->nd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nblk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lens, *, block_k: int = 128):
    """Flash-decoding attention.  See `ref.decode_attention_ref`.

    q: [B, nh, hd]; k, v: [B, C, kvh, hd]; lens: [B] int32 (>= 1).
    """
    B, nh, hd = q.shape
    C, kvh = k.shape[1], k.shape[2]
    assert C % block_k == 0, (C, block_k)
    group = nh // kvh
    grid = (B, C // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, bk=block_k, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nh, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, nh, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((nh, 1), jnp.float32),
            pltpu.VMEM((nh, 1), jnp.float32),
            pltpu.VMEM((nh, hd), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, lens)
