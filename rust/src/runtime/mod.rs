//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the *only* place the Rust side touches XLA; Python never runs
//! on the request path.
//!
//! Artifact ABI (see aot.py):
//!   prefill_s{S}: [*params, tokens i32[S], kv f32[L,2,C,kvh,hd],
//!                  start i32[1], n_valid i32[1]] -> (logits f32[V], kv')
//!   decode_b{B}:  [*params, tokens i32[B], kv f32[B,L,2,C,kvh,hd],
//!                  positions i32[B]] -> (logits f32[B,V], kv')
//! Weights come from `weights.npz`, whose member names sort in parameter
//! order by construction.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::util::json::{self, Value};

/// Parsed `manifest.json` — the model-config contract with Python.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_ctx: usize,
    pub page: usize,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
    pub artifacts: BTreeMap<String, String>,
    pub n_params: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read manifest in {dir:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).context("parse manifest.json")?;
        let model = v.get("model").ok_or_else(|| anyhow!("manifest missing model"))?;
        let gi = |obj: &Value, k: &str| -> Result<usize> {
            obj.get(k).and_then(Value::as_usize).ok_or_else(|| anyhow!("manifest field {k}"))
        };
        let arr = |k: &str| -> Result<Vec<usize>> {
            Ok(v.get(k)
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("manifest field {k}"))?
                .iter()
                .filter_map(Value::as_usize)
                .collect())
        };
        let artifacts = v
            .get("artifacts")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("manifest artifacts"))?
            .iter()
            .map(|(k, val)| (k.clone(), val.as_str().unwrap_or_default().to_string()))
            .collect();
        let n_params = v
            .get("param_names")
            .and_then(Value::as_arr)
            .map(|a| a.len())
            .ok_or_else(|| anyhow!("manifest param_names"))?;
        Ok(Manifest {
            vocab: gi(model, "vocab")?,
            d_model: gi(model, "d_model")?,
            n_layers: gi(model, "n_layers")?,
            n_heads: gi(model, "n_heads")?,
            n_kv_heads: gi(model, "n_kv_heads")?,
            head_dim: gi(model, "head_dim")?,
            max_ctx: gi(model, "max_ctx")?,
            page: gi(model, "page")?,
            prefill_buckets: arr("prefill_buckets")?,
            decode_buckets: arr("decode_buckets")?,
            artifacts,
            n_params,
        })
    }

    /// f32 element count of one request's KVCache [L, 2, C, kvh, hd].
    pub fn kv_elems(&self) -> usize {
        self.n_layers * 2 * self.max_ctx * self.n_kv_heads * self.head_dim
    }
}

/// Loaded executables + weights, ready to serve.
pub struct Runtime {
    pub manifest: Manifest,
    pub client: PjRtClient,
    weights: Vec<Literal>,
    prefill: BTreeMap<usize, PjRtLoadedExecutable>,
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub n_prefill_calls: std::cell::Cell<u64>,
    pub n_decode_calls: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        // Weights: npz member names are "p{idx:03d}_..." so sorting gives
        // parameter order.
        let mut weights: Vec<(String, Literal)> =
            Literal::read_npz(dir.join("weights.npz"), &())
                .map_err(|e| anyhow!("read weights.npz: {e:?}"))?;
        weights.sort_by(|a, b| a.0.cmp(&b.0));
        if weights.len() != manifest.n_params {
            bail!("weights.npz has {} members, manifest expects {}", weights.len(), manifest.n_params);
        }
        let weights: Vec<Literal> = weights.into_iter().map(|(_, l)| l).collect();

        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            let file: PathBuf = dir.join(
                manifest
                    .artifacts
                    .get(name)
                    .ok_or_else(|| anyhow!("artifact {name} missing from manifest"))?,
            );
            let proto = xla::HloModuleProto::from_text_file(&file)
                .map_err(|e| anyhow!("parse {file:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))
        };

        let mut prefill = BTreeMap::new();
        for &s in &manifest.prefill_buckets {
            prefill.insert(s, compile(&format!("prefill_s{s}"))?);
        }
        let mut decode = BTreeMap::new();
        for &b in &manifest.decode_buckets {
            decode.insert(b, compile(&format!("decode_b{b}"))?);
        }
        Ok(Runtime {
            manifest,
            client,
            weights,
            prefill,
            decode,
            n_prefill_calls: std::cell::Cell::new(0),
            n_decode_calls: std::cell::Cell::new(0),
        })
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Option<usize> {
        self.manifest.prefill_buckets.iter().copied().find(|&s| s >= n)
    }

    /// Smallest decode bucket that fits `b` sequences.
    pub fn decode_bucket(&self, b: usize) -> Option<usize> {
        self.manifest.decode_buckets.iter().copied().find(|&s| s >= b)
    }

    fn run(&self, exe: &PjRtLoadedExecutable, extra: Vec<Literal>) -> Result<(Literal, Literal)> {
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        for l in &extra {
            args.push(l);
        }
        let result = exe
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let mut parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != 2 {
            bail!("expected (logits, kv), got {} outputs", parts.len());
        }
        let kv = parts.pop().unwrap();
        let logits = parts.pop().unwrap();
        Ok((logits, kv))
    }

    /// Build a KVCache literal from a flat f32 slice.
    pub fn kv_literal(&self, kv: &[f32], batch: Option<usize>) -> Result<Literal> {
        let m = &self.manifest;
        let mut dims = vec![m.n_layers, 2, m.max_ctx, m.n_kv_heads, m.head_dim];
        let mut want = m.kv_elems();
        if let Some(b) = batch {
            dims.insert(0, b);
            want *= b;
        }
        if kv.len() != want {
            bail!("kv len {} != {}", kv.len(), want);
        }
        literal_f32(kv, &dims)
    }

    /// One prefill chunk.  `tokens.len()` must equal the bucket size `s`
    /// (pad with zeros; `n_valid` marks the real length).  `kv` is the
    /// request's [L,2,C,kvh,hd] cache, kept as a Literal so chunk chains
    /// and the decode loop never round-trip it through host Vecs.
    pub fn prefill_chunk(
        &self,
        s: usize,
        tokens: &[i32],
        kv: Literal,
        start: usize,
        n_valid: usize,
    ) -> Result<(Vec<f32>, Literal)> {
        let exe = self.prefill.get(&s).ok_or_else(|| anyhow!("no prefill bucket {s}"))?;
        if tokens.len() != s {
            bail!("tokens len {} != bucket {s}", tokens.len());
        }
        let tok = Literal::vec1(tokens);
        let st = Literal::vec1(&[start as i32]);
        let nv = Literal::vec1(&[n_valid as i32]);
        let (logits, kv_out) = self.run(exe, vec![tok, kv, st, nv])?;
        self.n_prefill_calls.set(self.n_prefill_calls.get() + 1);
        Ok((logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?, kv_out))
    }

    /// One continuous-batching decode step over `b` slots.  `kv` is the
    /// batched [B,L,2,C,kvh,hd] cache literal; returns (logits [B*V],
    /// kv') — the returned literal feeds the next step directly (the
    /// §Perf fix: no per-step host round-trip of the 8 MB cache).
    pub fn decode_step(
        &self,
        b: usize,
        tokens: &[i32],
        kv: Literal,
        positions: &[i32],
    ) -> Result<(Vec<f32>, Literal)> {
        let exe = self.decode.get(&b).ok_or_else(|| anyhow!("no decode bucket {b}"))?;
        if tokens.len() != b || positions.len() != b {
            bail!("batch args must have len {b}");
        }
        let tok = Literal::vec1(tokens);
        let pos = Literal::vec1(positions);
        let (logits, kv_out) = self.run(exe, vec![tok, kv, pos])?;
        self.n_decode_calls.set(self.n_decode_calls.get() + 1);
        Ok((logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?, kv_out))
    }
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("literal: {e:?}"))
}

/// Argmax over a logits slice (greedy sampling).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }
}
