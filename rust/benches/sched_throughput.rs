//! Scheduling-throughput benchmark — the perf stake for the scheduler
//! hot path (ISSUE 3's global prefix index, re-measured by ISSUE 5's
//! allocation-free interned-id refactor): Conductor must stay out of
//! the way (§6 notes TTFT estimation is "negligible compared to the
//! inference time"), yet the per-pool `FindBestPrefixMatch` scan costs
//! O(nodes × chain) map probes per decision — worst exactly in the
//! long-context regime the paper targets.
//!
//! Measures, at nodes ∈ {4, 16, 64} × chain ∈ {64, 512, 4096} blocks:
//!
//! * **scheduling decisions/sec** — full Algorithm 1 (`conductor::
//!   schedule`) over a cluster whose every node holds the request's
//!   chain (the scan's worst case), in SLO-rejecting steady state so
//!   both variants price identical cluster state every iteration (this
//!   steady state is exactly the loop the refactor made
//!   allocation-free);
//! * **simulator events/sec** — end-to-end `sim::run` over a synthetic
//!   chain-sharing trace, index on vs off.
//!
//! A **congestion cell** (ISSUE 4) rides along: one hot source holds
//! the probe chain (half demoted to SSD) behind deep NVMe and NIC-tx
//! backlogs, so every candidate's pricing walks the resource-queue
//! probes (source NVMe, source tx, destination rx) — decisions/sec with
//! index on vs off, plus an end-to-end finite-rx sim.  A **congestion
//! sweep** (ISSUE 5 satellite) grids rx-bw × ssd-write-bw × the
//! balancing threshold over an end-to-end tier-pressure replay — the
//! §6.2 ablation on the PR 4 knobs.
//!
//! A **sustained-replay cell** (ISSUE 7) rides along: a long synthetic
//! replay generated on the fly and fed straight through
//! `sim::run_streaming` — requests/sec end to end plus the live-request
//! high-water mark, with `max_live_requests` bounding admission and
//! epoch id recycling keeping the interner flat underneath.
//!
//! A **cluster cell** (ISSUE 8) rides along: 1024 nodes × 4096 blocks —
//! four 256-node index shards — with three decision-throughput rows:
//! per-pool scan, sharded index sequential (`sched_workers = 1`), and
//! sharded index parallel (`sched_workers = min(8, cores)`).  The
//! seq-vs-scan ≥3× floor is asserted in both full and smoke mode; the
//! par-vs-seq ≥3× floor only where `available_parallelism() ≥ 8`
//! (thread fan-out cannot beat itself on a 1-core runner — the skip is
//! printed loudly and recorded in the JSON row as
//! `par_floor_enforced: false`).
//!
//! Two cells ride along for ISSUE 9's hybrid load+recompute branch: a
//! **hybrid prefix-plan ablation** pricing Algorithm 1's four plans
//! (pure-dram / ssd-stage / recompute / hybrid) straight from the cost
//! model across NVMe backlog depths — pure arithmetic over queue
//! probes, so the hybrid-dominates-every-exclusive-plan floor is a
//! deterministic CI gate rather than a perf measurement — and a
//! **cold-start sweep** (DRAM capacity × session re-arrival gap) whose
//! returning prefixes have been demoted to SSD, exercising the
//! stage-vs-recompute-vs-hybrid decision end to end.
//!
//! A **degraded cell** (ISSUE 10) rides along: decision throughput
//! against a cluster that has already absorbed a fault — one node dead
//! and dropped from the index the way the sim's `NodeLoss` event does
//! it, and a quarter-speed NVMe under a half-demoted probe chain — each
//! compared to an identically shaped healthy baseline.  Both slowdowns
//! gate CI at ≤2× in full and smoke mode (`variant: "degraded"` rows).
//!
//! Emits `BENCH_sched.json` — the one trajectory artifact CI uploads;
//! every row carries a `variant` column (`"hybrid"` since ISSUE 9) so
//! the same file accumulates seed/interned/sharded/hybrid cells instead
//! of growing parallel artifacts.  The ≥5× decision-throughput floor on
//! the 64-node × 4096-block cell is asserted in **both** full and
//! `--smoke` mode (smoke runs that one target cell on top of its tiny
//! grid), as is the cluster cell's seq-vs-scan floor.

use std::time::Instant;

use mooncake::bench_util::{banner, row};
use mooncake::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig, SloConfig};
use mooncake::costmodel;
use mooncake::decode::DecodeInstance;
use mooncake::kvcache::{DenseBlockId, TierDelta};
use mooncake::model::PerfModel;
use mooncake::prefill::PrefillPool;
use mooncake::resource::Resources;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::{TraceRecord, BLOCK_TOKENS};
use mooncake::util::json::{self, Value};
use mooncake::util::rng::Rng;

/// Implementation variant stamped on every JSON row — bump when a perf
/// PR re-measures the same cells so the artifact reads as a trajectory.
const VARIANT: &str = "degraded";

const TARGET_NODES: usize = 64;
const TARGET_CHAIN: usize = 4096;
const TARGET_SPEEDUP: f64 = 5.0;

/// Cluster cell: four full 256-node shards, the regime ISSUE 8 exists
/// for.  The sequential-sharded-index-vs-scan floor is unconditional;
/// the parallel-vs-sequential floor needs real cores to mean anything.
const CLUSTER_NODES: usize = 1024;
const CLUSTER_CHAIN: usize = 4096;
const CLUSTER_SEQ_FLOOR: f64 = 3.0;
const CLUSTER_PAR_FLOOR: f64 = 3.0;
const CLUSTER_PAR_MIN_CORES: usize = 8;

const FULL_NODES: &[usize] = &[4, 16, 64];
const FULL_CHAINS: &[usize] = &[64, 512, 4096];
const SMOKE_NODES: &[usize] = &[4, 8];
const SMOKE_CHAINS: &[usize] = &[64, 256];

/// Hybrid ablation cell (ISSUE 9): on the contended row the hybrid plan
/// must beat the best exclusive plan by this factor.  The ablation is
/// deterministic cost-model arithmetic, so the floor is enforced in
/// both full and smoke mode.
const HYBRID_FLOOR: f64 = 1.25;

/// Degraded-mode cell (ISSUE 10): decision throughput against a cluster
/// that has already absorbed a fault — a dead node dropped from the
/// index, or the probe chain half-stranded on a quarter-speed NVMe —
/// must stay within this factor of the matching healthy cell.  Graceful
/// degradation is a scheduling property, not just a liveness one: a
/// fault must not turn Algorithm 1 into a slow path.
const DEGRADED_FLOOR: f64 = 2.0;

struct Cell {
    nodes: usize,
    chain: usize,
    dec_scan: f64,
    dec_index: f64,
    dec_speedup: f64,
    ev_scan: f64,
    ev_index: f64,
    ev_speedup: f64,
}

fn cfg_for(nodes: usize) -> SimConfig {
    SimConfig {
        n_prefill: nodes,
        n_decode: 4,
        scheduling: SchedulingPolicy::KvCacheCentric,
        rejection: RejectionPolicy::None,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: None,
        ..Default::default()
    }
}

/// Warm every node with the probe chain plus filler chains, so the scan
/// pays its worst case (no early miss) against realistically loaded
/// maps.  Chain ids are disjoint from the probe except the probe itself.
/// (The conductor path speaks interned dense ids; the bench fabricates
/// them directly — interning happens once per admission in the sim path
/// and is measured by `hotpath_micro`.)
fn warm_env(cfg: &SimConfig, chain: usize) -> (PrefillPool, Vec<DenseBlockId>) {
    let mut pool = PrefillPool::new(cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    for (node, inst) in pool.instances.iter_mut().enumerate() {
        let _ = inst.pool.admit_chain(&probe, 0.0);
        for f in 0..2u32 {
            let base = 1_000_000 + (node as u32 * 2 + f) * chain as u32;
            let filler: Vec<DenseBlockId> = (base..base + chain as u32).collect();
            let _ = inst.pool.admit_chain(&filler, 0.0);
        }
    }
    (pool, probe)
}

/// Algorithm-1 decisions/sec in SLO-rejecting steady state (the gate
/// fires *after* the full prefill+decode selection, before any
/// mutation), so every iteration prices identical cluster state — and,
/// post-refactor, performs zero heap allocations.
fn bench_decisions(cfg: &SimConfig, chain: usize, iters: usize, use_index: bool) -> f64 {
    let mut cfg = cfg.clone();
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    let perf = PerfModel::paper();
    let (mut pool, probe) = warm_env(&cfg, chain);
    let mut index = use_index.then(|| pool.build_prefix_index());
    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..iters.min(10) {
        run_one(w as f64);
    }
    let t = Instant::now();
    for k in 0..iters {
        run_one(k as f64);
    }
    iters as f64 / t.elapsed().as_secs_f64()
}

/// `allocs_per_decision` (the alloc-audit column): with the
/// `alloc-audit` feature on, the counting global allocator measures
/// heap allocations across a warmed steady-state rejecting loop — the
/// runtime proof of the "allocation-free decision" claim, expected to
/// report exactly 0.  Index-backed pricing, 8 nodes × 256 blocks (the
/// figure is allocation *count*, so cell size is irrelevant).
#[cfg(feature = "alloc-audit")]
fn measure_allocs_per_decision() -> Value {
    let mut cfg = cfg_for(8);
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    let chain = 256usize;
    let perf = PerfModel::paper();
    let (mut pool, probe) = warm_env(&cfg, chain);
    let mut index = Some(pool.build_prefix_index());
    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..64 {
        run_one(w as f64);
    }
    let guard = mooncake::util::alloc_audit::AllocGuard::new();
    let iters = 1_000usize;
    for k in 0..iters {
        run_one(k as f64);
    }
    json::num(guard.count() as f64 / iters as f64)
}

/// Without the feature the column is `null` — schema-stable, and no
/// allocator interposition distorts the throughput numbers.
#[cfg(not(feature = "alloc-audit"))]
fn measure_allocs_per_decision() -> Value {
    Value::Null
}

/// Synthetic chain-sharing trace: `n` requests cycling over 8 base
/// chains of `chain` blocks each, spread over 300 s.  The input length
/// is capped below decode VRAM capacity so every request can finish —
/// the hash chain keeps its full length, which is what the matcher
/// walks (admission caches the whole chain regardless).
fn synth_trace(n: usize, chain: usize) -> Vec<TraceRecord> {
    (0..n)
        .map(|k| {
            let c = (k % 8) as u64;
            TraceRecord {
                timestamp: (k as u64 * 300_000) / n as u64,
                input_length: (chain as u64 * BLOCK_TOKENS).min(1_000_000),
                output_length: 4,
                hash_ids: (c * 10_000_000..c * 10_000_000 + chain as u64).collect(),
            }
        })
        .collect()
}

fn bench_sim_events(cfg: &SimConfig, trace: &[TraceRecord], use_index: bool) -> f64 {
    let mut cfg = cfg.clone();
    cfg.use_prefix_index = use_index;
    cfg.slo = SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 };
    let t = Instant::now();
    let res = sim::run(&cfg, trace, 1.0);
    res.n_events as f64 / t.elapsed().as_secs_f64()
}

/// Congestion-cell decisions/sec: only node 0 holds the probe chain
/// (every other block demoted to its SSD tier) and its NVMe + NIC-tx
/// queues carry deep standing backlogs, so every candidate prices a
/// fetch-from-0 through the contended resource probes — source NVMe,
/// source tx, destination rx (finite rx bandwidth) — in SLO-rejecting
/// steady state ("many nodes staging against one hot source").
fn bench_congested_decisions(nodes: usize, chain: usize, iters: usize, use_index: bool) -> f64 {
    let mut cfg = cfg_for(nodes);
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    cfg.kvcache_balancing_threshold = 1.5;
    cfg.nic_rx_bw = Some(10e9);
    let perf = PerfModel::paper();
    let mut pool = PrefillPool::new(&cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    let _ = pool.instances[0].pool.admit_chain(&probe, 0.0);
    for (k, &b) in probe.iter().enumerate() {
        if k % 2 == 1 {
            let _ = pool.instances[0].pool.demote_block(b, 1.0);
        }
    }
    for (node, inst) in pool.instances.iter_mut().enumerate() {
        for f in 0..2u32 {
            let base = 1_000_000 + (node as u32 * 2 + f) * chain as u32;
            let filler: Vec<DenseBlockId> = (base..base + chain as u32).collect();
            let _ = inst.pool.admit_chain(&filler, 0.0);
        }
    }
    let mut index = use_index.then(|| pool.build_prefix_index());
    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    // Deep standing backlogs on the hot source's devices.
    res.nvme.schedule(0, 0.0, 1_000_000_000_000, 0.0);
    res.nic.schedule(0, 1, 0.0, 1_000_000_000_000);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..iters.min(10) {
        run_one(w as f64);
    }
    let t = Instant::now();
    for k in 0..iters {
        run_one(k as f64);
    }
    iters as f64 / t.elapsed().as_secs_f64()
}

/// Decisions/sec through the index-backed path against a cluster in a
/// chosen fault posture: `kill_last` marks the last node dead and drops
/// its pools from the index through the same `TierDelta` route the
/// sim's `NodeLoss` event uses; `demote_half` strands every other probe
/// block on node 0's SSD tier (so candidate pricing walks the NVMe
/// probe); `nvme_scale` degrades every prefill node's NVMe bandwidth.
/// The healthy baselines pass `(false, ·, 1.0)` so each degraded row is
/// compared against an identically shaped workload.
fn bench_decisions_degraded(
    cfg: &SimConfig,
    chain: usize,
    iters: usize,
    kill_last: bool,
    demote_half: bool,
    nvme_scale: f64,
) -> f64 {
    let mut cfg = cfg.clone();
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    let perf = PerfModel::paper();
    let (mut pool, probe) = warm_env(&cfg, chain);
    if demote_half {
        for (k, &b) in probe.iter().enumerate() {
            if k % 2 == 1 {
                let _ = pool.instances[0].pool.demote_block(b, 1.0);
            }
        }
    }
    let mut index = pool.build_prefix_index();
    if kill_last {
        let dead = cfg.n_prefill - 1;
        pool.instances[dead].alive = false;
        let mut delta = TierDelta::default();
        pool.instances[dead].pool.drop_all_into(&mut delta);
        index.apply(dead, &delta);
    }
    let mut index = Some(index);
    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    if nvme_scale != 1.0 {
        for n in 0..cfg.n_prefill {
            res.nvme.set_scale(n, nvme_scale);
        }
    }
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..iters.min(10) {
        run_one(w as f64);
    }
    let t = Instant::now();
    for k in 0..iters {
        run_one(k as f64);
    }
    iters as f64 / t.elapsed().as_secs_f64()
}

/// Degraded-mode cell (ISSUE 10): two decision-throughput comparisons —
/// one node dead vs healthy, and quarter-speed NVMe under a half-
/// demoted probe vs the same shape at full speed.  Both ratios gate CI
/// at [`DEGRADED_FLOOR`] in full and smoke mode alike.
fn degraded_cell(smoke: bool) -> Value {
    let (nodes, chain) = if smoke { (8, 256) } else { (TARGET_NODES, TARGET_CHAIN) };
    let cfg = cfg_for(nodes);
    let iters = (30_000_000 / (nodes * chain)).clamp(100, 5_000);
    let healthy = bench_decisions_degraded(&cfg, chain, iters, false, false, 1.0);
    let node_loss = bench_decisions_degraded(&cfg, chain, iters, true, false, 1.0);
    let healthy_staged = bench_decisions_degraded(&cfg, chain, iters, false, true, 1.0);
    let nvme_deg = bench_decisions_degraded(&cfg, chain, iters, false, true, 0.25);
    let loss_slowdown = healthy / node_loss;
    let nvme_slowdown = healthy_staged / nvme_deg;

    banner("degraded cell: decision throughput under fault postures");
    let header = ["posture", "healthy dec/s", "degraded dec/s", "slowdown"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    row(&[
        "node loss".into(),
        format!("{healthy:.0}"),
        format!("{node_loss:.0}"),
        format!("{loss_slowdown:.2}x"),
    ]);
    row(&[
        "nvme 25%".into(),
        format!("{healthy_staged:.0}"),
        format!("{nvme_deg:.0}"),
        format!("{nvme_slowdown:.2}x"),
    ]);

    assert!(
        loss_slowdown <= DEGRADED_FLOOR,
        "node-loss decision slowdown {loss_slowdown:.2}x exceeds the {DEGRADED_FLOOR}x floor \
         at {nodes} nodes x {chain} blocks"
    );
    assert!(
        nvme_slowdown <= DEGRADED_FLOOR,
        "degraded-NVMe decision slowdown {nvme_slowdown:.2}x exceeds the {DEGRADED_FLOOR}x \
         floor at {nodes} nodes x {chain} blocks"
    );

    Value::Arr(vec![
        json::obj(vec![
            ("variant", Value::Str("degraded".into())),
            ("posture", Value::Str("node_loss".into())),
            ("nodes", json::num(nodes as f64)),
            ("chain_blocks", json::num(chain as f64)),
            ("decisions_per_sec_healthy", json::num(healthy)),
            ("decisions_per_sec_degraded", json::num(node_loss)),
            ("slowdown", json::num(loss_slowdown)),
            ("max_slowdown", json::num(DEGRADED_FLOOR)),
        ]),
        json::obj(vec![
            ("variant", Value::Str("degraded".into())),
            ("posture", Value::Str("nvme_quarter_speed".into())),
            ("nodes", json::num(nodes as f64)),
            ("chain_blocks", json::num(chain as f64)),
            ("decisions_per_sec_healthy", json::num(healthy_staged)),
            ("decisions_per_sec_degraded", json::num(nvme_deg)),
            ("slowdown", json::num(nvme_slowdown)),
            ("max_slowdown", json::num(DEGRADED_FLOOR)),
        ]),
    ])
}

/// Cluster cell (ISSUE 8): 1024 nodes × 4096 blocks, three decision
/// rows — per-pool scan, sharded index with `sched_workers = 1`, and
/// sharded index with `sched_workers = min(8, cores)`.  Asserts the
/// seq-vs-scan ≥3× floor unconditionally; the par-vs-seq ≥3× floor
/// only when the host has ≥ `CLUSTER_PAR_MIN_CORES` cores (on a 1-core
/// runner thread fan-out is pure overhead and the measurement is
/// informational — the skip is printed and recorded in the row).
fn cluster_cell(smoke: bool) -> Value {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers = cores.min(8).max(2);
    // The scan walks nodes × chain ≈ 4.2M map probes per decision —
    // keep its iteration count small; the index rows are cheap enough
    // for a few thousand.
    let (scan_iters, idx_iters) = if smoke { (30, 500) } else { (100, 2_000) };
    let mut cfg = cfg_for(CLUSTER_NODES);
    let dec_scan = bench_decisions(&cfg, CLUSTER_CHAIN, scan_iters, false);
    let dec_seq = bench_decisions(&cfg, CLUSTER_CHAIN, idx_iters, true);
    cfg.sched_workers = workers;
    let dec_par = bench_decisions(&cfg, CLUSTER_CHAIN, idx_iters, true);
    let seq_speedup = dec_seq / dec_scan;
    let par_speedup = dec_par / dec_seq;
    let par_enforced = cores >= CLUSTER_PAR_MIN_CORES;

    banner("cluster cell: 1024 nodes x 4096 blocks (sharded index + parallel scoring)");
    let header = ["row", "workers", "dec/s", "vs scan", "vs seq"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    row(&["scan".into(), "1".into(), format!("{dec_scan:.0}"), "1.00x".into(), "-".into()]);
    row(&[
        "sharded seq".into(),
        "1".into(),
        format!("{dec_seq:.0}"),
        format!("{seq_speedup:.2}x"),
        "1.00x".into(),
    ]);
    row(&[
        "sharded par".into(),
        workers.to_string(),
        format!("{dec_par:.0}"),
        format!("{:.2}x", dec_par / dec_scan),
        format!("{par_speedup:.2}x"),
    ]);

    assert!(
        seq_speedup >= CLUSTER_SEQ_FLOOR,
        "cluster cell: sharded-index speedup {seq_speedup:.2}x below the \
         {CLUSTER_SEQ_FLOOR}x floor at {CLUSTER_NODES} nodes x {CLUSTER_CHAIN} blocks"
    );
    if par_enforced {
        assert!(
            par_speedup >= CLUSTER_PAR_FLOOR,
            "cluster cell: parallel scoring speedup {par_speedup:.2}x below the \
             {CLUSTER_PAR_FLOOR}x floor with {workers} workers on {cores} cores"
        );
    } else {
        println!(
            "cluster cell: par-vs-seq floor SKIPPED — {cores} core(s) < \
             {CLUSTER_PAR_MIN_CORES}; measured {par_speedup:.2}x is informational only"
        );
    }

    json::obj(vec![
        ("variant", Value::Str(VARIANT.into())),
        ("nodes", json::num(CLUSTER_NODES as f64)),
        ("chain_blocks", json::num(CLUSTER_CHAIN as f64)),
        ("decisions_per_sec_scan", json::num(dec_scan)),
        ("decisions_per_sec_seq", json::num(dec_seq)),
        ("decisions_per_sec_par", json::num(dec_par)),
        ("sched_workers_par", json::num(workers as f64)),
        ("available_cores", json::num(cores as f64)),
        ("seq_vs_scan_speedup", json::num(seq_speedup)),
        ("min_seq_vs_scan", json::num(CLUSTER_SEQ_FLOOR)),
        ("par_vs_seq_speedup", json::num(par_speedup)),
        ("min_par_vs_seq", json::num(CLUSTER_PAR_FLOOR)),
        ("par_floor_enforced", Value::Bool(par_enforced)),
    ])
}

fn run_cell(nodes: usize, chain: usize, n_trace: usize) -> Cell {
    let cfg = cfg_for(nodes);
    // Bound total probe work per side to ~30M node·block visits.
    let iters = (30_000_000 / (nodes * chain)).clamp(100, 5_000);
    let dec_scan = bench_decisions(&cfg, chain, iters, false);
    let dec_index = bench_decisions(&cfg, chain, iters, true);
    let trace = synth_trace(n_trace, chain);
    let ev_scan = bench_sim_events(&cfg, &trace, false);
    let ev_index = bench_sim_events(&cfg, &trace, true);
    Cell {
        nodes,
        chain,
        dec_scan,
        dec_index,
        dec_speedup: dec_index / dec_scan,
        ev_scan,
        ev_index,
        ev_speedup: ev_index / ev_scan,
    }
}

/// Congestion-sweep ablation (§6.2 on the PR 4 knobs): rx bandwidth ×
/// NVMe write bandwidth × the balancing threshold (how aggressively the
/// scheduler forwards prefixes — the replication knob), end to end over
/// a tier-pressure replay whose DRAM tier is far smaller than the
/// working set, so demotion writes, staging reads, fetches, and incast
/// are all live.  Rows land in the same `BENCH_sched.json`.
fn congestion_sweep(smoke: bool) -> Value {
    let (chain, n_req) = if smoke { (64, 40) } else { (256, 150) };
    let trace = synth_trace(n_req, chain);
    let rx_bws: &[Option<f64>] = &[None, Some(10e9)];
    let wr_bws: &[Option<f64>] = &[None, Some(2e9)];
    let thresholds: &[f64] = &[1.5, 4.0];
    banner("congestion sweep: rx-bw x ssd-write-bw x balancing threshold");
    let header = ["rx_bw", "wr_bw", "thresh", "ev/s", "fetches", "rx q-ms", "nvme q-ms", "done"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for &rx in rx_bws {
        for &wr in wr_bws {
            for &th in thresholds {
                let cfg = SimConfig {
                    n_prefill: 8,
                    n_decode: 4,
                    scheduling: SchedulingPolicy::KvCacheCentric,
                    rejection: RejectionPolicy::None,
                    cache_capacity_blocks: Some(chain + chain / 2),
                    ssd_capacity_blocks: None,
                    kvcache_balancing_threshold: th,
                    nic_rx_bw: rx,
                    ssd_write_bw: wr,
                    slo: SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
                    ..Default::default()
                };
                let t = Instant::now();
                let res = sim::run(&cfg, &trace, 1.0);
                let ev_per_sec = res.n_events as f64 / t.elapsed().as_secs_f64();
                let done = res
                    .metrics
                    .iter()
                    .filter(|m| m.outcome == mooncake::metrics::Outcome::Completed)
                    .count();
                let fmt_bw = |b: Option<f64>| match b {
                    None => "inf".to_string(),
                    Some(v) => format!("{:.0}G", v / 1e9),
                };
                row(&[
                    fmt_bw(rx),
                    fmt_bw(wr),
                    format!("{th}"),
                    format!("{ev_per_sec:.0}"),
                    res.conductor.remote_fetches.to_string(),
                    format!("{:.0}", res.resources.nic_rx.queued_ms),
                    format!("{:.0}", res.resources.nvme.queued_ms),
                    done.to_string(),
                ]);
                rows.push(json::obj(vec![
                    ("variant", Value::Str(VARIANT.into())),
                    ("rx_bw", rx.map_or(Value::Null, json::num)),
                    ("ssd_write_bw", wr.map_or(Value::Null, json::num)),
                    ("balancing_threshold", json::num(th)),
                    ("chain_blocks", json::num(chain as f64)),
                    ("requests", json::num(n_req as f64)),
                    ("sim_events_per_sec", json::num(ev_per_sec)),
                    ("remote_fetches", json::num(res.conductor.remote_fetches as f64)),
                    ("demotions", json::num(res.tier.demotions as f64)),
                    ("rx_queued_ms", json::num(res.resources.nic_rx.queued_ms)),
                    ("nvme_queued_ms", json::num(res.resources.nvme.queued_ms)),
                    ("completed", json::num(done as f64)),
                ]));
            }
        }
    }
    Value::Arr(rows)
}

/// Resident-set size in bytes from `/proc/self/statm` (field 2 is RSS
/// in pages; the kernel reports statm in the base 4 KiB page size on
/// every tier-1 target we run on).  `None` off Linux, so the JSON
/// column is schema-stable `null` there — a true OS-level footprint to
/// sit beside the simulator's own `live_peak` proxy.
#[cfg(target_os = "linux")]
fn rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * 4096)
}

#[cfg(not(target_os = "linux"))]
fn rss_bytes() -> Option<u64> {
    None
}

/// Sustained-replay cell: a generated arrival stream driven straight
/// through `sim::run_streaming` — no materialized request vector — so
/// the figure prices the whole streaming path: bounded admission
/// (`max_live_requests`), per-arrival scheduling, and epoch id
/// recycling under an unbounded distinct-block stream.  Every request
/// carries one shared and one never-seen-again block, the worst case
/// for interner growth.
fn sustained_replay(smoke: bool) -> Value {
    let n: u64 = if smoke { 20_000 } else { 500_000 };
    let live_cap = 64usize;
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        cache_capacity_blocks: Some(512),
        ssd_capacity_blocks: Some(512),
        max_live_requests: Some(live_cap),
        interner_epoch_blocks: Some(4_096),
        retain_metrics: false,
        slo: SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let stream = (0..n).map(|i| sim::Request {
        rid: i,
        arrival: i as f64 * 0.05,
        input: 1_024,
        output: 1,
        hash_ids: vec![1, 1_000 + i],
    });
    let t = Instant::now();
    let res = sim::run_streaming(&cfg, stream);
    let secs = t.elapsed().as_secs_f64();
    assert_eq!(res.n_completed + res.n_rejected, n, "streamed requests went missing");
    assert!(res.live_peak <= live_cap, "live cap breached: {}", res.live_peak);
    // True process footprint at end of replay (ISSUE 8 satellite): the
    // `live_peak` proxy counts requests, not bytes — RSS is the figure
    // the "bounded memory" claim is actually about.
    let rss = rss_bytes();
    banner("sustained streaming replay");
    let header = ["requests", "req/s", "ev/s", "live peak", "rss MiB", "epochs", "id space"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    row(&[
        n.to_string(),
        format!("{:.0}", n as f64 / secs),
        format!("{:.0}", res.n_events as f64 / secs),
        res.live_peak.to_string(),
        rss.map_or("-".to_string(), |b| format!("{:.1}", b as f64 / (1024.0 * 1024.0))),
        res.interner_epochs.to_string(),
        res.interner_id_space.to_string(),
    ]);
    json::obj(vec![
        ("variant", Value::Str(VARIANT.into())),
        ("requests", json::num(n as f64)),
        ("live_cap", json::num(live_cap as f64)),
        ("requests_per_sec", json::num(n as f64 / secs)),
        ("sim_events_per_sec", json::num(res.n_events as f64 / secs)),
        ("live_peak", json::num(res.live_peak as f64)),
        ("rss_bytes", rss.map_or(Value::Null, |b| json::num(b as f64))),
        ("completed", json::num(res.n_completed as f64)),
        ("interner_epochs", json::num(res.interner_epochs as f64)),
        ("interner_id_space", json::num(res.interner_id_space as f64)),
    ])
}

/// Hybrid-vs-exclusive prefix-plan ablation (ISSUE 9): price all four
/// plans of Algorithm 1's decision on one fixed cell — a 64-block
/// matched chain, half DRAM-resident and half demoted to SSD, with
/// 4 096 fresh tokens — across NVMe backlog depths, straight from the
/// cost model.  Pure arithmetic over queue probes (no timing noise), so
/// the dominance asserts are deterministic CI gates: the hybrid plan
/// must beat every exclusive plan in every row, and beat the best of
/// them by [`HYBRID_FLOOR`]x on the contended 500 ms-backlog row.
fn hybrid_ablation() -> Value {
    let cfg = SimConfig { n_prefill: 1, n_decode: 1, ..Default::default() };
    assert!(cfg.hybrid, "the ablation prices the default-on fourth branch");
    let perf = PerfModel::paper();
    let pool = PrefillPool::new(&cfg);
    let group = [0usize];
    let (m, dram) = (64usize, 32usize);
    let total = m as u64 * BLOCK_TOKENS + 4_096;
    let positions: Vec<u32> = (dram as u32..m as u32).collect();
    banner("hybrid prefix-plan ablation: plan end-ms vs NVMe backlog");
    let header = ["backlog ms", "pure-dram", "ssd-stage", "recompute", "hybrid", "staged", "gain"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for &(backlog_ms, min_gain) in &[(0.0f64, 1.0), (500.0, HYBRID_FLOOR), (2_000.0, 1.0)] {
        let mut res = Resources::new(&cfg, &perf);
        if backlog_ms > 0.0 {
            let bytes = (backlog_ms / 1e3 * perf.hw.ssd_read_bw) as u64;
            res.nvme.schedule(0, 0.0, bytes, 0.0);
        }
        let exclusive = |reuse: u64, ssd: u64| {
            costmodel::estimate_prefill(
                &perf,
                &cfg,
                &pool,
                &res,
                &group,
                total - reuse * BLOCK_TOKENS,
                reuse * BLOCK_TOKENS,
                ssd * BLOCK_TOKENS,
                None,
                0.0,
            )
        };
        let pure_dram = exclusive(dram as u64, 0);
        let ssd_stage = exclusive(m as u64, (m - dram) as u64);
        let recompute = exclusive(0, 0);
        let (k, j, hybrid) = costmodel::hybrid_split_scan(m, &positions, |k, j| {
            costmodel::estimate_prefill_hybrid(
                &perf,
                &cfg,
                &pool,
                &res,
                &group,
                total - k as u64 * BLOCK_TOKENS,
                k as u64 * BLOCK_TOKENS,
                j as u64 * BLOCK_TOKENS,
                0.0,
            )
        })
        .expect("half the chain sits on the SSD tier");
        let best_excl = pure_dram.end.min(ssd_stage.end).min(recompute.end);
        let gain = best_excl / hybrid.end;
        assert!(
            hybrid.end < best_excl,
            "hybrid plan must dominate at backlog {backlog_ms} ms: {:.0} vs {best_excl:.0}",
            hybrid.end
        );
        assert!(
            gain >= min_gain,
            "hybrid gain {gain:.2}x below the {min_gain}x floor at backlog {backlog_ms} ms"
        );
        row(&[
            format!("{backlog_ms:.0}"),
            format!("{:.0}", pure_dram.end),
            format!("{:.0}", ssd_stage.end),
            format!("{:.0}", recompute.end),
            format!("{:.0}", hybrid.end),
            format!("{j}/{}", m - dram),
            format!("{gain:.2}x"),
        ]);
        rows.push(json::obj(vec![
            ("variant", Value::Str(VARIANT.into())),
            ("chain_blocks", json::num(m as f64)),
            ("dram_blocks", json::num(dram as f64)),
            ("new_tokens", json::num(4_096.0)),
            ("nvme_backlog_ms", json::num(backlog_ms)),
            ("pure_dram_ms", json::num(pure_dram.end)),
            ("ssd_stage_ms", json::num(ssd_stage.end)),
            ("recompute_ms", json::num(recompute.end)),
            ("hybrid_ms", json::num(hybrid.end)),
            ("hybrid_staged_blocks", json::num(j as f64)),
            ("hybrid_reused_blocks", json::num(k as f64)),
            ("dominance_gain", json::num(gain)),
            ("min_gain", json::num(min_gain)),
        ]));
    }
    Value::Arr(rows)
}

/// Cold-start capacity sweep (ISSUE 9): sessions re-arrive after long
/// idle gaps against DRAM tiers smaller than the working set, so the
/// returning prefix has been demoted and Algorithm 1's
/// stage-vs-recompute-vs-hybrid choice runs end to end — the regime the
/// fourth branch exists for.  Grids DRAM capacity x re-arrival gap;
/// schema-stable rows (`hybrid_placements` et al.) land in
/// `BENCH_sched.json`.
fn cold_start_sweep(smoke: bool) -> Value {
    let n_req = if smoke { 150 } else { 500 };
    let dram_caps: &[usize] = &[256, 1_024];
    let gaps: &[f64] = &[120_000.0, 600_000.0];
    banner("cold-start sweep: dram capacity x re-arrival gap");
    let header = ["dram", "gap s", "done", "ttft ms", "ssd loads", "hybrid", "demotions", "hits"];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for &cap in dram_caps {
        for &gap in gaps {
            let trace = gen::generate(&TraceGenConfig {
                n_requests: n_req,
                duration_ms: 1_200_000,
                seed: 0xC01D,
                rearrival_fraction: 0.7,
                mean_rearrival_gap_ms: gap,
                ..Default::default()
            });
            let cfg = SimConfig {
                n_prefill: 4,
                n_decode: 4,
                cache_capacity_blocks: Some(cap),
                ssd_capacity_blocks: Some(100_000),
                demote_after_ms: Some(60_000.0),
                slo: SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
                ..Default::default()
            };
            let res = sim::run(&cfg, &trace, 1.0);
            let rep = res.report(&cfg);
            let done = res
                .metrics
                .iter()
                .filter(|m| m.outcome == mooncake::metrics::Outcome::Completed)
                .count();
            row(&[
                cap.to_string(),
                format!("{:.0}", gap / 1e3),
                done.to_string(),
                format!("{:.0}", rep.ttft_mean),
                res.conductor.ssd_loads.to_string(),
                res.conductor.hybrid_placements.to_string(),
                res.tier.demotions.to_string(),
                res.tier.ssd_hits.to_string(),
            ]);
            rows.push(json::obj(vec![
                ("variant", Value::Str(VARIANT.into())),
                ("dram_blocks", json::num(cap as f64)),
                ("rearrival_gap_ms", json::num(gap)),
                ("requests", json::num(n_req as f64)),
                ("completed", json::num(done as f64)),
                ("ttft_mean_ms", json::num(rep.ttft_mean)),
                ("ssd_loads", json::num(res.conductor.ssd_loads as f64)),
                ("hybrid_placements", json::num(res.conductor.hybrid_placements as f64)),
                ("hybrid_staged_blocks", json::num(res.conductor.hybrid_staged_blocks as f64)),
                ("demotions", json::num(res.tier.demotions as f64)),
                ("ssd_hits", json::num(res.tier.ssd_hits as f64)),
            ]));
        }
    }
    Value::Arr(rows)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner(if smoke {
        "scheduling throughput (smoke): global prefix index vs per-pool scan"
    } else {
        "scheduling throughput: global prefix index vs per-pool scan"
    });
    let (node_counts, chains, n_trace) =
        if smoke { (SMOKE_NODES, SMOKE_CHAINS, 40) } else { (FULL_NODES, FULL_CHAINS, 150) };

    let header = [
        "nodes", "chain", "dec/s scan", "dec/s index", "speedup", "ev/s scan", "ev/s index",
        "speedup",
    ];
    row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let mut cells = Vec::new();
    for &nodes in node_counts {
        for &chain in chains {
            let c = run_cell(nodes, chain, n_trace);
            row(&[
                c.nodes.to_string(),
                c.chain.to_string(),
                format!("{:.0}", c.dec_scan),
                format!("{:.0}", c.dec_index),
                format!("{:.2}x", c.dec_speedup),
                format!("{:.0}", c.ev_scan),
                format!("{:.0}", c.ev_index),
                format!("{:.2}x", c.ev_speedup),
            ]);
            cells.push(c);
        }
    }
    if smoke {
        // CI floor: smoke mode still measures the 64×4096 target cell so
        // the ≥5× index-vs-scan assertion runs on every push.
        let c = run_cell(TARGET_NODES, TARGET_CHAIN, n_trace.min(24));
        row(&[
            format!("{}!", c.nodes),
            c.chain.to_string(),
            format!("{:.0}", c.dec_scan),
            format!("{:.0}", c.dec_index),
            format!("{:.2}x", c.dec_speedup),
            format!("{:.0}", c.ev_scan),
            format!("{:.0}", c.ev_index),
            format!("{:.2}x", c.ev_speedup),
        ]);
        println!("(! = CI floor cell, also run in smoke mode)");
        cells.push(c);
    }

    // Congestion cell on the largest configured size: hot-source
    // contention on every probe of the pricing path, plus an end-to-end
    // finite-rx sim (incast congestion live in the event loop).
    let (cg_nodes, cg_chain) = (*node_counts.last().unwrap(), *chains.last().unwrap());
    let cg_iters = (10_000_000 / (cg_nodes * cg_chain)).clamp(50, 2_000);
    let cg_scan = bench_congested_decisions(cg_nodes, cg_chain, cg_iters, false);
    let cg_index = bench_congested_decisions(cg_nodes, cg_chain, cg_iters, true);
    let mut cg_cfg = cfg_for(cg_nodes);
    cg_cfg.nic_rx_bw = Some(10e9);
    let cg_trace = synth_trace(n_trace, cg_chain);
    let cg_ev_scan = bench_sim_events(&cg_cfg, &cg_trace, false);
    let cg_ev_index = bench_sim_events(&cg_cfg, &cg_trace, true);
    row(&[
        format!("{cg_nodes}*"),
        cg_chain.to_string(),
        format!("{cg_scan:.0}"),
        format!("{cg_index:.0}"),
        format!("{:.2}x", cg_index / cg_scan),
        format!("{cg_ev_scan:.0}"),
        format!("{cg_ev_index:.0}"),
        format!("{:.2}x", cg_ev_index / cg_ev_scan),
    ]);
    println!("(* = congestion cell: hot source with NVMe/tx backlogs, finite rx)");

    // Cluster cell runs in both modes — smoke is what CI executes, and
    // the seq-vs-scan floor must gate every push.
    let cluster = cluster_cell(smoke);

    // Degraded cell (ISSUE 10): fault postures must not slow Algorithm 1
    // past the 2x floor — asserted in both modes.
    let degraded = degraded_cell(smoke);

    let sweep = congestion_sweep(smoke);
    let replay = sustained_replay(smoke);
    // Deterministic cost-model ablation + end-to-end cold-start sweep
    // (ISSUE 9); the ablation's dominance floor gates every push.
    let ablation = hybrid_ablation();
    let cold = cold_start_sweep(smoke);

    let allocs_per_decision = measure_allocs_per_decision();
    println!("allocs_per_decision: {}", json::to_string(&allocs_per_decision));
    if let Some(a) = allocs_per_decision.as_f64() {
        assert_eq!(a, 0.0, "steady-state decision loop allocated ({a} allocs/decision)");
    }

    let target = cells.iter().find(|c| c.nodes == TARGET_NODES && c.chain == TARGET_CHAIN);
    let mut obj = vec![
        ("bench", Value::Str("sched_throughput".into())),
        ("variant", Value::Str(VARIANT.into())),
        ("mode", Value::Str(if smoke { "smoke" } else { "full" }.into())),
        (
            "cells",
            Value::Arr(
                cells
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("variant", Value::Str(VARIANT.into())),
                            ("nodes", json::num(c.nodes as f64)),
                            ("chain_blocks", json::num(c.chain as f64)),
                            ("decisions_per_sec_scan", json::num(c.dec_scan)),
                            ("decisions_per_sec_index", json::num(c.dec_index)),
                            ("decision_speedup", json::num(c.dec_speedup)),
                            ("sim_events_per_sec_scan", json::num(c.ev_scan)),
                            ("sim_events_per_sec_index", json::num(c.ev_index)),
                            ("sim_event_speedup", json::num(c.ev_speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    obj.push((
        "congestion",
        json::obj(vec![
            ("variant", Value::Str(VARIANT.into())),
            ("nodes", json::num(cg_nodes as f64)),
            ("chain_blocks", json::num(cg_chain as f64)),
            ("decisions_per_sec_scan", json::num(cg_scan)),
            ("decisions_per_sec_index", json::num(cg_index)),
            ("decision_speedup", json::num(cg_index / cg_scan)),
            ("sim_events_per_sec_scan", json::num(cg_ev_scan)),
            ("sim_events_per_sec_index", json::num(cg_ev_index)),
            ("sim_event_speedup", json::num(cg_ev_index / cg_ev_scan)),
        ]),
    ));
    obj.push(("cluster", cluster));
    obj.push(("degraded", degraded));
    obj.push(("congestion_sweep", sweep));
    obj.push(("sustained_replay", replay));
    obj.push(("hybrid_ablation", ablation));
    obj.push(("cold_start_sweep", cold));
    // The runtime no-alloc audit (null unless built with `alloc-audit`).
    obj.push(("allocs_per_decision", allocs_per_decision));
    if let Some(c) = target {
        obj.push((
            "target",
            json::obj(vec![
                ("nodes", json::num(TARGET_NODES as f64)),
                ("chain_blocks", json::num(TARGET_CHAIN as f64)),
                ("min_speedup", json::num(TARGET_SPEEDUP)),
                ("decision_speedup", json::num(c.dec_speedup)),
                ("pass", Value::Bool(c.dec_speedup >= TARGET_SPEEDUP)),
            ]),
        ));
    }
    std::fs::write("BENCH_sched.json", json::to_string(&json::obj(obj)) + "\n")
        .expect("write BENCH_sched.json");
    println!("\nwrote BENCH_sched.json");

    let c = target.expect("the 64x4096 target cell runs in both full and smoke mode");
    assert!(
        c.dec_speedup >= TARGET_SPEEDUP,
        "64-node x 4096-block scheduling speedup {:.2}x below the {TARGET_SPEEDUP}x target",
        c.dec_speedup
    );
    println!(
        "target cell {TARGET_NODES} nodes x {TARGET_CHAIN} blocks: {:.2}x (>= {TARGET_SPEEDUP}x)",
        c.dec_speedup
    );
}
