//! Minimal benchmark harness (criterion is unavailable offline — see
//! DESIGN.md).  Provides wall-clock timing of closures with warmup and
//! simple statistics, plus table printing helpers shared by the
//! per-figure bench binaries under `rust/benches/`.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    BenchStats {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ms: samples.iter().cloned().fold(0.0, f64::max),
    }
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:40} {:>10.3} ms/iter  (min {:.3}, max {:.3}, n={})",
            self.name, self.mean_ms, self.min_ms, self.max_ms, self.iters
        );
    }
}

/// Print a header banner for a figure/table reproduction.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one row of a markdown-ish table.
pub fn row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

pub fn fmt(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("noop-ish", 1, 5, || {
            let v: Vec<u64> = (0..1000).collect();
            std::hint::black_box(v.iter().sum::<u64>());
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_ms >= 0.0);
        assert!(s.min_ms <= s.mean_ms && s.mean_ms <= s.max_ms + 1e-12);
    }
}
