//! Golden-fixture and error-path tests for the streaming trace loader
//! (`trace::replay`).  `rust/tests/data/mooncake_trace.jsonl` pins the
//! published schema: an FNV-1a content hash over every parsed field
//! catches silent parser drift, and each malformed-input case asserts
//! its `file:line`-tagged diagnostic.

use std::fs::File;
use std::io::Write;
use std::path::PathBuf;

use mooncake::config::SimConfig;
use mooncake::sim;
use mooncake::trace::replay::{ReplayReader, ReplayStream};
use mooncake::trace::{jsonl, TraceRecord};

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/mooncake_trace.jsonl");
const FIXTURE_GZ: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/mooncake_trace.jsonl.gz");

/// FNV-1a fold over every field of every record (the same construction
/// as `kvcache::chain_hashes`): the pin breaks iff parsed content
/// drifts, not merely the byte count.
fn fnv_records(recs: &[TraceRecord]) -> u64 {
    fn fold(mut h: u64, x: u64) -> u64 {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for r in recs {
        h = fold(h, r.timestamp);
        h = fold(h, r.input_length);
        h = fold(h, r.output_length);
        h = fold(h, r.hash_ids.len() as u64);
        for &id in &r.hash_ids {
            h = fold(h, id);
        }
    }
    h
}

fn write_trace(name: &str, body: &str) -> PathBuf {
    let path = std::env::temp_dir().join(name);
    let mut f = File::create(&path).unwrap();
    f.write_all(body.as_bytes()).unwrap();
    path
}

#[test]
fn golden_fixture_parse_is_fnv_pinned() {
    let recs: Vec<TraceRecord> =
        ReplayReader::open(FIXTURE).unwrap().collect::<anyhow::Result<_>>().unwrap();
    assert_eq!(recs.len(), 8);
    assert_eq!(
        fnv_records(&recs),
        0xac17_4157_1860_3447,
        "fixture parse drifted — recompute the pin only for a deliberate schema change"
    );
    // Streaming parse equals the batch loader on the same (already
    // time-ordered) file, record for record.
    assert_eq!(recs, jsonl::load(FIXTURE).unwrap());
}

#[test]
fn fixture_streams_time_ordered_requests_with_rate_scaling() {
    let reqs: Vec<sim::Request> =
        ReplayStream::open(FIXTURE, 2.0).unwrap().collect::<anyhow::Result<_>>().unwrap();
    assert_eq!(reqs.len(), 8);
    for (i, r) in reqs.iter().enumerate() {
        assert_eq!(r.rid as usize, i, "rids are sequential in arrival order");
    }
    let arrivals: Vec<f64> = reqs.iter().map(|r| r.arrival).collect();
    assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "stream must be time-ordered");
    assert_eq!(arrivals[0], 0.0);
    // rate = 2.0 compresses the fixture's final t=3000 to 1500.
    assert_eq!(*arrivals.last().unwrap(), 1500.0);
}

#[test]
fn fixture_replay_matches_batch_simulation() {
    let cfg = SimConfig { n_prefill: 2, n_decode: 2, ..Default::default() };
    let batch = sim::run(&cfg, &jsonl::load(FIXTURE).unwrap(), 1.0);
    let stream =
        sim::run_streaming(&cfg, ReplayStream::open(FIXTURE, 1.0).unwrap().map(|r| r.unwrap()));
    assert_eq!(batch.n_events, stream.n_events);
    assert_eq!(batch.n_completed, stream.n_completed);
    assert_eq!(batch.decode_tokens_out, stream.decode_tokens_out);
    assert_eq!(batch.wall_ms.to_bits(), stream.wall_ms.to_bits());
}

/// The committed `.gz` fixture (produced by `gzip -9 -n`, dynamic
/// Huffman) parses to exactly the same records as the plain file, FNV
/// pin included — the gzip path is a pure transport change.
#[test]
fn gzipped_fixture_matches_plain_and_fnv_pin() {
    let plain: Vec<TraceRecord> =
        ReplayReader::open(FIXTURE).unwrap().collect::<anyhow::Result<_>>().unwrap();
    let gz: Vec<TraceRecord> =
        ReplayReader::open(FIXTURE_GZ).unwrap().collect::<anyhow::Result<_>>().unwrap();
    assert_eq!(gz, plain, "gzipped fixture must parse to the plain fixture's records");
    assert_eq!(fnv_records(&gz), 0xac17_4157_1860_3447);
    // The batch loader shares the sniff.
    assert_eq!(jsonl::load(FIXTURE_GZ).unwrap(), plain);
}

/// Detection is by content (the 0x1F 0x8B magic), not filename: gzip
/// bytes under a `.jsonl` name and plain text under a `.gz` name both
/// replay.
#[test]
fn gzip_detection_is_by_content_not_extension() {
    let misnamed_gz = std::env::temp_dir().join("loader_actually_gzip.jsonl");
    std::fs::copy(FIXTURE_GZ, &misnamed_gz).unwrap();
    let a: Vec<TraceRecord> =
        ReplayReader::open(&misnamed_gz).unwrap().collect::<anyhow::Result<_>>().unwrap();
    assert_eq!(a.len(), 8);

    let misnamed_plain = std::env::temp_dir().join("loader_actually_plain.jsonl.gz");
    std::fs::copy(FIXTURE, &misnamed_plain).unwrap();
    let b: Vec<TraceRecord> =
        ReplayReader::open(&misnamed_plain).unwrap().collect::<anyhow::Result<_>>().unwrap();
    assert_eq!(b, a);
    std::fs::remove_file(misnamed_gz).ok();
    std::fs::remove_file(misnamed_plain).ok();
}

/// A corrupt gzip trailer surfaces as a loader error after the decoded
/// records — never as silent truncation.
#[test]
fn corrupt_gzip_crc_is_a_loader_error() {
    let mut bytes = std::fs::read(FIXTURE_GZ).unwrap();
    let n = bytes.len();
    bytes[n - 5] ^= 0xFF; // trailer = 4 CRC bytes + 4 ISIZE bytes
    let path = std::env::temp_dir().join("loader_bad_crc.jsonl.gz");
    std::fs::write(&path, &bytes).unwrap();
    let results: Vec<anyhow::Result<TraceRecord>> = ReplayReader::open(&path).unwrap().collect();
    let err = results.last().unwrap().as_ref().unwrap_err().to_string();
    assert!(err.contains("CRC-32 mismatch"), "wrong diagnostic: {err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn bad_json_line_is_tagged_with_file_and_line() {
    let path = write_trace(
        "loader_bad_json.jsonl",
        concat!(
            r#"{"timestamp": 0, "input_length": 10, "output_length": 1, "hash_ids": []}"#,
            "\n",
            "{not json at all\n",
        ),
    );
    let mut r = ReplayReader::open(&path).unwrap();
    assert!(r.next().unwrap().is_ok());
    let err = r.next().unwrap().unwrap_err().to_string();
    let want = format!("{}:2:", path.display());
    assert!(err.starts_with(&want), "missing file:line tag: {err}");
    assert!(err.contains("bad trace line"), "wrong diagnostic: {err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn missing_field_is_tagged_with_file_and_line() {
    let path = write_trace(
        "loader_missing_field.jsonl",
        concat!(r#"{"input_length": 10, "output_length": 1, "hash_ids": []}"#, "\n"),
    );
    let err = ReplayReader::open(&path).unwrap().next().unwrap().unwrap_err().to_string();
    let want = format!("{}:1:", path.display());
    assert!(err.starts_with(&want), "missing file:line tag: {err}");
    assert!(err.contains("missing field timestamp"), "wrong diagnostic: {err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn non_monotone_timestamp_is_a_loader_error_not_a_reorder() {
    let path = write_trace(
        "loader_non_monotone.jsonl",
        concat!(
            r#"{"timestamp": 500, "input_length": 10, "output_length": 1, "hash_ids": [1]}"#,
            "\n",
            r#"{"timestamp": 400, "input_length": 10, "output_length": 1, "hash_ids": [1]}"#,
            "\n",
        ),
    );
    let mut r = ReplayReader::open(&path).unwrap();
    assert!(r.next().unwrap().is_ok());
    let err = r.next().unwrap().unwrap_err().to_string();
    let want = format!("{}:2:", path.display());
    assert!(err.starts_with(&want), "missing file:line tag: {err}");
    assert!(err.contains("non-monotone timestamp 400 after 500"), "wrong diagnostic: {err}");
    // The batch loader accepts the same file because it sorts; the
    // streaming loader cannot sort, so it must refuse loudly instead.
    assert_eq!(jsonl::load(&path).unwrap().len(), 2);
    std::fs::remove_file(path).ok();
}

#[test]
fn blank_lines_are_skipped_but_count_in_diagnostics() {
    let path = write_trace(
        "loader_blank_lines.jsonl",
        concat!(
            r#"{"timestamp": 0, "input_length": 10, "output_length": 1, "hash_ids": []}"#,
            "\n\n\n",
            "garbage\n",
        ),
    );
    let mut r = ReplayReader::open(&path).unwrap();
    assert!(r.next().unwrap().is_ok());
    let err = r.next().unwrap().unwrap_err().to_string();
    assert!(err.contains(":4:"), "diagnostics must count physical lines: {err}");
    std::fs::remove_file(path).ok();
}
