//! Per-node contended-bandwidth resource queues — the one primitive
//! behind every timed device in the cluster.
//!
//! Mooncake's §6.1 congestion warning ("high demand on the KVCache
//! server can lead to network congestion, prolonging the waiting time")
//! is not NIC-specific: an NVMe device staging several prefixes at once
//! serializes exactly the way a NIC serializing several transfers does.
//! [`BwQueue`] models that shape once — a per-node FIFO whose ops pay a
//! fixed setup latency plus `bytes / bandwidth` serialization — and the
//! cluster instantiates **three banks per node**:
//!
//! * **NIC-tx** — transfers *out of* a node (the original `Messenger`
//!   queue);
//! * **NIC-rx** — transfers *into* a node: a transfer completes at the
//!   max of its source-tx and destination-rx completion, so fan-in onto
//!   one hot node (incast) finally congests;
//! * **NVMe** — SSD staging reads *and* demotion writes share the
//!   device.
//!
//! The contract that makes the unified cost model work: for any op,
//! [`BwQueue::estimate_done`] (read-only) returns **bit-for-bit** the
//! completion time [`BwQueue::schedule`] (mutating) would produce from
//! the same state — so Conductor's TTFT estimates and the simulator's
//! execution cannot drift (`rust/tests/proptest_invariants.rs` hammers
//! the property under arbitrary op interleavings).

use crate::config::SimConfig;
use crate::messenger::Messenger;
use crate::model::PerfModel;
use crate::trace::BLOCK_TOKENS;
use crate::TimeMs;

/// One scheduled queue occupation (a transfer, a staging read, a
/// demotion write).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Op {
    pub start: TimeMs,
    pub end: TimeMs,
    pub bytes: u64,
}

/// A per-node FIFO bandwidth queue: each op occupies its node's device
/// for `latency + setup + bytes/bw` and queues behind every earlier op
/// on the same node.  `estimate_done` is the read-only probe the cost
/// model plans with; `schedule` is the mutating reservation execution
/// commits; `backlog_ms` is the congestion signal replication decisions
/// read.
#[derive(Debug)]
pub struct BwQueue {
    /// Serialization bandwidth, B/ms (`f64::INFINITY` = the device never
    /// serializes — ops cost only their latency/setup).
    bw_per_ms: f64,
    /// Fixed per-op setup latency, ms.
    latency_ms: f64,
    /// Each node's device is busy until this time.
    busy_until: Vec<TimeMs>,
    /// Per-node bandwidth multiplier (fault injection: a degraded device
    /// runs at `scale × nominal`).  1.0 everywhere by default — and
    /// `x * 1.0` is bit-identical to `x` in IEEE arithmetic (including
    /// `bw_per_ms = ∞`), so healthy runs are unchanged bit-for-bit.
    /// Changing a node's scale mid-run leaves `busy_until` (and any
    /// caller-side booked windows) untouched: already-reserved ops keep
    /// the completion times they were promised, only *future* ops pay
    /// the new rate — which is exactly what keeps estimate == actual
    /// across the change.
    scale: Vec<f64>,
    pub total_bytes: u64,
    pub n_ops: u64,
    /// Total time ops spent queued behind earlier ones (congestion).
    pub queued_ms: f64,
    /// Total device occupation scheduled (the utilization numerator).
    pub busy_ms: f64,
}

impl BwQueue {
    /// `n_nodes` devices at `bw_bytes_per_sec` with `latency_ms` setup
    /// cost per op.
    pub fn new(n_nodes: usize, bw_bytes_per_sec: f64, latency_ms: f64) -> Self {
        BwQueue {
            bw_per_ms: bw_bytes_per_sec / 1e3,
            latency_ms,
            busy_until: vec![0.0; n_nodes],
            scale: vec![1.0; n_nodes],
            total_bytes: 0,
            n_ops: 0,
            queued_ms: 0.0,
            busy_ms: 0.0,
        }
    }

    /// Device occupation of one op on `node`: setup latencies plus
    /// bandwidth serialization at the node's current (possibly degraded)
    /// rate.  `setup_ms` carries op-specific setup on top of the bank's
    /// fixed latency (e.g. the NVMe per-block IOPS term).
    pub fn serialize_ms(&self, node: usize, bytes: u64, setup_ms: f64) -> f64 {
        self.latency_ms + setup_ms + bytes as f64 / (self.bw_per_ms * self.scale[node])
    }

    /// Set `node`'s bandwidth multiplier (fault injection).  Existing
    /// reservations keep their completion times; only ops priced after
    /// this call see the new rate.
    pub fn set_scale(&mut self, node: usize, factor: f64) {
        self.scale[node] = factor;
    }

    /// `node`'s current bandwidth multiplier (1.0 = healthy).
    pub fn scale_of(&self, node: usize) -> f64 {
        self.scale[node]
    }

    /// Absolute completion time if an op of `bytes` were scheduled on
    /// `node` now — **bit-for-bit** what [`Self::schedule`] would
    /// return.  Read-only.
    // lint: hot
    #[must_use = "a discarded estimate means the probe's cost never reached the decision"]
    pub fn estimate_done(&self, node: usize, now: TimeMs, bytes: u64, setup_ms: f64) -> TimeMs {
        self.estimate_done_dur(node, now, self.serialize_ms(node, bytes, setup_ms))
    }

    /// Completion delay (ms from `now`) of the same probe.
    pub fn estimate_ms(&self, node: usize, now: TimeMs, bytes: u64, setup_ms: f64) -> f64 {
        self.estimate_done(node, now, bytes, setup_ms) - now
    }

    /// Read-only probe for an op whose duration the caller computed (an
    /// op at a non-default rate, e.g. an NVMe *write* on the read-bw
    /// bank).
    #[must_use = "a discarded estimate means the probe's cost never reached the decision"]
    pub fn estimate_done_dur(&self, node: usize, now: TimeMs, dur_ms: f64) -> TimeMs {
        self.busy_until[node].max(now) + dur_ms
    }

    /// Enqueue an op of `bytes` on `node`; returns its (start, end).
    pub fn schedule(&mut self, node: usize, now: TimeMs, bytes: u64, setup_ms: f64) -> Op {
        let dur = self.serialize_ms(node, bytes, setup_ms);
        self.schedule_dur(node, now, dur, bytes)
    }

    /// Enqueue an op with a caller-computed duration.
    pub fn schedule_dur(&mut self, node: usize, now: TimeMs, dur_ms: f64, bytes: u64) -> Op {
        let start = self.busy_until[node].max(now);
        let end = start + dur_ms;
        self.queued_ms += start - now;
        self.busy_ms += dur_ms;
        self.busy_until[node] = end;
        self.total_bytes += bytes;
        self.n_ops += 1;
        Op { start, end, bytes }
    }

    /// Current queue depth of a node in ms (the congestion signal for
    /// replication decisions).
    pub fn backlog_ms(&self, node: usize, now: TimeMs) -> f64 {
        (self.busy_until[node] - now).max(0.0)
    }

    /// When the node's device drains (absolute).
    pub fn free_at(&self, node: usize) -> TimeMs {
        self.busy_until[node]
    }

    pub fn n_nodes(&self) -> usize {
        self.busy_until.len()
    }

    pub fn stats(&self) -> BankStats {
        BankStats {
            n_ops: self.n_ops,
            total_bytes: self.total_bytes,
            queued_ms: self.queued_ms,
            busy_ms: self.busy_ms,
        }
    }
}

/// Aggregate counters of one resource bank over a run.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct BankStats {
    pub n_ops: u64,
    pub total_bytes: u64,
    /// Total time ops waited behind earlier ops (the congestion cost).
    pub queued_ms: f64,
    /// Total device occupation scheduled.
    pub busy_ms: f64,
}

impl BankStats {
    /// Mean device utilization over `n_nodes` devices for `wall_ms`.
    pub fn utilization(&self, wall_ms: f64, n_nodes: usize) -> f64 {
        if wall_ms <= 0.0 || n_nodes == 0 {
            0.0
        } else {
            self.busy_ms / (wall_ms * n_nodes as f64)
        }
    }
}

/// Per-resource counters of a run (`SimResult::resources`,
/// `RunReport::resources`).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ResourceStats {
    pub nic_tx: BankStats,
    pub nic_rx: BankStats,
    pub nvme: BankStats,
}

/// The cluster's resource banks: the NIC tx/rx pair (wrapped by
/// [`Messenger`]) and the per-node NVMe queue.  All banks cover
/// `n_prefill + n_decode` nodes (prefill nodes first, matching the
/// instance numbering everywhere else).
#[derive(Debug)]
pub struct Resources {
    pub nic: Messenger,
    pub nvme: BwQueue,
    /// NVMe write bandwidth, B/ms.  Infinite (the default) means
    /// demotion writes are free and untracked — the pre-queue behavior.
    ssd_write_per_ms: f64,
}

impl Resources {
    pub fn new(cfg: &SimConfig, perf: &PerfModel) -> Self {
        let n = cfg.n_prefill + cfg.n_decode;
        Resources {
            nic: Messenger::new(
                n,
                perf.hw.rdma_bw,
                cfg.nic_rx_bw.unwrap_or(f64::INFINITY),
                perf.hw.transfer_latency_ms,
            ),
            nvme: BwQueue::new(n, perf.hw.ssd_read_bw, 0.0),
            ssd_write_per_ms: cfg.ssd_write_bw.unwrap_or(f64::INFINITY) / 1e3,
        }
    }

    /// Charge `n_blocks` of demotion writes to `node`'s NVMe queue —
    /// writes share the device with staging reads, so a demotion burst
    /// delays the next prefix staging.  Sequential writes pay bandwidth
    /// only (no per-block IOPS term).  With infinite write bandwidth
    /// (the default) demotion stays free: no op is recorded at all, so
    /// default runs are bit-identical to the pre-queue model.
    pub fn schedule_demote_writes(
        &mut self,
        perf: &PerfModel,
        node: usize,
        now: TimeMs,
        n_blocks: usize,
    ) -> Option<Op> {
        if n_blocks == 0 || self.ssd_write_per_ms.is_infinite() {
            return None;
        }
        let bytes = n_blocks as u64 * BLOCK_TOKENS * perf.model.kv_bytes_per_token();
        // Writes share the (possibly degraded) device with staging reads.
        let dur = bytes as f64 / (self.ssd_write_per_ms * self.nvme.scale_of(node));
        Some(self.nvme.schedule_dur(node, now, dur, bytes))
    }

    pub fn stats(&self) -> ResourceStats {
        ResourceStats {
            nic_tx: self.nic.tx.stats(),
            nic_rx: self.nic.rx.stats(),
            nvme: self.nvme.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q() -> BwQueue {
        // 100 GB/s, 1 ms setup, 4 nodes — the Messenger NIC shape.
        BwQueue::new(4, 100e9, 1.0)
    }

    #[test]
    fn serialize_matches_pre_refactor_messenger_formula() {
        // The formula pin of the refactor: `latency + bytes / (bw/1e3)`
        // exactly, so a BwQueue-backed Messenger times transfers
        // bit-for-bit like the pre-refactor one.
        let q = q();
        let bytes = 5_242_880_000u64;
        let want = 1.0 + bytes as f64 / (100e9 / 1e3);
        assert_eq!(q.serialize_ms(0, bytes, 0.0).to_bits(), want.to_bits());
    }

    #[test]
    fn degraded_scale_slows_future_ops_but_honors_reservations() {
        let mut q = q();
        // Healthy scale is a bit-exact no-op on the formula pin.
        let bytes = 1_000_000_000u64;
        let healthy = q.serialize_ms(0, bytes, 0.0);
        assert_eq!(healthy.to_bits(), (1.0 + bytes as f64 / 1e8).to_bits());
        // Reserve an op at full speed, then degrade the device to 25%.
        let before = q.schedule(0, 0.0, bytes, 0.0);
        q.set_scale(0, 0.25);
        assert_eq!(q.scale_of(0), 0.25);
        // The reserved op keeps its window; the next op starts where the
        // reservation promised and pays 4× the serialization.
        let est = q.estimate_done(0, 0.0, bytes, 0.0);
        let after = q.schedule(0, 0.0, bytes, 0.0);
        assert_eq!(est.to_bits(), after.end.to_bits(), "estimate == schedule under degrade");
        assert_eq!(after.start.to_bits(), before.end.to_bits());
        assert!((after.end - after.start - (1.0 + 4.0 * bytes as f64 / 1e8)).abs() < 1e-9);
        // Restoring the scale restores the healthy rate for future ops.
        q.set_scale(0, 1.0);
        assert_eq!(q.serialize_ms(0, bytes, 0.0).to_bits(), healthy.to_bits());
        // Other nodes never saw the degrade.
        assert_eq!(q.serialize_ms(1, bytes, 0.0).to_bits(), healthy.to_bits());
    }

    #[test]
    fn fifo_serializes_per_node_only() {
        let mut q = q();
        let a = q.schedule(0, 0.0, 1_000_000_000, 0.0);
        let b = q.schedule(0, 0.0, 1_000_000_000, 0.0);
        assert_eq!(b.start, a.end);
        assert!(q.queued_ms > 0.0);
        let c = q.schedule(1, 0.0, 1_000_000_000, 0.0);
        assert_eq!(c.start, 0.0);
        assert_eq!(q.n_ops, 3);
        assert_eq!(q.total_bytes, 3_000_000_000);
    }

    #[test]
    fn estimate_equals_schedule_bit_for_bit() {
        let mut q = q();
        q.schedule(2, 0.0, 2_000_000_000, 0.0);
        let est = q.estimate_done(2, 5.0, 1_000_000_000, 0.25);
        let op = q.schedule(2, 5.0, 1_000_000_000, 0.25);
        assert_eq!(est.to_bits(), op.end.to_bits());
        // And the duration form.
        let est = q.estimate_done_dur(2, 7.0, 42.0);
        let op = q.schedule_dur(2, 7.0, 42.0, 10);
        assert_eq!(est.to_bits(), op.end.to_bits());
    }

    #[test]
    fn backlog_decays_and_busy_accumulates() {
        let mut q = q();
        q.schedule(0, 0.0, 10_000_000_000, 0.0); // 100 ms + 1 ms setup
        assert!(q.backlog_ms(0, 0.0) > 100.0);
        assert!(q.backlog_ms(0, 50.0) < q.backlog_ms(0, 0.0));
        assert_eq!(q.backlog_ms(0, 1_000.0), 0.0);
        assert!((q.busy_ms - 101.0).abs() < 1e-6);
        let s = q.stats();
        assert!((s.utilization(1_010.0, 4) - 101.0 / 4_040.0).abs() < 1e-12);
    }

    #[test]
    fn infinite_bandwidth_ops_never_occupy() {
        let mut q = BwQueue::new(2, f64::INFINITY, 0.0);
        let a = q.schedule(0, 5.0, u64::MAX, 0.0);
        assert_eq!(a.start, 5.0);
        assert_eq!(a.end, 5.0);
        // A later op sees no backlog.
        let b = q.schedule(0, 5.0, 1, 0.0);
        assert_eq!(b.start, 5.0);
        assert_eq!(q.backlog_ms(0, 5.0), 0.0);
    }

    #[test]
    fn setup_term_rides_on_top_of_bandwidth() {
        let q = BwQueue::new(1, 3e9, 0.0); // the NVMe read shape
        let bw_only = q.serialize_ms(0, 3_000_000, 0.0);
        assert!((bw_only - 1.0).abs() < 1e-9);
        let with_iops = q.serialize_ms(0, 3_000_000, 0.05);
        assert!((with_iops - 1.05).abs() < 1e-9);
    }

    #[test]
    fn demote_writes_share_the_nvme_queue() {
        let cfg = SimConfig {
            ssd_write_bw: Some(2e9),
            ..SimConfig::default()
        };
        let perf = PerfModel::paper();
        let mut res = Resources::new(&cfg, &perf);
        let w = res.schedule_demote_writes(&perf, 0, 0.0, 4).unwrap();
        assert!(w.end > 0.0);
        // A staging read on the same node queues behind the write...
        let r = res.nvme.schedule(0, 0.0, 1_000_000, 0.0);
        assert_eq!(r.start, w.end);
        // ...and an infinite-write-bw config records nothing at all.
        let mut free = Resources::new(&SimConfig::default(), &perf);
        assert!(free.schedule_demote_writes(&perf, 0, 0.0, 4).is_none());
        assert_eq!(free.nvme.n_ops, 0);
    }

    #[test]
    fn resources_default_knobs_are_infinite() {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let mut res = Resources::new(&cfg, &perf);
        // Default rx bandwidth is infinite: a transfer's completion is
        // exactly the tx side, and incast cannot congest.
        let t = res.nic.schedule(0, 1, 0.0, 1_000_000_000);
        let u = res.nic.schedule(2, 1, 0.0, 1_000_000_000);
        assert_eq!(t.end.to_bits(), u.end.to_bits());
        assert_eq!(res.nic.rx.backlog_ms(1, 0.0), 0.0);
    }
}
