//! # Mooncake — KVCache-centric disaggregated LLM serving (reproduction)
//!
//! This crate reimplements the system described in *"Mooncake: A
//! KVCache-centric Disaggregated Architecture for LLM Serving"* (Qin et
//! al., Moonshot AI / Tsinghua, 2024) as the Layer-3 Rust coordinator of a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * [`conductor`] — the global scheduler (Algorithm 1): cache-aware
//!   prefill instance selection, decode instance selection, SLO-gated
//!   admission, and heuristic hot-spot KVCache migration (§6).
//! * [`costmodel`] — the unified cost model: the single source of timing
//!   truth consumed by both Conductor's TTFT estimates and the
//!   simulator's event-driven prefill executor.
//! * [`kvcache`] — the disaggregated, paged, prefix-hashed KVCache pool
//!   with pluggable eviction (LRU / LFU / LengthAware), a global
//!   block-location registry (§3, §4.2), and the interning boundary
//!   that maps trace-level block hashes to the dense scheduler-internal
//!   ids every hot structure keys on.
//! * [`resource`] — the per-node contended-bandwidth queues (generic
//!   [`resource::BwQueue`]) instantiated as three banks per node: NIC-tx,
//!   NIC-rx (incast), and NVMe (staging reads + demotion writes share
//!   the device).  Every device time in the system flows through them.
//! * [`messenger`] — the (GPUDirect-)RDMA transfer engine model, a thin
//!   wrapper over the NIC tx/rx banks: bandwidth sharing, congestion
//!   (§3), incast.
//! * [`prefill`] / [`decode`] — the disaggregated instance pools: chunked
//!   pipeline parallelism + layer-wise prefill (§5), continuous-batching
//!   decode (§3).
//! * [`overload`] — overload-oriented scheduling: early rejection and
//!   prediction-based early rejection (§7).
//! * [`faults`] — deterministic scripted fault injection (node loss,
//!   device degradation) driving the degraded-mode scheduling scenarios.
//! * [`baseline`] — a vLLM-like *coupled* continuous-batching engine used
//!   as the paper's comparison system (§8).
//! * [`sim`] — the discrete-event cluster simulator that replays traces
//!   through either architecture at paper scale (dummy LLaMA2-70B on
//!   8×A800 nodes, modeled analytically by [`model`]).
//! * [`runtime`] / [`engine`] — the *live* path: load AOT HLO-text
//!   artifacts of the small dummy model (JAX + Pallas, compiled once at
//!   build time) into a PJRT CPU client and actually serve batched
//!   requests end-to-end. Python never runs on the request path.
//! * [`trace`] — the open-source Mooncake trace schema (`timestamp`,
//!   `input_length`, `output_length`, `hash_ids`), a statistical
//!   generator calibrated to the published trace features, and analyzers.
//!
//! See `DESIGN.md` for the paper→module inventory, the cost-model /
//! event-driven-prefill architecture, and the experiment index;
//! `CHANGES.md` tracks what each PR added.

pub mod baseline;
pub mod bench_util;
pub mod conductor;
pub mod config;
pub mod costmodel;
pub mod decode;
pub mod engine;
pub mod faults;
pub mod kvcache;
pub mod messenger;
pub mod metrics;
pub mod model;
pub mod overload;
pub mod prefill;
pub mod resource;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod verify;

/// Milliseconds since trace start — the simulator's clock unit.
pub type TimeMs = f64;

/// Unique request id.
pub type RequestId = u64;

/// Globally unique KVCache block id (a remapped prefix hash, as in the
/// published trace's `hash_ids` field).
pub type BlockId = u64;

/// Instance identifier within a pool.
pub type InstanceId = usize;
