//! Cluster / scheduling / SLO configuration for simulations and the live
//! engine.  Every §8 experiment is a point in this config space.

use crate::faults::FaultPlan;
use crate::kvcache::PolicyKind;
use crate::verify::Paranoia;

/// Per-node hardware override — the heterogeneity knob.  The cost model
/// already prices per-node speeds; this is the config-layer way to say
/// "node 3 is an H800 box with half the DRAM".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeOverride {
    /// Which prefill node this override applies to.
    pub node: usize,
    /// GPU-generation speed multiplier relative to the baseline A800
    /// node (1.0 = baseline; an H800 node computes prefill ~2.9× faster).
    /// Execution *and* estimation divide the nominal prefill makespan by
    /// the group's min speed, so estimate == actual holds on mixed
    /// groups.
    pub speed: f64,
    /// Override for the node's DRAM tier capacity (blocks); `None`
    /// keeps the cluster-wide `cache_capacity_blocks`.
    pub dram_blocks: Option<usize>,
    /// Override for the node's SSD tier capacity (blocks); `None` keeps
    /// the cluster-wide `ssd_capacity_blocks`.
    pub ssd_blocks: Option<usize>,
}

/// Latency SLOs (§2): absolute limits derived per-experiment from the
/// unloaded baseline (×10 for TTFT, ×5 for TBT in §8.1; fixed 30 s / 0.1 s
/// in §8.1.3).
#[derive(Debug, Clone, Copy)]
pub struct SloConfig {
    pub ttft_ms: f64,
    pub tbt_ms: f64,
}

/// Prefill-instance selection policy (Fig 8 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingPolicy {
    /// Pick a prefill instance uniformly at random.
    Random,
    /// Pick the least-loaded instance (shortest queue).
    LoadBalance,
    /// §6.1: minimize estimated TTFT using local prefix caches only.
    CacheAware,
    /// §6.1 + §6.2: cache-aware + cache load balancing (remote fetch and
    /// hot-spot replication) — full Algorithm 1.
    KvCacheCentric,
}

/// Overload admission policy (§7 / Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectionPolicy {
    /// Accept everything Algorithm 1 can schedule under SLO.
    None,
    /// Check prefill load at arrival and decode load only when the
    /// request reaches decode — wasting the prefill of late rejections.
    Baseline,
    /// §7.2: check max(prefill load, *current* decode load) at arrival.
    Early,
    /// §7.4: check prefill load and the *predicted* decode load at the
    /// moment this request would finish prefill.
    Predictive,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// Per-instance DRAM KVCache tier capacity in 512-token blocks
    /// (None=∞).
    pub cache_capacity_blocks: Option<usize>,
    /// Per-instance SSD KVCache tier capacity in 512-token blocks:
    /// DRAM eviction demotes here instead of dropping.  `Some(0)`
    /// disables the tier (the pre-tiering DRAM-only cache); None=∞.
    pub ssd_capacity_blocks: Option<usize>,
    pub eviction: PolicyKind,
    /// §5.1 prefill chunk size in tokens ("typically larger than 1000").
    pub prefill_chunk: u64,
    /// Max nodes in a chunked-pipeline-parallel group.
    pub cpp_group_max: u64,
    /// Input length above which CPP grouping is attempted.
    pub cpp_threshold_tokens: u64,
    /// Algorithm 1's kvcache_balancing_threshold: prefer local compute
    /// unless best_remote/local exceeds this ratio.
    pub kvcache_balancing_threshold: f64,
    pub scheduling: SchedulingPolicy,
    pub rejection: RejectionPolicy,
    /// Continuous-batching cap per decode instance (sequences).
    pub max_decode_batch: usize,
    pub slo: SloConfig,
    /// Load threshold (fraction of SLO) above which admission rejects.
    pub overload_threshold: f64,
    /// Conductor keeps a global block→node prefix index so
    /// `FindBestPrefixMatch` is one O(chain) walk instead of a scan of
    /// every pool.  Pure optimization: results are bit-for-bit identical
    /// either way.  `false` restores the per-node scan; clusters wider
    /// than `PrefixIndex::MAX_NODES` are tiled into fixed 256-node
    /// shards by `ShardedPrefixIndex`, so any `n_prefill` is covered.
    pub use_prefix_index: bool,
    /// Fourth branch of Algorithm 1's prefix decision: the *hybrid*
    /// load+recompute plan overlaps the SSD→DRAM staging read for the
    /// head of the matched prefix with recomputing its tail on the GPU,
    /// splitting at the point that minimizes `max(load, compute)`
    /// (`costmodel::hybrid_split_scan`).  `true` (the default) lets the
    /// hybrid plan compete with the three exclusive plans on equal
    /// estimated-TTFT terms; `false` restores the exclusive three-way
    /// decision bit-for-bit.
    pub hybrid: bool,
    /// Scheduler worker threads for the candidate walk + scoring fan-out
    /// (`std::thread::scope`, no pool).  The reduce is deterministic —
    /// strict min of `(est.end.to_bits(), node_id)` — so any value
    /// produces bit-for-bit the `sched_workers = 1` placement; pinned by
    /// `sched_workers_do_not_perturb_results`.  1 (the default) runs the
    /// historical sequential loop with zero thread traffic.
    pub sched_workers: usize,
    /// Per-node NIC *receive* bandwidth in B/s.  A transfer completes at
    /// the max of source-tx and destination-rx availability, so a finite
    /// value makes fan-in onto one hot node (incast, §6.1) congest.
    /// `None` = unconstrained ingress — bit-for-bit the pre-rx-queue
    /// behavior (the default).
    pub nic_rx_bw: Option<f64>,
    /// Per-node NVMe *write* bandwidth in B/s: demotion writes occupy
    /// the same device queue staging reads contend on.  `None` =
    /// demotion writes are free (the default, preserving the
    /// pre-NVMe-queue behavior).
    pub ssd_write_bw: Option<f64>,
    /// Proactive background demotion: a low-priority sweep moves DRAM
    /// blocks idle at least this long (ms) down to the SSD tier instead
    /// of waiting for eviction pressure.  `None` = off (the default —
    /// demotion stays eviction-driven).
    pub demote_after_ms: Option<f64>,
    /// Backpressure-aware replication (§6.2 + incast): the *standalone*
    /// proactive planner (`conductor::migration::plan_replications` —
    /// drivable by external schedulers and pinned by decision-level
    /// tests; the event-loop replication path is forwarding-based and
    /// does not consult it yet, see ROADMAP) skips destination nodes
    /// whose NIC-rx backlog exceeds this cap (ms) — a replica pushed
    /// into an incast hot spot queues behind the very congestion it
    /// should relieve.  `None` = off (the default — destination choice
    /// ignores rx backlogs, yesterday's behavior).
    pub replication_rx_backlog_cap_ms: Option<f64>,
    /// Runtime self-verification level (see [`crate::verify::Paranoia`]):
    /// gates the periodic index-vs-rebuild and end-of-run consistency
    /// checks.  `Debug` (the default) preserves the historical
    /// `debug_assert!` behavior; `Full` turns them on in release builds
    /// too (long replays can afford one rebuild per 1024 events).
    pub paranoia: Paranoia,
    /// Streaming replay backpressure: cap on simultaneously *live*
    /// requests (admitted but not yet finished/rejected).  When the cap
    /// is reached the event loop defers further arrivals — they are
    /// admitted, in trace order, as soon as live state drains below the
    /// cap — bounding per-request memory at the cap instead of the trace
    /// length.  `None` = unbounded (the default; with arrivals taken at
    /// their trace times this is bit-for-bit the materialized path).
    pub max_live_requests: Option<usize>,
    /// Epoch-based interner recycling for unbounded-distinct-block
    /// replays: when live interned blocks exceed this count, the `Sim`
    /// marks every id resident in any pool tier and recycles the rest
    /// (see `BlockInterner::recycle_epoch`), keeping the dense-id space
    /// — and the prefix index's flat table — bounded.  `None` = never
    /// recycle (the default, the historical append-only behavior).
    /// Recycled ids change LRU tie-break order for *re-entering* blocks,
    /// so this knob is not bit-for-bit neutral; it is off by default.
    pub interner_epoch_blocks: Option<usize>,
    /// Keep per-request [`crate::metrics::RequestMetrics`] rows in the
    /// result (the default).  `false` drops them as requests retire —
    /// aggregate counters (`n_completed`, rejections, tier/resource
    /// stats) still accumulate — so a 10M-request replay's memory stays
    /// flat instead of growing one row per request.
    pub retain_metrics: bool,
    /// Scripted fault schedule ([`crate::faults`]): node loss/recovery
    /// and device-bandwidth degradation injected as ordinary sim events.
    /// Empty (the default) pushes no events and reproduces the healthy
    /// run bit-for-bit.
    pub faults: FaultPlan,
    /// How many times a request orphaned by node loss may be re-priced
    /// and re-admitted against the surviving nodes before it counts as a
    /// rejection.  Only consulted when `faults` is non-empty.
    pub fault_retry_budget: u32,
    /// Per-node hardware overrides (mixed GPU generations, asymmetric
    /// DRAM/SSD capacities).  Empty (the default) = the homogeneous
    /// cluster, bit-for-bit yesterday's behavior.
    pub node_overrides: Vec<NodeOverride>,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_prefill: 8,
            n_decode: 8,
            cache_capacity_blocks: Some(50_000),
            ssd_capacity_blocks: Some(250_000),
            eviction: PolicyKind::Lru,
            prefill_chunk: 8_192,
            cpp_group_max: 4,
            cpp_threshold_tokens: 32_768,
            kvcache_balancing_threshold: 4.0,
            scheduling: SchedulingPolicy::KvCacheCentric,
            rejection: RejectionPolicy::None,
            max_decode_batch: 128,
            slo: SloConfig { ttft_ms: 30_000.0, tbt_ms: 100.0 },
            overload_threshold: 1.0,
            use_prefix_index: true,
            hybrid: true,
            sched_workers: 1,
            nic_rx_bw: None,
            ssd_write_bw: None,
            demote_after_ms: None,
            replication_rx_backlog_cap_ms: None,
            paranoia: Paranoia::default(),
            max_live_requests: None,
            interner_epoch_blocks: None,
            retain_metrics: true,
            faults: FaultPlan::default(),
            fault_retry_budget: 2,
            node_overrides: Vec::new(),
            seed: 42,
        }
    }
}

impl SimConfig {
    /// The paper's real-workload setup: Mooncake-[10P+10D], TTFT 30 s,
    /// TBT 0.1 s (§8.1.3).
    pub fn real_workload_10p10d() -> Self {
        SimConfig { n_prefill: 10, n_decode: 10, ..Default::default() }
    }

    /// The §6.2 / Table 3 cluster: 8 prefill + 8 decode.
    pub fn cluster_8p8d() -> Self {
        SimConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = SimConfig::default();
        assert!(c.prefill_chunk > 1_000); // §5.1 constraint
        assert!(c.kvcache_balancing_threshold >= 1.0);
        assert_eq!(c.n_prefill, 8);
    }
}
