//! Minimal vendored gzip/DEFLATE decoder (RFC 1951/1952) so `--trace
//! foo.jsonl.gz` works with zero external dependencies — the container
//! contract for this repo is "no new crates", and replay traces ship
//! gzipped in the wild (the original `mooncake_trace.jsonl` is
//! published compressed).
//!
//! Design: a *streaming* state machine behind [`std::io::Read`].  The
//! replay loader reads lines; each `read` call inflates just enough
//! symbols to hand bytes back, holding only the 32 KiB LZ77 window plus
//! a small pending-output queue — so a multi-gigabyte gzipped trace
//! replays in bounded memory, same as the plain-text path.
//!
//! Scope (deliberately minimal, loudly checked):
//! * single-member gzip streams (multi-member concatenation is rare for
//!   trace files and rejected as trailing garbage);
//! * all three DEFLATE block types — stored, fixed Huffman, dynamic
//!   Huffman;
//! * CRC-32 and ISIZE trailer verification (corruption is an error,
//!   not a silent truncation).
//!
//! Decoding is bit-at-a-time over canonical Huffman count tables (the
//! classic `puff` structure): a few hundred MB/s is not the goal;
//! correctness under hand-audit is.

use std::collections::VecDeque;
use std::io::{self, BufRead, Read};

const WINDOW: usize = 32 * 1024;

/// Max bits in a DEFLATE Huffman code.
const MAX_BITS: usize = 15;

/// Length-code bases and extra bits for symbols 257..=285 (RFC 1951
/// §3.2.5).
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];

/// Distance-code bases and extra bits for symbols 0..=29.
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

/// Order in which code-length-code lengths are stored in a dynamic
/// block header.
const CLEN_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("gzip: {msg}"))
}

/// Canonical Huffman decoder state: `count[l]` codes of length `l`,
/// symbols in canonical order.
#[derive(Debug, Clone)]
struct Huffman {
    count: [u16; MAX_BITS + 1],
    symbol: Vec<u16>,
}

impl Huffman {
    /// Build from per-symbol code lengths (0 = unused).  Rejects
    /// over-subscribed codes; incomplete codes are accepted (they decode
    /// fine until a gap is hit, which errors below).
    fn build(lengths: &[u16]) -> io::Result<Huffman> {
        let mut count = [0u16; MAX_BITS + 1];
        for &l in lengths {
            count[l as usize] += 1;
        }
        count[0] = 0;
        let mut left: i32 = 1;
        for l in 1..=MAX_BITS {
            left <<= 1;
            left -= count[l] as i32;
            if left < 0 {
                return Err(bad("over-subscribed Huffman code"));
            }
        }
        let mut offs = [0u16; MAX_BITS + 1];
        for l in 1..MAX_BITS {
            offs[l + 1] = offs[l] + count[l];
        }
        let mut symbol = vec![0u16; lengths.iter().filter(|&&l| l != 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }

    /// The fixed literal/length table (§3.2.6).
    fn fixed_lit() -> Huffman {
        let mut lengths = [0u16; 288];
        for (sym, l) in lengths.iter_mut().enumerate() {
            *l = match sym {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        Huffman::build(&lengths).expect("fixed literal table is well-formed")
    }

    /// The fixed distance table: 30 five-bit codes.
    fn fixed_dist() -> Huffman {
        Huffman::build(&[5u16; 30]).expect("fixed distance table is well-formed")
    }
}

/// Current position in the member being decoded.
#[derive(Debug)]
enum State {
    /// At a block boundary (next: block header, or the trailer if the
    /// final block has been consumed).
    Boundary,
    /// Inside a stored block with this many bytes left to copy.
    Stored(usize),
    /// Inside a fixed/dynamic Huffman block.
    Huffed { lit: Huffman, dist: Huffman },
    /// Trailer verified; everything after is EOF.
    Finished,
}

/// Streaming gzip reader: wraps any `BufRead` positioned at the gzip
/// magic and yields decompressed bytes through `Read`.
pub struct GzReader<R: BufRead> {
    src: R,
    /// LSB-first bit buffer over `src`.
    bitbuf: u32,
    bitcnt: u32,
    /// Last `WINDOW` bytes of output (ring once full).
    window: Vec<u8>,
    wpos: usize,
    /// Decoded bytes not yet handed to the caller.
    pending: VecDeque<u8>,
    state: State,
    /// Header parsed yet?
    started: bool,
    /// Was the current/last block the final one?
    last_block: bool,
    /// Running CRC-32 (pre-xorout) and output length for the trailer.
    crc: u32,
    crc_table: [u32; 256],
    total_out: u64,
}

impl<R: BufRead> GzReader<R> {
    pub fn new(src: R) -> Self {
        let mut crc_table = [0u32; 256];
        for (n, e) in crc_table.iter_mut().enumerate() {
            let mut c = n as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        GzReader {
            src,
            bitbuf: 0,
            bitcnt: 0,
            window: Vec::with_capacity(WINDOW),
            wpos: 0,
            pending: VecDeque::new(),
            state: State::Boundary,
            started: false,
            last_block: false,
            crc: 0xFFFF_FFFF,
            crc_table,
            total_out: 0,
        }
    }

    fn byte(&mut self) -> io::Result<u8> {
        debug_assert_eq!(self.bitcnt, 0, "raw byte read inside a bit run");
        let mut b = [0u8; 1];
        self.src.read_exact(&mut b).map_err(|e| {
            if e.kind() == io::ErrorKind::UnexpectedEof {
                bad("truncated stream")
            } else {
                e
            }
        })?;
        Ok(b[0])
    }

    fn bits(&mut self, n: u32) -> io::Result<u32> {
        while self.bitcnt < n {
            let mut b = [0u8; 1];
            self.src.read_exact(&mut b).map_err(|e| {
                if e.kind() == io::ErrorKind::UnexpectedEof {
                    bad("truncated stream")
                } else {
                    e
                }
            })?;
            self.bitbuf |= (b[0] as u32) << self.bitcnt;
            self.bitcnt += 8;
        }
        let v = self.bitbuf & ((1u32 << n) - 1);
        self.bitbuf >>= n;
        self.bitcnt -= n;
        Ok(v)
    }

    /// Discard buffered bits down to the next byte boundary.
    fn align(&mut self) {
        self.bitbuf = 0;
        self.bitcnt = 0;
    }

    /// Emit one decompressed byte: window, CRC, pending queue.
    fn emit(&mut self, b: u8) {
        if self.window.len() < WINDOW {
            self.window.push(b);
        } else {
            self.window[self.wpos] = b;
        }
        self.wpos = (self.wpos + 1) % WINDOW;
        self.crc = self.crc_table[((self.crc ^ b as u32) & 0xFF) as usize] ^ (self.crc >> 8);
        self.total_out += 1;
        self.pending.push_back(b);
    }

    /// Byte `dist` back in the output stream (LZ77 back-reference).
    fn lookback(&self, dist: usize) -> io::Result<u8> {
        if dist == 0 || dist > self.window.len() {
            return Err(bad("back-reference before start of output"));
        }
        let idx = if self.window.len() < WINDOW {
            // Window not yet wrapped: wpos == window.len().
            self.wpos - dist
        } else {
            (self.wpos + WINDOW - dist) % WINDOW
        };
        Ok(self.window[idx])
    }

    /// RFC 1952 member header.  FEXTRA/FNAME/FCOMMENT/FHCRC are skipped
    /// (we decode content, not metadata).
    fn read_header(&mut self) -> io::Result<()> {
        if self.byte()? != 0x1F || self.byte()? != 0x8B {
            return Err(bad("bad magic (not a gzip stream)"));
        }
        if self.byte()? != 8 {
            return Err(bad("unknown compression method (want DEFLATE)"));
        }
        let flg = self.byte()?;
        if flg & 0xE0 != 0 {
            return Err(bad("reserved header flag set"));
        }
        for _ in 0..6 {
            self.byte()?; // MTIME, XFL, OS
        }
        if flg & 0x04 != 0 {
            // FEXTRA
            let xlen = self.byte()? as usize | ((self.byte()? as usize) << 8);
            for _ in 0..xlen {
                self.byte()?;
            }
        }
        if flg & 0x08 != 0 {
            // FNAME: NUL-terminated.
            while self.byte()? != 0 {}
        }
        if flg & 0x10 != 0 {
            // FCOMMENT
            while self.byte()? != 0 {}
        }
        if flg & 0x02 != 0 {
            // FHCRC
            self.byte()?;
            self.byte()?;
        }
        Ok(())
    }

    /// One bit-at-a-time canonical Huffman decode (puff's walk).
    fn decode(&mut self, h: &Huffman) -> io::Result<u16> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..=MAX_BITS {
            code |= self.bits(1)? as i32;
            let count = h.count[len] as i32;
            if code - first < count {
                return Ok(h.symbol[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(bad("invalid Huffman code (ran past all lengths)"))
    }

    /// Dynamic block header: code-length code, then the literal/length
    /// and distance code lengths it encodes (§3.2.7).
    fn read_dynamic_tables(&mut self) -> io::Result<(Huffman, Huffman)> {
        let hlit = self.bits(5)? as usize + 257;
        let hdist = self.bits(5)? as usize + 1;
        let hclen = self.bits(4)? as usize + 4;
        if hlit > 286 || hdist > 30 {
            return Err(bad("too many literal/distance codes"));
        }
        let mut clen = [0u16; 19];
        for &pos in CLEN_ORDER.iter().take(hclen) {
            clen[pos] = self.bits(3)? as u16;
        }
        let cl = Huffman::build(&clen)?;
        let mut lengths = [0u16; 286 + 30];
        let total = hlit + hdist;
        let mut i = 0usize;
        while i < total {
            let sym = self.decode(&cl)?;
            match sym {
                0..=15 => {
                    lengths[i] = sym;
                    i += 1;
                }
                16 => {
                    if i == 0 {
                        return Err(bad("length repeat with no previous length"));
                    }
                    let prev = lengths[i - 1];
                    let n = 3 + self.bits(2)? as usize;
                    if i + n > total {
                        return Err(bad("length repeat overflows the table"));
                    }
                    for _ in 0..n {
                        lengths[i] = prev;
                        i += 1;
                    }
                }
                17 => {
                    let n = 3 + self.bits(3)? as usize;
                    if i + n > total {
                        return Err(bad("zero-run overflows the table"));
                    }
                    i += n; // lengths[] is zero-initialized
                }
                18 => {
                    let n = 11 + self.bits(7)? as usize;
                    if i + n > total {
                        return Err(bad("zero-run overflows the table"));
                    }
                    i += n;
                }
                _ => return Err(bad("invalid code-length symbol")),
            }
        }
        if lengths[256] == 0 {
            return Err(bad("dynamic block has no end-of-block code"));
        }
        let lit = Huffman::build(&lengths[..hlit])?;
        let dist = Huffman::build(&lengths[hlit..total])?;
        Ok((lit, dist))
    }

    /// Verify the CRC-32 + ISIZE trailer (§2.3.1) at end of member.
    fn read_trailer(&mut self) -> io::Result<()> {
        self.align();
        let mut crc = 0u32;
        for k in 0..4 {
            crc |= (self.byte()? as u32) << (8 * k);
        }
        let mut isize_ = 0u32;
        for k in 0..4 {
            isize_ |= (self.byte()? as u32) << (8 * k);
        }
        if crc != (self.crc ^ 0xFFFF_FFFF) {
            return Err(bad("CRC-32 mismatch (corrupt stream)"));
        }
        if isize_ != self.total_out as u32 {
            return Err(bad("ISIZE mismatch (truncated or corrupt stream)"));
        }
        // A well-formed single-member stream ends here; anything after
        // (e.g. a concatenated second member) is out of scope.
        let mut probe = [0u8; 1];
        match self.src.read(&mut probe) {
            Ok(0) => Ok(()),
            Ok(_) => Err(bad("trailing data after gzip member (multi-member unsupported)")),
            Err(e) => Err(e),
        }
    }

    /// Advance the state machine until at least one byte is pending or
    /// the stream is finished.
    fn step(&mut self) -> io::Result<()> {
        if !self.started {
            self.read_header()?;
            self.started = true;
        }
        match &mut self.state {
            State::Finished => Ok(()),
            State::Boundary => {
                if self.last_block {
                    self.read_trailer()?;
                    self.state = State::Finished;
                    return Ok(());
                }
                self.last_block = self.bits(1)? == 1;
                match self.bits(2)? {
                    0 => {
                        self.align();
                        let len = self.byte()? as usize | ((self.byte()? as usize) << 8);
                        let nlen = self.byte()? as usize | ((self.byte()? as usize) << 8);
                        if len != !nlen & 0xFFFF {
                            return Err(bad("stored block LEN/NLEN mismatch"));
                        }
                        self.state = State::Stored(len);
                    }
                    1 => {
                        self.state =
                            State::Huffed { lit: Huffman::fixed_lit(), dist: Huffman::fixed_dist() };
                    }
                    2 => {
                        let (lit, dist) = self.read_dynamic_tables()?;
                        self.state = State::Huffed { lit, dist };
                    }
                    _ => return Err(bad("reserved block type")),
                }
                Ok(())
            }
            State::Stored(remaining) => {
                let take = (*remaining).min(4096);
                *remaining -= take;
                if *remaining == 0 {
                    self.state = State::Boundary;
                }
                for _ in 0..take {
                    let b = self.byte()?;
                    self.emit(b);
                }
                Ok(())
            }
            State::Huffed { lit, dist } => {
                // Decode symbols until a chunk of output is ready or the
                // block ends.  Tables are cloned out of the state so the
                // decoder can borrow `self` mutably; they are small
                // (count array + symbol list) and this happens once per
                // ~4 KiB of output, not per symbol.
                let (lit, dist) = (lit.clone(), dist.clone());
                loop {
                    let sym = self.decode(&lit)?;
                    match sym {
                        0..=255 => self.emit(sym as u8),
                        256 => {
                            self.state = State::Boundary;
                            return Ok(());
                        }
                        257..=285 => {
                            let li = sym as usize - 257;
                            let len =
                                LEN_BASE[li] as usize + self.bits(LEN_EXTRA[li] as u32)? as usize;
                            let ds = self.decode(&dist)?;
                            if ds > 29 {
                                return Err(bad("invalid distance symbol"));
                            }
                            let di = ds as usize;
                            let d = DIST_BASE[di] as usize
                                + self.bits(DIST_EXTRA[di] as u32)? as usize;
                            for _ in 0..len {
                                let b = self.lookback(d)?;
                                self.emit(b);
                            }
                        }
                        _ => return Err(bad("invalid literal/length symbol")),
                    }
                    if self.pending.len() >= 4096 {
                        return Ok(());
                    }
                }
            }
        }
    }
}

impl<R: BufRead> Read for GzReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        while self.pending.is_empty() {
            if matches!(self.state, State::Finished) {
                return Ok(0);
            }
            self.step()?;
        }
        let n = buf.len().min(self.pending.len());
        for slot in buf.iter_mut().take(n) {
            *slot = self.pending.pop_front().expect("pending checked non-empty");
        }
        Ok(n)
    }
}

/// Reference CRC-32 (bitwise, reflected 0xEDB88320) for test encoders.
#[cfg(test)]
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            crc = if crc & 1 != 0 { 0xEDB8_8320 ^ (crc >> 1) } else { crc >> 1 };
        }
    }
    crc ^ 0xFFFF_FFFF
}

/// Build a single-member gzip stream around `data` using only stored
/// blocks — the test-side encoder for gzip fixtures (no compression,
/// full header/trailer semantics).
#[cfg(test)]
pub fn gzip_stored(data: &[u8]) -> Vec<u8> {
    let mut out = vec![0x1F, 0x8B, 8, 0, 0, 0, 0, 0, 0, 0xFF];
    if data.is_empty() {
        out.extend_from_slice(&[1, 0, 0, 0xFF, 0xFF]); // final empty stored block
    } else {
        let mut chunks = data.chunks(0xFFFF).peekable();
        while let Some(c) = chunks.next() {
            let fin = chunks.peek().is_none() as u8;
            let len = c.len() as u16;
            out.push(fin);
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(c);
        }
    }
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufReader, Read};

    fn inflate_all(gz: &[u8]) -> io::Result<Vec<u8>> {
        let mut r = GzReader::new(BufReader::new(gz));
        let mut out = Vec::new();
        r.read_to_end(&mut out)?;
        Ok(out)
    }

    /// LSB-first bit packer; Huffman codes go in MSB-of-code-first, per
    /// RFC 1951 §3.1.1.
    struct BitWriter {
        bytes: Vec<u8>,
        bitpos: u32,
    }

    impl BitWriter {
        fn new() -> Self {
            BitWriter { bytes: Vec::new(), bitpos: 0 }
        }

        fn push_bit(&mut self, bit: u32) {
            if self.bitpos == 0 {
                self.bytes.push(0);
            }
            let last = self.bytes.last_mut().expect("pushed above");
            *last |= ((bit & 1) as u8) << self.bitpos;
            self.bitpos = (self.bitpos + 1) % 8;
        }

        /// Non-Huffman field: LSB first.
        fn bits(&mut self, v: u32, n: u32) {
            for k in 0..n {
                self.push_bit(v >> k);
            }
        }

        /// Huffman code: MSB of the n-bit code first.
        fn huff(&mut self, code: u32, n: u32) {
            for k in (0..n).rev() {
                self.push_bit(code >> k);
            }
        }
    }

    #[test]
    fn stored_blocks_roundtrip() {
        for data in [
            b"".to_vec(),
            b"x".to_vec(),
            b"{\"timestamp\": 0, \"hash_ids\": [1, 2, 3]}\n".to_vec(),
            (0..200_000u32).map(|i| (i * 7 + i / 251) as u8).collect::<Vec<u8>>(), // >3 chunks
        ] {
            let gz = gzip_stored(&data);
            assert_eq!(inflate_all(&gz).expect("stored stream decodes"), data);
        }
    }

    #[test]
    fn fixed_huffman_block_with_backreference() {
        // "abcabcabc" = literals a,b,c then a length-6/distance-3 match
        // (overlapping copy), then end-of-block.  Fixed codes: literal
        // sym s ∈ 0..=143 → 8-bit code 0x30+s; length sym 260 (len 6) →
        // 7-bit code 4; distance sym 2 (dist 3) → 5-bit code 2; EOB 256
        // → 7-bit code 0.
        let mut w = BitWriter::new();
        w.bits(1, 1); // BFINAL
        w.bits(1, 2); // BTYPE = fixed
        for b in [b'a', b'b', b'c'] {
            w.huff(0x30 + b as u32, 8);
        }
        w.huff(4, 7); // length 6 (sym 260)
        w.huff(2, 5); // distance 3
        w.huff(0, 7); // end of block
        let mut gz = vec![0x1F, 0x8B, 8, 0, 0, 0, 0, 0, 0, 0xFF];
        gz.extend_from_slice(&w.bytes);
        gz.extend_from_slice(&crc32(b"abcabcabc").to_le_bytes());
        gz.extend_from_slice(&9u32.to_le_bytes());
        assert_eq!(inflate_all(&gz).expect("fixed-Huffman stream decodes"), b"abcabcabc");
    }

    #[test]
    fn optional_header_fields_are_skipped() {
        // FEXTRA + FNAME + FCOMMENT + FHCRC all present.
        let mut gz = vec![0x1F, 0x8B, 8, 0x1E, 1, 2, 3, 4, 0, 0xFF];
        gz.extend_from_slice(&[3, 0, 9, 9, 9]); // XLEN=3 + payload
        gz.extend_from_slice(b"trace.jsonl\0"); // FNAME
        gz.extend_from_slice(b"a comment\0"); // FCOMMENT
        gz.extend_from_slice(&[0xAB, 0xCD]); // FHCRC (unchecked)
        let data = b"payload after a decorated header";
        gz.push(1); // final stored block
        gz.extend_from_slice(&(data.len() as u16).to_le_bytes());
        gz.extend_from_slice(&(!(data.len() as u16)).to_le_bytes());
        gz.extend_from_slice(data);
        gz.extend_from_slice(&crc32(data).to_le_bytes());
        gz.extend_from_slice(&(data.len() as u32).to_le_bytes());
        assert_eq!(inflate_all(&gz).expect("decorated header decodes"), data);
    }

    #[test]
    fn bad_magic_is_rejected() {
        let err = inflate_all(b"{\"timestamp\": 0}\n").expect_err("plain text is not gzip");
        assert!(err.to_string().contains("bad magic"), "got: {err}");
    }

    #[test]
    fn corrupt_crc_is_rejected() {
        let mut gz = gzip_stored(b"some trace bytes");
        let n = gz.len();
        gz[n - 5] ^= 0xFF; // flip a CRC byte (trailer = 4 CRC + 4 ISIZE)
        let err = inflate_all(&gz).expect_err("corrupt CRC must fail");
        assert!(err.to_string().contains("CRC-32 mismatch"), "got: {err}");
    }

    #[test]
    fn corrupt_isize_is_rejected() {
        let mut gz = gzip_stored(b"some trace bytes");
        let n = gz.len();
        gz[n - 1] ^= 0xFF;
        let err = inflate_all(&gz).expect_err("corrupt ISIZE must fail");
        assert!(err.to_string().contains("ISIZE mismatch"), "got: {err}");
    }

    #[test]
    fn truncated_stream_is_rejected() {
        let gz = gzip_stored(b"some trace bytes that will be cut short");
        let err = inflate_all(&gz[..gz.len() / 2]).expect_err("truncation must fail");
        assert!(err.to_string().contains("truncated"), "got: {err}");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut gz = gzip_stored(b"one member");
        gz.push(0x00);
        let err = inflate_all(&gz).expect_err("trailing bytes must fail");
        assert!(err.to_string().contains("trailing data"), "got: {err}");
    }

    #[test]
    fn window_wraps_past_32k() {
        // Force back-references across the ring-buffer wrap: >32 KiB of
        // stored data, then (via a second gzip round) nothing — instead
        // exercise lookback directly through a fixed-Huffman stream that
        // first stores 40 000 bytes, then copies from distance 32 768.
        let mut data: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();
        let mut w = BitWriter::new();
        // Non-final stored block carrying the literals.
        let mut gz = vec![0x1F, 0x8B, 8, 0, 0, 0, 0, 0, 0, 0xFF];
        gz.push(0);
        gz.extend_from_slice(&40_000u16.to_le_bytes());
        gz.extend_from_slice(&(!40_000u16).to_le_bytes());
        gz.extend_from_slice(&data);
        // Final fixed-Huffman block: one max-distance match of length 3.
        w.bits(1, 1);
        w.bits(1, 2);
        w.huff(1, 7); // length sym 257 = len 3 (7-bit code 1)
        w.huff(29, 5); // distance sym 29: base 24577, 13 extra bits
        w.bits(32_768 - 24_577, 13); // → distance 32768
        w.huff(0, 7); // EOB
        gz.extend_from_slice(&w.bytes);
        let echo_from = data.len() - 32_768;
        for k in 0..3 {
            let b = data[echo_from + k];
            data.push(b);
        }
        gz.extend_from_slice(&crc32(&data).to_le_bytes());
        gz.extend_from_slice(&(data.len() as u32).to_le_bytes());
        assert_eq!(inflate_all(&gz).expect("wrap-distance stream decodes"), data);
    }
}
