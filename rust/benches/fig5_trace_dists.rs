//! Fig 5 — input and output length distributions of the request trace.
//! Paper: avg input 7,590 tokens, avg output 182 tokens (ratio ~42:1).

use mooncake::bench_util::{banner, fmt, row};
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::stats::{length_histograms, summarize};

fn main() {
    let trace = generate(&TraceGenConfig::default());
    let s = summarize(&trace);

    banner("Fig 5: trace length distributions");
    println!("requests: {}", s.n_requests);
    println!("mean input length:  {:.0} tokens (paper: 7,590)", s.mean_input);
    println!("mean output length: {:.0} tokens (paper: 182)", s.mean_output);

    let (hin, hout) = length_histograms(&trace, 24);
    println!("\ninput length histogram:");
    row(&["mid_tokens".into(), "fraction".into()]);
    for (mid, frac) in hin.normalized() {
        if frac > 0.001 {
            row(&[fmt(mid, 0), fmt(frac, 4)]);
        }
    }
    println!("\noutput length histogram:");
    row(&["mid_tokens".into(), "fraction".into()]);
    for (mid, frac) in hout.normalized() {
        if frac > 0.001 {
            row(&[fmt(mid, 0), fmt(frac, 4)]);
        }
    }

    assert!((s.mean_input / 7_590.0 - 1.0).abs() < 0.35, "input mean calibration");
    assert!((s.mean_output / 182.0 - 1.0).abs() < 0.35, "output mean calibration");
    assert!(s.mean_input / s.mean_output > 20.0, "long-context input/output skew");
    println!("\nfig5 calibration checks OK");
}
