//! Fig 13 — TTFT and TBT CDFs under real-workload replay:
//! Mooncake-[10P+10D] vs vLLM-[20M], TTFT limit 30 s, TBT limit 0.1 s.
//!
//! Paper: both systems' TTFT distributions are nearly identical (~100%
//! within SLO), but only ~57% of vLLM's requests meet the TBT SLO vs
//! ~100% for Mooncake; Mooncake can process ~75% more requests.

use mooncake::baseline::{self, VllmConfig};
use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{SimConfig, SloConfig};
use mooncake::metrics::RequestMetrics;
use mooncake::sim;
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::util::stats::cdf_at;

fn cdfs(metrics: &[RequestMetrics], ttft_grid: &[f64], tbt_grid: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let ttfts: Vec<f64> =
        metrics.iter().filter(|m| !m.ttft_ms.is_nan()).map(|m| m.ttft_ms).collect();
    let tbts: Vec<f64> =
        metrics.iter().filter(|m| !m.mean_tbt_ms.is_nan()).map(|m| m.mean_tbt_ms).collect();
    (cdf_at(&ttfts, ttft_grid), cdf_at(&tbts, tbt_grid))
}

fn main() {
    let slo = SloConfig { ttft_ms: 30_000.0, tbt_ms: 100.0 };
    // Scaled replay: half the trace on half the machines keeps per-node
    // load identical to the paper's 10P+10D/20M over 23.6k requests.
    let trace = generate(&TraceGenConfig { n_requests: 8_000, ..Default::default() });
    let speedup = 2.2; // push both systems into the interesting regime

    let mcfg = SimConfig { n_prefill: 4, n_decode: 4, slo, ..Default::default() };
    let mres = sim::run(&mcfg, &trace, speedup);
    let vcfg = VllmConfig { n_instances: 8, slo, ..Default::default() };
    let (vms, _wall) = baseline::run_raw(&vcfg, &trace, speedup);

    let ttft_grid: Vec<f64> = (0..=12).map(|i| 2_500.0 * i as f64).collect();
    let tbt_grid: Vec<f64> = (0..=12).map(|i| 25.0 * i as f64).collect();
    let (mt, mb) = cdfs(&mres.metrics, &ttft_grid, &tbt_grid);
    let (vt, vb) = cdfs(&vms, &ttft_grid, &tbt_grid);

    banner("Fig 13a: TTFT CDF (ms)");
    row(&["ttft_ms".into(), "mooncake".into(), "vllm".into()]);
    for (i, t) in ttft_grid.iter().enumerate() {
        row(&[fmt(*t, 0), fmt(mt[i], 3), fmt(vt[i], 3)]);
    }
    banner("Fig 13b: TBT CDF (mean inter-token gap, ms)");
    row(&["tbt_ms".into(), "mooncake".into(), "vllm".into()]);
    for (i, t) in tbt_grid.iter().enumerate() {
        row(&[fmt(*t, 0), fmt(mb[i], 3), fmt(vb[i], 3)]);
    }

    // SLO attainment at the caps.
    let m_tbt_ok = *mb.last().unwrap_or(&0.0);
    let m_tbt_at_slo = mb[4]; // 100 ms
    let v_tbt_at_slo = vb[4];
    let m_ttft_ok = mt.last().copied().unwrap_or(0.0);
    println!("\nTBT SLO (100 ms) attainment: mooncake {:.1}%, vllm {:.1}%", m_tbt_at_slo * 100.0, v_tbt_at_slo * 100.0);
    println!("TTFT CDF at 30 s: mooncake {:.3}", m_ttft_ok);

    assert!(
        m_tbt_at_slo > v_tbt_at_slo + 0.1,
        "Mooncake must dominate the TBT CDF: {m_tbt_at_slo} vs {v_tbt_at_slo}"
    );
    assert!(m_tbt_ok > 0.95, "nearly all Mooncake TBTs bounded");
    println!("\nfig13 shape checks OK");
}
