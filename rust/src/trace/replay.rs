//! Streaming replay of the published `mooncake_trace.jsonl` schema.
//!
//! [`super::jsonl::load`] materializes a whole trace — fine for the §8
//! experiment slices, impossible for the 10M-request production replay
//! the paper's headline numbers come from.  This module reads records
//! **incrementally** so `sim::Sim::run_stream` can admit requests from
//! the iterator and hold only the live window in memory:
//!
//! * [`ReplayReader`] — line-at-a-time parser with `file:line`
//!   diagnostics and a monotone-timestamp check (the streaming loop
//!   cannot sort, so out-of-order input is a hard error here rather
//!   than a silent reorder);
//! * [`ReplayStream`] — one tenant, arrival-rate scaling only: block
//!   hashes pass through untouched, so a single-trace streaming run is
//!   bit-for-bit the batch `sim::run` on the same file;
//! * [`ReplayMix`] — k-way merge of several traces ("multi-tenant"
//!   mixing): each tenant gets its own rate scale and its block hashes
//!   are FNV-folded with the tenant index so tenants never share
//!   prefixes by accidental hash collision (trace hash ids are
//!   file-local, not global).
//!
//! Rate semantics match `sim::run`'s `speedup`: `rate = 2.0` compresses
//! arrivals 2× (the paper's 2× overload replay).
//!
//! Traces may be gzipped (`mooncake_trace.jsonl.gz` — the form the
//! published trace actually ships in): [`ReplayReader::open`] sniffs the
//! two gzip magic bytes and, when present, routes the stream through the
//! vendored [`super::inflate::GzReader`].  Decompression is streaming,
//! so the bounded-memory guarantee survives: only the 32 KiB inflate
//! window is added to the live set.  Detection is by content, not file
//! extension — a mis-named plain file still replays.

use std::fs::File;
use std::io::{BufRead, BufReader, Lines};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::inflate::GzReader;
use super::{jsonl, TraceRecord};
use crate::sim::Request;
use crate::{RequestId, TimeMs};

/// Incremental `mooncake_trace.jsonl[.gz]` reader.  Yields records in
/// file order; blank lines are skipped; malformed lines and timestamp
/// regressions yield an `Err` tagged `path:line: …`.
pub struct ReplayReader {
    path: String,
    lines: Lines<Box<dyn BufRead>>,
    /// Physical lines consumed so far (1-based in diagnostics).
    line_no: u64,
    last_ts: Option<u64>,
}

impl ReplayReader {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let f = File::open(path).map_err(|e| anyhow!("open trace {path:?}: {e}"))?;
        let mut raw = BufReader::new(f);
        // Content sniff: a gzip member always starts 0x1F 0x8B.  Peeking
        // through `fill_buf` consumes nothing, so the plain path hands
        // the reader over byte-identical.
        let head = raw.fill_buf().map_err(|e| anyhow!("read trace {path:?}: {e}"))?;
        let lines: Box<dyn BufRead> = if head.starts_with(&[0x1F, 0x8B]) {
            Box::new(BufReader::new(GzReader::new(raw)))
        } else {
            Box::new(raw)
        };
        Ok(ReplayReader {
            path: path.display().to_string(),
            lines: lines.lines(),
            line_no: 0,
            last_ts: None,
        })
    }

    /// The path `file:line` diagnostics refer to.
    pub fn path(&self) -> &str {
        &self.path
    }
}

impl Iterator for ReplayReader {
    type Item = Result<TraceRecord>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => return Some(Err(anyhow!("{}:{}: {e}", self.path, self.line_no + 1))),
            };
            self.line_no += 1;
            if line.trim().is_empty() {
                continue;
            }
            let rec = match jsonl::parse_record(&line) {
                Ok(r) => r,
                Err(e) => return Some(Err(anyhow!("{}:{}: {e}", self.path, self.line_no))),
            };
            if let Some(last) = self.last_ts {
                if rec.timestamp < last {
                    return Some(Err(anyhow!(
                        "{}:{}: non-monotone timestamp {} after {}",
                        self.path,
                        self.line_no,
                        rec.timestamp,
                        last
                    )));
                }
            }
            self.last_ts = Some(rec.timestamp);
            return Some(Ok(rec));
        }
    }
}

/// `rate` must be a positive finite arrival-rate multiplier.
fn check_rate(rate: f64) -> Result<f64> {
    if rate > 0.0 && rate.is_finite() {
        Ok(rate)
    } else {
        bail!("arrival-rate scale must be positive and finite, got {rate}");
    }
}

fn scaled_arrival(timestamp: u64, rate: f64) -> TimeMs {
    timestamp as TimeMs / rate
}

/// Fold a tenant index into a block hash (FNV-1a over both, the same
/// construction as `kvcache::chain_hashes`) so distinct tenants occupy
/// disjoint hash namespaces in a [`ReplayMix`].
fn namespace_hash(tenant: u32, hash: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tenant.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    for b in hash.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Single-tenant streaming request source: rate scaling only, hashes
/// untouched, sequential rids in arrival order.  Fuses after the first
/// error.
pub struct ReplayStream {
    reader: ReplayReader,
    rate: f64,
    next_rid: RequestId,
    done: bool,
}

impl ReplayStream {
    pub fn new(reader: ReplayReader, rate: f64) -> Result<Self> {
        Ok(ReplayStream { reader, rate: check_rate(rate)?, next_rid: 0, done: false })
    }

    pub fn open<P: AsRef<Path>>(path: P, rate: f64) -> Result<Self> {
        Self::new(ReplayReader::open(path)?, rate)
    }
}

impl Iterator for ReplayStream {
    type Item = Result<Request>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        match self.reader.next()? {
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
            Ok(rec) => {
                let rid = self.next_rid;
                self.next_rid += 1;
                Some(Ok(Request {
                    rid,
                    arrival: scaled_arrival(rec.timestamp, self.rate),
                    input: rec.input_length,
                    output: rec.output_length.max(1),
                    hash_ids: rec.hash_ids,
                }))
            }
        }
    }
}

struct TenantStream {
    reader: ReplayReader,
    rate: f64,
    tenant: u32,
    head: Option<TraceRecord>,
    exhausted: bool,
}

/// K-way merge of per-tenant trace streams into one time-ordered
/// request source.  Each tenant's timestamps are scaled by its own
/// rate; the merge picks the earliest scaled arrival (ties go to the
/// lowest tenant index), assigns sequential rids, and FNV-namespaces
/// every block hash with the tenant index.  Fuses after the first
/// error from any tenant.
pub struct ReplayMix {
    streams: Vec<TenantStream>,
    next_rid: RequestId,
    done: bool,
}

impl ReplayMix {
    /// `sources` pairs each tenant's reader with its arrival-rate scale;
    /// tenant indices follow the vector order.
    pub fn new(sources: Vec<(ReplayReader, f64)>) -> Result<Self> {
        let mut streams = Vec::with_capacity(sources.len());
        for (tenant, (reader, rate)) in sources.into_iter().enumerate() {
            streams.push(TenantStream {
                reader,
                rate: check_rate(rate)?,
                tenant: u32::try_from(tenant).expect("tenant index fits u32"),
                head: None,
                exhausted: false,
            });
        }
        Ok(ReplayMix { streams, next_rid: 0, done: false })
    }

    /// Open every path with its rate (convenience for the CLI).
    pub fn open<P: AsRef<Path>>(paths: &[P], rates: &[f64]) -> Result<Self> {
        if paths.is_empty() {
            bail!("replay mix needs at least one trace");
        }
        if paths.len() != rates.len() {
            bail!("{} traces but {} rates", paths.len(), rates.len());
        }
        let mut sources = Vec::with_capacity(paths.len());
        for (p, &r) in paths.iter().zip(rates) {
            sources.push((ReplayReader::open(p)?, r));
        }
        Self::new(sources)
    }
}

impl Iterator for ReplayMix {
    type Item = Result<Request>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        // Refill every empty head so the minimum is over all tenants.
        for s in &mut self.streams {
            if s.head.is_none() && !s.exhausted {
                match s.reader.next() {
                    None => s.exhausted = true,
                    Some(Err(e)) => {
                        self.done = true;
                        return Some(Err(e));
                    }
                    Some(Ok(rec)) => s.head = Some(rec),
                }
            }
        }
        // Earliest scaled arrival wins; ties go to the lowest tenant.
        let mut best: Option<(usize, TimeMs)> = None;
        for (i, s) in self.streams.iter().enumerate() {
            if let Some(rec) = &s.head {
                let arr = scaled_arrival(rec.timestamp, s.rate);
                if best.is_none_or(|(_, t)| arr < t) {
                    best = Some((i, arr));
                }
            }
        }
        let (i, arrival) = best?;
        let rec = self.streams[i].head.take().expect("picked a live head");
        let tenant = self.streams[i].tenant;
        let rid = self.next_rid;
        self.next_rid += 1;
        Some(Ok(Request {
            rid,
            arrival,
            input: rec.input_length,
            output: rec.output_length.max(1),
            hash_ids: rec.hash_ids.iter().map(|&h| namespace_hash(tenant, h)).collect(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_trace(name: &str, body: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(body.as_bytes()).unwrap();
        path
    }

    #[test]
    fn reader_streams_records_in_order() {
        let path = write_trace(
            "replay_reader_ok.jsonl",
            concat!(
                r#"{"timestamp": 0, "input_length": 600, "output_length": 2, "hash_ids": [1, 2]}"#,
                "\n\n",
                r#"{"timestamp": 50, "input_length": 512, "output_length": 1, "hash_ids": [1]}"#,
                "\n",
            ),
        );
        let recs: Vec<TraceRecord> =
            ReplayReader::open(&path).unwrap().collect::<Result<_>>().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].hash_ids, vec![1, 2]);
        assert_eq!(recs[1].timestamp, 50);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn gzipped_trace_streams_identically_to_plain() {
        let body = concat!(
            r#"{"timestamp": 0, "input_length": 600, "output_length": 2, "hash_ids": [1, 2]}"#,
            "\n",
            r#"{"timestamp": 50, "input_length": 512, "output_length": 1, "hash_ids": [1]}"#,
            "\n",
        );
        let plain = write_trace("replay_gz_plain.jsonl", body);
        let gz = std::env::temp_dir().join("replay_gz.jsonl.gz");
        std::fs::write(&gz, crate::trace::inflate::gzip_stored(body.as_bytes())).unwrap();
        let a: Vec<TraceRecord> =
            ReplayReader::open(&plain).unwrap().collect::<Result<_>>().unwrap();
        let b: Vec<TraceRecord> = ReplayReader::open(&gz).unwrap().collect::<Result<_>>().unwrap();
        assert_eq!(a, b, "gz and plain must parse to identical records");
        // The full request stream (rate scaling, rids) is also identical.
        let ra: Vec<Request> =
            ReplayStream::open(&plain, 2.0).unwrap().collect::<Result<_>>().unwrap();
        let rb: Vec<Request> = ReplayStream::open(&gz, 2.0).unwrap().collect::<Result<_>>().unwrap();
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert!(x.rid == y.rid, "rid drifted through gzip");
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert!(x.input == y.input && x.output == y.output);
            assert_eq!(x.hash_ids, y.hash_ids);
        }
        std::fs::remove_file(plain).ok();
        std::fs::remove_file(gz).ok();
    }

    #[test]
    fn non_monotone_timestamp_is_tagged_with_file_and_line() {
        let path = write_trace(
            "replay_reader_mono.jsonl",
            concat!(
                r#"{"timestamp": 100, "input_length": 10, "output_length": 1, "hash_ids": []}"#,
                "\n",
                r#"{"timestamp": 99, "input_length": 10, "output_length": 1, "hash_ids": []}"#,
                "\n",
            ),
        );
        let mut r = ReplayReader::open(&path).unwrap();
        assert!(r.next().unwrap().is_ok());
        let err = r.next().unwrap().unwrap_err().to_string();
        assert!(err.contains(":2:"), "line number missing: {err}");
        assert!(err.contains("non-monotone"), "wrong diagnostic: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_scales_arrivals_and_keeps_hashes() {
        let path = write_trace(
            "replay_stream_rate.jsonl",
            concat!(
                r#"{"timestamp": 1000, "input_length": 600, "output_length": 2, "hash_ids": [7]}"#,
                "\n",
            ),
        );
        let reqs: Vec<Request> =
            ReplayStream::open(&path, 4.0).unwrap().collect::<Result<_>>().unwrap();
        assert_eq!(reqs[0].arrival, 250.0);
        assert_eq!(reqs[0].hash_ids, vec![7], "single-tenant hashes must pass through");
        assert!(ReplayStream::open(&path, 0.0).is_err());
        assert!(ReplayStream::open(&path, f64::NAN).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mix_merges_time_ordered_and_namespaces_tenants() {
        let a = write_trace(
            "replay_mix_a.jsonl",
            concat!(
                r#"{"timestamp": 0, "input_length": 600, "output_length": 1, "hash_ids": [9]}"#,
                "\n",
                r#"{"timestamp": 200, "input_length": 600, "output_length": 1, "hash_ids": [9]}"#,
                "\n",
            ),
        );
        let b = write_trace(
            "replay_mix_b.jsonl",
            concat!(
                r#"{"timestamp": 0, "input_length": 600, "output_length": 1, "hash_ids": [9]}"#,
                "\n",
                r#"{"timestamp": 300, "input_length": 600, "output_length": 1, "hash_ids": [9]}"#,
                "\n",
            ),
        );
        // Tenant 1 runs at 2× rate: its t=300 lands at 150, between
        // tenant 0's 0 and 200; the t=0 tie goes to tenant 0.
        let mix = ReplayMix::open(&[&a, &b], &[1.0, 2.0]).unwrap();
        let reqs: Vec<Request> = mix.collect::<Result<_>>().unwrap();
        let arrivals: Vec<f64> = reqs.iter().map(|r| r.arrival).collect();
        assert_eq!(arrivals, vec![0.0, 0.0, 150.0, 200.0]);
        assert_eq!(reqs.iter().map(|r| r.rid).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // Same file-local hash id, different tenants ⇒ different blocks.
        assert_eq!(reqs[0].hash_ids[0], namespace_hash(0, 9));
        assert_eq!(reqs[1].hash_ids[0], namespace_hash(1, 9));
        assert_ne!(reqs[0].hash_ids[0], reqs[1].hash_ids[0]);
        // And tenant 0's two requests share their block (prefix reuse
        // survives namespacing within a tenant).
        assert_eq!(reqs[0].hash_ids[0], reqs[3].hash_ids[0]);
        std::fs::remove_file(a).ok();
        std::fs::remove_file(b).ok();
    }

    #[test]
    fn mix_rejects_mismatched_rates() {
        let a = write_trace(
            "replay_mix_len.jsonl",
            concat!(
                r#"{"timestamp": 0, "input_length": 1, "output_length": 1, "hash_ids": []}"#,
                "\n",
            ),
        );
        assert!(ReplayMix::open(&[&a], &[1.0, 2.0]).is_err());
        assert!(ReplayMix::open::<&std::path::PathBuf>(&[], &[]).is_err());
        std::fs::remove_file(a).ok();
    }
}
