"""Pallas chunked-prefill flash-attention kernel.

This is the §5.1 CPP chunk's compute hot-spot: a chunk of S queries at
global offset `q_start` attends causally over the full per-request cache
(reused prefix + the chunk's freshly written K/V).  The grid tiles queries
(BQ) x cache (BK); the cache streams HBM->VMEM one block per step, which
is the TPU expression of the paper's layer-wise load/compute overlap
(§5.2) — the next KV block is fetched while the MXU contracts the current
one.  Online softmax in VMEM scratch persists across the kv-block grid
dimension (the minor, sequential one).

interpret=True for CPU-PJRT execution; see decode_attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(start_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, group):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)  # [BQ, nh, hd]
    k = k_ref[...].astype(jnp.float32)  # [BK, kvh, hd]
    v = v_ref[...].astype(jnp.float32)
    nh, hd = q.shape[1], q.shape[2]
    k = jnp.repeat(k, group, axis=1)  # [BK, nh, hd]
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("qnd,knd->qnk", q, k, preferred_element_type=jnp.float32) * scale

    # Causal mask in *global* positions: query row i*BQ+r sits at
    # q_start + i*BQ + r and may attend to cache cols <= its own position.
    q_start = start_ref[0]
    qpos = q_start + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1, bk), 0)
    kvpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, 1, bk), 2)
    mask = kvpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, :, None]          # [BQ, nh, 1]
    m_cur = jnp.max(s, axis=2, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)          # [BQ, nh, 1]
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = alpha[..., 0] * l_ref[...] + jnp.sum(p, axis=2)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum(
        "qnk,knd->qnd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new[..., 0]
    l_ref[...] = l_new

    @pl.when(j == nblk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)[:, :, None]
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(q, k, v, q_start, *, block_q: int = 64, block_k: int = 128):
    """Chunked causal prefill attention.  See `ref.prefill_attention_ref`.

    q: [S, nh, hd]; k, v: [C, kvh, hd]; q_start: [1] int32.
    Cache positions > q_start+S-1 are masked by causality alone, so no
    kv_len operand is needed (the chunk's own K/V are the newest entries).
    """
    S, nh, hd = q.shape
    C, kvh = k.shape[0], k.shape[1]
    bq = min(block_q, S)
    assert S % bq == 0 and C % block_k == 0, (S, bq, C, block_k)
    group = nh // kvh
    grid = (S // bq, C // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=block_k, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bq, nh, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_k, kvh, hd), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_k, kvh, hd), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, nh, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, nh, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, nh), jnp.float32),
            pltpu.VMEM((bq, nh), jnp.float32),
            pltpu.VMEM((bq, nh, hd), jnp.float32),
        ],
        interpret=True,
    )(q_start, q, k, v)
