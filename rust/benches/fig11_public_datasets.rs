//! Fig 11 — end-to-end comparison on public datasets (ArXiv Summarization
//! and L-Eval): Mooncake-[3P+1D] and Mooncake-[2P+2D] vs vLLM-[4M],
//! sweeping RPS and reporting P90 TTFT / P90 TBT normalized against the
//! SLO thresholds (×10 and ×5 of the unloaded baseline, §8.1).
//!
//! Paper: Mooncake-[3P+1D] sustains ~20% (ArXiv) and ~40% (L-Eval) higher
//! RPS than vLLM-[4M] within both SLOs; L-Eval benefits further from
//! prefix caching.

use mooncake::baseline::{self, VllmConfig};
use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{SimConfig, SloConfig};
use mooncake::model::PerfModel;
use mooncake::sim;
use mooncake::trace::gen;

struct Setup {
    name: &'static str,
    mean_in: u64,
}

fn slo_for(perf: &PerfModel, mean_in: u64) -> SloConfig {
    // Unloaded single-request baselines (§8.1 Metric).
    let ttft_base = perf.prefill_ms(mean_in, 0);
    let tbt_base = perf.decode_step_ms(1, mean_in);
    SloConfig { ttft_ms: 10.0 * ttft_base, tbt_ms: 5.0 * tbt_base }
}

fn max_rps_under_slo(name: &str, dataset: &str, slo: SloConfig, rps_grid: &[f64], n: usize) -> f64 {
    let mut best = 0.0f64;
    for &rps in rps_grid {
        let trace = gen::dataset(dataset, n, rps, 11);
        let (ttft_p90, tbt_p90, attain) = match name {
            "vLLM-[4M]" => {
                let cfg = VllmConfig { n_instances: 4, slo, ..Default::default() };
                let rep = baseline::run(&cfg, &trace, 1.0);
                (rep.ttft_p90, rep.tbt_p90, rep.slo_attainment)
            }
            _ => {
                let (p, d) = if name.contains("3P+1D") { (3, 1) } else { (2, 2) };
                let cfg = SimConfig { n_prefill: p, n_decode: d, slo, ..Default::default() };
                let rep = sim::run(&cfg, &trace, 1.0).report(&cfg);
                (rep.ttft_p90, rep.tbt_p90, rep.slo_attainment)
            }
        };
        row(&[
            name.into(),
            fmt(rps, 2),
            fmt(ttft_p90 / slo.ttft_ms, 2),
            fmt(tbt_p90 / slo.tbt_ms, 2),
            fmt(attain, 2),
        ]);
        // Sustained = P90s inside SLO *and* >=90% of requests actually
        // served within SLO (Mooncake's 429s must not count as capacity).
        if ttft_p90 <= slo.ttft_ms && tbt_p90 <= slo.tbt_ms && attain >= 0.9 {
            best = best.max(rps);
        }
    }
    best
}

fn main() {
    let perf = PerfModel::paper();
    let setups = [
        Setup { name: "arxiv", mean_in: 8_088 },
        Setup { name: "leval", mean_in: 19_019 },
    ];
    let systems = ["vLLM-[4M]", "Mooncake-[3P+1D]", "Mooncake-[2P+2D]"];
    let rps_grid = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0];

    let mut winners = Vec::new();
    for s in &setups {
        let slo = slo_for(&perf, s.mean_in);
        banner(&format!(
            "Fig 11: {} (SLO: TTFT {:.0} ms, TBT {:.0} ms)",
            s.name, slo.ttft_ms, slo.tbt_ms
        ));
        row(&["system".into(), "rps".into(), "P90_TTFT/SLO".into(), "P90_TBT/SLO".into(), "attain".into()]);
        let mut per_system = Vec::new();
        for sys in systems {
            let best = max_rps_under_slo(sys, s.name, slo, &rps_grid, 300);
            per_system.push((sys, best));
        }
        println!("max RPS under both SLOs:");
        for (sys, best) in &per_system {
            println!("  {sys:18} {best:.2} rps");
        }
        winners.push((s.name, per_system));
    }

    // Shape checks: Mooncake-[3P+1D] must beat vLLM on both datasets.
    for (ds, per_system) in &winners {
        let vllm = per_system.iter().find(|x| x.0.contains("vLLM")).unwrap().1;
        let mc = per_system.iter().find(|x| x.0.contains("3P+1D")).unwrap().1;
        assert!(
            mc >= vllm,
            "{ds}: Mooncake-[3P+1D] ({mc}) must sustain >= vLLM ({vllm}) rps"
        );
    }
    println!("\nfig11 shape checks OK");
}
