//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! Mooncake trace JSONL files and `artifacts/manifest.json`).
//!
//! Numbers parse to f64; integers round-trip exactly up to 2^53, which
//! covers every field in the trace schema (timestamps < 3.6e6, block ids,
//! token counts).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the trace writer.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn arr_u64(xs: &[u64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_trace_record() {
        let rec = r#"{"timestamp": 27482, "input_length": 6955, "output_length": 52,
                      "hash_ids": [46, 47, 2353]}"#;
        let v = parse(rec).unwrap();
        assert_eq!(v.get("timestamp").unwrap().as_u64(), Some(27482));
        assert_eq!(v.get("input_length").unwrap().as_u64(), Some(6955));
        let ids: Vec<u64> =
            v.get("hash_ids").unwrap().as_arr().unwrap().iter().map(|x| x.as_u64().unwrap()).collect();
        assert_eq!(ids, vec![46, 47, 2353]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y","c":null,"d":true,"e":{"f":false}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""aéb""#).unwrap();
        assert_eq!(v.as_str(), Some("aéb"));
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo"));
    }

    #[test]
    fn big_ints_roundtrip() {
        let v = parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_u64(), Some(9007199254740992));
    }
}
