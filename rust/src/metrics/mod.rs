//! Request-level outcomes and the aggregate measures the paper reports:
//! P90 TTFT/TBT (normalized against SLO), SLO attainment, goodput
//! (§2: only *fully completed* requests count — anything rejected or
//! SLO-violating is wasted work).

use crate::faults::FaultStats;
use crate::kvcache::TierCounters;
use crate::resource::ResourceStats;
use crate::util::stats;
use crate::{RequestId, TimeMs};

/// Where a request's life ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed all output tokens.
    Completed,
    /// Rejected at arrival (Conductor admission / early rejection).
    RejectedAtArrival,
    /// Rejected by the decode double-check after prefill (wasted prefill).
    RejectedAfterPrefill,
}

#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: RequestId,
    pub arrival: TimeMs,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub outcome: Outcome,
    /// Time to first token (prefill completion), ms, as *observed* by the
    /// simulator's `PrefillDone` event.  NaN if rejected.
    pub ttft_ms: f64,
    /// Conductor's TTFT estimate at admission (unified cost model).  NaN
    /// if rejected or the engine has no estimator (vLLM baseline).  The
    /// gap to `ttft_ms` is the estimate/actual drift §6-§7 depend on.
    pub est_ttft_ms: f64,
    /// Max inter-token gap during decode, ms.  NaN if no decode happened.
    pub max_tbt_ms: f64,
    /// Mean inter-token gap, ms.
    pub mean_tbt_ms: f64,
    /// Tokens actually generated (== output_tokens iff completed).
    pub generated: u64,
    /// Completion time, ms.
    pub finish: TimeMs,
}

impl RequestMetrics {
    pub fn rejected(id: RequestId, arrival: TimeMs, input: u64, output: u64, at_decode: bool) -> Self {
        RequestMetrics {
            id,
            arrival,
            input_tokens: input,
            output_tokens: output,
            outcome: if at_decode { Outcome::RejectedAfterPrefill } else { Outcome::RejectedAtArrival },
            ttft_ms: f64::NAN,
            est_ttft_ms: f64::NAN,
            max_tbt_ms: f64::NAN,
            mean_tbt_ms: f64::NAN,
            generated: 0,
            finish: arrival,
        }
    }

    /// SLO check uses the per-request *mean* inter-token time (the
    /// paper's TBT measure: decode wall time over tokens generated);
    /// `max_tbt_ms` is kept for tail diagnostics (Fig 13's long tail).
    pub fn meets_slo(&self, ttft_slo: f64, tbt_slo: f64) -> bool {
        self.outcome == Outcome::Completed
            && self.ttft_ms <= ttft_slo
            && (self.mean_tbt_ms.is_nan() || self.mean_tbt_ms <= tbt_slo)
    }
}

/// Aggregates over a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub n_total: usize,
    pub n_completed: usize,
    pub n_rejected_arrival: usize,
    pub n_rejected_after_prefill: usize,
    pub ttft_p90: f64,
    pub tbt_p90: f64,
    pub ttft_mean: f64,
    /// Fraction of requests meeting both SLOs (of all submitted).
    pub slo_attainment: f64,
    /// Completed-under-SLO requests per second.
    pub goodput_rps: f64,
    /// Total generated tokens of SLO-satisfying requests per second.
    pub goodput_tokens_per_sec: f64,
    /// Prefill compute (token·ms proxy) spent on requests later rejected.
    pub wasted_prefill_tokens: u64,
    /// Mean |estimated − observed| TTFT over completed requests with an
    /// estimate — the cost-model drift the scheduler's SLO gates ride on.
    pub ttft_est_mae: f64,
    /// Per-tier cache hit/demotion/promotion counters aggregated over
    /// the cluster's pools (filled by `SimResult::report`; zero for
    /// engines without a tiered cache, e.g. the vLLM baseline).
    pub tiers: TierCounters,
    /// Per-resource (NIC tx, NIC rx, NVMe) queued-ms / busy-ms / byte
    /// counters (filled by `SimResult::report`; use
    /// `BankStats::utilization` with the run's wall time for device
    /// utilization).
    pub resources: ResourceStats,
    /// Placements that chose the hybrid (overlapped load+recompute)
    /// prefix plan — Algorithm 1's fourth branch (filled by
    /// `SimResult::report`; zero for engines without it).
    pub hybrid_placements: u64,
    /// Fault-injection accounting (`crate::faults`): injected events,
    /// nodes lost/recovered, jobs killed, orphan retries/rescues/losses
    /// (filled by `SimResult::report`; all zero on healthy runs).
    pub faults: FaultStats,
}

pub fn report(metrics: &[RequestMetrics], ttft_slo: f64, tbt_slo: f64, wall_ms: f64) -> RunReport {
    let ttfts: Vec<f64> =
        metrics.iter().filter(|m| !m.ttft_ms.is_nan()).map(|m| m.ttft_ms).collect();
    let tbts: Vec<f64> =
        metrics.iter().filter(|m| !m.mean_tbt_ms.is_nan()).map(|m| m.mean_tbt_ms).collect();
    let ok: Vec<&RequestMetrics> =
        metrics.iter().filter(|m| m.meets_slo(ttft_slo, tbt_slo)).collect();
    let est_errs: Vec<f64> = metrics
        .iter()
        .filter(|m| m.ttft_ms.is_finite() && m.est_ttft_ms.is_finite())
        .map(|m| (m.est_ttft_ms - m.ttft_ms).abs())
        .collect();
    let wall_s = (wall_ms / 1e3).max(1e-9);
    RunReport {
        n_total: metrics.len(),
        n_completed: metrics.iter().filter(|m| m.outcome == Outcome::Completed).count(),
        n_rejected_arrival: metrics
            .iter()
            .filter(|m| m.outcome == Outcome::RejectedAtArrival)
            .count(),
        n_rejected_after_prefill: metrics
            .iter()
            .filter(|m| m.outcome == Outcome::RejectedAfterPrefill)
            .count(),
        ttft_p90: stats::percentile(&ttfts, 90.0),
        tbt_p90: stats::percentile(&tbts, 90.0),
        ttft_mean: stats::mean(&ttfts),
        slo_attainment: ok.len() as f64 / metrics.len().max(1) as f64,
        goodput_rps: ok.len() as f64 / wall_s,
        goodput_tokens_per_sec: ok.iter().map(|m| m.generated as f64).sum::<f64>() / wall_s,
        wasted_prefill_tokens: metrics
            .iter()
            .filter(|m| m.outcome == Outcome::RejectedAfterPrefill)
            .map(|m| m.input_tokens)
            .sum(),
        // NaN (not 0.0) when no request carried an estimate, so "no data"
        // is distinguishable from perfect agreement.
        ttft_est_mae: stats::mean(&est_errs),
        tiers: TierCounters::default(),
        resources: ResourceStats::default(),
        hybrid_placements: 0,
        faults: FaultStats::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(id: u64, ttft: f64, tbt: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival: 0.0,
            input_tokens: 100,
            output_tokens: 10,
            outcome: Outcome::Completed,
            ttft_ms: ttft,
            est_ttft_ms: ttft,
            max_tbt_ms: tbt,
            mean_tbt_ms: tbt,
            generated: 10,
            finish: 1_000.0,
        }
    }

    #[test]
    fn slo_check() {
        assert!(done(1, 100.0, 10.0).meets_slo(200.0, 20.0));
        assert!(!done(1, 300.0, 10.0).meets_slo(200.0, 20.0));
        assert!(!done(1, 100.0, 30.0).meets_slo(200.0, 20.0));
        assert!(!RequestMetrics::rejected(1, 0.0, 10, 1, false).meets_slo(1e9, 1e9));
    }

    #[test]
    fn report_counts() {
        let ms = vec![
            done(1, 100.0, 10.0),
            done(2, 300.0, 10.0),
            RequestMetrics::rejected(3, 0.0, 50, 1, false),
            RequestMetrics::rejected(4, 0.0, 70, 1, true),
        ];
        let r = report(&ms, 200.0, 20.0, 10_000.0);
        assert_eq!(r.n_total, 4);
        assert_eq!(r.n_completed, 2);
        assert_eq!(r.n_rejected_arrival, 1);
        assert_eq!(r.n_rejected_after_prefill, 1);
        assert_eq!(r.wasted_prefill_tokens, 70);
        assert!((r.slo_attainment - 0.25).abs() < 1e-9);
        assert!((r.goodput_rps - 0.1).abs() < 1e-9);
    }
}
