//! Tiny CLI argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed() {
        let a = parse("simulate trace.jsonl --rps 2.5 --instances=8 --verbose");
        assert_eq!(a.positional, vec!["simulate", "trace.jsonl"]);
        assert_eq!(a.get_f64("rps", 0.0), 2.5);
        assert_eq!(a.get_usize("instances", 0), 8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn bare_option_before_positional_consumes_it() {
        // Documented ambiguity: `--flag value` is parsed as an option.
        let a = parse("--verbose trace.jsonl");
        assert_eq!(a.get("verbose"), Some("trace.jsonl"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_u64("seed", 42), 42);
    }
}
