//! Fig 8 — scheduling-policy comparison on an 8-prefill + 8-decode
//! cluster replaying the real-workload trace: random vs load-balancing vs
//! cache-aware (§6.1) vs KVCache-centric (§6.2).
//!
//! Paper result: cache-aware and KVCache-centric cut average TTFT
//! dramatically and raise the TTFT SLO attainment rate, with
//! KVCache-centric best on both metrics.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{SchedulingPolicy, SimConfig};
use mooncake::sim;
use mooncake::trace::gen::{generate, TraceGenConfig};

fn main() {
    // Scaled-down replay (quarter of the trace, same distribution) keeps
    // the bench under a minute; relative policy ordering is unaffected.
    let trace = generate(&TraceGenConfig { n_requests: 6_000, ..Default::default() });
    let policies = [
        ("random", SchedulingPolicy::Random),
        ("load-balancing", SchedulingPolicy::LoadBalance),
        ("cache-aware", SchedulingPolicy::CacheAware),
        ("kvcache-centric", SchedulingPolicy::KvCacheCentric),
    ];

    banner("Fig 8: scheduling comparison (8P+8D, trace replay at 2x)");
    row(&[
        "policy".into(),
        "avg_TTFT_ms".into(),
        "P90_TTFT_ms".into(),
        "TTFT_SLO_attain_%".into(),
        "reused_blocks".into(),
    ]);

    let mut results = Vec::new();
    for (name, pol) in policies {
        let cfg = SimConfig { scheduling: pol, ..SimConfig::cluster_8p8d() };
        let res = sim::run(&cfg, &trace, 2.0);
        let rep = res.report(&cfg);
        // TTFT-only attainment (the figure's right panel).
        let ttft_ok = res
            .metrics
            .iter()
            .filter(|m| !m.ttft_ms.is_nan() && m.ttft_ms <= cfg.slo.ttft_ms)
            .count() as f64
            / res.metrics.len() as f64;
        row(&[
            name.into(),
            fmt(rep.ttft_mean, 0),
            fmt(rep.ttft_p90, 0),
            fmt(ttft_ok * 100.0, 1),
            res.conductor.reused_blocks.to_string(),
        ]);
        results.push((name, rep.ttft_mean, ttft_ok, res.conductor.reused_blocks));
    }

    // Shape checks: the paper's ordering.
    let get = |n: &str| results.iter().find(|r| r.0 == n).unwrap().clone();
    let random = get("random");
    let cache = get("cache-aware");
    let centric = get("kvcache-centric");
    assert!(cache.1 < random.1, "cache-aware TTFT must beat random");
    assert!(centric.1 < random.1, "kvcache-centric TTFT must beat random");
    assert!(centric.3 > random.3, "kvcache-centric must reuse more blocks");
    assert!(centric.2 >= random.2 - 0.02, "attainment must not regress");
    println!("\nfig8 shape checks OK");
}
