//! The Conductor's **global prefix index** (§5, §6): per-block, per-node,
//! tier-aware residency bitsets, replacing the per-request scan of every
//! prefill instance's pool.
//!
//! `FindBestPrefixMatch` used to cost O(nodes × chain) HashMap probes
//! per scheduling decision — worst in exactly the long-context regime
//! the paper targets (128K ctx ≈ thousands of blocks).  With the index,
//! [`PrefixIndex::best_prefix_into`] touches each chain block **once**
//! and advances every candidate node's match simultaneously with bitmask
//! arithmetic: per block, one direct array load plus O(words) mask ops
//! plus work proportional only to the nodes whose state *changes* at
//! that block (death, DRAM-run end, SSD copy).
//!
//! Storage is **dense and width-adaptive**: blocks are interned
//! [`DenseBlockId`]s (see `kvcache::intern`), so residency lives in one
//! flat `Vec<u64>` indexed by `block × stride` — no hashing at all on
//! the lookup path — and the stride is sized to the cluster at
//! construction: `n_words = n_nodes.div_ceil(64)` words per tier, so an
//! 8-node cluster pays 2 words (16 B) per block slot where the old fixed
//! `[u64; 4]`-per-tier representation paid 8 (64 B).  One monolithic
//! index covers up to [`PrefixIndex::MAX_NODES`] prefill nodes.
//!
//! **Cluster scale** (ROADMAP item 3): past that, [`ShardedPrefixIndex`]
//! tiles the cluster into fixed [`ShardedPrefixIndex::SHARD_NODES`]-node
//! groups, one monolithic index per group.  Per-block footprint stays
//! `O(shard_width)` — a block held by 3 nodes of a 1024-node cluster
//! occupies slots in (at most) the 3 owning shards' tables, not one
//! 1024-bit-wide row — and `TierDelta` application routes to the one
//! owning shard.  The walk runs shard-by-shard into disjoint slices of
//! the caller's output buffer, optionally fanned out across
//! `std::thread::scope` workers; the merge is shard-ordered and
//! sequential, so the result is **bit-for-bit identical** to the
//! monolithic walk regardless of worker count.  Only the explicit
//! `use_prefix_index: false` knob restores the per-pool scan.
//!
//! Consistency protocol: the index is owned next to the scheduler (the
//! `Sim`), not by the pools — pools stay self-contained LRU structures
//! and every mutation ([`CachePool::admit_chain_reusing`],
//! [`CachePool::insert_replica`], [`CachePool::demote_block`],
//! [`CachePool::demote_idle`], …) *returns* a [`TierDelta`] of residency
//! changes which the owner applies via [`PrefixIndex::apply`].  A
//! debug-mode invariant ([`PrefixIndex::equals_rebuild_of`]) checks the
//! incremental index against a brute-force rebuild.
//!
//! The walk also carries each node's SSD *positions* out into an
//! [`SsdPositions`] scratch — the §6.2 wire-refresh pricing consumes
//! them so it never re-probes a tier per head block (see
//! `conductor::select_prefill`).

use super::intern::DenseBlockId;
use super::pool::{CachePool, SsdPositions, Tier, TierDelta, TierMatch};

/// Hard width cap: enough words for [`PrefixIndex::MAX_NODES`] nodes.
/// The per-walk cursor masks live on the stack at this width; the per-
/// block storage only ever pays the *configured* width.
const MAX_WORDS: usize = 4;

#[derive(Debug)]
pub struct PrefixIndex {
    n_nodes: usize,
    /// Words actually carrying bits: `n_nodes.div_ceil(64)` (≥ 1).
    n_words: usize,
    /// `2 * n_words` — words per block slot (DRAM words, then SSD words).
    stride: usize,
    /// Flat residency table indexed by `block as usize * stride`; grows
    /// (zero-filled) as new dense ids appear.  A dropped block's slot
    /// zeroes out but is kept.  With `interner_epoch_blocks` set, the
    /// `Sim` recycles ids that are resident in no pool tier
    /// (`BlockInterner::recycle_epoch`) — such ids have all-zero slots
    /// here by construction, so a reused id re-enters an empty slot and
    /// the table stays consistent without any index-side bookkeeping.
    words: Vec<u64>,
    /// Blocks with at least one holder (the old map's `len`).
    resident: usize,
}

impl PrefixIndex {
    /// `MAX_WORDS` bitset words per tier per block at most.
    pub const MAX_NODES: usize = 64 * MAX_WORDS;

    /// Whether a single index can cover `n_nodes` prefill nodes.
    pub fn supports(n_nodes: usize) -> bool {
        n_nodes <= Self::MAX_NODES
    }

    pub fn new(n_nodes: usize) -> Self {
        assert!(Self::supports(n_nodes), "PrefixIndex covers at most {} nodes", Self::MAX_NODES);
        let n_words = n_nodes.div_ceil(64).max(1);
        PrefixIndex { n_nodes, n_words, stride: 2 * n_words, words: Vec::new(), resident: 0 }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Residency words per tier (`div_ceil(n_nodes, 64)`) — the width-
    /// adaptation the footprint depends on.
    pub fn n_words(&self) -> usize {
        self.n_words
    }

    /// Distinct blocks resident anywhere in the cluster.
    pub fn len(&self) -> usize {
        self.resident
    }

    pub fn is_empty(&self) -> bool {
        self.resident == 0
    }

    #[inline]
    fn word_bit(node: usize) -> (usize, u64) {
        (node >> 6, 1u64 << (node & 63))
    }

    /// Record `node`'s residency for one block (`None` = not resident).
    /// Setting one tier clears the other — a block lives in exactly one
    /// tier per pool.
    pub fn set(&mut self, node: usize, b: DenseBlockId, loc: Option<Tier>) {
        debug_assert!(node < self.n_nodes);
        let off = b as usize * self.stride;
        if off + self.stride > self.words.len() {
            if loc.is_none() {
                return; // clearing a block never seen: nothing to do
            }
            self.words.resize(off + self.stride, 0);
        }
        let e = &mut self.words[off..off + self.stride];
        let was_empty = e.iter().all(|&w| w == 0);
        let (w, bit) = Self::word_bit(node);
        e[w] &= !bit;
        e[self.n_words + w] &= !bit;
        match loc {
            Some(Tier::Dram) => e[w] |= bit,
            Some(Tier::Ssd) => e[self.n_words + w] |= bit,
            None => {}
        }
        let now_empty = e.iter().all(|&w| w == 0);
        match (was_empty, now_empty) {
            (true, false) => self.resident += 1,
            (false, true) => self.resident -= 1,
            _ => {}
        }
    }

    /// Apply a pool mutation's residency changes for `node`, in order.
    pub fn apply(&mut self, node: usize, delta: &TierDelta) {
        for &(b, loc) in &delta.changes {
            self.set(node, b, loc);
        }
    }

    #[inline]
    fn entry(&self, b: DenseBlockId) -> Option<&[u64]> {
        let off = b as usize * self.stride;
        self.words.get(off..off + self.stride)
    }

    /// `node`'s residency for one block, as the pool would report it.
    pub fn tier_on(&self, node: usize, b: DenseBlockId) -> Option<Tier> {
        debug_assert!(node < self.n_nodes);
        let e = self.entry(b)?;
        let (w, bit) = Self::word_bit(node);
        if e[w] & bit != 0 {
            Some(Tier::Dram)
        } else if e[self.n_words + w] & bit != 0 {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    /// Every node holding `b` (either tier), ascending — one probe for
    /// the whole cluster, replacing per-pool `contains` scans
    /// (`conductor::migration` reads holder sets through this).
    pub fn holders(&self, b: DenseBlockId) -> Vec<usize> {
        let mut out = Vec::new();
        self.push_holders(b, 0, &mut out);
        out
    }

    /// Append every holder of `b`, offset by `base` — the sharded
    /// index's holder probe collects all shards into one buffer.
    fn push_holders(&self, b: DenseBlockId, base: usize, out: &mut Vec<usize>) {
        if let Some(e) = self.entry(b) {
            for w in 0..self.n_words {
                let mut bits = e[w] | e[self.n_words + w];
                while bits != 0 {
                    out.push(base + w * 64 + bits.trailing_zeros() as usize);
                    bits &= bits - 1;
                }
            }
        }
    }

    /// Bulk-load one node's pool (brute-force rebuild path).
    pub fn insert_pool(&mut self, node: usize, pool: &CachePool) {
        for b in pool.iter_dram_blocks() {
            self.set(node, b, Some(Tier::Dram));
        }
        for b in pool.iter_ssd_blocks() {
            self.set(node, b, Some(Tier::Ssd));
        }
    }

    /// `FindBestPrefixMatch` for **all** nodes in one chain walk:
    /// `out[n]` equals `pools[n].prefix_match_with(hash_ids, …)` exactly
    /// — match, SSD-run summary, and per-node SSD positions — but the
    /// whole cluster costs one array load per chain block instead of one
    /// hash probe per (node, block) pair.  `out` and `ssd_pos` are
    /// caller-owned scratch (cleared here), so steady-state decisions
    /// allocate nothing.
    // lint: hot
    pub fn best_prefix_into(
        &self,
        hash_ids: &[DenseBlockId],
        out: &mut Vec<TierMatch>,
        ssd_pos: &mut SsdPositions,
    ) {
        out.clear();
        out.resize(self.n_nodes, TierMatch::default());
        ssd_pos.reset(self.n_nodes);
        self.walk_into(hash_ids, out, ssd_pos);
        ssd_pos.seal();
    }

    /// The walk core: fill `out` (exactly `n_nodes` default-reset slots)
    /// and push SSD positions into `ssd_pos` (already reset, NOT sealed
    /// here).  Factored out so [`ShardedPrefixIndex`] can aim each
    /// shard's walk at a disjoint slice of one cluster-wide buffer.
    // lint: hot
    fn walk_into(
        &self,
        hash_ids: &[DenseBlockId],
        out: &mut [TierMatch],
        ssd_pos: &mut SsdPositions,
    ) {
        debug_assert_eq!(out.len(), self.n_nodes);
        if self.n_nodes == 0 {
            return;
        }
        // Nodes whose match still extends / whose match is still a pure
        // DRAM run.  A cleared bit means that node's `blocks` (resp.
        // `dram_prefix`) has been finalized in `out`.
        let mut alive = [0u64; MAX_WORDS];
        for w in 0..self.n_words {
            let bits = self.n_nodes - w * 64;
            alive[w] = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
        }
        let mut dram_run = alive;
        for (i, &b) in hash_ids.iter().enumerate() {
            if alive[..self.n_words].iter().all(|&w| w == 0) {
                break;
            }
            let entry = self.entry(b);
            for w in 0..self.n_words {
                if alive[w] == 0 {
                    continue;
                }
                let (dram_w, ssd_w) = match entry {
                    Some(e) => (e[w], e[self.n_words + w]),
                    None => (0, 0),
                };
                let base = w * 64;
                let resident = (dram_w | ssd_w) & alive[w];
                // Nodes missing this block: their match ends at i blocks.
                let mut died = alive[w] & !resident;
                while died != 0 {
                    let bit = died & died.wrapping_neg();
                    let n = base + bit.trailing_zeros() as usize;
                    died ^= bit;
                    out[n].blocks = i;
                    if dram_run[w] & bit != 0 {
                        out[n].dram_prefix = i;
                    }
                }
                alive[w] = resident;
                dram_run[w] &= resident;
                // Nodes whose block is SSD-resident: their pure-DRAM
                // leading run ends here (and the block counts as an SSD
                // copy).
                let mut run_end = dram_run[w] & !dram_w;
                while run_end != 0 {
                    let n = base + run_end.trailing_zeros() as usize;
                    run_end &= run_end - 1;
                    out[n].dram_prefix = i;
                }
                dram_run[w] &= dram_w;
                let mut on_ssd = alive[w] & ssd_w;
                while on_ssd != 0 {
                    let n = base + on_ssd.trailing_zeros() as usize;
                    on_ssd &= on_ssd - 1;
                    out[n].ssd_blocks += 1;
                    out[n].ssd_last = i as u32;
                    ssd_pos.push(n, i as u32);
                }
            }
        }
        // Survivors matched the whole chain.
        let full = hash_ids.len();
        for w in 0..self.n_words {
            let base = w * 64;
            let mut still = alive[w];
            while still != 0 {
                let bit = still & still.wrapping_neg();
                let n = base + bit.trailing_zeros() as usize;
                still ^= bit;
                out[n].blocks = full;
                if dram_run[w] & bit != 0 {
                    out[n].dram_prefix = full;
                }
            }
        }
        for m in out.iter_mut() {
            m.dram_blocks = m.blocks - m.ssd_blocks;
        }
    }

    /// Allocating convenience wrapper around [`Self::best_prefix_into`].
    pub fn best_prefix(&self, hash_ids: &[DenseBlockId]) -> Vec<TierMatch> {
        let mut out = Vec::new();
        let mut ssd_pos = SsdPositions::default();
        self.best_prefix_into(hash_ids, &mut out, &mut ssd_pos);
        out
    }

    /// Debug invariant: the incrementally maintained index equals a
    /// brute-force rebuild from the pools (in node order).  The fresh
    /// table may be shorter (it only grows to the highest *resident*
    /// dense id); any overhang must be all-zero.
    pub fn equals_rebuild_of<'a>(&self, pools: impl Iterator<Item = &'a CachePool>) -> bool {
        let mut fresh = PrefixIndex::new(self.n_nodes);
        let mut count = 0usize;
        for (n, pool) in pools.enumerate() {
            fresh.insert_pool(n, pool);
            count = n + 1;
        }
        if count != self.n_nodes || fresh.resident != self.resident {
            return false;
        }
        let (a, b) = (&self.words, &fresh.words);
        let common = a.len().min(b.len());
        a[..common] == b[..common]
            && a[common..].iter().all(|&w| w == 0)
            && b[common..].iter().all(|&w| w == 0)
    }
}

/// The cluster-scale prefix index (ROADMAP item 3): fixed
/// [`Self::SHARD_NODES`]-node groups, one monolithic [`PrefixIndex`]
/// per group.  Shard `s` owns global nodes `[s·256, (s+1)·256)`; every
/// mutation routes to the one owning shard, so per-block storage stays
/// `O(shard_width)` however wide the cluster grows.  The walk fills
/// disjoint 256-node slices of the caller's output buffer — shard-by-
/// shard sequentially, or fanned out across `std::thread::scope`
/// workers — and merges SSD positions in shard order, so results are
/// **bit-for-bit identical** to a single flat walk at any worker count.
#[derive(Debug)]
pub struct ShardedPrefixIndex {
    n_nodes: usize,
    shards: Vec<PrefixIndex>,
}

impl ShardedPrefixIndex {
    /// Nodes per shard — one full-width monolithic index each.
    pub const SHARD_NODES: usize = PrefixIndex::MAX_NODES;

    /// Covers any cluster size: `div_ceil(n_nodes, SHARD_NODES)` shards
    /// (at least one), the last possibly partial.
    pub fn new(n_nodes: usize) -> Self {
        let n_shards = n_nodes.div_ceil(Self::SHARD_NODES).max(1);
        let shards = (0..n_shards)
            .map(|s| {
                let base = s * Self::SHARD_NODES;
                PrefixIndex::new(n_nodes.saturating_sub(base).min(Self::SHARD_NODES))
            })
            .collect();
        ShardedPrefixIndex { n_nodes, shards }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard monolithic indexes (inspection / tests).
    pub fn shards(&self) -> &[PrefixIndex] {
        &self.shards
    }

    /// Sum of per-shard resident counts.  A block held in several
    /// *shards* counts once per shard (shards don't see each other), so
    /// this upper-bounds the cluster-distinct count; within one shard it
    /// is exact, and for ≤ 256 nodes it equals the monolithic `len`.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    #[inline]
    fn route(&self, node: usize) -> (usize, usize) {
        debug_assert!(node < self.n_nodes);
        (node / Self::SHARD_NODES, node % Self::SHARD_NODES)
    }

    /// Record `node`'s residency for one block (`None` = not resident).
    pub fn set(&mut self, node: usize, b: DenseBlockId, loc: Option<Tier>) {
        let (s, ln) = self.route(node);
        self.shards[s].set(ln, b, loc);
    }

    /// Apply a pool mutation's residency changes: routed to the one
    /// shard owning `node`.
    pub fn apply(&mut self, node: usize, delta: &TierDelta) {
        let (s, ln) = self.route(node);
        self.shards[s].apply(ln, delta);
    }

    /// `node`'s residency for one block, as the pool would report it.
    pub fn tier_on(&self, node: usize, b: DenseBlockId) -> Option<Tier> {
        let (s, ln) = self.route(node);
        self.shards[s].tier_on(ln, b)
    }

    /// Every node holding `b` (either tier), ascending across the whole
    /// cluster — shards probed in order, offsets applied.
    pub fn holders(&self, b: DenseBlockId) -> Vec<usize> {
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            shard.push_holders(b, s * Self::SHARD_NODES, &mut out);
        }
        out
    }

    /// Bulk-load one node's pool (brute-force rebuild path).
    pub fn insert_pool(&mut self, node: usize, pool: &CachePool) {
        let (s, ln) = self.route(node);
        self.shards[s].insert_pool(ln, pool);
    }

    /// `FindBestPrefixMatch` for all nodes, sharded: identical outputs
    /// to a monolithic [`PrefixIndex::best_prefix_into`] over the same
    /// residency.  `shard_pos` is per-shard position scratch (warmed
    /// once, untouched in the common ≤ 256-node case, where the one
    /// shard walks straight into the caller's buffers).  `workers > 1`
    /// fans the shard walks out over scoped threads; the shard-ordered
    /// merge keeps the result bit-for-bit independent of worker count.
    // lint: hot
    pub fn best_prefix_into(
        &self,
        hash_ids: &[DenseBlockId],
        out: &mut Vec<TierMatch>,
        ssd_pos: &mut SsdPositions,
        shard_pos: &mut Vec<SsdPositions>,
        workers: usize,
    ) {
        if self.shards.len() == 1 {
            return self.shards[0].best_prefix_into(hash_ids, out, ssd_pos);
        }
        out.clear();
        out.resize(self.n_nodes, TierMatch::default());
        ssd_pos.reset(self.n_nodes);
        if shard_pos.len() < self.shards.len() {
            shard_pos.resize_with(self.shards.len(), SsdPositions::default);
        }
        let workers = workers.clamp(1, self.shards.len());
        if workers <= 1 {
            for ((shard, o), pos) in self
                .shards
                .iter()
                .zip(out.chunks_mut(Self::SHARD_NODES))
                .zip(shard_pos.iter_mut())
            {
                pos.reset(shard.n_nodes());
                shard.walk_into(hash_ids, o, pos);
                pos.seal();
            }
        } else {
            std::thread::scope(|scope| {
                let mut out_rest: &mut [TierMatch] = out;
                let mut pos_rest: &mut [SsdPositions] = shard_pos;
                let mut lo = 0usize;
                for w in 0..workers {
                    let take = (self.shards.len() - lo).div_ceil(workers - w);
                    let shards = &self.shards[lo..lo + take];
                    let slots: usize = shards.iter().map(|s| s.n_nodes()).sum();
                    let (out_mine, r) = out_rest.split_at_mut(slots);
                    out_rest = r;
                    let (pos_mine, r) = pos_rest.split_at_mut(take);
                    pos_rest = r;
                    lo += take;
                    scope.spawn(move || {
                        for ((shard, o), pos) in shards
                            .iter()
                            .zip(out_mine.chunks_mut(Self::SHARD_NODES))
                            .zip(pos_mine.iter_mut())
                        {
                            pos.reset(shard.n_nodes());
                            shard.walk_into(hash_ids, o, pos);
                            pos.seal();
                        }
                    });
                }
            });
        }
        // Deterministic merge: shard order, then node order within each
        // shard (counting-sorted again by the final seal) — the same
        // (node, position) multiset a flat walk would have produced.
        for (s, pos) in shard_pos[..self.shards.len()].iter().enumerate() {
            let base = s * Self::SHARD_NODES;
            for ln in 0..self.shards[s].n_nodes() {
                for &p in pos.node(ln) {
                    ssd_pos.push(base + ln, p);
                }
            }
        }
        ssd_pos.seal();
    }

    /// Allocating convenience wrapper around [`Self::best_prefix_into`].
    pub fn best_prefix(&self, hash_ids: &[DenseBlockId]) -> Vec<TierMatch> {
        let mut out = Vec::new();
        let mut ssd_pos = SsdPositions::default();
        let mut shard_pos = Vec::new();
        self.best_prefix_into(hash_ids, &mut out, &mut ssd_pos, &mut shard_pos, 1);
        out
    }

    /// Debug invariant: every shard equals a brute-force rebuild from
    /// its slice of the pools (in node order).
    pub fn equals_rebuild_of<'a>(&self, pools: impl Iterator<Item = &'a CachePool>) -> bool {
        let pools: Vec<&CachePool> = pools.collect();
        if pools.len() != self.n_nodes {
            return false;
        }
        self.shards.iter().enumerate().all(|(s, shard)| {
            let base = s * Self::SHARD_NODES;
            let hi = (base + shard.n_nodes()).min(pools.len());
            shard.equals_rebuild_of(pools[base..hi].iter().copied())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PolicyKind;

    fn pools(n: usize) -> Vec<CachePool> {
        (0..n).map(|_| CachePool::new(PolicyKind::Lru, Some(64), Some(64))).collect()
    }

    fn scan(pools: &[CachePool], chain: &[DenseBlockId]) -> Vec<TierMatch> {
        pools.iter().map(|p| p.prefix_match(chain)).collect()
    }

    #[test]
    fn width_adapts_to_the_cluster() {
        assert_eq!(PrefixIndex::new(1).n_words(), 1);
        assert_eq!(PrefixIndex::new(8).n_words(), 1);
        assert_eq!(PrefixIndex::new(64).n_words(), 1);
        assert_eq!(PrefixIndex::new(65).n_words(), 2);
        assert_eq!(PrefixIndex::new(128).n_words(), 2);
        assert_eq!(PrefixIndex::new(129).n_words(), 3);
        assert_eq!(PrefixIndex::new(256).n_words(), 4);
        // Small clusters are back to one word per tier: 16 B per block
        // slot instead of the old fixed 64.
        let mut idx = PrefixIndex::new(8);
        idx.set(3, 0, Some(Tier::Dram));
        idx.set(3, 1, Some(Tier::Ssd));
        assert_eq!(idx.words.len(), 2 * idx.stride);
        assert_eq!(idx.stride, 2);
    }

    #[test]
    fn best_prefix_matches_per_pool_scan() {
        let mut ps = pools(3);
        let mut idx = PrefixIndex::new(3);
        let chain: Vec<DenseBlockId> = (10..20).collect();
        // Node 0: full chain in DRAM; node 1: first half, with one block
        // demoted to SSD; node 2: nothing.
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        idx.apply(1, &ps[1].admit_chain(&chain[..5], 0.0));
        idx.apply(1, &ps[1].demote_block(12, 1.0).unwrap());
        let got = idx.best_prefix(&chain);
        let want = scan(&ps, &chain);
        assert_eq!(got, want);
        assert_eq!(got[0].blocks, 10);
        assert_eq!(
            got[1],
            TierMatch { blocks: 5, dram_prefix: 2, dram_blocks: 4, ssd_blocks: 1, ssd_last: 2 }
        );
        assert_eq!(got[2], TierMatch::default());
        assert!(idx.equals_rebuild_of(ps.iter()));
        // Holder probes agree with the pools.
        assert_eq!(idx.holders(12), vec![0, 1]);
        assert_eq!(idx.holders(17), vec![0]);
        assert_eq!(idx.holders(999), Vec::<usize>::new());
    }

    #[test]
    fn walk_positions_match_scan_positions() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        let chain: Vec<DenseBlockId> = (100..108).collect();
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        for b in [101, 103, 104] {
            idx.apply(0, &ps[0].demote_block(b, 1.0).unwrap());
        }
        idx.apply(1, &ps[1].admit_chain(&chain[..3], 0.0));
        let mut out = Vec::new();
        let mut walk_pos = SsdPositions::default();
        idx.best_prefix_into(&chain, &mut out, &mut walk_pos);
        let mut scan_list = Vec::new();
        for (n, p) in ps.iter().enumerate() {
            let m = p.prefix_match_with(&chain, &mut scan_list);
            assert_eq!(out[n], m, "node {n}");
            assert_eq!(walk_pos.node(n), &scan_list[..], "node {n} positions");
        }
        assert_eq!(walk_pos.node(0), &[1, 3, 4]);
        assert_eq!(out[0].ssd_last, 4);
        assert!(walk_pos.node(1).is_empty());
    }

    #[test]
    fn tier_on_tracks_moves_and_drops() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        idx.apply(0, &ps[0].admit_chain(&[1, 2], 0.0));
        idx.apply(1, &ps[1].admit_chain(&[2], 0.0));
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Dram));
        assert_eq!(idx.tier_on(1, 1), None);
        assert_eq!(idx.tier_on(1, 2), Some(Tier::Dram));
        idx.apply(0, &ps[0].demote_block(1, 1.0).unwrap());
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Ssd));
        // A drop removes the node's bit; the last holder's drop zeroes
        // the slot and the block stops counting as resident.
        idx.set(0, 1, None);
        assert_eq!(idx.tier_on(0, 1), None);
        assert_eq!(idx.len(), 1); // only block 2 remains
        // Clearing a block the index never saw is a no-op.
        idx.set(0, 10_000, None);
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn eviction_pressure_keeps_index_consistent() {
        // A 4-block DRAM tier over a 6-block SSD tier: admissions demote
        // and eventually drop; the deltas must keep the index equal to a
        // rebuild at every step, and best_prefix equal to the scan.
        let mut ps = vec![CachePool::new(PolicyKind::Lru, Some(4), Some(6))];
        let mut idx = PrefixIndex::new(1);
        for round in 0..8u32 {
            let chain: Vec<DenseBlockId> = (round * 3..round * 3 + 4).collect();
            let delta = ps[0].admit_chain(&chain, round as f64);
            idx.apply(0, &delta);
            assert!(idx.equals_rebuild_of(ps.iter()), "round {round}");
            assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain), "round {round}");
        }
    }

    #[test]
    fn wide_clusters_cross_word_boundaries() {
        // The residency bitset is width-adaptive, so one index covers
        // well past 64 prefill nodes with no fallback.
        assert!(PrefixIndex::supports(65));
        assert!(PrefixIndex::supports(PrefixIndex::MAX_NODES));
        assert!(!PrefixIndex::supports(PrefixIndex::MAX_NODES + 1));
        let n = 130; // three words, last one partial
        let mut ps = pools(n);
        let mut idx = PrefixIndex::new(n);
        assert_eq!(idx.n_words(), 3);
        let chain: Vec<DenseBlockId> = (1_000..1_016).collect();
        // Holders straddling every word: 0, 63, 64, 77, 127, 128, 129.
        for &node in &[0usize, 63, 64, 77, 127, 128, 129] {
            let len = 4 + node % 12;
            idx.apply(node, &ps[node].admit_chain(&chain[..len], 0.0));
        }
        idx.apply(77, &ps[77].demote_block(1_001, 1.0).unwrap());
        idx.apply(129, &ps[129].demote_block(1_000, 1.0).unwrap());
        assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain));
        assert!(idx.equals_rebuild_of(ps.iter()));
        assert_eq!(idx.tier_on(77, 1_001), Some(Tier::Ssd));
        assert_eq!(idx.tier_on(129, 1_000), Some(Tier::Ssd));
        assert_eq!(idx.holders(1_000), vec![0, 63, 64, 77, 127, 128, 129]);
        // Bit 63 of a full word and bit 0 of the next stay distinct.
        assert_eq!(idx.tier_on(63, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(64, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(65, 1_003), None);
    }

    #[test]
    fn max_width_masks_have_no_shift_overflow() {
        let last = PrefixIndex::MAX_NODES - 1;
        let mut idx = PrefixIndex::new(PrefixIndex::MAX_NODES);
        idx.set(last, 7, Some(Tier::Ssd));
        idx.set(63, 7, Some(Tier::Dram));
        assert_eq!(idx.tier_on(last, 7), Some(Tier::Ssd));
        let m = idx.best_prefix(&[7]);
        assert_eq!(
            m[last],
            TierMatch { blocks: 1, dram_prefix: 0, dram_blocks: 0, ssd_blocks: 1, ssd_last: 0 }
        );
        assert_eq!(
            m[63],
            TierMatch {
                blocks: 1,
                dram_prefix: 1,
                dram_blocks: 1,
                ssd_blocks: 0,
                ssd_last: TierMatch::NO_SSD
            }
        );
        assert_eq!(m[0], TierMatch::default());
    }

    #[test]
    fn empty_chain_and_empty_index() {
        let idx = PrefixIndex::new(2);
        assert!(idx.is_empty());
        let m = idx.best_prefix(&[]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
        let m = idx.best_prefix(&[99]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
    }

    #[test]
    fn sharding_tiles_any_cluster_width() {
        assert_eq!(ShardedPrefixIndex::new(1).n_shards(), 1);
        assert_eq!(ShardedPrefixIndex::new(256).n_shards(), 1);
        assert_eq!(ShardedPrefixIndex::new(257).n_shards(), 2);
        assert_eq!(ShardedPrefixIndex::new(1024).n_shards(), 4);
        // Partial trailing shard gets exactly the leftover nodes, and
        // every full shard stays at the per-shard word ceiling.
        let idx = ShardedPrefixIndex::new(300);
        assert_eq!(idx.n_nodes(), 300);
        assert_eq!(idx.shards().len(), 2);
        assert_eq!(idx.shards()[0].n_nodes(), 256);
        assert_eq!(idx.shards()[1].n_nodes(), 44);
        assert_eq!(idx.shards()[1].n_words(), 1); // footprint tracks shard width
    }

    /// Builds a 300-node (two-shard) environment with holders straddling
    /// the 255/256/257 shard boundary, plus demotions on both sides.
    fn sharded_env() -> (Vec<CachePool>, ShardedPrefixIndex, Vec<DenseBlockId>) {
        let nodes = [0usize, 5, 200, 254, 255, 256, 257, 299];
        let mut ps = pools(300);
        let mut idx = ShardedPrefixIndex::new(300);
        let chain: Vec<DenseBlockId> = (2_000..2_048).collect();
        for &node in &nodes {
            let len = 4 + node % 40;
            idx.apply(node, &ps[node].admit_chain(&chain[..len], 0.0));
        }
        idx.apply(255, &ps[255].demote_block(chain[2], 1.0).unwrap());
        idx.apply(256, &ps[256].demote_block(chain[0], 1.0).unwrap());
        (ps, idx, chain)
    }

    #[test]
    fn sharded_index_matches_per_pool_scan_across_the_boundary() {
        let (ps, idx, chain) = sharded_env();
        assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain));
        assert!(idx.equals_rebuild_of(ps.iter()));
        // Routing lands residency on the right side of the 256 split.
        assert_eq!(idx.tier_on(255, chain[2]), Some(Tier::Ssd));
        assert_eq!(idx.tier_on(256, chain[0]), Some(Tier::Ssd));
        assert_eq!(idx.tier_on(257, chain[1]), Some(Tier::Dram));
        assert_eq!(idx.tier_on(1, chain[0]), None);
        // Holder probes cross shards in ascending global node order.
        assert_eq!(idx.holders(chain[0]), vec![0, 5, 200, 254, 255, 256, 257, 299]);
        assert_eq!(idx.holders(chain[20]), vec![257, 299]); // only lens 21 and 23 reach it
        assert_eq!(idx.holders(9_999), Vec::<usize>::new());
        // Per-node SSD positions agree with the pools' own scan.
        let mut out = Vec::new();
        let mut pos = SsdPositions::default();
        let mut shard_pos = Vec::new();
        idx.best_prefix_into(&chain, &mut out, &mut pos, &mut shard_pos, 1);
        let mut scan_list = Vec::new();
        for (n, p) in ps.iter().enumerate() {
            let m = p.prefix_match_with(&chain, &mut scan_list);
            assert_eq!(out[n], m, "node {n}");
            assert_eq!(pos.node(n), &scan_list[..], "node {n} positions");
        }
        assert_eq!(pos.node(255), &[2]);
        assert_eq!(pos.node(256), &[0]);
    }

    #[test]
    fn sharded_walk_is_worker_count_invariant() {
        // The whole determinism story rests on this: any worker count
        // produces bit-for-bit the sequential walk's matches *and*
        // positions, so `sched_workers` can never perturb placement.
        let (_ps, idx, chain) = sharded_env();
        let mut base_out = Vec::new();
        let mut base_pos = SsdPositions::default();
        let mut shard_pos = Vec::new();
        idx.best_prefix_into(&chain, &mut base_out, &mut base_pos, &mut shard_pos, 1);
        for workers in [2usize, 3, 8] {
            let mut out = Vec::new();
            let mut pos = SsdPositions::default();
            idx.best_prefix_into(&chain, &mut out, &mut pos, &mut shard_pos, workers);
            assert_eq!(out, base_out, "{workers} workers");
            for n in 0..idx.n_nodes() {
                assert_eq!(pos.node(n), base_pos.node(n), "{workers} workers, node {n}");
            }
        }
    }

    #[test]
    fn single_shard_delegates_bit_for_bit_to_monolithic() {
        // ≤ 256 nodes: the sharded wrapper routes straight into one
        // monolithic shard, so outputs are the monolithic index's own.
        let mut ps = pools(130);
        let mut mono = PrefixIndex::new(130);
        let mut sharded = ShardedPrefixIndex::new(130);
        assert_eq!(sharded.n_shards(), 1);
        let chain: Vec<DenseBlockId> = (7_000..7_016).collect();
        for &node in &[0usize, 63, 64, 77, 129] {
            let d = ps[node].admit_chain(&chain[..4 + node % 12], 0.0);
            mono.apply(node, &d);
            sharded.apply(node, &d);
        }
        let d = ps[77].demote_block(7_001, 1.0).unwrap();
        mono.apply(77, &d);
        sharded.apply(77, &d);
        assert_eq!(sharded.best_prefix(&chain), mono.best_prefix(&chain));
        assert_eq!(sharded.holders(7_000), mono.holders(7_000));
        assert_eq!(sharded.len(), mono.len());
        let (mut mo, mut mp) = (Vec::new(), SsdPositions::default());
        mono.best_prefix_into(&chain, &mut mo, &mut mp);
        let (mut so, mut sp, mut scratch) = (Vec::new(), SsdPositions::default(), Vec::new());
        sharded.best_prefix_into(&chain, &mut so, &mut sp, &mut scratch, 4);
        assert_eq!(so, mo);
        for n in 0..130 {
            assert_eq!(sp.node(n), mp.node(n), "node {n}");
        }
        assert!(scratch.is_empty(), "single-shard walk must not touch per-shard scratch");
        assert!(sharded.equals_rebuild_of(ps.iter()));
    }
}
