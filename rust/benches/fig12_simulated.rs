//! Fig 12 — end-to-end comparison on simulated long-context data (16k /
//! 32k / 64k / 128k input, 512 output, 50% prefix cache ratio):
//! Mooncake-[3P+1D] / [2P+2D] vs vLLM-[4M].
//!
//! Paper: the long prefills wreck vLLM's TBT (it must process requests
//! individually), while Mooncake's disaggregation never breaks the TBT
//! SLO — throughput gains of 50% to 525%.

use mooncake::baseline::{self, VllmConfig};
use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{SimConfig, SloConfig};
use mooncake::model::PerfModel;
use mooncake::sim;
use mooncake::trace::gen;

fn main() {
    let perf = PerfModel::paper();
    let datasets = ["sim16k", "sim32k", "sim64k", "sim128k"];
    let rps_grid = [0.05, 0.1, 0.2, 0.4, 0.8, 1.2];

    let mut gains = Vec::new();
    for ds in datasets {
        let mean_in: u64 = match ds {
            "sim16k" => 16_384,
            "sim32k" => 32_768,
            "sim64k" => 65_536,
            _ => 131_072,
        };
        let slo = SloConfig {
            ttft_ms: 10.0 * perf.prefill_ms(mean_in, 0),
            tbt_ms: 5.0 * perf.decode_step_ms(1, mean_in),
        };
        banner(&format!("Fig 12: {ds} (SLO TTFT {:.0} ms, TBT {:.0} ms)", slo.ttft_ms, slo.tbt_ms));
        row(&["system".into(), "rps".into(), "P90_TTFT/SLO".into(), "P90_TBT/SLO".into()]);

        let mut best_vllm = 0.0f64;
        let mut best_mc = 0.0f64;
        for &rps in &rps_grid {
            let trace = gen::dataset(ds, 150, rps, 23);
            // vLLM serial mode for long context (§8.1.2).
            let vcfg = VllmConfig { n_instances: 4, serial_mode: true, slo, ..Default::default() };
            let vrep = baseline::run(&vcfg, &trace, 1.0);
            row(&[
                "vLLM-[4M]".into(),
                fmt(rps, 2),
                fmt(vrep.ttft_p90 / slo.ttft_ms, 2),
                fmt(vrep.tbt_p90 / slo.tbt_ms, 2),
            ]);
            if vrep.ttft_p90 <= slo.ttft_ms && vrep.tbt_p90 <= slo.tbt_ms
                && vrep.slo_attainment >= 0.9
            {
                best_vllm = best_vllm.max(rps);
            }
            let mcfg = SimConfig { n_prefill: 3, n_decode: 1, slo, ..Default::default() };
            let mrep = sim::run(&mcfg, &trace, 1.0).report(&mcfg);
            row(&[
                "Mooncake-[3P+1D]".into(),
                fmt(rps, 2),
                fmt(mrep.ttft_p90 / slo.ttft_ms, 2),
                fmt(mrep.tbt_p90 / slo.tbt_ms, 2),
            ]);
            if mrep.ttft_p90 <= slo.ttft_ms && mrep.tbt_p90 <= slo.tbt_ms
                && mrep.slo_attainment >= 0.9
            {
                best_mc = best_mc.max(rps);
            }
        }
        let gain = if best_vllm > 0.0 { (best_mc / best_vllm - 1.0) * 100.0 } else { f64::INFINITY };
        println!("max RPS: vLLM {best_vllm:.2}, Mooncake {best_mc:.2} (+{gain:.0}%)");
        gains.push((ds, best_vllm, best_mc));
    }

    for (ds, v, m) in &gains {
        assert!(m >= v, "{ds}: Mooncake ({m}) must sustain >= vLLM ({v})");
    }
    // At least one long-context point must show a large (>=50%) gain.
    assert!(
        gains.iter().any(|(_, v, m)| *v == 0.0 || m / v >= 1.5),
        "expected a >=50% throughput gain somewhere: {gains:?}"
    );
    println!("\nfig12 shape checks OK");
}
