//! Cross-module integration tests: trace generation -> JSONL roundtrip ->
//! full cluster simulation -> reports, plus Mooncake-vs-vLLM end-to-end
//! comparisons that mirror the paper's headline claims at small scale.

use mooncake::baseline::{self, VllmConfig};
use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig, SloConfig};
use mooncake::kvcache::PolicyKind;
use mooncake::metrics::Outcome;
use mooncake::model::PerfModel;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::{jsonl, stats};
use mooncake::verify::Paranoia;

fn trace(n: usize) -> Vec<mooncake::trace::TraceRecord> {
    gen::generate(&TraceGenConfig { n_requests: n, duration_ms: 1_200_000, ..Default::default() })
}

#[test]
fn trace_jsonl_roundtrip_preserves_simulation() {
    let t1 = trace(300);
    let path = std::env::temp_dir().join("mooncake_integration_trace.jsonl");
    jsonl::save(&path, &t1).unwrap();
    let t2 = jsonl::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(t1.len(), t2.len());

    let cfg = SimConfig::default();
    let r1 = sim::run(&cfg, &t1, 1.0).report(&cfg);
    let r2 = sim::run(&cfg, &t2, 1.0).report(&cfg);
    assert_eq!(r1.n_completed, r2.n_completed);
    assert!((r1.ttft_p90 - r2.ttft_p90).abs() < 1e-6);
}

#[test]
fn mooncake_beats_vllm_on_long_context_tbt() {
    // The paper's central end-to-end claim (Fig 12/13): disaggregation
    // keeps TBT bounded where coupled prefill wrecks it.
    let perf = PerfModel::paper();
    let slo = SloConfig {
        ttft_ms: 10.0 * perf.prefill_ms(65_536, 0),
        tbt_ms: 5.0 * perf.decode_step_ms(1, 65_536),
    };
    let data = gen::dataset("sim64k", 60, 0.3, 5);

    let vcfg = VllmConfig { n_instances: 4, slo, ..Default::default() };
    let vrep = baseline::run(&vcfg, &data, 1.0);

    let mcfg = SimConfig { n_prefill: 3, n_decode: 1, slo, ..Default::default() };
    let mrep = sim::run(&mcfg, &data, 1.0).report(&mcfg);

    assert!(
        mrep.tbt_p90 < vrep.tbt_p90,
        "Mooncake P90 TBT {} must beat vLLM {}",
        mrep.tbt_p90,
        vrep.tbt_p90
    );
    assert!(mrep.tbt_p90 <= slo.tbt_ms, "Mooncake must hold the TBT SLO");
}

#[test]
fn rejection_policies_ranked_by_waste() {
    // Table 3's mechanism: baseline wastes prefill, early rejection does
    // not, prediction completes at least as many requests.
    // Decode-contended regime: few decode slots relative to prefill
    // throughput, so the decode double-check actually fires.
    let t = trace(1_500);
    let run = |rej| {
        let cfg = SimConfig {
            n_prefill: 3,
            n_decode: 1,
            max_decode_batch: 16,
            rejection: rej,
            ..Default::default()
        };
        let res = sim::run(&cfg, &t, 6.0);
        let rep = res.report(&cfg);
        (rep.wasted_prefill_tokens, rep.n_completed, rep.n_rejected_after_prefill)
    };
    let (base_waste, base_done, base_after) = run(RejectionPolicy::Baseline);
    let (early_waste, _early_done, early_after) = run(RejectionPolicy::Early);
    let (pred_waste, pred_done, _pred_after) = run(RejectionPolicy::Predictive);

    assert!(base_after > 0, "baseline must reject some requests after prefill");
    assert!(
        early_after <= base_after && early_waste <= base_waste,
        "early rejection must waste less: {early_waste} vs {base_waste}"
    );
    assert!(pred_waste <= base_waste);
    assert!(
        pred_done + 50 >= base_done,
        "prediction must not complete meaningfully fewer: {pred_done} vs {base_done}"
    );
}

#[test]
fn scheduling_policies_ordered_on_reuse() {
    let t = trace(800);
    let run = |pol| {
        let cfg = SimConfig { scheduling: pol, n_prefill: 4, n_decode: 4, ..Default::default() };
        let res = sim::run(&cfg, &t, 1.0);
        (res.report(&cfg).ttft_mean, res.conductor.reused_blocks)
    };
    let (ttft_rand, reuse_rand) = run(SchedulingPolicy::Random);
    let (ttft_lb, _) = run(SchedulingPolicy::LoadBalance);
    let (ttft_ca, reuse_ca) = run(SchedulingPolicy::CacheAware);
    let (ttft_kc, reuse_kc) = run(SchedulingPolicy::KvCacheCentric);

    assert!(ttft_ca < ttft_rand, "cache-aware {ttft_ca} !< random {ttft_rand}");
    assert!(ttft_kc < ttft_rand, "centric {ttft_kc} !< random {ttft_rand}");
    assert!(ttft_kc < ttft_lb * 1.05, "centric should not lose badly to load-balance");
    assert!(reuse_ca > reuse_rand && reuse_kc > reuse_rand);
}

#[test]
fn token_and_tier_conservation_end_to_end() {
    // Two conservation laws over a full simulated run:
    //  1. every token the decode pool emitted belongs to exactly one
    //     finished sequence — sum(DecodeInstance::tokens_out) equals the
    //     total FinishedSeq::generated the metrics recorded;
    //  2. every block the scheduler counted as reused was served by
    //     exactly one cache tier — dram_hits + ssd_hits equals
    //     ConductorStats::reused_blocks.
    let t = trace(400);
    let cfg = SimConfig::default();
    let res = sim::run(&cfg, &t, 1.0);
    let generated: u64 = res.metrics.iter().map(|m| m.generated).sum();
    assert!(generated > 0);
    assert_eq!(res.decode_tokens_out, generated, "decode emitted orphan tokens");
    assert_eq!(
        res.tier.dram_hits + res.tier.ssd_hits,
        res.conductor.reused_blocks,
        "per-tier hits must sum to the scheduler's reused blocks"
    );
    // SSD byte accounting is internally consistent, and the report
    // carries the same tier counters the simulator aggregated.
    assert_eq!(res.ssd_loaded_bytes, res.ssd_loaded_bytes_by_node.iter().sum::<u64>());
    let rep = res.report(&cfg);
    assert_eq!(rep.tiers, res.tier);

    // The same laws under tier pressure (tiny DRAM, live SSD tier).
    let cfg2 = SimConfig {
        cache_capacity_blocks: Some(300),
        ssd_capacity_blocks: Some(50_000),
        n_prefill: 2,
        n_decode: 2,
        ..Default::default()
    };
    let res2 = sim::run(&cfg2, &t, 1.0);
    let generated2: u64 = res2.metrics.iter().map(|m| m.generated).sum();
    assert_eq!(res2.decode_tokens_out, generated2);
    assert_eq!(res2.tier.dram_hits + res2.tier.ssd_hits, res2.conductor.reused_blocks);
    assert!(res2.tier.demotions > 0, "DRAM pressure must demote");
    // Staged bytes observed via SsdLoad events match the scheduler's
    // block decisions exactly (both sides of the same cost model): one
    // event per local staging decision plus one per fetch whose source
    // staged from its own SSD tier.
    if res2.conductor.ssd_loads > 0 {
        assert!(
            res2.ssd_load_events == res2.conductor.ssd_loads + res2.conductor.fetch_stagings
        );
        assert!(res2.ssd_loaded_bytes > 0);
    }
}

/// Bit-for-bit equality of two runs that must be indistinguishable.
fn assert_runs_identical(a: &sim::SimResult, b: &sim::SimResult) {
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.iter().zip(&b.metrics) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.outcome, y.outcome, "request {}", x.id);
        assert_eq!(x.ttft_ms.to_bits(), y.ttft_ms.to_bits(), "request {}", x.id);
        assert_eq!(x.est_ttft_ms.to_bits(), y.est_ttft_ms.to_bits());
        assert_eq!(x.max_tbt_ms.to_bits(), y.max_tbt_ms.to_bits());
        assert_eq!(x.mean_tbt_ms.to_bits(), y.mean_tbt_ms.to_bits());
        assert_eq!(x.generated, y.generated);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits());
    }
    assert_eq!(a.conductor, b.conductor);
    assert_eq!(a.tier, b.tier);
    assert_eq!(a.wall_ms.to_bits(), b.wall_ms.to_bits());
    assert_eq!(a.transfer_bytes, b.transfer_bytes);
    assert_eq!(a.rejected_at_arrival, b.rejected_at_arrival);
    assert_eq!(a.rejected_at_decode, b.rejected_at_decode);
    assert_eq!(a.ssd_load_events, b.ssd_load_events);
    assert_eq!(a.ssd_loaded_bytes_by_node, b.ssd_loaded_bytes_by_node);
    assert_eq!(a.decode_tokens_out, b.decode_tokens_out);
    assert_eq!(a.n_events, b.n_events);
    assert_eq!(a.n_completed, b.n_completed);
    assert_eq!(a.n_rejected, b.n_rejected);
    assert_eq!(a.live_peak, b.live_peak);
    assert_eq!(a.interner_epochs, b.interner_epochs);
    assert_eq!(a.interner_freed, b.interner_freed);
    assert_eq!(a.interner_id_space, b.interner_id_space);
    assert_eq!(a.resources, b.resources);
    assert_eq!(a.load_samples.len(), b.load_samples.len());
    for (x, y) in a.load_samples.iter().zip(&b.load_samples) {
        assert_eq!(x.t.to_bits(), y.t.to_bits());
        assert_eq!(x.prefill_load.to_bits(), y.prefill_load.to_bits());
        assert_eq!(x.decode_load.to_bits(), y.decode_load.to_bits());
    }
}

#[test]
fn resource_queues_with_unconstrained_knobs_match_pre_refactor_model() {
    // The tentpole's regression pin: with rx bandwidth and NVMe write
    // bandwidth unconstrained (the defaults — `None` and an explicit
    // `f64::INFINITY` must be indistinguishable) and no staging in
    // flight, the three-bank resource model reproduces the pre-refactor
    // source-NIC-only behavior on the seeded default trace.  The
    // formula-level pin (a BwQueue op serializes bit-for-bit like the
    // old Messenger: `latency + bytes / (bw/1e3)` behind `busy_until`)
    // lives in the resource/messenger unit tests; this test pins the
    // sim-level consequences:
    //   * the rx bank is a true no-op (zero ops recorded),
    //   * the NVMe bank is never touched (no SSD residency at default
    //     capacities, demotion writes free),
    //   * every NIC op is one of the pre-refactor kinds — one KV stream
    //     per placement plus one wire op per remote fetch.
    let t = trace(500);
    let default = SimConfig::default();
    assert!(default.nic_rx_bw.is_none() && default.ssd_write_bw.is_none());
    let explicit = SimConfig {
        nic_rx_bw: Some(f64::INFINITY),
        ssd_write_bw: Some(f64::INFINITY),
        ..Default::default()
    };
    let a = sim::run(&default, &t, 1.0);
    let b = sim::run(&explicit, &t, 1.0);
    assert_runs_identical(&a, &b);
    assert!(a.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count() > 400);
    assert_eq!(a.resources.nic_rx.queued_ms, 0.0, "infinite rx must never queue");
    assert_eq!(a.resources.nvme.n_ops, 0, "default trace has no SSD traffic");
    assert_eq!(
        a.resources.nic_tx.n_ops,
        a.conductor.scheduled + a.conductor.remote_fetches,
        "one KV stream per placement + one wire op per fetch"
    );
    assert_eq!(a.transfer_bytes, a.resources.nic_tx.total_bytes);
    // Unconstrained ingress records nothing at all.
    assert_eq!(a.resources.nic_rx.n_ops, 0);
    assert_eq!(a.resources.nic_rx.busy_ms, 0.0);
}

#[test]
fn prefix_index_is_a_pure_optimization_bit_for_bit() {
    // The tentpole acceptance criterion: the seeded default trace must
    // produce a bit-for-bit identical SimResult with the global prefix
    // index on (default) and off (per-pool scan).
    let t = trace(500);
    let on = SimConfig::default();
    assert!(on.use_prefix_index, "the index is the default path");
    let off = SimConfig { use_prefix_index: false, ..Default::default() };
    assert_runs_identical(&sim::run(&on, &t, 1.0), &sim::run(&off, &t, 1.0));

    // And under tier pressure — evictions, demotions, SSD staging,
    // remote fetches, and the proactive sweep all feeding the index.
    let mk = |use_idx| SimConfig {
        use_prefix_index: use_idx,
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(50_000),
        demote_after_ms: Some(120_000.0),
        n_prefill: 4,
        n_decode: 4,
        ..Default::default()
    };
    let a = sim::run(&mk(true), &t, 2.0);
    let b = sim::run(&mk(false), &t, 2.0);
    assert!(a.tier.demotions > 0, "pressure scenario must exercise demotion");
    assert_runs_identical(&a, &b);
}

#[test]
fn sched_workers_do_not_perturb_results() {
    // ISSUE 8 acceptance pin: the parallel candidate walk is a pure
    // wall-clock optimization — `sched_workers = 1` and `= 4` produce
    // bit-for-bit identical SimResults, on the default config and under
    // tier pressure (evictions, demotions, SSD staging, remote fetches
    // all flowing through the sharded index while workers differ).
    let t = trace(500);
    let one = SimConfig { sched_workers: 1, ..Default::default() };
    assert_eq!(SimConfig::default().sched_workers, 1, "sequential is the default");
    let four = SimConfig { sched_workers: 4, ..Default::default() };
    assert_runs_identical(&sim::run(&one, &t, 1.0), &sim::run(&four, &t, 1.0));

    let mk = |workers| SimConfig {
        sched_workers: workers,
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(50_000),
        demote_after_ms: Some(120_000.0),
        n_prefill: 4,
        n_decode: 4,
        ..Default::default()
    };
    let a = sim::run(&mk(1), &t, 2.0);
    let b = sim::run(&mk(4), &t, 2.0);
    assert!(a.tier.demotions > 0, "pressure scenario must exercise demotion");
    assert_runs_identical(&a, &b);
}

#[test]
fn hybrid_off_reproduces_three_way_behavior() {
    // ISSUE 9 acceptance pin: `hybrid: false` restores the exclusive
    // three-way prefix decision bit-for-bit.  On the default trace
    // nothing is ever SSD-resident, so the fourth branch has no splits
    // to price and hybrid on/off must already be indistinguishable.
    let t = trace(500);
    let on = SimConfig::default();
    assert!(on.hybrid, "the fourth branch is the default");
    let off = SimConfig { hybrid: false, ..Default::default() };
    let a = sim::run(&on, &t, 1.0);
    assert_runs_identical(&a, &sim::run(&off, &t, 1.0));
    assert_eq!(a.conductor.hybrid_placements, 0, "no SSD tier, no hybrid plans");

    // Under tier pressure the fourth branch is live.  With it pinned
    // off, the run must stay invariant under every pure-optimization
    // knob (prefix index on/off, 1 or 4 scoring workers) — the
    // exclusive decision of PR 8 and earlier is fully intact.
    let mk = |hybrid, use_idx, workers| SimConfig {
        hybrid,
        use_prefix_index: use_idx,
        sched_workers: workers,
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(50_000),
        demote_after_ms: Some(120_000.0),
        n_prefill: 4,
        n_decode: 4,
        ..Default::default()
    };
    let exclusive = sim::run(&mk(false, true, 1), &t, 2.0);
    assert!(exclusive.tier.demotions > 0, "pressure scenario must exercise demotion");
    assert_eq!(exclusive.conductor.hybrid_placements, 0);
    assert_eq!(exclusive.conductor.hybrid_staged_blocks, 0);
    assert_eq!(exclusive.conductor.hybrid_recomputed_blocks, 0);
    assert_runs_identical(&exclusive, &sim::run(&mk(false, false, 1), &t, 2.0));
    assert_runs_identical(&exclusive, &sim::run(&mk(false, true, 4), &t, 2.0));

    // With the branch live, a hybrid placement is one of the staging
    // reads — a split of one, never an extra device op.
    let hybrid = sim::run(&mk(true, true, 1), &t, 2.0);
    assert!(hybrid.conductor.hybrid_placements <= hybrid.conductor.ssd_loads);
    assert!(
        hybrid.conductor.hybrid_staged_blocks >= hybrid.conductor.hybrid_placements,
        "every hybrid placement stages at least one block"
    );
}

#[test]
fn multi_shard_cluster_runs_end_to_end() {
    // The 256-node cap is gone: a 300-node prefill fleet (two index
    // shards, one only 44 nodes wide) completes a full run, stays
    // bit-for-bit identical to the per-pool scan path (index off) and to
    // itself under parallel scoring, and actually reuses prefixes.
    let t = trace(400);
    let mk = |use_idx, workers| SimConfig {
        n_prefill: 300,
        n_decode: 8,
        use_prefix_index: use_idx,
        sched_workers: workers,
        ..Default::default()
    };
    let idx = sim::run(&mk(true, 1), &t, 1.0);
    assert!(idx.n_completed > 0, "300-node cluster must complete requests");
    assert!(idx.conductor.reused_blocks > 0, "prefix reuse must survive sharding");
    assert_runs_identical(&idx, &sim::run(&mk(false, 1), &t, 1.0));
    assert_runs_identical(&idx, &sim::run(&mk(true, 4), &t, 1.0));
}

#[test]
fn streaming_replay_is_bit_for_bit_the_materialized_run() {
    // The streaming tentpole's equivalence pin: feeding the default
    // generated trace through `run_stream` as an iterator (no knobs set)
    // must produce a bit-for-bit identical SimResult to the
    // materialize-everything path, on the default config and under tier
    // pressure with the proactive sweep armed.
    let t = trace(500);
    let mk_stream = |speedup: f64| {
        let mut reqs: Vec<sim::Request> = t
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut req = sim::Request::from_trace(i as u64, r);
                req.arrival /= speedup;
                req
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        reqs
    };

    let cfg = SimConfig::default();
    assert!(cfg.max_live_requests.is_none() && cfg.interner_epoch_blocks.is_none());
    let batch = sim::run(&cfg, &t, 1.0);
    let streamed = sim::run_streaming(&cfg, mk_stream(1.0));
    assert_runs_identical(&batch, &streamed);

    let pressured = SimConfig {
        cache_capacity_blocks: Some(400),
        ssd_capacity_blocks: Some(50_000),
        demote_after_ms: Some(120_000.0),
        n_prefill: 4,
        n_decode: 4,
        ..Default::default()
    };
    let batch = sim::run(&pressured, &t, 2.0);
    assert!(batch.tier.demotions > 0, "pressure scenario must exercise demotion");
    assert_runs_identical(&batch, &sim::run_streaming(&pressured, mk_stream(2.0)));
}

#[test]
fn million_request_streaming_replay_holds_flat_state() {
    // The tentpole's acceptance test: a 1M-request replay from a
    // generator (never materialized) completes with the live-request
    // high-water mark bounded by `max_live_requests`, per-request rows
    // dropped, and the dense-id space held down by epoch recycling even
    // though >1M distinct blocks flow through.
    const N: u64 = 1_000_000;
    const CAP: usize = 64;
    let cfg = SimConfig {
        n_prefill: 2,
        n_decode: 2,
        cache_capacity_blocks: Some(512),
        ssd_capacity_blocks: Some(512),
        max_live_requests: Some(CAP),
        interner_epoch_blocks: Some(4_096),
        retain_metrics: false,
        paranoia: Paranoia::Off,
        ..Default::default()
    };
    // One shared leading block (a stable hot prefix) plus one block
    // unique to each request (unbounded distinct-block churn).
    let arrivals = (0..N).map(|i| sim::Request {
        rid: i,
        arrival: i as f64 * 0.05,
        input: 1024,
        output: 1,
        hash_ids: vec![1, 1_000 + i],
    });
    let res = sim::run_streaming(&cfg, arrivals);
    assert_eq!(res.n_completed + res.n_rejected, N, "every request must retire");
    assert!(res.n_completed > N / 2, "cap backpressure should let most requests finish");
    assert!(res.live_peak <= CAP, "live HWM {} exceeds the cap {CAP}", res.live_peak);
    assert!(res.metrics.is_empty(), "retain_metrics: false must not accumulate rows");
    assert!(res.interner_epochs > 0, "recycling must have run");
    assert!(res.interner_freed > 900_000, "only {} ids freed", res.interner_freed);
    assert!(
        res.interner_id_space < 100_000,
        "dense-id space {} not bounded by recycling",
        res.interner_id_space
    );
}

#[test]
fn eviction_policies_agree_with_table1_ordering() {
    let t = trace(4_000);
    // At infinite capacity every policy hits the same ceiling.
    let inf_lru = stats::cache_hit_rate(&t, PolicyKind::Lru, None);
    let inf_lfu = stats::cache_hit_rate(&t, PolicyKind::Lfu, None);
    assert!((inf_lru - inf_lfu).abs() < 1e-9);
    // At mid capacity LRU should not lose to LFU (temporal locality).
    let mid_lru = stats::cache_hit_rate(&t, PolicyKind::Lru, Some(5_000));
    let mid_lfu = stats::cache_hit_rate(&t, PolicyKind::Lfu, Some(5_000));
    assert!(mid_lru >= mid_lfu - 0.03, "LRU {mid_lru} vs LFU {mid_lfu}");
}

/// FNV-1a over every field of the first 1k default-config requests.
/// The calibrated generator's RNG stream is a repo contract: every
/// scenario knob added so far (bursts, re-arrival) short-circuits its
/// RNG draws when disabled so that seeds and calibration carry over
/// bit-identically.  This golden hash makes that provable — a future
/// knob that perturbs the default stream changes the hash and fails
/// here, instead of silently re-rolling every calibrated experiment.
#[test]
fn golden_default_trace_stream_pinned() {
    let trace = gen::generate(&TraceGenConfig { n_requests: 1_000, ..Default::default() });
    let mut h: u64 = 0xcbf29ce484222325;
    let mix = |h: &mut u64, v: u64| {
        for b in v.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100000001b3);
        }
    };
    for r in &trace {
        mix(&mut h, r.timestamp);
        mix(&mut h, r.input_length);
        mix(&mut h, r.output_length);
        mix(&mut h, r.hash_ids.len() as u64);
        for &b in &r.hash_ids {
            mix(&mut h, b);
        }
    }
    assert_eq!(
        h, 0x7aa958e3910f7633,
        "default trace::gen stream changed (got {h:#018x}) — scenario knobs \
         must leave the calibrated RNG stream bit-identical when disabled"
    );
}

#[test]
fn goodput_counts_only_slo_satisfying_completions() {
    let t = trace(400);
    let cfg = SimConfig { n_prefill: 1, n_decode: 1, ..Default::default() };
    let res = sim::run(&cfg, &t, 10.0); // heavy overload, no admission control
    let rep = res.report(&cfg);
    let completed = res.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count();
    let ok = res
        .metrics
        .iter()
        .filter(|m| m.meets_slo(cfg.slo.ttft_ms, cfg.slo.tbt_ms))
        .count();
    assert!(ok <= completed);
    assert!((rep.goodput_rps * res.wall_ms / 1e3 - ok as f64).abs() < 1.0);
    // Under 10x overload the cluster cannot serve everything within SLO:
    // either Algorithm 1 rejects (line 25) or completions violate SLO.
    assert!(
        ok < res.metrics.len(),
        "expected rejections or SLO violations under 10x overload"
    );
}

#[test]
fn cpp_reduces_long_context_ttft_end_to_end() {
    // §5.1: with CPP enabled, 128k-token requests see lower TTFT than
    // single-node prefill, end to end.
    let data = gen::dataset("sim128k", 20, 0.05, 9);
    let mk = |group: u64| SimConfig {
        n_prefill: 4,
        n_decode: 2,
        cpp_group_max: group,
        slo: SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 },
        ..Default::default()
    };
    let solo = sim::run(&mk(1), &data, 1.0).report(&mk(1));
    let cpp = sim::run(&mk(4), &data, 1.0).report(&mk(4));
    assert!(
        cpp.ttft_mean < solo.ttft_mean * 0.75,
        "CPP mean TTFT {} !<< solo {}",
        cpp.ttft_mean,
        solo.ttft_mean
    );
}
