//! Table 1 — cache hit rates under different cache policies and
//! capacities, on the (generated) 23,608-request trace with a single
//! global cache pool.
//!
//! Paper row (LRU): inf 0.51, 100k 0.51, 50k 0.50, 30k 0.48, 10k 0.40,
//! 1k 0.30 — and LRU >= LFU >= LengthAware at mid capacities.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::kvcache::PolicyKind;
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::stats::cache_hit_rate;

fn main() {
    let trace = generate(&TraceGenConfig::default());
    let caps: Vec<Option<usize>> =
        vec![None, Some(100_000), Some(50_000), Some(30_000), Some(10_000), Some(1_000)];

    banner("Table 1: cache hit rates (23,608-request trace, global pool)");
    let mut header = vec!["policy".to_string()];
    header.extend(caps.iter().map(|c| c.map(|x| x.to_string()).unwrap_or("inf".into())));
    row(&header);

    let mut rates = std::collections::HashMap::new();
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        let mut cells = vec![kind.name().to_string()];
        for cap in &caps {
            let r = cache_hit_rate(&trace, kind, *cap);
            rates.insert((kind.name(), cap.map(|c| c).unwrap_or(usize::MAX)), r);
            cells.push(fmt(r, 3));
        }
        row(&cells);
    }

    // Shape checks against the paper's qualitative claims.
    let lru_inf = rates[&("LRUCache", usize::MAX)];
    let lru_1k = rates[&("LRUCache", 1_000)];
    assert!(lru_inf > 0.38 && lru_inf < 0.62, "infinite-cache ceiling ~0.5, got {lru_inf}");
    assert!(lru_1k < lru_inf - 0.05, "small cache must lose hits");
    // Capacity growth from 1k to 50k must recover most of the ceiling.
    let lru_50k = rates[&("LRUCache", 50_000)];
    assert!(lru_50k > lru_inf - 0.03, "50k blocks should be near the ceiling");
    println!("\ntable1 shape checks OK (ceiling {lru_inf:.2})");
}
