//! Cache-policy analysis (§4.2, Table 1 + Fig 6) on any trace file in
//! the published JSONL schema — or a freshly generated calibrated trace.
//!
//!     cargo run --release --offline --example cache_policy -- \
//!         [--trace trace.jsonl] [--requests 23608]

use anyhow::Result;
use mooncake::kvcache::PolicyKind;
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::{jsonl, stats};
use mooncake::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let trace = match args.get("trace") {
        Some(p) => jsonl::load(p)?,
        None => generate(&TraceGenConfig {
            n_requests: args.get_usize("requests", 23_608),
            ..Default::default()
        }),
    };
    let s = stats::summarize(&trace);
    println!(
        "trace: {} requests, {} block refs, {} unique blocks",
        s.n_requests, s.total_blocks, s.unique_blocks
    );

    println!("\nTable 1: hit rate by policy x capacity");
    let caps = [None, Some(100_000), Some(50_000), Some(30_000), Some(10_000), Some(1_000)];
    print!("{:<18}", "policy");
    for c in &caps {
        print!("{:>9}", c.map(|x| x.to_string()).unwrap_or("inf".into()));
    }
    println!();
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        print!("{:<18}", kind.name());
        for cap in &caps {
            print!("{:>9.3}", stats::cache_hit_rate(&trace, kind, *cap));
        }
        println!();
    }

    println!("\nFig 6: block hit-count CDF");
    for (count, frac) in stats::block_hit_cdf(&trace) {
        println!("  hits <= {:>6}: {:.3}", count, frac);
    }
    let counts = stats::block_hit_counts(&trace);
    let once = counts.values().filter(|&&c| c == 1).count() as f64 / counts.len() as f64;
    println!(
        "\n{:.1}% of blocks never reused; hottest block hit {} times",
        once * 100.0,
        counts.values().max().unwrap_or(&0)
    );
    Ok(())
}
