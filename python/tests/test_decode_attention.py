"""L1 decode_attention kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention
from compile.kernels.ref import decode_attention_ref


def _mk(rng, B, nh, kvh, hd, C, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(B, nh, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, C, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, C, kvh, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("C,bk", [(128, 128), (256, 128), (512, 64)])
def test_matches_ref(B, C, bk):
    rng = np.random.default_rng(0)
    q, k, v = _mk(rng, B, 4, 2, 32, C)
    lens = jnp.asarray(rng.integers(1, C + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=bk)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_len_one():
    """A single valid cache entry: output must equal v[0] exactly-ish."""
    rng = np.random.default_rng(1)
    q, k, v = _mk(rng, 2, 4, 2, 32, 128)
    lens = jnp.asarray([1, 1], jnp.int32)
    out = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    # softmax over one element is the identity: out == repeated v[:, 0]
    vr = jnp.repeat(v[:, 0], 2, axis=-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vr), rtol=1e-5, atol=1e-5)


def test_junk_beyond_len_is_ignored():
    rng = np.random.default_rng(2)
    q, k, v = _mk(rng, 2, 4, 2, 32, 256)
    lens = jnp.asarray([100, 37], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    # Poison the invalid region; output must not change.
    k2 = k.at[0, 100:].set(1e9).at[1, 37:].set(-1e9)
    v2 = v.at[0, 100:].set(1e9).at[1, 37:].set(-1e9)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_mha_no_gqa():
    """kvh == nh (no grouping) must also work."""
    rng = np.random.default_rng(3)
    q, k, v = _mk(rng, 2, 4, 4, 16, 128)
    lens = jnp.asarray([64, 128], jnp.int32)
    out = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 4),
    nh_mult=st.integers(1, 4),
    kvh=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16, 32]),
    cblk=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(B, nh_mult, kvh, hd, cblk, seed):
    """Property: kernel == oracle for arbitrary GQA shapes and lengths."""
    rng = np.random.default_rng(seed)
    nh = kvh * nh_mult
    C = 64 * cblk
    q, k, v = _mk(rng, B, nh, kvh, hd, C)
    lens = jnp.asarray(rng.integers(1, C + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=64)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)
