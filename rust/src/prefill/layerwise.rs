//! Layer-wise prefill (§5.2): overlap KVCache load/store with per-layer
//! computation so the *visible* storage latency nearly vanishes and
//! prefill scheduling can ignore VRAM size (Fig 7).
//!
//! The numeric model lives in `PerfModel::layerwise_store_ms`; this module
//! provides the per-layer schedule itself (launch/wait pairs) so the live
//! engine and the Fig 7 bench share one implementation.

use crate::model::PerfModel;

/// Outcome of scheduling one prefill with per-layer async KVCache stores.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerwiseSchedule {
    /// Compute time per layer (ms).
    pub per_layer_compute_ms: f64,
    /// Store (dump to DRAM) time per layer (ms).
    pub per_layer_store_ms: f64,
    /// Total wall time with overlap (compute + visible store tail).
    pub total_ms: f64,
    /// Wall time if stores were serialized after compute.
    pub serialized_ms: f64,
}

/// Simulate the §5.2 schedule: layer i's store is launched right after
/// layer i's attention completes and overlaps layers i+1.. — the wall
/// clock is the max of the compute stream and the (offset) store stream.
pub fn schedule(perf: &PerfModel, n_tokens: u64) -> LayerwiseSchedule {
    let layers = perf.model.n_layers;
    let compute_total = perf.prefill_ms(n_tokens, 0);
    let (store_total, _) = perf.layerwise_store_ms(n_tokens);
    let c = compute_total / layers as f64;
    let s = store_total / layers as f64;

    // Event-accurate rollout of the two streams.
    let mut store_free = 0.0f64;
    let mut t = 0.0f64;
    for _layer in 0..layers {
        t += c; // layer compute finishes
        store_free = store_free.max(t) + s; // its store queues behind prior stores
    }
    LayerwiseSchedule {
        per_layer_compute_ms: c,
        per_layer_store_ms: s,
        total_ms: t.max(store_free),
        serialized_ms: compute_total + store_total,
    }
}

/// Fig 7's y-value: added latency of storing KVCache relative to a
/// prefill that does not store at all.
pub fn visible_store_latency_ms(perf: &PerfModel, n_tokens: u64) -> f64 {
    let sched = schedule(perf, n_tokens);
    sched.total_ms - perf.prefill_ms(n_tokens, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_beats_serialization() {
        let perf = PerfModel::paper();
        for n in [4_000u64, 16_000, 64_000, 128_000] {
            let s = schedule(&perf, n);
            assert!(s.total_ms < s.serialized_ms, "n={n}");
            // Visible latency is a small fraction of the full store cost.
            let visible = visible_store_latency_ms(&perf, n);
            let (full, _) = perf.layerwise_store_ms(n);
            assert!(visible <= full * 0.25 + 1e-9, "n={n}: {visible} vs {full}");
            assert!(visible >= 0.0);
        }
    }

    #[test]
    fn store_tail_at_least_one_layer() {
        let perf = PerfModel::paper();
        let s = schedule(&perf, 32_000);
        let visible = visible_store_latency_ms(&perf, 32_000);
        // The last layer's store can never be hidden.
        assert!(visible >= s.per_layer_store_ms * 0.99);
    }

    #[test]
    fn longer_inputs_amortize_better() {
        // Fig 7's point: layer-wise latency stays near-flat relative to
        // request length while the full store cost grows linearly.
        let perf = PerfModel::paper();
        let v8 = visible_store_latency_ms(&perf, 8_000);
        let v128 = visible_store_latency_ms(&perf, 128_000);
        let (f8, _) = perf.layerwise_store_ms(8_000);
        let (f128, _) = perf.layerwise_store_ms(128_000);
        assert!(f128 / f8 > 10.0);
        assert!(v128 / v8 < f128 / f8);
    }
}
