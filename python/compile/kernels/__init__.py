"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .decode_attention import decode_attention
from .prefill_attention import prefill_attention
from .paged_attention import paged_attention
from . import ref

__all__ = ["decode_attention", "prefill_attention", "paged_attention", "ref"]
