//! Table 1 — cache hit rates under different cache policies and
//! capacities, on the (generated) 23,608-request trace with a single
//! global cache pool.
//!
//! Paper row (LRU): inf 0.51, 100k 0.51, 50k 0.50, 30k 0.48, 10k 0.40,
//! 1k 0.30 — and LRU >= LFU >= LengthAware at mid capacities.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::SimConfig;
use mooncake::costmodel;
use mooncake::kvcache::PolicyKind;
use mooncake::model::PerfModel;
use mooncake::prefill::PrefillPool;
use mooncake::resource::Resources;
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::stats::{cache_hit_rate, tiered_cache_hit_rate};
use mooncake::trace::BLOCK_TOKENS;

fn main() {
    let trace = generate(&TraceGenConfig::default());
    let caps: Vec<Option<usize>> =
        vec![None, Some(100_000), Some(50_000), Some(30_000), Some(10_000), Some(1_000)];

    banner("Table 1: cache hit rates (23,608-request trace, global pool)");
    let mut header = vec!["policy".to_string()];
    header.extend(caps.iter().map(|c| c.map(|x| x.to_string()).unwrap_or("inf".into())));
    row(&header);

    let mut rates = std::collections::HashMap::new();
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        let mut cells = vec![kind.name().to_string()];
        for cap in &caps {
            let r = cache_hit_rate(&trace, kind, *cap);
            rates.insert((kind.name(), cap.map(|c| c).unwrap_or(usize::MAX)), r);
            cells.push(fmt(r, 3));
        }
        row(&cells);
    }

    // Shape checks against the paper's qualitative claims.
    let lru_inf = rates[&("LRUCache", usize::MAX)];
    let lru_1k = rates[&("LRUCache", 1_000)];
    assert!(lru_inf > 0.38 && lru_inf < 0.62, "infinite-cache ceiling ~0.5, got {lru_inf}");
    assert!(lru_1k < lru_inf - 0.05, "small cache must lose hits");
    // Capacity growth from 1k to 50k must recover most of the ceiling.
    let lru_50k = rates[&("LRUCache", 50_000)];
    assert!(lru_50k > lru_inf - 0.03, "50k blocks should be near the ceiling");
    println!("\ntable1 shape checks OK (ceiling {lru_inf:.2})");

    // Tier-capacity ablation: fixed DRAM, growing SSD tier underneath.
    // The SSD tier turns evictions into demotions, so DRAM+SSD at equal
    // DRAM capacity strictly dominates DRAM-only (§4.2's "underutilized
    // ... DRAM and SSD resources" claim made measurable).
    banner("Table 1b: DRAM+SSD tier ablation (LRU)");
    let ssd_caps: Vec<usize> = vec![0, 10_000, 50_000, 200_000];
    let header_b: Vec<String> =
        ["dram", "ssd", "hit", "demote", "promote", "dropped"].iter().map(|s| s.to_string()).collect();
    row(&header_b);
    for dram in [1_000usize, 10_000, 30_000] {
        for &ssd in &ssd_caps {
            let (r, tc) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(ssd));
            row(&[
                dram.to_string(),
                ssd.to_string(),
                fmt(r, 3),
                tc.demotions.to_string(),
                tc.promotions.to_string(),
                tc.dropped.to_string(),
            ]);
        }
    }
    for dram in [1_000usize, 10_000] {
        let (dram_only, _) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(0));
        assert!(
            (dram_only - rates[&("LRUCache", dram)]).abs() < 1e-12,
            "SSD-disabled tiered replay must equal the DRAM-only replay"
        );
        let (tiered, tc) = tiered_cache_hit_rate(&trace, PolicyKind::Lru, Some(dram), Some(200_000));
        assert!(
            tiered > dram_only + 0.02,
            "dram {dram}: DRAM+SSD hit rate {tiered} must beat DRAM-only {dram_only}"
        );
        assert!(tc.ssd_hits > 0 && tc.demotions > tc.dropped);
    }
    println!("\ntable1b tier ablation OK");

    prefix_plan_ablation();
}

/// Table 1c — the ISSUE 9 prefix-plan ablation: one fixed decision cell
/// (64-block matched chain, half DRAM / half SSD, 4 096 fresh tokens)
/// priced under every plan of Algorithm 1's four-way choice, idle and
/// behind a 500 ms NVMe backlog.  Rows are keyed by a schema-stable
/// `policy` name (pure-dram / ssd-stage / recompute / hybrid) so they
/// are self-describing rather than positional, and the hybrid plan must
/// strictly dominate every exclusive plan in both columns.
fn prefix_plan_ablation() {
    let cfg = SimConfig { n_prefill: 1, n_decode: 1, ..Default::default() };
    let perf = PerfModel::paper();
    let pool = PrefillPool::new(&cfg);
    let group = [0usize];
    let (m, dram) = (64usize, 32usize);
    let total = m as u64 * BLOCK_TOKENS + 4_096;
    let positions: Vec<u32> = (dram as u32..m as u32).collect();

    let price_all = |res: &Resources| -> [(&'static str, f64); 4] {
        let excl = |reuse: u64, ssd: u64| {
            costmodel::estimate_prefill(
                &perf,
                &cfg,
                &pool,
                res,
                &group,
                total - reuse * BLOCK_TOKENS,
                reuse * BLOCK_TOKENS,
                ssd * BLOCK_TOKENS,
                None,
                0.0,
            )
            .end
        };
        let (_, _, best) = costmodel::hybrid_split_scan(m, &positions, |k, j| {
            costmodel::estimate_prefill_hybrid(
                &perf,
                &cfg,
                &pool,
                res,
                &group,
                total - k as u64 * BLOCK_TOKENS,
                k as u64 * BLOCK_TOKENS,
                j as u64 * BLOCK_TOKENS,
                0.0,
            )
        })
        .expect("half the chain sits on the SSD tier");
        [
            ("pure-dram", excl(dram as u64, 0)),
            ("ssd-stage", excl(m as u64, (m - dram) as u64)),
            ("recompute", excl(0, 0)),
            ("hybrid", best.end),
        ]
    };

    let idle = Resources::new(&cfg, &perf);
    let mut contended = Resources::new(&cfg, &perf);
    // 500 ms of queued reads ahead of us on the primary's NVMe device.
    contended.nvme.schedule(0, 0.0, (0.5 * perf.hw.ssd_read_bw) as u64, 0.0);

    banner("Table 1c: prefix-plan ablation (64-block chain, half on SSD, 4096 new tokens)");
    let header: Vec<String> =
        ["policy", "idle ms", "contended ms"].iter().map(|s| s.to_string()).collect();
    row(&header);
    let idle_ms = price_all(&idle);
    let cont_ms = price_all(&contended);
    for (a, b) in idle_ms.iter().zip(cont_ms.iter()) {
        row(&[a.0.to_string(), format!("{:.0}", a.1), format!("{:.0}", b.1)]);
    }
    for t in [&idle_ms, &cont_ms] {
        let hybrid = t[3].1;
        let best_excl = t[0].1.min(t[1].1).min(t[2].1);
        assert!(
            hybrid < best_excl,
            "hybrid plan must strictly dominate: {hybrid:.0} vs best exclusive {best_excl:.0}"
        );
    }
    println!("\ntable1c prefix-plan ablation OK (hybrid dominates both columns)");
}
