//! Analytic performance model: maps (request shape, instance state) to
//! execution times.  This is the simulator's substitute for the paper's
//! A800 testbed and the source of Conductor's `EstimatePrefillExecutionTime`
//! / `EstimateKVCacheTransferTime` estimates (Algorithm 1).
//!
//! The shapes follow §2 / Fig 2: prefill time grows *superlinearly* with
//! input length (quadratic attention + linear MLP, compute-bound), decode
//! step time grows *sublinearly* in batch size (memory-bound: weights are
//! re-read once per step regardless of batch).

use super::{HardwareSpec, ModelSpec};

#[derive(Debug, Clone)]
pub struct PerfModel {
    pub model: ModelSpec,
    pub hw: HardwareSpec,
}

impl PerfModel {
    pub fn new(model: ModelSpec, hw: HardwareSpec) -> Self {
        PerfModel { model, hw }
    }

    pub fn paper() -> Self {
        Self::new(ModelSpec::llama2_70b(), HardwareSpec::a800_node())
    }

    /// Prefill execution time (ms) on one node for `n_new` uncached tokens
    /// given `prefix` reused tokens (their KVCache is loaded, not
    /// recomputed).  Compute-bound:
    ///   linear FLOPs: 2 * params * n_new
    ///   attn FLOPs:   4 * d_attn * L * n_new * (prefix + n_new/2)
    pub fn prefill_ms(&self, n_new: u64, prefix: u64) -> f64 {
        if n_new == 0 {
            return 0.0;
        }
        let n = n_new as f64;
        let avg_ctx = prefix as f64 + (n + 1.0) / 2.0;
        let flops =
            self.model.linear_flops_per_token() * n + self.model.attn_flops_per_token(avg_ctx) * n;
        let eff = self.hw.flops_peak * self.hw.prefill_mfu;
        flops / eff * 1e3
    }

    /// One continuous-batching decode iteration (ms) for a batch of
    /// `batch` sequences whose KVCaches total `kv_tokens` tokens.
    /// Memory-bound: weights once + the batch's KVCache + small compute.
    pub fn decode_step_ms(&self, batch: u64, kv_tokens: u64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let bw = self.hw.hbm_bw * self.hw.hbm_eff;
        let weight_ms = self.model.weight_bytes() as f64 / bw * 1e3;
        let kv_ms = (kv_tokens * self.model.kv_bytes_per_token()) as f64 / bw * 1e3;
        // Dense compute for `batch` tokens (usually negligible vs memory).
        let compute_ms = self.model.linear_flops_per_token() * batch as f64
            / (self.hw.flops_peak * 0.6)
            * 1e3;
        self.hw.step_overhead_ms + (weight_ms + kv_ms).max(compute_ms)
    }

    /// Time (ms) to move `tokens` of KVCache across one inter-node RDMA
    /// link at full bandwidth (queueing/congestion is the Messenger's job).
    pub fn rdma_transfer_ms(&self, tokens: u64) -> f64 {
        self.hw.transfer_latency_ms
            + (tokens * self.model.kv_bytes_per_token()) as f64 / self.hw.rdma_bw * 1e3
    }

    /// Time (ms) to load `tokens` of KVCache from local CPU DRAM into VRAM.
    pub fn dram_load_ms(&self, tokens: u64) -> f64 {
        (tokens * self.model.kv_bytes_per_token()) as f64 / self.hw.pcie_bw * 1e3
    }

    /// Analytic reference for one *uncontended* NVMe staging read of
    /// `tokens` spanning `blocks` cache blocks: a bandwidth term plus a
    /// per-block IOPS term.  Execution paths do NOT call this — all NVMe
    /// time flows through the per-node `resource::BwQueue` bank
    /// (`costmodel::estimate_stage_done`/`schedule_stage`), which charges
    /// the same serialization behind the device's queue.  Kept as the
    /// shape documentation of the load-vs-recompute tradeoff — for
    /// shallow prefixes recomputation beats the NVMe read, for deep ones
    /// (where attention makes recompute superlinear) the read wins.
    pub fn ssd_load_ms(&self, tokens: u64, blocks: u64) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        (tokens * self.model.kv_bytes_per_token()) as f64 / self.hw.ssd_read_bw * 1e3
            + blocks as f64 / self.hw.ssd_iops * 1e3
    }

    /// Layer-wise prefill (§5.2): storing KVCache is overlapped with the
    /// per-layer computation, so the *visible* store latency is the excess
    /// of transfer over compute, surfacing only at the final layer(s).
    ///
    /// Returns (full store latency if serialized, visible latency with
    /// layer-wise overlap) in ms — the two curves of Fig 7.
    pub fn layerwise_store_ms(&self, n_tokens: u64) -> (f64, f64) {
        let total_store = (n_tokens * self.model.kv_bytes_per_token()) as f64 / self.hw.pcie_bw * 1e3;
        let compute = self.prefill_ms(n_tokens, 0);
        let per_layer_store = total_store / self.model.n_layers as f64;
        let per_layer_compute = compute / self.model.n_layers as f64;
        // Each layer's store overlaps the next layer's compute; only the
        // slack (if store > compute per layer) plus the last layer's store
        // remains visible.
        let visible = if per_layer_store <= per_layer_compute {
            per_layer_store // just the tail store
        } else {
            (per_layer_store - per_layer_compute) * (self.model.n_layers - 1) as f64
                + per_layer_store
        };
        (total_store, visible)
    }

    /// Max KVCache tokens a decode node can hold in VRAM.
    pub fn vram_kv_capacity_tokens(&self) -> u64 {
        self.hw.vram_kv_bytes / self.model.kv_bytes_per_token()
    }

    /// Chunked-pipeline-parallel prefill (§5.1): a request of `n_new`
    /// tokens split into chunks of `chunk` across `group` nodes.  The
    /// pipeline's makespan is roughly the per-node work serialized over
    /// chunks but overlapped across stages.
    pub fn cpp_prefill_ms(&self, n_new: u64, prefix: u64, chunk: u64, group: u64) -> f64 {
        if n_new == 0 {
            return 0.0;
        }
        let n_chunks = n_new.div_ceil(chunk);
        if group <= 1 || n_chunks <= 1 {
            return self.prefill_ms(n_new, prefix);
        }
        // Per-chunk time varies with its context offset; the pipeline's
        // makespan ≈ (sum over chunks)/group + (group-1) * max chunk time
        // (fill/drain).  Cross-node communication happens only at stage
        // boundaries (activations, d_model per token) — negligible vs
        // KVCache-sized traffic, matching the paper's motivation for CPP
        // over SP.
        let mut times = Vec::with_capacity(n_chunks as usize);
        let mut done = 0u64;
        for _ in 0..n_chunks {
            let this = chunk.min(n_new - done);
            times.push(self.prefill_ms(this, prefix + done));
            done += this;
        }
        let sum: f64 = times.iter().sum();
        let max = times.iter().cloned().fold(0.0, f64::max);
        sum / group as f64 + (group - 1) as f64 * max / n_chunks as f64 + max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PerfModel {
        PerfModel::paper()
    }

    #[test]
    fn prefill_superlinear_in_length() {
        let p = pm();
        let t8k = p.prefill_ms(8_000, 0);
        let t64k = p.prefill_ms(64_000, 0);
        let t128k = p.prefill_ms(128_000, 0);
        // 8x tokens must cost more than 8x time (attention quadratic term).
        assert!(t64k > 8.0 * t8k, "{t8k} {t64k}");
        assert!(t128k > 2.0 * t64k);
        // Sanity: 8k-token 70B prefill lands near a second on one node.
        assert!(t8k > 200.0 && t8k < 3_000.0, "{t8k}");
    }

    #[test]
    fn prefix_cache_cuts_prefill_time() {
        let p = pm();
        let cold = p.prefill_ms(16_000, 0);
        let warm = p.prefill_ms(8_000, 8_000);
        assert!(warm < cold * 0.7, "{warm} vs {cold}");
    }

    #[test]
    fn decode_throughput_sublinear_in_batch() {
        let p = pm();
        // Fixed per-sequence context of 4k tokens.
        let t1 = p.decode_step_ms(1, 4_000);
        let t64 = p.decode_step_ms(64, 64 * 4_000);
        let thru1 = 1.0 / t1;
        let thru64 = 64.0 / t64;
        // Throughput improves with batch...
        assert!(thru64 > 10.0 * thru1);
        // ...but sublinearly (KV reads grow with batch).
        assert!(thru64 < 60.0 * thru1);
        // Latency grows with batch.
        assert!(t64 > t1);
    }

    #[test]
    fn decode_step_dominated_by_weights_at_small_batch() {
        let p = pm();
        let t = p.decode_step_ms(1, 1_000);
        // ~140GB / (16TB/s * 0.55) ≈ 16ms + 25ms iteration overhead
        assert!(t > 20.0 && t < 60.0, "{t}");
    }

    #[test]
    fn transfer_time_scales_with_tokens() {
        let p = pm();
        let t16k = p.rdma_transfer_ms(16_000);
        // 16k tokens * 327,680 B ≈ 5.2 GB over 100 GB/s ≈ 52ms + latency
        assert!(t16k > 40.0 && t16k < 80.0, "{t16k}");
        assert!(p.rdma_transfer_ms(32_000) > 1.8 * t16k);
    }

    #[test]
    fn ssd_slower_than_dram_but_crosses_recompute() {
        let p = pm();
        // SSD is the slow tier: loading from it costs far more than DRAM.
        assert!(p.ssd_load_ms(8_000, 16) > 5.0 * p.dram_load_ms(8_000));
        // Deep prefix: the quadratic attention recompute loses to the read.
        let deep = 32_768u64;
        assert!(
            p.ssd_load_ms(deep, deep / 512) < p.prefill_ms(deep, 0),
            "deep prefix must favor the SSD load"
        );
        // Shallow prefix: recompute at near-zero context wins.
        let shallow = 512u64;
        assert!(
            p.prefill_ms(shallow, 0) < p.ssd_load_ms(shallow, 1),
            "shallow prefix must favor recompute"
        );
        assert_eq!(p.ssd_load_ms(0, 0), 0.0);
    }

    #[test]
    fn layerwise_overlap_hides_most_of_store() {
        let p = pm();
        for n in [8_000u64, 32_000, 128_000] {
            let (full, visible) = p.layerwise_store_ms(n);
            assert!(visible < full * 0.35, "n={n}: visible={visible} full={full}");
        }
    }

    #[test]
    fn cpp_speeds_up_long_context() {
        let p = pm();
        let single = p.prefill_ms(128_000, 0);
        let cpp2 = p.cpp_prefill_ms(128_000, 0, 8_000, 2);
        let cpp4 = p.cpp_prefill_ms(128_000, 0, 8_000, 4);
        assert!(cpp2 < single * 0.75, "{cpp2} vs {single}");
        assert!(cpp4 < cpp2);
        // Short requests see no benefit and no big penalty.
        let short_single = p.prefill_ms(2_000, 0);
        let short_cpp = p.cpp_prefill_ms(2_000, 0, 8_000, 4);
        assert!((short_cpp / short_single - 1.0).abs() < 1e-9);
    }
}
