//! Ablations over Mooncake's design knobs (beyond the paper's figures):
//!
//! 1. `kvcache_balancing_threshold` (Algorithm 1 line 8 / footnote 1:
//!    "currently adjusted manually") — sweep the local-vs-remote tradeoff.
//! 2. `prefill_chunk` (§5.1: "typically larger than 1000 tokens").
//! 3. CPP group size (§5.1) on a long-context workload.
//! 4. Per-instance cache capacity (the DRAM pool sizing question of §6.2).

use mooncake::bench_util::{banner, fmt, row};
use mooncake::config::{SimConfig, SloConfig};
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};

fn main() {
    let trace = gen::generate(&TraceGenConfig { n_requests: 4_000, ..Default::default() });

    banner("Ablation 1: kvcache_balancing_threshold (8P+8D, 2x)");
    row(&["threshold".into(), "mean_TTFT_ms".into(), "fetches".into(), "reused_blocks".into()]);
    let mut ttfts = Vec::new();
    for thr in [1.0, 2.0, 4.0, 8.0, 1e9] {
        let cfg = SimConfig { kvcache_balancing_threshold: thr, ..Default::default() };
        let res = sim::run(&cfg, &trace, 2.0);
        let rep = res.report(&cfg);
        row(&[
            if thr > 1e8 { "inf".into() } else { fmt(thr, 1) },
            fmt(rep.ttft_mean, 0),
            res.conductor.remote_fetches.to_string(),
            res.conductor.reused_blocks.to_string(),
        ]);
        ttfts.push((thr, rep.ttft_mean, res.conductor.remote_fetches));
    }
    // Higher thresholds prefer local recompute: fetch volume must be
    // monotone non-increasing in the threshold.  (Even at thr=inf a
    // zero-local-match instance still fetches — ratio is infinite.)
    assert!(ttfts[0].2 > 0, "threshold 1.0 must fetch");
    assert!(
        ttfts.last().unwrap().2 <= ttfts[0].2,
        "fetches must not grow with the threshold"
    );

    banner("Ablation 2: prefill_chunk (long-context 64k workload)");
    let long = gen::dataset("sim64k", 120, 0.2, 3);
    let slo = SloConfig { ttft_ms: 1e9, tbt_ms: 1e9 };
    row(&["chunk_tokens".into(), "mean_TTFT_ms".into()]);
    for chunk in [1_024u64, 4_096, 8_192, 16_384, 65_536] {
        let cfg = SimConfig { prefill_chunk: chunk, n_prefill: 4, n_decode: 2, slo, ..Default::default() };
        let rep = sim::run(&cfg, &long, 1.0).report(&cfg);
        row(&[chunk.to_string(), fmt(rep.ttft_mean, 0)]);
    }

    banner("Ablation 3: CPP group size (128k inputs)");
    let xl = gen::dataset("sim128k", 60, 0.05, 5);
    row(&["cpp_group_max".into(), "mean_TTFT_ms".into()]);
    let mut cpp = Vec::new();
    for g in [1u64, 2, 4, 8] {
        let cfg = SimConfig { cpp_group_max: g, n_prefill: 8, n_decode: 2, slo, ..Default::default() };
        let rep = sim::run(&cfg, &xl, 1.0).report(&cfg);
        row(&[g.to_string(), fmt(rep.ttft_mean, 0)]);
        cpp.push(rep.ttft_mean);
    }
    assert!(cpp[2] < cpp[0] * 0.7, "CPP(4) must cut 128k TTFT vs single node");

    banner("Ablation 4: per-instance cache capacity (blocks)");
    row(&["capacity".into(), "mean_TTFT_ms".into(), "reused_blocks".into()]);
    let mut caps = Vec::new();
    for cap in [Some(500usize), Some(5_000), Some(50_000), None] {
        let cfg = SimConfig { cache_capacity_blocks: cap, ..Default::default() };
        let res = sim::run(&cfg, &trace, 2.0);
        let rep = res.report(&cfg);
        row(&[
            cap.map(|c| c.to_string()).unwrap_or("inf".into()),
            fmt(rep.ttft_mean, 0),
            res.conductor.reused_blocks.to_string(),
        ]);
        caps.push((rep.ttft_mean, res.conductor.reused_blocks));
    }
    assert!(
        caps.last().unwrap().1 >= caps[0].1,
        "bigger caches must not reuse fewer blocks"
    );
    println!("\nablation shape checks OK");
}
