//! The Conductor's **global prefix index** (§5, §6): one map from
//! `BlockId` to a per-node, tier-aware residency bitset, replacing the
//! per-request scan of every prefill instance's pool.
//!
//! `FindBestPrefixMatch` used to cost O(nodes × chain) HashMap probes
//! per scheduling decision — worst in exactly the long-context regime
//! the paper targets (128K ctx ≈ thousands of blocks).  With the index,
//! [`PrefixIndex::best_prefix`] touches each chain block **once** and
//! advances every candidate node's match simultaneously with bitmask
//! arithmetic: per block, one probe plus O(words) mask ops plus work
//! proportional only to the nodes whose state *changes* at that block
//! (death, DRAM-run end, SSD copy).
//!
//! Consistency protocol: the index is owned next to the scheduler (the
//! `Sim`), not by the pools — pools stay self-contained LRU structures
//! and every mutation ([`CachePool::admit_chain_reusing`],
//! [`CachePool::insert_replica`], [`CachePool::demote_block`],
//! [`CachePool::demote_idle`], …) *returns* a [`TierDelta`] of residency
//! changes which the owner applies via [`PrefixIndex::apply`].  A
//! debug-mode invariant ([`PrefixIndex::equals_rebuild_of`]) checks the
//! incremental index against a brute-force rebuild.
//!
//! The bitset is `[u64; WORDS]` per tier per block, so one index shard
//! covers up to [`PrefixIndex::MAX_NODES`] prefill nodes — wide enough
//! that the old ≤64-node automatic scan fallback is gone; only the
//! explicit `use_prefix_index: false` knob restores the per-pool scan.
//! Word loops run over `n_nodes.div_ceil(64)` words, so small clusters
//! pay for one.

use std::collections::HashMap;

use super::pool::{CachePool, Tier, TierDelta, TierMatch};
use crate::BlockId;

/// Bitset words per tier per block.
const WORDS: usize = 4;

/// Which nodes hold a block, split by tier.  A node's bit is set in at
/// most one of the two masks (a block lives in exactly one tier per
/// pool).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
struct Residency {
    dram: [u64; WORDS],
    ssd: [u64; WORDS],
}

impl Residency {
    fn is_empty(&self) -> bool {
        self.dram.iter().all(|&w| w == 0) && self.ssd.iter().all(|&w| w == 0)
    }
}

#[derive(Debug)]
pub struct PrefixIndex {
    n_nodes: usize,
    /// Words actually carrying bits: `n_nodes.div_ceil(64)`.
    n_words: usize,
    map: HashMap<BlockId, Residency>,
}

impl PrefixIndex {
    /// `WORDS` bitset words per tier per block.
    pub const MAX_NODES: usize = 64 * WORDS;

    /// Whether a single index shard can cover `n_nodes` prefill nodes.
    pub fn supports(n_nodes: usize) -> bool {
        n_nodes <= Self::MAX_NODES
    }

    pub fn new(n_nodes: usize) -> Self {
        assert!(
            Self::supports(n_nodes),
            "PrefixIndex shard covers at most {} nodes",
            Self::MAX_NODES
        );
        PrefixIndex { n_nodes, n_words: n_nodes.div_ceil(64).max(1), map: HashMap::new() }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Distinct blocks resident anywhere in the cluster.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    #[inline]
    fn word_bit(node: usize) -> (usize, u64) {
        (node >> 6, 1u64 << (node & 63))
    }

    /// Record `node`'s residency for one block (`None` = not resident).
    /// Setting one tier clears the other — a block lives in exactly one
    /// tier per pool — and entries with no holders are removed so the
    /// index stays equal to a fresh rebuild.
    pub fn set(&mut self, node: usize, b: BlockId, loc: Option<Tier>) {
        debug_assert!(node < self.n_nodes);
        let (w, bit) = Self::word_bit(node);
        let r = self.map.entry(b).or_default();
        r.dram[w] &= !bit;
        r.ssd[w] &= !bit;
        match loc {
            Some(Tier::Dram) => r.dram[w] |= bit,
            Some(Tier::Ssd) => r.ssd[w] |= bit,
            None => {}
        }
        if r.is_empty() {
            self.map.remove(&b);
        }
    }

    /// Apply a pool mutation's residency changes for `node`, in order.
    pub fn apply(&mut self, node: usize, delta: &TierDelta) {
        for &(b, loc) in &delta.changes {
            self.set(node, b, loc);
        }
    }

    /// `node`'s residency for one block, as the pool would report it.
    pub fn tier_on(&self, node: usize, b: BlockId) -> Option<Tier> {
        debug_assert!(node < self.n_nodes);
        let r = self.map.get(&b)?;
        let (w, bit) = Self::word_bit(node);
        if r.dram[w] & bit != 0 {
            Some(Tier::Dram)
        } else if r.ssd[w] & bit != 0 {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    /// Every node holding `b` (either tier), ascending — one probe for
    /// the whole cluster, replacing per-pool `contains` scans
    /// (`conductor::migration` reads holder sets through this).
    pub fn holders(&self, b: BlockId) -> Vec<usize> {
        let mut out = Vec::new();
        if let Some(r) = self.map.get(&b) {
            for w in 0..self.n_words {
                let mut bits = r.dram[w] | r.ssd[w];
                while bits != 0 {
                    out.push(w * 64 + bits.trailing_zeros() as usize);
                    bits &= bits - 1;
                }
            }
        }
        out
    }

    /// Bulk-load one node's pool (brute-force rebuild path).
    pub fn insert_pool(&mut self, node: usize, pool: &CachePool) {
        for b in pool.iter_dram_blocks() {
            self.set(node, b, Some(Tier::Dram));
        }
        for b in pool.iter_ssd_blocks() {
            self.set(node, b, Some(Tier::Ssd));
        }
    }

    /// `FindBestPrefixMatch` for **all** nodes in one chain walk:
    /// `out[n]` equals `pools[n].prefix_match(hash_ids)` exactly, but the
    /// whole cluster costs one HashMap probe per chain block instead of
    /// one per (node, block) pair.
    pub fn best_prefix_into(&self, hash_ids: &[BlockId], out: &mut Vec<TierMatch>) {
        out.clear();
        out.resize(self.n_nodes, TierMatch::default());
        if self.n_nodes == 0 {
            return;
        }
        // Nodes whose match still extends / whose match is still a pure
        // DRAM run.  A cleared bit means that node's `blocks` (resp.
        // `dram_prefix`) has been finalized in `out`.
        let mut alive = [0u64; WORDS];
        for w in 0..self.n_words {
            let bits = self.n_nodes - w * 64;
            alive[w] = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
        }
        let mut dram_run = alive;
        for (i, &b) in hash_ids.iter().enumerate() {
            if alive[..self.n_words].iter().all(|&w| w == 0) {
                break;
            }
            let r = self.map.get(&b).copied().unwrap_or_default();
            for w in 0..self.n_words {
                if alive[w] == 0 {
                    continue;
                }
                let base = w * 64;
                let resident = (r.dram[w] | r.ssd[w]) & alive[w];
                // Nodes missing this block: their match ends at i blocks.
                let mut died = alive[w] & !resident;
                while died != 0 {
                    let bit = died & died.wrapping_neg();
                    let n = base + bit.trailing_zeros() as usize;
                    died ^= bit;
                    out[n].blocks = i;
                    if dram_run[w] & bit != 0 {
                        out[n].dram_prefix = i;
                    }
                }
                alive[w] = resident;
                dram_run[w] &= resident;
                // Nodes whose block is SSD-resident: their pure-DRAM
                // leading run ends here (and the block counts as an SSD
                // copy).
                let mut run_end = dram_run[w] & !r.dram[w];
                while run_end != 0 {
                    let n = base + run_end.trailing_zeros() as usize;
                    run_end &= run_end - 1;
                    out[n].dram_prefix = i;
                }
                dram_run[w] &= r.dram[w];
                let mut on_ssd = alive[w] & r.ssd[w];
                while on_ssd != 0 {
                    let n = base + on_ssd.trailing_zeros() as usize;
                    on_ssd &= on_ssd - 1;
                    out[n].ssd_blocks += 1;
                }
            }
        }
        // Survivors matched the whole chain.
        let full = hash_ids.len();
        for w in 0..self.n_words {
            let base = w * 64;
            let mut still = alive[w];
            while still != 0 {
                let bit = still & still.wrapping_neg();
                let n = base + bit.trailing_zeros() as usize;
                still ^= bit;
                out[n].blocks = full;
                if dram_run[w] & bit != 0 {
                    out[n].dram_prefix = full;
                }
            }
        }
        for m in out.iter_mut() {
            m.dram_blocks = m.blocks - m.ssd_blocks;
        }
    }

    /// Allocating convenience wrapper around [`Self::best_prefix_into`].
    pub fn best_prefix(&self, hash_ids: &[BlockId]) -> Vec<TierMatch> {
        let mut out = Vec::new();
        self.best_prefix_into(hash_ids, &mut out);
        out
    }

    /// Debug invariant: the incrementally maintained index equals a
    /// brute-force rebuild from the pools (in node order).
    pub fn equals_rebuild_of<'a>(&self, pools: impl Iterator<Item = &'a CachePool>) -> bool {
        let mut fresh = PrefixIndex::new(self.n_nodes);
        let mut count = 0usize;
        for (n, pool) in pools.enumerate() {
            fresh.insert_pool(n, pool);
            count = n + 1;
        }
        count == self.n_nodes && fresh.map == self.map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PolicyKind;

    fn pools(n: usize) -> Vec<CachePool> {
        (0..n).map(|_| CachePool::new(PolicyKind::Lru, Some(64), Some(64))).collect()
    }

    fn scan(pools: &[CachePool], chain: &[BlockId]) -> Vec<TierMatch> {
        pools.iter().map(|p| p.prefix_match(chain)).collect()
    }

    #[test]
    fn best_prefix_matches_per_pool_scan() {
        let mut ps = pools(3);
        let mut idx = PrefixIndex::new(3);
        let chain: Vec<BlockId> = (10..20).collect();
        // Node 0: full chain in DRAM; node 1: first half, with one block
        // demoted to SSD; node 2: nothing.
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        idx.apply(1, &ps[1].admit_chain(&chain[..5], 0.0));
        idx.apply(1, &ps[1].demote_block(12, 1.0).unwrap());
        let got = idx.best_prefix(&chain);
        let want = scan(&ps, &chain);
        assert_eq!(got, want);
        assert_eq!(got[0].blocks, 10);
        assert_eq!(got[1], TierMatch { blocks: 5, dram_prefix: 2, dram_blocks: 4, ssd_blocks: 1 });
        assert_eq!(got[2], TierMatch::default());
        assert!(idx.equals_rebuild_of(ps.iter()));
        // Holder probes agree with the pools.
        assert_eq!(idx.holders(12), vec![0, 1]);
        assert_eq!(idx.holders(17), vec![0]);
        assert_eq!(idx.holders(999), Vec::<usize>::new());
    }

    #[test]
    fn tier_on_tracks_moves_and_drops() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        idx.apply(0, &ps[0].admit_chain(&[1, 2], 0.0));
        idx.apply(1, &ps[1].admit_chain(&[2], 0.0));
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Dram));
        assert_eq!(idx.tier_on(1, 1), None);
        assert_eq!(idx.tier_on(1, 2), Some(Tier::Dram));
        idx.apply(0, &ps[0].demote_block(1, 1.0).unwrap());
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Ssd));
        // A drop removes the node's bit; the last holder's drop removes
        // the entry entirely.
        idx.set(0, 1, None);
        assert_eq!(idx.tier_on(0, 1), None);
        assert_eq!(idx.len(), 1); // only block 2 remains
    }

    #[test]
    fn eviction_pressure_keeps_index_consistent() {
        // A 4-block DRAM tier over a 6-block SSD tier: admissions demote
        // and eventually drop; the deltas must keep the index equal to a
        // rebuild at every step, and best_prefix equal to the scan.
        let mut ps = vec![CachePool::new(PolicyKind::Lru, Some(4), Some(6))];
        let mut idx = PrefixIndex::new(1);
        for round in 0..8u64 {
            let chain: Vec<BlockId> = (round * 3..round * 3 + 4).collect();
            let delta = ps[0].admit_chain(&chain, round as f64);
            idx.apply(0, &delta);
            assert!(idx.equals_rebuild_of(ps.iter()), "round {round}");
            assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain), "round {round}");
        }
    }

    #[test]
    fn wide_clusters_cross_word_boundaries() {
        // ROADMAP PR 3 follow-up: the residency bitset is [u64; W], so a
        // shard covers well past 64 prefill nodes with no fallback.
        assert!(PrefixIndex::supports(65));
        assert!(PrefixIndex::supports(PrefixIndex::MAX_NODES));
        assert!(!PrefixIndex::supports(PrefixIndex::MAX_NODES + 1));
        let n = 130; // three words, last one partial
        let mut ps = pools(n);
        let mut idx = PrefixIndex::new(n);
        let chain: Vec<BlockId> = (1_000..1_016).collect();
        // Holders straddling every word: 0, 63, 64, 77, 127, 128, 129.
        for &node in &[0usize, 63, 64, 77, 127, 128, 129] {
            let len = 4 + node % 12;
            idx.apply(node, &ps[node].admit_chain(&chain[..len], 0.0));
        }
        idx.apply(77, &ps[77].demote_block(1_001, 1.0).unwrap());
        idx.apply(129, &ps[129].demote_block(1_000, 1.0).unwrap());
        assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain));
        assert!(idx.equals_rebuild_of(ps.iter()));
        assert_eq!(idx.tier_on(77, 1_001), Some(Tier::Ssd));
        assert_eq!(idx.tier_on(129, 1_000), Some(Tier::Ssd));
        assert_eq!(idx.holders(1_000), vec![0, 63, 64, 77, 127, 128, 129]);
        // Bit 63 of a full word and bit 0 of the next stay distinct.
        assert_eq!(idx.tier_on(63, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(64, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(65, 1_003), None);
    }

    #[test]
    fn max_width_masks_have_no_shift_overflow() {
        let last = PrefixIndex::MAX_NODES - 1;
        let mut idx = PrefixIndex::new(PrefixIndex::MAX_NODES);
        idx.set(last, 7, Some(Tier::Ssd));
        idx.set(63, 7, Some(Tier::Dram));
        assert_eq!(idx.tier_on(last, 7), Some(Tier::Ssd));
        let m = idx.best_prefix(&[7]);
        assert_eq!(m[last], TierMatch { blocks: 1, dram_prefix: 0, dram_blocks: 0, ssd_blocks: 1 });
        assert_eq!(m[63], TierMatch { blocks: 1, dram_prefix: 1, dram_blocks: 1, ssd_blocks: 0 });
        assert_eq!(m[0], TierMatch::default());
    }

    #[test]
    fn empty_chain_and_empty_index() {
        let idx = PrefixIndex::new(2);
        assert!(idx.is_empty());
        let m = idx.best_prefix(&[]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
        let m = idx.best_prefix(&[99]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
    }
}
