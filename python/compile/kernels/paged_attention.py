"""Pallas paged decode-attention kernel (vLLM PagedAttention, TPU-shaped).

The KVCache lives in a global *page pool* ([NP, PS, kvh, hd]); each
sequence owns a block table of page ids.  This mirrors Mooncake's paged
CPU-DRAM KVCache (Fig 3): pages are the dedup/transfer unit, and the
decode kernel must gather a sequence's pages at attention time.

TPU adaptation: on GPU, PagedAttention resolves the page indirection with
per-warp gather loads from HBM.  On TPU the gather is expressed inside the
kernel with `pl.load` + `pl.dslice` on a whole-pool ref (on real hardware
the block table would be scalar-prefetched via PrefetchScalarGridSpec so
the HBM->VMEM DMA schedule can chase it); pages are walked sequentially
with an online-softmax accumulator, one grid step per sequence.

interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, kp_ref, vp_ref, bt_ref, len_ref, o_ref, *, ps, group, max_blocks):
    q = q_ref[0].astype(jnp.float32)  # [nh, hd]
    nh, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    seq_len = len_ref[0]

    m = jnp.full((nh, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((nh, 1), jnp.float32)
    acc = jnp.zeros((nh, hd), jnp.float32)

    # Walk the sequence's pages.  max_blocks is static (block table width);
    # pages past the valid length contribute nothing via masking.
    for blk in range(max_blocks):
        page = bt_ref[0, blk]
        k = pl.load(kp_ref, (pl.dslice(page, 1),))[0].astype(jnp.float32)  # [PS, kvh, hd]
        v = pl.load(vp_ref, (pl.dslice(page, 1),))[0].astype(jnp.float32)
        k = jnp.repeat(k, group, axis=1)  # [PS, nh, hd]
        v = jnp.repeat(v, group, axis=1)
        s = jnp.einsum("nd,knd->nk", q, k, preferred_element_type=jnp.float32) * scale
        kvpos = blk * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = kvpos < seq_len
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l = alpha * l + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jnp.einsum("nk,knd->nd", p, v, preferred_element_type=jnp.float32)
        m = m_new

    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@jax.jit
def paged_attention(q, k_pages, v_pages, block_tables, lens):
    """Paged decode attention.  See `ref.paged_attention_ref`.

    q: [B, nh, hd]; k/v_pages: [NP, PS, kvh, hd];
    block_tables: [B, MB] int32; lens: [B] int32 (>= 1).
    """
    B, nh, hd = q.shape
    NP, PS, kvh, _ = k_pages.shape
    MB = block_tables.shape[1]
    group = nh // kvh
    grid = (B,)
    return pl.pallas_call(
        functools.partial(_kernel, ps=PS, group=group, max_blocks=MB),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nh, hd), lambda b: (b, 0, 0)),
            # Whole page pool visible to every grid step; the kernel
            # gathers pages with dynamic `pl.load`s.
            pl.BlockSpec((NP, PS, kvh, hd), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((NP, PS, kvh, hd), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((1, MB), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, nh, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, hd), q.dtype),
        interpret=True,
    )(q, k_pages, v_pages, block_tables, lens)
