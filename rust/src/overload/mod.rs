//! Overload-oriented scheduling (§7): SLO-based load metrics, early
//! rejection, and prediction-based early rejection.
//!
//! Load is *SLO satisfaction* (§7.1): a prefill instance's load is its
//! predicted TTFT over `l_ttft`; a decode instance's is its predicted TBT
//! over `l_tbt` (or VRAM occupancy, whichever is tighter).  Admission
//! compares pool-level load against a threshold:
//!
//! * [`RejectionPolicy::Baseline`] — prefill load at arrival, decode load
//!   only when the KVCache reaches the decode node (wasting the prefill
//!   of anything rejected there).
//! * [`RejectionPolicy::Early`] — §7.2: also check *current* decode load
//!   at arrival.  Removes most waste but causes the Fig 9/10 anti-phase
//!   load oscillation (the decode load it reads is stale by one prefill).
//! * [`RejectionPolicy::Predictive`] — §7.4: check the decode load
//!   *predicted for the moment this request would finish prefill*, using
//!   the system-level uniform-`t_d` model.

use crate::config::{RejectionPolicy, SimConfig};
use crate::decode::DecodeInstance;
use crate::model::PerfModel;
use crate::prefill::PrefillPool;
use crate::util::fasthash::FastMap;
use crate::TimeMs;

/// An in-flight prefill whose KVCache will land on a decode instance.
#[derive(Debug, Clone, Copy)]
pub struct InFlight {
    pub kv_arrive: TimeMs,
    pub decode: usize,
    pub ctx_tokens: u64,
}

#[derive(Debug)]
pub struct Admission {
    pub policy: RejectionPolicy,
    /// Pool load above which requests are rejected.
    pub threshold: f64,
    /// Running estimate of the uniform decode duration t_d (ms), §7.4.
    t_d_ms: f64,
    n_obs: u64,
    pub rejected_at_arrival: u64,
    pub rejected_at_decode: u64,
}

impl Admission {
    pub fn new(policy: RejectionPolicy, threshold: f64) -> Self {
        Admission {
            policy,
            threshold,
            t_d_ms: 10_000.0, // prior until observations arrive
            n_obs: 0,
            rejected_at_arrival: 0,
            rejected_at_decode: 0,
        }
    }

    /// Feed a completed request's decode duration into the t_d estimate.
    pub fn observe_decode_duration(&mut self, ms: f64) {
        self.n_obs += 1;
        let alpha = 1.0 / self.n_obs.min(500) as f64; // EWMA after warmup
        self.t_d_ms += alpha * (ms - self.t_d_ms);
    }

    pub fn t_d_ms(&self) -> f64 {
        self.t_d_ms
    }

    /// Prefill pool load: the *best* instance's predicted TTFT ratio for
    /// a request of this size (if even the best can't meet it, the pool
    /// is loaded).  The nominal execution time and the queue drain both
    /// come from the unified cost model, so this load reads the same
    /// FIFO queues the simulator executes.
    pub fn prefill_load(
        &self,
        cfg: &SimConfig,
        pool: &PrefillPool,
        perf: &PerfModel,
        input_tokens: u64,
        now: TimeMs,
    ) -> f64 {
        let nominal = crate::costmodel::prefill_exec_ms(perf, cfg, input_tokens, 0, 1);
        // Dead nodes can't serve anyone — with no survivor the fold
        // stays INFINITY, which reads as a fully loaded pool (reject).
        pool.instances
            .iter()
            .filter(|i| i.alive)
            .map(|i| i.load(now, nominal, cfg.slo.ttft_ms))
            .fold(f64::INFINITY, f64::min)
    }

    /// Current decode pool load (average TBT ratio across instances, as
    /// §7.4 defines it).
    pub fn decode_load_now(
        &self,
        decodes: &[DecodeInstance],
        perf: &PerfModel,
        tbt_slo: f64,
    ) -> f64 {
        let sum: f64 = decodes.iter().map(|d| d.load(perf, tbt_slo)).sum();
        sum / decodes.len().max(1) as f64
    }

    /// §7.4 system-level prediction of decode pool load at `t_future`:
    /// requests decoding for longer than t_d by then are assumed done;
    /// in-flight prefills that land before `t_future` are added.
    pub fn decode_load_predicted(
        &self,
        decodes: &[DecodeInstance],
        in_flight: &FastMap<u64, InFlight>,
        perf: &PerfModel,
        t_future: TimeMs,
        tbt_slo: f64,
    ) -> f64 {
        let mut total = 0.0;
        for (i, d) in decodes.iter().enumerate() {
            let mut batch = 0u64;
            let mut kv = 0u64;
            for s in &d.active {
                if t_future - s.joined < self.t_d_ms {
                    batch += 1;
                    kv += s.ctx;
                }
            }
            for s in &d.waiting {
                if t_future - s.joined < self.t_d_ms {
                    batch += 1;
                    kv += s.ctx;
                }
            }
            for f in in_flight.values().filter(|f| f.decode == i && f.kv_arrive <= t_future) {
                batch += 1;
                kv += f.ctx_tokens;
            }
            if batch > 0 {
                // TBT ratio, concurrency-slot pressure, and VRAM pressure
                // — the same capacity axes as DecodeInstance::load.
                let tbt = perf.decode_step_ms(batch.min(d.max_batch as u64), kv) / tbt_slo;
                let slots = batch as f64 / d.max_batch.max(1) as f64;
                let vram = kv as f64 / d.kv_capacity_tokens.max(1) as f64;
                total += tbt.max(slots).max(vram);
            }
        }
        total / decodes.len().max(1) as f64
    }

    /// Arrival-time admission (§7.2 / §7.4).  `est_prefill_ms` is the
    /// scheduler's estimate for this request.
    #[allow(clippy::too_many_arguments)]
    pub fn admit_at_arrival(
        &mut self,
        cfg: &SimConfig,
        perf: &PerfModel,
        pool: &PrefillPool,
        decodes: &[DecodeInstance],
        in_flight: &FastMap<u64, InFlight>,
        input_tokens: u64,
        now: TimeMs,
    ) -> bool {
        if self.policy == RejectionPolicy::None {
            return true;
        }
        let p_load = self.prefill_load(cfg, pool, perf, input_tokens, now);
        if p_load > self.threshold {
            self.rejected_at_arrival += 1;
            return false;
        }
        let d_load = match self.policy {
            RejectionPolicy::Baseline => return true, // decode checked later
            RejectionPolicy::Early => self.decode_load_now(decodes, perf, cfg.slo.tbt_ms),
            RejectionPolicy::Predictive => {
                let est_prefill = crate::costmodel::prefill_exec_ms(perf, cfg, input_tokens, 0, 1)
                    + pool
                        .instances
                        .iter()
                        .filter(|i| i.alive)
                        .map(|i| i.queue_ms(now))
                        .fold(f64::INFINITY, f64::min);
                self.decode_load_predicted(
                    decodes,
                    in_flight,
                    perf,
                    now + est_prefill,
                    cfg.slo.tbt_ms,
                )
            }
            RejectionPolicy::None => unreachable!(),
        };
        if d_load > self.threshold {
            self.rejected_at_arrival += 1;
            return false;
        }
        true
    }

    /// Decode-side check when the KVCache lands (§3 step 4).  Under
    /// early/predictive rejection this assessment already happened at
    /// arrival (§7.2 "advance the load assessment ... to precede the
    /// beginning of the prefill stage"), so only the baseline pays here —
    /// wasting the completed prefill.
    pub fn admit_at_decode(
        &mut self,
        cfg: &SimConfig,
        perf: &PerfModel,
        decode: &DecodeInstance,
        _now: TimeMs,
    ) -> bool {
        if self.policy != RejectionPolicy::Baseline {
            return true;
        }
        let load = decode.load(perf, cfg.slo.tbt_ms);
        if load > self.threshold {
            self.rejected_at_decode += 1;
            return false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn env() -> (SimConfig, PerfModel, PrefillPool, Vec<DecodeInstance>) {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
            .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
            .collect();
        (cfg, perf, pool, decodes)
    }

    #[test]
    fn none_policy_admits_everything() {
        let (cfg, perf, pool, decodes) = env();
        let mut adm = Admission::new(RejectionPolicy::None, 1.0);
        let none = FastMap::default();
        assert!(adm.admit_at_arrival(&cfg, &perf, &pool, &decodes, &none, 1_000_000, 0.0));
    }

    #[test]
    fn baseline_ignores_decode_at_arrival_early_does_not() {
        let (cfg, perf, pool, mut decodes) = env();
        // Saturate decode instances far past the TBT SLO.
        for d in &mut decodes {
            for rid in 0..120 {
                d.enqueue(rid, 120_000, 500, 0.0);
            }
            d.admit_waiting();
        }
        let mut base = Admission::new(RejectionPolicy::Baseline, 1.0);
        let mut early = Admission::new(RejectionPolicy::Early, 1.0);
        let none = FastMap::default();
        assert!(base.admit_at_arrival(&cfg, &perf, &pool, &decodes, &none, 8_000, 0.0));
        assert!(!early.admit_at_arrival(&cfg, &perf, &pool, &decodes, &none, 8_000, 0.0));
        assert_eq!(early.rejected_at_arrival, 1);
        // The baseline pays at the decode double-check instead.
        assert!(!base.admit_at_decode(&cfg, &perf, &decodes[0], 0.0));
        assert_eq!(base.rejected_at_decode, 1);
    }

    #[test]
    fn predictive_sees_in_flight_prefills() {
        let (cfg, perf, pool, decodes) = env();
        let mut adm = Admission::new(RejectionPolicy::Predictive, 1.0);
        adm.t_d_ms = 1e9; // nothing finishes
        // Idle decode pool but a wall of in-flight prefills about to land.
        let in_flight: FastMap<u64, InFlight> = (0..2_000u64)
            .map(|i| {
                (i, InFlight {
                    kv_arrive: 10.0,
                    decode: i as usize % cfg.n_decode,
                    ctx_tokens: 64_000,
                })
            })
            .collect();
        assert!(!adm.admit_at_arrival(&cfg, &perf, &pool, &decodes, &in_flight, 8_000, 0.0));
        // Early rejection (current load only) would have accepted.
        let mut early = Admission::new(RejectionPolicy::Early, 1.0);
        assert!(early.admit_at_arrival(&cfg, &perf, &pool, &decodes, &in_flight, 8_000, 0.0));
    }

    #[test]
    fn prefill_saturation_rejects_all_policies() {
        let (cfg, perf, mut pool, decodes) = env();
        let none = FastMap::default();
        for i in &mut pool.instances {
            i.block_until(1e9);
        }
        for policy in
            [RejectionPolicy::Baseline, RejectionPolicy::Early, RejectionPolicy::Predictive]
        {
            let mut adm = Admission::new(policy, 1.0);
            assert!(
                !adm.admit_at_arrival(&cfg, &perf, &pool, &decodes, &none, 8_000, 0.0),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn t_d_estimate_converges() {
        let mut adm = Admission::new(RejectionPolicy::Predictive, 1.0);
        for _ in 0..1_000 {
            adm.observe_decode_duration(4_000.0);
        }
        assert!((adm.t_d_ms() - 4_000.0).abs() < 100.0);
    }
}
