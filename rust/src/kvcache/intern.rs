//! Block interning — the boundary where trace-level block *hashes*
//! become scheduler-internal dense ids.
//!
//! The published trace (and `chain_hashes` on the live path) identifies a
//! KVCache block by a 64-bit prefix-chain hash.  Those hashes are the
//! *public* surface (JSONL schema, Fig 6 analyzers) — but nothing inside
//! the scheduler needs them: Conductor, the pools, and the prefix index
//! only ever compare ids for equality.  [`BlockInterner`] maps each hash
//! to a dense `u32` at request admission (`sim::Sim::handle_arrival`),
//! and everything downstream — [`super::CachePool`],
//! [`super::PrefixIndex`], [`super::TierDelta`], migration heat — carries
//! [`DenseBlockId`]:
//!
//! * hot maps key on 4-byte ids instead of 8-byte hashes;
//! * the prefix index stops hashing entirely — dense ids index a flat
//!   residency table directly (see `kvcache::index`);
//! * ids are assigned in first-appearance order, so every run of the
//!   same trace produces the same ids (determinism is preserved).
//!
//! Interning is injective by construction: a new hash gets the next
//! unused dense id and a seen hash gets its existing id.  By default
//! nothing is ever un-interned, but a sustained multi-hour replay streams
//! an unbounded set of *distinct* blocks through a bounded cache — an
//! append-only id space would grow forever (and the index's flat
//! residency table with it).  [`BlockInterner::recycle_epoch`] therefore
//! supports **epoch-based id recycling**: the owner (the `Sim`, between
//! arrivals) passes a liveness bitset of ids still resident in any pool
//! tier; every dead id's hash mapping is dropped and the id goes onto a
//! free list for reuse by future hashes.  Within an epoch ids stay
//! stable, so determinism holds per (trace, recycle schedule) — and the
//! default schedule is "never", which is bit-for-bit the append-only
//! behavior.  A dropped block that re-enters the cluster later is simply
//! re-interned (possibly to a different id — its *identity* is the hash,
//! which the trace keeps).

use crate::util::fasthash::FastMap;
use crate::BlockId;

/// Dense scheduler-internal block id (see module docs).  `u32` bounds
/// the cluster at ~4.3 B distinct cache blocks — at 512 tokens/block
/// that is two *trillion* tokens of distinct prefix, far past any trace.
pub type DenseBlockId = u32;

/// Hash → dense-id map (one per simulated cluster, owned by the `Sim`
/// next to the interner's consumers).
#[derive(Debug, Default)]
pub struct BlockInterner {
    map: FastMap<BlockId, DenseBlockId>,
    /// Reverse map: id → the hash it was last assigned to.  An id is
    /// *allocated* iff `map[rev[id]] == id`; free-list entries keep a
    /// stale hash here until reassignment.
    rev: Vec<BlockId>,
    /// Recycled ids available for reuse, kept sorted **descending** so
    /// `pop()` hands them out lowest-first (deterministic and dense).
    free: Vec<DenseBlockId>,
    /// Completed recycle epochs.
    epochs: u64,
    /// Total ids ever freed across all epochs.
    freed: u64,
}

impl BlockInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Dense id for `hash`, assigning the lowest free id on first sight
    /// (the next never-used id when the free list is empty — with
    /// recycling off this is exactly the historical append-only order).
    #[inline]
    pub fn intern(&mut self, hash: BlockId) -> DenseBlockId {
        if let Some(&id) = self.map.get(&hash) {
            return id;
        }
        let id = match self.free.pop() {
            Some(id) => {
                self.rev[id as usize] = hash;
                id
            }
            None => {
                let id = DenseBlockId::try_from(self.rev.len())
                    .expect("interner exhausted u32 id space");
                self.rev.push(hash);
                id
            }
        };
        self.map.insert(hash, id);
        id
    }

    /// Intern a whole hash chain into a reused buffer (the per-arrival
    /// path — `out` is cleared first, so the caller's scratch never
    /// reallocates past the longest chain seen).
    pub fn intern_chain_into(&mut self, chain: &[BlockId], out: &mut Vec<DenseBlockId>) {
        out.clear();
        out.reserve(chain.len());
        for &h in chain {
            let id = self.intern(h);
            out.push(id);
        }
    }

    /// Dense id of an already-interned hash (read-only probe).
    pub fn lookup(&self, hash: BlockId) -> Option<DenseBlockId> {
        self.map.get(&hash).copied()
    }

    /// Distinct hashes currently interned (== allocated dense ids).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Size of the dense id space ever allocated (`0..id_space()` covers
    /// every id that may appear downstream — the liveness bitset for
    /// [`Self::recycle_epoch`] must span this range).
    pub fn id_space(&self) -> usize {
        self.rev.len()
    }

    /// Ids currently on the free list.
    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    /// Completed recycle epochs.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Total ids freed across all epochs.
    pub fn freed_total(&self) -> u64 {
        self.freed
    }

    /// Whether `id` is currently allocated (maps back to a live hash).
    pub fn is_allocated(&self, id: DenseBlockId) -> bool {
        self.rev.get(id as usize).is_some_and(|h| self.map.get(h) == Some(&id))
    }

    /// End an epoch: free every allocated id whose bit in `live` is
    /// clear.  `live` is a bitset over `0..id_space()` (word `i/64`, bit
    /// `i%64`; missing words read as all-dead).  The caller owns the
    /// liveness definition — for the `Sim` an id is live iff it is
    /// resident in some pool tier, which covers the `PrefixIndex` too
    /// (the index holds exactly the pool-resident ids).  Returns the
    /// number of ids freed this epoch.
    pub fn recycle_epoch(&mut self, live: &[u64]) -> usize {
        let before = self.free.len();
        for id in 0..self.rev.len() {
            let alive = (live.get(id / 64).copied().unwrap_or(0) >> (id % 64)) & 1 != 0;
            if alive {
                continue;
            }
            // Skip ids already on the free list (their rev entry is a
            // stale hash that no longer maps back to them).
            let hash = self.rev[id];
            if self.map.get(&hash) != Some(&(id as DenseBlockId)) {
                continue;
            }
            self.map.remove(&hash);
            self.free.push(id as DenseBlockId);
        }
        let freed = self.free.len() - before;
        // Keep the free list descending so pop() reuses lowest-first.
        self.free.sort_unstable_by(|a, b| b.cmp(a));
        self.epochs += 1;
        self.freed += freed as u64;
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_appearance_order_and_stability() {
        let mut it = BlockInterner::new();
        assert_eq!(it.intern(0xdead_beef), 0);
        assert_eq!(it.intern(42), 1);
        assert_eq!(it.intern(0xdead_beef), 0, "re-interning must be stable");
        assert_eq!(it.intern(u64::MAX), 2);
        assert_eq!(it.len(), 3);
        assert_eq!(it.lookup(42), Some(1));
        assert_eq!(it.lookup(7), None);
    }

    #[test]
    fn chain_interning_reuses_the_buffer() {
        let mut it = BlockInterner::new();
        let mut buf = Vec::new();
        it.intern_chain_into(&[10, 20, 10, 30], &mut buf);
        assert_eq!(buf, vec![0, 1, 0, 2]);
        let cap = buf.capacity();
        it.intern_chain_into(&[20, 30], &mut buf);
        assert_eq!(buf, vec![1, 2]);
        assert_eq!(buf.capacity(), cap, "shorter chains must not shrink the scratch");
    }

    #[test]
    fn recycle_frees_dead_ids_and_reuses_lowest_first() {
        let mut it = BlockInterner::new();
        for h in 100..108u64 {
            it.intern(h);
        }
        assert_eq!(it.id_space(), 8);
        // Only ids 2 and 5 (hashes 102/105) survive.
        let live = [(1u64 << 2) | (1 << 5)];
        let freed = it.recycle_epoch(&live);
        assert_eq!(freed, 6);
        assert_eq!(it.len(), 2);
        assert_eq!(it.free_len(), 6);
        assert_eq!(it.epochs(), 1);
        assert_eq!(it.freed_total(), 6);
        assert_eq!(it.lookup(102), Some(2));
        assert_eq!(it.lookup(105), Some(5));
        assert_eq!(it.lookup(100), None, "dead hash must be un-interned");
        // New hashes reuse freed ids ascending; the id space stays flat.
        assert_eq!(it.intern(200), 0);
        assert_eq!(it.intern(201), 1);
        assert_eq!(it.intern(202), 3);
        assert_eq!(it.intern(203), 4);
        assert_eq!(it.id_space(), 8, "recycling must not grow the id space");
        // Live ids were untouched and stay stable.
        assert_eq!(it.intern(102), 2);
        assert_eq!(it.intern(105), 5);
    }

    #[test]
    fn recycle_skips_free_list_entries_with_stale_hashes() {
        let mut it = BlockInterner::new();
        it.intern(1); // id 0
        it.intern(2); // id 1
        it.intern(3); // id 2
        // Free ids 0 and 1; then hash 1 re-enters and takes id 0 back.
        assert_eq!(it.recycle_epoch(&[1 << 2]), 2);
        assert_eq!(it.intern(1), 0);
        // Id 1 is still free: its rev entry (hash 2) is stale.  A second
        // epoch with everything dead must not double-free it.
        assert_eq!(it.recycle_epoch(&[0]), 2, "ids 0 and 2 freed, id 1 skipped");
        assert_eq!(it.free_len(), 3);
        assert!(it.is_empty());
        // And all three come back ascending.
        assert_eq!(it.intern(10), 0);
        assert_eq!(it.intern(11), 1);
        assert_eq!(it.intern(12), 2);
        assert_eq!(it.id_space(), 3);
    }

    #[test]
    fn allocation_probe_tracks_liveness() {
        let mut it = BlockInterner::new();
        it.intern(7); // id 0
        assert!(it.is_allocated(0));
        assert!(!it.is_allocated(1), "never-assigned id is not allocated");
        it.recycle_epoch(&[0]);
        assert!(!it.is_allocated(0), "freed id is not allocated");
        it.intern(9);
        assert!(it.is_allocated(0), "reused id is allocated again");
    }

    #[test]
    fn empty_epoch_is_a_noop_on_mappings() {
        let mut it = BlockInterner::new();
        it.intern(5);
        it.intern(6);
        let freed = it.recycle_epoch(&[0b11]);
        assert_eq!(freed, 0);
        assert_eq!(it.len(), 2);
        assert_eq!(it.epochs(), 1);
        assert_eq!(it.lookup(5), Some(0));
        assert_eq!(it.lookup(6), Some(1));
    }
}
