//! Model + hardware descriptions and the analytic performance model that
//! drives the discrete-event simulator (the paper's testbed substitute).

pub mod llama;
pub mod perf;

pub use llama::{HardwareSpec, ModelSpec};
pub use perf::PerfModel;
