//! Runtime no-alloc audit (tier-1, `--features alloc-audit`): the
//! counting global allocator in `util::alloc_audit` pins the
//! scheduler's warmed steady-state decision loop at **zero** heap
//! allocations — the runtime twin of `pallas_lint`'s static
//! `hot-no-alloc` rule, catching what token scanning cannot (an
//! allocation hidden behind a helper call, an amortized `Vec` that was
//! never pre-sized).
//!
//! One `#[test]` only: the allocation counter is process-global, so a
//! second concurrent test in this binary would pollute the audited
//! regions.  Both phases (scan pricing and index-backed pricing) run
//! sequentially inside it.

use mooncake::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig, SloConfig};
use mooncake::decode::DecodeInstance;
use mooncake::kvcache::DenseBlockId;
use mooncake::model::PerfModel;
use mooncake::prefill::PrefillPool;
use mooncake::resource::Resources;
use mooncake::trace::BLOCK_TOKENS;
use mooncake::util::alloc_audit::AllocGuard;
use mooncake::util::rng::Rng;

/// Allocations across `iters` warmed steady-state `schedule` calls
/// (SLO-rejecting, so every iteration prices identical cluster state
/// and nothing mutates).  Mirrors `benches/sched_throughput.rs`'s
/// `measure_allocs_per_decision`, as a pass/fail gate instead of a
/// reported column.
fn audit_decisions(use_index: bool, iters: usize) -> u64 {
    let mut cfg = SimConfig {
        n_prefill: 8,
        n_decode: 4,
        scheduling: SchedulingPolicy::KvCacheCentric,
        rejection: RejectionPolicy::None,
        cache_capacity_blocks: None,
        ssd_capacity_blocks: None,
        ..Default::default()
    };
    // ttft_ms = 0 makes the SLO gate reject after the *full* pricing
    // pass (prefill + decode selection), before any mutation.
    cfg.slo = SloConfig { ttft_ms: 0.0, tbt_ms: 1e9 };
    let chain = 256usize;
    let perf = PerfModel::paper();

    // Warm every node with the probe chain plus two filler chains, so
    // pricing pays its worst case against realistically loaded maps.
    let mut pool = PrefillPool::new(&cfg);
    let probe: Vec<DenseBlockId> = (0..chain as u32).collect();
    for (node, inst) in pool.instances.iter_mut().enumerate() {
        let _ = inst.pool.admit_chain(&probe, 0.0);
        for f in 0..2u32 {
            let base = 1_000_000 + (node as u32 * 2 + f) * chain as u32;
            let filler: Vec<DenseBlockId> = (base..base + chain as u32).collect();
            let _ = inst.pool.admit_chain(&filler, 0.0);
        }
    }
    let mut index = use_index.then(|| pool.build_prefix_index());

    let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
        .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
        .collect();
    let mut res = Resources::new(&cfg, &perf);
    let mut rng = Rng::new(7);
    let mut scratch = SchedScratch::default();
    let mut stats = ConductorStats::default();
    let req = SchedRequest {
        rid: 1,
        input_tokens: chain as u64 * BLOCK_TOKENS,
        output_tokens: 8,
        hash_ids: probe,
    };
    let mut run_one = |now: f64| {
        let mut ctx = conductor::Ctx {
            cfg: &cfg,
            perf: &perf,
            prefill: &mut pool,
            decodes: &decodes,
            res: &mut res,
            rng: &mut rng,
            now,
            index: index.as_mut(),
            scratch: &mut scratch,
        };
        let out = conductor::schedule(&mut ctx, &req, &mut stats);
        assert!(out.is_err(), "SLO-rejecting steady state must reject");
    };
    for w in 0..64 {
        run_one(w as f64);
    }
    let guard = AllocGuard::new();
    for k in 0..iters {
        run_one(k as f64);
    }
    guard.count()
}

#[test]
fn steady_state_decisions_do_not_allocate() {
    let iters = 1_000usize;

    // Scan pricing (no global index): allocation-free in every build
    // profile once the scratch buffers are warm.
    let scan = audit_decisions(false, iters);
    assert_eq!(scan, 0, "scan-path decision loop allocated ({scan} allocs / {iters} decisions)");

    // Index-backed pricing: the release hot path is allocation-free.
    // Debug builds run the scan-vs-index parity self-check inside
    // `find_prefix_matches_into`, which allocates by design — so this
    // phase only gates optimized builds (CI runs it via
    // `cargo test --release --features alloc-audit`).
    if !cfg!(debug_assertions) {
        let indexed = audit_decisions(true, iters);
        assert_eq!(
            indexed, 0,
            "index-path decision loop allocated ({indexed} allocs / {iters} decisions)"
        );
    }
}
