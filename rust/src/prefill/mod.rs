//! Prefill instance pool (§5): **real per-instance FIFO job queues**
//! driven by the simulator's `PrefillStart`/`PrefillDone` events, chunked
//! pipeline parallelism for long contexts, and the layer-wise overlap
//! accounting that lets scheduling ignore VRAM on prefill nodes.
//!
//! Queueing, CPP group occupancy, and the KV stream to decode used to be
//! analytic side effects of a scalar `busy_until`; they are now
//! observable events over an explicit queue:
//!
//! * [`PrefillPool::submit`] admits a [`PrefillJob`] onto every group
//!   member's FIFO queue and fixes its execution makespan from the
//!   unified cost model ([`crate::costmodel::prefill_exec_ms`]) — the
//!   same function Conductor's estimate used, so the *planned* window
//!   recorded at admission equals what the events deliver.
//! * [`PrefillPool::startable`] / [`PrefillPool::start`] /
//!   [`PrefillPool::finish`] are the executor: a job starts when it is at
//!   the head of **all** its members' queues, every member is idle, and
//!   its gate (remote prefix fetch landing §6.2, and/or the local
//!   SSD→DRAM staging read reserved on the NVMe queue) has passed.  FIFO
//!   order per instance is preserved — a gated head blocks its queue,
//!   exactly like a real dispatch loop.

pub mod layerwise;

use std::collections::VecDeque;

use crate::config::SimConfig;
use crate::costmodel;
use crate::kvcache::{CachePool, PolicyKind, ShardedPrefixIndex};
use crate::model::PerfModel;
use crate::util::fasthash::FastMap;
use crate::{RequestId, TimeMs};

/// Monotonically increasing prefill job id (admission order).
pub type JobId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting in its group's FIFO queues.
    Queued,
    /// Occupying every group member.
    Running,
    /// Completed (only observed on the job returned by `finish`).
    Done,
}

/// One admitted prefill job.
#[derive(Debug, Clone)]
pub struct PrefillJob {
    pub id: JobId,
    pub rid: RequestId,
    /// CPP group members (primary first).
    pub group: Vec<usize>,
    pub n_new: u64,
    pub prefix_tokens: u64,
    /// May not start before this: the latest of the remote prefix fetch
    /// landing and the local SSD→DRAM staging read, both reserved on
    /// their resource queues at admission.
    pub gate: TimeMs,
    /// Execution makespan fixed at admission from the unified cost model.
    pub exec_ms: f64,
    pub submitted: TimeMs,
    /// Planned window from the cost model at admission — kept so
    /// estimate/actual drift is measurable per job.
    pub planned_start: TimeMs,
    pub planned_end: TimeMs,
    pub state: JobState,
    /// NaN until the corresponding event happens.
    pub actual_start: TimeMs,
    pub actual_end: TimeMs,
}

/// One prefill node: a FIFO queue of committed jobs plus the node's
/// CPU-DRAM KVCache pool.
#[derive(Debug)]
pub struct PrefillInstance {
    /// Committed jobs in FIFO order (this instance participates in each).
    pub queue: VecDeque<JobId>,
    /// Job currently occupying this instance, if any.
    pub running: Option<JobId>,
    /// Drain horizon: when the committed queue is expected to empty.
    /// Maintained by `submit`/`finish` from the same cost model the
    /// executor uses, so it doubles as the queue-time estimate.
    free_at: TimeMs,
    pub pool: CachePool,
    /// Requests prefilled and compute-ms spent (utilization accounting).
    pub n_prefilled: u64,
    pub busy_ms: f64,
    /// False while the node is down (fault injection): the conductor
    /// skips it for placement, CPP recruitment, and admission load; the
    /// sim cancels its jobs and drops its pools on loss.  `true` by
    /// default — and a recovered node comes back `true` with empty
    /// pools.
    pub alive: bool,
    /// GPU-generation speed multiplier (heterogeneity): execution and
    /// estimation both divide the nominal prefill makespan by the
    /// group's min speed.  1.0 (the default) is bit-identical to the
    /// homogeneous cluster.
    pub speed: f64,
}

impl PrefillInstance {
    pub fn new(
        eviction: PolicyKind,
        dram_capacity_blocks: Option<usize>,
        ssd_capacity_blocks: Option<usize>,
    ) -> Self {
        PrefillInstance {
            queue: VecDeque::new(),
            running: None,
            free_at: 0.0,
            pool: CachePool::new(eviction, dram_capacity_blocks, ssd_capacity_blocks),
            n_prefilled: 0,
            busy_ms: 0.0,
            alive: true,
            speed: 1.0,
        }
    }

    /// Algorithm 1's `EstimatePrefillQueueTime`: time until this
    /// instance's committed FIFO work drains.
    pub fn queue_ms(&self, now: TimeMs) -> f64 {
        (self.free_at - now).max(0.0)
    }

    /// Jobs committed but not yet started on this instance.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Test/bench hook: model external load by pushing the drain horizon
    /// (the estimator sees this instance as busy until `t`).
    pub fn block_until(&mut self, t: TimeMs) {
        self.free_at = self.free_at.max(t);
    }

    /// §7.1 load: predicted TTFT of a nominal request against the SLO.
    pub fn load(&self, now: TimeMs, nominal_prefill_ms: f64, ttft_slo: f64) -> f64 {
        (self.queue_ms(now) + nominal_prefill_ms) / ttft_slo
    }
}

/// The prefill pool: instances, their job queues, and CPP group
/// formation.
#[derive(Debug)]
pub struct PrefillPool {
    pub instances: Vec<PrefillInstance>,
    jobs: FastMap<JobId, PrefillJob>,
    next_job: JobId,
    /// Recycled CPP-group buffers: `finish` reclaims each completed
    /// job's group vector and `submit` reuses it, so a warmed
    /// admit→start→finish cycle allocates nothing for the job record.
    group_pool: Vec<Vec<usize>>,
}

impl PrefillPool {
    pub fn new(cfg: &SimConfig) -> Self {
        for o in &cfg.node_overrides {
            assert!(
                o.node < cfg.n_prefill,
                "node override {} out of range (n_prefill {})",
                o.node,
                cfg.n_prefill
            );
        }
        PrefillPool {
            instances: (0..cfg.n_prefill)
                .map(|node| {
                    // Heterogeneity: a NodeOverride replaces this node's
                    // speed and/or tier capacities; everything else keeps
                    // the cluster-wide config.
                    let ov = cfg.node_overrides.iter().find(|o| o.node == node);
                    let mut inst = PrefillInstance::new(
                        cfg.eviction,
                        ov.and_then(|o| o.dram_blocks).or(cfg.cache_capacity_blocks),
                        ov.and_then(|o| o.ssd_blocks).or(cfg.ssd_capacity_blocks),
                    );
                    if let Some(o) = ov {
                        assert!(
                            o.speed.is_finite() && o.speed > 0.0,
                            "node {node}: bad speed override {}",
                            o.speed
                        );
                        inst.speed = o.speed;
                    }
                    inst
                })
                .collect(),
            jobs: FastMap::default(),
            next_job: 0,
            group_pool: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Brute-force build of the Conductor's global [`ShardedPrefixIndex`]
    /// from the current pools.  Incremental maintenance afterwards goes
    /// through the [`crate::kvcache::TierDelta`]s the pool mutators
    /// return — this rebuild is the debug invariant's ground truth and
    /// the cold-start path.
    pub fn build_prefix_index(&self) -> ShardedPrefixIndex {
        let mut idx = ShardedPrefixIndex::new(self.len());
        for (node, inst) in self.instances.iter().enumerate() {
            idx.insert_pool(node, &inst.pool);
        }
        idx
    }

    /// Latest drain horizon across a CPP group — when a job admitted now
    /// could start (gates aside).
    pub fn group_free_at(&self, group: &[usize]) -> TimeMs {
        group.iter().map(|&i| self.instances[i].free_at).fold(0.0f64, f64::max)
    }

    /// Admitted jobs not yet finished (queued or running).
    pub fn outstanding(&self) -> usize {
        self.jobs.len()
    }

    /// Look up an admitted job.
    pub fn job(&self, id: JobId) -> &PrefillJob {
        self.jobs.get(&id).expect("unknown prefill job")
    }

    /// Is `id` still admitted (queued or running)?  Node loss cancels
    /// jobs out from under their scheduled events, so the sim guards
    /// `PrefillStart`/`PrefillDone` handlers with this.
    pub fn contains_job(&self, id: JobId) -> bool {
        self.jobs.contains_key(&id)
    }

    /// Slowest member bounds a CPP group: pipeline stages synchronize,
    /// so a mixed-generation group runs at its min speed.
    pub fn group_speed(&self, group: &[usize]) -> f64 {
        group.iter().map(|&i| self.instances[i].speed).fold(f64::INFINITY, f64::min)
    }

    /// The ONE heterogeneity-aware execution makespan — nominal cost
    /// over the group divided by the group's min speed — used by both
    /// the estimator ([`costmodel::estimate_prefill`]) and the executor
    /// ([`Self::submit_with_floor`]), so estimate == actual holds on
    /// mixed clusters.  `x / 1.0` is bit-identical to `x`, so the
    /// homogeneous default is unchanged bit-for-bit.
    // lint: hot
    pub fn exec_ms_for(
        &self,
        perf: &PerfModel,
        cfg: &SimConfig,
        group: &[usize],
        n_new: u64,
        prefix_tokens: u64,
    ) -> f64 {
        costmodel::prefill_exec_ms(perf, cfg, n_new, prefix_tokens, group.len() as u64)
            / self.group_speed(group)
    }

    /// Collect every admitted (queued or running) job whose CPP group
    /// contains `node`, appending to `out` — the member-based half of
    /// the node-loss doomed set.  The caller sorts + dedups before
    /// acting, so FastMap iteration order never reaches a decision.
    pub fn collect_jobs_touching(&self, node: usize, out: &mut Vec<JobId>) {
        for (&id, job) in self.jobs.iter() {
            if job.group.contains(&node) {
                out.push(id);
            }
        }
    }

    /// Cancel jobs by id (callers pass a sorted, deduped list): remove
    /// the records, purge every member's FIFO queue, free occupied
    /// running slots, and recompute each instance's drain horizon from
    /// the surviving jobs' planned ends (a horizon in the past is
    /// harmless — `queue_ms` clamps at zero).  Appends `(id, rid)` per
    /// cancelled job to `out` in the order given, so the sim can hand
    /// the orphaned requests back to the conductor.  Ids no longer
    /// admitted are skipped silently (a request may have finished
    /// between collection and cancellation).
    // lint: hot
    pub fn cancel_jobs(&mut self, ids: &[JobId], out: &mut Vec<(JobId, RequestId)>) {
        for &id in ids {
            let Some(mut job) = self.jobs.remove(&id) else { continue };
            for &m in &job.group {
                self.instances[m].queue.retain(|&q| q != id);
                if self.instances[m].running == Some(id) {
                    self.instances[m].running = None;
                }
            }
            out.push((id, job.rid));
            self.group_pool.push(std::mem::take(&mut job.group));
        }
        // Drain horizons restate over the survivors: every remaining
        // queued/running job keeps the planned end it was admitted with
        // (cancellation never *delays* surviving work, and the
        // planned-start floor in `startable_into` keeps it from starting
        // early into the freed gap — estimate == actual survives).
        for inst in self.instances.iter_mut() {
            inst.free_at = 0.0;
        }
        for job in self.jobs.values() {
            for &m in &job.group {
                if self.instances[m].free_at < job.planned_end {
                    self.instances[m].free_at = job.planned_end;
                }
            }
        }
    }

    /// Decide the CPP group for an input of `n_new` uncached tokens
    /// (§5.1), writing the member ids into a caller-owned (reused)
    /// buffer — the primary is always first.  Long contexts recruit idle
    /// peers, short ones stay local.  Allocation-free: the scheduler's
    /// decision loop calls this per candidate estimate.
    pub fn cpp_group_into(
        &self,
        cfg: &SimConfig,
        primary: usize,
        n_new: u64,
        now: TimeMs,
        group: &mut Vec<usize>,
    ) {
        group.clear();
        group.push(primary);
        if n_new < cfg.cpp_threshold_tokens || cfg.cpp_group_max <= 1 {
            return;
        }
        // Recruit the idlest peers; only nearly-idle nodes join a pipeline
        // group (recruiting a busy node would delay its own queue).
        // Repeated min-extraction with a strict `<` keeps ties in index
        // order — the same members the old sort-based selection picked —
        // without a candidate list allocation.
        for _ in 0..cfg.cpp_group_max as usize - 1 {
            let mut best_i = usize::MAX;
            let mut best_q = f64::INFINITY;
            for (i, inst) in self.instances.iter().enumerate() {
                if i == primary || !inst.alive || group.contains(&i) {
                    continue;
                }
                let q = inst.queue_ms(now);
                if q < 1.0 && q < best_q {
                    best_q = q;
                    best_i = i;
                }
            }
            if best_i == usize::MAX {
                break;
            }
            group.push(best_i);
        }
    }

    /// Allocating convenience form of [`Self::cpp_group_into`].
    pub fn cpp_group(
        &self,
        cfg: &SimConfig,
        primary: usize,
        n_new: u64,
        now: TimeMs,
    ) -> Vec<usize> {
        let mut group = Vec::new();
        self.cpp_group_into(cfg, primary, n_new, now, &mut group);
        group
    }

    /// Admit a prefill job onto every group member's FIFO queue.  The
    /// execution makespan and planned window come from the unified cost
    /// model over the current queue state, so they match what Conductor
    /// just estimated.  Returns the job id; execution happens through
    /// `startable`/`start`/`finish` (the simulator's
    /// `PrefillStart`/`PrefillDone` events).
    #[allow(clippy::too_many_arguments)]
    // lint: hot
    pub fn submit(
        &mut self,
        perf: &PerfModel,
        cfg: &SimConfig,
        rid: RequestId,
        group: &[usize],
        n_new: u64,
        prefix_tokens: u64,
        gate: TimeMs,
        now: TimeMs,
    ) -> JobId {
        self.submit_with_floor(
            perf,
            cfg,
            rid,
            group,
            n_new,
            prefix_tokens,
            gate,
            now,
            f64::NEG_INFINITY,
        )
    }

    /// [`Self::submit`] with a completion floor: the job may not finish
    /// before `min_end` (absolute ms).  This is how a *hybrid* placement
    /// executes its overlapped staging read — the NVMe reservation is not
    /// a start gate but a floor on the end, so any staging overhang folds
    /// into the job's effective makespan exactly as
    /// [`costmodel::estimate_prefill_hybrid`] priced it.
    /// `f64::NEG_INFINITY` (what [`Self::submit`] passes) makes the floor
    /// a no-op bit-for-bit: `exec.max(-inf - start) == exec`.
    #[allow(clippy::too_many_arguments)]
    // lint: hot
    pub fn submit_with_floor(
        &mut self,
        perf: &PerfModel,
        cfg: &SimConfig,
        rid: RequestId,
        group: &[usize],
        n_new: u64,
        prefix_tokens: u64,
        gate: TimeMs,
        now: TimeMs,
        min_end: TimeMs,
    ) -> JobId {
        debug_assert!(!group.is_empty());
        let base_exec_ms = self.exec_ms_for(perf, cfg, group, n_new, prefix_tokens);
        let planned_start = self.group_free_at(group).max(gate).max(now);
        let exec_ms = base_exec_ms.max(min_end - planned_start);
        let planned_end = planned_start + exec_ms;
        self.next_job += 1;
        let id = self.next_job;
        for &m in group {
            self.instances[m].queue.push_back(id);
            self.instances[m].free_at = planned_end;
        }
        // Reuse a reclaimed group buffer (warmed steady state: zero
        // allocations per admitted job).
        let mut g = self.group_pool.pop().unwrap_or_default();
        g.clear();
        g.extend_from_slice(group);
        self.jobs.insert(
            id,
            PrefillJob {
                id,
                rid,
                group: g,
                n_new,
                prefix_tokens,
                gate,
                exec_ms,
                submitted: now,
                planned_start,
                planned_end,
                state: JobState::Queued,
                actual_start: f64::NAN,
                actual_end: f64::NAN,
            },
        );
        id
    }

    /// Jobs that can start at `now`, written into a caller-owned
    /// (reused) buffer: at the head of every member's queue, all members
    /// idle, gate passed.  Sorted by admission order.  Allocation-free
    /// once `out` has warmed — the Sim's event pump calls this per
    /// start opportunity.
    // lint: hot
    pub fn startable_into(&self, now: TimeMs, out: &mut Vec<JobId>) {
        out.clear();
        for inst in &self.instances {
            if inst.running.is_some() {
                continue;
            }
            let Some(&id) = inst.queue.front() else { continue };
            if out.contains(&id) {
                continue;
            }
            let job = &self.jobs[&id];
            if job.gate > now {
                continue;
            }
            // Planned-start floor: in a healthy run a job is never ready
            // before its planned start (predecessors finish exactly at
            // their planned ends), so this is bit-neutral — but after a
            // cancellation frees a queue slot early, starting into the
            // gap would finish *before* the estimate and break the
            // estimate == actual contract.  The job's outstanding wake
            // event at `planned_start` starts it on time.
            if job.planned_start > now {
                continue;
            }
            let ready = job.group.iter().all(|&m| {
                self.instances[m].running.is_none()
                    && self.instances[m].queue.front() == Some(&id)
            });
            if ready {
                out.push(id);
            }
        }
        out.sort_unstable();
    }

    /// Allocating convenience form of [`Self::startable_into`].
    pub fn startable(&self, now: TimeMs) -> Vec<JobId> {
        let mut out = Vec::new();
        self.startable_into(now, &mut out);
        out
    }

    /// Earliest future gate among queued jobs.  The simulator does not
    /// need this — it arms a `PrefillStart` event per job at admission —
    /// but external drivers (tests, future schedulers) use it to know
    /// when a fully idle pool wakes up next.
    pub fn min_pending_gate(&self, now: TimeMs) -> Option<TimeMs> {
        self.jobs
            .values()
            .filter(|j| j.state == JobState::Queued && j.gate > now)
            .map(|j| j.gate)
            .fold(None, |acc, g| Some(acc.map_or(g, |a: f64| a.min(g))))
    }

    /// Start a job: pops it from every member's queue and occupies the
    /// members.  Returns (primary, exec_ms, rid) for the caller to
    /// schedule the completion event and the decode-bound KV stream.
    /// Allocation-free: the group buffer is borrowed out of the job
    /// record for the member walk and put back.
    // lint: hot
    pub fn start(&mut self, id: JobId, now: TimeMs) -> (usize, f64, RequestId) {
        let (group, exec_ms, rid) = {
            let job = self.jobs.get_mut(&id).expect("start of unknown job");
            debug_assert_eq!(job.state, JobState::Queued);
            debug_assert!(job.gate <= now + 1e-9, "started before its gate");
            job.state = JobState::Running;
            job.actual_start = now;
            (std::mem::take(&mut job.group), job.exec_ms, job.rid)
        };
        for &m in &group {
            let head = self.instances[m].queue.pop_front();
            debug_assert_eq!(head, Some(id), "job not at queue head on start");
            debug_assert!(self.instances[m].running.is_none());
            self.instances[m].running = Some(id);
        }
        let primary = group[0];
        self.jobs.get_mut(&id).expect("job vanished mid-start").group = group;
        (primary, exec_ms, rid)
    }

    /// Complete a job at `now`: frees the members, records utilization,
    /// and returns the job (with actual start/end filled in).  The CPP
    /// group buffer is reclaimed for reuse by a future `submit`, so the
    /// returned job's `group` is empty — callers read ids and timings.
    // lint: hot
    pub fn finish(&mut self, id: JobId, now: TimeMs) -> PrefillJob {
        let mut job = self.jobs.remove(&id).expect("finish of unknown job");
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::Done;
        job.actual_end = now;
        for &m in &job.group {
            debug_assert_eq!(self.instances[m].running, Some(id));
            self.instances[m].running = None;
            self.instances[m].busy_ms += job.exec_ms;
            if self.instances[m].free_at < now {
                self.instances[m].free_at = now;
            }
        }
        self.instances[job.group[0]].n_prefilled += 1;
        self.group_pool.push(std::mem::take(&mut job.group));
        job
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    /// Minimal event loop over a pool: starts whatever is startable,
    /// advances to the next completion or gate, finishes jobs.  Returns
    /// each job's (id, actual_start, actual_end) in completion order.
    fn drive(pool: &mut PrefillPool) -> Vec<(JobId, TimeMs, TimeMs)> {
        let mut now = 0.0f64;
        let mut running: Vec<(TimeMs, JobId)> = Vec::new();
        let mut done = Vec::new();
        loop {
            for id in pool.startable(now) {
                let (_, exec, _) = pool.start(id, now);
                running.push((now + exec, id));
            }
            if running.is_empty() {
                match pool.min_pending_gate(now) {
                    Some(g) => {
                        now = g;
                        continue;
                    }
                    None => break,
                }
            }
            running.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let (t, id) = running.remove(0);
            now = t;
            let job = pool.finish(id, now);
            done.push((id, job.actual_start, job.actual_end));
        }
        done
    }

    #[test]
    fn fifo_order_preserved_per_instance() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let ids: Vec<JobId> = [8_000u64, 2_000, 16_000]
            .iter()
            .map(|&n| pool.submit(&perf, &c, n, &[0], n, 0, 0.0, 0.0))
            .collect();
        let done = drive(&mut pool);
        // Completion (and start) order == admission order, even though the
        // second job is the shortest.
        let order: Vec<JobId> = done.iter().map(|d| d.0).collect();
        assert_eq!(order, ids);
        for w in done.windows(2) {
            assert!(w[1].1 >= w[0].2, "next start {} before prior end {}", w[1].1, w[0].2);
        }
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn actual_execution_matches_planned_window() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let mut planned = Vec::new();
        for (i, n) in [8_000u64, 12_000, 4_000, 9_000].iter().enumerate() {
            let id = pool.submit(&perf, &c, i as u64, &[i % 2], *n, 0, 0.0, 0.0);
            let j = pool.job(id);
            planned.push((id, j.planned_start, j.planned_end));
        }
        let mut done = drive(&mut pool);
        done.sort_by_key(|d| d.0);
        for ((id, ps, pe), (jid, s, e)) in planned.into_iter().zip(done) {
            assert_eq!(id, jid);
            assert!((s - ps).abs() < 1e-9, "job {id}: actual start {s} != planned {ps}");
            assert!((e - pe).abs() < 1e-9, "job {id}: actual end {e} != planned {pe}");
        }
    }

    #[test]
    fn queue_estimate_matches_simulated_drain() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        for n in [8_000u64, 8_000, 8_000] {
            pool.submit(&perf, &c, n, &[0], n, 0, 0.0, 0.0);
        }
        let est_drain = pool.instances[0].queue_ms(0.0);
        let done = drive(&mut pool);
        let actual_drain = done.last().unwrap().2;
        assert!(
            (est_drain - actual_drain).abs() < 1e-9,
            "queue estimate {est_drain} != simulated drain {actual_drain}"
        );
        // Other instances untouched.
        assert_eq!(pool.instances[1].queue_ms(0.0), 0.0);
    }

    #[test]
    fn group_job_occupies_all_members() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let id = pool.submit(&perf, &c, 1, &[0, 1], 100_000, 0, 0.0, 0.0);
        assert_eq!(pool.startable(0.0), vec![id]);
        let (primary, exec, _) = pool.start(id, 0.0);
        assert_eq!(primary, 0);
        assert_eq!(pool.instances[0].running, Some(id));
        assert_eq!(pool.instances[1].running, Some(id));
        // Neither member can take other work while occupied.
        let id2 = pool.submit(&perf, &c, 2, &[1], 8_000, 0, 0.0, 0.0);
        assert!(pool.startable(0.0).is_empty());
        let job = pool.finish(id, exec);
        assert_eq!(job.actual_end, exec);
        assert!((pool.instances[0].busy_ms - exec).abs() < 1e-9);
        assert!((pool.instances[1].busy_ms - exec).abs() < 1e-9);
        assert_eq!(pool.instances[0].n_prefilled, 1);
        assert_eq!(pool.instances[1].n_prefilled, 0);
        assert_eq!(pool.startable(exec), vec![id2]);
    }

    #[test]
    fn gated_job_waits_for_fetch_and_blocks_its_queue() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let gated = pool.submit(&perf, &c, 1, &[0], 8_000, 0, 500.0, 0.0);
        let behind = pool.submit(&perf, &c, 2, &[0], 2_000, 0, 0.0, 0.0);
        // Head-of-line: nothing starts before the gate...
        assert!(pool.startable(0.0).is_empty());
        assert_eq!(pool.min_pending_gate(0.0), Some(500.0));
        // ...and the gated job starts exactly at it, FIFO intact.
        assert_eq!(pool.startable(500.0), vec![gated]);
        assert!(pool.job(gated).planned_start >= 500.0);
        assert!(pool.job(behind).planned_start >= pool.job(gated).planned_end - 1e-9);
        let done = drive(&mut pool);
        assert_eq!(done[0].0, gated);
        assert!((done[0].1 - 500.0).abs() < 1e-9);
        assert_eq!(done[1].0, behind);
    }

    #[test]
    fn no_job_left_behind_under_mixed_load() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let mut submitted = Vec::new();
        for k in 0..20u64 {
            let primary = (k % 4) as usize;
            let group: Vec<usize> = if k % 5 == 0 { vec![primary, (primary + 1) % 4] } else { vec![primary] };
            let gate = if k % 3 == 0 { 50.0 * k as f64 } else { 0.0 };
            submitted.push(pool.submit(&perf, &c, k, &group, 4_000 + 500 * k, 0, gate, 0.0));
        }
        let done = drive(&mut pool);
        assert_eq!(done.len(), 20);
        assert_eq!(pool.outstanding(), 0);
        let mut finished: Vec<JobId> = done.iter().map(|d| d.0).collect();
        finished.sort_unstable();
        assert_eq!(finished, submitted);
    }

    #[test]
    fn cpp_group_only_for_long_inputs() {
        let c = cfg();
        let pool = PrefillPool::new(&c);
        assert_eq!(pool.cpp_group(&c, 0, 8_000, 0.0).len(), 1);
        let g = pool.cpp_group(&c, 0, 100_000, 0.0);
        assert!(g.len() > 1 && g.len() <= c.cpp_group_max as usize);
        assert_eq!(g[0], 0);
    }

    #[test]
    fn cpp_group_skips_busy_peers() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        // Make every peer busy with committed work.
        for i in 1..c.n_prefill {
            pool.submit(&perf, &c, i as u64, &[i], 64_000, 0, 0.0, 0.0);
        }
        let g = pool.cpp_group(&c, 0, 100_000, 0.0);
        assert_eq!(g, vec![0]);
    }

    #[test]
    fn cpp_shortens_long_prefill() {
        let c = cfg();
        let perf = PerfModel::paper();
        let solo = costmodel::prefill_exec_ms(&perf, &c, 128_000, 0, 1);
        let quad = costmodel::prefill_exec_ms(&perf, &c, 128_000, 0, 4);
        assert!(quad < solo * 0.6, "{quad} vs {solo}");
        // And the pool charges the group the same makespan.
        let mut pool = PrefillPool::new(&c);
        let id = pool.submit(&perf, &c, 1, &[0, 1, 2, 3], 128_000, 0, 0.0, 0.0);
        assert!((pool.job(id).exec_ms - quad).abs() < 1e-9);
    }
}
