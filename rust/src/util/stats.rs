//! Descriptive statistics used across experiments: percentiles, CDFs,
//! means, and simple fixed-width histograms.

/// Percentile (0..=100) by linear interpolation on a *sorted copy*.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile on already-sorted data (no copy).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Empirical CDF evaluated at the given thresholds: fraction of xs <= t.
pub fn cdf_at(xs: &[f64], thresholds: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    thresholds
        .iter()
        .map(|&t| {
            let idx = v.partition_point(|&x| x <= t);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Fraction of values <= threshold (SLO attainment).
pub fn attainment(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; out-of-range
/// values clamp to the edge buckets.
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            bins - 1
        } else {
            ((x - self.lo) / (self.hi - self.lo) * bins as f64) as usize
        };
        self.counts[idx.min(bins - 1)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// (bucket midpoint, fraction) pairs.
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + w * (i as f64 + 0.5), c as f64 / total))
            .collect()
    }
}

/// Streaming mean/min/max/count accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-9);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn attainment_counts_boundary() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(attainment(&xs, 2.0), 0.5);
        assert_eq!(attainment(&xs, 0.5), 0.0);
        assert_eq!(attainment(&xs, 10.0), 1.0);
    }

    #[test]
    fn cdf_monotone() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let c = cdf_at(&xs, &[10.0, 50.0, 99.0]);
        assert!(c[0] < c[1] && c[1] < c[2]);
        assert!((c[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(15.0);
        h.add(5.0);
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.counts[5], 1);
    }

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.add(x);
        }
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean(), 2.0);
    }
}
