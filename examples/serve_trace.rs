//! Trace replay through the full Mooncake cluster simulator — the
//! paper-scale path (dummy LLaMA2-70B on 8xA800 nodes, modeled
//! analytically).  Generates (or loads) a calibrated trace, replays it
//! through Conductor + prefill pool + Messenger + decode pool, and
//! prints the §8-style report plus TTFT/TBT CDFs.
//!
//!     cargo run --release --offline --example serve_trace -- \
//!         [--trace trace.jsonl] [--requests 8000] [--prefill 8] \
//!         [--decode 8] [--speedup 1.0]

use anyhow::Result;
use mooncake::config::SimConfig;
use mooncake::sim;
use mooncake::trace::{gen, jsonl, stats};
use mooncake::util::args::Args;
use mooncake::util::stats::cdf_at;

fn main() -> Result<()> {
    let args = Args::parse();
    let trace = match args.get("trace") {
        Some(path) => {
            println!("loading trace from {path}");
            jsonl::load(path)?
        }
        None => {
            let n = args.get_usize("requests", 8_000);
            println!("generating calibrated trace ({n} requests)");
            gen::generate(&gen::TraceGenConfig { n_requests: n, ..Default::default() })
        }
    };
    let s = stats::summarize(&trace);
    println!(
        "trace: {} requests, mean input {:.0} / output {:.0} tokens, {} unique blocks\n",
        s.n_requests, s.mean_input, s.mean_output, s.unique_blocks
    );

    let cfg = SimConfig {
        n_prefill: args.get_usize("prefill", 8),
        n_decode: args.get_usize("decode", 8),
        ..Default::default()
    };
    let speedup = args.get_f64("speedup", 1.0);
    let t = std::time::Instant::now();
    let res = sim::run(&cfg, &trace, speedup);
    let wall = t.elapsed().as_secs_f64();
    let rep = res.report(&cfg);

    println!("--- Mooncake [{}P+{}D], replay x{speedup} ---", cfg.n_prefill, cfg.n_decode);
    println!("completed {} / {} requests", rep.n_completed, rep.n_total);
    println!(
        "rejected: {} at arrival, {} after prefill",
        rep.n_rejected_arrival, rep.n_rejected_after_prefill
    );
    println!("TTFT: mean {:.0} ms, P90 {:.0} ms", rep.ttft_mean, rep.ttft_p90);
    println!("TBT (max-gap): P90 {:.1} ms", rep.tbt_p90);
    println!("SLO attainment: {:.1}%", rep.slo_attainment * 100.0);
    println!(
        "goodput: {:.2} req/s | {:.0} tok/s | {} GB KVCache moved",
        rep.goodput_rps,
        rep.goodput_tokens_per_sec,
        res.transfer_bytes / 1_000_000_000
    );
    println!(
        "cache: {} reused / {} recomputed blocks, {} fetches, {} migrations",
        res.conductor.reused_blocks,
        res.conductor.recomputed_blocks,
        res.conductor.remote_fetches,
        res.conductor.migrations
    );

    // CDFs (Fig 13 style).
    let ttfts: Vec<f64> =
        res.metrics.iter().filter(|m| !m.ttft_ms.is_nan()).map(|m| m.ttft_ms).collect();
    let grid: Vec<f64> = (1..=10).map(|i| cfg.slo.ttft_ms * i as f64 / 10.0).collect();
    println!("\nTTFT CDF:");
    for (g, c) in grid.iter().zip(cdf_at(&ttfts, &grid)) {
        println!("  <= {:>8.0} ms: {:.3}", g, c);
    }
    println!("\nsimulated {:.1}x faster than real time", s.duration_ms as f64 / speedup / 1e3 / wall);
    Ok(())
}
