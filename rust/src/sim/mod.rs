//! Discrete-event cluster simulator: replays a trace through the full
//! Mooncake architecture (Conductor → prefill pool → Messenger → decode
//! pool) at paper scale, using the analytic [`crate::model::PerfModel`]
//! as the testbed substitute.  Every §8 experiment is a [`Sim::run`] over
//! some (config, trace) point.
//!
//! The event loop **streams**: [`Sim::run_stream`] admits requests from
//! an iterator (arrivals never enter the event heap) and retires
//! per-request state as requests finish, so a 10M-request replay holds
//! only the live window in memory — `max_live_requests` bounds it
//! explicitly (arrivals defer under backpressure), `retain_metrics:
//! false` drops per-request result rows, and `interner_epoch_blocks`
//! keeps the dense block-id space flat via epoch recycling (see
//! `kvcache::intern`).  [`Sim::run`] materializes the trace and
//! delegates; with the knobs at their defaults the two paths are
//! bit-for-bit identical (pinned in `integration.rs`).
//!
//! Prefill execution is **event-driven**: Conductor admits a job onto
//! the group's FIFO queues, a `PrefillStart` event fires when its gate
//! (remote prefix fetch and/or local SSD staging, both reserved on the
//! per-node resource queues at admission) passes, the pump starts every
//! job that is at the head of all its members' queues, and `PrefillDone`
//! completes it — recording the *actual* TTFT next to Conductor's
//! estimate (both come from [`crate::costmodel`], so they agree;
//! `cost_model_agreement.rs` asserts it).  The layer-wise KVCache stream
//! to the decode node is scheduled on the primary's NIC-tx (and the
//! decode node's NIC-rx) when the job actually starts (§5.2).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::conductor::{self, ConductorStats, SchedRequest, SchedScratch};
use crate::config::SimConfig;
use crate::costmodel;
use crate::decode::DecodeInstance;
use crate::faults::{Bank, FaultEntry, FaultStats};
use crate::kvcache::{BlockInterner, DenseBlockId, ShardedPrefixIndex, TierCounters, TierDelta};
use crate::metrics::{self, Outcome, RequestMetrics};
use crate::model::PerfModel;
use crate::overload::{Admission, InFlight};
use crate::prefill::{JobId, PrefillPool};
use crate::resource::{ResourceStats, Resources};
use crate::trace::TraceRecord;
use crate::util::fasthash::FastMap;
use crate::util::rng::Rng;
use crate::{RequestId, TimeMs};

/// A simulation input request.
#[derive(Debug, Clone)]
pub struct Request {
    pub rid: RequestId,
    pub arrival: TimeMs,
    pub input: u64,
    pub output: u64,
    pub hash_ids: Vec<u64>,
}

impl Request {
    pub fn from_trace(rid: RequestId, r: &TraceRecord) -> Self {
        Request {
            rid,
            arrival: r.timestamp as TimeMs,
            input: r.input_length,
            output: r.output_length.max(1),
            hash_ids: r.hash_ids.clone(),
        }
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    /// A job's gate passed (fetch landed): try to start queued work.
    PrefillStart { jid: JobId },
    /// A running prefill job completed.
    PrefillDone { jid: JobId },
    /// An SSD→DRAM staging read finished on `node` — armed at admission
    /// for the completion time the NVMe queue reservation reported
    /// (local prefix staging, or a remote fetch's source-side staging):
    /// tier traffic as observable simulator state.
    SsdLoad { node: usize, bytes: u64 },
    KvArrive { rid: RequestId, decode: usize, ctx: u64, out: u64 },
    DecodeStep { decode: usize, seq: u64, dur: f64 },
    /// Low-priority proactive demotion sweep (`demote_after_ms`): move
    /// idle DRAM blocks down to the SSD tier ahead of eviction pressure.
    DemoteSweep,
    Sample,
    /// Scripted fault (`cfg.faults`): prefill node `node` dies — pools
    /// drop, its jobs cancel, orphans re-admit against the survivors.
    NodeLoss { node: usize },
    /// Scripted fault: the node rejoins, empty but placeable.
    NodeRecover { node: usize },
    /// Scripted fault: set `bank` on `node` to `factor` × nominal
    /// bandwidth (a `BwDegrade` window compiles to a degrade event at
    /// `from_ms` and a `factor: 1.0` restore at `to_ms`).
    BwChange { node: usize, bank: Bank, factor: f64 },
}

#[derive(Debug, Clone)]
struct Event {
    t: TimeMs,
    order: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.order == other.order
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.order.cmp(&self.order))
    }
}

/// One point of the Fig 9/10 load curves.
#[derive(Debug, Clone, Copy)]
pub struct LoadSample {
    pub t: TimeMs,
    pub prefill_load: f64,
    pub decode_load: f64,
}

#[derive(Debug)]
pub struct SimResult {
    pub metrics: Vec<RequestMetrics>,
    pub conductor: ConductorStats,
    pub load_samples: Vec<LoadSample>,
    pub wall_ms: TimeMs,
    /// Total bytes moved over the NIC banks.
    pub transfer_bytes: u64,
    pub rejected_at_arrival: u64,
    pub rejected_at_decode: u64,
    /// Per-resource queued-ms / busy-ms / byte counters (NIC tx, NIC rx,
    /// NVMe) over the run.
    pub resources: ResourceStats,
    /// Aggregated tier counters over every prefill instance's pool.
    pub tier: TierCounters,
    /// SSD staging reads observed via `SsdLoad` events, total and
    /// per prefill node.
    pub ssd_load_events: u64,
    pub ssd_loaded_bytes: u64,
    pub ssd_loaded_bytes_by_node: Vec<u64>,
    /// Tokens emitted across all decode instances (continuous-batching
    /// throughput accounting; equals the sum of completed `generated`).
    pub decode_tokens_out: u64,
    /// Discrete events processed over the run (the `sched_throughput`
    /// bench's events/sec denominator).
    pub n_events: u64,
    /// Requests completed (accumulated even when `retain_metrics:
    /// false` drops the per-request rows).
    pub n_completed: u64,
    /// Requests rejected at any point — arrival admission, infeasible
    /// scheduling, or the decode-side double-check (also accumulated
    /// independently of `retain_metrics`).
    pub n_rejected: u64,
    /// High-water mark of simultaneously live (admitted, unfinished)
    /// requests — the streaming loop's flat-memory proxy, bounded by
    /// `max_live_requests`.
    pub live_peak: usize,
    /// Interner recycle epochs completed (`interner_epoch_blocks`).
    pub interner_epochs: u64,
    /// Dense block ids freed across all recycle epochs.
    pub interner_freed: u64,
    /// Dense-id space high-water mark (`BlockInterner::id_space`) — with
    /// recycling on this stays bounded under unbounded distinct blocks.
    pub interner_id_space: usize,
    /// Fault-injection accounting (`cfg.faults`): every orphaned request
    /// is either rescued or counted in `n_rejected` — never lost
    /// silently.  All zero on healthy runs.
    pub faults: FaultStats,
}

impl SimResult {
    pub fn report(&self, cfg: &SimConfig) -> metrics::RunReport {
        metrics::RunReport {
            tiers: self.tier,
            resources: self.resources,
            hybrid_placements: self.conductor.hybrid_placements,
            faults: self.faults,
            ..metrics::report(&self.metrics, cfg.slo.ttft_ms, cfg.slo.tbt_ms, self.wall_ms)
        }
    }
}

struct Pending {
    arrival: TimeMs,
    input: u64,
    output: u64,
    decode: usize,
    /// Conductor's TTFT estimate at admission (cost-model planned end).
    est_ttft: f64,
    /// Actual TTFT, set by `PrefillDone` (NaN until then).
    ttft: f64,
    /// KV stream completion on the wire, set when the job starts.
    stream_end: TimeMs,
    /// Node-loss re-admissions so far (`cfg.fault_retry_budget` bounds
    /// it; 0 on every healthy request).
    retries: u32,
    /// The original *trace-level* block hashes, retained only in fault
    /// runs (`retain_chains`) so an orphan can be re-interned and
    /// re-priced — trace hashes stay valid across interner epochs where
    /// dense ids would not.  Empty (capacity 0) on healthy runs.
    chain: Vec<u64>,
}

pub struct Sim<'a> {
    cfg: &'a SimConfig,
    perf: PerfModel,
    prefill: PrefillPool,
    decodes: Vec<DecodeInstance>,
    /// The per-node resource banks: NIC tx/rx (via the Messenger
    /// wrapper) and the shared NVMe queue.
    resources: Resources,
    rng: Rng,
    admission: Admission,
    events: BinaryHeap<Event>,
    order: u64,
    stats: ConductorStats,
    pending: FastMap<RequestId, Pending>,
    in_flight: FastMap<RequestId, InFlight>,
    metrics: Vec<RequestMetrics>,
    samples: Vec<LoadSample>,
    sample_interval: f64,
    ssd_load_events: u64,
    ssd_loaded_bytes_by_node: Vec<u64>,
    /// The Conductor's global prefix index (§5) — `None` only when
    /// explicitly disabled (`use_prefix_index: false`).
    index: Option<ShardedPrefixIndex>,
    /// The interning boundary: trace-level block hashes become dense
    /// scheduler ids here, at request admission, and nothing downstream
    /// ever sees a hash again.
    interner: BlockInterner,
    /// Reused interned-chain buffer (swapped into each `SchedRequest`).
    chain_buf: Vec<DenseBlockId>,
    /// The Conductor's reusable decision buffers.
    scratch: SchedScratch,
    /// Reused startable-job buffer for the prefill event pump.
    ready_buf: Vec<JobId>,
    n_events: u64,
    /// Outstanding non-bookkeeping events.  `Sample` and `DemoteSweep`
    /// re-arm themselves only while real work remains — gating on this
    /// count (not heap emptiness) so the two cannot keep each other
    /// alive forever.
    real_events: usize,
    /// Sanitized `cfg.demote_after_ms`: a sweep interval must be a
    /// positive finite time or the re-armed event would never advance
    /// the clock (infinite loop at zero, time travel when negative).
    demote_after: Option<f64>,
    n_completed: u64,
    n_rejected: u64,
    live_peak: usize,
    /// Reused liveness bitset for interner recycling (one bit per dense
    /// id, marked from the pools).
    mark_buf: Vec<u64>,
    /// Live-block count at which the next recycle scan runs (hysteresis
    /// above `interner_epoch_blocks` so a mostly-live epoch does not
    /// re-scan on every arrival).
    epoch_trigger: usize,
    /// Fault-injection accounting (all zero on healthy runs).
    fault_stats: FaultStats,
    /// True iff `cfg.faults` is non-empty: gates the per-request chain
    /// retention and fetch-source tracking below, so the default path
    /// stays allocation-free (pinned by `tests/alloc_audit.rs`).
    retain_chains: bool,
    /// Remote-fetch source of each still-gated job (fault runs only):
    /// node loss dooms jobs whose pending fetch came *from* the dead
    /// node — the transfer will never land.
    fetch_src: FastMap<JobId, usize>,
    /// Reused doomed-job buffer for the node-loss handler.
    doomed_buf: Vec<JobId>,
    /// Reused (job, request) orphan buffer for the node-loss handler.
    orphan_buf: Vec<(JobId, RequestId)>,
    /// Reused residency delta for `CachePool::drop_all_into`.
    fault_delta: TierDelta,
}

impl<'a> Sim<'a> {
    pub fn new(cfg: &'a SimConfig) -> Self {
        let perf = PerfModel::paper();
        let decodes: Vec<DecodeInstance> = (0..cfg.n_decode)
            .map(|_| DecodeInstance::new(perf.vram_kv_capacity_tokens(), cfg.max_decode_batch))
            .collect();
        let resources = Resources::new(cfg, &perf);
        Sim {
            cfg,
            prefill: PrefillPool::new(cfg),
            decodes,
            resources,
            rng: Rng::new(cfg.seed),
            admission: Admission::new(cfg.rejection, cfg.overload_threshold),
            events: BinaryHeap::new(),
            order: 0,
            stats: ConductorStats::default(),
            pending: FastMap::default(),
            in_flight: FastMap::default(),
            metrics: Vec::new(),
            samples: Vec::new(),
            sample_interval: 10_000.0,
            ssd_load_events: 0,
            ssd_loaded_bytes_by_node: vec![0; cfg.n_prefill],
            // The sharded index tiles any cluster width into 256-node
            // groups, so there is no automatic scan fallback — only the
            // explicit `use_prefix_index: false` knob restores the scan.
            index: cfg.use_prefix_index.then(|| ShardedPrefixIndex::new(cfg.n_prefill)),
            interner: BlockInterner::new(),
            chain_buf: Vec::new(),
            scratch: SchedScratch::default(),
            ready_buf: Vec::new(),
            n_events: 0,
            real_events: 0,
            demote_after: cfg.demote_after_ms.filter(|&x| x > 0.0 && x.is_finite()),
            n_completed: 0,
            n_rejected: 0,
            live_peak: 0,
            mark_buf: Vec::new(),
            epoch_trigger: 0,
            fault_stats: FaultStats::default(),
            retain_chains: !cfg.faults.is_empty(),
            fetch_src: FastMap::default(),
            doomed_buf: Vec::new(),
            orphan_buf: Vec::new(),
            fault_delta: TierDelta::default(),
            perf,
        }
    }

    /// Is this event *work* (counted in `real_events`) or bookkeeping?
    /// Samples and sweeps re-arm themselves and must not keep each other
    /// alive; scripted fault events fire exactly once at plan-fixed
    /// times, so counting them would only stretch the bookkeeping tail.
    fn is_bookkeeping(kind: &EventKind) -> bool {
        matches!(
            kind,
            EventKind::Sample
                | EventKind::DemoteSweep
                | EventKind::NodeLoss { .. }
                | EventKind::NodeRecover { .. }
                | EventKind::BwChange { .. }
        )
    }

    fn push(&mut self, t: TimeMs, kind: EventKind) {
        if !Self::is_bookkeeping(&kind) {
            self.real_events += 1;
        }
        self.order += 1;
        self.events.push(Event { t, order: self.order, kind });
    }

    fn sample_loads(&mut self, now: TimeMs) {
        let p = self
            .prefill
            .instances
            .iter()
            .map(|i| (i.queue_ms(now) / self.cfg.slo.ttft_ms).min(1.0))
            .sum::<f64>()
            / self.prefill.len().max(1) as f64;
        let d = self
            .decodes
            .iter()
            .map(|d| d.load(&self.perf, self.cfg.slo.tbt_ms).min(1.0))
            .sum::<f64>()
            / self.decodes.len().max(1) as f64;
        self.samples.push(LoadSample { t: now, prefill_load: p, decode_load: d });
    }

    fn start_decode_step(&mut self, d: usize, now: TimeMs) {
        let inst = &mut self.decodes[d];
        inst.admit_waiting();
        if inst.active.is_empty() {
            inst.stepping = false;
            return;
        }
        inst.stepping = true;
        inst.step_seq += 1;
        let dur = inst.step_duration_ms(&self.perf);
        let seq = inst.step_seq;
        self.push(now + dur, EventKind::DecodeStep { decode: d, seq, dur });
    }

    /// Paranoia invariant: the incrementally maintained prefix index
    /// must equal a brute-force rebuild of the pools.  Gated on
    /// `SimConfig::paranoia` — a hard assert when active, a no-op
    /// otherwise (the default level reproduces the old `debug_assert!`
    /// behavior; `Full` checks in release builds too).
    fn validate_index(&self) {
        if !self.cfg.paranoia.active() {
            return;
        }
        if let Some(idx) = &self.index {
            assert!(
                idx.equals_rebuild_of(self.prefill.instances.iter().map(|i| &i.pool)),
                "global prefix index diverged from the pools"
            );
        }
    }

    /// Start every startable prefill job: occupy its group, schedule the
    /// layer-wise KV stream on the primary's NIC-tx + the decode node's
    /// NIC-rx, and arm `PrefillDone`.  (SSD staging already happened —
    /// it was reserved on the NVMe queue at admission and gated the
    /// start.)
    fn pump_prefill(&mut self, now: TimeMs) {
        // The startable list rides a reused buffer (swapped in and out
        // around the loop), keeping the warmed event pump allocation-free.
        let mut ready = std::mem::take(&mut self.ready_buf);
        loop {
            self.prefill.startable_into(now, &mut ready);
            if ready.is_empty() {
                break;
            }
            for &jid in &ready {
                let (primary, exec_ms, rid) = self.prefill.start(jid, now);
                let (input, decode) =
                    self.pending.get(&rid).map(|p| (p.input, p.decode)).unwrap_or((0, 0));
                let stream = self.resources.nic.schedule(
                    primary,
                    self.cfg.n_prefill + decode,
                    now,
                    costmodel::kv_stream_bytes(&self.perf, input),
                );
                if let Some(p) = self.pending.get_mut(&rid) {
                    p.stream_end = stream.end;
                }
                self.push(now + exec_ms, EventKind::PrefillDone { jid });
            }
        }
        self.ready_buf = ready;
    }

    /// Admit one request at time `now` (its arrival time, except when a
    /// `max_live_requests` cap deferred it past that).
    fn handle_arrival(&mut self, req: &Request, now: TimeMs) {
        // §7 admission control.
        if !self.admission.admit_at_arrival(
            self.cfg,
            &self.perf,
            &self.prefill,
            &self.decodes,
            &self.in_flight,
            req.input,
            now,
        ) {
            self.n_rejected += 1;
            if self.cfg.retain_metrics {
                self.metrics.push(RequestMetrics::rejected(
                    req.rid, now, req.input, req.output, false,
                ));
            }
            return;
        }
        // Algorithm 1, on *interned* ids: this is the one boundary where
        // trace-level block hashes become dense scheduler ids.  The
        // chain buffer is reused across arrivals (swapped in and out of
        // the SchedRequest), so admission allocates nothing for it.
        let mut hash_ids = std::mem::take(&mut self.chain_buf);
        self.interner.intern_chain_into(&req.hash_ids, &mut hash_ids);
        let sched = SchedRequest {
            rid: req.rid,
            input_tokens: req.input,
            output_tokens: req.output,
            hash_ids,
        };
        let mut ctx = conductor::Ctx {
            cfg: self.cfg,
            perf: &self.perf,
            prefill: &mut self.prefill,
            decodes: &self.decodes,
            res: &mut self.resources,
            rng: &mut self.rng,
            now,
            index: self.index.as_mut(),
            scratch: &mut self.scratch,
        };
        let outcome = conductor::schedule(&mut ctx, &sched, &mut self.stats);
        self.chain_buf = sched.hash_ids;
        match outcome {
            Err(_) => {
                self.n_rejected += 1;
                if self.cfg.retain_metrics {
                    self.metrics.push(RequestMetrics::rejected(
                        req.rid, now, req.input, req.output, false,
                    ));
                }
            }
            Ok(p) => {
                // SSD staging reads are observable tier traffic.  Both
                // kinds were reserved on the NVMe queues inside
                // `conductor::schedule` — the events land exactly when
                // the queue said the reads finish: the fetch's
                // source-side staging (§6.2 + tiering) just before the
                // source NIC starts, the local staging when the job's
                // gate passes.
                if let Some(t) = p.fetch_stage_done {
                    let (src, _) = p.fetch.expect("staging implies a fetch");
                    let tokens = p.fetch_ssd_stage_blocks as u64 * crate::trace::BLOCK_TOKENS;
                    self.push(
                        t,
                        EventKind::SsdLoad {
                            node: src,
                            bytes: costmodel::stage_bytes(&self.perf, tokens),
                        },
                    );
                }
                if let Some(t) = p.ssd_stage_done {
                    self.push(
                        t,
                        EventKind::SsdLoad {
                            node: p.prefill_group[0],
                            bytes: costmodel::stage_bytes(&self.perf, p.ssd_stage_tokens),
                        },
                    );
                }
                self.pending.insert(
                    req.rid,
                    Pending {
                        arrival: now,
                        input: req.input,
                        output: req.output,
                        decode: p.decode,
                        est_ttft: p.prefill_end - now,
                        ttft: f64::NAN,
                        stream_end: f64::NAN,
                        retries: 0,
                        chain: if self.retain_chains {
                            req.hash_ids.clone()
                        } else {
                            Vec::new() // capacity 0: no heap traffic on healthy runs
                        },
                    },
                );
                self.live_peak = self.live_peak.max(self.pending.len());
                self.in_flight.insert(
                    req.rid,
                    InFlight { kv_arrive: p.kv_arrive, decode: p.decode, ctx_tokens: req.input },
                );
                // Wake the queue at the job's planned start.  On a
                // healthy run this is bit-neutral versus waking at the
                // gate: planned_start = max(queue_free, gate, now), and
                // whenever queue_free dominates, the predecessor's
                // PrefillDone fires at exactly that instant and pumps
                // first (the equal-time wake pops later and no-ops).
                // After a node-loss cancellation, though, the
                // predecessor's PrefillDone never comes — this wake is
                // what keeps survivors' restated planned starts live
                // without any extra recovery events.
                let planned = self.prefill.job(p.job).planned_start;
                self.push(planned.max(now), EventKind::PrefillStart { jid: p.job });
                if self.retain_chains {
                    if let Some((src, _)) = p.fetch {
                        self.fetch_src.insert(p.job, src);
                    }
                }
                // Placement consumed: hand its group buffer back so the
                // next accept reuses it instead of allocating.
                self.scratch.recycle_placement_group(p.prefill_group);
            }
        }
    }

    fn handle_prefill_done(&mut self, jid: JobId, now: TimeMs) {
        if self.retain_chains {
            self.fetch_src.remove(&jid);
        }
        let job = self.prefill.finish(jid, now);
        let rid = job.rid;
        let (kv_arrive, decode, ctx_tokens, out) = {
            let p = self.pending.get_mut(&rid).expect("prefill done for unknown request");
            p.ttft = now - p.arrival;
            let kv_arrive = if p.stream_end.is_nan() { now } else { p.stream_end.max(now) };
            (kv_arrive, p.decode, p.input, p.output)
        };
        // Refresh the in-flight record with the observed landing time
        // (predictive admission reads it).
        if let Some(f) = self.in_flight.get_mut(&rid) {
            f.kv_arrive = kv_arrive;
        }
        self.push(kv_arrive, EventKind::KvArrive { rid, decode, ctx: ctx_tokens, out });
        // The freed group members can take their next queued jobs.
        self.pump_prefill(now);
    }

    fn handle_kv_arrive(&mut self, rid: RequestId, d: usize, ctx: u64, out: u64, now: TimeMs) {
        self.in_flight.remove(&rid);
        // §3 step 4 double-check by the local scheduler.
        let ok = self.admission.admit_at_decode(self.cfg, &self.perf, &self.decodes[d], now);
        if !ok {
            let p = self.pending.remove(&rid).unwrap();
            self.n_rejected += 1;
            if self.cfg.retain_metrics {
                self.metrics.push(RequestMetrics::rejected(
                    rid, p.arrival, p.input, p.output, true,
                ));
            }
            return;
        }
        self.decodes[d].enqueue(rid, ctx, out, now);
        if !self.decodes[d].stepping {
            self.start_decode_step(d, now);
        }
    }

    fn handle_decode_step(&mut self, d: usize, seq: u64, dur: f64, now: TimeMs) {
        if self.decodes[d].step_seq != seq {
            return; // stale event
        }
        let done = self.decodes[d].finish_step(now, dur);
        for f in done {
            let p = self.pending.remove(&f.rid).expect("finish for unknown request");
            self.admission.observe_decode_duration(now - (p.arrival + p.ttft));
            self.n_completed += 1;
            if p.retries > 0 {
                // Orphaned by a node loss, re-admitted, and completed.
                self.fault_stats.rescued += 1;
            }
            if self.cfg.retain_metrics {
                self.metrics.push(RequestMetrics {
                    id: f.rid,
                    arrival: p.arrival,
                    input_tokens: p.input,
                    output_tokens: p.output,
                    outcome: Outcome::Completed,
                    ttft_ms: p.ttft,
                    est_ttft_ms: p.est_ttft,
                    max_tbt_ms: f.max_gap,
                    mean_tbt_ms: f.mean_gap,
                    generated: f.generated,
                    finish: now,
                });
            }
        }
        self.start_decode_step(d, now);
    }

    /// `FaultEntry::NodeLoss` — the node's pools vanish, its in-flight
    /// prefill work dies, and every orphaned request goes back through
    /// the conductor for bounded re-admission.  Cache state is removed
    /// through an ordinary `TierDelta` applied to the prefix index, so
    /// `equals_rebuild_of` keeps holding without a rebuild.  The doomed
    /// set is: every queued/running job whose group touches the node,
    /// plus every still-gated job whose remote fetch *sources* from it
    /// (the layer-wise transfer can no longer complete).  A running
    /// job's already-reserved NIC window is deliberately not unwound —
    /// the wire time was spent; surviving reservations stay honored.
    // lint: hot
    fn handle_node_loss(&mut self, node: usize, now: TimeMs) {
        self.fault_stats.nodes_lost += 1;
        self.prefill.instances[node].alive = false;
        let mut delta = std::mem::take(&mut self.fault_delta);
        self.prefill.instances[node].pool.drop_all_into(&mut delta);
        if let Some(idx) = self.index.as_mut() {
            idx.apply(node, &delta);
        }
        self.fault_delta = delta;
        let mut doomed = std::mem::take(&mut self.doomed_buf);
        doomed.clear();
        self.prefill.collect_jobs_touching(node, &mut doomed);
        // lint: allow(unordered-iter) — doomed is sorted + deduped below
        for (&jid, &src) in self.fetch_src.iter() {
            if src == node && self.prefill.contains_job(jid) && self.prefill.job(jid).gate > now
            {
                doomed.push(jid);
            }
        }
        doomed.sort_unstable();
        doomed.dedup();
        self.fault_stats.jobs_killed += doomed.len() as u64;
        let mut orphans = std::mem::take(&mut self.orphan_buf);
        orphans.clear();
        self.prefill.cancel_jobs(&doomed, &mut orphans);
        // Re-admit in job-id (= admission) order: deterministic, and
        // earliest-admitted requests get first claim on survivors.
        orphans.sort_unstable_by_key(|&(jid, _)| jid);
        for i in 0..orphans.len() {
            let (jid, rid) = orphans[i];
            self.readmit_orphan(jid, rid, now);
        }
        self.orphan_buf = orphans;
        self.doomed_buf = doomed;
        self.pump_prefill(now);
    }

    /// `FaultEntry::NodeRecover` — the node takes new placements again.
    /// Its pools stay empty (the crash lost them); the prefix index
    /// already reflects that, so nothing to reconcile.
    fn handle_node_recover(&mut self, node: usize) {
        self.fault_stats.nodes_recovered += 1;
        self.prefill.instances[node].alive = true;
    }

    /// One orphaned request back through the conductor at fault time.
    /// Within budget it is re-priced against the *surviving* topology
    /// (so the cost-model contract holds for the new placement); past
    /// budget it becomes an ordinary rejection — never silent loss.
    /// TTFT keeps being measured from the original arrival.
    // lint: hot
    fn readmit_orphan(&mut self, jid: JobId, rid: RequestId, now: TimeMs) {
        self.fetch_src.remove(&jid);
        self.in_flight.remove(&rid);
        let Some(p) = self.pending.remove(&rid) else {
            return;
        };
        if p.retries >= self.cfg.fault_retry_budget {
            self.n_rejected += 1;
            self.fault_stats.lost += 1;
            if self.cfg.retain_metrics {
                self.metrics.push(RequestMetrics::rejected(
                    rid, p.arrival, p.input, p.output, false,
                ));
            }
            return;
        }
        // Re-intern the retained trace-level chain: the original dense
        // ids may have been recycled by an interner epoch since
        // admission, so the chain is re-resolved like a fresh arrival.
        let mut hash_ids = std::mem::take(&mut self.chain_buf);
        self.interner.intern_chain_into(&p.chain, &mut hash_ids);
        let sched = SchedRequest {
            rid,
            input_tokens: p.input,
            output_tokens: p.output,
            hash_ids,
        };
        let mut ctx = conductor::Ctx {
            cfg: self.cfg,
            perf: &self.perf,
            prefill: &mut self.prefill,
            decodes: &self.decodes,
            res: &mut self.resources,
            rng: &mut self.rng,
            now,
            index: self.index.as_mut(),
            scratch: &mut self.scratch,
        };
        let outcome = conductor::schedule(&mut ctx, &sched, &mut self.stats);
        self.chain_buf = sched.hash_ids;
        match outcome {
            Err(_) => {
                // No survivor can take it (or SLO says don't) — an
                // ordinary rejection, counted like any other.
                self.n_rejected += 1;
                self.fault_stats.lost += 1;
                if self.cfg.retain_metrics {
                    self.metrics.push(RequestMetrics::rejected(
                        rid, p.arrival, p.input, p.output, false,
                    ));
                }
            }
            Ok(pl) => {
                if let Some(t) = pl.fetch_stage_done {
                    let (src, _) = pl.fetch.expect("staging implies a fetch");
                    let tokens = pl.fetch_ssd_stage_blocks as u64 * crate::trace::BLOCK_TOKENS;
                    self.push(
                        t,
                        EventKind::SsdLoad {
                            node: src,
                            bytes: costmodel::stage_bytes(&self.perf, tokens),
                        },
                    );
                }
                if let Some(t) = pl.ssd_stage_done {
                    self.push(
                        t,
                        EventKind::SsdLoad {
                            node: pl.prefill_group[0],
                            bytes: costmodel::stage_bytes(&self.perf, pl.ssd_stage_tokens),
                        },
                    );
                }
                self.pending.insert(
                    rid,
                    Pending {
                        arrival: p.arrival,
                        input: p.input,
                        output: p.output,
                        decode: pl.decode,
                        est_ttft: pl.prefill_end - p.arrival,
                        ttft: f64::NAN,
                        stream_end: f64::NAN,
                        retries: p.retries + 1,
                        chain: p.chain,
                    },
                );
                self.live_peak = self.live_peak.max(self.pending.len());
                self.in_flight.insert(
                    rid,
                    InFlight { kv_arrive: pl.kv_arrive, decode: pl.decode, ctx_tokens: p.input },
                );
                let planned = self.prefill.job(pl.job).planned_start;
                self.push(planned.max(now), EventKind::PrefillStart { jid: pl.job });
                if let Some((src, _)) = pl.fetch {
                    self.fetch_src.insert(pl.job, src);
                }
                self.scratch.recycle_placement_group(pl.prefill_group);
                self.fault_stats.retried += 1;
            }
        }
    }

    /// Epoch-based interner recycling (`interner_epoch_blocks`): once
    /// live interned blocks exceed the knob, mark every dense id still
    /// resident in some pool tier and recycle the rest (see
    /// [`BlockInterner::recycle_epoch`]), keeping the dense-id space —
    /// and the prefix index's flat residency table — bounded under
    /// unbounded distinct trace blocks.  Runs at the arrival boundary,
    /// *before* the new request's chain is interned: between events
    /// nothing outside the pools (and the index, which mirrors them)
    /// retains dense ids, so pool residency *is* liveness.
    fn maybe_recycle(&mut self) {
        let Some(cap) = self.cfg.interner_epoch_blocks else {
            return;
        };
        let cap = cap.max(1);
        if self.interner.len() < self.epoch_trigger.max(cap) {
            return;
        }
        self.mark_buf.clear();
        self.mark_buf.resize(self.interner.id_space().div_ceil(64), 0);
        for inst in &self.prefill.instances {
            for b in inst.pool.iter_blocks() {
                self.mark_buf[b as usize / 64] |= 1u64 << (b as usize % 64);
            }
        }
        // Paranoia: a recycled (unmarked, allocated) id must have no
        // holders left in the prefix index either.
        if self.cfg.paranoia.active() {
            if let Some(idx) = &self.index {
                for id in 0..self.interner.id_space() as DenseBlockId {
                    let marked = (self.mark_buf[id as usize / 64] >> (id as usize % 64)) & 1 != 0;
                    if !marked && self.interner.is_allocated(id) {
                        assert!(
                            idx.holders(id).is_empty(),
                            "recycling dense id {id} still held in the prefix index"
                        );
                    }
                }
            }
        }
        self.interner.recycle_epoch(&self.mark_buf);
        // Hysteresis: wait for a quarter-cap of fresh blocks before
        // scanning again (a mostly-live epoch frees little — re-running
        // on every arrival would be quadratic).
        self.epoch_trigger = self.interner.len() + (cap / 4).max(1);
    }

    /// Replay `trace` to completion; `speedup` rescales arrival times
    /// (2.0 = the paper's 2× overload replay).  Materializes the trace
    /// as a time-sorted request list and delegates to the streaming
    /// loop — the two paths are bit-for-bit identical (pinned in
    /// `integration.rs`).
    pub fn run(self, trace: &[TraceRecord], speedup: f64) -> SimResult {
        let mut requests: Vec<Request> = trace
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut req = Request::from_trace(i as RequestId, r);
                req.arrival /= speedup;
                req
            })
            .collect();
        // The streaming loop takes arrivals in time order; the stable
        // sort keeps trace order among ties — exactly the old arrival
        // heap's tie-break (push order == trace index).
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        self.run_stream(requests)
    }

    /// Replay a streaming arrival source to completion.  Requests must
    /// come in non-decreasing `arrival` order (`trace::replay` readers
    /// enforce monotone timestamps at parse time); arrivals never enter
    /// the event heap, so only the live window plus in-flight state is
    /// ever held and memory stays flat over arbitrarily long traces.
    pub fn run_stream<I>(mut self, arrivals: I) -> SimResult
    where
        I: IntoIterator<Item = Request>,
    {
        let mut arrivals = arrivals.into_iter();
        let mut next_arr = arrivals.next();
        // Compile the fault plan into ordinary heap events up front: the
        // script is part of the run's inputs, so two runs with the same
        // (config, plan) pop the same events in the same order and stay
        // bit-for-bit identical.  An empty plan pushes nothing — the
        // healthy path is untouched.  A `BwDegrade` window compiles to a
        // degrade edge at `from_ms` and a restore edge (factor 1.0) at
        // `to_ms`; each plan entry counts once in `injected`.
        let cfg = self.cfg;
        if !cfg.faults.is_empty() {
            if let Err(e) = cfg.faults.validate(cfg.n_prefill, cfg.n_prefill + cfg.n_decode) {
                panic!("invalid fault plan: {e}");
            }
            for e in &cfg.faults.entries {
                self.fault_stats.injected += 1;
                match *e {
                    FaultEntry::NodeLoss { node, at_ms } => {
                        self.push(at_ms, EventKind::NodeLoss { node });
                    }
                    FaultEntry::NodeRecover { node, at_ms } => {
                        self.push(at_ms, EventKind::NodeRecover { node });
                    }
                    FaultEntry::BwDegrade { node, bank, factor, from_ms, to_ms } => {
                        self.push(from_ms, EventKind::BwChange { node, bank, factor });
                        self.push(to_ms, EventKind::BwChange { node, bank, factor: 1.0 });
                    }
                }
            }
        }
        self.push(0.0, EventKind::Sample);
        if let Some(idle) = self.demote_after {
            self.push(idle, EventKind::DemoteSweep);
        }
        let cap = self.cfg.max_live_requests.unwrap_or(usize::MAX).max(1);
        let mut last_arrival = f64::NEG_INFINITY;
        let mut now = 0.0f64;
        loop {
            // Take the next arrival when it is due no later than the
            // earliest queued event — ties go to the arrival, matching
            // the materialized path where arrival events carried the
            // lowest orders — unless live state is at the cap
            // (backpressure defers admission until something retires;
            // every live request keeps an event chain in flight, so the
            // heap cannot drain while the cap is binding).
            let take_arrival = match (&next_arr, self.events.peek()) {
                (Some(_), _) if self.pending.len() >= cap => false,
                (Some(r), Some(ev)) => r.arrival <= ev.t,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_arrival {
                let req = next_arr.take().expect("checked by take_arrival");
                next_arr = arrivals.next();
                assert!(
                    req.arrival >= last_arrival,
                    "streaming arrivals must be time-ordered: {} after {last_arrival}",
                    req.arrival
                );
                last_arrival = req.arrival;
                // A deferred (cap-blocked) arrival is admitted late: the
                // clock never runs backwards.
                now = now.max(req.arrival);
                self.n_events += 1;
                if self.n_events % 1024 == 0 {
                    self.validate_index();
                }
                self.maybe_recycle();
                self.handle_arrival(&req, now);
                continue;
            }
            let Some(ev) = self.events.pop() else { break };
            let arrivals_left = next_arr.is_some();
            now = ev.t;
            self.n_events += 1;
            if !Self::is_bookkeeping(&ev.kind) {
                self.real_events -= 1;
            }
            if self.n_events % 1024 == 0 {
                self.validate_index();
            }
            match ev.kind {
                EventKind::PrefillStart { jid: _ } => {
                    self.pump_prefill(now);
                }
                EventKind::PrefillDone { jid } => {
                    // A node loss may have cancelled the job after this
                    // event was armed; the stale completion is skipped.
                    if self.prefill.contains_job(jid) {
                        self.handle_prefill_done(jid, now);
                    }
                }
                EventKind::SsdLoad { node, bytes } => {
                    // Reads on a node that died after the reservation are
                    // not observable traffic.
                    if self.prefill.instances[node].alive {
                        self.ssd_load_events += 1;
                        self.ssd_loaded_bytes_by_node[node] += bytes;
                    }
                }
                EventKind::KvArrive { rid, decode, ctx, out } => {
                    self.handle_kv_arrive(rid, decode, ctx, out, now);
                }
                EventKind::DecodeStep { decode, seq, dur } => {
                    self.handle_decode_step(decode, seq, dur, now);
                }
                EventKind::NodeLoss { node } => {
                    self.handle_node_loss(node, now);
                }
                EventKind::NodeRecover { node } => {
                    self.handle_node_recover(node);
                }
                EventKind::BwChange { node, bank, factor } => {
                    self.fault_stats.bw_changes += 1;
                    match bank {
                        Bank::NicTx => self.resources.nic.tx.set_scale(node, factor),
                        Bank::NicRx => self.resources.nic.rx.set_scale(node, factor),
                        Bank::Nvme => self.resources.nvme.set_scale(node, factor),
                    }
                }
                EventKind::DemoteSweep => {
                    let idle = self.demote_after.expect("sweep without a config");
                    for node in 0..self.prefill.len() {
                        let delta = self.prefill.instances[node].pool.demote_idle(now, idle);
                        if let Some(idx) = self.index.as_mut() {
                            idx.apply(node, &delta);
                        }
                        // The sweep's demotion writes occupy the node's
                        // NVMe device alongside staging reads.
                        let _ = self.resources.schedule_demote_writes(
                            &self.perf,
                            node,
                            now,
                            delta.demoted_to_ssd(),
                        );
                    }
                    // Low priority: keep sweeping only while real work
                    // (or an undrained arrival stream) remains.
                    if self.real_events > 0 || arrivals_left {
                        self.push(now + idle, EventKind::DemoteSweep);
                    }
                }
                EventKind::Sample => {
                    self.sample_loads(now);
                    // Keep sampling while real work (or an undrained
                    // arrival stream) remains.
                    if self.real_events > 0 || arrivals_left {
                        self.push(now + self.sample_interval, EventKind::Sample);
                    }
                }
            }
        }
        assert!(next_arr.is_none(), "arrival stream not drained");
        assert!(self.pending.is_empty(), "requests stuck in flight");
        assert_eq!(self.prefill.outstanding(), 0, "prefill jobs stuck in queue");
        self.validate_index();
        self.metrics.sort_by(|a, b| a.id.cmp(&b.id));
        let mut tier = TierCounters::default();
        for inst in &self.prefill.instances {
            tier.merge(&inst.pool.stats);
        }
        SimResult {
            metrics: self.metrics,
            conductor: self.stats,
            load_samples: self.samples,
            wall_ms: now,
            transfer_bytes: self.resources.nic.total_bytes(),
            rejected_at_arrival: self.admission.rejected_at_arrival,
            rejected_at_decode: self.admission.rejected_at_decode,
            resources: self.resources.stats(),
            tier,
            ssd_load_events: self.ssd_load_events,
            ssd_loaded_bytes: self.ssd_loaded_bytes_by_node.iter().sum(),
            ssd_loaded_bytes_by_node: self.ssd_loaded_bytes_by_node,
            decode_tokens_out: self.decodes.iter().map(|d| d.tokens_out).sum(),
            n_events: self.n_events,
            n_completed: self.n_completed,
            n_rejected: self.n_rejected,
            live_peak: self.live_peak,
            interner_epochs: self.interner.epochs(),
            interner_freed: self.interner.freed_total(),
            interner_id_space: self.interner.id_space(),
            faults: self.fault_stats,
        }
    }
}

/// Convenience: run a config over a trace.
pub fn run(cfg: &SimConfig, trace: &[TraceRecord], speedup: f64) -> SimResult {
    Sim::new(cfg).run(trace, speedup)
}

/// Convenience: run a config over a streaming arrival source (requests
/// in non-decreasing `arrival` order, e.g. from `trace::replay`).
pub fn run_streaming(cfg: &SimConfig, arrivals: impl IntoIterator<Item = Request>) -> SimResult {
    Sim::new(cfg).run_stream(arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RejectionPolicy, SchedulingPolicy};
    use crate::metrics::Outcome;
    use crate::trace::gen::{self, TraceGenConfig};

    fn small_trace(n: usize) -> Vec<TraceRecord> {
        gen::generate(&TraceGenConfig {
            n_requests: n,
            duration_ms: 600_000,
            ..Default::default()
        })
    }

    #[test]
    fn completes_all_requests_when_unloaded() {
        let cfg = SimConfig::default();
        let trace = small_trace(100);
        let res = run(&cfg, &trace, 1.0);
        assert_eq!(res.metrics.len(), 100);
        let completed =
            res.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count();
        assert_eq!(completed, 100, "unloaded cluster must finish everything");
        for m in &res.metrics {
            assert!(m.ttft_ms > 0.0 && m.ttft_ms.is_finite());
            assert!(m.est_ttft_ms > 0.0 && m.est_ttft_ms.is_finite());
            assert_eq!(m.generated, m.output_tokens);
            assert!(m.max_tbt_ms > 0.0);
        }
    }

    #[test]
    fn ttft_includes_queueing_under_load() {
        let trace = small_trace(400);
        let cfg1 = SimConfig { n_prefill: 1, n_decode: 1, ..Default::default() };
        let cfg8 = SimConfig::default();
        let r1 = run(&cfg1, &trace, 4.0);
        let r8 = run(&cfg8, &trace, 4.0);
        let rep1 = r1.report(&cfg1);
        let rep8 = r8.report(&cfg8);
        assert!(
            rep1.ttft_p90 > rep8.ttft_p90,
            "1 instance should queue more: {} vs {}",
            rep1.ttft_p90,
            rep8.ttft_p90
        );
    }

    #[test]
    fn cache_aware_lowers_ttft_vs_random() {
        let trace = small_trace(600);
        let mk = |pol| SimConfig { scheduling: pol, n_prefill: 4, n_decode: 4, ..Default::default() };
        let random = run(&mk(SchedulingPolicy::Random), &trace, 1.0);
        let central = run(&mk(SchedulingPolicy::KvCacheCentric), &trace, 1.0);
        let tr = random.report(&mk(SchedulingPolicy::Random));
        let tc = central.report(&mk(SchedulingPolicy::KvCacheCentric));
        assert!(
            tc.ttft_mean < tr.ttft_mean,
            "cache-aware mean TTFT {} !< random {}",
            tc.ttft_mean,
            tr.ttft_mean
        );
        // And reuses far more blocks.
        assert!(central.conductor.reused_blocks > random.conductor.reused_blocks);
    }

    #[test]
    fn overload_rejections_happen_and_complete_cleanly() {
        let trace = small_trace(500);
        let cfg = SimConfig {
            n_prefill: 2,
            n_decode: 2,
            rejection: RejectionPolicy::Early,
            ..Default::default()
        };
        let res = run(&cfg, &trace, 8.0);
        let rejected = res
            .metrics
            .iter()
            .filter(|m| m.outcome != Outcome::Completed)
            .count();
        assert!(rejected > 0, "8x overload on a tiny cluster must reject");
        assert_eq!(res.metrics.len(), 500);
    }

    #[test]
    fn load_samples_recorded() {
        let cfg = SimConfig::default();
        let trace = small_trace(200);
        let res = run(&cfg, &trace, 1.0);
        assert!(res.load_samples.len() > 5);
        assert!(res
            .load_samples
            .iter()
            .all(|s| (0.0..=1.0).contains(&s.prefill_load) && (0.0..=1.0).contains(&s.decode_load)));
    }

    #[test]
    fn proactive_demotion_sweeps_idle_blocks() {
        // Uncontended capacity: without the sweep nothing ever demotes;
        // with `demote_after_ms` set, idle DRAM blocks move down to SSD
        // proactively — and the cluster still completes everything.
        let trace = small_trace(120);
        let base = SimConfig::default();
        let swept = SimConfig { demote_after_ms: Some(60_000.0), ..Default::default() };
        let r0 = run(&base, &trace, 1.0);
        let r1 = run(&swept, &trace, 1.0);
        assert_eq!(r0.tier.demotions, 0, "no pressure and no sweep -> no demotions");
        assert!(r1.tier.demotions > 0, "the sweep must demote idle blocks");
        let done = r1.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count();
        assert_eq!(done, trace.len(), "proactive demotion must not lose requests");
        // Default-off: the knob changes nothing unless opted into.
        let r2 = run(&base, &trace, 1.0);
        assert_eq!(r0.tier, r2.tier);
        // Degenerate intervals are sanitized to "off" — a zero/negative
        // period would otherwise re-arm the sweep at `now` forever.
        for bad in [0.0, -5.0, f64::NAN] {
            let cfg = SimConfig { demote_after_ms: Some(bad), ..Default::default() };
            let r = run(&cfg, &trace[..20], 1.0);
            assert_eq!(r.tier.demotions, 0, "demote_after_ms={bad} must disable the sweep");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SimConfig::default();
        let trace = small_trace(150);
        let a = run(&cfg, &trace, 1.0);
        let b = run(&cfg, &trace, 1.0);
        let ta: Vec<f64> = a.metrics.iter().map(|m| m.ttft_ms).collect();
        let tb: Vec<f64> = b.metrics.iter().map(|m| m.ttft_ms).collect();
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            assert!((x.is_nan() && y.is_nan()) || x == y);
        }
    }

    #[test]
    fn live_cap_bounds_in_flight_state() {
        // A compressed replay on a tiny cluster piles up live requests;
        // `max_live_requests` must hold the high-water mark at the cap
        // by deferring arrivals, without losing any request.
        let trace = small_trace(300);
        let base = SimConfig { n_prefill: 2, n_decode: 2, ..Default::default() };
        let uncapped = run(&base, &trace, 20.0);
        assert!(uncapped.live_peak > 8, "test premise: uncapped peak {} > 8", uncapped.live_peak);
        let capped_cfg = SimConfig { max_live_requests: Some(8), ..base };
        let capped = run(&capped_cfg, &trace, 20.0);
        assert!(capped.live_peak <= 8, "cap violated: {}", capped.live_peak);
        assert_eq!(capped.metrics.len(), 300, "every request must still be accounted for");
        assert_eq!(capped.n_completed + capped.n_rejected, 300);
        // The totals agree with the per-request rows.
        let done = capped.metrics.iter().filter(|m| m.outcome == Outcome::Completed).count();
        assert_eq!(done as u64, capped.n_completed);
    }

    #[test]
    fn retain_metrics_off_keeps_aggregates() {
        let trace = small_trace(200);
        let with = SimConfig::default();
        let without = SimConfig { retain_metrics: false, ..Default::default() };
        let a = run(&with, &trace, 1.0);
        let b = run(&without, &trace, 1.0);
        assert!(b.metrics.is_empty(), "retain_metrics: false must drop per-request rows");
        assert_eq!(a.metrics.len(), 200);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(a.n_rejected, b.n_rejected);
        assert_eq!(a.n_events, b.n_events);
        assert_eq!(a.decode_tokens_out, b.decode_tokens_out);
        assert_eq!(a.tier, b.tier);
        assert_eq!(a.wall_ms.to_bits(), b.wall_ms.to_bits());
    }

    #[test]
    fn epoch_recycling_bounds_the_dense_id_space() {
        // Every request brings fresh distinct blocks (the sustained-
        // replay regime): append-only interning would grow the id space
        // to ~1600; epoch recycling must keep it near pool capacity.
        let trace: Vec<TraceRecord> = (0..400u64)
            .map(|i| TraceRecord {
                timestamp: i * 500,
                input_length: 4 * crate::trace::BLOCK_TOKENS,
                output_length: 4,
                hash_ids: (0..4).map(|b| 1_000_000 + i * 4 + b).collect(),
            })
            .collect();
        let cfg = SimConfig {
            n_prefill: 2,
            n_decode: 2,
            cache_capacity_blocks: Some(16),
            ssd_capacity_blocks: Some(16),
            interner_epoch_blocks: Some(64),
            ..Default::default()
        };
        let res = run(&cfg, &trace, 1.0);
        assert_eq!(res.n_completed, 400);
        assert!(res.interner_epochs > 0, "recycling never triggered");
        assert!(res.interner_freed > 1_000, "freed only {} ids", res.interner_freed);
        assert!(
            res.interner_id_space < 256,
            "id space {} not bounded (1600 distinct blocks streamed)",
            res.interner_id_space
        );
        // Off by default: the append-only path interns every block.
        let plain = SimConfig { interner_epoch_blocks: None, ..cfg };
        let base = run(&plain, &trace, 1.0);
        assert_eq!(base.interner_id_space, 1600);
        assert_eq!(base.interner_epochs, 0);
        assert_eq!(base.n_completed, res.n_completed, "recycling must not change outcomes");
    }
}
