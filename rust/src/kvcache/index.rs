//! The Conductor's **global prefix index** (§5, §6): per-block, per-node,
//! tier-aware residency bitsets, replacing the per-request scan of every
//! prefill instance's pool.
//!
//! `FindBestPrefixMatch` used to cost O(nodes × chain) HashMap probes
//! per scheduling decision — worst in exactly the long-context regime
//! the paper targets (128K ctx ≈ thousands of blocks).  With the index,
//! [`PrefixIndex::best_prefix_into`] touches each chain block **once**
//! and advances every candidate node's match simultaneously with bitmask
//! arithmetic: per block, one direct array load plus O(words) mask ops
//! plus work proportional only to the nodes whose state *changes* at
//! that block (death, DRAM-run end, SSD copy).
//!
//! Storage is **dense and width-adaptive**: blocks are interned
//! [`DenseBlockId`]s (see `kvcache::intern`), so residency lives in one
//! flat `Vec<u64>` indexed by `block × stride` — no hashing at all on
//! the lookup path — and the stride is sized to the cluster at
//! construction: `n_words = n_nodes.div_ceil(64)` words per tier, so an
//! 8-node cluster pays 2 words (16 B) per block slot where the old fixed
//! `[u64; 4]`-per-tier representation paid 8 (64 B).  One index covers
//! up to [`PrefixIndex::MAX_NODES`] prefill nodes; only the explicit
//! `use_prefix_index: false` knob restores the per-pool scan.
//!
//! Consistency protocol: the index is owned next to the scheduler (the
//! `Sim`), not by the pools — pools stay self-contained LRU structures
//! and every mutation ([`CachePool::admit_chain_reusing`],
//! [`CachePool::insert_replica`], [`CachePool::demote_block`],
//! [`CachePool::demote_idle`], …) *returns* a [`TierDelta`] of residency
//! changes which the owner applies via [`PrefixIndex::apply`].  A
//! debug-mode invariant ([`PrefixIndex::equals_rebuild_of`]) checks the
//! incremental index against a brute-force rebuild.
//!
//! The walk also carries each node's SSD *positions* out into an
//! [`SsdPositions`] scratch — the §6.2 wire-refresh pricing consumes
//! them so it never re-probes a tier per head block (see
//! `conductor::select_prefill`).

use super::intern::DenseBlockId;
use super::pool::{CachePool, SsdPositions, Tier, TierDelta, TierMatch};

/// Hard width cap: enough words for [`PrefixIndex::MAX_NODES`] nodes.
/// The per-walk cursor masks live on the stack at this width; the per-
/// block storage only ever pays the *configured* width.
const MAX_WORDS: usize = 4;

#[derive(Debug)]
pub struct PrefixIndex {
    n_nodes: usize,
    /// Words actually carrying bits: `n_nodes.div_ceil(64)` (≥ 1).
    n_words: usize,
    /// `2 * n_words` — words per block slot (DRAM words, then SSD words).
    stride: usize,
    /// Flat residency table indexed by `block as usize * stride`; grows
    /// (zero-filled) as new dense ids appear.  A dropped block's slot
    /// zeroes out but is kept.  With `interner_epoch_blocks` set, the
    /// `Sim` recycles ids that are resident in no pool tier
    /// (`BlockInterner::recycle_epoch`) — such ids have all-zero slots
    /// here by construction, so a reused id re-enters an empty slot and
    /// the table stays consistent without any index-side bookkeeping.
    words: Vec<u64>,
    /// Blocks with at least one holder (the old map's `len`).
    resident: usize,
}

impl PrefixIndex {
    /// `MAX_WORDS` bitset words per tier per block at most.
    pub const MAX_NODES: usize = 64 * MAX_WORDS;

    /// Whether a single index can cover `n_nodes` prefill nodes.
    pub fn supports(n_nodes: usize) -> bool {
        n_nodes <= Self::MAX_NODES
    }

    pub fn new(n_nodes: usize) -> Self {
        assert!(Self::supports(n_nodes), "PrefixIndex covers at most {} nodes", Self::MAX_NODES);
        let n_words = n_nodes.div_ceil(64).max(1);
        PrefixIndex { n_nodes, n_words, stride: 2 * n_words, words: Vec::new(), resident: 0 }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Residency words per tier (`div_ceil(n_nodes, 64)`) — the width-
    /// adaptation the footprint depends on.
    pub fn n_words(&self) -> usize {
        self.n_words
    }

    /// Distinct blocks resident anywhere in the cluster.
    pub fn len(&self) -> usize {
        self.resident
    }

    pub fn is_empty(&self) -> bool {
        self.resident == 0
    }

    #[inline]
    fn word_bit(node: usize) -> (usize, u64) {
        (node >> 6, 1u64 << (node & 63))
    }

    /// Record `node`'s residency for one block (`None` = not resident).
    /// Setting one tier clears the other — a block lives in exactly one
    /// tier per pool.
    pub fn set(&mut self, node: usize, b: DenseBlockId, loc: Option<Tier>) {
        debug_assert!(node < self.n_nodes);
        let off = b as usize * self.stride;
        if off + self.stride > self.words.len() {
            if loc.is_none() {
                return; // clearing a block never seen: nothing to do
            }
            self.words.resize(off + self.stride, 0);
        }
        let e = &mut self.words[off..off + self.stride];
        let was_empty = e.iter().all(|&w| w == 0);
        let (w, bit) = Self::word_bit(node);
        e[w] &= !bit;
        e[self.n_words + w] &= !bit;
        match loc {
            Some(Tier::Dram) => e[w] |= bit,
            Some(Tier::Ssd) => e[self.n_words + w] |= bit,
            None => {}
        }
        let now_empty = e.iter().all(|&w| w == 0);
        match (was_empty, now_empty) {
            (true, false) => self.resident += 1,
            (false, true) => self.resident -= 1,
            _ => {}
        }
    }

    /// Apply a pool mutation's residency changes for `node`, in order.
    pub fn apply(&mut self, node: usize, delta: &TierDelta) {
        for &(b, loc) in &delta.changes {
            self.set(node, b, loc);
        }
    }

    #[inline]
    fn entry(&self, b: DenseBlockId) -> Option<&[u64]> {
        let off = b as usize * self.stride;
        self.words.get(off..off + self.stride)
    }

    /// `node`'s residency for one block, as the pool would report it.
    pub fn tier_on(&self, node: usize, b: DenseBlockId) -> Option<Tier> {
        debug_assert!(node < self.n_nodes);
        let e = self.entry(b)?;
        let (w, bit) = Self::word_bit(node);
        if e[w] & bit != 0 {
            Some(Tier::Dram)
        } else if e[self.n_words + w] & bit != 0 {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    /// Every node holding `b` (either tier), ascending — one probe for
    /// the whole cluster, replacing per-pool `contains` scans
    /// (`conductor::migration` reads holder sets through this).
    pub fn holders(&self, b: DenseBlockId) -> Vec<usize> {
        let mut out = Vec::new();
        if let Some(e) = self.entry(b) {
            for w in 0..self.n_words {
                let mut bits = e[w] | e[self.n_words + w];
                while bits != 0 {
                    out.push(w * 64 + bits.trailing_zeros() as usize);
                    bits &= bits - 1;
                }
            }
        }
        out
    }

    /// Bulk-load one node's pool (brute-force rebuild path).
    pub fn insert_pool(&mut self, node: usize, pool: &CachePool) {
        for b in pool.iter_dram_blocks() {
            self.set(node, b, Some(Tier::Dram));
        }
        for b in pool.iter_ssd_blocks() {
            self.set(node, b, Some(Tier::Ssd));
        }
    }

    /// `FindBestPrefixMatch` for **all** nodes in one chain walk:
    /// `out[n]` equals `pools[n].prefix_match_with(hash_ids, …)` exactly
    /// — match, SSD-run summary, and per-node SSD positions — but the
    /// whole cluster costs one array load per chain block instead of one
    /// hash probe per (node, block) pair.  `out` and `ssd_pos` are
    /// caller-owned scratch (cleared here), so steady-state decisions
    /// allocate nothing.
    // lint: hot
    pub fn best_prefix_into(
        &self,
        hash_ids: &[DenseBlockId],
        out: &mut Vec<TierMatch>,
        ssd_pos: &mut SsdPositions,
    ) {
        out.clear();
        out.resize(self.n_nodes, TierMatch::default());
        ssd_pos.reset(self.n_nodes);
        if self.n_nodes == 0 {
            return;
        }
        // Nodes whose match still extends / whose match is still a pure
        // DRAM run.  A cleared bit means that node's `blocks` (resp.
        // `dram_prefix`) has been finalized in `out`.
        let mut alive = [0u64; MAX_WORDS];
        for w in 0..self.n_words {
            let bits = self.n_nodes - w * 64;
            alive[w] = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
        }
        let mut dram_run = alive;
        for (i, &b) in hash_ids.iter().enumerate() {
            if alive[..self.n_words].iter().all(|&w| w == 0) {
                break;
            }
            let entry = self.entry(b);
            for w in 0..self.n_words {
                if alive[w] == 0 {
                    continue;
                }
                let (dram_w, ssd_w) = match entry {
                    Some(e) => (e[w], e[self.n_words + w]),
                    None => (0, 0),
                };
                let base = w * 64;
                let resident = (dram_w | ssd_w) & alive[w];
                // Nodes missing this block: their match ends at i blocks.
                let mut died = alive[w] & !resident;
                while died != 0 {
                    let bit = died & died.wrapping_neg();
                    let n = base + bit.trailing_zeros() as usize;
                    died ^= bit;
                    out[n].blocks = i;
                    if dram_run[w] & bit != 0 {
                        out[n].dram_prefix = i;
                    }
                }
                alive[w] = resident;
                dram_run[w] &= resident;
                // Nodes whose block is SSD-resident: their pure-DRAM
                // leading run ends here (and the block counts as an SSD
                // copy).
                let mut run_end = dram_run[w] & !dram_w;
                while run_end != 0 {
                    let n = base + run_end.trailing_zeros() as usize;
                    run_end &= run_end - 1;
                    out[n].dram_prefix = i;
                }
                dram_run[w] &= dram_w;
                let mut on_ssd = alive[w] & ssd_w;
                while on_ssd != 0 {
                    let n = base + on_ssd.trailing_zeros() as usize;
                    on_ssd &= on_ssd - 1;
                    out[n].ssd_blocks += 1;
                    out[n].ssd_last = i as u32;
                    ssd_pos.push(n, i as u32);
                }
            }
        }
        // Survivors matched the whole chain.
        let full = hash_ids.len();
        for w in 0..self.n_words {
            let base = w * 64;
            let mut still = alive[w];
            while still != 0 {
                let bit = still & still.wrapping_neg();
                let n = base + bit.trailing_zeros() as usize;
                still ^= bit;
                out[n].blocks = full;
                if dram_run[w] & bit != 0 {
                    out[n].dram_prefix = full;
                }
            }
        }
        for m in out.iter_mut() {
            m.dram_blocks = m.blocks - m.ssd_blocks;
        }
        ssd_pos.seal();
    }

    /// Allocating convenience wrapper around [`Self::best_prefix_into`].
    pub fn best_prefix(&self, hash_ids: &[DenseBlockId]) -> Vec<TierMatch> {
        let mut out = Vec::new();
        let mut ssd_pos = SsdPositions::default();
        self.best_prefix_into(hash_ids, &mut out, &mut ssd_pos);
        out
    }

    /// Debug invariant: the incrementally maintained index equals a
    /// brute-force rebuild from the pools (in node order).  The fresh
    /// table may be shorter (it only grows to the highest *resident*
    /// dense id); any overhang must be all-zero.
    pub fn equals_rebuild_of<'a>(&self, pools: impl Iterator<Item = &'a CachePool>) -> bool {
        let mut fresh = PrefixIndex::new(self.n_nodes);
        let mut count = 0usize;
        for (n, pool) in pools.enumerate() {
            fresh.insert_pool(n, pool);
            count = n + 1;
        }
        if count != self.n_nodes || fresh.resident != self.resident {
            return false;
        }
        let (a, b) = (&self.words, &fresh.words);
        let common = a.len().min(b.len());
        a[..common] == b[..common]
            && a[common..].iter().all(|&w| w == 0)
            && b[common..].iter().all(|&w| w == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PolicyKind;

    fn pools(n: usize) -> Vec<CachePool> {
        (0..n).map(|_| CachePool::new(PolicyKind::Lru, Some(64), Some(64))).collect()
    }

    fn scan(pools: &[CachePool], chain: &[DenseBlockId]) -> Vec<TierMatch> {
        pools.iter().map(|p| p.prefix_match(chain)).collect()
    }

    #[test]
    fn width_adapts_to_the_cluster() {
        assert_eq!(PrefixIndex::new(1).n_words(), 1);
        assert_eq!(PrefixIndex::new(8).n_words(), 1);
        assert_eq!(PrefixIndex::new(64).n_words(), 1);
        assert_eq!(PrefixIndex::new(65).n_words(), 2);
        assert_eq!(PrefixIndex::new(128).n_words(), 2);
        assert_eq!(PrefixIndex::new(129).n_words(), 3);
        assert_eq!(PrefixIndex::new(256).n_words(), 4);
        // Small clusters are back to one word per tier: 16 B per block
        // slot instead of the old fixed 64.
        let mut idx = PrefixIndex::new(8);
        idx.set(3, 0, Some(Tier::Dram));
        idx.set(3, 1, Some(Tier::Ssd));
        assert_eq!(idx.words.len(), 2 * idx.stride);
        assert_eq!(idx.stride, 2);
    }

    #[test]
    fn best_prefix_matches_per_pool_scan() {
        let mut ps = pools(3);
        let mut idx = PrefixIndex::new(3);
        let chain: Vec<DenseBlockId> = (10..20).collect();
        // Node 0: full chain in DRAM; node 1: first half, with one block
        // demoted to SSD; node 2: nothing.
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        idx.apply(1, &ps[1].admit_chain(&chain[..5], 0.0));
        idx.apply(1, &ps[1].demote_block(12, 1.0).unwrap());
        let got = idx.best_prefix(&chain);
        let want = scan(&ps, &chain);
        assert_eq!(got, want);
        assert_eq!(got[0].blocks, 10);
        assert_eq!(
            got[1],
            TierMatch { blocks: 5, dram_prefix: 2, dram_blocks: 4, ssd_blocks: 1, ssd_last: 2 }
        );
        assert_eq!(got[2], TierMatch::default());
        assert!(idx.equals_rebuild_of(ps.iter()));
        // Holder probes agree with the pools.
        assert_eq!(idx.holders(12), vec![0, 1]);
        assert_eq!(idx.holders(17), vec![0]);
        assert_eq!(idx.holders(999), Vec::<usize>::new());
    }

    #[test]
    fn walk_positions_match_scan_positions() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        let chain: Vec<DenseBlockId> = (100..108).collect();
        idx.apply(0, &ps[0].admit_chain(&chain, 0.0));
        for b in [101, 103, 104] {
            idx.apply(0, &ps[0].demote_block(b, 1.0).unwrap());
        }
        idx.apply(1, &ps[1].admit_chain(&chain[..3], 0.0));
        let mut out = Vec::new();
        let mut walk_pos = SsdPositions::default();
        idx.best_prefix_into(&chain, &mut out, &mut walk_pos);
        let mut scan_list = Vec::new();
        for (n, p) in ps.iter().enumerate() {
            let m = p.prefix_match_with(&chain, &mut scan_list);
            assert_eq!(out[n], m, "node {n}");
            assert_eq!(walk_pos.node(n), &scan_list[..], "node {n} positions");
        }
        assert_eq!(walk_pos.node(0), &[1, 3, 4]);
        assert_eq!(out[0].ssd_last, 4);
        assert!(walk_pos.node(1).is_empty());
    }

    #[test]
    fn tier_on_tracks_moves_and_drops() {
        let mut ps = pools(2);
        let mut idx = PrefixIndex::new(2);
        idx.apply(0, &ps[0].admit_chain(&[1, 2], 0.0));
        idx.apply(1, &ps[1].admit_chain(&[2], 0.0));
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Dram));
        assert_eq!(idx.tier_on(1, 1), None);
        assert_eq!(idx.tier_on(1, 2), Some(Tier::Dram));
        idx.apply(0, &ps[0].demote_block(1, 1.0).unwrap());
        assert_eq!(idx.tier_on(0, 1), Some(Tier::Ssd));
        // A drop removes the node's bit; the last holder's drop zeroes
        // the slot and the block stops counting as resident.
        idx.set(0, 1, None);
        assert_eq!(idx.tier_on(0, 1), None);
        assert_eq!(idx.len(), 1); // only block 2 remains
        // Clearing a block the index never saw is a no-op.
        idx.set(0, 10_000, None);
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn eviction_pressure_keeps_index_consistent() {
        // A 4-block DRAM tier over a 6-block SSD tier: admissions demote
        // and eventually drop; the deltas must keep the index equal to a
        // rebuild at every step, and best_prefix equal to the scan.
        let mut ps = vec![CachePool::new(PolicyKind::Lru, Some(4), Some(6))];
        let mut idx = PrefixIndex::new(1);
        for round in 0..8u32 {
            let chain: Vec<DenseBlockId> = (round * 3..round * 3 + 4).collect();
            let delta = ps[0].admit_chain(&chain, round as f64);
            idx.apply(0, &delta);
            assert!(idx.equals_rebuild_of(ps.iter()), "round {round}");
            assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain), "round {round}");
        }
    }

    #[test]
    fn wide_clusters_cross_word_boundaries() {
        // The residency bitset is width-adaptive, so one index covers
        // well past 64 prefill nodes with no fallback.
        assert!(PrefixIndex::supports(65));
        assert!(PrefixIndex::supports(PrefixIndex::MAX_NODES));
        assert!(!PrefixIndex::supports(PrefixIndex::MAX_NODES + 1));
        let n = 130; // three words, last one partial
        let mut ps = pools(n);
        let mut idx = PrefixIndex::new(n);
        assert_eq!(idx.n_words(), 3);
        let chain: Vec<DenseBlockId> = (1_000..1_016).collect();
        // Holders straddling every word: 0, 63, 64, 77, 127, 128, 129.
        for &node in &[0usize, 63, 64, 77, 127, 128, 129] {
            let len = 4 + node % 12;
            idx.apply(node, &ps[node].admit_chain(&chain[..len], 0.0));
        }
        idx.apply(77, &ps[77].demote_block(1_001, 1.0).unwrap());
        idx.apply(129, &ps[129].demote_block(1_000, 1.0).unwrap());
        assert_eq!(idx.best_prefix(&chain), scan(&ps, &chain));
        assert!(idx.equals_rebuild_of(ps.iter()));
        assert_eq!(idx.tier_on(77, 1_001), Some(Tier::Ssd));
        assert_eq!(idx.tier_on(129, 1_000), Some(Tier::Ssd));
        assert_eq!(idx.holders(1_000), vec![0, 63, 64, 77, 127, 128, 129]);
        // Bit 63 of a full word and bit 0 of the next stay distinct.
        assert_eq!(idx.tier_on(63, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(64, 1_003), Some(Tier::Dram));
        assert_eq!(idx.tier_on(65, 1_003), None);
    }

    #[test]
    fn max_width_masks_have_no_shift_overflow() {
        let last = PrefixIndex::MAX_NODES - 1;
        let mut idx = PrefixIndex::new(PrefixIndex::MAX_NODES);
        idx.set(last, 7, Some(Tier::Ssd));
        idx.set(63, 7, Some(Tier::Dram));
        assert_eq!(idx.tier_on(last, 7), Some(Tier::Ssd));
        let m = idx.best_prefix(&[7]);
        assert_eq!(
            m[last],
            TierMatch { blocks: 1, dram_prefix: 0, dram_blocks: 0, ssd_blocks: 1, ssd_last: 0 }
        );
        assert_eq!(
            m[63],
            TierMatch {
                blocks: 1,
                dram_prefix: 1,
                dram_blocks: 1,
                ssd_blocks: 0,
                ssd_last: TierMatch::NO_SSD
            }
        );
        assert_eq!(m[0], TierMatch::default());
    }

    #[test]
    fn empty_chain_and_empty_index() {
        let idx = PrefixIndex::new(2);
        assert!(idx.is_empty());
        let m = idx.best_prefix(&[]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
        let m = idx.best_prefix(&[99]);
        assert_eq!(m, vec![TierMatch::default(), TierMatch::default()]);
    }
}
