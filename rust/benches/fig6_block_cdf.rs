//! Fig 6 — CDF of block hit counts in the request trace.
//! Paper: >50% of cache blocks are never reused (hit count 1 in our
//! accounting: first touch only) while hot blocks are accessed tens of
//! thousands of times.

use mooncake::bench_util::{banner, fmt, row};
use mooncake::trace::gen::{generate, TraceGenConfig};
use mooncake::trace::stats::{block_hit_cdf, block_hit_counts};

fn main() {
    let trace = generate(&TraceGenConfig::default());

    banner("Fig 6: CDF of block hit counts");
    row(&["hit_count<=".into(), "fraction_of_blocks".into()]);
    let cdf = block_hit_cdf(&trace);
    for (count, frac) in &cdf {
        row(&[count.to_string(), fmt(*frac, 4)]);
    }

    let counts = block_hit_counts(&trace);
    let once = counts.values().filter(|&&c| c == 1).count() as f64 / counts.len() as f64;
    let max = counts.values().copied().max().unwrap_or(0);
    println!("\nblocks used exactly once: {:.1}% (paper: >50%)", once * 100.0);
    println!("hottest block hit count:  {max} (paper: tens of thousands)");

    assert!(once > 0.45, "cold-tail fraction {once}");
    assert!(max > 1_000, "hot blocks must exist, max={max}");
    assert!(cdf.windows(2).all(|w| w[0].1 <= w[1].1), "CDF monotone");
    println!("\nfig6 shape checks OK");
}
