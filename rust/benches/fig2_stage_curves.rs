//! Fig 2 — normalized throughput and latency of the prefill and decoding
//! stages for the dummy LLaMA2-70B model.
//!
//! Left: prefill latency vs sequence length (superlinear) and throughput
//! (tokens/s, peaking then falling as attention dominates).
//! Right: decode latency vs batch size (grows) and throughput
//! (sublinear growth — memory-bound).

use mooncake::bench_util::{banner, fmt, row};
use mooncake::model::PerfModel;

fn main() {
    let perf = PerfModel::paper();

    banner("Fig 2 (left): prefill stage vs sequence length");
    row(&["seq_len".into(), "latency_ms".into(), "tok_per_s".into(), "norm_latency".into()]);
    let base = perf.prefill_ms(1_000, 0);
    for n in [1_000u64, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000, 128_000] {
        let ms = perf.prefill_ms(n, 0);
        row(&[
            n.to_string(),
            fmt(ms, 1),
            fmt(n as f64 / ms * 1e3, 0),
            fmt(ms / base, 2),
        ]);
    }

    banner("Fig 2 (right): decoding stage vs batch size (ctx 4k/seq)");
    row(&["batch".into(), "step_ms".into(), "tok_per_s".into(), "norm_throughput".into()]);
    let t1 = perf.decode_step_ms(1, 4_000);
    let thru1 = 1.0 / t1 * 1e3;
    for b in [1u64, 2, 4, 8, 16, 32, 64, 128, 256] {
        let ms = perf.decode_step_ms(b, b * 4_000);
        let thru = b as f64 / ms * 1e3;
        row(&[b.to_string(), fmt(ms, 2), fmt(thru, 0), fmt(thru / thru1, 2)]);
    }

    // Shape assertions (the figure's qualitative content).
    let lat64k = perf.prefill_ms(64_000, 0);
    let lat8k = perf.prefill_ms(8_000, 0);
    assert!(lat64k > 8.0 * lat8k, "prefill must be superlinear");
    let thru256 = 256.0 / perf.decode_step_ms(256, 256 * 4_000);
    let thru16 = 16.0 / perf.decode_step_ms(16, 16 * 4_000);
    assert!(thru256 > thru16 && thru256 < 16.0 * thru16, "decode throughput sublinear");
    println!("\nfig2 shape checks OK");
}
