//! Statistical trace generator calibrated to the published Mooncake trace
//! (§4.2): ~23.6k requests/hour, avg input ≈ 7,590 tokens, avg output ≈
//! 182 tokens, session-based prefix sharing, a ceiling of ~50% reusable
//! blocks at infinite cache (Table 1), >50% of blocks never reused while
//! hot (system-prompt) blocks are hit by a large share of all requests
//! (Fig 6).
//!
//! The real trace is proprietary-derived; this generator reproduces the
//! *distributional features the experiments consume* — lengths, arrival
//! pattern, and prefix-caching relationships — in the exact published
//! JSONL schema.  Substitution rationale in DESIGN.md.

use crate::trace::{TraceRecord, BLOCK_TOKENS};
use crate::util::rng::Rng;
use crate::BlockId;

#[derive(Debug, Clone)]
pub struct TraceGenConfig {
    pub n_requests: usize,
    /// Trace duration (ms); arrivals form a Poisson process over it.
    pub duration_ms: u64,
    pub seed: u64,
    /// Mean tokens of the *first* turn of a session (doc/context upload).
    pub mean_first_input: f64,
    /// Lognormal sigma for input lengths.
    pub sigma_input: f64,
    pub mean_output: f64,
    pub sigma_output: f64,
    /// Fraction of requests belonging to multi-turn sessions.
    pub session_fraction: f64,
    /// Mean turns per session (geometric).
    pub mean_session_turns: f64,
    /// Mean gap between turns of a session (ms, exponential).
    pub mean_turn_gap_ms: f64,
    /// Mean *new* input blocks added per follow-up turn.
    pub mean_new_blocks: f64,
    /// Distinct system prompts and their block lengths; a Zipf-popular
    /// system prompt prefixes most requests (the Fig 6 hot blocks).
    pub n_system_prompts: usize,
    pub system_prompt_blocks: u64,
    /// Fraction of requests carrying a system prompt.
    pub system_fraction: f64,
    /// Fraction of sessions/one-shots whose arrival lands inside a burst
    /// window instead of uniformly over the trace (0.0 = the calibrated
    /// Poisson-like default).  Bursty replay stresses the Fig 8/9 queue
    /// dynamics the event-driven prefill executor makes observable.
    pub burst_fraction: f64,
    /// Number of burst windows spread evenly over the duration.
    pub n_bursts: usize,
    /// Width of each burst window, ms.
    pub burst_width_ms: u64,
    /// Probability that a finished session *re-arrives* after a long idle
    /// gap, re-sending its whole prefix (multi-turn prefix re-arrival: by
    /// then the cache may have evicted it — the Table 1 capacity story).
    pub rearrival_fraction: f64,
    /// Mean idle gap before a session re-arrives (ms, exponential).
    pub mean_rearrival_gap_ms: f64,
    /// Flash-crowd storm: fraction of sessions/one-shots whose arrival
    /// lands inside *one* spike window at `storm_start_ms` instead of
    /// uniformly over the trace (0.0 = off, bit-for-bit the calibrated
    /// stream).  Unlike `burst_fraction`'s evenly spaced bumps, a storm
    /// is a single overload wall — the §7 early-rejection scenario.
    pub storm_fraction: f64,
    /// Where the storm window starts (ms).
    pub storm_start_ms: u64,
    /// Storm window width (ms).
    pub storm_width_ms: u64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        TraceGenConfig {
            n_requests: 23_608,
            duration_ms: 3_600_000,
            seed: 42,
            mean_first_input: 7_000.0,
            sigma_input: 0.9,
            mean_output: 182.0,
            sigma_output: 1.0,
            session_fraction: 0.47,
            mean_session_turns: 2.5,
            mean_turn_gap_ms: 45_000.0,
            mean_new_blocks: 1.6,
            n_system_prompts: 24,
            system_prompt_blocks: 2,
            system_fraction: 0.85,
            burst_fraction: 0.0,
            n_bursts: 4,
            burst_width_ms: 20_000,
            rearrival_fraction: 0.0,
            mean_rearrival_gap_ms: 900_000.0,
            storm_fraction: 0.0,
            storm_start_ms: 0,
            storm_width_ms: 30_000,
        }
    }
}

/// Generate a trace in the published schema.
pub fn generate(cfg: &TraceGenConfig) -> Vec<TraceRecord> {
    let mut rng = Rng::new(cfg.seed);
    let mut next_block: BlockId = 1_000; // leave room for system blocks
    let fresh = |n: u64, next_block: &mut BlockId| -> Vec<BlockId> {
        let start = *next_block;
        *next_block += n;
        (start..start + n).collect()
    };

    // System prompt block chains: system prompt k occupies ids
    // [k*B, (k+1)*B).  Popularity is Zipf-ish via squared-uniform rank.
    let spb = cfg.system_prompt_blocks;
    let system_chain = |k: u64| -> Vec<BlockId> { (k * spb..(k + 1) * spb).collect() };

    let mut out: Vec<TraceRecord> = Vec::with_capacity(cfg.n_requests);

    while out.len() < cfg.n_requests {
        // Arrival: uniform over the trace, or — for the bursty-replay
        // scenario — concentrated into evenly spaced burst windows.  The
        // guards short-circuit so the default config consumes the exact
        // RNG stream earlier seeds calibrated against.  The storm branch
        // is checked first: a flash crowd dominates any background
        // burstiness it is layered over.
        let t0 = if cfg.storm_fraction > 0.0 && rng.f64() < cfg.storm_fraction {
            (cfg.storm_start_ms + rng.below(cfg.storm_width_ms.max(1)))
                .min(cfg.duration_ms - 1)
        } else if cfg.burst_fraction > 0.0 && rng.f64() < cfg.burst_fraction {
            let k = rng.below(cfg.n_bursts.max(1) as u64);
            let center = (k + 1) * cfg.duration_ms / (cfg.n_bursts as u64 + 1);
            let start = center.saturating_sub(cfg.burst_width_ms / 2);
            (start + rng.below(cfg.burst_width_ms.max(1))).min(cfg.duration_ms - 1)
        } else {
            rng.below(cfg.duration_ms)
        };
        let sys: Vec<BlockId> = if rng.f64() < cfg.system_fraction {
            let u = rng.f64();
            let k = ((u * u) * cfg.n_system_prompts as f64) as u64; // skewed to 0
            system_chain(k)
        } else {
            vec![]
        };

        let first_tokens =
            (rng.lognormal_mean(cfg.mean_first_input, cfg.sigma_input) as u64).clamp(64, 131_072);
        let sys_tokens = sys.len() as u64 * BLOCK_TOKENS;
        let doc_blocks = (first_tokens.saturating_sub(sys_tokens)).div_ceil(BLOCK_TOKENS).max(1);

        if rng.f64() < cfg.session_fraction {
            // Multi-turn session: context grows monotonically, so every
            // turn's hash_ids start with the previous turn's chain.
            let mut turns = rng.geometric_mean(cfg.mean_session_turns).min(20);
            let mut chain = sys.clone();
            chain.extend(fresh(doc_blocks, &mut next_block));
            let mut t = t0 as f64;
            loop {
                for _ in 0..turns {
                    if out.len() >= cfg.n_requests {
                        break;
                    }
                    let output = (rng.lognormal_mean(cfg.mean_output, cfg.sigma_output) as u64)
                        .clamp(1, 4_000);
                    out.push(TraceRecord {
                        timestamp: (t as u64).min(cfg.duration_ms - 1),
                        input_length: chain.len() as u64 * BLOCK_TOKENS
                            - rng.below(BLOCK_TOKENS / 2),
                        output_length: output,
                        hash_ids: chain.clone(),
                    });
                    // Next turn: previous output + fresh user input become
                    // new blocks appended to the chain.
                    let add = (rng.exp(1.0 / cfg.mean_new_blocks) as u64).clamp(1, 8);
                    chain.extend(fresh(add, &mut next_block));
                    t += rng.exp(1.0 / cfg.mean_turn_gap_ms);
                }
                // Prefix re-arrival: the user comes back much later and the
                // whole grown chain re-arrives (guards short-circuit so the
                // default config's RNG stream is untouched).
                if cfg.rearrival_fraction <= 0.0
                    || out.len() >= cfg.n_requests
                    || rng.f64() >= cfg.rearrival_fraction
                {
                    break;
                }
                t += rng.exp(1.0 / cfg.mean_rearrival_gap_ms);
                if t >= cfg.duration_ms as f64 {
                    // The user would come back after the trace ends; do
                    // not clamp the re-arrival into an artificial burst
                    // at the final millisecond.
                    break;
                }
                turns = rng.geometric_mean(cfg.mean_session_turns).min(20);
            }
        } else {
            // One-shot request: its document blocks are never reused.
            let mut chain = sys;
            chain.extend(fresh(doc_blocks, &mut next_block));
            let output =
                (rng.lognormal_mean(cfg.mean_output, cfg.sigma_output) as u64).clamp(1, 4_000);
            out.push(TraceRecord {
                timestamp: t0,
                input_length: chain.len() as u64 * BLOCK_TOKENS - rng.below(BLOCK_TOKENS / 2),
                output_length: output,
                hash_ids: chain,
            });
        }
    }

    out.sort_by_key(|r| r.timestamp);
    out
}

/// Poisson-arrival dataset with a controlled prefix-cache ratio — the
/// §8.1 workloads (Table 2):
///   ArXiv Summarization:  mean_in 8088,  mean_out 229, cache ~0%
///   L-Eval:               mean_in 19019, mean_out 72,  cache >80%
///   Simulated data:       in ∈ {16k,32k,64k,128k}, out 512, cache 50%
pub fn poisson_dataset(
    n: usize,
    rps: f64,
    mean_in: u64,
    mean_out: u64,
    cache_ratio: f64,
    fixed_lengths: bool,
    seed: u64,
) -> Vec<TraceRecord> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut next_block: BlockId = 1;
    let mut out = Vec::with_capacity(n);
    // Documents provide the shared prefix; each is reused ~`reuse` times.
    let reuse = if cache_ratio >= 0.8 { 12 } else { 4 };
    let mut doc: Vec<BlockId> = Vec::new();
    let mut doc_uses = 0usize;

    for _ in 0..n {
        t += rng.exp(rps) * 1e3;
        let input = if fixed_lengths {
            mean_in
        } else {
            (rng.lognormal_mean(mean_in as f64, 0.3) as u64).clamp(256, 200_000)
        };
        let blocks = input.div_ceil(BLOCK_TOKENS).max(1);
        let shared = ((blocks as f64) * cache_ratio) as u64;
        if doc.is_empty() || doc_uses >= reuse || doc.len() < shared as usize {
            doc = (next_block..next_block + shared.max(1)).collect();
            next_block += shared.max(1);
            doc_uses = 0;
        }
        doc_uses += 1;
        let mut hash_ids: Vec<BlockId> = doc[..shared as usize].to_vec();
        let fresh = blocks - shared;
        hash_ids.extend(next_block..next_block + fresh);
        next_block += fresh;
        let output = if fixed_lengths {
            mean_out
        } else {
            (rng.lognormal_mean(mean_out as f64, 0.6) as u64).clamp(1, 4_000)
        };
        out.push(TraceRecord {
            timestamp: t as u64,
            input_length: input,
            output_length: output,
            hash_ids,
        });
    }
    out
}

/// The four Table-2 workloads by name.
pub fn dataset(name: &str, n: usize, rps: f64, seed: u64) -> Vec<TraceRecord> {
    match name {
        "arxiv" => poisson_dataset(n, rps, 8_088, 229, 0.0, false, seed),
        "leval" => poisson_dataset(n, rps, 19_019, 72, 0.85, false, seed),
        "sim16k" => poisson_dataset(n, rps, 16_384, 512, 0.5, true, seed),
        "sim32k" => poisson_dataset(n, rps, 32_768, 512, 0.5, true, seed),
        "sim64k" => poisson_dataset(n, rps, 65_536, 512, 0.5, true, seed),
        "sim128k" => poisson_dataset(n, rps, 131_072, 512, 0.5, true, seed),
        other => panic!("unknown dataset {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn small_cfg() -> TraceGenConfig {
        TraceGenConfig { n_requests: 4_000, ..Default::default() }
    }

    #[test]
    fn calibrated_lengths() {
        let trace = generate(&small_cfg());
        let mean_in: f64 =
            trace.iter().map(|r| r.input_length as f64).sum::<f64>() / trace.len() as f64;
        let mean_out: f64 =
            trace.iter().map(|r| r.output_length as f64).sum::<f64>() / trace.len() as f64;
        // §4.2: avg input 7,590 / avg output 182 (tolerate ±35%, sessions
        // grow inputs beyond the first-turn mean).
        assert!((mean_in / 7590.0 - 1.0).abs() < 0.35, "mean_in={mean_in}");
        assert!((mean_out / 182.0 - 1.0).abs() < 0.35, "mean_out={mean_out}");
    }

    #[test]
    fn sorted_and_in_range() {
        let cfg = small_cfg();
        let trace = generate(&cfg);
        assert_eq!(trace.len(), cfg.n_requests);
        assert!(trace.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
        assert!(trace.iter().all(|r| r.timestamp < cfg.duration_ms));
        assert!(trace.iter().all(|r| !r.hash_ids.is_empty() && r.output_length >= 1));
    }

    #[test]
    fn infinite_cache_hit_rate_near_half() {
        // Table 1: ~51% hit rate at infinite capacity.
        let trace = generate(&TraceGenConfig { n_requests: 10_000, ..Default::default() });
        let mut seen = std::collections::HashSet::new();
        let mut hits = 0u64;
        let mut total = 0u64;
        for r in &trace {
            for &b in &r.hash_ids {
                total += 1;
                if !seen.insert(b) {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.38 && rate < 0.62, "infinite-cache hit rate {rate}");
    }

    #[test]
    fn block_popularity_is_skewed() {
        // Fig 6: >50% of blocks used once; hot blocks hit by a large
        // share of requests.
        let trace = generate(&TraceGenConfig { n_requests: 10_000, ..Default::default() });
        let mut counts: HashMap<u64, u64> = HashMap::new();
        for r in &trace {
            for &b in &r.hash_ids {
                *counts.entry(b).or_default() += 1;
            }
        }
        let once = counts.values().filter(|&&c| c == 1).count();
        let frac_once = once as f64 / counts.len() as f64;
        assert!(frac_once > 0.45, "single-use fraction {frac_once}");
        let max = counts.values().copied().max().unwrap();
        assert!(max > 1_000, "hottest block count {max}");
    }

    #[test]
    fn session_prefixes_chain() {
        // Any two requests sharing a first hash id share the whole prefix
        // up to the shorter chain's divergence point — by construction
        // chains only append.
        let trace = generate(&small_cfg());
        let mut by_first: HashMap<u64, Vec<&TraceRecord>> = HashMap::new();
        for r in &trace {
            if r.hash_ids[0] >= 1_000 {
                // session/doc blocks (not system prompts)
                by_first.entry(r.hash_ids[0]).or_default().push(r);
            }
        }
        for (_, rs) in by_first.iter().filter(|(_, rs)| rs.len() > 1) {
            let min_len = rs.iter().map(|r| r.hash_ids.len()).min().unwrap();
            for w in rs.windows(2) {
                assert_eq!(w[0].hash_ids[..min_len], w[1].hash_ids[..min_len]);
            }
        }
    }

    #[test]
    fn dataset_cache_ratios() {
        for (name, want_lo, want_hi) in
            [("arxiv", 0.0, 0.05), ("leval", 0.6, 0.95), ("sim32k", 0.3, 0.55)]
        {
            let trace = dataset(name, 500, 1.0, 7);
            let mut seen = std::collections::HashSet::new();
            let (mut hits, mut total) = (0u64, 0u64);
            for r in &trace {
                for &b in &r.hash_ids {
                    total += 1;
                    if !seen.insert(b) {
                        hits += 1;
                    }
                }
            }
            let rate = hits as f64 / total as f64;
            assert!(rate >= want_lo && rate <= want_hi, "{name}: {rate}");
        }
    }

    #[test]
    fn simulated_lengths_fixed() {
        let trace = dataset("sim64k", 100, 1.0, 3);
        assert!(trace.iter().all(|r| r.input_length == 65_536 && r.output_length == 512));
    }

    /// Largest request count in any `window` ms of the trace.
    fn peak_window_count(trace: &[TraceRecord], window: u64) -> usize {
        let ts: Vec<u64> = trace.iter().map(|r| r.timestamp).collect(); // sorted
        let mut lo = 0;
        let mut best = 0;
        for hi in 0..ts.len() {
            while ts[hi] - ts[lo] > window {
                lo += 1;
            }
            best = best.max(hi - lo + 1);
        }
        best
    }

    #[test]
    fn bursty_arrivals_concentrate_load() {
        let uniform = generate(&TraceGenConfig { n_requests: 4_000, seed: 9, ..Default::default() });
        let bursty = generate(&TraceGenConfig {
            n_requests: 4_000,
            seed: 9,
            burst_fraction: 0.7,
            n_bursts: 3,
            burst_width_ms: 10_000,
            ..Default::default()
        });
        assert_eq!(bursty.len(), 4_000);
        assert!(bursty.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
        let pu = peak_window_count(&uniform, 60_000);
        let pb = peak_window_count(&bursty, 60_000);
        assert!(
            pb > 2 * pu,
            "bursty peak {pb} must dwarf the uniform peak {pu}"
        );
    }

    #[test]
    fn burst_knob_off_is_bitwise_default() {
        // burst_fraction = 0.0 must not perturb the RNG stream: seeds and
        // calibration carry over unchanged.
        let a = generate(&TraceGenConfig { n_requests: 500, seed: 3, ..Default::default() });
        let b = generate(&TraceGenConfig {
            n_requests: 500,
            seed: 3,
            n_bursts: 99,          // ignored while burst_fraction == 0
            burst_width_ms: 1,     // ignored while burst_fraction == 0
            ..Default::default()
        });
        assert_eq!(a, b);
    }

    #[test]
    fn storm_knob_off_is_bitwise_default() {
        // storm_fraction = 0.0 must not perturb the RNG stream — the
        // golden-hash pin in tests/determinism.rs rides on this.
        let a = generate(&TraceGenConfig { n_requests: 500, seed: 3, ..Default::default() });
        let b = generate(&TraceGenConfig {
            n_requests: 500,
            seed: 3,
            storm_start_ms: 123_456, // ignored while storm_fraction == 0
            storm_width_ms: 1,       // ignored while storm_fraction == 0
            ..Default::default()
        });
        assert_eq!(a, b);
    }

    #[test]
    fn storm_concentrates_arrivals_into_the_window() {
        let cfg = TraceGenConfig {
            n_requests: 4_000,
            seed: 9,
            storm_fraction: 0.6,
            storm_start_ms: 1_200_000,
            storm_width_ms: 30_000,
            ..Default::default()
        };
        let storm = generate(&cfg);
        assert_eq!(storm.len(), 4_000);
        assert!(storm.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
        // First arrivals of sessions/one-shots land in the window; their
        // follow-up turns trail behind it, so count the window share
        // directly: it must dwarf the uniform expectation (width/duration
        // ≈ 0.8% of requests) without demanding every turn lands inside.
        let in_window = storm
            .iter()
            .filter(|r| {
                r.timestamp >= cfg.storm_start_ms
                    && r.timestamp < cfg.storm_start_ms + cfg.storm_width_ms
            })
            .count();
        assert!(
            in_window as f64 > 0.25 * storm.len() as f64,
            "storm window holds {in_window}/{} requests",
            storm.len()
        );
        // The spike is also the trace's load peak.
        let uniform =
            generate(&TraceGenConfig { n_requests: 4_000, seed: 9, ..Default::default() });
        let pu = peak_window_count(&uniform, 30_000);
        let ps = peak_window_count(&storm, 30_000);
        assert!(ps > 3 * pu, "storm peak {ps} must dwarf the uniform peak {pu}");
    }

    #[test]
    fn storm_stream_is_deterministic_and_distinct() {
        let cfg = TraceGenConfig {
            n_requests: 1_000,
            seed: 5,
            storm_fraction: 0.5,
            storm_start_ms: 600_000,
            storm_width_ms: 20_000,
            ..Default::default()
        };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b, "same (config, seed) must generate bit-for-bit the same trace");
        let plain = generate(&TraceGenConfig { n_requests: 1_000, seed: 5, ..Default::default() });
        assert_ne!(a, plain, "an active storm must change the arrival pattern");
    }

    #[test]
    fn session_rearrival_resends_prefix_after_long_gap() {
        let mk = |rearrival: f64| {
            generate(&TraceGenConfig {
                n_requests: 3_000,
                seed: 11,
                rearrival_fraction: rearrival,
                mean_rearrival_gap_ms: 500_000.0,
                ..Default::default()
            })
        };
        // Sessions that go quiet for > 300 s and then re-send their chain.
        let long_gap_resumes = |trace: &[TraceRecord]| {
            let mut by_first: HashMap<u64, Vec<u64>> = HashMap::new();
            for r in trace {
                if r.hash_ids[0] >= 1_000 {
                    by_first.entry(r.hash_ids[0]).or_default().push(r.timestamp);
                }
            }
            let mut n = 0;
            for ts in by_first.values() {
                let mut ts = ts.clone();
                ts.sort_unstable();
                if ts.windows(2).any(|w| w[1] - w[0] > 300_000) {
                    n += 1;
                }
            }
            n
        };
        let with = long_gap_resumes(&mk(0.6));
        let without = long_gap_resumes(&mk(0.0));
        assert!(
            with > without + 10,
            "re-arrival must create long-gap prefix reuse: {with} vs {without}"
        );
        // Re-arrived turns still extend the same chain (prefix property).
        let trace = mk(0.6);
        assert!(trace.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
    }
}
