//! `pallas_lint` — repo-specific static analysis for the deterministic
//! simulation core, dependency-free (its own token-level lexer, no
//! `syn`, runs fully offline).  Walks `rust/src/**` and enforces the
//! invariants DESIGN.md's "Static analysis & invariant enforcement"
//! section documents:
//!
//! - **no-std-hash** (R1): `std::collections::HashMap`/`HashSet` are
//!   banned outside `util::fasthash` and a short allowlist of cold
//!   modules — SipHash's per-process random seed would make iteration
//!   order (and anything derived from it) nondeterministic, and the
//!   hot path pays its hashing cost.
//! - **no-wallclock** (R2): `Instant`/`SystemTime` are banned in the
//!   simulation-side modules (`sim`, `conductor`, `costmodel`,
//!   `kvcache`, `resource`) — simulated time is the only clock there.
//! - **hot-no-alloc** (R3): a function annotated `lint: hot` (as a
//!   `//`-comment directive on the line(s) above its `fn`, attributes
//!   may intervene) must not contain allocating constructs:
//!   `Vec::new`, `vec![`, `.clone()`, `.collect()`, `.to_vec()`,
//!   `format!`, `Box::new`, `String::from`.  `.resize()` is
//!   deliberately *not* banned — growing a warmed scratch buffer in
//!   place is the idiom these functions use instead of allocating.
//! - **unordered-iter** (R4): iterating a `FastMap`/`FastSet` (via
//!   `.keys()`, `.values()`, `.iter()`, …) in `sim`, `conductor`, or
//!   `metrics` requires an explicit allow — map order is
//!   deterministic per build but arbitrary, so it must never reach an
//!   observable result without a re-sort.  Detection is a documented
//!   heuristic: bindings declared `name: FastMap<…>`/`FastSet<…>` are
//!   tracked by name and their order-exposing method calls flagged
//!   (direct `for x in &map` loops are not caught — keep those out of
//!   scoped modules or name the binding).
//! - **must-apply-delta** (R5): every `fn` whose return type mentions
//!   `TierDelta` must carry `#[must_use]` (the pool mutators feed the
//!   global prefix index; a dropped delta silently diverges it), and
//!   `sim`/`conductor` code must not discard a mutator's delta with
//!   `let _ =`.  The call-site half is a same-line heuristic — the
//!   compiler's `#[must_use]` is the exhaustive complement.
//!
//! Escape hatch: `lint: allow(rule) — reason` as a `//`-comment on the
//! violating line or the line directly above it.  The reason is
//! mandatory; an allow without one is itself a violation.  String
//! literals, comments, and `#[cfg(test)] mod` bodies are exempt from
//! all rules.
//!
//! Output: a human-readable line per violation, a machine-readable
//! `LINT_report.json` at the repo root, exit 1 on any violation (or
//! reason-less allow), exit 2 on I/O errors.

use std::fs;
use std::path::{Path, PathBuf};
use std::process;

use mooncake::util::json::{self, Value};

const RULES: [&str; 5] =
    ["no-std-hash", "no-wallclock", "hot-no-alloc", "unordered-iter", "must-apply-delta"];

/// R1 — files allowed to use std hash containers: offline analysis and
/// plumbing that never feeds the deterministic decision path, plus the
/// one module that wraps the containers behind a fixed hasher.
const R1_ALLOWLIST: [&str; 5] =
    ["util/fasthash.rs", "trace/stats.rs", "trace/gen.rs", "engine/mod.rs", "baseline/mod.rs"];

/// R2 — modules where simulated time is the only legal clock.
const R2_SCOPE: [&str; 5] = ["sim/", "conductor/", "costmodel/", "kvcache/", "resource/"];

/// R3 — allocating constructs banned inside `lint: hot` functions.
const FORBIDDEN_IN_HOT: [&str; 8] = [
    "Vec::new",
    "vec![",
    ".clone()",
    ".collect()",
    ".to_vec()",
    "format!",
    "Box::new",
    "String::from",
];

/// R4 — modules where map iteration order must not leak, and the
/// order-exposing methods that flag an iteration.
const R4_SCOPE: [&str; 3] = ["sim/", "conductor/", "metrics/"];
const R4_ITER_METHODS: [&str; 7] =
    ["keys", "values", "iter", "iter_mut", "values_mut", "drain", "retain"];

/// R5 — TierDelta-returning pool mutators whose result must reach the
/// prefix index (or at least not be pattern-discarded).
const R5_SCOPE: [&str; 2] = ["sim/", "conductor/"];
const R5_MUTATORS: [&str; 5] =
    ["admit_chain", "admit_block", "insert_replica", "demote_block", "demote_idle"];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

#[derive(Debug)]
struct AllowRec {
    line: usize,
    rule: String,
    reason: String,
}

#[derive(Debug, Default)]
struct FileResult {
    violations: Vec<(usize, &'static str, String)>,
    allows: Vec<AllowRec>,
    hot_fns: usize,
}

fn main() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src"));
    let mut files = Vec::new();
    if let Err(e) = walk(root, &mut files) {
        eprintln!("pallas_lint: cannot walk {}: {e}", root.display());
        process::exit(2);
    }

    let mut violations: Vec<Violation> = Vec::new();
    let mut allow_entries: Vec<Value> = Vec::new();
    let mut hot_fns = 0usize;
    for path in &files {
        let rel = path.strip_prefix(root).unwrap().to_string_lossy().replace('\\', "/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("pallas_lint: cannot read {}: {e}", path.display());
                process::exit(2);
            }
        };
        let res = analyze(&rel, &src);
        hot_fns += res.hot_fns;
        for (line, rule, msg) in res.violations {
            violations.push(Violation { file: rel.clone(), line, rule, msg });
        }
        for a in res.allows {
            allow_entries.push(json::obj(vec![
                ("file", Value::Str(rel.clone())),
                ("line", json::num(a.line as f64)),
                ("rule", Value::Str(a.rule)),
                ("reason", Value::Str(a.reason)),
            ]));
        }
    }

    let ok = violations.is_empty();
    let report = json::obj(vec![
        ("files_scanned", json::num(files.len() as f64)),
        ("hot_fns", json::num(hot_fns as f64)),
        ("rules", Value::Arr(RULES.iter().map(|r| Value::Str(r.to_string())).collect())),
        ("allows", Value::Arr(allow_entries.clone())),
        (
            "violations",
            Value::Arr(
                violations
                    .iter()
                    .map(|v| {
                        json::obj(vec![
                            ("file", Value::Str(v.file.clone())),
                            ("line", json::num(v.line as f64)),
                            ("rule", Value::Str(v.rule.to_string())),
                            ("msg", Value::Str(v.msg.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ok", Value::Bool(ok)),
    ]);
    let report_path = concat!(env!("CARGO_MANIFEST_DIR"), "/LINT_report.json");
    if let Err(e) = fs::write(report_path, json::to_string(&report) + "\n") {
        eprintln!("pallas_lint: cannot write {report_path}: {e}");
        process::exit(2);
    }

    for v in &violations {
        eprintln!("rust/src/{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    if !ok {
        eprintln!("pallas_lint: {} violation(s) across {} files", violations.len(), files.len());
        process::exit(1);
    }
    println!(
        "pallas_lint: {} files, {} hot fns, {} allows, 0 violations",
        files.len(),
        hot_fns,
        allow_entries.len()
    );
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Source with comments, string/char literals masked out (replaced by
/// spaces, line structure preserved), plus the `//`-comment texts by
/// 1-based line for directive parsing.
struct Lexed {
    code: String,
    comments: Vec<(usize, String)>,
}

fn strip(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut code = String::with_capacity(src.len());
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < cs.len() {
        let c = cs[i];
        let next = cs.get(i + 1).copied();
        match c {
            '\n' => {
                code.push('\n');
                line += 1;
                i += 1;
            }
            '/' if next == Some('/') => {
                let start = i + 2;
                let mut j = start;
                while j < cs.len() && cs[j] != '\n' {
                    j += 1;
                }
                comments.push((line, cs[start..j].iter().collect()));
                for _ in i..j {
                    code.push(' ');
                }
                i = j;
            }
            '/' if next == Some('*') => {
                let mut depth = 1u32;
                code.push(' ');
                code.push(' ');
                let mut j = i + 2;
                while j < cs.len() && depth > 0 {
                    if cs[j] == '*' && cs.get(j + 1).copied() == Some('/') {
                        depth -= 1;
                        code.push(' ');
                        code.push(' ');
                        j += 2;
                    } else if cs[j] == '/' && cs.get(j + 1).copied() == Some('*') {
                        depth += 1;
                        code.push(' ');
                        code.push(' ');
                        j += 2;
                    } else {
                        if cs[j] == '\n' {
                            code.push('\n');
                            line += 1;
                        } else {
                            code.push(' ');
                        }
                        j += 1;
                    }
                }
                i = j;
            }
            '"' => {
                code.push(' ');
                let mut j = i + 1;
                while j < cs.len() {
                    match cs[j] {
                        '\\' => {
                            code.push(' ');
                            j += 1;
                            if j < cs.len() {
                                if cs[j] == '\n' {
                                    code.push('\n');
                                    line += 1;
                                } else {
                                    code.push(' ');
                                }
                                j += 1;
                            }
                        }
                        '"' => {
                            code.push(' ');
                            j += 1;
                            break;
                        }
                        '\n' => {
                            code.push('\n');
                            line += 1;
                            j += 1;
                        }
                        _ => {
                            code.push(' ');
                            j += 1;
                        }
                    }
                }
                i = j;
            }
            'r' if (next == Some('"') || next == Some('#'))
                && !code.ends_with(|p: char| p.is_alphanumeric() || p == '_') =>
            {
                let mut j = i + 1;
                let mut hashes = 0usize;
                while cs.get(j).copied() == Some('#') {
                    hashes += 1;
                    j += 1;
                }
                if cs.get(j).copied() == Some('"') {
                    for _ in 0..hashes + 2 {
                        code.push(' ');
                    }
                    j += 1;
                    while j < cs.len() {
                        if cs[j] == '"' {
                            let mut k = 0usize;
                            while k < hashes && cs.get(j + 1 + k).copied() == Some('#') {
                                k += 1;
                            }
                            if k == hashes {
                                for _ in 0..hashes + 1 {
                                    code.push(' ');
                                }
                                j += 1 + hashes;
                                break;
                            }
                        }
                        if cs[j] == '\n' {
                            code.push('\n');
                            line += 1;
                        } else {
                            code.push(' ');
                        }
                        j += 1;
                    }
                    i = j;
                } else {
                    // raw identifier (r#type) — plain code
                    code.push('r');
                    i += 1;
                }
            }
            '\'' => {
                let n1 = cs.get(i + 1).copied();
                let n2 = cs.get(i + 2).copied();
                if n1 == Some('\\') {
                    // escaped char literal — scan to the closing quote
                    code.push(' ');
                    let mut j = i + 1;
                    while j < cs.len() && cs[j] != '\'' {
                        code.push(' ');
                        if cs[j] == '\\' && j + 1 < cs.len() {
                            code.push(' ');
                            j += 2;
                        } else {
                            j += 1;
                        }
                    }
                    if j < cs.len() {
                        code.push(' ');
                        j += 1;
                    }
                    i = j;
                } else if n2 == Some('\'') && n1 != Some('\'') {
                    // 'x' char literal (three chars)
                    code.push(' ');
                    code.push(' ');
                    code.push(' ');
                    i += 3;
                } else {
                    // lifetime or loop label
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    Lexed { code, comments }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All occurrences of `pat` in `code`, word-bounded on whichever ends of
/// the pattern are identifier characters.
fn find_word(code: &str, pat: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let pb = pat.as_bytes();
    let first_ident = is_ident(pb[0]);
    let last_ident = is_ident(*pb.last().unwrap());
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(pat) {
        let at = from + p;
        let end = at + pat.len();
        let before_ok = !first_ident || at == 0 || !is_ident(b[at - 1]);
        let after_ok = !last_ident || end >= b.len() || !is_ident(b[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

/// 1-based line of a byte offset, given the line-start offsets.
fn line_of(line_starts: &[usize], off: usize) -> usize {
    match line_starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

fn match_brace(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

fn match_paren(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'(' {
            depth += 1;
        } else if c == b')' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

fn analyze(rel: &str, src: &str) -> FileResult {
    let mut res = FileResult::default();
    let lexed = strip(src);
    let code = lexed.code.as_str();
    let b = code.as_bytes();
    let mut line_starts = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let lines: Vec<&str> = code.lines().collect();

    // Directives.
    let mut hots: Vec<usize> = Vec::new();
    let mut allows: Vec<AllowRec> = Vec::new();
    for (cline, text) in &lexed.comments {
        let t = text.trim();
        let Some(rest) = t.strip_prefix("lint:") else { continue };
        let rest = rest.trim();
        if rest == "hot" {
            hots.push(*cline);
            continue;
        }
        if let Some(inner) = rest.strip_prefix("allow(") {
            let Some(close) = inner.find(')') else {
                res.violations.push((
                    *cline,
                    "lint-directive",
                    "malformed lint allow — expected allow(rule)".to_string(),
                ));
                continue;
            };
            let rule = inner[..close].trim().to_string();
            if !RULES.contains(&rule.as_str()) {
                res.violations.push((
                    *cline,
                    "lint-directive",
                    format!("unknown rule '{rule}' in lint allow"),
                ));
                continue;
            }
            let reason = inner[close + 1..]
                .trim_start_matches(|c: char| {
                    c.is_whitespace() || c == '—' || c == '–' || c == '-' || c == ':'
                })
                .trim()
                .to_string();
            if reason.is_empty() {
                res.violations.push((
                    *cline,
                    "lint-directive",
                    format!("allow({rule}) without a reason — every escape hatch must say why"),
                ));
                continue;
            }
            allows.push(AllowRec { line: *cline, rule, reason });
            continue;
        }
        res.violations.push((
            *cline,
            "lint-directive",
            format!("unknown lint directive '{rest}'"),
        ));
    }

    // `#[cfg(test)] mod …` bodies are exempt from every rule.
    let mut test_regions: Vec<(usize, usize)> = Vec::new();
    for at in find_word(code, "#[cfg(test)]") {
        let Some(m) = find_word(&code[at..], "mod").first().map(|p| at + p) else { continue };
        let Some(open) = code[m..].find('{').map(|p| m + p) else { continue };
        let Some(close) = match_brace(b, open) else { continue };
        test_regions.push((line_of(&line_starts, at), line_of(&line_starts, close)));
    }
    let in_test =
        |line: usize| test_regions.iter().any(|&(lo, hi)| line >= lo && line <= hi);
    let allowed = |allows: &[AllowRec], rule: &str, line: usize| {
        allows.iter().any(|a| a.rule == rule && (a.line == line || a.line + 1 == line))
    };

    // R1 — no-std-hash.
    if !R1_ALLOWLIST.contains(&rel) {
        for pat in ["HashMap", "HashSet"] {
            for at in find_word(code, pat) {
                let line = line_of(&line_starts, at);
                if !in_test(line) && !allowed(&allows, "no-std-hash", line) {
                    res.violations.push((
                        line,
                        "no-std-hash",
                        format!(
                            "std {pat} is banned on the deterministic side — use \
                             util::fasthash::Fast* (or BTreeMap for cold ordered data)"
                        ),
                    ));
                }
            }
        }
    }

    // R2 — no-wallclock.
    if R2_SCOPE.iter().any(|p| rel.starts_with(p)) {
        for pat in ["Instant", "SystemTime"] {
            for at in find_word(code, pat) {
                let line = line_of(&line_starts, at);
                if !in_test(line) && !allowed(&allows, "no-wallclock", line) {
                    res.violations.push((
                        line,
                        "no-wallclock",
                        format!("{pat} in a simulation module — simulated time is the only clock"),
                    ));
                }
            }
        }
    }

    // R3 — hot-no-alloc over each `lint: hot` function body.
    let fn_tokens = find_word(code, "fn");
    for &hline in &hots {
        let from = line_starts.get(hline).copied().unwrap_or(code.len());
        let Some(&fnat) = fn_tokens.iter().find(|&&p| p >= from) else {
            res.violations.push((
                hline,
                "hot-no-alloc",
                "lint hot directive with no following fn".to_string(),
            ));
            continue;
        };
        let Some(open) = code[fnat..].find('{').map(|p| fnat + p) else {
            res.violations.push((
                hline,
                "hot-no-alloc",
                "lint hot directive on a bodyless fn".to_string(),
            ));
            continue;
        };
        let Some(close) = match_brace(b, open) else {
            res.violations.push((hline, "hot-no-alloc", "unbalanced braces".to_string()));
            continue;
        };
        res.hot_fns += 1;
        for pat in FORBIDDEN_IN_HOT {
            for p in find_word(code, pat) {
                if p > open && p < close {
                    let line = line_of(&line_starts, p);
                    if !allowed(&allows, "hot-no-alloc", line) {
                        res.violations.push((
                            line,
                            "hot-no-alloc",
                            format!("`{pat}` inside a hot function — reuse a warmed scratch"),
                        ));
                    }
                }
            }
        }
    }

    // R4 — unordered-iter: Fast* bindings whose order-exposing methods
    // are called.
    if R4_SCOPE.iter().any(|p| rel.starts_with(p)) {
        let mut names: Vec<String> = Vec::new();
        for pat in ["FastMap", "FastSet"] {
            for at in find_word(code, pat) {
                let mut i = at;
                while i > 0 && b[i - 1].is_ascii_whitespace() {
                    i -= 1;
                }
                if i == 0 || b[i - 1] != b':' {
                    continue;
                }
                i -= 1;
                if i > 0 && b[i - 1] == b':' {
                    continue; // a `::` path, not a binding
                }
                while i > 0 && b[i - 1].is_ascii_whitespace() {
                    i -= 1;
                }
                let end = i;
                while i > 0 && is_ident(b[i - 1]) {
                    i -= 1;
                }
                if i < end {
                    let name = code[i..end].to_string();
                    if !names.contains(&name) {
                        names.push(name);
                    }
                }
            }
        }
        for name in &names {
            for at in find_word(code, name) {
                let mut i = at + name.len();
                while i < b.len() && b[i].is_ascii_whitespace() {
                    i += 1;
                }
                if i >= b.len() || b[i] != b'.' {
                    continue;
                }
                i += 1;
                while i < b.len() && b[i].is_ascii_whitespace() {
                    i += 1;
                }
                let mstart = i;
                while i < b.len() && is_ident(b[i]) {
                    i += 1;
                }
                let method = &code[mstart..i];
                if i < b.len() && b[i] == b'(' && R4_ITER_METHODS.contains(&method) {
                    let line = line_of(&line_starts, mstart);
                    if !in_test(line) && !allowed(&allows, "unordered-iter", line) {
                        res.violations.push((
                            line,
                            "unordered-iter",
                            format!(
                                "{name}.{method}() iterates a Fast* container — map order \
                                 must not reach an observable result without a re-sort"
                            ),
                        ));
                    }
                }
            }
        }
    }

    // R5a — every TierDelta-returning fn carries #[must_use].
    for &fnat in &fn_tokens {
        let line = line_of(&line_starts, fnat);
        if in_test(line) {
            continue;
        }
        let Some(open) = code[fnat..].find('(').map(|p| fnat + p) else { continue };
        let Some(close) = match_paren(b, open) else { continue };
        let mut j = close + 1;
        while j < b.len() && b[j] != b'{' && b[j] != b';' {
            j += 1;
        }
        if !code[close + 1..j].contains("TierDelta") {
            continue;
        }
        if allowed(&allows, "must-apply-delta", line) {
            continue;
        }
        let li = line - 1;
        let mut ok = code[line_starts[li]..fnat].contains("#[must_use");
        let mut k = li;
        while !ok && k > 0 {
            k -= 1;
            let t = lines[k].trim();
            if t.contains("#[must_use") {
                ok = true;
            } else if !(t.is_empty() || t.starts_with("#[")) {
                break;
            }
        }
        if !ok {
            res.violations.push((
                line,
                "must-apply-delta",
                "fn returns a TierDelta without #[must_use] — a dropped delta silently \
                 diverges the prefix index"
                    .to_string(),
            ));
        }
    }

    // R5b — no pattern-discarded deltas where a live index may exist.
    if R5_SCOPE.iter().any(|p| rel.starts_with(p)) {
        for (i, lt) in lines.iter().enumerate() {
            let line = i + 1;
            if in_test(line) || allowed(&allows, "must-apply-delta", line) {
                continue;
            }
            if lt.contains("let _ =") && R5_MUTATORS.iter().any(|m| lt.contains(m)) {
                res.violations.push((
                    line,
                    "must-apply-delta",
                    "mutator delta discarded with `let _ =` — apply it to the prefix index"
                        .to_string(),
                ));
            }
        }
    }

    res.allows = allows;
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_masks_strings_comments_and_chars() {
        let src = "let a = \"Vec::new\"; // Vec::new\nlet b = 'x'; /* vec![ */ let c = 1;\n";
        let l = strip(src);
        assert!(!l.code.contains("Vec::new"));
        assert!(!l.code.contains("vec!["));
        assert!(l.code.contains("let a ="));
        assert!(l.code.contains("let c = 1;"));
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].0, 1);
        // Line structure is preserved through the masking.
        assert_eq!(l.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn strip_keeps_lifetimes_and_masks_raw_strings() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet r = r#\"HashMap\"#;\n";
        let l = strip(src);
        assert!(l.code.contains("<'a>"));
        assert!(!l.code.contains("HashMap"));
    }

    #[test]
    fn hot_fn_alloc_is_flagged_and_allow_excuses_it() {
        let bad = "// lint: hot\nfn f() {\n    let v = Vec::new();\n    drop(v);\n}\n";
        let r = analyze("sim/x.rs", bad);
        assert_eq!(r.hot_fns, 1);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].1, "hot-no-alloc");
        assert_eq!(r.violations[0].0, 3);

        let ok = "// lint: hot\nfn f() {\n    // lint: allow(hot-no-alloc) — test fixture\n    \
                  let v = Vec::new();\n    drop(v);\n}\n";
        let r = analyze("sim/x.rs", ok);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.allows.len(), 1);
    }

    #[test]
    fn reasonless_allow_is_a_violation() {
        let src = "// lint: allow(hot-no-alloc)\nfn f() {}\n";
        let r = analyze("sim/x.rs", src);
        assert_eq!(r.violations.len(), 1);
        assert!(r.violations[0].2.contains("without a reason"));
    }

    #[test]
    fn std_hash_and_wallclock_scopes() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        let r = analyze("kvcache/x.rs", src);
        let rules: Vec<&str> = r.violations.iter().map(|v| v.1).collect();
        assert!(rules.contains(&"no-std-hash"));
        assert!(rules.contains(&"no-wallclock"));
        // Outside both scopes (and on the R1 allowlist) the same source
        // is clean.
        let r = analyze("engine/mod.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn g() { \
                   let _ = HashMap::<u32, u32>::new(); }\n}\n";
        let r = analyze("sim/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn unordered_iter_follows_chains_across_lines() {
        let src = "struct S { heat: FastMap<u32, f64> }\nimpl S {\n    fn f(&self) -> usize {\n  \
                   self.heat\n            .keys()\n            .count()\n    }\n}\n";
        let r = analyze("conductor/x.rs", src);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].1, "unordered-iter");
        // Order-safe probes on the same binding are not flagged.
        let src = "struct S { heat: FastMap<u32, f64> }\nimpl S {\n    fn f(&self) -> bool { \
                   self.heat.contains_key(&1) }\n}\n";
        let r = analyze("conductor/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn tierdelta_fns_require_must_use() {
        let bad = "impl P {\n    pub fn admit(&mut self) -> TierDelta {\n        \
                   TierDelta::default()\n    }\n}\n";
        let r = analyze("kvcache/x.rs", bad);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].1, "must-apply-delta");

        let good = "impl P {\n    #[must_use = \"apply it\"]\n    pub fn admit(&mut self) -> \
                    TierDelta {\n        TierDelta::default()\n    }\n}\n";
        let r = analyze("kvcache/x.rs", good);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn discarded_delta_in_scope_is_flagged() {
        let src = "fn f(p: &mut CachePool) {\n    let _ = p.admit_chain(&[1], 0.0);\n}\n";
        let r = analyze("sim/x.rs", src);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].1, "must-apply-delta");
        // Out of scope (kvcache implements the mutators; only the
        // index-holding layers are checked) the same line is fine.
        let r = analyze("kvcache/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn word_boundaries_respected() {
        let src = "// lint: hot\nfn f() { let v = SmallVec::newish(); drop(v); }\n";
        let r = analyze("sim/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }
}
