//! Property-based tests (hand-rolled generators — proptest is not
//! available offline) over coordinator invariants: request conservation,
//! cache capacity bounds, prefix-chain consistency, JSON roundtrips, and
//! simulator determinism, across randomized configurations and traces.

use mooncake::config::{RejectionPolicy, SchedulingPolicy, SimConfig};
use mooncake::kvcache::{
    chain_hashes, BlockInterner, CachePool, DenseBlockId, EvictionPolicy, PolicyKind, PrefixIndex,
    ShardedPrefixIndex,
};
use mooncake::metrics::Outcome;
use mooncake::sim;
use mooncake::trace::gen::{self, TraceGenConfig};
use mooncake::trace::jsonl;
use mooncake::trace::{TraceRecord, BLOCK_TOKENS};
use mooncake::util::json;
use mooncake::util::rng::Rng;

fn random_trace(rng: &mut Rng, n: usize) -> Vec<TraceRecord> {
    let cfg = TraceGenConfig {
        n_requests: n,
        duration_ms: 300_000 + rng.below(1_200_000),
        seed: rng.next_u64(),
        mean_first_input: 1_000.0 + rng.f64() * 15_000.0,
        session_fraction: rng.f64(),
        mean_session_turns: 1.0 + rng.f64() * 5.0,
        ..Default::default()
    };
    gen::generate(&cfg)
}

fn random_sim_config(rng: &mut Rng) -> SimConfig {
    let scheds = [
        SchedulingPolicy::Random,
        SchedulingPolicy::LoadBalance,
        SchedulingPolicy::CacheAware,
        SchedulingPolicy::KvCacheCentric,
    ];
    let rejects = [
        RejectionPolicy::None,
        RejectionPolicy::Baseline,
        RejectionPolicy::Early,
        RejectionPolicy::Predictive,
    ];
    SimConfig {
        n_prefill: 1 + rng.below(6) as usize,
        n_decode: 1 + rng.below(6) as usize,
        scheduling: scheds[rng.below(4) as usize],
        rejection: rejects[rng.below(4) as usize],
        cache_capacity_blocks: if rng.f64() < 0.3 { Some(1 + rng.below(5_000) as usize) } else { None },
        seed: rng.next_u64(),
        ..Default::default()
    }
}

/// Property: every submitted request is accounted for exactly once, with
/// a consistent outcome.
#[test]
fn prop_request_conservation() {
    let mut rng = Rng::new(0xC0FFEE);
    for round in 0..8 {
        let n = 200 + rng.below(300) as usize;
        let trace = random_trace(&mut rng, n);
        let cfg = random_sim_config(&mut rng);
        let speedup = 1.0 + rng.f64() * 5.0;
        let res = sim::run(&cfg, &trace, speedup);
        assert_eq!(res.metrics.len(), trace.len(), "round {round}: {cfg:?}");
        for m in &res.metrics {
            match m.outcome {
                Outcome::Completed => {
                    assert!(m.ttft_ms.is_finite() && m.ttft_ms >= 0.0);
                    assert_eq!(m.generated, m.output_tokens);
                    assert!(m.finish >= m.arrival + m.ttft_ms - 1e-6);
                }
                _ => {
                    assert!(m.ttft_ms.is_nan());
                    assert_eq!(m.generated, 0);
                }
            }
        }
        // Block accounting: every block a scheduled request *needs* is
        // either reused or recomputed — needed is the hash chain capped
        // at the blocks covering the input (a chain may overhang a
        // non-block-aligned input; the overhang is neither).
        let scheduled_blocks: u64 = res
            .metrics
            .iter()
            .filter(|m| m.outcome != Outcome::RejectedAtArrival)
            .map(|m| {
                let r = &trace[m.id as usize];
                (r.hash_ids.len() as u64).min(r.input_length.div_ceil(BLOCK_TOKENS))
            })
            .sum();
        assert_eq!(
            res.conductor.reused_blocks + res.conductor.recomputed_blocks,
            scheduled_blocks,
            "round {round}"
        );
    }
}

/// Property: simulation is a pure function of (config, trace).
#[test]
fn prop_determinism() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..4 {
        let trace = random_trace(&mut rng, 150);
        let cfg = random_sim_config(&mut rng);
        let a = sim::run(&cfg, &trace, 2.0);
        let b = sim::run(&cfg, &trace, 2.0);
        assert_eq!(a.metrics.len(), b.metrics.len());
        for (x, y) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(x.outcome, y.outcome);
            assert!((x.ttft_ms.is_nan() && y.ttft_ms.is_nan()) || x.ttft_ms == y.ttft_ms);
            assert_eq!(x.finish, y.finish);
        }
        assert_eq!(a.transfer_bytes, b.transfer_bytes);
    }
}

/// Property (tentpole): for every resource-queue op, the read-only
/// `estimate_done`/`estimate_done_dur` probe returns **bit-for-bit** the
/// completion time the mutating `schedule`/`schedule_dur` then produces,
/// under arbitrary interleavings of op kinds, nodes, sizes, setup
/// latencies, and (non-decreasing) clock jumps — the contract that lets
/// Conductor's TTFT estimates and the simulator's execution share one
/// `BwQueue` without drifting.  A mirror of `busy_until` checks FIFO
/// semantics and `backlog_ms` along the way.
#[test]
fn prop_bwqueue_estimate_exactly_predicts_schedule() {
    use mooncake::resource::BwQueue;
    let mut rng = Rng::new(0xB10C5);
    for round in 0..20 {
        let n = 1 + rng.below(6) as usize;
        let bw = match rng.below(3) {
            0 => f64::INFINITY,
            1 => 3e9,
            _ => 1e8 + rng.f64() * 1e11,
        };
        let latency = if rng.below(2) == 0 { 0.0 } else { rng.f64() * 5.0 };
        let mut q = BwQueue::new(n, bw, latency);
        let mut free_at = vec![0.0f64; n];
        let mut now = 0.0f64;
        for step in 0..400 {
            if rng.below(3) == 0 {
                now += rng.f64() * 200.0;
            }
            let node = rng.below(n as u64) as usize;
            let bytes = rng.below(1 << 32);
            let (est, op) = if rng.below(4) == 0 {
                // A caller-computed-duration op (e.g. an NVMe write).
                let dur = rng.f64() * 100.0;
                (q.estimate_done_dur(node, now, dur), q.schedule_dur(node, now, dur, bytes))
            } else {
                let setup = if rng.below(2) == 0 { 0.0 } else { rng.f64() * 2.0 };
                (q.estimate_done(node, now, bytes, setup), q.schedule(node, now, bytes, setup))
            };
            assert_eq!(
                est.to_bits(),
                op.end.to_bits(),
                "round {round} step {step}: estimate must equal schedule"
            );
            // FIFO: the op starts exactly when the device frees (or now).
            assert_eq!(op.start.to_bits(), free_at[node].max(now).to_bits());
            assert!(op.end >= op.start);
            free_at[node] = op.end;
            let want_backlog = (free_at[node] - now).max(0.0);
            assert_eq!(q.backlog_ms(node, now).to_bits(), want_backlog.to_bits());
            assert_eq!(q.free_at(node).to_bits(), free_at[node].to_bits());
        }
    }
}

/// Property: eviction policies never exceed capacity and never lose a
/// block that wasn't evicted or removed.
#[test]
fn prop_eviction_capacity_and_accounting() {
    let mut rng = Rng::new(0xFEED);
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware] {
        for _ in 0..5 {
            let cap = 1 + rng.below(200) as usize;
            let mut p = EvictionPolicy::new(kind, Some(cap));
            let mut inserted = std::collections::HashSet::new();
            let mut evicted = std::collections::HashSet::new();
            for step in 0..3_000u64 {
                let b = rng.below(500) as DenseBlockId;
                match rng.below(10) {
                    0 => {
                        if p.remove(b) {
                            inserted.remove(&b);
                        }
                    }
                    1..=3 => {
                        p.touch(b, step as f64, rng.below(40) as usize);
                    }
                    _ => {
                        if let Some(e) = p.insert(b, step as f64, rng.below(40) as usize) {
                            evicted.insert(e);
                            inserted.remove(&e);
                        }
                        inserted.insert(b);
                    }
                }
                assert!(p.len() <= cap, "{kind:?}: {} > {cap}", p.len());
                // Everything we believe is inside must be inside.
                for &x in inserted.iter() {
                    assert!(p.contains(x), "{kind:?} lost block {x}");
                }
            }
        }
    }
}

/// Property: the tiered pool conserves blocks — every resident block
/// lives in exactly one tier, neither tier exceeds its capacity, and
/// counter accounting stays consistent — under random interleavings of
/// chain admission (with arbitrary reuse splits), per-block admission,
/// replica insertion, and explicit demotion.
#[test]
fn prop_tiered_pool_conservation() {
    let mut rng = Rng::new(0x71E2ED);
    for round in 0..12 {
        let kind = [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware][round % 3];
        let dram_cap = rng.below(60) as usize; // 0 = degenerate no-DRAM config
        let ssd_cap = rng.below(120) as usize; // 0 = SSD tier disabled
        let mut pool = CachePool::new(kind, Some(dram_cap), Some(ssd_cap));
        for step in 0..1_500u64 {
            let now = step as f64;
            match rng.below(8) {
                0 => {
                    let b = rng.below(300) as DenseBlockId;
                    let _ = pool.admit_block(b, rng.below(40) as usize, now);
                }
                1 => {
                    let chain: Vec<DenseBlockId> =
                        (0..1 + rng.below(10)).map(|_| rng.below(300) as DenseBlockId).collect();
                    let _ = pool.insert_replica(&chain, now);
                }
                2 => {
                    let _ = pool.demote_block(rng.below(300) as DenseBlockId, now);
                }
                _ => {
                    let len = 1 + rng.below(24) as u32;
                    let start = rng.below(280) as u32;
                    let chain: Vec<DenseBlockId> = (start..start + len).collect();
                    let reused = rng.below(len as u64 + 1) as usize;
                    let _ = pool.admit_chain_reusing(&chain, reused, now);
                }
            }
            // Capacity bounds per tier.
            assert!(pool.dram_len() <= dram_cap, "round {round}: DRAM over capacity");
            assert!(pool.ssd_len() <= ssd_cap, "round {round}: SSD over capacity");
            // Conservation: tiers are disjoint and partition the pool.
            let dram: std::collections::HashSet<DenseBlockId> = pool.iter_dram_blocks().collect();
            let ssd: std::collections::HashSet<DenseBlockId> = pool.iter_ssd_blocks().collect();
            assert!(dram.is_disjoint(&ssd), "round {round}: block in both tiers");
            assert_eq!(dram.len() + ssd.len(), pool.len());
            assert_eq!(pool.dram_len() + pool.ssd_len(), pool.len());
        }
        // Counter sanity: hits split cleanly and nothing was dropped
        // unless a finite tier actually overflowed.
        let s = pool.stats;
        assert_eq!(s.hits() + s.misses, s.accesses());
        if ssd_cap == 0 {
            assert_eq!(s.demotions, 0);
            assert_eq!(s.ssd_hits, 0);
            assert_eq!(s.promotions, 0);
        }
    }
}

/// Property: a demote + promote round trip preserves the prefix hash
/// chain — a chain pushed down to SSD by capacity pressure still prefix-
/// matches in full across tiers, and re-admitting it promotes every
/// block back without losing any.
#[test]
fn prop_demote_promote_round_trip_preserves_chain() {
    let mut rng = Rng::new(0x0DE11);
    for _ in 0..15 {
        let len = 4 + rng.below(40) as usize;
        // DRAM smaller than the chain forces demotion; SSD holds the rest
        // with slack so nothing is dropped.
        let dram_cap = 1 + rng.below(len as u64 - 1) as usize;
        let mut pool = CachePool::new(PolicyKind::Lru, Some(dram_cap), Some(2 * len));
        let chain: Vec<DenseBlockId> = (0..len as u32).map(|i| 1_000 + i * 7).collect();
        let _ = pool.admit_chain_reusing(&chain, 0, 0.0);
        // The tail fits in DRAM, the head demoted to SSD — but the whole
        // chain must still be resident and prefix-matchable.
        assert_eq!(pool.dram_len(), dram_cap);
        assert_eq!(pool.ssd_len(), len - dram_cap);
        let m = pool.prefix_match(&chain);
        assert_eq!(m.blocks, len, "demotion must not break the chain");
        assert_eq!(m.ssd_blocks, len - dram_cap);
        // Re-admit with full reuse: every SSD block promotes (an SSD hit),
        // every DRAM block touches, and the chain stays whole.
        let before = pool.stats;
        let _ = pool.admit_chain_reusing(&chain, len, 1.0);
        let s = pool.stats;
        assert_eq!(s.dram_hits + s.ssd_hits - (before.dram_hits + before.ssd_hits), len as u64);
        assert!(s.ssd_hits - before.ssd_hits >= (len - dram_cap) as u64);
        assert_eq!(s.dropped, 0, "round trip must not destroy blocks");
        assert_eq!(pool.prefix_match(&chain).blocks, len);
        assert_eq!(pool.len(), len);
    }
}

/// Property: the Conductor's global prefix index — maintained *only*
/// from the `TierDelta`s the pool mutators return — agrees with the
/// brute-force per-node `prefix_match` and with a full rebuild, after an
/// arbitrary interleaving of admit / evict / demote / promote / replica
/// / idle-sweep operations across every eviction policy.
#[test]
fn prop_prefix_index_agrees_with_per_node_scan() {
    let mut rng = Rng::new(0x1DE7);
    for round in 0..9 {
        let n_nodes = 1 + rng.below(6) as usize;
        let kind = [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LengthAware][round % 3];
        let dram_cap = 1 + rng.below(40) as usize;
        let ssd_cap = rng.below(80) as usize; // 0 = tier disabled
        let mut pools: Vec<CachePool> = (0..n_nodes)
            .map(|_| CachePool::new(kind, Some(dram_cap), Some(ssd_cap)))
            .collect();
        let mut idx = PrefixIndex::new(n_nodes);
        for step in 0..1_200u64 {
            let now = step as f64;
            let node = rng.below(n_nodes as u64) as usize;
            let delta = match rng.below(8) {
                0 => {
                    let b = rng.below(200) as DenseBlockId;
                    pools[node].admit_block(b, rng.below(30) as usize, now).1
                }
                1 => {
                    let chain: Vec<DenseBlockId> =
                        (0..1 + rng.below(8)).map(|_| rng.below(200) as DenseBlockId).collect();
                    pools[node].insert_replica(&chain, now)
                }
                2 => {
                    let b = rng.below(200) as DenseBlockId;
                    pools[node].demote_block(b, now).unwrap_or_default()
                }
                3 => pools[node].demote_idle(now, 1.0 + rng.f64() * 50.0),
                _ => {
                    let len = 1 + rng.below(16) as u32;
                    let start = rng.below(180) as u32;
                    let chain: Vec<DenseBlockId> = (start..start + len).collect();
                    let reused = rng.below(len as u64 + 1) as usize;
                    pools[node].admit_chain_reusing(&chain, reused, now)
                }
            };
            idx.apply(node, &delta);
            if step % 100 == 0 {
                assert!(
                    idx.equals_rebuild_of(pools.iter()),
                    "round {round} step {step}: incremental index != rebuild"
                );
            }
            // The one-walk match equals every node's own scan.
            let start = rng.below(180) as u32;
            let probe: Vec<DenseBlockId> = (start..start + 1 + rng.below(20) as u32).collect();
            let got = idx.best_prefix(&probe);
            for (n, pool) in pools.iter().enumerate() {
                assert_eq!(
                    got[n],
                    pool.prefix_match(&probe),
                    "round {round} step {step} node {n}"
                );
            }
        }
        assert!(idx.equals_rebuild_of(pools.iter()), "round {round}: final state diverged");
    }
}

/// Property: a pool's prefix match length never exceeds the chain length
/// and is monotone under chain extension.
#[test]
fn prop_prefix_match_monotone() {
    let mut rng = Rng::new(0xABCD);
    for _ in 0..20 {
        let mut pool = CachePool::new(PolicyKind::Lru, Some(1_000), Some(2_000));
        let chain: Vec<DenseBlockId> =
            (0..rng.range(1, 40)).map(|_| rng.below(10_000) as DenseBlockId).collect();
        let _ = pool.admit_chain(&chain, 0.0);
        let m1 = pool.prefix_match_blocks(&chain);
        assert!(m1 <= chain.len());
        let mut longer = chain.clone();
        longer.push(99_999_999);
        let m2 = pool.prefix_match_blocks(&longer);
        assert!(m2 >= m1.min(chain.len()));
        // Divergence at position k caps the match at k.
        if chain.len() > 2 {
            let mut diverged = chain.clone();
            diverged[1] = 77_777_777;
            assert!(pool.prefix_match_blocks(&diverged) <= 1);
        }
    }
}

/// Property: chain hashes are prefix-stable and divergence-propagating.
#[test]
fn prop_chain_hash_prefix_stability() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..30 {
        let n = rng.range(1, 2_000) as usize;
        let toks: Vec<u32> = (0..n).map(|_| rng.below(1 << 20) as u32).collect();
        let block = [16usize, 64, 512][rng.below(3) as usize];
        let h = chain_hashes(&toks, block);
        assert_eq!(h.len(), n.div_ceil(block));
        // A prefix of the tokens yields a prefix of the hashes (for the
        // full blocks it covers).
        let cut = rng.range(1, n as u64) as usize;
        let h2 = chain_hashes(&toks[..cut], block);
        let full = cut / block;
        assert_eq!(h[..full], h2[..full]);
    }
}

/// Property: JSONL roundtrip is the identity on generated traces.
#[test]
fn prop_jsonl_roundtrip_identity() {
    let mut rng = Rng::new(0xD00D);
    for _ in 0..5 {
        let trace = random_trace(&mut rng, 100);
        let path = std::env::temp_dir().join(format!("mc_prop_{}.jsonl", rng.next_u64()));
        jsonl::save(&path, &trace).unwrap();
        let loaded = jsonl::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace.len(), loaded.len());
        let mut sorted = trace.clone();
        sorted.sort_by_key(|r| r.timestamp);
        // Loader sorts by timestamp; compare multisets via sorted order.
        for (a, b) in sorted.iter().zip(&loaded) {
            assert_eq!(a.timestamp, b.timestamp);
        }
    }
}

/// Property: arbitrary JSON values survive serialize -> parse.
#[test]
fn prop_json_roundtrip_fuzz() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.f64() < 0.5),
            2 => json::Value::Num((rng.below(1 << 30) as f64) - (1 << 29) as f64),
            3 => json::Value::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => json::Value::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => json::Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0xFACE);
    for _ in 0..200 {
        let v = random_value(&mut rng, 3);
        let s = json::to_string(&v);
        let back = json::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(v, back, "roundtrip failed for {s}");
    }
}

/// Property (tentpole): interning is a stable bijection onto a dense
/// prefix of u32 — over arbitrary hash streams (duplicates, re-arrivals,
/// adversarial values), every hash keeps one id forever, distinct hashes
/// never share an id, and ids are exactly `0..n` in first-appearance
/// order.
#[test]
fn prop_interner_round_trips_arbitrary_hash_streams() {
    let mut rng = Rng::new(0x1472);
    for round in 0..10 {
        let mut interner = BlockInterner::new();
        let mut seen: std::collections::HashMap<u64, DenseBlockId> =
            std::collections::HashMap::new();
        for step in 0..5_000u64 {
            // Mix of clustered ids (heavy re-interning) and raw 64-bit
            // hashes (the trace-realistic case).
            let h = match rng.below(3) {
                0 => rng.below(200),
                1 => 0xdead_beef_0000_0000 | rng.below(500),
                _ => rng.next_u64(),
            };
            let id = interner.intern(h);
            match seen.get(&h) {
                Some(&prev) => assert_eq!(id, prev, "round {round} step {step}: id moved"),
                None => {
                    // A fresh hash gets the next dense id.
                    assert_eq!(id as usize, seen.len(), "round {round} step {step}");
                    seen.insert(h, id);
                }
            }
            assert_eq!(interner.lookup(h), Some(id));
            assert_eq!(interner.len(), seen.len());
        }
        // Injective by construction: as many distinct ids as hashes.
        let ids: std::collections::HashSet<DenseBlockId> = seen.values().copied().collect();
        assert_eq!(ids.len(), seen.len(), "round {round}: id collision");
    }
}

/// One recycle epoch against the `Sim`'s liveness rule (pool-tier
/// residency), with every invariant the epoch must preserve asserted:
/// live bindings stable, freed ids nowhere resident, injectivity, and a
/// shadow hash→id map that matches the interner exactly afterwards.
fn recycle_and_check(
    interner: &mut BlockInterner,
    pools: &[CachePool],
    idx: &PrefixIndex,
    binding: &mut std::collections::HashMap<u64, DenseBlockId>,
    tag: &str,
) {
    let mut live = vec![0u64; interner.id_space().div_ceil(64)];
    for pool in pools {
        for b in pool.iter_blocks() {
            live[b as usize / 64] |= 1 << (b as usize % 64);
        }
    }
    let live_bit = |id: DenseBlockId| (live[id as usize / 64] >> (id as usize % 64)) & 1 != 0;
    let live_pairs: Vec<(u64, DenseBlockId)> =
        binding.iter().map(|(&h, &id)| (h, id)).filter(|&(_, id)| live_bit(id)).collect();
    let allocated_before: Vec<DenseBlockId> = (0..interner.id_space() as DenseBlockId)
        .filter(|&id| interner.is_allocated(id))
        .collect();
    let space_before = interner.id_space();
    let freed = interner.recycle_epoch(&live);

    // Live blocks keep their exact hash -> id binding across the epoch.
    for &(h, id) in &live_pairs {
        assert_eq!(interner.lookup(h), Some(id), "{tag}: live binding moved");
        assert!(interner.is_allocated(id), "{tag}: live id {id} deallocated");
    }
    // Every freed id was resident in no pool tier and held in no
    // PrefixIndex slot at recycle time.
    let mut n_freed = 0usize;
    for &id in &allocated_before {
        if interner.is_allocated(id) {
            continue;
        }
        n_freed += 1;
        assert!(!live_bit(id), "{tag}: freed live id {id}");
        assert!(idx.holders(id).is_empty(), "{tag}: freed id {id} still indexed");
        for (n, pool) in pools.iter().enumerate() {
            assert!(!pool.contains(id), "{tag}: freed id {id} resident in pool {n}");
        }
    }
    assert_eq!(n_freed, freed, "{tag}: freed-count drift");
    assert_eq!(interner.id_space(), space_before, "{tag}: recycling must not grow the space");
    // Injectivity survives: exactly one allocated id per interned hash.
    let allocated_after = (0..interner.id_space() as DenseBlockId)
        .filter(|&id| interner.is_allocated(id))
        .count();
    assert_eq!(allocated_after, interner.len(), "{tag}: allocation probe drift");
    // Dead hashes really are un-interned: the shadow map, pruned to
    // still-valid bindings, is the interner's map exactly.
    binding.retain(|&h, &mut id| interner.lookup(h) == Some(id));
    assert_eq!(binding.len(), interner.len(), "{tag}: shadow map drift");
}

/// Property (tentpole): epoch recycling preserves the dense bijection
/// for live (pool-resident) blocks and only frees ids that no pool tier
/// and no `PrefixIndex` slot still holds; freed ids are reused without
/// growing the id space.  Extends
/// `prop_interner_round_trips_arbitrary_hash_streams` across epochs.
#[test]
fn prop_epoch_recycling_keeps_live_bijection_and_frees_only_dead_ids() {
    let mut rng = Rng::new(0xEC1C7E);
    for round in 0..6 {
        let n_nodes = 1 + rng.below(4) as usize;
        let mut interner = BlockInterner::new();
        let mut pools: Vec<CachePool> =
            (0..n_nodes).map(|_| CachePool::new(PolicyKind::Lru, Some(24), Some(32))).collect();
        let mut idx = PrefixIndex::new(n_nodes);
        // Shadow of the latest hash -> id assignment per hash.
        let mut binding: std::collections::HashMap<u64, DenseBlockId> =
            std::collections::HashMap::new();
        let mut next_hash: u64 = 1;
        for step in 0..1_500u64 {
            let now = step as f64;
            let node = rng.below(n_nodes as u64) as usize;
            let n_blocks = 1 + rng.below(6);
            let chain: Vec<DenseBlockId> = (0..n_blocks)
                .map(|_| {
                    // Mostly fresh hashes (churn), some re-arrivals.
                    let h = if rng.below(4) == 0 && next_hash > 1 {
                        1 + rng.below(next_hash - 1)
                    } else {
                        next_hash += 1;
                        next_hash - 1
                    };
                    let id = interner.intern(h);
                    binding.insert(h, id);
                    id
                })
                .collect();
            idx.apply(node, &pools[node].admit_chain_reusing(&chain, 0, now));
            if rng.below(4) == 0 {
                idx.apply(node, &pools[node].demote_idle(now, 1.0 + rng.f64() * 30.0));
            }
            if step % 250 == 249 {
                let tag = format!("round {round} step {step}");
                recycle_and_check(&mut interner, &pools, &idx, &mut binding, &tag);
            }
        }
        assert!(interner.epochs() >= 6, "round {round}: epochs must have run");
        assert!(interner.freed_total() > 0, "round {round}: churn must free ids");
    }
}

/// Property (tentpole): the width-adaptive residency representation is
/// invisible — a width-1 (≤64 nodes), width-2, and width-4 `PrefixIndex`
/// all agree with `equals_rebuild_of` and with every node's own
/// `prefix_match_with` (match, SSD-run summary, *and* SSD positions)
/// under arbitrary op interleavings.
#[test]
fn prop_prefix_index_widths_agree_with_scan() {
    use mooncake::kvcache::SsdPositions;
    let mut rng = Rng::new(0x51D7);
    for &n_nodes in &[3usize, 70, 200] {
        let width = n_nodes.div_ceil(64);
        let mut pools: Vec<CachePool> =
            (0..n_nodes).map(|_| CachePool::new(PolicyKind::Lru, Some(24), Some(40))).collect();
        let mut idx = PrefixIndex::new(n_nodes);
        assert_eq!(idx.n_words(), width);
        let mut out = Vec::new();
        let mut pos = SsdPositions::default();
        let mut scan_pos = Vec::new();
        for step in 0..400u64 {
            let now = step as f64;
            let node = rng.below(n_nodes as u64) as usize;
            let delta = match rng.below(6) {
                0 => {
                    let chain: Vec<DenseBlockId> =
                        (0..1 + rng.below(8)).map(|_| rng.below(150) as DenseBlockId).collect();
                    pools[node].insert_replica(&chain, now)
                }
                1 => {
                    let b = rng.below(150) as DenseBlockId;
                    pools[node].demote_block(b, now).unwrap_or_default()
                }
                2 => pools[node].demote_idle(now, 1.0 + rng.f64() * 40.0),
                _ => {
                    let len = 1 + rng.below(12) as u32;
                    let start = rng.below(130) as u32;
                    let chain: Vec<DenseBlockId> = (start..start + len).collect();
                    let reused = rng.below(len as u64 + 1) as usize;
                    pools[node].admit_chain_reusing(&chain, reused, now)
                }
            };
            idx.apply(node, &delta);
            let start = rng.below(130) as u32;
            let probe: Vec<DenseBlockId> = (start..start + 1 + rng.below(16) as u32).collect();
            idx.best_prefix_into(&probe, &mut out, &mut pos);
            for (n, pool) in pools.iter().enumerate() {
                let want = pool.prefix_match_with(&probe, &mut scan_pos);
                assert_eq!(out[n], want, "width {width} step {step} node {n}");
                assert_eq!(
                    pos.node(n),
                    &scan_pos[..],
                    "width {width} step {step} node {n}: SSD positions"
                );
            }
            if step % 100 == 0 {
                assert!(idx.equals_rebuild_of(pools.iter()), "width {width} step {step}");
            }
        }
        assert!(idx.equals_rebuild_of(pools.iter()), "width {width}: final state");
    }
}

/// Property (tentpole, ISSUE 8): the sharded index is observationally
/// identical to the monolithic one — over arbitrary interleavings of
/// admit / demote / replica / idle-sweep ops at cluster widths from a
/// single node to 1024 (one shard, an exactly-full shard, a one-node
/// overflow shard, and four full shards):
///
/// * `best_prefix_into` (matches, SSD positions) equals every node's own
///   `prefix_match_with`, at every worker count — the parallel walk may
///   not perturb a single bit;
/// * for ≤ 256 nodes it is also bit-for-bit the monolithic
///   `PrefixIndex` fed the identical deltas;
/// * `holders` / `tier_on` agree with the ground-truth pools;
/// * every shard survives `equals_rebuild_of`.
#[test]
fn prop_sharded_index_agrees_with_monolithic() {
    use mooncake::kvcache::SsdPositions;
    let mut rng = Rng::new(0x5AADED);
    for &n_nodes in &[1usize, 3, 255, 256, 257, 300, 1024] {
        // Larger clusters get fewer steps: each probe cross-checks every
        // node, so the work per step is already O(n_nodes).
        let steps = if n_nodes > 300 { 60 } else { 250 };
        let mut pools: Vec<CachePool> =
            (0..n_nodes).map(|_| CachePool::new(PolicyKind::Lru, Some(24), Some(40))).collect();
        let mut sharded = ShardedPrefixIndex::new(n_nodes);
        assert_eq!(sharded.n_shards(), n_nodes.div_ceil(256));
        let mut mono = (n_nodes <= 256).then(|| PrefixIndex::new(n_nodes));
        let mut out = Vec::new();
        let mut pos = SsdPositions::default();
        let mut shard_pos: Vec<SsdPositions> = Vec::new();
        let mut mono_out = Vec::new();
        let mut mono_pos = SsdPositions::default();
        let mut scan_pos = Vec::new();
        for step in 0..steps {
            let now = step as f64;
            // A few mutations per probe, spread over random nodes (with
            // some clustering so shard-boundary nodes see real traffic).
            for _ in 0..4 {
                let node = match rng.below(4) {
                    0 if n_nodes > 2 => n_nodes - 1 - rng.below(2) as usize,
                    _ => rng.below(n_nodes as u64) as usize,
                };
                let delta = match rng.below(6) {
                    0 => {
                        let chain: Vec<DenseBlockId> = (0..1 + rng.below(8))
                            .map(|_| rng.below(150) as DenseBlockId)
                            .collect();
                        pools[node].insert_replica(&chain, now)
                    }
                    1 => {
                        let b = rng.below(150) as DenseBlockId;
                        pools[node].demote_block(b, now).unwrap_or_default()
                    }
                    2 => pools[node].demote_idle(now, 1.0 + rng.f64() * 40.0),
                    _ => {
                        let len = 1 + rng.below(12) as u32;
                        let start = rng.below(130) as u32;
                        let chain: Vec<DenseBlockId> = (start..start + len).collect();
                        let reused = rng.below(len as u64 + 1) as usize;
                        pools[node].admit_chain_reusing(&chain, reused, now)
                    }
                };
                sharded.apply(node, &delta);
                if let Some(m) = mono.as_mut() {
                    m.apply(node, &delta);
                }
            }
            let start = rng.below(130) as u32;
            let probe: Vec<DenseBlockId> = (start..start + 1 + rng.below(16) as u32).collect();
            let workers = [1usize, 2, 3, 8][step % 4];
            sharded.best_prefix_into(&probe, &mut out, &mut pos, &mut shard_pos, workers);
            assert_eq!(out.len(), n_nodes);
            for (n, pool) in pools.iter().enumerate() {
                let want = pool.prefix_match_with(&probe, &mut scan_pos);
                assert_eq!(out[n], want, "{n_nodes} nodes, {workers} workers, node {n}");
                assert_eq!(
                    pos.node(n),
                    &scan_pos[..],
                    "{n_nodes} nodes, {workers} workers, node {n}: SSD positions"
                );
            }
            if let Some(m) = &mono {
                m.best_prefix_into(&probe, &mut mono_out, &mut mono_pos);
                assert_eq!(out, mono_out, "{n_nodes} nodes: sharded != monolithic");
                for n in 0..n_nodes {
                    assert_eq!(pos.node(n), mono_pos.node(n), "{n_nodes} nodes, node {n}");
                }
            }
            // Holders and tier_on against the ground-truth pools.
            let b = rng.below(150) as DenseBlockId;
            let want_holders: Vec<usize> = pools
                .iter()
                .enumerate()
                .filter(|(_, p)| p.contains(b))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(sharded.holders(b), want_holders, "{n_nodes} nodes: holders of {b}");
            for _ in 0..8 {
                let n = rng.below(n_nodes as u64) as usize;
                assert_eq!(
                    sharded.tier_on(n, b),
                    pools[n].tier_of(b),
                    "{n_nodes} nodes: tier_on({n}, {b})"
                );
            }
            if step % 50 == 0 {
                assert!(sharded.equals_rebuild_of(pools.iter()), "{n_nodes} nodes, step {step}");
            }
        }
        assert!(sharded.equals_rebuild_of(pools.iter()), "{n_nodes} nodes: final state");
    }
}

/// Property (ISSUE 9): the hybrid split chosen by `hybrid_split_scan` is
/// the *first* global argmin of the priced completion — so no other
/// split, in particular neither neighbor, strictly beats it — and the
/// number of staged blocks is monotone nonincreasing in the NVMe
/// backlog: the busier the device, the more of the SSD tail Algorithm
/// 1's fourth branch recomputes instead of loading.
#[test]
fn prop_hybrid_split_is_locally_optimal_and_monotone_in_backlog() {
    use mooncake::costmodel;
    use mooncake::model::PerfModel;
    use mooncake::prefill::PrefillPool;
    use mooncake::resource::Resources;

    let cfg = SimConfig { n_prefill: 1, n_decode: 1, ..Default::default() };
    let perf = PerfModel::paper();
    let prefill = PrefillPool::new(&cfg);
    let group = [0usize];
    let mut rng = Rng::new(0x4B81D);
    for round in 0..40 {
        // A matched chain of `m` blocks whose DRAM head covers
        // `dram_prefix` of them; the SSD tail starts at `dram_prefix`
        // and sits at random ascending positions (DRAM-resident blocks
        // may be interleaved between them).
        let m = 2 + rng.below(48) as usize;
        let dram_prefix = rng.below(m as u64 - 1) as u32;
        let mut positions: Vec<u32> = vec![dram_prefix];
        loop {
            let next = *positions.last().unwrap() + 1 + rng.below(4) as u32;
            if next as usize >= m {
                break;
            }
            positions.push(next);
        }
        let total_tokens = m as u64 * BLOCK_TOKENS + 1 + rng.below(4_096);
        let mut prev_j: Option<usize> = None;
        for backlog_step in 0..6u64 {
            // A fresh device with `backlog_step` × ~500 ms of reads
            // queued in front of any staging the split would schedule.
            let mut res = Resources::new(&cfg, &perf);
            if backlog_step > 0 {
                let _ = res.nvme.schedule(0, 0.0, backlog_step * 1_500_000_000, 0.0);
            }
            let price = |k: usize, j: usize| {
                let prefix_tokens = k as u64 * BLOCK_TOKENS;
                let n_new = total_tokens - prefix_tokens;
                let ssd_tokens = (j as u64 * BLOCK_TOKENS).min(prefix_tokens);
                costmodel::estimate_prefill_hybrid(
                    &perf,
                    &cfg,
                    &prefill,
                    &res,
                    &group,
                    n_new,
                    prefix_tokens,
                    ssd_tokens,
                    0.0,
                )
            };
            let scan = costmodel::hybrid_split_scan(m, &positions, |k, j| price(k, j));
            let (k, j, est) = scan.expect("the SSD tail is non-empty");
            assert_eq!(k, if j < positions.len() { positions[j] as usize } else { m });
            for jj in 1..=positions.len() {
                let kk = if jj < positions.len() { positions[jj] as usize } else { m };
                let alt = price(kk, jj);
                assert!(
                    alt.end >= est.end,
                    "round {round} backlog {backlog_step}: split {jj} beats chosen {j}"
                );
                if jj < j {
                    assert!(alt.end > est.end, "round {round}: {j} must be the first argmin");
                }
            }
            // Monotone in backlog: a busier NVMe never stages *more*.
            if let Some(p) = prev_j {
                assert!(
                    j <= p,
                    "round {round} backlog {backlog_step}: staged blocks grew {p} -> {j}"
                );
            }
            prev_j = Some(j);
        }
    }
}
