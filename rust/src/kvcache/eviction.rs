//! Block eviction policies from §4.2 / Table 1: LRU, LFU, and
//! LengthAwareCache ("similar to LFU but prioritizing eviction of cache
//! blocks occurring later in requests").
//!
//! All three share one implementation: a fast-hashed map of block
//! metadata plus a `BTreeSet` ordered by a policy-specific composite
//! key, giving O(log n) insert/touch/evict.  Keys are interned
//! [`DenseBlockId`]s — membership probes are the innermost loop of every
//! prefix match, so they use the Fx hasher over 4-byte ids rather than
//! SipHash over trace hashes.
//!
//! **Unbounded tiers skip the order set entirely.**  The `BTreeSet` is
//! only ever *read* by `evict_entry`, which is only reachable when a
//! capacity bound exists — so with `capacity: None` every
//! touch/insert/remove skips the tree's node churn.  That keeps the
//! default (uncapped) configuration's admission hit path free of both
//! O(log n) maintenance and the BTree's split/merge heap traffic, which
//! is what lets the accept path audit to zero allocations.

use std::collections::BTreeSet;

use crate::kvcache::intern::DenseBlockId;
use crate::util::fasthash::FastMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Lru,
    Lfu,
    LengthAware,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Lru => "LRUCache",
            PolicyKind::Lfu => "LFUCache",
            PolicyKind::LengthAware => "LengthAwareCache",
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Meta {
    /// Monotonic use stamp (recency).
    stamp: u64,
    /// Access count (frequency).
    freq: u64,
    /// Most recent block index within a request (position).
    pos: usize,
    /// Wall-clock time of the last touch/insert (ms) — the idleness
    /// signal proactive background demotion sweeps on.
    last_used_ms: f64,
}

/// Composite eviction key; the BTreeSet's *first* element is the next
/// eviction victim.
type Key = (u64, u64, u64, DenseBlockId);

#[derive(Debug)]
pub struct EvictionPolicy {
    kind: PolicyKind,
    capacity: Option<usize>,
    entries: FastMap<DenseBlockId, Meta>,
    order: BTreeSet<Key>,
    tick: u64,
    pub evictions: u64,
}

impl EvictionPolicy {
    pub fn new(kind: PolicyKind, capacity: Option<usize>) -> Self {
        EvictionPolicy {
            kind,
            capacity,
            entries: FastMap::default(),
            order: BTreeSet::new(),
            tick: 0,
            evictions: 0,
        }
    }

    fn key(&self, b: DenseBlockId, m: &Meta) -> Key {
        match self.kind {
            // Oldest stamp first.
            PolicyKind::Lru => (m.stamp, 0, 0, b),
            // Lowest frequency first, ties by oldest stamp.
            PolicyKind::Lfu => (m.freq, m.stamp, 0, b),
            // Deepest request position first (late blocks = cold tails of
            // long documents), ties by frequency then stamp.
            PolicyKind::LengthAware => (u64::MAX - m.pos as u64, m.freq, m.stamp, b),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Configured capacity (None = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Whether an insertion of a new block would require an eviction.
    pub fn at_capacity(&self) -> bool {
        matches!(self.capacity, Some(cap) if self.entries.len() >= cap)
    }

    pub fn contains(&self, b: DenseBlockId) -> bool {
        self.entries.contains_key(&b)
    }

    /// Last recorded request position of a resident block (LengthAware's
    /// eviction key) — lets a tiered caller demote with metadata intact.
    pub fn pos_of(&self, b: DenseBlockId) -> Option<usize> {
        self.entries.get(&b).map(|m| m.pos)
    }

    /// Blocks whose last touch/insert is at least `idle_ms` before `now`
    /// — the candidate set for proactive background demotion.  Sorted by
    /// id so sweeps are deterministic despite HashMap iteration order.
    pub fn idle_blocks(&self, now_ms: f64, idle_ms: f64) -> Vec<DenseBlockId> {
        let mut v: Vec<DenseBlockId> = self
            .entries
            .iter()
            .filter(|(_, m)| now_ms - m.last_used_ms >= idle_ms)
            .map(|(&b, _)| b)
            .collect();
        v.sort_unstable();
        v
    }

    /// Whether the eviction-order set is maintained at all: an unbounded
    /// tier never evicts, so it never pays the BTree churn.
    #[inline]
    fn ordered(&self) -> bool {
        self.capacity.is_some()
    }

    /// Record a hit: bump recency/frequency/position metadata.
    // lint: hot
    pub fn touch(&mut self, b: DenseBlockId, now_ms: f64, pos: usize) {
        self.tick += 1;
        if let Some(m) = self.entries.get(&b).copied() {
            let m2 = Meta { stamp: self.tick, freq: m.freq + 1, pos, last_used_ms: now_ms };
            if self.ordered() {
                self.order.remove(&self.key(b, &m));
                self.order.insert(self.key(b, &m2));
            }
            self.entries.insert(b, m2);
        }
    }

    /// Insert a block (miss path), evicting if at capacity.  Returns the
    /// evicted block, if any.  The victim is chosen among *existing*
    /// entries before insertion, so a fresh block never evicts itself
    /// (the standard guard against LFU's new-entry starvation).
    pub fn insert(&mut self, b: DenseBlockId, now_ms: f64, pos: usize) -> Option<DenseBlockId> {
        if self.contains(b) {
            self.touch(b, now_ms, pos);
            return None;
        }
        let mut evicted = None;
        if let Some(cap) = self.capacity {
            if self.entries.len() >= cap {
                evicted = self.evict();
            }
        }
        self.tick += 1;
        let m = Meta { stamp: self.tick, freq: 1, pos, last_used_ms: now_ms };
        self.entries.insert(b, m);
        if self.ordered() {
            self.order.insert(self.key(b, &m));
        }
        evicted
    }

    /// Evict the policy's victim.
    pub fn evict(&mut self) -> Option<DenseBlockId> {
        self.evict_entry().map(|(b, _)| b)
    }

    /// Evict the policy's victim, returning `(block, last request
    /// position)` so a tiered caller can demote it with its position
    /// metadata intact (LengthAwareCache keys on position).
    pub fn evict_entry(&mut self) -> Option<(DenseBlockId, usize)> {
        let victim = self.order.iter().next().copied()?;
        self.order.remove(&victim);
        let b = victim.3;
        let meta = self.entries.remove(&b);
        self.evictions += 1;
        Some((b, meta.map(|m| m.pos).unwrap_or(0)))
    }

    /// Remove a specific block (e.g. swapped out by Conductor).
    pub fn remove(&mut self, b: DenseBlockId) -> bool {
        if let Some(m) = self.entries.remove(&b) {
            if self.ordered() {
                self.order.remove(&self.key(b, &m));
            }
            true
        } else {
            false
        }
    }

    pub fn iter_blocks(&self) -> impl Iterator<Item = DenseBlockId> + '_ {
        self.entries.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_oldest() {
        let mut p = EvictionPolicy::new(PolicyKind::Lru, Some(2));
        p.insert(1, 0.0, 0);
        p.insert(2, 1.0, 0);
        p.touch(1, 2.0, 0); // 1 is now newer than 2
        let evicted = p.insert(3, 3.0, 0);
        assert_eq!(evicted, Some(2));
        assert!(p.contains(1) && p.contains(3));
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut p = EvictionPolicy::new(PolicyKind::Lfu, Some(2));
        p.insert(1, 0.0, 0);
        p.insert(2, 1.0, 0);
        p.touch(1, 2.0, 0);
        p.touch(1, 3.0, 0);
        p.touch(2, 4.0, 0); // freq: 1->3, 2->2
        let evicted = p.insert(3, 5.0, 0);
        assert_eq!(evicted, Some(2));
    }

    #[test]
    fn length_aware_evicts_deepest_position() {
        let mut p = EvictionPolicy::new(PolicyKind::LengthAware, Some(2));
        p.insert(1, 0.0, 0); // early block (system prompt)
        p.insert(2, 1.0, 30); // deep block of a long request
        p.touch(2, 2.0, 30);
        p.touch(2, 3.0, 30); // even if block 2 is more frequent...
        let evicted = p.insert(3, 4.0, 1);
        assert_eq!(evicted, Some(2)); // ...position dominates
    }

    #[test]
    fn insert_existing_is_touch() {
        let mut p = EvictionPolicy::new(PolicyKind::Lru, Some(2));
        p.insert(1, 0.0, 0);
        p.insert(2, 1.0, 0);
        assert_eq!(p.insert(1, 2.0, 0), None); // touch, no eviction
        assert_eq!(p.len(), 2);
        let evicted = p.insert(3, 3.0, 0);
        assert_eq!(evicted, Some(2));
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut p = EvictionPolicy::new(PolicyKind::Lfu, Some(10));
        for i in 0..100 {
            p.insert(i, i as f64, (i % 7) as usize);
            assert!(p.len() <= 10);
        }
        assert_eq!(p.evictions, 90);
    }

    #[test]
    fn remove_unknown_is_false() {
        let mut p = EvictionPolicy::new(PolicyKind::Lru, None);
        assert!(!p.remove(9));
        p.insert(9, 0.0, 0);
        assert!(p.remove(9));
        assert!(p.is_empty());
    }

    #[test]
    fn idle_blocks_by_wall_clock_and_sorted() {
        let mut p = EvictionPolicy::new(PolicyKind::Lru, None);
        p.insert(3, 0.0, 0);
        p.insert(1, 0.0, 0);
        p.insert(2, 900.0, 0);
        p.touch(3, 950.0, 0); // refreshed: no longer idle
        assert_eq!(p.idle_blocks(1_000.0, 500.0), vec![1]);
        assert_eq!(p.idle_blocks(1_000.0, 50.0), vec![1, 2, 3]);
        assert!(p.idle_blocks(1_000.0, 2_000.0).is_empty());
    }

    #[test]
    fn infinite_capacity_never_evicts() {
        let mut p = EvictionPolicy::new(PolicyKind::Lru, None);
        for i in 0..10_000 {
            assert_eq!(p.insert(i, i as f64, 0), None);
        }
        assert_eq!(p.len(), 10_000);
        assert_eq!(p.evictions, 0);
    }
}
