//! Live serving engine: a single-node Mooncake-in-miniature that actually
//! runs the AOT-compiled dummy model through PJRT — proving the three
//! layers compose.  Architecture mirrors the paper at small scale:
//!
//! * a CPU-DRAM **prefix cache** of KVCache block chains (Fig 3): hashes
//!   are chained per block; a new request reuses the longest cached
//!   prefix and skips its prefill (§3 step 1);
//! * **chunked prefill** through the `prefill_s*` buckets (§5.1's CPP
//!   chunks, executed sequentially on this one node);
//! * **continuous-batching decode** through the `decode_b*` buckets
//!   (§3 step 4), with per-token timing for TTFT/TBT reporting.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::kvcache::chain_hashes;
use crate::runtime::{argmax, Runtime};

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Tokens per prefix-cache block (the live analogue of the trace's
    /// 512-token blocks, scaled to the tiny model).
    pub block_tokens: usize,
    /// Cap on stored prefix entries (tiny-LRU on insertion order).
    pub max_cache_entries: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { block_tokens: 64, max_cache_entries: 256 }
    }
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub output: Vec<i32>,
    pub ttft_ms: f64,
    pub mean_tbt_ms: f64,
    pub max_tbt_ms: f64,
    /// Prompt tokens served from the prefix cache (no recompute).
    pub reused_tokens: usize,
    pub prompt_tokens: usize,
}

struct CacheEntry {
    /// Tokens this entry's key covers (a block-aligned prefix).
    tokens: usize,
    /// Rows per plane in the packed buffer (>= tokens); one buffer is
    /// shared by every boundary entry of the same chain.
    packed_len: usize,
    /// KV prefix: per (layer, k/v) plane, the first `packed_len` rows —
    /// stored in the same plane order as the full tensor.
    kv: std::sync::Arc<Vec<f32>>,
    stamp: u64,
}

struct Sequence {
    id: u64,
    kv: Vec<f32>, // full [L,2,C,kvh,hd] (host copy, post-prefill)
    pos: usize,   // valid cache length == tokens processed
    last_token: i32,
    output: Vec<i32>,
    max_new: usize,
    ttft_ms: f64,
    gaps: Vec<f64>,
    reused: usize,
    prompt_tokens: usize,
    done: bool,
}

pub struct Engine {
    pub rt: Runtime,
    cfg: EngineConfig,
    cache: HashMap<u64, CacheEntry>,
    stamp: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Self {
        Engine { rt, cfg, cache: HashMap::new(), stamp: 0, cache_hits: 0, cache_misses: 0 }
    }

    fn kv_elems(&self) -> usize {
        self.rt.manifest.kv_elems()
    }

    /// Extract the first `len` cache rows of every (layer, k/v) plane.
    fn slice_prefix(&self, kv: &[f32], len: usize) -> Vec<f32> {
        let m = &self.rt.manifest;
        let row = m.n_kv_heads * m.head_dim;
        let plane = m.max_ctx * row;
        let planes = m.n_layers * 2;
        let mut out = Vec::with_capacity(planes * len * row);
        for p in 0..planes {
            let s = p * plane;
            out.extend_from_slice(&kv[s..s + len * row]);
        }
        out
    }

    /// Paste a stored prefix (packed with `packed_len` rows per plane)
    /// back into a zeroed full-size cache, copying the first `len` rows.
    fn paste_prefix(&self, prefix: &[f32], packed_len: usize, len: usize, kv: &mut [f32]) {
        let m = &self.rt.manifest;
        let row = m.n_kv_heads * m.head_dim;
        let plane = m.max_ctx * row;
        let planes = m.n_layers * 2;
        for p in 0..planes {
            let src = p * packed_len * row;
            let dst = p * plane;
            kv[dst..dst + len * row].copy_from_slice(&prefix[src..src + len * row]);
        }
    }

    /// Register every block boundary of a prompt's chain (Fig 3's
    /// per-block dedup): entries share one packed buffer via Arc.
    fn cache_insert_chain(&mut self, hashes: &[u64], full_blocks: usize, kv_full: &[f32]) {
        if full_blocks == 0 {
            return;
        }
        let packed_len = full_blocks * self.cfg.block_tokens;
        let arc = std::sync::Arc::new(self.slice_prefix(kv_full, packed_len));
        for j in 1..=full_blocks {
            let key = hashes[j - 1];
            if self.cache.contains_key(&key) {
                continue;
            }
            while self.cache.len() >= self.cfg.max_cache_entries {
                // Evict the oldest entry (insertion-stamp LRU).
                if let Some((&victim, _)) = self.cache.iter().min_by_key(|(_, e)| e.stamp) {
                    self.cache.remove(&victim);
                } else {
                    break;
                }
            }
            self.stamp += 1;
            self.cache.insert(
                key,
                CacheEntry {
                    tokens: j * self.cfg.block_tokens,
                    packed_len,
                    kv: arc.clone(),
                    stamp: self.stamp,
                },
            );
        }
    }

    /// Longest cached prefix of the prompt (in whole blocks, capped at
    /// prompt_len - 1 so at least one token always goes through prefill).
    fn lookup_prefix(&mut self, prompt: &[i32]) -> Option<(u64, usize)> {
        let toks: Vec<u32> = prompt.iter().map(|&t| t as u32).collect();
        let hashes = chain_hashes(&toks, self.cfg.block_tokens);
        let max_reuse = prompt.len() - 1;
        for j in (1..=hashes.len()).rev() {
            let covered = (j * self.cfg.block_tokens).min(prompt.len());
            if covered > max_reuse {
                continue;
            }
            if let Some(e) = self.cache.get(&hashes[j - 1]) {
                debug_assert_eq!(e.tokens, covered);
                return Some((hashes[j - 1], covered));
            }
        }
        None
    }

    /// Prefill one request (reusing cached prefix when possible); returns
    /// the sequence ready for decode.
    fn prefill(&mut self, req: &GenRequest, t0: Instant) -> Result<Sequence> {
        let m = self.rt.manifest.clone();
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if req.prompt.len() + req.max_new > m.max_ctx {
            bail!("prompt {} + max_new {} exceeds context {}", req.prompt.len(), req.max_new, m.max_ctx);
        }
        let mut kv = vec![0f32; self.kv_elems()];
        let mut start = 0usize;
        let mut reused = 0usize;
        if let Some((key, covered)) = self.lookup_prefix(&req.prompt) {
            let entry = &self.cache[&key];
            let (prefix, packed_len) = (entry.kv.clone(), entry.packed_len);
            self.paste_prefix(&prefix, packed_len, covered, &mut kv);
            start = covered;
            reused = covered;
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
        }

        // Chunked prefill over the uncached suffix (§5.1): each chunk goes
        // through the smallest bucket that fits; the cache stays a Literal
        // across chunks (no host round-trips between chunks).
        let mut logits = Vec::new();
        let mut kv_lit = self.rt.kv_literal(&kv, None)?;
        while start < req.prompt.len() {
            let remaining = req.prompt.len() - start;
            let biggest = *m.prefill_buckets.last().unwrap();
            let take = remaining.min(biggest);
            let bucket = self.rt.prefill_bucket(take).unwrap();
            let mut toks = vec![0i32; bucket];
            toks[..take].copy_from_slice(&req.prompt[start..start + take]);
            let (lg, kv_out) = self.rt.prefill_chunk(bucket, &toks, kv_lit, start, take)?;
            kv_lit = kv_out;
            logits = lg;
            start += take;
        }
        let kv: Vec<f32> = kv_lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;

        // Store the prompt's prefix chain (every block boundary) for reuse.
        let toks: Vec<u32> = req.prompt.iter().map(|&t| t as u32).collect();
        let hashes = chain_hashes(&toks, self.cfg.block_tokens);
        let full_blocks = req.prompt.len() / self.cfg.block_tokens;
        self.cache_insert_chain(&hashes, full_blocks, &kv);

        let first = argmax(&logits) as i32;
        Ok(Sequence {
            id: req.id,
            kv,
            pos: req.prompt.len(),
            last_token: first,
            output: vec![first],
            max_new: req.max_new.max(1),
            ttft_ms: t0.elapsed().as_secs_f64() * 1e3,
            gaps: Vec::new(),
            reused,
            prompt_tokens: req.prompt.len(),
            done: req.max_new <= 1,
        })
    }

    /// Serve a batch end-to-end: sequential prefills (the prefill "pool"
    /// of this one node), then continuous-batching decode until every
    /// sequence finishes.
    pub fn serve(&mut self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        let t0 = Instant::now();
        let mut seqs: Vec<Sequence> = Vec::with_capacity(reqs.len());
        for r in reqs {
            seqs.push(self.prefill(r, t0)?);
        }

        let m = self.rt.manifest.clone();
        let kvn = self.kv_elems();
        let max_bucket = *m.decode_buckets.last().unwrap();

        // Waves of at most max_bucket sequences (zombie slots pad the
        // bucket; their writes land on scratch copies and are discarded).
        for wave in seqs.chunks_mut(max_bucket) {
            let b = self.rt.decode_bucket(wave.len()).unwrap();
            // Assemble the batched cache once per wave; from then on the
            // cache lives as a Literal handed from step to step (§Perf:
            // saves two 8 MB host copies per iteration).
            let mut kv = vec![0f32; b * kvn];
            for (i, s) in wave.iter().enumerate() {
                kv[i * kvn..(i + 1) * kvn].copy_from_slice(&s.kv);
            }
            let mut kv_lit = self.rt.kv_literal(&kv, Some(b))?;
            drop(kv);
            let mut last = Instant::now();
            while wave.iter().any(|s| !s.done) {
                let mut toks = vec![0i32; b];
                let mut pos = vec![0i32; b];
                for (i, s) in wave.iter().enumerate() {
                    toks[i] = s.last_token;
                    pos[i] = s.pos as i32;
                }
                let (logits, kv_out) = self.rt.decode_step(b, &toks, kv_lit, &pos)?;
                kv_lit = kv_out;
                let now = Instant::now();
                let gap = now.duration_since(last).as_secs_f64() * 1e3;
                last = now;
                for (i, s) in wave.iter_mut().enumerate() {
                    if s.done {
                        continue;
                    }
                    let tok = argmax(&logits[i * m.vocab..(i + 1) * m.vocab]) as i32;
                    s.pos += 1;
                    s.last_token = tok;
                    s.output.push(tok);
                    s.gaps.push(gap);
                    if s.output.len() >= s.max_new || s.pos + 1 >= m.max_ctx {
                        s.done = true;
                    }
                }
            }
            // Persist final KV back (so reuse across serve() calls sees
            // decode-extended caches too — not block-aligned, so only the
            // prompt prefix matters; skip).
        }

        Ok(seqs
            .into_iter()
            .map(|s| GenResult {
                id: s.id,
                ttft_ms: s.ttft_ms,
                mean_tbt_ms: if s.gaps.is_empty() {
                    0.0
                } else {
                    s.gaps.iter().sum::<f64>() / s.gaps.len() as f64
                },
                max_tbt_ms: s.gaps.iter().cloned().fold(0.0, f64::max),
                reused_tokens: s.reused,
                prompt_tokens: s.prompt_tokens,
                output: s.output,
            })
            .collect())
    }
}
