"""AOT lowering: JAX entry points -> HLO *text* artifacts + weights.npz.

Run once at build time (`make artifacts`); the Rust runtime loads the HLO
text via `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
client, and executes it on the request path — Python is never involved
after this script exits.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.  Lowered
with return_tuple=True; the Rust side unwraps with `to_tuple()`.

Artifacts (per shape bucket, see ModelConfig):
  prefill_s{S}.hlo.txt   args = [*params, tokens i32[S], kv f32[L,2,C,kvh,hd],
                                 start i32[1], n_valid i32[1]]
                         -> (last_logits f32[V], kv_out)
  decode_b{B}.hlo.txt    args = [*params, tokens i32[B], kv f32[B,L,2,C,kvh,hd],
                                 positions i32[B]]
                         -> (logits f32[B,V], kv_out)
  weights.npz            params in param_specs order (npz member names sort
                         in ABI order by construction)
  manifest.json          model config + bucket/artifact inventory for Rust
"""

import argparse
import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import TINY, ModelConfig
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_shape_dtype(cfg: ModelConfig):
    return [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in cfg.param_specs()]


def lower_prefill(cfg: ModelConfig, s: int) -> str:
    fn = functools.partial(M.prefill_step, cfg)
    lowered = jax.jit(fn).lower(
        param_shape_dtype(cfg),
        jax.ShapeDtypeStruct((s,), jnp.int32),
        jax.ShapeDtypeStruct(M.kv_shape(cfg), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_decode(cfg: ModelConfig, b: int) -> str:
    fn = functools.partial(M.decode_step, cfg)
    lowered = jax.jit(fn).lower(
        param_shape_dtype(cfg),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct(M.kv_shape(cfg, b), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    return to_hlo_text(lowered)


def write_weights(cfg: ModelConfig, path: str, seed: int = 0):
    params = M.init_params(cfg, seed)
    arrays = {name: np.asarray(p) for (name, _), p in zip(cfg.param_specs(), params)}
    np.savez(path, **arrays)


def build(outdir: str, cfg: ModelConfig = TINY, seed: int = 0):
    os.makedirs(outdir, exist_ok=True)
    artifacts = {}
    for s in cfg.prefill_buckets:
        name = f"prefill_s{s}.hlo.txt"
        text = lower_prefill(cfg, s)
        with open(os.path.join(outdir, name), "w") as f:
            f.write(text)
        artifacts[f"prefill_s{s}"] = name
        print(f"  {name}: {len(text)} chars")
    for b in cfg.decode_buckets:
        name = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, b)
        with open(os.path.join(outdir, name), "w") as f:
            f.write(text)
        artifacts[f"decode_b{b}"] = name
        print(f"  {name}: {len(text)} chars")

    write_weights(cfg, os.path.join(outdir, "weights.npz"), seed)
    print("  weights.npz")

    manifest = {
        "model": cfg.to_dict(),
        "param_names": [n for n, _ in cfg.param_specs()],
        "param_shapes": [list(s) for _, s in cfg.param_specs()],
        "prefill_buckets": list(cfg.prefill_buckets),
        "decode_buckets": list(cfg.decode_buckets),
        "artifacts": artifacts,
        "weights": "weights.npz",
        "seed": seed,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("  manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(f"AOT-lowering dummy model to {args.out}")
    build(args.out, TINY, args.seed)


if __name__ == "__main__":
    main()
