//! Hot-spot migration heuristics (§6.2).
//!
//! The *forwarding* replication path (schedule a request to a non-holder
//! and pull the prefix) lives in `conductor::schedule`.  This module adds
//! the standalone proactive view: tracking block heat and deciding, given
//! NIC backlogs, which blocks deserve an extra replica — used by the Fig 8
//! "KVCache-centric" configuration and unit-testable in isolation.
//!
//! Holder sets come from the Conductor's global
//! [`ShardedPrefixIndex`] — one probe per block for the whole
//! cluster — instead of a `contains` scan of every pool; congestion is
//! read off the NIC-tx resource queues, and (PR 4 follow-up) the
//! *destination* side consults `Messenger::rx_backlog_ms`: pushing a
//! replica at a node already drowning in ingress traffic makes the §6.1
//! incast worse, so backpressured destinations are skipped when
//! `SimConfig::replication_rx_backlog_cap_ms` is set.

use crate::config::SimConfig;
use crate::kvcache::{DenseBlockId, ShardedPrefixIndex};
use crate::prefill::PrefillPool;
use crate::resource::Resources;
use crate::util::fasthash::FastMap;
use crate::TimeMs;

/// Exponentially-decayed access counter per block (interned ids — heat
/// is conductor-side state, inside the interning boundary).
#[derive(Debug, Default)]
pub struct HeatTracker {
    heat: FastMap<DenseBlockId, (f64, TimeMs)>,
    /// Decay half-life (ms).
    pub half_life_ms: f64,
}

impl HeatTracker {
    pub fn new(half_life_ms: f64) -> Self {
        HeatTracker { heat: FastMap::default(), half_life_ms }
    }

    fn decayed(&self, b: DenseBlockId, now: TimeMs) -> f64 {
        match self.heat.get(&b) {
            None => 0.0,
            Some(&(h, t)) => h * 0.5f64.powf((now - t).max(0.0) / self.half_life_ms),
        }
    }

    pub fn touch(&mut self, b: DenseBlockId, now: TimeMs) {
        let h = self.decayed(b, now) + 1.0;
        self.heat.insert(b, (h, now));
    }

    pub fn heat_of(&self, b: DenseBlockId, now: TimeMs) -> f64 {
        self.decayed(b, now)
    }

    /// Blocks hotter than `threshold`, hottest first (ties by id, so the
    /// ordering is fully deterministic).
    pub fn hot_blocks(&self, now: TimeMs, threshold: f64) -> Vec<(DenseBlockId, f64)> {
        let mut v: Vec<(DenseBlockId, f64)> = self
            .heat
            // lint: allow(unordered-iter) — candidates are fully re-sorted by (heat, id) below, so map order never escapes
            .keys()
            .map(|&b| (b, self.decayed(b, now)))
            .filter(|(_, h)| *h >= threshold)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        v
    }

    /// Drop heat entries whose dense id is not set in `live` — the same
    /// residency bitmap handed to `BlockInterner::recycle_epoch`.  Called
    /// by external planner drivers after a recycle epoch so a *reused* id
    /// does not inherit a dead block's heat.  Ids beyond the bitmap are
    /// dead by definition.
    pub fn retain_live(&mut self, live: &[u64]) {
        let alive = |b: DenseBlockId| {
            (live.get(b as usize / 64).copied().unwrap_or(0) >> (b as usize % 64)) & 1 != 0
        };
        // lint: allow(unordered-iter) — pure filter; which entries survive does not depend on visit order
        self.heat.retain(|&b, _| alive(b));
    }
}

/// Decide proactive replications: a hot block held by a congested node
/// (deep NIC-tx backlog) is copied to the least-loaded non-holder.
/// Holder sets come from the global `index`; destination load from the
/// prefill queues.  `cfg.replication_rx_backlog_cap_ms` (`None` = the
/// default = yesterday's behavior) disqualifies destinations whose
/// NIC-rx backlog exceeds the cap — a replica pushed into an incast hot
/// spot would queue behind the very congestion it is meant to relieve.
/// Returns (block, from, to) triples; the caller performs the
/// transfers.
#[allow(clippy::too_many_arguments)]
pub fn plan_replications(
    tracker: &HeatTracker,
    pool: &PrefillPool,
    index: &ShardedPrefixIndex,
    res: &Resources,
    cfg: &SimConfig,
    now: TimeMs,
    heat_threshold: f64,
    backlog_threshold_ms: f64,
    max_plans: usize,
) -> Vec<(DenseBlockId, usize, usize)> {
    let rx_backlog_cap_ms = cfg.replication_rx_backlog_cap_ms;
    let mut plans = Vec::new();
    for (block, _) in tracker.hot_blocks(now, heat_threshold) {
        if plans.len() >= max_plans {
            break;
        }
        let holders = index.holders(block);
        if holders.is_empty() || holders.len() == pool.len() {
            continue; // nowhere to copy from / already everywhere
        }
        // Only replicate when every holder's NIC is congested.
        let min_backlog = holders
            .iter()
            .map(|&h| res.nic.backlog_ms(h, now))
            .fold(f64::INFINITY, f64::min);
        if min_backlog < backlog_threshold_ms {
            continue;
        }
        let src = *holders
            .iter()
            .min_by(|&&a, &&b| {
                res.nic
                    .backlog_ms(a, now)
                    .partial_cmp(&res.nic.backlog_ms(b, now))
                    .unwrap()
            })
            .unwrap();
        let dst = (0..pool.len())
            .filter(|i| !holders.contains(i))
            .filter(|&i| match rx_backlog_cap_ms {
                Some(cap) => res.nic.rx_backlog_ms(i, now) <= cap,
                None => true,
            })
            .min_by(|&a, &b| {
                pool.instances[a]
                    .queue_ms(now)
                    .partial_cmp(&pool.instances[b].queue_ms(now))
                    .unwrap()
            });
        if let Some(dst) = dst {
            plans.push((block, src, dst));
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::model::PerfModel;
    use crate::resource::Resources;

    #[test]
    fn heat_decays() {
        let mut t = HeatTracker::new(1_000.0);
        t.touch(1, 0.0);
        t.touch(1, 0.0);
        assert!((t.heat_of(1, 0.0) - 2.0).abs() < 1e-9);
        assert!((t.heat_of(1, 1_000.0) - 1.0).abs() < 1e-9); // one half-life
        assert!(t.heat_of(1, 10_000.0) < 0.01);
        assert_eq!(t.heat_of(99, 0.0), 0.0);
    }

    #[test]
    fn hot_blocks_sorted() {
        let mut t = HeatTracker::new(1e9);
        for _ in 0..5 {
            t.touch(1, 0.0);
        }
        for _ in 0..2 {
            t.touch(2, 0.0);
        }
        let hot = t.hot_blocks(0.0, 1.5);
        assert_eq!(hot.len(), 2);
        assert_eq!(hot[0].0, 1);
        // Equal heat breaks ties by id — deterministic planning order.
        let mut u = HeatTracker::new(1e9);
        u.touch(9, 0.0);
        u.touch(4, 0.0);
        let tied = u.hot_blocks(0.0, 0.5);
        assert_eq!(tied.iter().map(|&(b, _)| b).collect::<Vec<_>>(), vec![4, 9]);
    }

    #[test]
    fn retain_live_purges_recycled_ids() {
        let mut t = HeatTracker::new(1e9);
        t.touch(3, 0.0);
        t.touch(64, 0.0);
        t.touch(70, 0.0);
        // Bitmap keeps 3 and 70 only.
        let mut live = vec![0u64; 2];
        live[0] |= 1 << 3;
        live[1] |= 1 << (70 - 64);
        t.retain_live(&live);
        assert!(t.heat_of(3, 0.0) > 0.0);
        assert!(t.heat_of(70, 0.0) > 0.0);
        assert_eq!(t.heat_of(64, 0.0), 0.0);
        // Ids beyond the bitmap are dead by definition.
        t.touch(1_000, 0.0);
        t.retain_live(&live);
        assert_eq!(t.heat_of(1_000, 0.0), 0.0);
    }

    #[test]
    fn replication_targets_congested_holders() {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&cfg);
        let mut res = Resources::new(&cfg, &perf);
        let mut tracker = HeatTracker::new(1e9);

        // Block 7 lives only on instance 0, which is congested.  The
        // planner reads holders off the index, not the pools.
        let _ = pool.instances[0].pool.insert_replica(&[7], 0.0);
        let idx = pool.build_prefix_index();
        assert_eq!(idx.holders(7), vec![0]);
        for _ in 0..100 {
            tracker.touch(7, 0.0);
        }
        res.nic.schedule(0, 1, 0.0, 500_000_000_000); // 5000 ms backlog

        let plans = plan_replications(&tracker, &pool, &idx, &res, &cfg, 0.0, 10.0, 100.0, 4);
        assert_eq!(plans.len(), 1);
        let (b, src, dst) = plans[0];
        assert_eq!((b, src), (7, 0));
        assert_ne!(dst, 0);

        // Without congestion: no replication.
        let quiet = Resources::new(&cfg, &perf);
        let plans = plan_replications(&tracker, &pool, &idx, &quiet, &cfg, 0.0, 10.0, 100.0, 4);
        assert!(plans.is_empty());
    }

    #[test]
    fn backpressured_destinations_are_skipped_when_capped() {
        // ROADMAP PR 4 follow-up: with `replication_rx_backlog_cap_ms`
        // set, a destination whose NIC-rx backlog exceeds the cap is
        // disqualified; with the knob off (None — the default), the
        // decision is exactly yesterday's.
        let cfg = SimConfig {
            n_prefill: 3,
            nic_rx_bw: Some(10e9), // finite ingress so rx backlogs exist
            ..Default::default()
        };
        assert!(cfg.replication_rx_backlog_cap_ms.is_none(), "knob defaults off");
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&cfg);
        let mut res = Resources::new(&cfg, &perf);
        let mut tracker = HeatTracker::new(1e9);

        let _ = pool.instances[0].pool.insert_replica(&[7], 0.0);
        let idx = pool.build_prefix_index();
        for _ in 0..100 {
            tracker.touch(7, 0.0);
        }
        // Holder 0: deep tx backlog (sent towards a decode node so no
        // prefill destination picks up stray rx traffic from it).
        res.nic.schedule(0, 5, 0.0, 500_000_000_000);
        // Node 1 (the queue-idle favourite) is drowning in ingress.
        res.nic.schedule(2, 1, 0.0, 100_000_000_000); // ~10 s of rx backlog on 1
        pool.instances[2].block_until(50.0); // node 2 slightly busy

        // Off (the default None): destination choice ignores rx — node 1
        // wins on queue time despite its rx backlog (yesterday's
        // behavior).
        let off = plan_replications(&tracker, &pool, &idx, &res, &cfg, 0.0, 10.0, 100.0, 4);
        assert_eq!(off, vec![(7, 0, 1)]);

        // On with a cap below node 1's backlog: the plan flips to the
        // only non-backpressured non-holder, node 2.
        let capped = SimConfig { replication_rx_backlog_cap_ms: Some(1_000.0), ..cfg.clone() };
        let on = plan_replications(&tracker, &pool, &idx, &res, &capped, 0.0, 10.0, 100.0, 4);
        assert_eq!(on, vec![(7, 0, 2)]);

        // Cap so tight every destination is backpressured (node 2 also
        // receives now): no plan at all rather than a harmful one.
        res.nic.schedule(0, 2, 0.0, 100_000_000_000);
        let zero = SimConfig { replication_rx_backlog_cap_ms: Some(0.0), ..cfg.clone() };
        let none = plan_replications(&tracker, &pool, &idx, &res, &zero, 0.0, 10.0, 100.0, 4);
        assert!(none.is_empty(), "fully backpressured cluster must not replicate");
    }
}
