//! Quickstart — the end-to-end live path: load the AOT-compiled dummy
//! model (JAX + Pallas kernels lowered to HLO text at build time), serve
//! a batch of prompts through the Rust engine via PJRT, and report
//! latency/throughput.  Run `make artifacts` first, then:
//!
//!     cargo run --release --offline --example quickstart
//!
//! This proves the three layers compose: the Pallas attention kernels
//! (L1) inside the JAX model (L2) execute under the Rust coordinator
//! (L3) with Python nowhere on the request path.

use anyhow::Result;
use mooncake::engine::{Engine, EngineConfig, GenRequest};
use mooncake::runtime::Runtime;
use mooncake::util::rng::Rng;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    println!("loading artifacts from {dir}/ ...");
    let rt = Runtime::load(&dir)?;
    println!(
        "model: {} layers, d_model {}, vocab {}, ctx {} | prefill buckets {:?}, decode buckets {:?}",
        rt.manifest.n_layers,
        rt.manifest.d_model,
        rt.manifest.vocab,
        rt.manifest.max_ctx,
        rt.manifest.prefill_buckets,
        rt.manifest.decode_buckets
    );

    let vocab = rt.manifest.vocab as u64;
    let mut engine = Engine::new(rt, EngineConfig::default());
    let mut rng = Rng::new(7);

    // A shared 128-token "system prompt" exercises prefix caching —
    // the second serve() call must reuse its KVCache blocks.
    let system: Vec<i32> = (0..128).map(|_| rng.below(vocab) as i32).collect();
    let make = |rng: &mut Rng, id: u64, system: &[i32]| {
        let mut prompt = system.to_vec();
        prompt.extend((0..64).map(|_| rng.below(vocab) as i32));
        GenRequest { id, prompt, max_new: 24 }
    };

    println!("\n-- wave 1 (cold cache) --");
    let reqs: Vec<GenRequest> = (0..4).map(|i| make(&mut rng, i, &system)).collect();
    let t = std::time::Instant::now();
    let res1 = engine.serve(&reqs)?;
    let w1 = t.elapsed().as_secs_f64();
    for r in &res1 {
        println!(
            "req {}: {} prompt tok ({} reused), {} out, TTFT {:.0} ms, mean TBT {:.1} ms",
            r.id, r.prompt_tokens, r.reused_tokens, r.output.len(), r.ttft_ms, r.mean_tbt_ms
        );
    }

    println!("\n-- wave 2 (warm prefix cache) --");
    let reqs: Vec<GenRequest> = (4..8).map(|i| make(&mut rng, i, &system)).collect();
    let t = std::time::Instant::now();
    let res2 = engine.serve(&reqs)?;
    let w2 = t.elapsed().as_secs_f64();
    for r in &res2 {
        println!(
            "req {}: {} prompt tok ({} reused), {} out, TTFT {:.0} ms, mean TBT {:.1} ms",
            r.id, r.prompt_tokens, r.reused_tokens, r.output.len(), r.ttft_ms, r.mean_tbt_ms
        );
    }

    let tok1: usize = res1.iter().map(|r| r.output.len()).sum();
    let tok2: usize = res2.iter().map(|r| r.output.len()).sum();
    println!(
        "\nwave1: {:.2} s ({:.1} tok/s) | wave2: {:.2} s ({:.1} tok/s) | cache {} hits / {} misses",
        w1,
        tok1 as f64 / w1,
        w2,
        tok2 as f64 / w2,
        engine.cache_hits,
        engine.cache_misses
    );
    assert!(
        res2.iter().all(|r| r.reused_tokens >= 128),
        "wave 2 must reuse the shared system prefix"
    );
    println!("quickstart OK");
    Ok(())
}
