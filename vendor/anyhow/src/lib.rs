//! Vendored, dependency-free subset of the `anyhow` crate API surface
//! used by this repository: [`Error`], [`Result`], [`Context`], and the
//! `anyhow!` / `bail!` macros.  The build must work fully offline (no
//! crates.io registry), so this shim stands in for the real crate; it is
//! string-based (no backtraces, no downcasting) which is all the CLI and
//! trace tooling need.

use std::fmt;

/// A string-backed error with an optional chain of context messages.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn from_msg(msg: String) -> Self {
        Error { msg }
    }

    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// std::error::Error so this blanket conversion (enabling `?` on
// io::Error, ParseError, ...) does not overlap the reflexive From.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow`-style result alias: the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (or missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::from_msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/mooncake")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e}").starts_with("outer: "));
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
        let s: Option<u32> = Some(7);
        assert_eq!(s.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 1");
    }
}
