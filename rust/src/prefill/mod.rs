//! Prefill instance pool (§5): FIFO prefill queues, chunked pipeline
//! parallelism for long contexts, and the layer-wise overlap accounting
//! that lets scheduling ignore VRAM on prefill nodes.

pub mod layerwise;

use crate::config::SimConfig;
use crate::kvcache::{CachePool, PolicyKind};
use crate::model::PerfModel;
use crate::TimeMs;

/// One prefill node: a FIFO queue (modeled by its drain time) plus the
/// node's CPU-DRAM KVCache pool.
#[derive(Debug)]
pub struct PrefillInstance {
    /// The queue drains at this time; new work starts no earlier.
    pub busy_until: TimeMs,
    pub pool: CachePool,
    /// Requests prefilled and compute-ms spent (utilization accounting).
    pub n_prefilled: u64,
    pub busy_ms: f64,
}

impl PrefillInstance {
    pub fn new(eviction: PolicyKind, capacity_blocks: Option<usize>) -> Self {
        PrefillInstance {
            busy_until: 0.0,
            pool: CachePool::new(eviction, capacity_blocks),
            n_prefilled: 0,
            busy_ms: 0.0,
        }
    }

    /// Algorithm 1's `EstimatePrefillQueueTime`.
    pub fn queue_ms(&self, now: TimeMs) -> f64 {
        (self.busy_until - now).max(0.0)
    }

    /// §7.1 load: predicted TTFT of a nominal request against the SLO.
    pub fn load(&self, now: TimeMs, nominal_prefill_ms: f64, ttft_slo: f64) -> f64 {
        (self.queue_ms(now) + nominal_prefill_ms) / ttft_slo
    }
}

/// The prefill pool with CPP group formation.
#[derive(Debug)]
pub struct PrefillPool {
    pub instances: Vec<PrefillInstance>,
}

impl PrefillPool {
    pub fn new(cfg: &SimConfig) -> Self {
        PrefillPool {
            instances: (0..cfg.n_prefill)
                .map(|_| PrefillInstance::new(cfg.eviction, cfg.cache_capacity_blocks))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Decide the CPP group size for an input of `n_new` uncached tokens
    /// (§5.1): long contexts recruit idle peers, short ones stay local.
    /// Returns (group_size, member ids) — the primary is always included.
    pub fn cpp_group(
        &self,
        cfg: &SimConfig,
        primary: usize,
        n_new: u64,
        now: TimeMs,
    ) -> Vec<usize> {
        let mut group = vec![primary];
        if n_new < cfg.cpp_threshold_tokens || cfg.cpp_group_max <= 1 {
            return group;
        }
        // Recruit the idlest peers; only nearly-idle nodes join a pipeline
        // group (recruiting a busy node would delay its own queue).
        let mut candidates: Vec<(usize, f64)> = self
            .instances
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != primary)
            .map(|(i, inst)| (i, inst.queue_ms(now)))
            .filter(|(_, q)| *q < 1.0)
            .collect();
        candidates.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (i, _) in candidates.into_iter().take(cfg.cpp_group_max as usize - 1) {
            group.push(i);
        }
        group
    }

    /// Execute a prefill job: occupies every group member from
    /// `start` for the pipeline's makespan.  Returns (start, end).
    pub fn run_prefill(
        &mut self,
        perf: &PerfModel,
        cfg: &SimConfig,
        group: &[usize],
        n_new: u64,
        prefix_tokens: u64,
        earliest_start: TimeMs,
    ) -> (TimeMs, TimeMs) {
        let queue_free = group
            .iter()
            .map(|&i| self.instances[i].busy_until)
            .fold(0.0f64, f64::max);
        let start = queue_free.max(earliest_start);
        let dur = perf.cpp_prefill_ms(n_new, prefix_tokens, cfg.prefill_chunk, group.len() as u64);
        let end = start + dur;
        for &i in group {
            self.instances[i].busy_until = end;
            self.instances[i].busy_ms += dur;
        }
        self.instances[group[0]].n_prefilled += 1;
        (start, end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn queue_time_accumulates() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let (s1, e1) = pool.run_prefill(&perf, &c, &[0], 8_000, 0, 0.0);
        assert_eq!(s1, 0.0);
        let (s2, e2) = pool.run_prefill(&perf, &c, &[0], 8_000, 0, 0.0);
        assert_eq!(s2, e1);
        assert!(e2 > e1);
        assert!(pool.instances[0].queue_ms(0.0) >= e2);
        // Other instances untouched.
        assert_eq!(pool.instances[1].queue_ms(0.0), 0.0);
    }

    #[test]
    fn cpp_group_only_for_long_inputs() {
        let c = cfg();
        let pool = PrefillPool::new(&c);
        assert_eq!(pool.cpp_group(&c, 0, 8_000, 0.0).len(), 1);
        let g = pool.cpp_group(&c, 0, 100_000, 0.0);
        assert!(g.len() > 1 && g.len() <= c.cpp_group_max as usize);
        assert_eq!(g[0], 0);
    }

    #[test]
    fn cpp_group_skips_busy_peers() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        // Make every peer busy.
        for i in 1..c.n_prefill {
            pool.run_prefill(&perf, &c, &[i], 64_000, 0, 0.0);
        }
        let g = pool.cpp_group(&c, 0, 100_000, 0.0);
        assert_eq!(g, vec![0]);
    }

    #[test]
    fn group_prefill_occupies_all_members() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut pool = PrefillPool::new(&c);
        let (_, end) = pool.run_prefill(&perf, &c, &[0, 1], 100_000, 0, 5.0);
        assert_eq!(pool.instances[0].busy_until, end);
        assert_eq!(pool.instances[1].busy_until, end);
    }

    #[test]
    fn cpp_shortens_long_prefill() {
        let c = cfg();
        let perf = PerfModel::paper();
        let mut solo = PrefillPool::new(&c);
        let mut duo = PrefillPool::new(&c);
        let (_, e1) = solo.run_prefill(&perf, &c, &[0], 128_000, 0, 0.0);
        let (_, e2) = duo.run_prefill(&perf, &c, &[0, 1, 2, 3], 128_000, 0, 0.0);
        assert!(e2 < e1 * 0.6, "{e2} vs {e1}");
    }
}
