//! The unified cost model — the **single source of timing truth** for
//! prefill scheduling.
//!
//! Algorithm 1 (§6) only works if Conductor's TTFT *estimates* agree with
//! what the cluster actually *does*: SLO-gated admission and early
//! rejection (§7) both compare an estimate against a limit, so any drift
//! between the estimator and the executor silently re-tunes every
//! threshold.  Historically the two were separate code paths
//! (`conductor::est_ttft` summed queue+transfer+compute analytically
//! while `PrefillPool::run_prefill` re-derived start/end with different
//! rules — e.g. the estimate charged the remote-prefix fetch to the
//! *destination* NIC and added fetch and queue serially, where execution
//! used the *source* NIC and overlapped the fetch with queue drain).
//!
//! Now both sides call this module, and **every device term is a queue
//! probe, not a closed form**: NIC-tx, NIC-rx, and NVMe time all flows
//! through [`crate::resource::BwQueue`] banks, so estimates stay honest
//! even under concurrent stagings and incast:
//!
//! * [`estimate_prefill`] — Conductor's `EstimatePrefillExecutionTime` +
//!   `EstimateKVCacheTransferTime` + queue probes (prefill FIFO, source
//!   tx, destination rx, both ends' NVMe), returning an absolute planned
//!   (start, end) window;
//! * [`crate::prefill::PrefillPool::submit`] — the executor admits a job
//!   using the *same* function of the *same* state, so the simulator's
//!   `PrefillStart`/`PrefillDone` events land exactly where the estimate
//!   said they would (a property `rust/tests/cost_model_agreement.rs`
//!   asserts end-to-end).
//!
//! SSD staging is a **gate**, like the remote fetch: the NVMe read is
//! reserved on the node's queue at admission and the job may not start
//! before it lands (it overlaps queue drain and any fetch — independent
//! devices), which is also what makes concurrent stagings contend.

use crate::config::SimConfig;
use crate::model::PerfModel;
use crate::prefill::PrefillPool;
use crate::resource::{BwQueue, Op, Resources};
use crate::trace::BLOCK_TOKENS;
use crate::TimeMs;

/// Fraction of the local DRAM→VRAM prefix load that stays on the critical
/// path: loading reused KVCache overlaps layer-wise with computation
/// (§5.2), but it bounds when the first layer can start, so a small
/// non-overlapped head remains visible.
pub const PREFIX_LOAD_VISIBLE_FRACTION: f64 = 0.1;

/// Visible (non-overlapped) latency of loading `prefix_tokens` of reused
/// KVCache from local CPU DRAM before prefill can run.
pub fn prefix_load_ms(perf: &PerfModel, prefix_tokens: u64) -> f64 {
    perf.dram_load_ms(prefix_tokens) * PREFIX_LOAD_VISIBLE_FRACTION
}

/// Wire bytes of `tokens` of KVCache (an NVMe staging read or write
/// moves the same bytes the wire would).
pub fn stage_bytes(perf: &PerfModel, tokens: u64) -> u64 {
    tokens * perf.model.kv_bytes_per_token()
}

/// Per-op setup of an NVMe staging read spanning `tokens`: the
/// random-access IOPS term, one seek per cache block.
pub fn stage_setup_ms(perf: &PerfModel, tokens: u64) -> f64 {
    tokens.div_ceil(BLOCK_TOKENS) as f64 / perf.hw.ssd_iops * 1e3
}

/// Absolute landing time of an SSD→DRAM staging read of `tokens` on
/// `node`, **through the node's NVMe queue** — concurrent stagings (and
/// demotion writes) on the same device serialize.  Read-only;
/// [`schedule_stage`] is the matching reservation and returns the same
/// time bit-for-bit.
pub fn estimate_stage_done(
    perf: &PerfModel,
    nvme: &BwQueue,
    node: usize,
    now: TimeMs,
    tokens: u64,
) -> TimeMs {
    if tokens == 0 {
        return now;
    }
    nvme.estimate_done(node, now, stage_bytes(perf, tokens), stage_setup_ms(perf, tokens))
}

/// Reserve the staging read [`estimate_stage_done`] priced.
pub fn schedule_stage(
    perf: &PerfModel,
    nvme: &mut BwQueue,
    node: usize,
    now: TimeMs,
    tokens: u64,
) -> Op {
    nvme.schedule(node, now, stage_bytes(perf, tokens), stage_setup_ms(perf, tokens))
}

/// Execution makespan of one prefill job on a CPP group of `group_len`
/// nodes: chunked-pipeline compute plus the visible prefix-load head.
/// SSD staging is *not* part of the makespan — it is a gate reserved on
/// the node's NVMe queue, overlapping queue drain.  This is the ONE
/// definition of "how long a running prefill takes" — both the
/// estimator and the executor use it.
pub fn prefill_exec_ms(
    perf: &PerfModel,
    cfg: &SimConfig,
    n_new: u64,
    prefix_tokens: u64,
    group_len: u64,
) -> f64 {
    perf.cpp_prefill_ms(n_new, prefix_tokens, cfg.prefill_chunk, group_len)
        + prefix_load_ms(perf, prefix_tokens)
}

/// Wire bytes of a remote prefix fetch of `blocks` cache blocks (§6.2).
pub fn fetch_bytes(perf: &PerfModel, blocks: usize) -> u64 {
    blocks as u64 * BLOCK_TOKENS * perf.model.kv_bytes_per_token()
}

/// A remote §6.2 prefix fetch: `blocks` cache blocks pulled from `src`,
/// of which `src_ssd_blocks` live on the **source's SSD tier** and must
/// be staged into its DRAM before the NIC can serialize them — so the
/// fetch pays the source's NVMe queue *and then* the wire (source tx,
/// destination rx).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FetchPlan {
    pub src: usize,
    pub blocks: usize,
    pub src_ssd_blocks: usize,
}

/// Wire bytes of the layer-wise KVCache stream to the decode node (§5.2).
pub fn kv_stream_bytes(perf: &PerfModel, input_tokens: u64) -> u64 {
    input_tokens * perf.model.kv_bytes_per_token()
}

/// A placement's predicted timing, in absolute simulator time.  Plain
/// `Copy` data — the CPP group is the *caller's* (reused) buffer, so the
/// scheduler's candidate loop prices dozens of estimates per decision
/// without a heap allocation per probe.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefillEstimate {
    /// Planned start: the job runs when its whole group has drained AND
    /// any remote prefix fetch has landed AND any local SSD staging has
    /// landed (the three overlap — they are `max`ed, not summed).
    pub start: TimeMs,
    /// Planned completion (start + exec) — the TTFT moment.
    pub end: TimeMs,
    /// Wait behind the group's committed FIFO work, ms from now.
    pub queue_wait_ms: f64,
    /// Remote-prefix fetch landing delay, ms from now: the source's NVMe
    /// queue (SSD-held blocks), then its tx queue, then the
    /// destination's rx queue.
    pub fetch_wait_ms: f64,
    /// Local SSD→DRAM staging landing delay, ms from now, through the
    /// primary's NVMe queue.
    pub stage_wait_ms: f64,
    /// Execution makespan from [`prefill_exec_ms`].
    pub exec_ms: f64,
}

impl PrefillEstimate {
    /// Estimated TTFT relative to `now` (what Algorithm 1 line 25 gates).
    pub fn ttft_ms(&self, now: TimeMs) -> f64 {
        self.end - now
    }
}

/// Estimate a prefill on the CPP `group` (primary first — the caller
/// forms it with [`PrefillPool::cpp_group_into`] over the same state)
/// with `n_new` uncached tokens and `prefix_tokens` reused ones, of
/// which `ssd_prefix_tokens` must first be staged up through the node's
/// NVMe queue; `fetch` adds a remote prefix fetch that must land first —
/// charged to the source's NVMe queue (staging), its tx queue, and the
/// destination's rx queue.  Read-only and allocation-free: probes the
/// prefill queues and every resource bank without mutating any of them.
#[allow(clippy::too_many_arguments)]
#[must_use = "a discarded estimate means the probe's cost never reached the decision"]
// lint: hot
pub fn estimate_prefill(
    perf: &PerfModel,
    cfg: &SimConfig,
    pool: &PrefillPool,
    res: &Resources,
    group: &[usize],
    n_new: u64,
    prefix_tokens: u64,
    ssd_prefix_tokens: u64,
    fetch: Option<FetchPlan>,
    now: TimeMs,
) -> PrefillEstimate {
    debug_assert!(ssd_prefix_tokens <= prefix_tokens);
    debug_assert!(!group.is_empty());
    let primary = group[0];
    let exec_ms = prefill_exec_ms(perf, cfg, n_new, prefix_tokens, group.len() as u64);
    let queue_free = pool.group_free_at(group).max(now);
    let stage_done = estimate_stage_done(perf, &res.nvme, primary, now, ssd_prefix_tokens);
    let fetch_done = match fetch {
        Some(f) if f.blocks > 0 => {
            let wire_from = estimate_stage_done(
                perf,
                &res.nvme,
                f.src,
                now,
                f.src_ssd_blocks as u64 * BLOCK_TOKENS,
            );
            res.nic.estimate_done(f.src, primary, wire_from, fetch_bytes(perf, f.blocks))
        }
        _ => now,
    };
    let start = queue_free.max(stage_done).max(fetch_done);
    PrefillEstimate {
        start,
        end: start + exec_ms,
        queue_wait_ms: queue_free - now,
        fetch_wait_ms: fetch_done - now,
        stage_wait_ms: stage_done - now,
        exec_ms,
    }
}

/// When the streamed KVCache lands at the decode node: the layer-wise
/// stream starts with the prefill and can finish no earlier than the
/// prefill itself, than the wire time on the primary's tx queue, nor
/// than the decode node's rx queue.
pub fn estimate_kv_arrival(
    perf: &PerfModel,
    res: &Resources,
    primary: usize,
    decode_node: usize,
    start: TimeMs,
    end: TimeMs,
    input_tokens: u64,
) -> TimeMs {
    let stream_end =
        res.nic.estimate_done(primary, decode_node, start, kv_stream_bytes(perf, input_tokens));
    stream_end.max(end)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn env() -> (SimConfig, PerfModel, PrefillPool, Resources) {
        let cfg = SimConfig::default();
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let res = Resources::new(&cfg, &perf);
        (cfg, perf, pool, res)
    }

    /// Old-signature shim: form the CPP group the way the scheduler does,
    /// then estimate on it.
    #[allow(clippy::too_many_arguments)]
    fn est(
        perf: &PerfModel,
        cfg: &SimConfig,
        pool: &PrefillPool,
        res: &Resources,
        primary: usize,
        n_new: u64,
        prefix_tokens: u64,
        ssd_prefix_tokens: u64,
        fetch: Option<FetchPlan>,
        now: TimeMs,
    ) -> PrefillEstimate {
        let group = pool.cpp_group(cfg, primary, n_new, now);
        estimate_prefill(
            perf,
            cfg,
            pool,
            res,
            &group,
            n_new,
            prefix_tokens,
            ssd_prefix_tokens,
            fetch,
            now,
        )
    }

    #[test]
    fn exec_includes_visible_prefix_load() {
        let (cfg, perf, _, _) = env();
        let cold = prefill_exec_ms(&perf, &cfg, 8_000, 0, 1);
        assert_eq!(cold, perf.prefill_ms(8_000, 0));
        // Fully cached input still pays the non-overlapped load head.
        let warm = prefill_exec_ms(&perf, &cfg, 0, 8_000, 1);
        assert!(warm > 0.0 && warm < cold * 0.05, "warm={warm} cold={cold}");
        assert!((warm - prefix_load_ms(&perf, 8_000)).abs() < 1e-9);
    }

    #[test]
    fn ssd_staging_gates_the_start_and_crossover_holds() {
        let (cfg, perf, pool, res) = env();
        // An SSD-resident prefix delays the planned start by exactly the
        // NVMe queue probe (idle queue here), on top of the DRAM head.
        let dram_warm = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 0, None, 0.0);
        let ssd_warm = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        let stage = estimate_stage_done(&perf, &res.nvme, 0, 0.0, 8_000);
        assert!(stage > 10.0 * dram_warm.end, "{stage} vs {}", dram_warm.end);
        assert!((ssd_warm.stage_wait_ms - stage).abs() < 1e-9);
        assert!((ssd_warm.end - dram_warm.exec_ms - stage).abs() < 1e-9);
        // The load-vs-recompute crossover both ways, through the ONE
        // timing API the scheduler and executor share (single node, so
        // CPP grouping doesn't shrink the recompute side):
        // deep prefix — loading from SSD beats recomputing it...
        let deep = 32_768u64;
        let load_deep = estimate_stage_done(&perf, &res.nvme, 0, 0.0, deep)
            + prefill_exec_ms(&perf, &cfg, 0, deep, 1);
        let recompute_deep = prefill_exec_ms(&perf, &cfg, deep, 0, 1);
        assert!(load_deep < recompute_deep, "{load_deep} !< {recompute_deep}");
        // ...shallow prefix — recomputing beats the NVMe read.
        let shallow = 512u64;
        let load_shallow = estimate_stage_done(&perf, &res.nvme, 0, 0.0, shallow)
            + prefill_exec_ms(&perf, &cfg, 0, shallow, 1);
        let recompute_shallow = prefill_exec_ms(&perf, &cfg, shallow, 0, 1);
        assert!(recompute_shallow < load_shallow, "{recompute_shallow} !< {load_shallow}");
    }

    #[test]
    fn staging_overlaps_queue_wait() {
        // The gate semantics: the NVMe read proceeds while the job waits
        // in the FIFO — start = max(queue, stage), not their sum.
        let (cfg, perf, mut pool, res) = env();
        pool.instances[0].block_until(100_000.0);
        let est = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        assert!(est.queue_wait_ms >= 100_000.0);
        assert!(est.stage_wait_ms > 100.0 && est.stage_wait_ms < 100_000.0);
        assert!((est.start - 100_000.0).abs() < 1e-6, "start={}", est.start);
    }

    #[test]
    fn concurrent_stagings_contend_on_the_nvme_queue() {
        let (cfg, perf, pool, mut res) = env();
        // Reserve one staging on node 0's NVMe; a second estimate on the
        // same node queues behind it, a different node does not.
        let first = schedule_stage(&perf, &mut res.nvme, 0, 0.0, 8_000);
        let queued = est(&perf, &cfg, &pool, &res, 0, 0, 8_000, 8_000, None, 0.0);
        let fresh = est(&perf, &cfg, &pool, &res, 1, 0, 8_000, 8_000, None, 0.0);
        assert!(
            (queued.stage_wait_ms - fresh.stage_wait_ms - (first.end - first.start)).abs() < 1e-6,
            "second staging must wait out the first: {} vs {}",
            queued.stage_wait_ms,
            fresh.stage_wait_ms
        );
        assert!((queued.end - fresh.end - (first.end - first.start)).abs() < 1e-6);
    }

    #[test]
    fn fetch_charged_to_source_nic() {
        let (cfg, perf, pool, mut res) = env();
        // Congest node 2's outgoing NIC; node 5 stays idle.
        res.nic.schedule(2, 0, 0.0, 2_000_000_000_000); // ~20 s backlog
        let dram_fetch = |src| Some(FetchPlan { src, blocks: 4, src_ssd_blocks: 0 });
        let idle =
            est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, dram_fetch(5), 0.0);
        let congested =
            est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, dram_fetch(2), 0.0);
        assert!(
            congested.fetch_wait_ms > idle.fetch_wait_ms + 10_000.0,
            "source congestion must surface: {} vs {}",
            congested.fetch_wait_ms,
            idle.fetch_wait_ms
        );
        assert!(congested.end > idle.end + 10_000.0);
    }

    #[test]
    fn fetch_charged_to_destination_rx() {
        // Incast: with finite rx bandwidth, a fetch into a destination
        // already receiving another transfer queues on the rx side even
        // though the sources differ.
        let cfg = SimConfig { nic_rx_bw: Some(10e9), ..SimConfig::default() };
        let perf = PerfModel::paper();
        let pool = PrefillPool::new(&cfg);
        let mut res = Resources::new(&cfg, &perf);
        // Node 5 is already pushing 10 GB into node 0 (~1 s of rx).
        res.nic.schedule(5, 0, 0.0, 10_000_000_000);
        let fetch = Some(FetchPlan { src: 3, blocks: 4, src_ssd_blocks: 0 });
        let onto_hot = est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, fetch, 0.0);
        let onto_cold = est(&perf, &cfg, &pool, &res, 1, 4_096, 2_048, 0, fetch, 0.0);
        assert!(
            onto_hot.fetch_wait_ms > onto_cold.fetch_wait_ms + 500.0,
            "incast onto the hot node must surface: {} vs {}",
            onto_hot.fetch_wait_ms,
            onto_cold.fetch_wait_ms
        );
    }

    #[test]
    fn fetch_overlaps_queue_wait() {
        let (cfg, perf, mut pool, mut res) = env();
        pool.instances[0].block_until(5_000.0);
        res.nic.schedule(3, 1, 0.0, 300_000_000_000); // ~3 s source backlog
        let fetch = Some(FetchPlan { src: 3, blocks: 4, src_ssd_blocks: 0 });
        let est = est(&perf, &cfg, &pool, &res, 0, 4_096, 2_048, 0, fetch, 0.0);
        // start = max(queue, fetch), not their sum.
        assert!(est.queue_wait_ms >= 5_000.0);
        assert!(est.fetch_wait_ms > 2_000.0 && est.fetch_wait_ms < 5_000.0);
        assert!((est.start - 5_000.0).abs() < 1e-6, "start={}", est.start);
    }

    #[test]
    fn fetch_charges_source_ssd_staging_before_the_wire() {
        // A source holding the fetched prefix on its SSD tier must stage
        // it into DRAM before the NIC can serialize — the estimate pays
        // the source's NVMe queue *then* the wire, serially.
        let (cfg, perf, pool, res) = env();
        let blocks = 64usize;
        let dram = FetchPlan { src: 3, blocks, src_ssd_blocks: 0 };
        let ssd = FetchPlan { src: 3, blocks, src_ssd_blocks: blocks };
        let a = est(&perf, &cfg, &pool, &res, 0, 4_096, 0, 0, Some(dram), 0.0);
        let b = est(&perf, &cfg, &pool, &res, 0, 4_096, 0, 0, Some(ssd), 0.0);
        let stage = estimate_stage_done(&perf, &res.nvme, 3, 0.0, blocks as u64 * BLOCK_TOKENS);
        assert!(stage > 1_000.0);
        assert!(
            (b.fetch_wait_ms - a.fetch_wait_ms - stage).abs() < 1e-9,
            "SSD-held source must add exactly the staging latency: {} vs {} (+{stage})",
            b.fetch_wait_ms,
            a.fetch_wait_ms
        );
        assert!((b.end - a.end - stage).abs() < 1e-9);
    }

    #[test]
    fn estimate_reads_group_queue_not_just_primary() {
        let (cfg, perf, mut pool, res) = env();
        // Only instance 1 is recruitable (others exceed the 1 ms recruit
        // threshold); its 0.5 ms backlog must drive the planned start.
        pool.instances[1].block_until(0.5);
        for i in 2..pool.len() {
            pool.instances[i].block_until(10.0);
        }
        let group = pool.cpp_group(&cfg, 0, 100_000, 0.0);
        assert_eq!(group, vec![0, 1]);
        let e = estimate_prefill(&perf, &cfg, &pool, &res, &group, 100_000, 0, 0, None, 0.0);
        assert!((e.start - 0.5).abs() < 1e-9, "group max drives start: {}", e.start);
        assert!((e.queue_wait_ms - 0.5).abs() < 1e-9);
    }

    #[test]
    fn kv_arrival_no_earlier_than_prefill_end() {
        let (_, perf, _, res) = env();
        let a = estimate_kv_arrival(&perf, &res, 0, 9, 100.0, 5_000.0, 1_000);
        assert!(a >= 5_000.0);
        // Huge stream on a short prefill: the wire dominates.
        let b = estimate_kv_arrival(&perf, &res, 0, 9, 100.0, 200.0, 100_000);
        assert!(b > 200.0 + 100.0);
    }
}
