//! Messenger — the (GPUDirect-)RDMA KVCache transfer engine (§3), now a
//! thin wrapper over two [`BwQueue`] NIC banks.
//!
//! Each node runs a Messenger endpoint that owns the node's NIC.
//! Transfers out of a node serialize on its **tx** queue — the
//! congestion effect §6.1 worries about ("high demand on the KVCache
//! server can lead to network congestion, prolonging the waiting time")
//! and the reason hot blocks must be replicated (§6.2).  Transfers into
//! a node additionally serialize on its **rx** queue, so fan-in onto one
//! hot node (incast — many holders pushing prefixes at a single prefill
//! instance) congests too: a transfer completes at the **max** of its
//! source-tx and destination-rx completion.
//!
//! With infinite rx bandwidth (the default — `SimConfig::nic_rx_bw` is
//! `None`) the rx side never contributes and behavior is bit-for-bit the
//! pre-refactor source-NIC-only model.
//!
//! The simulator uses [`Messenger::estimate_done`] for Conductor's
//! `EstimateKVCacheTransferTime` (a *read-only* probe) and
//! [`Messenger::schedule`] to actually enqueue the transfer.

use crate::resource::BwQueue;
use crate::TimeMs;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// When the source NIC begins serializing.
    pub start: TimeMs,
    /// When the transfer has fully landed: max(source-tx, destination-rx).
    pub end: TimeMs,
    pub bytes: u64,
}

#[derive(Debug)]
pub struct Messenger {
    /// Outgoing (source-side) NIC queues: setup latency + wire
    /// serialization.
    pub tx: BwQueue,
    /// Incoming (destination-side) NIC capacity: pure bandwidth, no
    /// extra setup (the rendezvous was paid on the tx side).  Holds the
    /// rx speed and the aggregate counters; occupancy itself lives in
    /// `rx_windows`.
    pub rx: BwQueue,
    /// Per-destination busy intervals `(start, end)`, sorted and
    /// disjoint: each admitted transfer books the window where its bytes
    /// actually cross the ingress wire.
    rx_windows: Vec<Vec<(TimeMs, TimeMs)>>,
    /// Finite ingress bandwidth?  When false (unconstrained, the
    /// default) the rx side is a true no-op — no ops recorded, no state
    /// touched — so default runs are the pre-rx model *exactly*.
    rx_active: bool,
}

/// Earliest start `>= lb` of a `dur`-long slot among sorted disjoint
/// busy `windows` — first-fit into the gaps.  Expired windows need not
/// be pruned first: anything ending at or before `lb` is skipped.
fn earliest_gap(windows: &[(TimeMs, TimeMs)], lb: TimeMs, dur: f64) -> TimeMs {
    let mut s = lb;
    for &(a, b) in windows {
        if b <= s {
            continue;
        }
        if s + dur <= a {
            break;
        }
        s = b;
    }
    s
}

impl Messenger {
    /// `n_nodes` NICs sending at `tx_bw` B/s and receiving at `rx_bw`
    /// B/s (`f64::INFINITY` = unconstrained ingress), with `latency_ms`
    /// per-transfer setup cost.
    pub fn new(n_nodes: usize, tx_bw: f64, rx_bw: f64, latency_ms: f64) -> Self {
        Messenger {
            tx: BwQueue::new(n_nodes, tx_bw, latency_ms),
            rx: BwQueue::new(n_nodes, rx_bw, 0.0),
            rx_windows: vec![Vec::new(); n_nodes],
            rx_active: rx_bw.is_finite(),
        }
    }

    /// The rx placement both [`Self::estimate_done`] and
    /// [`Self::schedule`] compute: the transfer's ingress window starts
    /// no earlier than `now` and no earlier than `tx_end - d` (its bytes
    /// cannot finish landing before the source has sent them), first-fit
    /// into the destination's gaps.  Returns `(start, dur)`.
    fn rx_slot(&self, dst: usize, now: TimeMs, tx_end: TimeMs, bytes: u64) -> (TimeMs, f64) {
        let d = self.rx.serialize_ms(dst, bytes, 0.0);
        let lb = now.max(tx_end - d);
        (earliest_gap(&self.rx_windows[dst], lb, d), d)
    }

    /// Absolute landing time if a transfer of `bytes` from `src` to
    /// `dst` were enqueued now — includes queueing behind in-flight
    /// transfers on the source tx queue *and* the destination's booked
    /// ingress windows.  Read-only, and bit-for-bit what
    /// [`Self::schedule`] would return.
    ///
    /// Modeling note: ingress capacity is booked as a per-op *interval*
    /// at the time the bytes actually arrive (PR 4's admission-order rx
    /// FIFO reserved from probe time instead, so a tx-backlogged
    /// transfer blocked later senders out of the gap in front of its own
    /// arrival).  First-fit over sorted disjoint windows keeps the
    /// estimate==schedule contract: the probe runs the identical
    /// placement against the identical windows.
    pub fn estimate_done(&self, src: usize, dst: usize, now: TimeMs, bytes: u64) -> TimeMs {
        let tx_end = self.tx.estimate_done(src, now, bytes, 0.0);
        if !self.rx_active {
            return tx_end;
        }
        let (s, d) = self.rx_slot(dst, now, tx_end, bytes);
        tx_end.max(s + d)
    }

    /// Landing delay (ms from `now`) of the same probe.
    pub fn estimate_ms(&self, src: usize, dst: usize, now: TimeMs, bytes: u64) -> f64 {
        self.estimate_done(src, dst, now, bytes) - now
    }

    /// Enqueue a transfer from `src` to `dst`; returns its (start, end).
    pub fn schedule(&mut self, src: usize, dst: usize, now: TimeMs, bytes: u64) -> Transfer {
        let tx = self.tx.schedule(src, now, bytes, 0.0);
        if !self.rx_active {
            return Transfer { start: tx.start, end: tx.end, bytes };
        }
        let (s, d) = self.rx_slot(dst, now, tx.end, bytes);
        // Book the window: drop expired intervals (they can never
        // constrain a future placement — every later probe has `lb >=
        // now`), insert in start order.  The probe above skipped the
        // expired ones anyway, so pruning preserves estimate == schedule.
        let windows = &mut self.rx_windows[dst];
        windows.retain(|&(_, b)| b > now);
        let pos = windows.partition_point(|&(a, _)| a < s);
        windows.insert(pos, (s, s + d));
        self.rx.n_ops += 1;
        self.rx.total_bytes += bytes;
        self.rx.busy_ms += d;
        self.rx.queued_ms += s - now.max(tx.end - d);
        Transfer { start: tx.start, end: tx.end.max(s + d), bytes }
    }

    /// Current outgoing-queue depth of a node in ms (the congestion
    /// signal for replication decisions).
    pub fn backlog_ms(&self, src: usize, now: TimeMs) -> f64 {
        self.tx.backlog_ms(src, now)
    }

    /// Current incoming-queue depth of a node in ms (the incast signal):
    /// how far past `now` the destination's last booked window reaches.
    pub fn rx_backlog_ms(&self, dst: usize, now: TimeMs) -> f64 {
        if !self.rx_active {
            return 0.0;
        }
        self.rx_windows[dst].last().map_or(0.0, |&(_, b)| (b - now).max(0.0))
    }

    /// Wire bytes moved (each transfer counted once, on the tx side).
    pub fn total_bytes(&self) -> u64 {
        self.tx.total_bytes
    }

    pub fn n_transfers(&self) -> u64 {
        self.tx.n_ops
    }

    /// Total time transfers spent queued (tx and rx congestion).
    pub fn queued_ms(&self) -> f64 {
        self.tx.queued_ms + self.rx.queued_ms
    }

    pub fn n_nodes(&self) -> usize {
        self.tx.n_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Messenger {
        // 100 GB/s tx (800 Gbps), unconstrained rx, 1 ms latency, 4 nodes.
        Messenger::new(4, 100e9, f64::INFINITY, 1.0)
    }

    #[test]
    fn uncongested_transfer_time() {
        let mut msg = m();
        // 5.24 GB (16k tokens of 70B KVCache) -> ~52.4 ms + 1 ms latency.
        let t = msg.schedule(0, 1, 0.0, 5_242_880_000);
        assert!((t.end - t.start - 53.4).abs() < 0.5, "{t:?}");
        assert_eq!(t.start, 0.0);
    }

    #[test]
    fn same_nic_serializes() {
        let mut msg = m();
        let a = msg.schedule(0, 1, 0.0, 1_000_000_000);
        let b = msg.schedule(0, 2, 0.0, 1_000_000_000);
        assert_eq!(b.start, a.end);
        assert!(msg.queued_ms() > 0.0);
        // Different NIC does not queue.
        let c = msg.schedule(1, 2, 0.0, 1_000_000_000);
        assert_eq!(c.start, 0.0);
    }

    #[test]
    fn estimate_matches_schedule() {
        let mut msg = m();
        msg.schedule(2, 0, 0.0, 2_000_000_000);
        let est = msg.estimate_done(2, 0, 5.0, 1_000_000_000);
        let t = msg.schedule(2, 0, 5.0, 1_000_000_000);
        assert_eq!(est.to_bits(), t.end.to_bits());
    }

    #[test]
    fn backlog_decays_with_time() {
        let mut msg = m();
        msg.schedule(0, 1, 0.0, 10_000_000_000); // 100ms serialize + 1ms
        assert!(msg.backlog_ms(0, 0.0) > 100.0);
        assert!(msg.backlog_ms(0, 50.0) < msg.backlog_ms(0, 0.0));
        assert_eq!(msg.backlog_ms(0, 1_000.0), 0.0);
    }

    #[test]
    fn infinite_rx_never_contributes() {
        // The pre-refactor pin: with unconstrained ingress, fan-in onto
        // one destination is timed purely by each source's tx queue and
        // the rx bank records nothing at all.
        let mut msg = m();
        let a = msg.schedule(0, 3, 0.0, 1_000_000_000);
        let b = msg.schedule(1, 3, 0.0, 1_000_000_000);
        assert_eq!(a.end.to_bits(), b.end.to_bits());
        assert_eq!(msg.rx_backlog_ms(3, 0.0), 0.0);
        assert_eq!(msg.rx.n_ops, 0);
    }

    #[test]
    fn finite_rx_serializes_incast() {
        // 100 GB/s tx but only 10 GB/s rx: two senders converging on one
        // destination land one after the other on the rx queue.
        let mut msg = Messenger::new(4, 100e9, 10e9, 1.0);
        let bytes = 1_000_000_000u64; // 100 ms at rx speed, 10 ms at tx
        let a = msg.schedule(0, 3, 0.0, bytes);
        let b = msg.schedule(1, 3, 0.0, bytes);
        assert!((a.end - 100.0).abs() < 1e-6, "rx-bound landing: {a:?}");
        assert!((b.end - 200.0).abs() < 1e-6, "incast serializes: {b:?}");
        assert!(msg.rx_backlog_ms(3, 0.0) > 100.0);
        // A transfer to an idle destination is unaffected.
        let c = msg.schedule(2, 0, 0.0, bytes);
        assert!((c.end - 100.0).abs() < 1e-6);
        // Estimates see the rx queue exactly.
        let est = msg.estimate_done(2, 3, 0.0, bytes);
        let d = msg.schedule(2, 3, 0.0, bytes);
        assert_eq!(est.to_bits(), d.end.to_bits());
        assert!((d.end - 300.0).abs() < 1e-6);
    }

    #[test]
    fn later_sender_interleaves_into_rx_gap() {
        // The admission-order rx FIFO reserved ingress from probe time,
        // so a tx-backlogged transfer held its rx slot while its bytes
        // were still queued at the source and a later sender to the same
        // destination serialized behind a reservation whose bytes hadn't
        // even left.  The interval model books the window where the
        // bytes actually arrive, so the later sender lands in the gap in
        // front of it.
        let mut msg = Messenger::new(4, 100e9, 10e9, 1.0);
        // ~1001 ms of tx backlog on node 0.
        msg.schedule(0, 2, 0.0, 100_000_000_000);
        // Transfer a (0 -> 3): tx start 1001, landed 1012; its ingress
        // window is the last 100 ms of wire time, [912, 1012].
        let est_a = msg.estimate_done(0, 3, 0.0, 1_000_000_000);
        let a = msg.schedule(0, 3, 0.0, 1_000_000_000);
        assert_eq!(est_a.to_bits(), a.end.to_bits());
        assert!((a.end - 1012.0).abs() < 1e-6, "{a:?}");
        // Transfer b (1 -> 3): idle tx, its 100 ms ingress window fits
        // entirely in the gap before a's.  The old FIFO parked it at
        // 200 behind a's phantom reservation; the interval model lands
        // it the moment its own wire time is done.
        let est_b = msg.estimate_done(1, 3, 0.0, 1_000_000_000);
        let b = msg.schedule(1, 3, 0.0, 1_000_000_000);
        assert_eq!(est_b.to_bits(), b.end.to_bits());
        assert!((b.end - 100.0).abs() < 1e-6, "later sender must use the gap: {b:?}");
        // A third transfer still fits in the gap, right behind b.
        let c = msg.schedule(1, 3, 0.0, 1_000_000_000);
        assert!((c.end - 200.0).abs() < 1e-6, "{c:?}");
        // The rx side accounted for all three landings.
        assert_eq!(msg.rx.n_ops, 3);
        assert!(msg.rx_backlog_ms(3, 0.0) > 1_000.0);
    }
}
